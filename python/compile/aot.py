"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (NOT ``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Run once via ``make artifacts``; the Rust runtime
(rust/src/runtime/) loads every ``*.hlo.txt`` listed in
``artifacts/manifest.json`` at startup. Python never runs on the request
path.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# Candidate batch width the Rust side pads to. One row per candidate plan.
PLAN_BATCH = 64
# Feature-row batch for the comm-time model.
COMM_BATCH = 256
# Physical torus extent of the 4096-XPU cluster (16x16x16 node coordinates).
TORUS = (16, 16, 16)

# (artifact stem, cube count C, cube side N). 64*4^3 = 8*8^3 = 512*2^3 = 4096.
SCORER_VARIANTS = [
    ("plan_scorer_n4", 64, 4),
    ("plan_scorer_n8", 8, 8),
    ("plan_scorer_n2", 512, 2),
]


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_scorer(cubes: int, n: int) -> str:
    occ = jax.ShapeDtypeStruct((PLAN_BATCH, cubes, n, n, n), jnp.float32)
    loads = jax.ShapeDtypeStruct((3,) + TORUS, jnp.float32)
    mask = jax.ShapeDtypeStruct((PLAN_BATCH,) + TORUS, jnp.float32)
    return to_hlo_text(jax.jit(model.plan_score).lower(occ, loads, mask))


def lower_comm_model() -> str:
    feat = jax.ShapeDtypeStruct((COMM_BATCH, ref.COMM_FEATURES), jnp.float32)
    return to_hlo_text(jax.jit(model.comm_time).lower(feat))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file target; "
                    "writes the n4 scorer there and the rest alongside")
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    out_dir = out_dir or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "plan_batch": PLAN_BATCH,
        "comm_batch": COMM_BATCH,
        "torus": list(TORUS),
        "score_cols": model.SCORE_COLS,
        "comm_features": ref.COMM_FEATURES,
        "modules": {},
    }

    for stem, cubes, n in SCORER_VARIANTS:
        text = lower_scorer(cubes, n)
        path = os.path.join(out_dir, f"{stem}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["modules"][stem] = {
            "file": f"{stem}.hlo.txt",
            "kind": "plan_scorer",
            "cubes": cubes,
            "cube_side": n,
        }
        print(f"wrote {path} ({len(text)} chars)")

    text = lower_comm_model()
    path = os.path.join(out_dir, "comm_model.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["modules"]["comm_model"] = {
        "file": "comm_model.hlo.txt",
        "kind": "comm_model",
    }
    print(f"wrote {path} ({len(text)} chars)")

    if args.out:
        # Legacy Makefile target: alias of the n4 scorer.
        n4 = os.path.join(out_dir, "plan_scorer_n4.hlo.txt")
        with open(n4) as f, open(args.out, "w") as g:
            g.write(f.read())
        print(f"wrote {args.out} (alias of plan_scorer_n4)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
