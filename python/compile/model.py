"""L2: the RFold plan-scoring graph, composed from the L1 Pallas kernels.

This is the numeric hot spot of the scheduler: every placement decision
evaluates up to K candidate plans; the score vector drives the ranking
heuristic in the Rust coordinator (fewest cubes / fewest OCS links / least
fragmentation, §3.1 of the paper).

Lowered ONCE by ``aot.py`` to HLO text; the Rust runtime loads and runs the
artifact via PJRT. Python never executes on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import contention, frag, ref

# Combined score width: frag stats ++ contention stats ++ 1 composite rank.
SCORE_COLS = ref.FRAG_STATS + ref.CONT_STATS + 1

# Ranking weights (mirrored in rust/src/placement/score.rs — keep in sync).
# Lower composite = better plan.
W_PARTIAL_CUBES = 64.0  # paper heuristic: touch the fewest cubes
W_STRANDED = 8.0  # §3.2 inefficiency 1: unreachable core XPUs
W_THRU_LOST = 1.0  # every blocked pass-through position costs OCS options
W_TRANSITIONS = 0.5  # surface fragmentation proxy
W_MAX_LOAD = 32.0  # contention dominates when links are shared


def plan_score(occ: jnp.ndarray, loads: jnp.ndarray, mask: jnp.ndarray) -> tuple:
    """Score K candidate plans.

    Args:
      occ:   f32[K, C, N, N, N] post-plan cube occupancy.
      loads: f32[3, X, Y, Z] current per-axis link loads.
      mask:  f32[K, X, Y, Z] nodes each plan would occupy.

    Returns:
      1-tuple of f32[K, SCORE_COLS]: frag stats, contention stats, composite.
    """
    f = frag.frag_stats(occ)  # [K, 6]
    c = contention.contention_stats(loads, mask)  # [K, 3]
    n = occ.shape[2]
    cubes = occ.shape[1]
    max_thru = 3.0 * n * n * cubes
    composite = (
        W_PARTIAL_CUBES * f[:, 1]
        + W_STRANDED * f[:, 2]
        + W_THRU_LOST * (max_thru - f[:, 3])
        + W_TRANSITIONS * f[:, 4]
        + W_MAX_LOAD * c[:, 0]
    )
    return (jnp.concatenate([f, c, composite[:, None]], axis=1),)


def plan_score_ref(occ: jnp.ndarray, loads: jnp.ndarray, mask: jnp.ndarray) -> tuple:
    """Oracle twin of :func:`plan_score` built on the pure-jnp kernels."""
    f = ref.frag_stats(occ)
    c = ref.contention_stats(loads, mask)
    n = occ.shape[2]
    cubes = occ.shape[1]
    max_thru = 3.0 * n * n * cubes
    composite = (
        W_PARTIAL_CUBES * f[:, 1]
        + W_STRANDED * f[:, 2]
        + W_THRU_LOST * (max_thru - f[:, 3])
        + W_TRANSITIONS * f[:, 4]
        + W_MAX_LOAD * c[:, 0]
    )
    return (jnp.concatenate([f, c, composite[:, None]], axis=1),)


def comm_time(feat: jnp.ndarray) -> tuple:
    """AllReduce step-time model over a feature batch (see kernels.ref)."""
    return (contention.comm_time(feat),)
