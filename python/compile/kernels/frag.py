"""Pallas kernel: batched fragmentation scoring of candidate plans.

TPU-oriented layout (see DESIGN.md §Hardware-Adaptation): the grid iterates
over candidate plans; each program instance streams one plan's full cube
occupancy block (C·N³ f32 ≈ 16 KiB for the 64×4³ cluster — far below VMEM)
from HBM into VMEM and reduces it with dense VPU ops. No scalar loops, no
atomics: the output block is indexed by the grid so each instance owns its
row.

``interpret=True`` is mandatory on this image — real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _frag_kernel(occ_ref, out_ref, *, n: int):
    """Scores one plan: occ_ref is ``f32[1, C, N, N, N]`` in VMEM."""
    occ = occ_ref[0]  # [C, N, N, N]
    free = 1.0 - occ
    per_cube_busy = occ.sum(axis=(1, 2, 3))  # [C]
    total_free = free.sum()
    is_partial = jnp.logical_and(per_cube_busy > 0.0, per_cube_busy < n**3)
    partial_cubes = is_partial.astype(jnp.float32).sum()
    empty_cubes = (per_cube_busy == 0.0).astype(jnp.float32).sum()

    if n >= 3:
        stranded = free[:, 1 : n - 1, 1 : n - 1, 1 : n - 1].sum()
    else:
        stranded = jnp.float32(0.0)

    thru = (
        (free[:, 0, :, :] * free[:, n - 1, :, :]).sum()
        + (free[:, :, 0, :] * free[:, :, n - 1, :]).sum()
        + (free[:, :, :, 0] * free[:, :, :, n - 1]).sum()
    )

    transitions = (
        jnp.abs(occ[:, 1:, :, :] - occ[:, :-1, :, :]).sum()
        + jnp.abs(occ[:, :, 1:, :] - occ[:, :, :-1, :]).sum()
        + jnp.abs(occ[:, :, :, 1:] - occ[:, :, :, :-1]).sum()
    )

    out_ref[0, :] = jnp.stack(
        [total_free, partial_cubes, stranded, thru, transitions, empty_cubes]
    ).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def frag_stats(occ: jnp.ndarray) -> jnp.ndarray:
    """Pallas counterpart of :func:`ref.frag_stats` (same contract)."""
    k, c, n = occ.shape[0], occ.shape[1], occ.shape[2]
    kernel = functools.partial(_frag_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, c, n, n, n), lambda i: (i, 0, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, ref.FRAG_STATS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, ref.FRAG_STATS), jnp.float32),
        interpret=True,
    )(occ)
