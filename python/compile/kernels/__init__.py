"""L1: Pallas kernels for RFold's plan-scoring hot spot.

``ref`` holds the pure-jnp oracles; ``frag`` and ``contention`` hold the
Pallas implementations validated against them.
"""

from . import contention, frag, ref  # noqa: F401
