"""Pallas kernels: link-contention scoring and the AllReduce time model.

``contention_stats`` streams one candidate mask (X·Y·Z f32 = 16 KiB at 16³)
plus the shared 3-axis load field into VMEM per program instance and
reduces with dense VPU ops; the torus +1 neighbour shift is expressed with
``jnp.roll`` which lowers to cheap slice/concat pairs.

``comm_time`` is a purely elementwise batch model; a single program instance
processes a row block of the feature matrix.

Both run under ``interpret=True`` (CPU PJRT cannot execute Mosaic calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _contention_kernel(loads_ref, mask_ref, out_ref):
    loads = loads_ref[...]  # [3, X, Y, Z]
    mask = mask_ref[0]  # [X, Y, Z]
    mx = jnp.float32(0.0)
    tot = jnp.float32(0.0)
    cnt = jnp.float32(0.0)
    for axis in range(3):
        rolled = jnp.roll(mask, shift=-1, axis=axis)
        adj = jnp.maximum(mask, rolled)
        masked = adj * loads[axis]
        mx = jnp.maximum(mx, masked.max())
        tot = tot + masked.sum()
        cnt = cnt + adj.sum()
    out_ref[0, :] = jnp.stack([mx, tot, cnt]).astype(jnp.float32)


@jax.jit
def contention_stats(loads: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Pallas counterpart of :func:`ref.contention_stats` (same contract)."""
    k = mask.shape[0]
    x, y, z = mask.shape[1], mask.shape[2], mask.shape[3]
    return pl.pallas_call(
        _contention_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((3, x, y, z), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((1, x, y, z), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ref.CONT_STATS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, ref.CONT_STATS), jnp.float32),
        interpret=True,
    )(loads, mask)


# Rows per program instance for the elementwise comm-time model. 128 rows ×
# 5 features is a natural VPU lane tile.
_COMM_BLOCK = 128


def _comm_kernel(feat_ref, out_ref):
    feat = feat_ref[...]  # [B_blk, 5]
    n = feat[:, 0]
    nbytes = feat[:, 1]
    bw = feat[:, 2]
    has_ring = feat[:, 3]
    cont = feat[:, 4]
    n_safe = jnp.maximum(n, 2.0)
    base = 2.0 * (n_safe - 1.0) / n_safe * nbytes / jnp.maximum(bw, 1e-9)
    line_penalty = jnp.where(has_ring > 0.5, 1.0, 2.0)
    t = base * line_penalty * jnp.maximum(cont, 1.0)
    t = jnp.where(n > 1.5, t, 0.0)
    out_ref[...] = t[:, None].astype(jnp.float32)


@jax.jit
def comm_time(feat: jnp.ndarray) -> jnp.ndarray:
    """Pallas counterpart of :func:`ref.comm_time` (same contract)."""
    b = feat.shape[0]
    blk = min(_COMM_BLOCK, b)
    if b % blk != 0:  # pad to a whole number of blocks, slice after
        pad = blk - b % blk
        feat = jnp.concatenate([feat, jnp.zeros((pad, feat.shape[1]), feat.dtype)])
    padded = feat.shape[0]
    out = pl.pallas_call(
        _comm_kernel,
        grid=(padded // blk,),
        in_specs=[pl.BlockSpec((blk, ref.COMM_FEATURES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, 1), jnp.float32),
        interpret=True,
    )(feat)
    return out[:b]
