"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must agree (allclose) with the function of the same name here, across the
shape/dtype sweep in ``python/tests/``.

All functions operate on float32 0/1 indicator grids so the same HLO runs
unchanged on any PJRT backend.
"""

from __future__ import annotations

import jax.numpy as jnp

# Number of statistic columns emitted per plan by the fragmentation scorer.
FRAG_STATS = 6
# Number of statistic columns emitted per plan by the contention scorer.
CONT_STATS = 3
# Feature columns consumed by the ring-AllReduce step-time model.
COMM_FEATURES = 5


def frag_stats(occ: jnp.ndarray) -> jnp.ndarray:
    """Fragmentation statistics for a batch of candidate plans.

    Args:
      occ: ``f32[K, C, N, N, N]`` occupancy (1.0 = busy) of every cube
        *after* hypothetically committing plan ``k``.

    Returns:
      ``f32[K, FRAG_STATS]`` with columns:
        0. total free XPUs
        1. partially used cubes (neither empty nor full) — the paper's
           "fewest cubes touched" heuristic penalises these
        2. stranded-core free XPUs (free cells with no face exposure;
           unreachable by OCS reconfiguration, §3.2 inefficiency #1)
        3. pass-through capacity: per axis, positions free on *both*
           opposite faces (position-aligned OCS ports, §2) summed
        4. surface transitions free→busy along each axis (fragmentation
           proxy: perimeter of the occupied region)
        5. fully free cubes (the currency of reconfiguration)
    """
    k, c, n = occ.shape[0], occ.shape[1], occ.shape[2]
    free = 1.0 - occ
    per_cube_busy = occ.sum(axis=(2, 3, 4))  # [K, C]
    total_free = free.sum(axis=(1, 2, 3, 4))  # [K]
    is_partial = jnp.logical_and(per_cube_busy > 0.0, per_cube_busy < n**3)
    partial_cubes = is_partial.astype(jnp.float32).sum(axis=1)
    empty_cubes = (per_cube_busy == 0.0).astype(jnp.float32).sum(axis=1)

    if n >= 3:
        core = free[:, :, 1 : n - 1, 1 : n - 1, 1 : n - 1]
        stranded = core.sum(axis=(1, 2, 3, 4))
    else:
        stranded = jnp.zeros((k,), jnp.float32)

    thru_x = (free[:, :, 0, :, :] * free[:, :, n - 1, :, :]).sum(axis=(1, 2, 3))
    thru_y = (free[:, :, :, 0, :] * free[:, :, :, n - 1, :]).sum(axis=(1, 2, 3))
    thru_z = (free[:, :, :, :, 0] * free[:, :, :, :, n - 1]).sum(axis=(1, 2, 3))
    thru = thru_x + thru_y + thru_z

    tx = jnp.abs(occ[:, :, 1:, :, :] - occ[:, :, :-1, :, :]).sum(axis=(1, 2, 3, 4))
    ty = jnp.abs(occ[:, :, :, 1:, :] - occ[:, :, :, :-1, :]).sum(axis=(1, 2, 3, 4))
    tz = jnp.abs(occ[:, :, :, :, 1:] - occ[:, :, :, :, :-1]).sum(axis=(1, 2, 3, 4))
    transitions = tx + ty + tz

    return jnp.stack(
        [total_free, partial_cubes, stranded, thru, transitions, empty_cubes],
        axis=1,
    ).astype(jnp.float32)


def contention_stats(loads: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Contention statistics for a batch of candidate placements.

    Args:
      loads: ``f32[3, X, Y, Z]`` — current traffic load on the *positive*
        direction link of each node, per axis (dimension-order routing
        aggregates both directions onto this field symmetrically).
      mask: ``f32[K, X, Y, Z]`` — 1.0 on nodes the candidate would occupy.

    Returns:
      ``f32[K, CONT_STATS]``: [max load on any adjacent link,
      total load over adjacent links, number of adjacent links].

    A link on axis ``a`` at node ``p`` is *adjacent* to the placement if
    either endpoint (``p`` or its +a torus neighbour) is in the mask.
    """
    k = mask.shape[0]
    maxes, totals, counts = [], [], []
    for axis in range(3):
        rolled = jnp.roll(mask, shift=-1, axis=axis + 1)
        adj = jnp.maximum(mask, rolled)  # [K, X, Y, Z]
        lod = loads[axis][None, :, :, :]  # [1, X, Y, Z]
        masked = adj * lod
        maxes.append(masked.reshape(k, -1).max(axis=1))
        totals.append(masked.reshape(k, -1).sum(axis=1))
        counts.append(adj.reshape(k, -1).sum(axis=1))
    mx = jnp.maximum(jnp.maximum(maxes[0], maxes[1]), maxes[2])
    tot = totals[0] + totals[1] + totals[2]
    cnt = counts[0] + counts[1] + counts[2]
    return jnp.stack([mx, tot, cnt], axis=1).astype(jnp.float32)


def comm_time(feat: jnp.ndarray) -> jnp.ndarray:
    """Ring-AllReduce step-time model (§2, §3.1 calibration).

    Args:
      feat: ``f32[B, COMM_FEATURES]`` columns:
        0. ring length ``n`` (participants)
        1. payload bytes
        2. per-link bandwidth (bytes/s)
        3. has_ring (1.0 if the placement provides a closed cycle,
           0.0 → the logical ring folds back over a line, doubling the
           worst-link load: 2× penalty)
        4. contention multiplier (≥ 1.0; from ``contention_stats``)

    Returns:
      ``f32[B, 1]`` seconds for one AllReduce of ``bytes`` over the ring:
      ``2*(n-1)/n * bytes / bw * line_penalty * contention``.
      Degenerate rings (n <= 1) take 0.
    """
    n = feat[:, 0]
    nbytes = feat[:, 1]
    bw = feat[:, 2]
    has_ring = feat[:, 3]
    cont = feat[:, 4]
    n_safe = jnp.maximum(n, 2.0)
    base = 2.0 * (n_safe - 1.0) / n_safe * nbytes / jnp.maximum(bw, 1e-9)
    line_penalty = jnp.where(has_ring > 0.5, 1.0, 2.0)
    t = base * line_penalty * jnp.maximum(cont, 1.0)
    t = jnp.where(n > 1.5, t, 0.0)
    return t[:, None].astype(jnp.float32)
