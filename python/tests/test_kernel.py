"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Hypothesis sweeps shapes and occupancy densities; every kernel must be
allclose to its ``ref.py`` twin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is installed in CI
    HAVE_HYPOTHESIS = False

from compile.kernels import contention, frag, ref

RTOL = 1e-5
ATOL = 1e-5


def rand_occ(rng, k, c, n, density=0.5):
    return (rng.random((k, c, n, n, n)) < density).astype(np.float32)


def rand_mask(rng, k, dims, density=0.2):
    return (rng.random((k,) + dims) < density).astype(np.float32)


# ---------------------------------------------------------------- frag


@pytest.mark.parametrize("k", [1, 3, 8])
@pytest.mark.parametrize("c,n", [(64, 4), (8, 8), (512, 2), (27, 3)])
def test_frag_matches_ref(k, c, n):
    rng = np.random.default_rng(k * 1000 + c + n)
    occ = jnp.asarray(rand_occ(rng, k, c, n))
    got = frag.frag_stats(occ)
    want = ref.frag_stats(occ)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_frag_all_free():
    occ = jnp.zeros((2, 4, 4, 4, 4), jnp.float32)
    s = np.asarray(frag.frag_stats(occ))
    assert s[0, 0] == 4 * 64  # total free
    assert s[0, 1] == 0  # no partial cubes
    assert s[0, 2] == 4 * 8  # all cores free (2^3 per 4^3 cube)
    assert s[0, 3] == 4 * 3 * 16  # every pass-through open
    assert s[0, 4] == 0  # no transitions
    assert s[0, 5] == 4  # all cubes empty


def test_frag_all_busy():
    occ = jnp.ones((1, 4, 4, 4, 4), jnp.float32)
    s = np.asarray(frag.frag_stats(occ))
    assert s[0, 0] == 0 and s[0, 1] == 0 and s[0, 2] == 0
    assert s[0, 3] == 0 and s[0, 4] == 0 and s[0, 5] == 0


def test_frag_single_cell():
    occ = np.zeros((1, 1, 4, 4, 4), np.float32)
    occ[0, 0, 0, 0, 0] = 1.0  # a corner cell
    s = np.asarray(frag.frag_stats(jnp.asarray(occ)))
    assert s[0, 0] == 63
    assert s[0, 1] == 1  # one partial cube
    assert s[0, 2] == 8  # core untouched
    # corner cell blocks one position on each of the three minus-faces
    assert s[0, 3] == 3 * 16 - 3
    assert s[0, 4] == 3  # one transition along each axis
    assert s[0, 5] == 0


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        k=st.integers(1, 6),
        cn=st.sampled_from([(2, 2), (4, 3), (8, 4), (3, 5)]),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_frag_hypothesis(k, cn, density, seed):
        c, n = cn
        rng = np.random.default_rng(seed)
        occ = jnp.asarray(rand_occ(rng, k, c, n, density))
        np.testing.assert_allclose(
            frag.frag_stats(occ), ref.frag_stats(occ), rtol=RTOL, atol=ATOL
        )


# ---------------------------------------------------------- contention


@pytest.mark.parametrize("k", [1, 4])
@pytest.mark.parametrize("dims", [(16, 16, 16), (4, 4, 4), (8, 4, 2)])
def test_contention_matches_ref(k, dims):
    rng = np.random.default_rng(sum(dims) + k)
    loads = jnp.asarray(rng.random((3,) + dims).astype(np.float32))
    mask = jnp.asarray(rand_mask(rng, k, dims))
    got = contention.contention_stats(loads, mask)
    want = ref.contention_stats(loads, mask)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_contention_empty_mask():
    loads = jnp.ones((3, 4, 4, 4), jnp.float32)
    mask = jnp.zeros((2, 4, 4, 4), jnp.float32)
    s = np.asarray(contention.contention_stats(loads, mask))
    np.testing.assert_allclose(s, 0.0)


def test_contention_counts_wraparound_neighbor():
    # A single node at x=3 (the +x face) is adjacent to the wraparound link
    # whose other endpoint is x=0: both its own +x link and the one at x=2.
    loads = np.zeros((3, 4, 1, 1), np.float32)
    loads[0, 3, 0, 0] = 5.0  # node's own +x link
    loads[0, 2, 0, 0] = 2.0  # predecessor's +x link (we are its +neighbour)
    mask = np.zeros((1, 4, 1, 1), np.float32)
    mask[0, 3, 0, 0] = 1.0
    s = np.asarray(
        contention.contention_stats(jnp.asarray(loads), jnp.asarray(mask))
    )
    assert s[0, 0] == 5.0
    assert s[0, 1] == 7.0


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        k=st.integers(1, 5),
        dims=st.sampled_from([(2, 2, 2), (4, 4, 4), (5, 3, 2), (16, 4, 4)]),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_contention_hypothesis(k, dims, density, seed):
        rng = np.random.default_rng(seed)
        loads = jnp.asarray((rng.random((3,) + dims) * 10).astype(np.float32))
        mask = jnp.asarray(rand_mask(rng, k, dims, density))
        np.testing.assert_allclose(
            contention.contention_stats(loads, mask),
            ref.contention_stats(loads, mask),
            rtol=RTOL,
            atol=ATOL,
        )


# ----------------------------------------------------------- comm_time


@pytest.mark.parametrize("b", [1, 7, 128, 300])
def test_comm_time_matches_ref(b):
    rng = np.random.default_rng(b)
    feat = np.stack(
        [
            rng.integers(1, 64, b).astype(np.float32),  # ring length
            rng.random(b).astype(np.float32) * 1e9,  # bytes
            np.full(b, 25e9, np.float32),  # bw
            (rng.random(b) < 0.5).astype(np.float32),  # has_ring
            1.0 + rng.random(b).astype(np.float32) * 3,  # contention
        ],
        axis=1,
    )
    feat = jnp.asarray(feat)
    got = contention.comm_time(feat)
    want = ref.comm_time(feat)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_comm_time_ring_halves_line():
    base = [8.0, 1e9, 25e9, 1.0, 1.0]
    line = [8.0, 1e9, 25e9, 0.0, 1.0]
    feat = jnp.asarray(np.array([base, line], np.float32))
    t = np.asarray(contention.comm_time(feat))
    np.testing.assert_allclose(t[1, 0] / t[0, 0], 2.0, rtol=1e-6)


def test_comm_time_single_node_free():
    feat = jnp.asarray(np.array([[1.0, 1e9, 25e9, 1.0, 1.0]], np.float32))
    assert float(contention.comm_time(feat)[0, 0]) == 0.0
