"""L2 plan-score graph: shapes, composition with kernels, AOT lowering."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def _inputs(k=4, c=8, n=4, torus=(8, 8, 8), seed=0):
    rng = np.random.default_rng(seed)
    occ = jnp.asarray((rng.random((k, c, n, n, n)) < 0.4).astype(np.float32))
    loads = jnp.asarray((rng.random((3,) + torus) * 5).astype(np.float32))
    mask = jnp.asarray((rng.random((k,) + torus) < 0.15).astype(np.float32))
    return occ, loads, mask


def test_plan_score_shape():
    occ, loads, mask = _inputs()
    (s,) = model.plan_score(occ, loads, mask)
    assert s.shape == (4, model.SCORE_COLS)


def test_plan_score_matches_oracle():
    occ, loads, mask = _inputs(k=6, seed=3)
    (got,) = model.plan_score(occ, loads, mask)
    (want,) = model.plan_score_ref(occ, loads, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_composite_prefers_fewer_partial_cubes():
    # Plan A splits a 2x2x2 job across two cubes; plan B packs one cube.
    c, n = 4, 4
    occ = np.zeros((2, c, n, n, n), np.float32)
    occ[0, 0, :2, :2, :1] = 1.0  # half in cube 0
    occ[0, 1, :2, :2, :1] = 1.0  # half in cube 1
    occ[1, 0, :2, :2, :2] = 1.0  # all in cube 0
    loads = np.zeros((3, 8, 8, 8), np.float32)
    mask = np.zeros((2, 8, 8, 8), np.float32)
    (s,) = model.plan_score(jnp.asarray(occ), jnp.asarray(loads), jnp.asarray(mask))
    s = np.asarray(s)
    assert s[1, -1] < s[0, -1], "packed plan must rank better (lower)"


def test_composite_penalizes_contention():
    occ = np.zeros((2, 4, 4, 4, 4), np.float32)
    loads = np.zeros((3, 8, 8, 8), np.float32)
    loads[0, 0, 0, 0] = 10.0
    mask = np.zeros((2, 8, 8, 8), np.float32)
    mask[0, 0, 0, 0] = 1.0  # plan 0 sits on the hot link
    mask[1, 4, 4, 4] = 1.0  # plan 1 avoids it
    (s,) = model.plan_score(jnp.asarray(occ), jnp.asarray(loads), jnp.asarray(mask))
    s = np.asarray(s)
    assert s[1, -1] < s[0, -1]


def test_comm_time_tuple():
    feat = jnp.zeros((8, ref.COMM_FEATURES), jnp.float32)
    (t,) = model.comm_time(feat)
    assert t.shape == (8, 1)


# ------------------------------------------------------------- AOT path


@pytest.mark.parametrize("cubes,n", [(8, 4)])
def test_lower_scorer_emits_hlo(cubes, n):
    text = aot.lower_scorer(cubes, n)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lower_comm_model_emits_hlo():
    text = aot.lower_comm_model()
    assert "HloModule" in text


def test_scorer_variants_cover_cluster():
    for _, cubes, n in aot.SCORER_VARIANTS:
        assert cubes * n**3 == 4096
