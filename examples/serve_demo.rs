//! Live-coordinator demo: spawn the RFold leader with a TCP front end,
//! drive it with a burst of mixed-shape submissions over the socket, and
//! print the stats stream — the "cluster operator" view of the system.
//!
//! Run with: `cargo run --release --example serve_demo`

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use rfold::coordinator::leader::Leader;
use rfold::coordinator::server;
use rfold::placement::builtins;
use rfold::topology::cluster::ClusterTopo;

fn main() {
    // 10'000× time compression: a 1-hour job runs for 360 ms.
    let scale = 1e-4;
    let (handle, join) = Leader::new(
        ClusterTopo::reconfigurable_4096(4),
        builtins::RFOLD,
        scale,
    )
    .spawn();

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    println!("leader listening on {addr}");
    let h2 = handle.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let h = h2.clone();
            std::thread::spawn(move || server::handle_conn(stream, h));
        }
    });

    // A client submits the paper's example jobs plus a burst of small ones.
    let mut conn = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut submit = |a: usize, b: usize, c: usize, dur: f64| {
        writeln!(conn, "SUBMIT {a} {b} {c} {dur}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        println!("  SUBMIT {a}x{b}x{c} {dur}s -> {}", line.trim());
    };

    println!("\nsubmitting the Figure-2 jobs:");
    submit(18, 1, 1, 1800.0);
    submit(1, 6, 4, 3600.0);
    submit(4, 8, 2, 3600.0);
    println!("\nsubmitting a burst of small jobs:");
    for i in 0..12 {
        submit(2, 2 + i % 3, 2, 600.0 + 100.0 * i as f64);
    }
    // An impossible shape is rejected, not queued (FIFO stays live).
    submit(64, 64, 64, 60.0);

    // Poll stats until the cluster drains.
    loop {
        writeln!(conn, "STATS").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        println!("  {}", line.trim());
        if line.contains("\"running\":0") && line.contains("\"queued\":0") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(120));
    }

    writeln!(conn, "QUIT").unwrap();
    handle.shutdown();
    let stats = join.join().unwrap();
    println!(
        "\nfinal: submitted={} finished={} rejected={}",
        stats.submitted, stats.finished, stats.rejected
    );
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.finished, stats.submitted - 1);
    println!("serve_demo OK");
}
