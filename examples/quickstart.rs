//! Quickstart: the RFold public API in ~60 lines.
//!
//! Builds the paper's reconfigurable 4096-XPU cluster (64 cubes of 4³),
//! walks the three Figure-2 jobs through folding + reconfiguration, and
//! prints what each policy decides.
//!
//! Run with: `cargo run --release --example quickstart`

use rfold::placement::policies::{RFold, Reconfig};
use rfold::placement::PlacementPolicy;
use rfold::shape::JobShape;
use rfold::topology::cluster::{ClusterState, ClusterTopo};

fn main() {
    // The paper's evaluation cluster: 64 reconfigurable 4×4×4 cubes.
    let mut cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let mut rfold = RFold::new();
    let mut reconfig = Reconfig::new();

    println!("cluster: {} XPUs, {} free", cluster.num_nodes(), cluster.free_count());

    // The three jobs of Figure 2.
    let jobs = [
        (1u64, JobShape::new(18, 1, 1), "green 1D job (DP-only ring of 18)"),
        (2, JobShape::new(1, 6, 4), "blue 2D job (6-way TP x 4-way DP)"),
        (3, JobShape::new(4, 8, 2), "red 3D job (DP x TP x PP)"),
    ];

    for (id, shape, desc) in jobs {
        println!("\njob {id}: {shape}  — {desc}");

        // What would reconfiguration alone do?
        if let Some(plan) = reconfig.place_now(&cluster, id + 100, shape) {
            println!(
                "  Reconfig : {} as-is, {} cube(s), {} OCS circuits",
                plan.variant.placed,
                plan.cubes.len(),
                plan.ocs_entries()
            );
        }

        // RFold folds the shape first, then reconfigures.
        let plan = rfold.place_now(&cluster, id, shape).expect("placeable");
        println!(
            "  RFold    : folded to {} ({:?}), {} cube(s), {} OCS circuits",
            plan.variant.placed,
            plan.variant.kind,
            plan.cubes.len(),
            plan.ocs_entries()
        );

        // Commit: nodes become busy, OCS circuits are reserved, and the
        // homomorphism of the fold is re-verified in debug builds.
        plan.commit(&mut cluster).expect("commit");
        let alloc = cluster.allocation(id).unwrap();
        println!(
            "  committed: {} XPUs, rings {:?} (len, closed)",
            alloc.nodes.len(),
            alloc.rings
        );
    }

    println!(
        "\nfinal: {} / {} XPUs busy, {} OCS entries reserved",
        cluster.busy_count(),
        cluster.num_nodes(),
        cluster.ocs().unwrap().reserved_entries()
    );
    cluster.check_consistency().expect("invariants hold");
    println!("quickstart OK");
}
