//! End-to-end driver (DESIGN.md §3): run the full system on a realistic
//! workload — a Philly-style synthetic trace on the 4096-XPU reconfigurable
//! cluster — through every policy, and report the paper's headline metrics
//! (JCR / JCT percentiles / utilization). This is the run recorded in
//! EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example philly_sim [-- jobs runs]`

use rfold::metrics::{report, summarize};
use rfold::placement::builtins;
use rfold::sim::engine::{SimConfig, Simulation};
use rfold::topology::cluster::ClusterTopo;
use rfold::trace::gen::{generate, TraceConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let runs: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);

    println!("== RFold end-to-end: {runs} trace(s) x {jobs} jobs on 4096 XPUs ==");

    let cells = [
        ("FirstFit (16^3)", builtins::FIRST_FIT, ClusterTopo::static_4096()),
        ("Folding (16^3)", builtins::FOLDING, ClusterTopo::static_4096()),
        ("Reconfig (4^3)", builtins::RECONFIG, ClusterTopo::reconfigurable_4096(4)),
        ("RFold (4^3)", builtins::RFOLD, ClusterTopo::reconfigurable_4096(4)),
    ];

    let mut summaries = Vec::new();
    for (label, policy, topo) in cells {
        let mut pairs = Vec::new();
        let mut traces = Vec::new();
        for seed in 1..=runs as u64 {
            traces.push(generate(&TraceConfig {
                num_jobs: jobs,
                seed,
                ..Default::default()
            }));
        }
        let t0 = std::time::Instant::now();
        for t in &traces {
            let r = Simulation::new(SimConfig::new(topo, policy)).run(t);
            pairs.push((r, t.as_slice()));
        }
        let s = summarize(label, &pairs);
        println!(
            "{label:<18} jcr={:>6.2}%  jct p50/p90/p99 = {} / {} / {}  util={:.3}  ({:.1}s)",
            s.avg_jcr_pct,
            report::fmt_secs(s.jct_p50),
            report::fmt_secs(s.jct_p90),
            report::fmt_secs(s.jct_p99),
            s.avg_util,
            t0.elapsed().as_secs_f64(),
        );
        summaries.push(s);
    }

    // Headline checks (the paper's qualitative claims).
    let jcr = |l: &str| summaries.iter().find(|s| s.label == l).unwrap().avg_jcr_pct;
    let p50 = |l: &str| summaries.iter().find(|s| s.label == l).unwrap().jct_p50;
    let util = |l: &str| summaries.iter().find(|s| s.label == l).unwrap().avg_util;
    println!("\nheadlines:");
    println!("  JCR  FirstFit {:.1}% < Folding {:.1}% < RFold {:.1}%", jcr("FirstFit (16^3)"), jcr("Folding (16^3)"), jcr("RFold (4^3)"));
    println!("  JCT  RFold/Reconfig p50 speedup = {:.2}x", p50("Reconfig (4^3)") / p50("RFold (4^3)"));
    println!("  UTIL RFold - FirstFit = {:+.1} points (absolute)", 100.0 * (util("RFold (4^3)") - util("FirstFit (16^3)")));
    assert!(jcr("RFold (4^3)") > 99.9, "RFold(4^3) must schedule everything");
    assert!(p50("RFold (4^3)") <= p50("Reconfig (4^3)"), "RFold must not be slower");
    println!("philly_sim OK");
}
