//! Fold explorer: print every homomorphic variant RFold would consider
//! for a job shape, with its cube cost on a given cluster — a debugging /
//! capacity-planning tool for operators.
//!
//! Run with: `cargo run --release --example fold_explorer -- 4 8 2 [cube_n]`

use rfold::placement::reconfig_place;
use rfold::shape::fold::enumerate_variants;
use rfold::shape::{verify, JobShape};
use rfold::topology::cluster::{ClusterState, ClusterTopo};

fn main() {
    let args: Vec<usize> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    let (a, b, c) = match args.as_slice() {
        [a, b, c, ..] => (*a, *b, *c),
        _ => (4, 8, 2),
    };
    let n = args.get(3).copied().unwrap_or(4);
    let shape = JobShape::new(a, b, c);
    let cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(n));

    println!(
        "shape {shape} ({} XPUs, {}D) on {n}^3 cubes:\n",
        shape.size(),
        shape.dimensionality()
    );
    println!(
        "{:<12} {:<36} {:>6} {:>6} {:>8} {:>8}",
        "placed", "fold", "cubes", "ocs", "wrap", "verified"
    );

    let mut best: Option<(usize, String)> = None;
    for v in enumerate_variants(shape, 256) {
        let verified = verify::verify(&v, v.requires_wrap).is_ok();
        let (cubes, ocs, wrap) = match reconfig_place::place(&cluster, &v, 1) {
            Some(p) => (
                p.cubes.len().to_string(),
                p.ocs_entries().to_string(),
                format!("{:?}", p.wrap.map(|w| w as u8)),
            ),
            None => ("-".into(), "-".into(), "unplaceable".into()),
        };
        println!(
            "{:<12} {:<36} {:>6} {:>6} {:>8} {:>8}",
            v.placed.to_string(),
            format!("{:?}", v.kind),
            cubes,
            ocs,
            wrap,
            if verified { "ok" } else { "FAIL" }
        );
        assert!(verified, "generated variants must verify");
        if let Ok(nc) = cubes.parse::<usize>() {
            if best.as_ref().map(|(b, _)| nc < *b).unwrap_or(true) {
                best = Some((nc, v.placed.to_string()));
            }
        }
    }
    match best {
        Some((nc, placed)) => {
            println!("\nRFold would commit: {placed} using {nc} cube(s)");
        }
        None => println!("\nshape is unplaceable on this topology"),
    }
}
