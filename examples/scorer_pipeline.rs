//! Three-layer pipeline demo: the L3 coordinator scoring candidate plans
//! through the AOT-compiled L1 Pallas kernel via PJRT, cross-checked
//! against the native scorer — the full rust↔XLA round trip on real
//! placement decisions.
//!
//! Requires `make artifacts`. Run with:
//! `cargo run --release --example scorer_pipeline`

use std::rc::Rc;

use rfold::placement::policies::RFold;
use rfold::placement::PlacementPolicy;
use rfold::placement::score::{hypothetical_occupancy, NativeScorer, PlanScorer};
use rfold::placement::reconfig_place;
use rfold::runtime::{Artifacts, XlaScorer};
use rfold::shape::fold::enumerate_variants;
use rfold::shape::JobShape;
use rfold::topology::cluster::{ClusterState, ClusterTopo};
use rfold::util::Pcg64;

fn main() {
    let dir = Artifacts::default_dir();
    let arts = match Artifacts::load(&dir) {
        Ok(a) => Rc::new(a),
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "loaded {} AOT modules on PJRT platform '{}'",
        arts.manifest.modules.len(),
        arts.platform()
    );

    // Fill a cluster to ~40% with random jobs, then score candidates for
    // the paper's 4×8×2 example through BOTH scorers.
    let mut cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let mut policy = RFold::new();
    let mut rng = Pcg64::seeded(11);
    let mut id = 0;
    let mut attempts = 0;
    // Origin-anchored placement plateaus before 40% on random fills —
    // bound the attempts and take whatever density we reach.
    while cluster.utilization() < 0.4 && attempts < 2000 {
        attempts += 1;
        let size = rng.range(8, 192);
        if let Some(shape) =
            rfold::trace::gen::shape_for_size(&mut rng, size, &Default::default())
        {
            if let Some(p) = policy.place_now(&cluster, id, shape) {
                p.commit(&mut cluster).unwrap();
                id += 1;
            }
        }
    }
    println!(
        "cluster at {:.0}% utilization with {} jobs",
        100.0 * cluster.utilization(),
        id
    );

    let shape = JobShape::new(4, 8, 2);
    let plans: Vec<_> = enumerate_variants(shape, 256)
        .iter()
        .filter_map(|v| reconfig_place::place(&cluster, v, 9999))
        .collect();
    println!("\n{} candidate plans for {shape}:", plans.len());

    let (occ, cubes, n) = hypothetical_occupancy(&cluster, &plans);
    let native = NativeScorer.frag_stats(&occ, plans.len(), cubes, n);
    let mut xs = XlaScorer::new(arts);
    let t0 = std::time::Instant::now();
    let xla = xs.frag_stats(&occ, plans.len(), cubes, n);
    let dt = t0.elapsed();

    println!(
        "{:<12} {:>7} {:>9} {:>9} {:>11} {:>11}",
        "placed", "cubes", "partial", "stranded", "native", "xla(pjrt)"
    );
    for ((p, ns), xl) in plans.iter().zip(&native).zip(&xla) {
        let comp_n = ns.composite(cubes, n, 0.0);
        let comp_x = xl.composite(cubes, n, 0.0);
        println!(
            "{:<12} {:>7} {:>9} {:>9} {:>11.1} {:>11.1}",
            p.variant.placed.to_string(),
            p.cubes.len(),
            ns.partial_cubes,
            ns.stranded,
            comp_n,
            comp_x
        );
        assert!((comp_n - comp_x).abs() < 1e-2, "scorers disagree");
    }
    println!("\nPJRT batch scored in {dt:?}; native and XLA agree. scorer_pipeline OK");
}
