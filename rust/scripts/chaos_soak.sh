#!/usr/bin/env bash
# Chaos soak for the crash-safe service mode: replay the recorded Philly
# sample into `rfold serve --wal --snapshot-every`, SIGKILL the daemon
# twice mid-replay, restore each time from the snapshot directory + WAL
# suffix, and assert the final DRAIN rows and STATUS are byte-identical
# to an uninterrupted daemon fed the same trace.
#
# Run from the crate root (rust/): BIN=target/release/rfold scripts/chaos_soak.sh
set -euo pipefail

BIN=${BIN:-target/release/rfold}
TRACE=${TRACE:-tests/data/philly_sample.csv}
REF_ADDR=127.0.0.1:17410
DIR=$(mktemp -d)
PIDS=()
cleanup() {
    for p in "${PIDS[@]:-}"; do kill -9 "$p" 2>/dev/null || true; done
    rm -rf "$DIR"
}
trap cleanup EXIT

# Split the sample into three chunks, each keeping the CSV header: the
# kill points sit between chunks, i.e. mid-way through the replay.
header=$(head -1 "$TRACE")
tail -n +2 "$TRACE" >"$DIR/body.csv"
total=$(wc -l <"$DIR/body.csv")
a=$((total / 3))
b=$((2 * total / 3))
{ echo "$header"; head -n "$a" "$DIR/body.csv"; } >"$DIR/chunk1.csv"
{ echo "$header"; sed -n "$((a + 1)),${b}p" "$DIR/body.csv"; } >"$DIR/chunk2.csv"
{ echo "$header"; tail -n +"$((b + 1))" "$DIR/body.csv"; } >"$DIR/chunk3.csv"

wait_up() { # $1 = host:port
    local hp=$1 i
    for i in $(seq 100); do
        if (exec 3<>"/dev/tcp/${hp%:*}/${hp#*:}") 2>/dev/null; then
            return 0
        fi
        sleep 0.1
    done
    echo "chaos: daemon on $hp never came up" >&2
    return 1
}

status_of() { # $1 = host:port → STATUS minus wall-clock latency fields
    local hp=$1
    exec 3<>"/dev/tcp/${hp%:*}/${hp#*:}"
    printf 'STATUS\n' >&3
    head -1 <&3 | sed -E 's/"decision_(p50|p99)_us":[^,}]*,?//g; s/"decisions":[^,}]*,?//g'
    exec 3>&- 3<&- || true
}

# --- Reference: one uninterrupted daemon over the whole trace. ---------
"$BIN" serve --addr $REF_ADDR 2>"$DIR/ref.log" &
PIDS+=($!)
wait_up $REF_ADDR
"$BIN" submit --trace "$TRACE" --addr $REF_ADDR --drain | grep '^ROW ' >"$DIR/ref.rows"
status_of $REF_ADDR >"$DIR/ref.status"

# --- Chaos: three daemon generations sharing one WAL + snapshot dir. ---
WAL="$DIR/arrivals.wal"
SNAPS="$DIR/snaps"
gen=0
for chunk in chunk1 chunk2 chunk3; do
    gen=$((gen + 1))
    addr=127.0.0.1:$((17410 + gen))
    restore=()
    if [ "$gen" -gt 1 ]; then
        restore=(--restore "$SNAPS")
    fi
    "$BIN" serve --addr "$addr" --wal "$WAL" \
        --snapshot-every 30m --snapshot-dir "$SNAPS" --snapshot-keep 3 \
        "${restore[@]}" 2>"$DIR/gen$gen.log" &
    pid=$!
    PIDS+=($pid)
    wait_up "$addr"
    if [ "$chunk" = chunk3 ]; then
        "$BIN" submit --trace "$DIR/$chunk.csv" --addr "$addr" --drain |
            grep '^ROW ' >"$DIR/chaos.rows"
        status_of "$addr" >"$DIR/chaos.status"
    else
        "$BIN" submit --trace "$DIR/$chunk.csv" --addr "$addr"
        kill -9 "$pid" # SIGKILL mid-replay: only the WAL has the tail
        wait "$pid" 2>/dev/null || true
    fi
done

# --- The contract: zero accepted jobs lost, bytes identical. -----------
diff -u "$DIR/ref.rows" "$DIR/chaos.rows" || {
    echo "chaos: DRAIN rows diverged after SIGKILL + restore" >&2
    exit 1
}
diff -u "$DIR/ref.status" "$DIR/chaos.status" || {
    echo "chaos: STATUS diverged after SIGKILL + restore" >&2
    exit 1
}
rows=$(wc -l <"$DIR/chaos.rows")
echo "chaos: OK — $rows rows byte-identical across 2 SIGKILLs ($(grep -c '^J ' "$WAL") journaled jobs)"
