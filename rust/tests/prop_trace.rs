//! Property tests for the trace generator and the scenario registry:
//! every generated shape respects the rule caps, arrivals are
//! non-decreasing, and each named scenario yields a non-empty trace whose
//! every job is placeable on an empty Reconfig(4³) cluster — the Table-1
//! invariant that keeps 100% JCR reachable.

use rfold::placement::policies::Reconfig;
use rfold::placement::PlacementPolicy;
use rfold::shape::JobShape;
use rfold::topology::cluster::ClusterTopo;
use rfold::trace::gen::{generate, shape_for_size, ShapeRule};
use rfold::trace::scenarios::Scenario;
use rfold::util::prop::{check, expect};

/// Cost of a shape in 4³ cubes (the Reconfig(4³) feasibility measure).
fn cubes4(s: JobShape) -> usize {
    s.dims().0.iter().map(|&d| d.div_ceil(4)).product()
}

#[test]
fn generated_shapes_respect_rule_caps_across_scenarios() {
    check("shape caps", 30, |rng| {
        let sc = Scenario::ALL[rng.below(Scenario::ALL.len())];
        let cfg = sc.trace_config(rng.range(1, 120), rng.next_u64());
        let rule = cfg.shape_rule;
        let t = generate(&cfg);
        expect(t.len() == cfg.num_jobs, format!("{sc:?}: wrong job count"))?;
        for j in &t {
            let dims = j.shape.dims().0;
            expect(
                dims.iter().all(|d| (1..=rule.max_dim).contains(d)),
                format!("{sc:?}: {} exceeds max_dim {}", j.shape, rule.max_dim),
            )?;
            expect(
                cubes4(j.shape) <= rule.max_cubes4,
                format!(
                    "{sc:?}: {} needs {} cubes > {}",
                    j.shape,
                    cubes4(j.shape),
                    rule.max_cubes4
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn arrivals_non_decreasing_across_scenarios() {
    check("arrivals monotone", 30, |rng| {
        let sc = Scenario::ALL[rng.below(Scenario::ALL.len())];
        let cfg = sc.trace_config(rng.range(2, 150), rng.next_u64());
        let t = generate(&cfg);
        for w in t.windows(2) {
            expect(
                w[1].arrival >= w[0].arrival,
                format!("{sc:?}: arrival went backwards at job {}", w[1].id),
            )?;
        }
        expect(t[0].arrival >= 0.0, "negative first arrival")?;
        Ok(())
    });
}

#[test]
fn durations_and_comm_fraction_within_configured_bounds() {
    check("duration/comm bounds", 30, |rng| {
        let sc = Scenario::ALL[rng.below(Scenario::ALL.len())];
        let cfg = sc.trace_config(rng.range(1, 100), rng.next_u64());
        for j in generate(&cfg) {
            expect(
                (cfg.dur_min..=cfg.dur_max).contains(&j.duration),
                format!("{sc:?}: duration {} out of bounds", j.duration),
            )?;
            expect(
                (cfg.comm_lo..cfg.comm_hi).contains(&j.comm_frac),
                format!(
                    "{sc:?}: comm_frac {} outside [{}, {})",
                    j.comm_frac, cfg.comm_lo, cfg.comm_hi
                ),
            )?;
            expect(
                (1..=4096).contains(&j.size()),
                format!("{sc:?}: size {} out of cluster range", j.size()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn every_scenario_is_nonempty_and_placeable_on_empty_reconfig4() {
    let topo = ClusterTopo::reconfigurable_4096(4);
    for sc in Scenario::ALL {
        let t = generate(&sc.trace_config(80, 7));
        assert!(!t.is_empty(), "{sc:?}: empty trace");
        let mut policy = Reconfig::new();
        for j in &t {
            assert!(
                policy.feasible_ever(topo, j.shape),
                "{sc:?}: job {} shape {} not placeable on empty Reconfig(4^3)",
                j.id,
                j.shape
            );
        }
    }
}

#[test]
fn shape_for_size_respects_caps_under_scenario_rules() {
    // The per-scenario ShapeRule variants must uphold the same caps the
    // default rule guarantees.
    check("shape_for_size caps", 40, |rng| {
        let sc = Scenario::ALL[rng.below(Scenario::ALL.len())];
        let rule: ShapeRule = sc.trace_config(1, 1).shape_rule;
        let size = rng.range(1, 4096);
        if let Some(s) = shape_for_size(rng, size, &rule) {
            expect(s.size() == size, format!("size mismatch for {size}"))?;
            expect(
                s.dims().0.iter().all(|&d| d <= rule.max_dim),
                format!("{s} exceeds max_dim"),
            )?;
            expect(cubes4(s) <= rule.max_cubes4, format!("{s} exceeds cube cap"))?;
        }
        Ok(())
    });
}
