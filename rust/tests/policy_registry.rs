//! Acceptance suite for the open placement-policy API:
//!
//! * an **external policy** defined entirely in this file — its own
//!   module plus exactly one `PolicyRegistry::register` line — runs
//!   end-to-end through the unmodified engine, demonstrating that adding
//!   a policy requires no edits anywhere else;
//! * a **smoke matrix**: every registered policy runs a small trace on
//!   both topology families, and every decision is `Placed` or a
//!   structured rejection — never a panic;
//! * a **parse → name round-trip** over all registry entries (keys,
//!   aliases, case-insensitivity, display names).

use std::sync::Once;

use rfold::placement::{
    best_effort, builtins, Attempt, DecisionStats, PlacementDecision, PlacementPolicy,
    PlacementRequest, PolicyCore, PolicyHandle, PolicyRegistry,
};
use rfold::shape::JobShape;
use rfold::sim::{SharedTelemetry, SimConfig, Simulation};
use rfold::topology::cluster::{ClusterState, ClusterTopo};
use rfold::trace::gen::{generate, TraceConfig};

/// The external policy, self-contained: accepts only tiny jobs (≤ 8 XPUs)
/// and scatters them best-effort. Deliberately minimal — the point is the
/// integration surface, not the scheduling quality.
mod tiny_only {
    use super::*;

    #[derive(Default)]
    pub struct TinyOnly {
        core: PolicyCore,
    }

    pub const MAX_XPUS: usize = 8;

    impl PlacementPolicy for TinyOnly {
        fn name(&self) -> &'static str {
            "TinyOnly"
        }

        fn core(&mut self) -> &mut PolicyCore {
            &mut self.core
        }

        fn scattered(&self) -> bool {
            true
        }

        fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
            if shape.size() > MAX_XPUS {
                return Attempt::rejected(DecisionStats::default());
            }
            Attempt::single(best_effort::place_scattered(cluster, job, shape))
        }
    }

    fn make() -> Box<dyn PlacementPolicy> {
        Box::new(TinyOnly::default())
    }

    pub const HANDLE: PolicyHandle =
        PolicyHandle::new("tiny-only", "TinyOnly", &["tiny"], false, false, make);
}

/// One registration line — the entirety of the integration work.
fn ensure_registered() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        PolicyRegistry::global()
            .register(tiny_only::HANDLE)
            .expect("tiny-only registers once");
    });
}

fn small_trace(seed: u64) -> Vec<rfold::trace::JobSpec> {
    generate(&TraceConfig {
        num_jobs: 30,
        seed,
        ..Default::default()
    })
}

#[test]
fn external_policy_runs_end_to_end_without_engine_edits() {
    ensure_registered();
    let handle = PolicyRegistry::global()
        .resolve("tiny-only")
        .expect("registered from this test file");
    assert_eq!(handle, tiny_only::HANDLE);
    assert_eq!(PolicyRegistry::global().resolve("TINY"), Some(handle));

    let trace = small_trace(5);
    let telemetry = SharedTelemetry::new();
    let r = Simulation::new(SimConfig::new(ClusterTopo::static_4096(), handle))
        .with_observer(Box::new(telemetry.clone()))
        .run(&trace);
    assert_eq!(r.policy, "TinyOnly");
    // Every job is accounted for: tiny ones scheduled, big ones dropped
    // as structured Infeasible rejections.
    assert_eq!(r.scheduled + r.dropped, trace.len());
    let tiny = trace
        .iter()
        .filter(|j| j.size() <= tiny_only::MAX_XPUS)
        .count();
    assert_eq!(r.scheduled, tiny, "exactly the tiny jobs get placed");
    let t = telemetry.snapshot();
    assert_eq!(t.placed as usize, r.scheduled);
    assert_eq!(t.infeasible as usize, r.dropped);
}

#[test]
fn registry_smoke_matrix_covers_both_topology_families() {
    ensure_registered();
    let topos = [
        ClusterTopo::static_4096(),
        ClusterTopo::reconfigurable_4096(4),
    ];
    for handle in PolicyRegistry::global().handles() {
        for topo in topos {
            // End-to-end: the engine must finish the trace with every job
            // accounted for, whatever the policy decides.
            let trace = small_trace(7);
            let telemetry = SharedTelemetry::new();
            let r = Simulation::new(SimConfig::new(topo, handle))
                .with_observer(Box::new(telemetry.clone()))
                .run(&trace);
            assert_eq!(
                r.outcomes.len(),
                trace.len(),
                "{} on {topo:?}: every job needs an outcome",
                handle.key()
            );
            let t = telemetry.snapshot();
            assert!(t.decisions > 0, "{} on {topo:?}", handle.key());
            assert_eq!(t.decisions, t.placed + t.no_capacity + t.infeasible);

            // Decision-level: a loaded cluster must still yield structured
            // decisions, and placed plans must commit.
            let mut cluster = ClusterState::new(topo);
            let mut policy = handle.instantiate();
            for (i, job) in trace.iter().take(12).enumerate() {
                let decision =
                    policy.plan(&PlacementRequest::new(i as u64, job.shape, &cluster));
                match decision {
                    PlacementDecision::Placed { plan, stats } => {
                        assert!(stats.candidates >= 1, "{}: placed w/o candidate", handle.key());
                        plan.commit(&mut cluster).unwrap_or_else(|e| {
                            panic!("{} on {topo:?}: commit failed: {e}", handle.key())
                        });
                    }
                    PlacementDecision::Infeasible { .. }
                    | PlacementDecision::NoCapacity { .. } => {}
                }
                cluster.check_consistency().expect("cluster stays consistent");
            }
        }
    }
}

#[test]
fn parse_name_roundtrip_over_all_registry_entries() {
    ensure_registered();
    let reg = PolicyRegistry::global();
    let handles = reg.handles();
    assert!(handles.len() >= 8, "seven builtins + the test-only policy");

    let mut keys = std::collections::BTreeSet::new();
    let mut displays = std::collections::BTreeSet::new();
    for h in &handles {
        // Canonical key round-trips, case-insensitively.
        assert_eq!(reg.resolve(h.key()), Some(*h));
        assert_eq!(reg.resolve(&h.key().to_ascii_uppercase()), Some(*h));
        // Every alias lands on the same handle.
        for a in h.aliases() {
            assert_eq!(reg.resolve(a), Some(*h), "alias {a}");
        }
        // A fresh instance reports the registered display name.
        assert_eq!(h.instantiate().name(), h.name());
        assert!(keys.insert(h.key()), "duplicate key {}", h.key());
        assert!(displays.insert(h.name()), "duplicate display {}", h.name());
    }

    // The deprecated shim agrees with the registry for every builtin it
    // predates; `preempt-rfold` arrived after the enum was frozen and is
    // deliberately absent from it.
    for h in builtins::ALL {
        let Some(kind) = rfold::placement::PolicyKind::parse(h.key()) else {
            assert_eq!(h.key(), "preempt-rfold", "only post-shim builtins may miss the enum");
            continue;
        };
        assert_eq!(kind.handle(), h);
        assert_eq!(kind.name(), h.name());
    }

    // Re-registering any existing entry is rejected.
    for h in handles {
        assert!(reg.register(h).is_err(), "{} re-registered", h.key());
    }
}
