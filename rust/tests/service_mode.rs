//! Service-mode determinism bridge and soak tests.
//!
//! The contract under test: `rfold serve` is the *same scheduler* as
//! `rfold simulate`, not a lookalike. A trace replayed into a live
//! daemon (any wall-clock pacing) and drained must produce `ROW` lines
//! byte-identical to a closed-loop batch run of the accepted jobs, and
//! a snapshot→kill→restore cycle mid-run must lose no accepted job and
//! reproduce those exact bytes.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use rfold::coordinator::pool;
use rfold::coordinator::serve::{spawn_server_on, spawn_server_on_opts, submit_trace, ServeOptions};
use rfold::coordinator::snapshot;
use rfold::coordinator::wal;
use rfold::metrics::report;
use rfold::placement::builtins;
use rfold::shape::JobShape;
use rfold::sim::{SimConfig, Simulation};
use rfold::topology::cluster::ClusterTopo;
use rfold::trace::scenarios::ModifierSet;
use rfold::trace::{self, JobSpec};
use rfold::util::json::Json;

fn synthetic_trace(jobs: usize, seed: u64) -> Vec<JobSpec> {
    trace::gen::generate(&trace::gen::TraceConfig {
        num_jobs: jobs,
        seed,
        ..Default::default()
    })
}

/// The reference bytes: a closed-loop batch run's outcome rows.
fn batch_rows(cfg: SimConfig, t: &[JobSpec]) -> Vec<String> {
    let r = Simulation::new(cfg).run(t);
    report::outcome_rows(&r, t)
}

/// A raw line-protocol client, for the commands `submit_trace` doesn't
/// issue (SNAPSHOT, SHUTDOWN, malformed input).
struct Client {
    out: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        Client {
            out: stream.try_clone().expect("clone"),
            reader: BufReader::new(stream),
        }
    }

    fn cmd(&mut self, line: &str) -> String {
        writeln!(self.out, "{line}").expect("write");
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("read");
        assert!(!reply.is_empty(), "daemon closed on: {line}");
        reply.trim().to_string()
    }
}

fn status_field(status: &str, key: &str) -> usize {
    let j = Json::parse(status.strip_prefix("STATUS ").expect("STATUS prefix"))
        .expect("status json");
    j.get(key)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("no usize field '{key}' in {status}"))
}

#[test]
fn streamed_replay_matches_batch_rows() {
    // Plain and fault-injected: the daemon must match batch bytes even
    // when the engine is drawing from the fault RNG between arrivals.
    for mods in ["", "failures=philly,ocs-latency=5s,stragglers=0.05"] {
        let mut cfg =
            SimConfig::new(ClusterTopo::reconfigurable_4096(4), builtins::RFOLD);
        cfg.modifiers = ModifierSet::parse(mods).expect("mods").for_trial(7);
        let t = synthetic_trace(60, 11);
        let expect = batch_rows(cfg, &t);

        let (addr, _handle, join) =
            spawn_server_on("127.0.0.1:0", cfg, 1024, None).expect("bind");
        let s = submit_trace(&addr.to_string(), &t, 0.0, true).expect("submit");
        assert_eq!(s.accepted, t.len(), "mods '{mods}': every job admitted");
        assert_eq!(s.rejected, 0, "mods '{mods}'");
        assert_eq!(s.errors, 0, "mods '{mods}'");
        assert_eq!(s.rows, expect, "mods '{mods}': daemon bytes != batch bytes");

        assert_eq!(Client::connect(addr).cmd("SHUTDOWN"), "BYE");
        join.join().expect("service thread");
    }
}

#[test]
fn snapshot_kill_restore_preserves_bytes() {
    let mut cfg = SimConfig::new(ClusterTopo::static_4096(), builtins::FIRST_FIT);
    cfg.modifiers = ModifierSet::parse("preempt=priority,checkpoint=3s,migration-cost=30s")
        .expect("mods")
        .for_trial(3);
    let t = synthetic_trace(60, 3);
    let expect = batch_rows(cfg, &t);
    let snap_path = std::env::temp_dir()
        .join(format!("rfold-service-snap-{}.txt", std::process::id()))
        .to_string_lossy()
        .into_owned();

    // First daemon: accept half the trace, snapshot, die.
    let (addr, _handle, join) =
        spawn_server_on("127.0.0.1:0", cfg, 1024, None).expect("bind");
    let s = submit_trace(&addr.to_string(), &t[..30], 0.0, false).expect("submit");
    assert_eq!((s.accepted, s.rejected, s.errors), (30, 0, 0));
    let mut c = Client::connect(addr);
    let reply = c.cmd(&format!("SNAPSHOT {snap_path}"));
    assert!(reply.starts_with("SNAPSHOT-OK"), "{reply}");
    let status = c.cmd("STATUS");
    assert_eq!(status_field(&status, "admitted"), 30);
    assert_eq!(c.cmd("SHUTDOWN"), "BYE");
    join.join().expect("service thread");

    // Second daemon: restore, finish the trace, drain.
    let snap = snapshot::load(&snap_path).expect("load snapshot");
    assert_eq!(snap.jobs.len(), 30);
    assert_eq!(snap.submitted, 30);
    let (addr2, _handle2, join2) =
        spawn_server_on("127.0.0.1:0", cfg, 1024, Some(snap)).expect("bind");
    let s = submit_trace(&addr2.to_string(), &t[30..], 0.0, true).expect("submit");
    assert_eq!((s.accepted, s.rejected, s.errors), (30, 0, 0));
    assert_eq!(
        s.rows, expect,
        "restore lost or perturbed state: drained bytes != uninterrupted batch bytes"
    );
    assert_eq!(Client::connect(addr2).cmd("SHUTDOWN"), "BYE");
    join2.join().expect("service thread");
    let _ = std::fs::remove_file(&snap_path);
}

#[test]
fn malformed_submit_keeps_connection_serving() {
    let cfg = SimConfig::new(ClusterTopo::static_4096(), builtins::FIRST_FIT);
    let (addr, _handle, join) =
        spawn_server_on("127.0.0.1:0", cfg, 1024, None).expect("bind");
    let mut c = Client::connect(addr);
    // Garbage, wrong JSON shape, unknown verb: all ERR, none fatal.
    assert!(c.cmd("SUBMIT {not json").starts_with("ERR bad job json"));
    assert!(c.cmd("SUBMIT [1,2,3]").starts_with("ERR bad job"));
    assert!(c.cmd("FROBNICATE").starts_with("ERR unknown command"));
    // The same connection still schedules real work.
    let job = JobSpec {
        id: 0,
        arrival: 0.0,
        duration: 10.0,
        shape: JobShape::new(2, 2, 2),
        comm_frac: 0.1,
        priority: 0,
    };
    assert!(c.cmd(&format!("SUBMIT {}", pool::job_json(&job))).starts_with("OK "));
    let status = c.cmd("STATUS");
    assert_eq!(status_field(&status, "submitted"), 1, "garbage counted: {status}");
    assert_eq!(status_field(&status, "admitted"), 1);
    assert_eq!(c.cmd("SHUTDOWN"), "BYE");
    join.join().expect("service thread");
}

#[test]
fn queue_cap_rejects_over_tcp() {
    let cfg = SimConfig::new(ClusterTopo::static_4096(), builtins::FIRST_FIT);
    let (addr, _handle, join) =
        spawn_server_on("127.0.0.1:0", cfg, 1, None).expect("bind");
    let mut c = Client::connect(addr);
    let big = |id: u64| JobSpec {
        id,
        arrival: id as f64,
        duration: 1000.0,
        shape: JobShape::new(16, 16, 16),
        comm_frac: 0.1,
        priority: 0,
    };
    // Job 0 fills the cluster, job 1 queues (cap reached), job 2 bounces.
    assert!(c.cmd(&format!("SUBMIT {}", pool::job_json(&big(0)))).starts_with("OK "));
    assert!(c.cmd(&format!("SUBMIT {}", pool::job_json(&big(1)))).starts_with("OK "));
    let reply = c.cmd(&format!("SUBMIT {}", pool::job_json(&big(2))));
    assert!(reply.starts_with("REJECT "), "{reply}");
    let j = Json::parse(reply.strip_prefix("REJECT ").unwrap()).expect("reject json");
    assert_eq!(j.get("queue_cap").and_then(Json::as_usize), Some(1));
    // The drain covers exactly the two accepted jobs.
    let drain_rows: Vec<String> = {
        writeln!(c.out, "DRAIN").expect("write");
        let mut rows = Vec::new();
        loop {
            let mut line = String::new();
            c.reader.read_line(&mut line).expect("read");
            let line = line.trim().to_string();
            if line.starts_with("DRAIN-OK") {
                assert_eq!(line, "DRAIN-OK rows=2");
                break;
            }
            rows.push(line);
        }
        rows
    };
    assert_eq!(drain_rows.len(), 2);
    assert!(drain_rows.iter().all(|r| r.starts_with("ROW ")));
    assert_eq!(c.cmd("SHUTDOWN"), "BYE");
    join.join().expect("service thread");
}

/// The crash-point lock: kill the daemon at *seeded, randomized* points
/// mid-stream and recover purely from the durable artifacts (newest
/// valid auto-snapshot + WAL suffix). Two kills, three daemon
/// generations, one shared journal — the drained rows must be
/// byte-identical to an uninterrupted batch run. Runs under correlated
/// faults so recovery is exercised while the engine is mid-way through
/// a fault RNG stream.
#[test]
fn seeded_crash_points_lose_no_acknowledged_job() {
    let mut cfg = SimConfig::new(ClusterTopo::reconfigurable_4096(4), builtins::RFOLD);
    cfg.modifiers = ModifierSet::parse("failures=corr:21600:3600:rack:0.3")
        .expect("mods")
        .for_trial(5);
    let t = synthetic_trace(48, 9);
    let expect = batch_rows(cfg, &t);

    let dir = std::env::temp_dir().join(format!("rfold-crashpoints-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");
    let dir_s = dir.to_string_lossy().into_owned();
    let wal_path = format!("{dir_s}/arrivals.wal");
    let opts = || ServeOptions {
        wal: Some(wal_path.clone()),
        replay: Vec::new(),
        snapshot_every: 120.0,
        snapshot_dir: Some(dir_s.clone()),
        snapshot_keep: 3,
    };

    // Seeded crash points: one in each half of the stream, never at the
    // very ends (a kill before any ACK or after the last is the trivial
    // case the other tests already cover).
    let mut rng = rfold::util::Pcg64::new(0xC4A5_0FF5, 1);
    let half = t.len() / 2;
    let cut1 = 1 + rng.below(half - 1);
    let cut2 = half + rng.below(half - 1);
    let spans = [0..cut1, cut1..cut2, cut2..t.len()];

    let mut rows = Vec::new();
    for (generation, span) in spans.into_iter().enumerate() {
        // Recover from whatever the previous generation left on disk.
        let (restore, skip) = match snapshot::load_newest(&dir_s).expect("snapshot scan") {
            Some((snap, _)) => {
                let skip = snap.jobs.len();
                (Some(snap), skip)
            }
            None => (None, 0),
        };
        let mut o = opts();
        if std::path::Path::new(&wal_path).exists() {
            let r = wal::replay(&wal_path).expect("wal replay");
            assert_eq!(
                r.jobs.len(),
                span.start,
                "generation {generation}: the journal must hold every ACKed job"
            );
            assert!(!r.torn);
            o.replay = r.jobs[skip..].to_vec();
        } else {
            assert_eq!(generation, 0, "only the first generation starts without a journal");
        }
        let (addr, _handle, join) =
            spawn_server_on_opts("127.0.0.1:0", cfg, 1024, restore, o).expect("bind");
        let last = span.end == t.len();
        let s = submit_trace(&addr.to_string(), &t[span], 0.0, last).expect("submit");
        assert_eq!(s.rejected, 0, "generation {generation}");
        assert_eq!(s.errors, 0, "generation {generation}");
        if last {
            rows = s.rows;
        }
        // Kill without draining: in-memory state dies, disk survives.
        assert_eq!(Client::connect(addr).cmd("SHUTDOWN"), "BYE");
        join.join().expect("service thread");
    }
    assert_eq!(
        rows, expect,
        "crash points {cut1}/{cut2}: recovered bytes != uninterrupted batch bytes"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corruption hardening, end to end: damaged durable artifacts must be
/// refused with structured errors — never a panic, and never a silent
/// resume from wrong state.
#[test]
fn corrupt_durable_artifacts_fail_structurally() {
    // Produce a genuine snapshot from a live daemon.
    let cfg = SimConfig::new(ClusterTopo::static_4096(), builtins::FIRST_FIT);
    let t = synthetic_trace(10, 2);
    let dir = std::env::temp_dir().join(format!("rfold-corrupt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");
    let dir_s = dir.to_string_lossy().into_owned();
    let snap_path = format!("{dir_s}/manual.snap");
    let (addr, _handle, join) = spawn_server_on("127.0.0.1:0", cfg, 1024, None).expect("bind");
    let s = submit_trace(&addr.to_string(), &t, 0.0, false).expect("submit");
    assert_eq!(s.accepted, t.len());
    let mut c = Client::connect(addr);
    assert!(c.cmd(&format!("SNAPSHOT {snap_path}")).starts_with("SNAPSHOT-OK"));
    assert_eq!(c.cmd("SHUTDOWN"), "BYE");
    join.join().expect("service thread");
    let good = std::fs::read_to_string(&snap_path).expect("read snapshot");

    // Truncated: the body line is gone.
    let truncated = good.lines().next().unwrap().to_string();
    std::fs::write(&snap_path, truncated).unwrap();
    let err = snapshot::load(&snap_path).unwrap_err();
    assert!(err.contains("missing body"), "{err}");

    // Flipped checksum byte in the header.
    let flipped = {
        let (header, body) = good.split_once('\n').unwrap();
        let mut h: Vec<char> = header.chars().collect();
        let i = h.len() - 1;
        h[i] = if h[i] == '0' { '1' } else { '0' };
        format!("{}\n{body}", h.into_iter().collect::<String>())
    };
    std::fs::write(&snap_path, flipped).unwrap();
    let err = snapshot::load(&snap_path).unwrap_err();
    assert!(err.contains("checksum mismatch"), "{err}");

    // Wrong version.
    std::fs::write(&snap_path, good.replacen("v1", "v999", 1)).unwrap();
    let err = snapshot::load(&snap_path).unwrap_err();
    assert!(err.contains("unsupported version"), "{err}");

    // A directory holding only damaged snapshots is an error (resuming
    // fresh would silently drop acknowledged state) ...
    let err = snapshot::load_newest(&dir_s).unwrap_err();
    assert!(err.contains("no valid"), "{err}");
    // ... but the scan recovers the moment one valid snapshot exists.
    std::fs::write(&snap_path, &good).unwrap();
    assert!(snapshot::load_newest(&dir_s).expect("scan").is_some());

    // An empty WAL is a structured error, not an empty replay.
    let wal_path = format!("{dir_s}/empty.wal");
    std::fs::write(&wal_path, "").unwrap();
    let err = wal::replay(&wal_path).unwrap_err();
    assert!(err.contains("empty file"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The CI soak: replay the recorded Philly sample into a live daemon at
/// high speedup and check the daemon's telemetry is self-consistent.
#[test]
fn philly_soak_is_self_consistent() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data/philly_sample.csv");
    let t = trace::io::read_csv(&path).expect("read philly sample");
    assert!(!t.is_empty());
    let mut cfg = SimConfig::new(ClusterTopo::reconfigurable_4096(4), builtins::RFOLD);
    cfg.modifiers = ModifierSet::parse("").expect("mods").for_trial(1);
    let expect = batch_rows(cfg, &t);

    let (addr, _handle, join) =
        spawn_server_on("127.0.0.1:0", cfg, 1024, None).expect("bind");
    // A real (finite) speedup exercises the pacing path; 1e9x compresses
    // the sample's hours of arrivals into microseconds of wall clock.
    let s = submit_trace(&addr.to_string(), &t, 1e9, true).expect("submit");
    assert_eq!(s.accepted + s.rejected, t.len(), "every job got a verdict");
    assert_eq!(s.errors, 0);
    assert_eq!(s.rows.len(), s.accepted, "one row per accepted job");
    assert_eq!(s.rows, expect, "soak bytes != batch bytes");

    let mut c = Client::connect(addr);
    let status = c.cmd("STATUS");
    assert_eq!(status_field(&status, "submitted"), t.len());
    assert_eq!(
        status_field(&status, "admitted") + status_field(&status, "rejected"),
        t.len()
    );
    assert!(status.contains("\"drained\":true"), "{status}");
    assert_eq!(c.cmd("SHUTDOWN"), "BYE");
    join.join().expect("service thread");
}
