//! End-to-end L1↔L3 integration: load the AOT artifacts through PJRT and
//! verify the compiled Pallas plan-scorer agrees with the native Rust
//! scorer, and the comm-model with its analytic twin.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo
//! test` works on a fresh checkout).

use std::rc::Rc;

use rfold::placement::score::{NativeScorer, PlanScorer};
use rfold::runtime::comm::{CommFeatures, CommModel};
use rfold::runtime::{Artifacts, XlaScorer};
use rfold::util::Pcg64;

fn artifacts() -> Option<Rc<Artifacts>> {
    if !Artifacts::runtime_available() {
        eprintln!("skipping: rfold built without the `xla` feature");
        return None;
    }
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Rc::new(Artifacts::load(&dir).expect("artifacts must load")))
}

#[test]
fn manifest_describes_all_variants() {
    let Some(arts) = artifacts() else { return };
    assert_eq!(arts.manifest().torus, [16, 16, 16]);
    assert!(arts.manifest().plan_batch >= 1);
    assert!(arts.has_scorer(64, 4), "4^3 scorer required");
    assert!(arts.has_scorer(8, 8), "8^3 scorer required");
    assert!(arts.has_scorer(512, 2), "2^3 scorer required");
    assert!(arts.comm_exe().is_some(), "comm model required");
}

#[test]
fn xla_scorer_matches_native_on_random_grids() {
    let Some(arts) = artifacts() else { return };
    let mut rng = Pcg64::seeded(42);
    let mut native = NativeScorer;
    let mut xla = XlaScorer::new(arts);
    for (cubes, n) in [(64usize, 4usize), (8, 8), (512, 2)] {
        let k = 9; // deliberately not a multiple of the batch
        let vol = cubes * n * n * n;
        for density in [0.0, 0.2, 0.7, 1.0] {
            let occ: Vec<f32> = (0..k * vol)
                .map(|_| if rng.chance(density) { 1.0 } else { 0.0 })
                .collect();
            let a = native.frag_stats(&occ, k, cubes, n);
            let b = xla.frag_stats(&occ, k, cubes, n);
            assert_eq!(a.len(), b.len());
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert!(
                    (x.total_free - y.total_free).abs() < 1e-3
                        && (x.partial_cubes - y.partial_cubes).abs() < 1e-3
                        && (x.stranded - y.stranded).abs() < 1e-3
                        && (x.thru - y.thru).abs() < 1e-3
                        && (x.transitions - y.transitions).abs() < 1e-3
                        && (x.empty_cubes - y.empty_cubes).abs() < 1e-3,
                    "{cubes}x{n}^3 density {density} plan {i}: {x:?} vs {y:?}"
                );
            }
        }
    }
}

#[test]
fn comm_model_matches_analytic() {
    let Some(arts) = artifacts() else { return };
    let model = CommModel::new(arts);
    let mut rng = Pcg64::seeded(7);
    let feats: Vec<CommFeatures> = (0..300)
        .map(|_| CommFeatures {
            ring_len: rng.range(1, 64) as f64,
            bytes: rng.f64() * 1e9,
            bandwidth: 25e9,
            has_ring: rng.chance(0.5),
            contention: 1.0 + rng.f64() * 3.0,
        })
        .collect();
    let got = model.estimate(&feats).expect("execute comm model");
    assert_eq!(got.len(), feats.len());
    for (f, g) in feats.iter().zip(&got) {
        let want = CommModel::analytic(f);
        let tol = want.abs() * 1e-4 + 1e-9;
        assert!((g - want).abs() < tol, "{f:?}: {g} vs {want}");
    }
}

#[test]
fn xla_scorer_ranks_like_native_in_policy() {
    // The PJRT scorer must produce the same plan choice as the native one
    // when wired into a real policy decision.
    let Some(arts) = artifacts() else { return };
    use rfold::placement::policies::RFold;
    use rfold::placement::PlacementPolicy;
    use rfold::shape::JobShape;
    use rfold::topology::cluster::{ClusterState, ClusterTopo};

    let cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let mut native_policy = RFold::new();
    let mut xla_policy = RFold::new();
    xla_policy.set_scorer(Box::new(XlaScorer::new(arts)));
    for shape in [
        JobShape::new(4, 8, 2),
        JobShape::new(18, 1, 1),
        JobShape::new(1, 6, 4),
        JobShape::new(4, 4, 32),
    ] {
        let a = native_policy.place_now(&cluster, 1, shape).expect("native plan");
        let b = xla_policy.place_now(&cluster, 1, shape).expect("xla plan");
        assert_eq!(a.nodes, b.nodes, "{shape}: scorers disagree on the plan");
        assert_eq!(a.cubes, b.cubes);
    }
}
