//! The sweep runner's determinism contract: a sweep run with 1 thread and
//! with many threads must produce byte-identical JSON rows. This is what
//! catches seed-derivation and result-ordering races in the sharded
//! runner.

use rfold::metrics::report;
use rfold::sim::experiments as exp;
use rfold::sim::sweep::{self, SweepConfig};
use rfold::trace::scenarios::Scenario;

/// Cheap sub-grid: two static cells plus one reconfigurable cell, two
/// scenarios — enough to cross every code path without long runtimes.
fn small_cells() -> Vec<exp::Cell> {
    let all = exp::table1_cells();
    all.into_iter()
        .filter(|c| {
            matches!(
                c.label,
                "FirstFit (16^3)" | "Folding (16^3)" | "Reconfig (4^3)"
            )
        })
        .collect()
}

fn rows_json(threads: usize) -> Vec<String> {
    let scenarios = [Scenario::PaperDefault, Scenario::UniformSmall];
    let rows = sweep::run_grid(&small_cells(), &scenarios, 4, 40, 5, threads);
    rows.iter().map(report::sweep_row_json).collect()
}

#[test]
fn grid_rows_byte_identical_across_thread_counts() {
    let one = rows_json(1);
    let eight = rows_json(8);
    assert_eq!(one.len(), eight.len());
    for (a, b) in one.iter().zip(&eight) {
        assert_eq!(a, b, "sweep row differs between --threads 1 and --threads 8");
    }
}

#[test]
fn auto_threads_matches_explicit_one() {
    // threads=0 (auto) must also land on the same bytes.
    assert_eq!(rows_json(1), rows_json(0));
}

#[test]
fn trials_land_in_seed_order_regardless_of_sharding() {
    let cell = small_cells()[0];
    let per_trial = |threads: usize| -> Vec<(usize, usize, usize)> {
        let mut cfg = SweepConfig::new(6, 30, 11);
        cfg.threads = threads;
        sweep::run_trials(cell, &cfg)
            .iter()
            .map(|(r, t)| (r.scheduled, r.dropped, t.len()))
            .collect()
    };
    let serial = per_trial(1);
    for threads in [2, 3, 6, 16] {
        assert_eq!(serial, per_trial(threads), "threads={threads}");
    }
}

#[test]
fn sharded_run_cell_matches_manual_serial_aggregation() {
    // experiments::run_cell (now sharded) must equal a hand-rolled serial
    // loop using the same seed derivation — exact float equality, since
    // the aggregation consumes identical values in identical order.
    use rfold::metrics::summarize;
    use rfold::sim::engine::{RunResult, SimConfig, Simulation};
    use rfold::trace::gen::{generate, TraceConfig};
    use rfold::trace::JobSpec;

    let cell = small_cells()[1];
    let (runs, jobs, seed) = (3usize, 35usize, 9u64);
    let mut results: Vec<(RunResult, Vec<JobSpec>)> = Vec::new();
    for r in 0..runs {
        let trace = generate(&TraceConfig {
            num_jobs: jobs,
            seed: seed + r as u64,
            ..Default::default()
        });
        let res = Simulation::new(SimConfig::new(cell.topo, cell.policy)).run(&trace);
        results.push((res, trace));
    }
    let pairs: Vec<(RunResult, &[JobSpec])> = results
        .iter()
        .map(|(r, t)| (r.clone(), t.as_slice()))
        .collect();
    let serial = summarize(cell.label, &pairs);
    let sharded = exp::run_cell(cell, runs, jobs, seed);
    assert_eq!(serial.avg_jcr_pct, sharded.avg_jcr_pct);
    assert_eq!(serial.jct_p50, sharded.jct_p50);
    assert_eq!(serial.jct_p90, sharded.jct_p90);
    assert_eq!(serial.jct_p99, sharded.jct_p99);
    assert_eq!(serial.avg_util, sharded.avg_util);
    assert_eq!(serial.avg_queue_delay, sharded.avg_queue_delay);
    assert_eq!(serial.util_cdf, sharded.util_cdf);
}

#[test]
fn all_scenarios_flow_through_the_grid() {
    // Every named scenario must survive the full pipeline and emit a row
    // whose JSON carries its name (acceptance criterion of the sweep PR).
    let cells = [exp::table1_cells()[1]]; // Folding (16^3): cheap, drops some jobs
    let rows = sweep::run_grid(&cells, &Scenario::ALL, 2, 30, 3, 0);
    assert_eq!(rows.len(), Scenario::ALL.len());
    for (row, sc) in rows.iter().zip(Scenario::ALL) {
        let json = report::sweep_row_json(row);
        assert!(
            json.contains(&format!("\"scenario\":\"{}\"", sc.name())),
            "row missing scenario {}: {json}",
            sc.name()
        );
        assert_eq!(row.runs, 2);
        assert!(row.summary.avg_jcr_pct > 0.0, "{}: no jobs completed", sc.name());
    }
}
