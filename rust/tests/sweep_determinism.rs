//! The work-queue runner's determinism and caching contracts:
//!
//! * a sweep run with 1 worker and with many workers must produce
//!   byte-identical JSON rows — this catches seed-derivation and
//!   result-ordering races in the global (scenario, cell, trial) queue,
//!   including the small-`runs` grids (runs=2) where the old per-cell
//!   sharding left cores idle;
//! * a grid containing duplicated (policy, topology, scenario) cells must
//!   simulate each unique trial exactly once and still emit identical
//!   summaries for the duplicates;
//! * the cached replay of a grid must be byte-identical to the cold run.

use rfold::metrics::report;
use rfold::sim::experiments as exp;
use rfold::sim::sweep::{self, ResultCache, SweepConfig};
use rfold::trace::scenarios::{Scenario, Workload};

/// Cheap sub-grid: two static cells plus one reconfigurable cell — enough
/// to cross every code path without long runtimes.
fn small_cells() -> Vec<exp::Cell> {
    let all = exp::table1_cells();
    all.into_iter()
        .filter(|c| {
            matches!(
                c.label,
                "FirstFit (16^3)" | "Folding (16^3)" | "Reconfig (4^3)"
            )
        })
        .collect()
}

/// Synthetic-scenario list → workload axis for `run_grid`.
fn wl(scenarios: &[Scenario]) -> Vec<Workload> {
    scenarios.iter().copied().map(Workload::Synthetic).collect()
}

/// A multi-scenario grid at `runs=2` — the regime where per-cell trial
/// sharding degenerates (at most 2 busy threads per cell) and only the
/// global work queue keeps every worker fed.
fn rows_json(workers: usize) -> Vec<String> {
    let scenarios = wl(&[Scenario::PaperDefault, Scenario::UniformSmall]);
    let cache = ResultCache::new(); // fresh: determinism, not cache replay
    let rows = sweep::run_grid(&small_cells(), &scenarios, 2, 40, 5, workers, &cache);
    rows.iter().map(report::sweep_row_json).collect()
}

#[test]
fn grid_rows_byte_identical_across_worker_counts() {
    let one = rows_json(1);
    let eight = rows_json(8);
    assert_eq!(one.len(), eight.len());
    for (a, b) in one.iter().zip(&eight) {
        assert_eq!(a, b, "sweep row differs between --workers 1 and --workers 8");
    }
}

#[test]
fn auto_workers_matches_explicit_one() {
    // workers=0 (auto) must also land on the same bytes.
    assert_eq!(rows_json(1), rows_json(0));
}

#[test]
fn trials_land_in_seed_order_regardless_of_scheduling() {
    let cell = small_cells()[0];
    let per_trial = |workers: usize| -> Vec<(usize, usize, usize)> {
        let mut cfg = SweepConfig::new(6, 30, 11);
        cfg.workers = workers;
        sweep::run_trials_with(cell, &cfg, &ResultCache::new())
            .iter()
            .map(|t| (t.result.scheduled, t.result.dropped, t.trace.len()))
            .collect()
    };
    let serial = per_trial(1);
    for workers in [2, 3, 6, 16] {
        assert_eq!(serial, per_trial(workers), "workers={workers}");
    }
}

#[test]
fn queued_run_cell_matches_manual_serial_aggregation() {
    // experiments::run_cell (work-queue backed) must equal a hand-rolled
    // serial loop using the same seed derivation — exact float equality,
    // since the aggregation consumes identical values in identical order.
    use rfold::metrics::summarize;
    use rfold::sim::engine::{RunResult, SimConfig, Simulation};
    use rfold::trace::gen::{generate, TraceConfig};
    use rfold::trace::JobSpec;

    let cell = small_cells()[1];
    let (runs, jobs, seed) = (3usize, 35usize, 9u64);
    let mut results: Vec<(RunResult, Vec<JobSpec>)> = Vec::new();
    for r in 0..runs {
        let trace = generate(&TraceConfig {
            num_jobs: jobs,
            seed: seed + r as u64,
            ..Default::default()
        });
        let res = Simulation::new(SimConfig::new(cell.topo, cell.policy)).run(&trace);
        results.push((res, trace));
    }
    let pairs: Vec<(&RunResult, &[JobSpec])> = results
        .iter()
        .map(|(r, t)| (r, t.as_slice()))
        .collect();
    let serial = summarize(cell.label, &pairs);
    let queued = exp::run_cell(cell, runs, jobs, seed);
    assert_eq!(serial.avg_jcr_pct, queued.avg_jcr_pct);
    assert_eq!(serial.jct_p50, queued.jct_p50);
    assert_eq!(serial.jct_p90, queued.jct_p90);
    assert_eq!(serial.jct_p99, queued.jct_p99);
    assert_eq!(serial.avg_util, queued.avg_util);
    assert_eq!(serial.avg_queue_delay, queued.avg_queue_delay);
    assert_eq!(serial.util_cdf, queued.util_cdf);
}

#[test]
fn duplicated_cells_simulate_once_with_identical_summaries() {
    // "Reconfig (4^3)" twice in one grid (as Table 1 vs Figure 3 would
    // list it): the cache must collapse them to one simulation per trial
    // and both rows must serialize to the same summary bytes.
    let base = small_cells();
    let dup = base[2]; // Reconfig (4^3)
    let cells = vec![base[0], dup, base[1], dup];
    let cache = ResultCache::new();
    let runs = 2usize;
    let rows = sweep::run_grid(&cells, &wl(&[Scenario::PaperDefault]), runs, 30, 3, 4, &cache);
    assert_eq!(rows.len(), 4);
    // 3 unique cells × 2 trials simulate; the duplicate's 2 slots hit.
    assert_eq!(cache.misses(), 3 * runs as u64);
    assert_eq!(cache.hits(), runs as u64);
    let a = report::sweep_row_json(&rows[1]);
    let b = report::sweep_row_json(&rows[3]);
    assert_eq!(a, b, "duplicated cell rows must be byte-identical");
}

#[test]
fn cached_replay_is_byte_identical_to_cold_run() {
    let cells = small_cells();
    let scenarios = wl(&[Scenario::PaperDefault, Scenario::CommHeavy]);
    let cache = ResultCache::new();
    let cold = sweep::run_grid(&cells, &scenarios, 2, 30, 7, 4, &cache);
    let misses_after_cold = cache.misses();
    let warm = sweep::run_grid(&cells, &scenarios, 2, 30, 7, 1, &cache);
    assert_eq!(cache.misses(), misses_after_cold, "warm run must not simulate");
    let json = |rows: &[sweep::SweepRow]| -> Vec<String> {
        rows.iter().map(report::sweep_row_json).collect()
    };
    assert_eq!(json(&cold), json(&warm));
}

#[test]
fn scale_smoke_row_at_64k_nodes_stays_deterministic() {
    // The 16x16x256 torus (65,536 nodes) — the extent the packed-word /
    // incremental-index scale refactor targets. A tiny grid on it must
    // flow through the whole sweep pipeline and land on the same row
    // bytes regardless of worker count: the determinism lock at the
    // scale ceiling, kept cheap (2 runs × 25 jobs) so it rides in CI.
    use rfold::placement::builtins;
    use rfold::topology::cluster::ClusterTopo;
    use rfold::topology::P3;

    let cells = [exp::Cell {
        policy: builtins::FIRST_FIT,
        topo: ClusterTopo::Static {
            ext: P3([16, 16, 256]),
        },
        label: "FirstFit (16x16x256)",
    }];
    let rows = |workers: usize| -> Vec<String> {
        sweep::run_grid(
            &cells,
            &wl(&[Scenario::PaperDefault]),
            2,
            25,
            13,
            workers,
            &ResultCache::new(),
        )
        .iter()
        .map(report::sweep_row_json)
        .collect()
    };
    let one = rows(1);
    assert_eq!(one.len(), 1);
    assert!(
        one[0].contains("16x16x256"),
        "row must carry the scale label: {}",
        one[0]
    );
    assert_eq!(one, rows(4), "64k-node row differs across worker counts");
}

#[test]
fn all_scenarios_flow_through_the_grid() {
    // Every named scenario must survive the full pipeline and emit a row
    // whose JSON carries its name (acceptance criterion of the sweep PR).
    let cells = [exp::table1_cells()[1]]; // Folding (16^3): cheap, drops some jobs
    let rows = sweep::run_grid(
        &cells,
        &wl(&Scenario::ALL),
        2,
        30,
        3,
        0,
        &ResultCache::new(),
    );
    assert_eq!(rows.len(), Scenario::ALL.len());
    for (row, sc) in rows.iter().zip(Scenario::ALL) {
        let json = report::sweep_row_json(row);
        assert!(
            json.contains(&format!("\"scenario\":\"{}\"", sc.name())),
            "row missing scenario {}: {json}",
            sc.name()
        );
        assert_eq!(row.runs, 2);
        assert!(row.summary.avg_jcr_pct > 0.0, "{}: no jobs completed", sc.name());
    }
}
