//! Fault-injection determinism contracts (the PR-6 bugfix suite):
//!
//! * a modified sweep (`--with failures=philly,...`) must emit SWEEP rows
//!   byte-identical across worker counts AND between local and TCP-pool
//!   execution — fault draws come from a dedicated per-trial stream, so
//!   scheduling can never reorder them;
//! * job traces must be byte-identical with modifiers on and off: fault
//!   injection perturbs *execution*, never the workload;
//! * modified trials must occupy distinct cache keys from their
//!   unmodified twins (and from each other when only the fault seed
//!   differs), including the fixed-CSV trials whose unmodified key
//!   deliberately drops the trial seed.

use rfold::metrics::report;
use rfold::sim::experiments as exp;
use rfold::sim::sweep::{self, ResultCache, SweepConfig};
use rfold::trace::gen::{generate, TraceConfig};
use rfold::trace::scenarios::{ModifierSet, Scenario, Workload};

/// One static + one reconfigurable cell: crosses the straggler, kill, and
/// OCS-latency paths without long runtimes.
fn cells() -> Vec<exp::Cell> {
    exp::table1_cells()
        .into_iter()
        .filter(|c| matches!(c.label, "Folding (16^3)" | "RFold (4^3)"))
        .collect()
}

fn mods() -> ModifierSet {
    ModifierSet::parse("failures=philly,ocs-latency=5s,stragglers=0.05").unwrap()
}

fn rows_json(workers: usize, m: ModifierSet) -> Vec<String> {
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let rows = sweep::run_grid_with(
        &cells(),
        &workloads,
        3,
        40,
        5,
        m,
        &ResultCache::new(),
        &sweep::LocalExecutor::new(workers),
    );
    rows.iter().map(report::sweep_row_json).collect()
}

#[test]
fn modified_rows_byte_identical_across_worker_counts() {
    let one = rows_json(1, mods());
    let eight = rows_json(8, mods());
    assert_eq!(one.len(), eight.len());
    for (a, b) in one.iter().zip(&eight) {
        assert_eq!(
            a, b,
            "modified sweep row differs between --workers 1 and --workers 8"
        );
    }
}

#[test]
fn modified_rows_byte_identical_local_vs_pool() {
    let addr = rfold::coordinator::pool::spawn_worker().expect("spawn worker");
    let pool = rfold::coordinator::pool::PoolExecutor::new(vec![addr.to_string()]);
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let grid = |executor: &dyn sweep::TrialExecutor| -> Vec<String> {
        sweep::run_grid_with(
            &cells(),
            &workloads,
            2,
            30,
            5,
            mods(),
            &ResultCache::new(),
            executor,
        )
        .iter()
        .map(report::sweep_row_json)
        .collect()
    };
    let local = grid(&sweep::LocalExecutor::new(1));
    let pooled = grid(&pool);
    assert_eq!(local, pooled, "pool must reproduce modified rows byte-exactly");
    let stats = pool.stats();
    assert_eq!(
        stats.leader_fallback, 0,
        "the worker must have served the modified items itself"
    );
}

#[test]
fn job_streams_identical_with_and_without_modifiers() {
    // The fault RNG lives on its own stream: enabling modifiers must not
    // move a single arrival, duration, or shape in the generated traces.
    let cell = cells()[1]; // RFold (4^3)
    let traces = |m: ModifierSet| {
        let mut cfg = SweepConfig::new(3, 40, 9);
        cfg.workers = 1;
        cfg.modifiers = m;
        sweep::run_trials_with(cell, &cfg, &ResultCache::new())
            .iter()
            .map(|t| t.trace.clone())
            .collect::<Vec<_>>()
    };
    let plain = traces(ModifierSet::default());
    let modified = traces(mods());
    assert_eq!(plain.len(), modified.len());
    for (slot, (a, b)) in plain.iter().zip(&modified).enumerate() {
        assert_eq!(
            a, b,
            "trial {slot}: modifiers changed the job stream itself"
        );
    }
}

#[test]
fn modifiers_are_part_of_the_cache_key() {
    // The same cell swept plain and then modified must miss twice per
    // trial — a modified trial served from its unmodified twin's cache
    // entry would silently report fault-free numbers.
    let cell = cells()[0];
    let cache = ResultCache::new();
    let run = |m: ModifierSet| {
        let mut cfg = SweepConfig::new(2, 30, 7);
        cfg.workers = 1;
        cfg.modifiers = m;
        sweep::run_trials_with(cell, &cfg, &cache)
    };
    run(ModifierSet::default());
    assert_eq!(cache.misses(), 2);
    run(mods());
    assert_eq!(cache.misses(), 4, "modified trials must not hit plain entries");
    // Same modifiers, different fault seed: distinct realizations,
    // distinct keys.
    run(ModifierSet::parse("failures=philly,ocs-latency=5s,stragglers=0.05,seed=99").unwrap());
    assert_eq!(cache.misses(), 6, "the fault seed must be part of the key");
    // Replaying any of the three is all hits.
    run(mods());
    assert_eq!(cache.misses(), 6);
}

#[test]
fn modified_csv_trials_keep_their_per_trial_seed() {
    // Unmodified fixed traces collapse all trials onto one key (replays
    // ignore the seed). With modifiers each trial draws its own fault
    // realization, so the collapse would be wrong twice over: trial 1..n
    // would reuse trial 0's faults, and a modified run could collide with
    // the unmodified cached bytes.
    let jobs = generate(&TraceConfig {
        num_jobs: 12,
        seed: 3,
        ..Default::default()
    });
    let workload = Workload::from_jobs("fixed".into(), jobs);
    let cell = cells()[0];
    let cache = ResultCache::new();
    let run = |m: ModifierSet| {
        let mut cfg = SweepConfig::new(2, 12, 7);
        cfg.workers = 1;
        cfg.workload = workload.clone();
        cfg.modifiers = m;
        sweep::run_trials_with(cell, &cfg, &cache)
    };
    run(ModifierSet::default());
    assert_eq!(cache.misses(), 1, "plain fixed trace: one simulation");
    assert_eq!(cache.hits(), 1, "plain fixed trace: trial 1 replays trial 0");
    let outs = run(mods());
    assert_eq!(
        cache.misses(),
        3,
        "each modified CSV trial simulates its own fault realization"
    );
    // Both trials replay the same fixed job list — only the fault
    // realization (mixed from the per-trial seed) may differ.
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].trace, outs[1].trace, "same recorded jobs");
}

#[test]
fn modified_runs_are_reproducible_end_to_end() {
    // Same grid, fresh caches, twice: byte-identical rows. This is the
    // `rfold sweep --scenario paper-default --with failures=philly`
    // acceptance path in miniature.
    let m = ModifierSet::parse("failures=philly").unwrap();
    assert_eq!(rows_json(4, m), rows_json(2, m));
}

#[test]
fn correlated_failures_byte_identical_across_worker_counts() {
    // Domain-level faults (a whole rack/cube going down atomically, plus
    // cascades) draw from the same dedicated fault stream as independent
    // node faults, so the blast-radius path must hold the identical
    // determinism contract: rows never move with the worker count.
    let m = ModifierSet::parse("failures=corr:21600:3600:rack:0.3").unwrap();
    let one = rows_json(1, m);
    let eight = rows_json(8, m);
    assert_eq!(one.len(), eight.len());
    for (a, b) in one.iter().zip(&eight) {
        assert_eq!(
            a, b,
            "correlated-failure row differs between --workers 1 and --workers 8"
        );
    }
}

#[test]
fn correlated_failures_byte_identical_local_vs_pool() {
    // The corr modifier crosses the wire as part of the ModifierSet
    // fingerprint, so a pooled sweep must reproduce the same blast-radius
    // realizations bit-for-bit.
    let addr = rfold::coordinator::pool::spawn_worker().expect("spawn worker");
    let pool = rfold::coordinator::pool::PoolExecutor::new(vec![addr.to_string()]);
    let m = ModifierSet::parse("failures=corr:21600:3600:cube").unwrap();
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let grid = |executor: &dyn sweep::TrialExecutor| -> Vec<String> {
        sweep::run_grid_with(
            &cells(),
            &workloads,
            2,
            30,
            5,
            m,
            &ResultCache::new(),
            executor,
        )
        .iter()
        .map(report::sweep_row_json)
        .collect()
    };
    let local = grid(&sweep::LocalExecutor::new(1));
    let pooled = grid(&pool);
    assert_eq!(
        local, pooled,
        "pool must reproduce correlated-failure rows byte-exactly"
    );
}
