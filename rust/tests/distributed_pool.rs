//! End-to-end distributed sweep contracts:
//!
//! * a grid fanned out to in-process TCP workers must emit SWEEP rows
//!   byte-identical to `--workers 1` on the leader — the determinism
//!   guarantee that makes `--pool` a drop-in scale-out;
//! * a worker dying mid-grid must cost retries, never rows: the
//!   survivors (or the leader itself) pick up the orphaned items;
//! * `--trace-file` workloads flow through the sweep result cache with
//!   content-hashed keys — identical files hit, distinct files with the
//!   same stem never collide (the ROADMAP cache-key bugfix).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

use rfold::coordinator::pool::{self, PoolExecutor};
use rfold::metrics::report;
use rfold::sim::experiments as exp;
use rfold::sim::sweep::{self, ResultCache};
use rfold::trace::gen::{generate, TraceConfig};
use rfold::trace::scenarios::{ModifierSet, Scenario, Workload};

/// Cheap sub-grid: one static cell and one reconfigurable cell cross the
/// wire format's topology variants without long runtimes.
fn cells() -> Vec<exp::Cell> {
    exp::table1_cells()
        .into_iter()
        .filter(|c| matches!(c.label, "Folding (16^3)" | "Reconfig (4^3)"))
        .collect()
}

fn rows_local(workloads: &[Workload]) -> Vec<String> {
    let rows = sweep::run_grid(&cells(), workloads, 2, 30, 5, 1, &ResultCache::new());
    rows.iter().map(report::sweep_row_json).collect()
}

fn rows_pooled(workloads: &[Workload], executor: &PoolExecutor) -> Vec<String> {
    let rows = sweep::run_grid_with(
        &cells(),
        workloads,
        2,
        30,
        5,
        ModifierSet::default(),
        &ResultCache::new(),
        executor,
    );
    rows.iter().map(report::sweep_row_json).collect()
}

#[test]
fn two_tcp_workers_match_local_bytes() {
    let a = pool::spawn_worker().unwrap();
    let b = pool::spawn_worker().unwrap();
    let workloads = [
        Workload::Synthetic(Scenario::PaperDefault),
        Workload::Synthetic(Scenario::UniformSmall),
    ];
    let executor = PoolExecutor::new(vec![a.to_string(), b.to_string()]);
    let pooled = rows_pooled(&workloads, &executor);
    let local = rows_local(&workloads);
    assert_eq!(local.len(), pooled.len());
    for (l, p) in local.iter().zip(&pooled) {
        assert_eq!(l, p, "SWEEP row differs between --workers 1 and a 2-worker pool");
    }
    let stats = executor.stats();
    let completed: usize = stats.workers.iter().map(|w| w.completed).sum();
    // 2 cells × 2 workloads × 2 runs = 8 unique trials, each computed
    // exactly once, somewhere.
    assert_eq!(completed + stats.leader_fallback, 8, "{stats:?}");
    assert!(
        stats.workers.iter().all(|w| w.connected),
        "both workers served: {stats:?}"
    );
}

#[test]
fn multiple_connections_per_host_match_local_bytes() {
    // `--pool-connections 3` on one worker host: every connection gets
    // its own serving thread on the worker, the rows stay byte-identical,
    // and the per-connection telemetry covers all three connections.
    let a = pool::spawn_worker().unwrap();
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let executor = PoolExecutor::new(vec![a.to_string()]).with_connections(3);
    let pooled = rows_pooled(&workloads, &executor);
    assert_eq!(
        rows_local(&workloads),
        pooled,
        "SWEEP rows differ between --workers 1 and a 1-host x 3-connection pool"
    );
    let stats = executor.stats();
    assert_eq!(stats.workers.len(), 3, "one stats row per connection: {stats:?}");
    let completed: usize = stats.workers.iter().map(|w| w.completed).sum();
    // 2 cells × 1 workload × 2 runs = 4 unique trials.
    assert_eq!(completed + stats.leader_fallback, 4, "{stats:?}");
    assert!(
        stats.workers.iter().all(|w| w.connected),
        "every connection must be accepted: {stats:?}"
    );
}

#[test]
fn csv_workload_ships_inline_and_matches_local() {
    // A file-backed workload must survive the wire (jobs ship inline, no
    // shared filesystem) and produce local-identical bytes.
    let jobs = generate(&TraceConfig {
        num_jobs: 18,
        seed: 31,
        ..Default::default()
    });
    let workloads = [Workload::from_jobs("wire-trace".into(), jobs)];
    let a = pool::spawn_worker().unwrap();
    let executor = PoolExecutor::new(vec![a.to_string()]);
    let pooled = rows_pooled(&workloads, &executor);
    let local = rows_local(&workloads);
    assert_eq!(local, pooled);
    assert!(pooled[0].contains("\"scenario\":\"wire-trace\""), "{}", pooled[0]);
}

#[test]
fn csv_delta_pool_matches_local_bytes() {
    // `--pool-delta`: the first trial ships the CSV job list inline, every
    // later trial on the connection references it by content hash. The
    // worker resolves refs from its per-connection cache, so the rows must
    // not move by a byte relative to the inline encoding or a local run.
    let jobs = generate(&TraceConfig {
        num_jobs: 18,
        seed: 31,
        ..Default::default()
    });
    let workloads = [Workload::from_jobs("wire-trace".into(), jobs)];
    let a = pool::spawn_worker().unwrap();
    let executor = PoolExecutor::new(vec![a.to_string()]).with_csv_delta(true);
    let pooled = rows_pooled(&workloads, &executor);
    assert_eq!(
        rows_local(&workloads),
        pooled,
        "delta encoding must not change a byte of any row"
    );
}

#[test]
fn csv_delta_survives_a_stateless_peer() {
    // A peer answering every line through the *stateless* dispatch — the
    // behavior of a worker predating the delta encoding — accepts inline
    // CSV trials but rejects `csv-ref` with ERR. The leader must route
    // rejected items to retry/fallback and still emit local bytes.
    let legacy = spawn_flaky_worker(usize::MAX);
    let jobs = generate(&TraceConfig {
        num_jobs: 14,
        seed: 32,
        ..Default::default()
    });
    let workloads = [Workload::from_jobs("legacy-trace".into(), jobs)];
    let executor = PoolExecutor::new(vec![legacy.to_string()]).with_csv_delta(true);
    let pooled = rows_pooled(&workloads, &executor);
    assert_eq!(
        rows_local(&workloads),
        pooled,
        "old-worker interop: rejected refs must degrade, not corrupt rows"
    );
}

/// A worker that honestly serves `limit` trials through the library's own
/// dispatch, then drops the connection mid-grid.
fn spawn_flaky_worker(limit: usize) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            let mut out = stream.try_clone().unwrap();
            let mut served = 0usize;
            for line in BufReader::new(stream).lines() {
                let Ok(line) = line else { break };
                if served >= limit {
                    break; // die mid-grid, connection dropped
                }
                match pool::worker_dispatch(line.trim()) {
                    Some(reply) => {
                        if writeln!(out, "{reply}").is_err() {
                            break;
                        }
                    }
                    None => break,
                }
                served += 1;
            }
        }
    });
    addr
}

#[test]
fn worker_death_mid_grid_is_retried_not_lost() {
    // A worker that dies after two trials next to a healthy one: whoever
    // ends up holding the orphaned items (the survivor via the retry
    // queue, or the leader), the rows must not change. Which worker
    // observes the death is a scheduling race, so this test asserts the
    // byte contract plus conservation of trials only.
    let flaky = spawn_flaky_worker(2);
    let healthy = pool::spawn_worker().unwrap();
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let executor = PoolExecutor::new(vec![flaky.to_string(), healthy.to_string()]);
    let pooled = rows_pooled(&workloads, &executor);
    assert_eq!(
        rows_local(&workloads),
        pooled,
        "rows must be byte-identical even with a mid-grid worker death"
    );
    let stats = executor.stats();
    let completed: usize = stats.workers.iter().map(|w| w.completed).sum();
    // 2 cells × 1 workload × 2 runs = 4 unique trials.
    assert_eq!(completed + stats.leader_fallback, 4, "{stats:?}");
}

#[test]
fn sole_worker_death_is_observed_and_survived() {
    // With only the flaky worker in the pool, it is guaranteed to receive
    // a third item and die mid-grid; the leader must absorb the orphans.
    let flaky = spawn_flaky_worker(2);
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let executor = PoolExecutor::new(vec![flaky.to_string()]);
    let pooled = rows_pooled(&workloads, &executor);
    assert_eq!(rows_local(&workloads), pooled);
    let stats = executor.stats();
    assert!(stats.workers[0].died, "{stats:?}");
    assert_eq!(stats.workers[0].completed, 2, "{stats:?}");
    assert_eq!(
        stats.workers[0].completed + stats.leader_fallback,
        4,
        "leader picks up everything the dead worker dropped: {stats:?}"
    );
}

/// A worker whose first `flaky` accepted connections are dropped on the
/// floor, after which every connection is served honestly through the
/// library's own dispatch — the shape of a worker process restarting
/// mid-sweep.
fn spawn_recovering_worker(flaky: usize) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let mut dropped = 0usize;
        while let Ok((stream, _)) = listener.accept() {
            if dropped < flaky {
                dropped += 1;
                continue; // drop the stream: instant connection death
            }
            let mut out = stream.try_clone().unwrap();
            for line in BufReader::new(stream).lines() {
                let Ok(line) = line else { break };
                match pool::worker_dispatch(line.trim()) {
                    Some(reply) => {
                        if writeln!(out, "{reply}").is_err() {
                            break;
                        }
                    }
                    None => break, // QUIT — back to accepting
                }
            }
        }
    });
    addr
}

#[test]
fn breaker_trips_then_probe_recovery_rejoins_the_grid() {
    // Three dropped connections in a row trip the host's circuit
    // breaker; after the cool-off, the half-open PING probe finds the
    // worker serving again, closes the breaker, and the host finishes
    // the grid remotely. The rows must not move by a byte, and the
    // telemetry must record exactly one trip and one recovery.
    let addr = spawn_recovering_worker(3);
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let executor = PoolExecutor::new(vec![addr.to_string()])
        .with_breaker_backoff(Duration::from_millis(5));
    let pooled = rows_pooled(&workloads, &executor);
    assert_eq!(
        rows_local(&workloads),
        pooled,
        "a breaker trip/recovery cycle must never change row bytes"
    );
    let stats = executor.stats();
    assert_eq!(stats.hosts.len(), 1, "{stats:?}");
    assert_eq!(stats.hosts[0].trips, 1, "three strikes, one trip: {stats:?}");
    assert_eq!(
        stats.hosts[0].recoveries, 1,
        "the probe's PONG closes the breaker: {stats:?}"
    );
    let completed: usize = stats.workers.iter().map(|w| w.completed).sum();
    // 2 cells × 1 workload × 2 runs = 4 unique trials, conserved.
    assert_eq!(completed + stats.leader_fallback, 4, "{stats:?}");
    assert!(
        completed >= 3,
        "the recovered worker serves the tail of the grid: {stats:?}"
    );
}

#[test]
fn unreachable_pool_falls_back_to_leader() {
    // Bind-then-drop yields a port that refuses connections.
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let workloads = [Workload::Synthetic(Scenario::CommHeavy)];
    let executor = PoolExecutor::new(vec![dead.to_string()]);
    let pooled = rows_pooled(&workloads, &executor);
    assert_eq!(
        rows_local(&workloads),
        pooled,
        "an unreachable pool must degrade to leader-local bytes, not fail"
    );
    let stats = executor.stats();
    assert!(stats.leader_fallback > 0, "{stats:?}");
    assert!(stats.workers.iter().all(|w| !w.connected));
}

#[test]
fn trace_files_hit_the_cache_and_never_collide_by_stem() {
    // Two files with the same stem but different content, plus a replay
    // of the first: the replay is all hits, the second file all misses.
    let dir_a = std::env::temp_dir().join("rfold_pool_a");
    let dir_b = std::env::temp_dir().join("rfold_pool_b");
    std::fs::create_dir_all(&dir_a).unwrap();
    std::fs::create_dir_all(&dir_b).unwrap();
    let path_a = dir_a.join("trace.csv");
    let path_b = dir_b.join("trace.csv");
    let mk = |seed: u64| {
        generate(&TraceConfig {
            num_jobs: 12,
            seed,
            ..Default::default()
        })
    };
    rfold::trace::io::write_csv(&path_a, &mk(1)).unwrap();
    rfold::trace::io::write_csv(&path_b, &mk(2)).unwrap();
    let wa = Workload::from_csv(&path_a).unwrap();
    let wb = Workload::from_csv(&path_b).unwrap();
    assert_eq!(wa.name(), wb.name(), "same stem");
    assert_ne!(wa.cache_key(), wb.cache_key(), "distinct files, distinct keys");

    let cells = cells();
    let cache = ResultCache::new();
    let rows_a = sweep::run_grid(&cells, &[wa.clone()], 2, 0, 5, 1, &cache);
    let misses_a = cache.misses();
    // A fixed trace ignores the trial seed: one simulation per cell, the
    // second trial of each cell is an in-grid hit.
    assert_eq!(misses_a, cells.len() as u64, "cold file simulates once per cell");

    // Identical content (re-read from disk) replays entirely from cache.
    let wa2 = Workload::from_csv(&path_a).unwrap();
    let rows_a2 = sweep::run_grid(&cells, &[wa2], 2, 0, 5, 1, &cache);
    assert_eq!(cache.misses(), misses_a, "identical trace file is all hits");
    assert_eq!(
        rows_a.iter().map(report::sweep_row_json).collect::<Vec<_>>(),
        rows_a2.iter().map(report::sweep_row_json).collect::<Vec<_>>(),
        "cached replay must be byte-identical"
    );

    // Same stem, different content: must simulate from scratch.
    let _ = sweep::run_grid(&cells, &[wb], 2, 0, 5, 1, &cache);
    assert_eq!(
        cache.misses(),
        misses_a * 2,
        "a different file with the same stem must not reuse cached trials"
    );

    std::fs::remove_file(&path_a).ok();
    std::fs::remove_file(&path_b).ok();
}
