//! Golden regression lock on the Table-1 cells: for a fixed seed and a
//! small job count, the exact `scheduled`/`dropped` counts and JCR of
//! every cell must not drift. Scheduler refactors that silently shift
//! paper results fail here first.
//!
//! Snapshot workflow (insta-style): the fingerprint is compared against
//! `tests/golden/table1.txt`. If the file is missing, or `UPDATE_GOLDEN`
//! is set in the environment, the snapshot is (re)blessed and written —
//! commit the result. See `tests/golden/README.md`.

use std::fmt::Write as _;
use std::path::PathBuf;

use rfold::sim::experiments as exp;
use rfold::sim::sweep::{self, ResultCache, SweepConfig};

const GOLDEN_RUNS: usize = 2;
const GOLDEN_JOBS: usize = 48;
const GOLDEN_SEED: u64 = 77;

/// One line per Table-1 cell: label + exact counts + JCR to 4 decimals.
/// Each fingerprint gets a fresh result cache so worker-count invariance
/// is exercised on real computation, not cache replay.
fn table1_fingerprint(workers: usize) -> String {
    let cache = ResultCache::new();
    let mut out = String::new();
    for cell in exp::table1_cells() {
        let mut cfg = SweepConfig::new(GOLDEN_RUNS, GOLDEN_JOBS, GOLDEN_SEED);
        cfg.workers = workers;
        let trials = sweep::run_trials_with(cell, &cfg, &cache);
        let scheduled: usize = trials.iter().map(|t| t.result.scheduled).sum();
        let dropped: usize = trials.iter().map(|t| t.result.dropped).sum();
        let total: usize = trials.iter().map(|t| t.result.outcomes.len()).sum();
        let jcr = 100.0 * scheduled as f64 / total as f64;
        writeln!(
            out,
            "{} scheduled={scheduled} dropped={dropped} total={total} jcr={jcr:.4}",
            cell.label
        )
        .unwrap();
    }
    out
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/table1.txt")
}

#[test]
fn table1_fingerprint_is_deterministic_and_worker_invariant() {
    let serial = table1_fingerprint(1);
    assert_eq!(serial, table1_fingerprint(1), "same-config reruns must match");
    assert_eq!(serial, table1_fingerprint(4), "worker count must not matter");
}

#[test]
fn table1_matches_golden_snapshot() {
    let got = table1_fingerprint(0);
    let path = golden_path();
    if !path.exists() && std::env::var_os("UPDATE_GOLDEN").is_none() {
        // Self-bless only in interactive/local runs. In CI a missing
        // snapshot must fail loudly — otherwise a fresh checkout would
        // re-bless every run and the regression lock would be inert.
        assert!(
            std::env::var_os("CI").is_none(),
            "tests/golden/table1.txt is missing in CI; generate it locally \
             with `cargo test -q`, inspect it, and commit it"
        );
    }
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &got).unwrap();
        eprintln!("golden_table1: blessed snapshot at {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got, want,
        "Table-1 fingerprint drifted from tests/golden/table1.txt; if the \
         change is intentional, re-bless with `UPDATE_GOLDEN=1 cargo test`"
    );
}

#[test]
fn table1_qualitative_ordering_holds_at_golden_scale() {
    // Even at the golden suite's tiny scale, the paper's headline ordering
    // must hold: both 4^3 cells complete everything, FirstFit is worst.
    let got = table1_fingerprint(0);
    let jcr_of = |label: &str| -> f64 {
        let line = got
            .lines()
            .find(|l| l.starts_with(label))
            .unwrap_or_else(|| panic!("missing cell {label}"));
        line.rsplit("jcr=")
            .next()
            .unwrap()
            .trim()
            .parse()
            .expect("jcr parses")
    };
    assert!(jcr_of("RFold (4^3)") >= 99.9);
    assert!(jcr_of("Reconfig (4^3)") >= 99.9);
    assert!(jcr_of("FirstFit (16^3)") < jcr_of("Folding (16^3)"));
}
