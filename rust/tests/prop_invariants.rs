//! Property-based invariants (in-tree `util::prop` harness; see DESIGN.md
//! §7): randomized workloads must never violate the core safety and
//! algebraic properties of the system.

use rfold::placement::policies::{PolicyKind, RFold, Reconfig};
use rfold::placement::PlacementPolicy;
use rfold::shape::fold::{enumerate_variants, FoldKind};
use rfold::shape::{verify, JobShape};
use rfold::topology::cluster::{ClusterState, ClusterTopo};
use rfold::topology::routing::LinkLoads;
use rfold::topology::P3;
use rfold::util::prop::{check, expect};
use rfold::util::Pcg64;

fn random_shape(rng: &mut Pcg64) -> JobShape {
    let size = rng.range(1, 512);
    rfold::trace::gen::shape_for_size(rng, size, &Default::default())
        .unwrap_or(JobShape::new(1, 1, 1))
}

#[test]
fn prop_no_double_booking_across_random_schedules() {
    check("no double booking", 30, |rng| {
        let n = *rng.choose(&[2usize, 4, 8]);
        let mut cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(n));
        let mut policy = rng.choose(&[PolicyKind::Reconfig, PolicyKind::RFold]).build();
        let mut live: Vec<u64> = Vec::new();
        for job in 0..40u64 {
            if !live.is_empty() && rng.chance(0.35) {
                let idx = rng.below(live.len());
                let id = live.swap_remove(idx);
                cluster.release(id);
            }
            let shape = random_shape(rng);
            if let Some(plan) = policy.place_now(&cluster, job, shape) {
                plan.commit(&mut cluster).map_err(|e| e.to_string())?;
                live.push(job);
            }
            cluster.check_consistency()?;
        }
        Ok(())
    });
}

#[test]
fn prop_commit_release_restores_everything() {
    check("commit/release roundtrip", 40, |rng| {
        let n = *rng.choose(&[2usize, 4, 8]);
        let mut cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(n));
        let mut policy = RFold::new();
        let shape = random_shape(rng);
        let free0 = cluster.free_count();
        let rewired0 = cluster.ocs().unwrap().rewired_entries();
        if let Some(plan) = policy.place_now(&cluster, 7, shape) {
            plan.commit(&mut cluster).map_err(|e| e.to_string())?;
            cluster.release(7);
        }
        expect(cluster.free_count() == free0, "free count restored")?;
        expect(
            cluster.ocs().unwrap().rewired_entries() == rewired0,
            "OCS restored",
        )?;
        cluster.check_consistency()?;
        Ok(())
    });
}

#[test]
fn prop_every_generated_variant_is_homomorphic() {
    check("fold homomorphism", 60, |rng| {
        let shape = random_shape(rng);
        for v in enumerate_variants(shape, 256) {
            expect(v.placed.volume() == shape.size(), format!("volume {v:?}"))?;
            verify::verify(&v, v.requires_wrap).map_err(|e| format!("{shape} {v:?}: {e}"))?;
            // Fold-promised rings must close even with wrap only where
            // declared; identity needs full wrap to close everything.
            if v.kind != FoldKind::Identity {
                let closures = verify::ring_closures(&v, v.requires_wrap);
                for (dim, closed) in closures {
                    let promised = verify::promised_dims(&v);
                    let logical_dims: Vec<usize> = (0..3)
                        .filter(|&d| v.orig.dims().0[d] >= 2)
                        .map(|d| v.orig.dims().0[d])
                        .collect();
                    let _ = (dim, closed, promised, logical_dims);
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_placed_plans_respect_wrap_requirements() {
    check("plans satisfy requires_wrap", 30, |rng| {
        let n = *rng.choose(&[4usize, 8]);
        let cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(n));
        let mut policy = RFold::new();
        let shape = random_shape(rng);
        if let Some(plan) = policy.place_now(&cluster, 1, shape) {
            for k in 0..3 {
                expect(
                    !plan.variant.requires_wrap[k] || plan.wrap[k],
                    format!("axis {k} wrap missing: {:?}", plan.variant),
                )?;
            }
            // Node list is duplicate-free and matches the variant volume.
            let mut nodes = plan.nodes.clone();
            nodes.sort_unstable();
            nodes.dedup();
            expect(nodes.len() == plan.variant.placed.volume(), "node count")?;
        }
        Ok(())
    });
}

#[test]
fn prop_dor_routes_match_torus_distance() {
    check("DOR hop count = torus distance", 100, |rng| {
        let ext = P3([
            *rng.choose(&[2usize, 4, 8, 16]),
            *rng.choose(&[2usize, 4, 8, 16]),
            *rng.choose(&[2usize, 4, 8, 16]),
        ]);
        let mut loads = LinkLoads::new(ext);
        let a = P3([rng.below(ext.0[0]), rng.below(ext.0[1]), rng.below(ext.0[2])]);
        let b = P3([rng.below(ext.0[0]), rng.below(ext.0[1]), rng.below(ext.0[2])]);
        let hops = loads.add_path(a, b, 1.0);
        expect(
            hops == a.torus_dist(b, ext),
            format!("{a}->{b} in {ext}: {hops}"),
        )
    });
}

#[test]
fn prop_link_loads_add_remove_cancel() {
    check("ring load cancellation", 60, |rng| {
        let ext = P3([8, 8, 8]);
        let mut loads = LinkLoads::new(ext);
        let members: Vec<P3> = (0..rng.range(2, 9))
            .map(|_| P3([rng.below(8), rng.below(8), rng.below(8)]))
            .collect();
        loads.add_ring(&members, 1.5);
        loads.add_ring(&members, -1.5);
        expect(loads.max_load().abs() < 1e-12, "loads must cancel")
    });
}

#[test]
fn prop_rfold_jcr_dominates_reconfig() {
    // On any trace, RFold schedules at least as many jobs as Reconfig
    // (folding only adds options) — the paper's core claim.
    check("JCR(RFold) >= JCR(Reconfig)", 6, |rng| {
        let seed = rng.next_u64() % 10_000;
        let t = rfold::trace::gen::generate(&rfold::trace::gen::TraceConfig {
            num_jobs: 80,
            seed,
            ..Default::default()
        });
        for n in [4usize, 8] {
            let topo = ClusterTopo::reconfigurable_4096(n);
            let rc = rfold::sim::Simulation::new(rfold::sim::SimConfig::new(
                topo,
                PolicyKind::Reconfig,
            ))
            .run(&t);
            let rf = rfold::sim::Simulation::new(rfold::sim::SimConfig::new(
                topo,
                PolicyKind::RFold,
            ))
            .run(&t);
            expect(
                rf.jcr() >= rc.jcr() - 1e-9,
                format!("n={n} seed={seed}: {} < {}", rf.jcr(), rc.jcr()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_ocs_crossbar_invariant_under_churn() {
    check("OCS invariants under churn", 20, |rng| {
        let mut cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let mut policy = Reconfig::new();
        let mut live = Vec::new();
        for job in 0..30u64 {
            if !live.is_empty() && rng.chance(0.4) {
                let id = live.swap_remove(rng.below(live.len()));
                cluster.release(id);
            }
            let shape = random_shape(rng);
            if let Some(plan) = policy.place_now(&cluster, job, shape) {
                plan.commit(&mut cluster).map_err(|e| e.to_string())?;
                live.push(job);
            }
            expect(
                cluster.ocs().unwrap().check_invariants(),
                "crossbar invariant",
            )?;
        }
        Ok(())
    });
}
