//! Cross-module integration: trace generator → policies → simulator →
//! metrics, asserting the paper's qualitative results hold end-to-end.

use rfold::metrics::summarize;
use rfold::placement::PolicyKind;
use rfold::sim::engine::{RunResult, SimConfig, Simulation};
use rfold::sim::experiments;
use rfold::topology::cluster::ClusterTopo;
use rfold::trace::gen::{generate, TraceConfig};
use rfold::trace::JobSpec;

fn run(policy: PolicyKind, topo: ClusterTopo, trace: &[JobSpec]) -> RunResult {
    Simulation::new(SimConfig::new(topo, policy)).run(trace)
}

fn trace(seed: u64, jobs: usize) -> Vec<JobSpec> {
    generate(&TraceConfig {
        num_jobs: jobs,
        seed,
        ..Default::default()
    })
}

#[test]
fn table1_ordering_holds() {
    // FirstFit < Folding, Reconfig(8³) < RFold(8³), 4³ cells at 100%.
    let mut jcr = std::collections::HashMap::new();
    for seed in [3u64, 4] {
        let t = trace(seed, 160);
        for (name, policy, topo) in [
            ("ff", PolicyKind::FirstFit, ClusterTopo::static_4096()),
            ("fold", PolicyKind::Folding, ClusterTopo::static_4096()),
            ("rc8", PolicyKind::Reconfig, ClusterTopo::reconfigurable_4096(8)),
            ("rf8", PolicyKind::RFold, ClusterTopo::reconfigurable_4096(8)),
            ("rc4", PolicyKind::Reconfig, ClusterTopo::reconfigurable_4096(4)),
            ("rf4", PolicyKind::RFold, ClusterTopo::reconfigurable_4096(4)),
        ] {
            let r = run(policy, topo, &t);
            *jcr.entry(name).or_insert(0.0) += r.jcr() / 2.0;
        }
    }
    assert!(jcr["ff"] < jcr["fold"], "{jcr:?}");
    assert!(jcr["rc8"] < jcr["rf8"], "{jcr:?}");
    assert!(jcr["fold"] < jcr["rf8"], "{jcr:?}");
    assert!(jcr["rc4"] > 0.999 && jcr["rf4"] > 0.999, "{jcr:?}");
}

#[test]
fn rfold_jct_never_worse_at_4cubes() {
    let t = trace(11, 140);
    let topo = ClusterTopo::reconfigurable_4096(4);
    let rc = run(PolicyKind::Reconfig, topo, &t);
    let rf = run(PolicyKind::RFold, topo, &t);
    let p = |r: &RunResult, q| rfold::util::stats::percentile_of(&r.jcts(&t), q);
    assert!(p(&rf, 50.0) <= p(&rc, 50.0) * 1.05, "p50 regressed");
    assert!(p(&rf, 90.0) <= p(&rc, 90.0) * 1.05, "p90 regressed");
}

#[test]
fn utilization_cdf_sane_and_summary_consistent() {
    let t = trace(5, 120);
    let r = run(
        PolicyKind::RFold,
        ClusterTopo::reconfigurable_4096(4),
        &t,
    );
    let pairs = vec![(&r, t.as_slice())];
    let s = summarize("cell", &pairs);
    assert!(s.avg_util > 0.0 && s.avg_util <= 1.0);
    for w in s.util_cdf.windows(2) {
        assert!(w[1].1 >= w[0].1 - 1e-12, "CDF must be monotone");
    }
    assert!(s.jct_p50 <= s.jct_p99);
}

#[test]
fn motivation_rows_are_ordered() {
    let rows = experiments::motivation_rows();
    assert_eq!(rows.len(), 5);
    // Baseline first, then strictly increasing contention.
    assert!((rows[0].1 - 1.0).abs() < 1e-9);
    assert!(rows[2].1 < rows[3].1 && rows[3].1 < rows[4].1);
}

#[test]
fn besteffort_trades_queueing_for_contention() {
    let t = trace(21, 120);
    let topo = ClusterTopo::reconfigurable_4096(4);
    let rf = run(PolicyKind::RFold, topo, &t);
    let be = run(PolicyKind::BestEffort, topo, &t);
    // Best-effort schedules everything it has XPUs for.
    assert!(be.jcr() >= rf.jcr() - 1e-9);
    // ...but pays for it in contention: its service times (finish − start)
    // are stretched relative to RFold's contention-free placements. (At
    // this load the stretched services also back the queue up — §5's
    // point that best-effort is *not* uniformly better.)
    let service = |r: &rfold::sim::engine::RunResult| {
        let mut total = 0.0;
        let mut n = 0usize;
        for (_, o) in &r.outcomes {
            if let rfold::sim::engine::JobOutcome::Completed { start, finish } = o {
                total += finish - start;
                n += 1;
            }
        }
        total / n as f64
    };
    assert!(
        service(&be) > service(&rf),
        "contention must stretch best-effort services: {} vs {}",
        service(&be),
        service(&rf)
    );
}

#[test]
fn cube_size_sweep_improves_reconfig() {
    // Paper §4: "Reconfig performs more efficiently with these smaller
    // cubes" — JCR(2³) ≥ JCR(4³) ≥ JCR(8³).
    let t = trace(31, 140);
    let jcr = |n| {
        run(
            PolicyKind::Reconfig,
            ClusterTopo::reconfigurable_4096(n),
            &t,
        )
        .jcr()
    };
    let (j8, j4, j2) = (jcr(8), jcr(4), jcr(2));
    assert!(j4 >= j8, "4^3 {j4} vs 8^3 {j8}");
    assert!(j2 >= j4 - 1e-9, "2^3 {j2} vs 4^3 {j4}");
}

#[test]
fn fold_dim_ablation_degrades_gracefully() {
    let t = trace(41, 120);
    let mut cfg = SimConfig::new(ClusterTopo::reconfigurable_4096(8), PolicyKind::RFold);
    let full = Simulation::new(cfg).run(&t).jcr();
    cfg.fold_dims_enabled = [false, false, false];
    let none = Simulation::new(cfg).run(&t).jcr();
    assert!(full >= none, "disabling folds cannot help: {full} vs {none}");
}
