//! Property suite for the epoch-cached placement index (`placement::index`):
//! under randomized commit/release churn, on both topology families,
//! index-backed queries must stay byte-equivalent to fresh rebuilds and
//! to the raw-bitmap oracles — and a policy that caches its index across
//! probes must decide exactly like one that rebuilds from scratch.

use rfold::placement::index::{PlacementIndex, ReconfigIndex};
use rfold::placement::policies::{Folding, RFold};
use rfold::placement::static_place::{self, OccupancySums};
use rfold::placement::PlacementPolicy;
use rfold::shape::JobShape;
use rfold::topology::cluster::{Allocation, ClusterState, ClusterTopo};
use rfold::topology::P3;
use rfold::util::prop::{check, expect};
use rfold::util::Pcg64;

fn random_shape(rng: &mut Pcg64) -> JobShape {
    let size = rng.range(1, 512);
    rfold::trace::gen::shape_for_size(rng, size, &Default::default())
        .unwrap_or(JobShape::new(1, 1, 1))
}

/// Commit a random batch of currently-free nodes as one allocation.
fn commit_random_nodes(cluster: &mut ClusterState, rng: &mut Pcg64, job: u64) {
    let total = cluster.num_nodes();
    let mut nodes: Vec<usize> = (0..rng.range(1, 200))
        .map(|_| rng.below(total))
        .filter(|&n| cluster.is_free(n))
        .collect();
    nodes.sort_unstable();
    nodes.dedup();
    if nodes.is_empty() {
        return;
    }
    cluster.commit(Allocation {
        job,
        nodes,
        cubes: vec![],
        ocs_entries: 0,
        rings: vec![],
        placed_ext: P3([1, 1, 1]),
    });
}

#[test]
fn prop_reconfig_index_matches_bitmap_oracle_under_churn() {
    check("reconfig index == bitmap oracle", 25, |rng| {
        let n = *rng.choose(&[2usize, 4, 8]);
        let mut cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(n));
        let mut live: Vec<u64> = Vec::new();
        for step in 0..12u64 {
            if !live.is_empty() && rng.chance(0.4) {
                let id = live.swap_remove(rng.below(live.len()));
                cluster.release(id);
            } else {
                commit_random_nodes(&mut cluster, rng, step);
                live.push(step);
            }
            let idx = ReconfigIndex::build(&cluster);
            // Box-freeness: O(1) summed tables vs the O(volume) bitmap scan.
            for _ in 0..40 {
                let cube = rng.below(idx.num_cubes());
                let off = P3([rng.below(n + 1), rng.below(n + 1), rng.below(n + 1)]);
                let ext = P3([
                    rng.range(1, n + 2),
                    rng.range(1, n + 2),
                    rng.range(1, n + 2),
                ]);
                expect(
                    idx.is_box_free(cube, off, ext)
                        == cluster.is_cube_box_free(cube, off, ext),
                    "indexed box query must equal the bitmap scan",
                )?;
            }
            // Candidate order: exactly the legacy per-probe computation.
            let mut legacy: Vec<usize> = (0..idx.num_cubes())
                .filter(|&c| cluster.cube_free_count(c) > 0)
                .collect();
            legacy.sort_by_key(|&c| cluster.cube_free_count(c));
            expect(
                idx.candidate_cubes() == legacy.as_slice(),
                "candidate-cube order must equal the legacy stable sort",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_static_index_matches_bruteforce_under_churn() {
    check("static sums == brute force", 25, |rng| {
        let mut cluster = ClusterState::new(ClusterTopo::static_4096());
        let ext = P3([16, 16, 16]);
        let mut live: Vec<u64> = Vec::new();
        for step in 0..10u64 {
            if !live.is_empty() && rng.chance(0.4) {
                let id = live.swap_remove(rng.below(live.len()));
                cluster.release(id);
            } else {
                commit_random_nodes(&mut cluster, rng, step);
                live.push(step);
            }
            let sums = OccupancySums::build(&cluster);
            expect(
                sums.free_count() == cluster.free_count(),
                "table free count must match the cluster",
            )?;
            for _ in 0..30 {
                let anchor = P3([rng.below(16), rng.below(16), rng.below(16)]);
                let e = P3([rng.range(1, 6), rng.range(1, 6), rng.range(1, 6)]);
                let brute = e.iter_box().all(|d| {
                    let p = P3([
                        (anchor.0[0] + d.0[0]) % 16,
                        (anchor.0[1] + d.0[1]) % 16,
                        (anchor.0[2] + d.0[2]) % 16,
                    ]);
                    cluster.is_free(p.index_in(ext))
                });
                expect(
                    sums.box_free(anchor, e) == brute,
                    "wrap-aware box query must equal the brute force scan",
                )?;
            }
            // The indexed first-fit scan equals the uncached wrapper.
            for _ in 0..10 {
                let e = P3([
                    rng.range(1, 17),
                    rng.range(1, 17),
                    rng.range(1, 17),
                ]);
                expect(
                    sums.find_first_box(e) == static_place::find_first_box(&cluster, e),
                    "indexed find_first_box must equal the fresh-build path",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cached_policy_decides_like_fresh_policy_under_churn() {
    // One long-lived policy (epoch-cached index reused across probes)
    // against a throwaway instance per probe (always a cold rebuild):
    // every plan must be byte-identical through arbitrary commit/release
    // churn, on both topology families.
    check("cached == fresh policy decisions", 12, |rng| {
        let reconfigurable = rng.chance(0.5);
        let topo = if reconfigurable {
            ClusterTopo::reconfigurable_4096(*rng.choose(&[2usize, 4, 8]))
        } else {
            ClusterTopo::static_4096()
        };
        let mut cluster = ClusterState::new(topo);
        let mut cached_rfold = RFold::new();
        let mut cached_folding = Folding::new();
        let mut live: Vec<u64> = Vec::new();
        for job in 0..25u64 {
            if !live.is_empty() && rng.chance(0.35) {
                let id = live.swap_remove(rng.below(live.len()));
                cluster.release(id);
            }
            let shape = random_shape(rng);
            let (cached_plan, fresh_plan) = if reconfigurable {
                (
                    cached_rfold.place_now(&cluster, job, shape),
                    RFold::new().place_now(&cluster, job, shape),
                )
            } else {
                (
                    cached_folding.place_now(&cluster, job, shape),
                    Folding::new().place_now(&cluster, job, shape),
                )
            };
            expect(
                cached_plan.as_ref().map(|p| &p.nodes)
                    == fresh_plan.as_ref().map(|p| &p.nodes),
                "cached index must never change the chosen nodes",
            )?;
            expect(
                cached_plan.as_ref().map(|p| &p.cubes)
                    == fresh_plan.as_ref().map(|p| &p.cubes),
                "cached index must never change the chosen cubes",
            )?;
            if let Some(plan) = cached_plan {
                plan.commit(&mut cluster).map_err(|e| e.to_string())?;
                live.push(job);
            }
            cluster.check_consistency()?;
        }
        Ok(())
    });
}

/// A random topology from both families, including a non-cubic static
/// extent so asymmetric strides get exercised.
fn random_topo(rng: &mut Pcg64) -> ClusterTopo {
    if rng.chance(0.5) {
        ClusterTopo::reconfigurable_4096(*rng.choose(&[2usize, 4, 8]))
    } else {
        ClusterTopo::Static {
            ext: *rng.choose(&[P3([16, 16, 16]), P3([8, 8, 32])]),
        }
    }
}

#[test]
fn prop_packed_occupancy_matches_bool_vec_oracle_under_churn() {
    // The packed `NodeSet` words behind `ClusterState`, driven through
    // the public API under commit/release/fail/repair churn, against a
    // plain `Vec<bool>` mirror — the representation the refactor
    // replaced. Every accessor the placement and engine layers read must
    // agree with the mirror at every step.
    check("packed occupancy == Vec<bool> oracle", 15, |rng| {
        let mut cluster = ClusterState::new(random_topo(rng));
        let total = cluster.num_nodes();
        // The mirror matches the flip semantics: a failed node reads as
        // busy to every occupancy query until repaired.
        let mut busy = vec![false; total];
        let mut failed = vec![false; total];
        let mut live: Vec<u64> = Vec::new();
        for step in 0..30u64 {
            match rng.below(4) {
                0 if !live.is_empty() => {
                    let id = live.swap_remove(rng.below(live.len()));
                    let alloc = cluster.release(id).expect("live job releases");
                    for n in alloc.nodes {
                        busy[n] = false;
                    }
                }
                1 => {
                    let n = rng.below(total);
                    if !busy[n] {
                        expect(cluster.fail_node(n), "a free node must fail")?;
                        expect(!cluster.fail_node(n), "double fail is a no-op")?;
                        busy[n] = true;
                        failed[n] = true;
                    }
                }
                2 if failed.iter().any(|&b| b) => {
                    let down: Vec<usize> = (0..total).filter(|&n| failed[n]).collect();
                    let n = down[rng.below(down.len())];
                    expect(cluster.repair_node(n), "a down node must repair")?;
                    expect(!cluster.repair_node(n), "double repair is a no-op")?;
                    busy[n] = false;
                    failed[n] = false;
                }
                _ => {
                    let mut nodes: Vec<usize> = (0..rng.range(1, 150))
                        .map(|_| rng.below(total))
                        .filter(|&n| cluster.is_free(n))
                        .collect();
                    nodes.sort_unstable();
                    nodes.dedup();
                    if nodes.is_empty() {
                        continue;
                    }
                    for &n in &nodes {
                        busy[n] = true;
                    }
                    cluster.commit(Allocation {
                        job: step,
                        nodes,
                        cubes: vec![],
                        ocs_entries: 0,
                        rings: vec![],
                        placed_ext: P3([1, 1, 1]),
                    });
                    live.push(step);
                }
            }
            let ones = busy.iter().filter(|&&b| b).count();
            expect(cluster.busy_count() == ones, "busy_count vs mirror")?;
            expect(cluster.free_count() == total - ones, "free_count vs mirror")?;
            expect(
                cluster.failed_count() == failed.iter().filter(|&&b| b).count(),
                "failed_count vs mirror",
            )?;
            for _ in 0..50 {
                let n = rng.below(total);
                expect(cluster.is_free(n) == !busy[n], "is_free vs mirror")?;
                expect(cluster.is_failed(n) == failed[n], "is_failed vs mirror")?;
            }
            let down: Vec<usize> = (0..total).filter(|&n| failed[n]).collect();
            expect(
                cluster.failed_nodes().collect::<Vec<_>>() == down,
                "failed_nodes iterator vs mirror",
            )?;
            // free_runs must tile exactly the maximal zero runs.
            let mut runs = Vec::new();
            let mut i = 0;
            while i < total {
                if busy[i] {
                    i += 1;
                    continue;
                }
                let start = i;
                while i < total && !busy[i] {
                    i += 1;
                }
                runs.push((start, i - start));
            }
            expect(
                cluster.free_runs().collect::<Vec<_>>() == runs,
                "free_runs vs mirror",
            )?;
            cluster.check_consistency()?;
        }
        Ok(())
    });
}

#[test]
fn prop_advanced_index_matches_fresh_rebuild_under_churn() {
    // The incremental path: one long-lived PlacementIndex advanced via
    // the cluster's delta journal after every mutation, against a fresh
    // O(V) rebuild — the PR-5 oracle. Every public query must agree, on
    // both topology families, through commit/release/fail/repair churn.
    check("advanced index == fresh rebuild", 12, |rng| {
        let mut cluster = ClusterState::new(random_topo(rng));
        let total = cluster.num_nodes();
        let mut idx = PlacementIndex::build(&cluster);
        let mut live: Vec<u64> = Vec::new();
        for step in 0..18u64 {
            match rng.below(4) {
                0 if !live.is_empty() => {
                    let id = live.swap_remove(rng.below(live.len()));
                    cluster.release(id);
                }
                1 => {
                    let n = rng.below(total);
                    if cluster.is_free(n) {
                        cluster.fail_node(n);
                    }
                }
                2 if cluster.failed_count() > 0 => {
                    let down: Vec<usize> = cluster.failed_nodes().collect();
                    cluster.repair_node(down[rng.below(down.len())]);
                }
                _ => {
                    commit_random_nodes(&mut cluster, rng, step);
                    live.push(step);
                }
            }
            // Single-step churn always fits the delta journal, so the
            // advance must succeed and land on the live epoch.
            expect(idx.advance(&cluster), "journal must cover one step")?;
            expect(idx.epoch() == cluster.epoch(), "advanced stamp is live")?;
            let fresh = PlacementIndex::build(&cluster);
            match cluster.topo() {
                ClusterTopo::Reconfigurable { grid } => {
                    let n = grid.n;
                    for _ in 0..40 {
                        let cube = rng.below(fresh.reconfig().num_cubes());
                        let off = P3([rng.below(n + 1), rng.below(n + 1), rng.below(n + 1)]);
                        let e = P3([
                            rng.range(1, n + 2),
                            rng.range(1, n + 2),
                            rng.range(1, n + 2),
                        ]);
                        expect(
                            idx.reconfig().is_box_free(cube, off, e)
                                == fresh.reconfig().is_box_free(cube, off, e),
                            "advanced box query must equal the fresh rebuild",
                        )?;
                    }
                    expect(
                        idx.reconfig().candidate_cubes() == fresh.reconfig().candidate_cubes(),
                        "advanced candidate order must equal the fresh rebuild",
                    )?;
                }
                ClusterTopo::Static { ext } => {
                    expect(
                        idx.static_sums().free_count() == fresh.static_sums().free_count(),
                        "advanced free count must equal the fresh rebuild",
                    )?;
                    for _ in 0..40 {
                        let anchor = P3([
                            rng.below(ext.0[0]),
                            rng.below(ext.0[1]),
                            rng.below(ext.0[2]),
                        ]);
                        let e = P3([rng.range(1, 6), rng.range(1, 6), rng.range(1, 6)]);
                        expect(
                            idx.static_sums().box_free(anchor, e)
                                == fresh.static_sums().box_free(anchor, e),
                            "advanced box query must equal the fresh rebuild",
                        )?;
                    }
                    for _ in 0..10 {
                        let e = P3([rng.range(1, 9), rng.range(1, 9), rng.range(1, 9)]);
                        expect(
                            idx.static_sums().find_first_box(e)
                                == fresh.static_sums().find_first_box(e),
                            "advanced first-fit scan must equal the fresh rebuild",
                        )?;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn placement_index_epoch_tracks_cluster() {
    // Deterministic regression for epoch invalidation: a stale index is
    // detectable by epoch comparison, and a rebuilt one sees the change.
    let mut cluster = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let before = PlacementIndex::build(&cluster);
    assert_eq!(before.epoch(), cluster.epoch());
    assert!(before
        .reconfig()
        .is_box_free(0, P3([0, 0, 0]), P3([4, 4, 4])));
    let mut policy = RFold::new();
    policy
        .place_now(&cluster, 1, JobShape::new(4, 4, 4))
        .unwrap()
        .commit(&mut cluster)
        .unwrap();
    assert_ne!(before.epoch(), cluster.epoch(), "stale epoch must differ");
    let after = PlacementIndex::build(&cluster);
    assert_eq!(after.epoch(), cluster.epoch());
    assert!(!after
        .reconfig()
        .is_box_free(0, P3([0, 0, 0]), P3([4, 4, 4])));
    cluster.release(1).unwrap();
    let released = PlacementIndex::build(&cluster);
    assert_ne!(released.epoch(), after.epoch());
    assert!(released
        .reconfig()
        .is_box_free(0, P3([0, 0, 0]), P3([4, 4, 4])));
}
