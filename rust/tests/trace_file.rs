//! Real-trace wiring (`--trace-file` → `Workload::from_csv` →
//! simulation): the checked-in sample CSV must round-trip through the
//! trace I/O layer byte-faithfully and drive every relevant policy
//! end-to-end through the registry.

use std::path::PathBuf;

use rfold::placement::PolicyRegistry;
use rfold::sim::{SimConfig, Simulation};
use rfold::topology::cluster::ClusterTopo;
use rfold::trace::io::{read_csv, write_csv};
use rfold::trace::scenarios::Workload;

fn sample_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data/philly_sample.csv")
}

#[test]
fn sample_csv_round_trips_through_trace_io() {
    let jobs = read_csv(&sample_path()).expect("checked-in sample parses");
    assert_eq!(jobs.len(), 12);
    // Arrivals are sorted and ids are unique — the engine's FIFO relies
    // on both.
    for w in jobs.windows(2) {
        assert!(w[0].arrival <= w[1].arrival);
    }
    let ids: std::collections::BTreeSet<u64> = jobs.iter().map(|j| j.id).collect();
    assert_eq!(ids.len(), jobs.len());

    // write → read round trip preserves every field (the sample uses the
    // writer's own precision, so values survive exactly).
    let tmp = std::env::temp_dir().join("rfold_sample_roundtrip.csv");
    write_csv(&tmp, &jobs).unwrap();
    let back = read_csv(&tmp).unwrap();
    assert_eq!(jobs.len(), back.len());
    for (a, b) in jobs.iter().zip(&back) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.shape, b.shape);
        assert!((a.arrival - b.arrival).abs() < 1e-9, "arrival {}", a.id);
        assert!((a.duration - b.duration).abs() < 1e-9);
        assert!((a.comm_frac - b.comm_frac).abs() < 1e-9);
    }
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn workload_replays_the_sample_unchanged() {
    let w = Workload::from_csv(&sample_path()).unwrap();
    assert_eq!(w.name(), "philly_sample");
    // Seed and requested size are ignored: one recorded realization.
    assert_eq!(w.trace(999, 1), w.trace(3, 42));
    assert_eq!(w.num_jobs(999), 12);
}

#[test]
fn sample_trace_drives_policies_end_to_end() {
    let w = Workload::from_csv(&sample_path()).unwrap();
    let t = w.trace(0, 0);
    let reg = PolicyRegistry::global();

    // RFold on the reconfigurable cluster places everything in the sample.
    let rfold = reg.resolve("rfold").unwrap();
    let r = Simulation::new(SimConfig::new(
        ClusterTopo::reconfigurable_4096(4),
        rfold,
    ))
    .run(&t);
    assert_eq!(r.scheduled, t.len(), "RFold(4^3) places the whole sample");
    assert_eq!(r.dropped, 0);
    assert_eq!(r.jcts(&t).len(), t.len());

    // FirstFit on the static torus must drop the 4×4×32 job (id 3) but
    // finish the trace.
    let ff = reg.resolve("firstfit").unwrap();
    let r = Simulation::new(SimConfig::new(ClusterTopo::static_4096(), ff)).run(&t);
    assert!(r.dropped >= 1, "4x4x32 cannot fit the static torus");
    assert_eq!(r.scheduled + r.dropped, t.len());
}
