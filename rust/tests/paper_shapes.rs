//! Every concrete example the paper walks through, as executable checks.

use rfold::placement::policies::{FirstFit, RFold, Reconfig};
use rfold::placement::PlacementPolicy;
use rfold::placement::reconfig_place;
use rfold::shape::fold::{enumerate_variants, FoldKind, Variant};
use rfold::shape::JobShape;
use rfold::topology::cluster::{ClusterState, ClusterTopo};
use rfold::topology::P3;

#[test]
fn s2_shape_semantics() {
    // "a job with a 4×6×1 shape signifies ... six-way TP ... four-way DP"
    let s = JobShape::new(4, 6, 1);
    assert_eq!(s.size(), 24);
    assert_eq!(s.dimensionality(), 2);
    // "a 18×1×1 shape indicates DP-only, and 4×4×4 denotes DP+TP+PP"
    assert_eq!(JobShape::new(18, 1, 1).dimensionality(), 1);
    assert_eq!(JobShape::new(4, 4, 4).dimensionality(), 3);
}

#[test]
fn s3_2_static_torus_cannot_host_4x4x32() {
    // "Consider a job that requires 4×4×32 XPUs ... this job can never be
    // placed because one of its dimensions exceeds the maximum dimension
    // size of the torus (32>16)."
    let c = ClusterState::new(ClusterTopo::static_4096());
    let mut ff = FirstFit::new();
    assert!(!ff.feasible_ever(c.topo(), JobShape::new(4, 4, 32)));
}

#[test]
fn s3_2_reconfigurable_hosts_4x4x32_with_8_cubes() {
    // "we only need eight 4×4×4 cubes to be reconfigured side-by-side"
    let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let v = Variant::identity(JobShape::new(4, 4, 32));
    let p = reconfig_place::place(&c, &v, 1).unwrap();
    assert_eq!(p.cubes.len(), 8);
    assert_eq!(p.wrap, [true, true, true]);
}

#[test]
fn s3_2_4x4x34_strands_a_partial_cube() {
    // "When job shapes are not a multiple of four—for example, 4×4×34—it
    // results in at least one partially used cube" and loses wrap-around.
    let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let v = Variant::identity(JobShape::new(4, 4, 34));
    let p = reconfig_place::place(&c, &v, 1).unwrap();
    assert_eq!(p.cubes.len(), 9);
    assert!(!p.wrap[2], "no wrap-around on the 34 dimension");
    p.commit(&mut c).unwrap();
    let partial = p
        .cubes
        .iter()
        .filter(|&&cu| {
            let f = c.cube_free_count(cu);
            f > 0 && f < 64
        })
        .count();
    assert_eq!(partial, 1, "exactly one partially used cube");
}

#[test]
fn fig2_left_green_18x1x1_folds_into_two_cubes() {
    // "the green job ... is a 1D job of shape 18×1×1. There are only two
    // available 4×4×4 cubes ... With folding, we are able to find 18
    // scattered XPUs forming a cycle."
    let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let mut rfold = RFold::new();
    let plan = rfold.place_now(&c, 1, JobShape::new(18, 1, 1)).unwrap();
    assert!(plan.cubes.len() <= 2, "18 XPUs fit two cubes: {plan:?}");
    // Reconfig-only needs a straight 18-line = 5 chained cubes.
    let mut rc = Reconfig::new();
    let plan_rc = rc.place_now(&c, 2, JobShape::new(18, 1, 1)).unwrap();
    assert!(plan_rc.cubes.len() >= 5);
}

#[test]
fn fig2_middle_1x6x4_folds_to_4x2x3() {
    // "we can fold the original 2D job to a 3D job of shape 4×2×3 ...
    // shape 1×6×4 is graph-homomorphic to shape 4×2×3"
    let vs = enumerate_variants(JobShape::new(1, 6, 4), 64);
    let v = vs
        .iter()
        .find(|v| {
            let mut d = v.placed.0;
            d.sort_unstable();
            d == [2, 3, 4] && v.kind != FoldKind::Identity
        })
        .expect("the 4×2×3 fold must be generated");
    rfold::shape::verify::verify(v, v.requires_wrap).unwrap();
    // All rings close inside the box (the Y′ circular mapping).
    let rc = rfold::shape::verify::ring_closures(v, [false; 3]);
    for (len, closed) in rc {
        if len == 6 {
            assert!(closed, "the 6-ring must close via the fold");
        }
    }
}

#[test]
fn fig2_right_4x8x2_folds_into_one_cube() {
    // "Through folding, it is possible to place the entire job in one
    // single 4×4×4 cube."
    let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let mut rfold = RFold::new();
    let plan = rfold.place_now(&c, 1, JobShape::new(4, 8, 2)).unwrap();
    assert_eq!(plan.cubes.len(), 1);
    assert_eq!(plan.variant.placed, P3([4, 4, 4]));
}

#[test]
fn s3_3_4x8x3_cannot_fold_to_4x4x6() {
    // "a job of shape 4×8×3 cannot be folded to 4×4×6 ... the middle
    // layer cannot be mapped to any cycle"
    let vs = enumerate_variants(JobShape::new(4, 8, 3), 256);
    assert!(vs.iter().all(|v| v.kind == FoldKind::Identity));
}

#[test]
fn s3_3_foldability_ordering() {
    // "jobs can be ranked by their foldability ... 1D > 2D > 3D": count
    // non-identity variants for same-size jobs of each dimensionality.
    let count = |s: JobShape| {
        enumerate_variants(s, 256)
            .iter()
            .filter(|v| v.kind != FoldKind::Identity)
            .count()
    };
    let c1 = count(JobShape::new(24, 1, 1));
    let c2 = count(JobShape::new(6, 4, 1));
    let c3 = count(JobShape::new(2, 3, 4));
    assert!(c1 >= c2, "1D ({c1}) >= 2D ({c2})");
    assert!(c2 >= c3, "2D ({c2}) >= 3D ({c3})");
}

#[test]
fn s2_wraparound_only_at_multiples_of_n() {
    // "jobs in a reconfigurable torus only receive wrap-around links when
    // their shapes are a multiple of the cube dimension size N"
    let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    for (len, wrap) in [(4usize, true), (8, true), (6, false), (7, false), (12, true)] {
        let v = Variant::identity(JobShape::new(len, 2, 2));
        let p = reconfig_place::place(&c, &v, 1).unwrap();
        assert_eq!(p.wrap[0], wrap, "len={len}");
    }
}
