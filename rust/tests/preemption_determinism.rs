//! Preemption/defragmentation determinism contracts (the PR-7 suite):
//!
//! * a preemptive sweep (`--with preempt=...,defrag=idle,...`) must emit
//!   SWEEP rows byte-identical across worker counts AND between local and
//!   TCP-pool execution — eviction, checkpoint credit, and migration
//!   surcharges are pure functions of the trial seed;
//! * priority classes must survive the pool wire (the optional eighth
//!   job-array element) bit-exactly;
//! * rows without preemption knobs must carry no disruption keys at all —
//!   the preemption machinery is invisible until switched on;
//! * each preemption knob combination must occupy its own result-cache
//!   key, so a preemptive trial can never be served a non-preemptive
//!   twin's bytes (or vice versa);
//! * defragmentation must never strand a job: every trace entry ends with
//!   exactly one outcome, moved jobs keep their completion events;
//! * on a head-of-line-blocked two-class trace, priority preemption must
//!   improve JCR over the FIFO twin (the paper's multi-tenant motivation).

use rfold::metrics::report;
use rfold::placement::builtins;
use rfold::shape::JobShape;
use rfold::sim::experiments as exp;
use rfold::sim::sweep::{self, ResultCache, SweepConfig};
use rfold::sim::{SimConfig, Simulation};
use rfold::topology::cluster::ClusterTopo;
use rfold::trace::gen::{generate, TraceConfig};
use rfold::trace::scenarios::{ModifierSet, Scenario, Workload};
use rfold::trace::JobSpec;

/// One static + one reconfigurable cell: crosses the contiguous and
/// folding placement paths without long runtimes.
fn cells() -> Vec<exp::Cell> {
    exp::table1_cells()
        .into_iter()
        .filter(|c| matches!(c.label, "Folding (16^3)" | "RFold (4^3)"))
        .collect()
}

/// The full disruption stack, layered over fault injection: preemption
/// (SRTF tie-break on the single-class synthetic trace), checkpointed
/// restarts, migration surcharge, and idle-time defragmentation.
fn mods() -> ModifierSet {
    ModifierSet::parse(
        "failures=philly,preempt=priority,migration-cost=30s,defrag=idle,checkpoint=10m",
    )
    .unwrap()
}

fn rows_json(workers: usize, m: ModifierSet) -> Vec<String> {
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let rows = sweep::run_grid_with(
        &cells(),
        &workloads,
        3,
        40,
        5,
        m,
        &ResultCache::new(),
        &sweep::LocalExecutor::new(workers),
    );
    rows.iter().map(report::sweep_row_json).collect()
}

/// A trace whose head fills the whole cluster for 10000 s, a small
/// high-priority job arriving early, and a late straggler that stretches
/// the horizon far enough for the evicted blocker to restart and finish:
/// the canonical preemption beneficiary. Under FIFO only the blocker
/// completes; with `preempt=priority` the small job runs immediately and
/// the blocker still completes after its restart.
fn two_class_jobs() -> Vec<JobSpec> {
    vec![
        JobSpec {
            id: 0,
            arrival: 0.0,
            duration: 10_000.0,
            shape: JobShape::new(16, 16, 16),
            comm_frac: 0.3,
            priority: 0,
        },
        JobSpec {
            id: 1,
            arrival: 10.0,
            duration: 10.0,
            shape: JobShape::new(2, 2, 2),
            comm_frac: 0.3,
            priority: 1,
        },
        JobSpec {
            id: 2,
            arrival: 200.0,
            duration: 1.0,
            shape: JobShape::new(1, 1, 1),
            comm_frac: 0.3,
            priority: 1,
        },
    ]
}

#[test]
fn preemptive_rows_byte_identical_across_worker_counts() {
    let one = rows_json(1, mods());
    let eight = rows_json(8, mods());
    assert_eq!(one.len(), eight.len());
    for (a, b) in one.iter().zip(&eight) {
        assert_eq!(
            a, b,
            "preemptive sweep row differs between --workers 1 and --workers 8"
        );
    }
}

#[test]
fn preemptive_rows_byte_identical_local_vs_pool() {
    let addr = rfold::coordinator::pool::spawn_worker().expect("spawn worker");
    let pool = rfold::coordinator::pool::PoolExecutor::new(vec![addr.to_string()]);
    let workloads = [Workload::Synthetic(Scenario::PaperDefault)];
    let grid = |executor: &dyn sweep::TrialExecutor| -> Vec<String> {
        sweep::run_grid_with(
            &cells(),
            &workloads,
            2,
            30,
            5,
            mods(),
            &ResultCache::new(),
            executor,
        )
        .iter()
        .map(report::sweep_row_json)
        .collect()
    };
    let local = grid(&sweep::LocalExecutor::new(1));
    let pooled = grid(&pool);
    assert_eq!(local, pooled, "pool must reproduce preemptive rows byte-exactly");
    let stats = pool.stats();
    assert_eq!(
        stats.leader_fallback, 0,
        "the worker must have served the preemptive items itself"
    );
}

#[test]
fn priority_classes_cross_the_pool_wire() {
    // A CSV workload with real priority classes ships its job list inline;
    // the optional eighth wire element must reach the worker bit-exactly
    // or priority preemption would silently degrade to FIFO remotely.
    let addr = rfold::coordinator::pool::spawn_worker().expect("spawn worker");
    let pool = rfold::coordinator::pool::PoolExecutor::new(vec![addr.to_string()]);
    let workloads = [Workload::from_jobs("two-class".into(), two_class_jobs())];
    let m = ModifierSet::parse("preempt=priority").unwrap();
    let grid = |executor: &dyn sweep::TrialExecutor| -> Vec<String> {
        sweep::run_grid_with(
            &cells(),
            &workloads,
            2,
            9,
            5,
            m,
            &ResultCache::new(),
            executor,
        )
        .iter()
        .map(report::sweep_row_json)
        .collect()
    };
    let local = grid(&sweep::LocalExecutor::new(1));
    let pooled = grid(&pool);
    assert_eq!(local, pooled, "priority classes must survive the wire");
    assert_eq!(pool.stats().leader_fallback, 0);
}

#[test]
fn preempt_free_rows_carry_no_disruption_keys() {
    // Fault injection alone is not "disruption" in the preemption sense:
    // its rows (and plain rows) must not grow new JSON keys, keeping them
    // byte-compatible with every pre-preemption consumer.
    for m in [
        ModifierSet::default(),
        ModifierSet::parse("failures=philly").unwrap(),
    ] {
        for row in rows_json(2, m) {
            assert!(
                !row.contains("\"preemptions\"") && !row.contains("\"wasted_work_s\""),
                "knob-free row grew disruption keys: {row}"
            );
        }
    }
}

#[test]
fn preempt_knobs_occupy_distinct_cache_keys() {
    // The same cell swept with different preemption knobs must miss the
    // cache each time — a migration-cost change that silently replayed
    // the cheap twin's bytes would corrupt every comparison.
    let cell = cells()[0];
    let cache = ResultCache::new();
    let run = |spec: Option<&str>| {
        let mut cfg = SweepConfig::new(2, 30, 7);
        cfg.workers = 1;
        cfg.modifiers = spec.map_or_else(ModifierSet::default, |s| {
            ModifierSet::parse(s).unwrap()
        });
        sweep::run_trials_with(cell, &cfg, &cache)
    };
    run(None);
    assert_eq!(cache.misses(), 2);
    run(Some("preempt=priority"));
    assert_eq!(cache.misses(), 4, "preemptive trials must not hit plain entries");
    run(Some("preempt=priority,migration-cost=30s"));
    assert_eq!(cache.misses(), 6, "the migration cost must be part of the key");
    run(Some("preempt=priority,migration-cost=30s,defrag=idle,checkpoint=10m"));
    assert_eq!(cache.misses(), 8, "defrag/checkpoint must be part of the key");
    // Replaying any of the four is all hits.
    run(Some("preempt=priority"));
    assert_eq!(cache.misses(), 8);
}

#[test]
fn defrag_never_strands_jobs() {
    // Defragmentation relocates live jobs between completion events; a
    // botched move would lose a completion and leave a job with no
    // outcome. Every trace entry must finish with exactly one outcome on
    // both topology families, with preemption churning the queue too.
    let trace = generate(&TraceConfig {
        num_jobs: 60,
        seed: 11,
        ..Default::default()
    });
    let m = ModifierSet::parse("preempt=srtf,defrag=idle").unwrap();
    for (policy, topo) in [
        (builtins::FIRST_FIT, ClusterTopo::static_4096()),
        (builtins::RFOLD, ClusterTopo::reconfigurable_4096(4)),
    ] {
        let mut sc = SimConfig::new(topo, policy);
        sc.modifiers = m.for_trial(11);
        let r = Simulation::new(sc).run(&trace);
        assert_eq!(
            r.outcomes.len(),
            trace.len(),
            "{}: every job needs exactly one outcome",
            r.policy
        );
        let mut ids: Vec<u64> = r.outcomes.iter().map(|&(id, _)| id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "{}: duplicated outcome", r.policy);
    }
}

#[test]
fn priority_preemption_improves_jcr_on_a_blocked_trace() {
    // The acceptance trade: on a head-of-line-blocked two-class trace the
    // preemptive twin completes the high-priority burst the FIFO engine
    // strands behind the 10000-second blocker.
    let workload = Workload::from_jobs("two-class".into(), two_class_jobs());
    let row = |m: ModifierSet| {
        let rows = sweep::run_grid_with(
            &cells()[..1], // Folding (16^3)
            &[workload.clone()],
            2,
            9,
            5,
            m,
            &ResultCache::new(),
            &sweep::LocalExecutor::new(1),
        );
        assert_eq!(rows.len(), 1);
        rows.into_iter().next().unwrap()
    };
    let fifo = row(ModifierSet::default());
    let preempt = row(ModifierSet::parse("preempt=priority").unwrap());
    assert!(
        preempt.summary.avg_jcr_pct > fifo.summary.avg_jcr_pct,
        "preemption must improve JCR: {} vs {}",
        preempt.summary.avg_jcr_pct,
        fifo.summary.avg_jcr_pct
    );
    assert!(preempt.summary.avg_preemptions > 0.0, "preemption must fire");
    let json = report::sweep_row_json(&preempt);
    assert!(
        json.contains("\"preemptions\"") && json.contains("\"useful_util\""),
        "disrupted row must carry the accounting keys: {json}"
    );
}

#[test]
fn preemptive_runs_are_reproducible_end_to_end() {
    // Same grid, fresh caches, different worker counts: byte-identical
    // rows — the `--with preempt=...` acceptance path in miniature.
    assert_eq!(rows_json(4, mods()), rows_json(2, mods()));
}
