//! Bench: regenerate **Figure 4** (cluster-utilization CDF per policy)
//! plus the paper's two headline deltas (+57% absolute over FirstFit,
//! +20% over Reconfig).

use rfold::metrics::report;
use rfold::sim::experiments as exp;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs = env("RFOLD_BENCH_RUNS", 8);
    let jobs = env("RFOLD_BENCH_JOBS", 512);
    let seed = env("RFOLD_BENCH_SEED", 1) as u64;
    rfold::util::bench::section(&format!(
        "Figure 4 — utilization CDFs ({runs} runs x {jobs} jobs)"
    ));
    let sums: Vec<_> = exp::table1_cells()
        .into_iter()
        .map(|c| exp::run_cell(c, runs, jobs, seed))
        .collect();
    report::print_fig4(&sums);
    let util = |l: &str| sums.iter().find(|s| s.label == l).unwrap().avg_util;
    println!(
        "\nFIG4-DELTA RFold(4^3) - FirstFit = {:+.1} points (paper: +57 absolute)",
        100.0 * (util("RFold (4^3)") - util("FirstFit (16^3)"))
    );
    println!(
        "FIG4-DELTA RFold(4^3) - Reconfig(4^3) = {:+.1} points (paper: +20)",
        100.0 * (util("RFold (4^3)") - util("Reconfig (4^3)"))
    );
}
