//! Bench: **ablation A1** (cube-size sweep 8³/4³/2³ for Reconfig & RFold)
//! and **A2** (folding-dimensionality knockouts for RFold 4³) — the design
//! choices §5 calls out.

use rfold::metrics::report;
use rfold::placement::builtins;
use rfold::sim::experiments as exp;
use rfold::topology::cluster::ClusterTopo;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs = env("RFOLD_BENCH_RUNS", 5);
    let jobs = env("RFOLD_BENCH_JOBS", 256);
    let seed = env("RFOLD_BENCH_SEED", 1) as u64;

    rfold::util::bench::section("Ablation A1 — cube-size sweep");
    for cell in exp::ablation_cube_cells() {
        let s = exp::run_cell(cell, runs, jobs, seed);
        println!(
            "ABLATION-CUBES {:<16} jcr={:>6.2}% p50={:>10} p99={:>10} util={:.3}",
            s.label,
            s.avg_jcr_pct,
            report::fmt_secs(s.jct_p50),
            report::fmt_secs(s.jct_p99),
            s.avg_util
        );
    }

    rfold::util::bench::section("Ablation A2 — folding dimensionality (RFold 4^3)");
    let cell = exp::Cell {
        policy: builtins::RFOLD,
        topo: ClusterTopo::reconfigurable_4096(4),
        label: "RFold (4^3)",
    };
    for (label, dims) in [
        ("all folds", [true, true, true]),
        ("no 1D folds", [false, true, true]),
        ("no 2D folds", [true, false, true]),
        ("no 3D folds", [true, true, false]),
        ("rotations only", [false, false, false]),
    ] {
        let s = exp::run_cell_with(cell, runs, jobs, seed, dims);
        println!(
            "ABLATION-FOLDS {:<16} jcr={:>6.2}% p50={:>10} util={:.3}",
            label,
            s.avg_jcr_pct,
            report::fmt_secs(s.jct_p50),
            s.avg_util
        );
    }
}
