//! Bench: the full policy × topology × scenario grid on the sharded sweep
//! runner, plus the serial-vs-sharded wall-clock comparison for the
//! Table-1 cells (the headline speedup of the sweep subsystem).
//!
//! Configure with `RFOLD_BENCH_RUNS` (default 8), `RFOLD_BENCH_JOBS`
//! (default 192), `RFOLD_BENCH_SEED` (default 1), `RFOLD_BENCH_THREADS`
//! (default 0 = auto).

use std::time::Instant;

use rfold::metrics::report;
use rfold::sim::experiments as exp;
use rfold::sim::sweep;
use rfold::trace::scenarios::Scenario;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs = env("RFOLD_BENCH_RUNS", 8);
    let jobs = env("RFOLD_BENCH_JOBS", 192);
    let seed = env("RFOLD_BENCH_SEED", 1) as u64;
    let threads = env("RFOLD_BENCH_THREADS", 0);
    let cells = exp::table1_cells();

    rfold::util::bench::section(&format!(
        "sweep grid — {} cells x {} scenarios ({runs} runs x {jobs} jobs)",
        cells.len(),
        Scenario::ALL.len()
    ));
    let rows = sweep::run_grid(&cells, &Scenario::ALL, runs, jobs, seed, threads);
    report::print_sweep(&rows);

    rfold::util::bench::section("sharded-runner speedup (Table-1 cells, paper-default)");
    let t0 = Instant::now();
    let serial = sweep::run_grid(&cells, &[Scenario::PaperDefault], runs, jobs, seed, 1);
    let t_serial = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let sharded = sweep::run_grid(&cells, &[Scenario::PaperDefault], runs, jobs, seed, threads);
    let t_sharded = t1.elapsed().as_secs_f64();
    // Sharding must never change results — only wall-clock.
    let json = |rows: &[sweep::SweepRow]| -> Vec<String> {
        rows.iter().map(report::sweep_row_json).collect()
    };
    assert_eq!(json(&serial), json(&sharded), "sharding changed sweep rows");
    println!(
        "SWEEP-SPEEDUP threads={} serial={t_serial:.1}s sharded={t_sharded:.1}s speedup={:.2}x",
        if threads == 0 { sweep::auto_threads() } else { threads },
        t_serial / t_sharded.max(1e-9)
    );
}
