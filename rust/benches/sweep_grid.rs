//! Bench: the full policy × topology × scenario grid on the global
//! work-queue runner, the serial-vs-parallel wall-clock comparison for the
//! Table-1 cells, and the warm-cache replay (the two headline speedups of
//! the sweep subsystem).
//!
//! Configure with `RFOLD_BENCH_RUNS` (default 8), `RFOLD_BENCH_JOBS`
//! (default 192), `RFOLD_BENCH_SEED` (default 1), `RFOLD_BENCH_WORKERS`
//! (default 0 = auto; `RFOLD_BENCH_THREADS` kept as an alias).

use std::time::Instant;

use rfold::metrics::report;
use rfold::sim::experiments as exp;
use rfold::sim::sweep::{self, ResultCache};
use rfold::trace::scenarios::{Scenario, Workload};

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs = env("RFOLD_BENCH_RUNS", 8);
    let jobs = env("RFOLD_BENCH_JOBS", 192);
    let seed = env("RFOLD_BENCH_SEED", 1) as u64;
    let workers = env("RFOLD_BENCH_WORKERS", env("RFOLD_BENCH_THREADS", 0));
    let cells = exp::table1_cells();

    rfold::util::bench::section(&format!(
        "sweep grid — {} cells x {} scenarios ({runs} runs x {jobs} jobs)",
        cells.len(),
        Scenario::ALL.len()
    ));
    let grid_cache = ResultCache::new();
    let all: Vec<Workload> = Scenario::ALL.iter().copied().map(Workload::Synthetic).collect();
    let rows = sweep::run_grid(
        &cells,
        &all,
        runs,
        jobs,
        seed,
        workers,
        &grid_cache,
    );
    report::print_sweep(&rows);

    // Fresh caches per timed run: the comparison measures the queue, not
    // cache replay.
    rfold::util::bench::section("work-queue speedup (Table-1 cells, paper-default)");
    let t0 = Instant::now();
    let serial = sweep::run_grid(
        &cells,
        &[Workload::Synthetic(Scenario::PaperDefault)],
        runs,
        jobs,
        seed,
        1,
        &ResultCache::new(),
    );
    let t_serial = t0.elapsed().as_secs_f64();
    let warm = ResultCache::new();
    let t1 = Instant::now();
    let parallel = sweep::run_grid(
        &cells,
        &[Workload::Synthetic(Scenario::PaperDefault)],
        runs,
        jobs,
        seed,
        workers,
        &warm,
    );
    let t_parallel = t1.elapsed().as_secs_f64();
    // Worker count must never change results — only wall-clock.
    let json = |rows: &[sweep::SweepRow]| -> Vec<String> {
        rows.iter().map(report::sweep_row_json).collect()
    };
    assert_eq!(json(&serial), json(&parallel), "worker count changed sweep rows");
    println!(
        "SWEEP-SPEEDUP workers={} serial={t_serial:.1}s parallel={t_parallel:.1}s speedup={:.2}x",
        if workers == 0 { sweep::auto_workers() } else { workers },
        t_serial / t_parallel.max(1e-9)
    );

    rfold::util::bench::section("result-cache replay (same grid, warm cache)");
    let hits0 = warm.hits();
    let t2 = Instant::now();
    let replay = sweep::run_grid(
        &cells,
        &[Workload::Synthetic(Scenario::PaperDefault)],
        runs,
        jobs,
        seed,
        workers,
        &warm,
    );
    let t_replay = t2.elapsed().as_secs_f64();
    assert_eq!(json(&parallel), json(&replay), "cache replay changed sweep rows");
    println!(
        "SWEEP-CACHE warm replay={t_replay:.3}s ({} hits) cold={t_parallel:.1}s speedup={:.0}x",
        warm.hits() - hits0,
        t_parallel / t_replay.max(1e-9)
    );
}
