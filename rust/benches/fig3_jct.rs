//! Bench: regenerate **Figure 3** (JCT p50/p90/p99 for Reconfig vs RFold
//! at 4³ and 2³ cubes) plus the headline speedup ratios.

use rfold::metrics::report;
use rfold::sim::experiments as exp;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs = env("RFOLD_BENCH_RUNS", 8);
    let jobs = env("RFOLD_BENCH_JOBS", 512);
    let seed = env("RFOLD_BENCH_SEED", 1) as u64;
    rfold::util::bench::section(&format!(
        "Figure 3 — JCT percentiles ({runs} runs x {jobs} jobs)"
    ));
    let sums: Vec<_> = exp::fig3_cells()
        .into_iter()
        .map(|c| exp::run_cell(c, runs, jobs, seed))
        .collect();
    report::print_fig3(&sums);
    let find = |l: &str| sums.iter().find(|s| s.label == l).unwrap();
    let (rc4, rf4) = (find("Reconfig (4^3)"), find("RFold (4^3)"));
    let (rc2, rf2) = (find("Reconfig (2^3)"), find("RFold (2^3)"));
    println!(
        "FIG3-RATIO 4^3 p50={:.2}x p90={:.2}x p99={:.2}x   (paper: 11x / 6x / 2x)",
        rc4.jct_p50 / rf4.jct_p50,
        rc4.jct_p90 / rf4.jct_p90,
        rc4.jct_p99 / rf4.jct_p99
    );
    println!(
        "FIG3-RATIO 2^3 p50={:.2}x p90={:.2}x p99={:.2}x   (paper: up to 1.3x)",
        rc2.jct_p50 / rf2.jct_p50,
        rc2.jct_p90 / rf2.jct_p90,
        rc2.jct_p99 / rf2.jct_p99
    );
}
