//! Bench: regenerate **Table 1** (average JCR per policy/topology).
//!
//! Each `exp::run_cell` call rides the work-queue runner with the
//! process-wide result cache (`sim::sweep`): a cell's trials parallelize
//! across workers (cells themselves run sequentially here, one
//! `run_cell` at a time), and any cell already simulated this process is
//! served from the cache.
//!
//! Configure with env vars: `RFOLD_BENCH_RUNS` (default 20),
//! `RFOLD_BENCH_JOBS` (default 512), `RFOLD_BENCH_SEED` (default 1).
//! The paper uses 100 runs; `make bench-full` sets that.

use rfold::metrics::report;
use rfold::sim::experiments as exp;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs = env("RFOLD_BENCH_RUNS", 8);
    let jobs = env("RFOLD_BENCH_JOBS", 512);
    let seed = env("RFOLD_BENCH_SEED", 1) as u64;
    rfold::util::bench::section(&format!(
        "Table 1 — average JCR ({runs} runs x {jobs} jobs, seed {seed})"
    ));
    let paper = [10.4, 44.11, 31.46, 73.35, 100.0, 100.0];
    let mut sums = Vec::new();
    for (cell, p) in exp::table1_cells().into_iter().zip(paper) {
        let t0 = std::time::Instant::now();
        let s = exp::run_cell(cell, runs, jobs, seed);
        eprintln!(
            "  {} done in {:.1}s (paper: {p}%)",
            cell.label,
            t0.elapsed().as_secs_f64()
        );
        sums.push(s);
    }
    report::print_table1(&sums);
    println!("\npaper reference: FirstFit 10.4 / Folding 44.11 / Reconfig8 31.46 / RFold8 73.35 / Reconfig4 100 / RFold4 100");
}
