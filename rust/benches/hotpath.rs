//! Microbenchmarks of the placement hot path (the §Perf targets in
//! EXPERIMENTS.md): variant enumeration, box search, reconfig planning,
//! plan scoring (native and, when artifacts exist, PJRT), and end-to-end
//! simulator throughput.
//!
//! Machine-readable mode: `BENCH_JSON=BENCH_hotpath.json` writes one JSON
//! row per case (name, iters, ns_per_iter, p50/p99) so CI can track the
//! perf trajectory across PRs; `BENCH_SMOKE=1` truncates iteration counts
//! to a smoke run (see `util::bench`).

use std::rc::Rc;

use rfold::placement::index::{PlacementIndex, ReconfigIndex};
use rfold::placement::policies::RFold;
use rfold::placement::score::{hypothetical_occupancy, rank_plans, NativeScorer, PlanScorer};
use rfold::placement::{builtins, PlacementPolicy};
use rfold::placement::{reconfig_place, static_place};
use rfold::shape::fold::{enumerate_variants, Variant};
use rfold::shape::JobShape;
use rfold::sim::engine::{SimConfig, Simulation};
use rfold::topology::cluster::{ClusterState, ClusterTopo};
use rfold::topology::P3;
use rfold::util::bench::{bench, section, smoke_iters, write_json_env, BenchResult};
use rfold::util::Pcg64;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    // Shorthand: run one case at smoke-scaled iterations and collect it.
    macro_rules! case {
        ($name:expr, $warmup:expr, $iters:expr, $f:expr) => {
            results.push(bench($name, smoke_iters($warmup), smoke_iters($iters), $f))
        };
    }

    section("shape algebra");
    case!("enumerate_variants 18x1x1", 10, 200, || {
        enumerate_variants(JobShape::new(18, 1, 1), 256)
    });
    case!("enumerate_variants 4x8x2", 10, 200, || {
        enumerate_variants(JobShape::new(4, 8, 2), 256)
    });
    case!("rings 4x4x4 fold", 10, 200, || {
        let vs = enumerate_variants(JobShape::new(4, 8, 2), 64);
        vs.iter().map(|v| v.rings().len()).sum::<usize>()
    });

    section("placement engines (empty cluster)");
    let static_c = ClusterState::new(ClusterTopo::static_4096());
    case!("static find_first_box 4x4x4", 10, 200, || {
        static_place::find_first_box(&static_c, P3([4, 4, 4]))
    });
    let rc = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let v = Variant::identity(JobShape::new(4, 4, 32));
    // Renamed from "reconfig place 4x4x32 (8 cubes)": since the index PR,
    // the convenience wrapper builds a fresh ReconfigIndex per call, so
    // this row measures build + search — a different quantity than the
    // pre-index rows. The policy hot path amortizes the build per epoch
    // (see "placement under load" below).
    case!("reconfig place 4x4x32 (8 cubes, fresh index)", 10, 200, || {
        reconfig_place::place(&rc, &v, 1)
    });

    section("placement under load (50% busy cluster)");
    let mut busy = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
    let mut policy = RFold::new();
    let mut rng = Pcg64::seeded(3);
    let mut id = 0u64;
    let mut attempts = 0;
    while busy.utilization() < 0.5 && attempts < 2000 {
        attempts += 1;
        let size = rng.range(8, 256);
        if let Some(shape) =
            rfold::trace::gen::shape_for_size(&mut rng, size, &Default::default())
        {
            if let Some(plan) = policy.place_now(&busy, id, shape) {
                plan.commit(&mut busy).unwrap();
                id += 1;
            }
        }
    }
    case!("RFold plan 4x8x2 @50% util", 5, 100, || {
        policy.place_now(&busy, 999_999, JobShape::new(4, 8, 2))
    });
    case!("RFold plan 18x1x1 @50% util", 5, 100, || {
        policy.place_now(&busy, 999_999, JobShape::new(18, 1, 1))
    });

    section("spatial index (epoch rebuild cost vs per-probe savings)");
    case!("PlacementIndex build @50% util (4^3)", 5, 100, || {
        PlacementIndex::build(&busy)
    });
    let idx = ReconfigIndex::build(&busy);
    let v48 = Variant::identity(JobShape::new(4, 8, 2));
    case!("indexed place 4x8x2 @50% util", 5, 100, || {
        reconfig_place::place_indexed(&busy, &idx, &v48, 999_999, true)
    });
    // Build + search per call — the cost a caller pays when it cannot
    // amortize the index across probes (NOT the pre-index algorithm,
    // which paid per-probe sorts and O(box-volume) scans instead).
    case!("per-call-build place 4x8x2 @50% util", 5, 100, || {
        reconfig_place::place_with_offsets(&busy, &v48, 999_999)
    });
    let static_idx = static_place::OccupancySums::build(&static_c);
    case!("indexed find_first_box 4x4x4", 10, 200, || {
        static_idx.find_first_box(P3([4, 4, 4]))
    });

    section("plan scoring");
    let plans: Vec<_> = enumerate_variants(JobShape::new(4, 8, 2), 64)
        .iter()
        .filter_map(|v| reconfig_place::place(&busy, v, 999_999))
        .collect();
    eprintln!("  ({} candidate plans)", plans.len());
    case!("native rank_plans", 5, 100, || {
        rank_plans(&busy, &plans, &mut NativeScorer)
    });
    let (occ, cubes, n) = hypothetical_occupancy(&busy, &plans);
    case!("native frag_stats batch", 5, 100, || {
        NativeScorer.frag_stats(&occ, plans.len(), cubes, n)
    });
    let dir = rfold::runtime::Artifacts::default_dir();
    if !rfold::runtime::Artifacts::runtime_available() {
        eprintln!("  (skipping PJRT scorer: built without the `xla` feature)");
    } else if dir.join("manifest.json").exists() {
        let arts = Rc::new(rfold::runtime::Artifacts::load(&dir).unwrap());
        let mut xla = rfold::runtime::XlaScorer::new(arts);
        case!("xla frag_stats batch (PJRT)", 3, 30, || {
            xla.frag_stats(&occ, plans.len(), cubes, n)
        });
    } else {
        eprintln!("  (skipping PJRT scorer: run `make artifacts`)");
    }

    section("end-to-end simulation");
    let trace = rfold::trace::gen::generate(&rfold::trace::gen::TraceConfig {
        num_jobs: 256,
        ..Default::default()
    });
    case!("sim 256 jobs RFold(4^3)", 1, 5, || {
        Simulation::new(SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            builtins::RFOLD,
        ))
        .run(&trace)
        .scheduled
    });
    case!("sim 256 jobs FirstFit(16^3)", 1, 5, || {
        Simulation::new(SimConfig::new(
            ClusterTopo::static_4096(),
            builtins::FIRST_FIT,
        ))
        .run(&trace)
        .scheduled
    });

    write_json_env(&results);
}
