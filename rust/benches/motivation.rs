//! Bench: regenerate the **§3.1 motivation table** (contention slowdowns
//! on a 2×2 TPU-v2-like mesh) against the paper's measured percentages.

use rfold::sim::experiments as exp;

fn main() {
    rfold::util::bench::section("§3.1 motivation — placement-induced slowdowns");
    let paper = [1.0, 1.17, 1.35, 1.95, 2.86];
    println!("{:<46} {:>8} {:>8} {:>7}", "configuration", "model", "paper", "err%");
    let mut worst: f64 = 0.0;
    for (row, p) in exp::motivation_rows().iter().zip(paper) {
        let err = 100.0 * (row.1 - p) / p;
        worst = worst.max(err.abs());
        println!("MOTIV {:<40} {:>7.2}x {:>7.2}x {:>+6.1}%", row.0, row.1, p, err);
    }
    println!("worst calibration error: {worst:.1}%");
    assert!(worst < 10.0, "calibration drifted");
}
