//! Bench: **§5 "revisiting best-effort placement"** — sweep offered load
//! and find where non-contiguous placement (immediate start + contention)
//! beats contiguous RFold (queueing + exclusive links).

use rfold::placement::PolicyKind;
use rfold::sim::engine::{SimConfig, Simulation};
use rfold::topology::cluster::ClusterTopo;
use rfold::trace::gen::{generate, TraceConfig};
use rfold::util::stats;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs = env("RFOLD_BENCH_RUNS", 3);
    let jobs = env("RFOLD_BENCH_JOBS", 192);
    rfold::util::bench::section(
        "§5 crossover — best-effort vs RFold across offered load",
    );
    println!(
        "{:>10} {:>14} {:>14} {:>9}",
        "lull(s)", "RFold p50 JCT", "BestEff p50", "winner"
    );
    let topo = ClusterTopo::reconfigurable_4096(4);
    for lull in [12_000.0, 6_000.0, 3_800.0, 2_000.0, 1_000.0] {
        let mut rf_all = Vec::new();
        let mut be_all = Vec::new();
        for seed in 0..runs {
            let t = generate(&TraceConfig {
                num_jobs: jobs,
                seed: seed as u64 + 1,
                mean_lull: lull,
                ..Default::default()
            });
            let rf = Simulation::new(SimConfig::new(topo, PolicyKind::RFold)).run(&t);
            let be = Simulation::new(SimConfig::new(topo, PolicyKind::BestEffort)).run(&t);
            rf_all.extend(rf.jcts(&t));
            be_all.extend(be.jcts(&t));
        }
        let rf50 = stats::percentile_of(&rf_all, 50.0);
        let be50 = stats::percentile_of(&be_all, 50.0);
        println!(
            "CROSSOVER {:>7.0} {:>13.0}s {:>13.0}s {:>9}",
            lull,
            rf50,
            be50,
            if be50 < rf50 { "besteff" } else { "rfold" }
        );
    }
    println!("\n(best-effort wins when queueing delay under contiguous placement\n exceeds its contention slowdown — §5's stated condition)");
}
