//! Scale curve: decision latency and memory as the static torus grows
//! from the paper's 16³ toward a 64k-node machine (8³ → 16×16×256).
//!
//! Each extent runs the same workload through the FIFO engine (FirstFit,
//! so the cost measured is the topology/placement substrate, not policy
//! search), times a fresh `OccupancySums` build against a single-flip
//! incremental refresh, and records a peak-RSS proxy read from
//! `/proc/self/status` (`VmHWM`, kB; 0 where procfs is unavailable).
//! The RSS rows reuse the `ns_per_iter` JSON field to carry kB — the
//! name says so — because CI's perf-trajectory tooling reads one fixed
//! schema.
//!
//! `BENCH_SMOKE=1` truncates iteration counts; `BENCH_JSON=<path>`
//! (CI uses `BENCH_scale.json`) writes machine-readable rows.

use rfold::placement::builtins;
use rfold::placement::static_place::OccupancySums;
use rfold::sim::engine::{SimConfig, Simulation};
use rfold::topology::cluster::{ClusterState, ClusterTopo};
use rfold::topology::P3;
use rfold::util::bench::{bench, section, smoke_iters, write_json_env, BenchResult};

const EXTENTS: [[usize; 3]; 4] = [[8, 8, 8], [16, 16, 16], [16, 16, 64], [16, 16, 256]];
const JOBS: usize = 96;

/// Peak resident set size in kB (`VmHWM`), or 0.0 off-Linux.
fn peak_rss_kb() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0.0)
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    // One trace for every extent: the curve is "same workload, growing
    // machine". Shapes a small torus cannot fit are dropped by the
    // engine's infeasible-shape path, which is itself part of the cost.
    let trace = rfold::trace::gen::generate(&rfold::trace::gen::TraceConfig {
        num_jobs: JOBS,
        ..Default::default()
    });

    for ext in EXTENTS {
        let label = format!("{}x{}x{}", ext[0], ext[1], ext[2]);
        let topo = ClusterTopo::Static { ext: P3(ext) };
        section(&format!("static torus {label} ({} nodes)", topo.num_xpus()));

        let r = bench(
            &format!("sim {JOBS} jobs FirstFit {label}"),
            smoke_iters(1),
            smoke_iters(3),
            || {
                Simulation::new(SimConfig::new(topo, builtins::FIRST_FIT))
                    .run(&trace)
                    .scheduled
            },
        );
        eprintln!(
            "  ({} ns/decision over {JOBS} jobs)",
            (r.mean_ns / JOBS as f64).round()
        );
        results.push(r);

        let cluster = ClusterState::new(topo);
        results.push(bench(
            &format!("OccupancySums fresh build {label}"),
            smoke_iters(3),
            smoke_iters(20),
            || OccupancySums::build(&cluster),
        ));
        // The incremental path a release/commit actually pays: one node
        // flips, only the suffix region past it refreshes. A trailing
        // node is the common case (new jobs pack low, release high);
        // the fresh-build row above is the worst case (node 0 flips).
        let mut sums = OccupancySums::build(&cluster);
        let last = cluster.num_nodes() - 1;
        results.push(bench(
            &format!("OccupancySums apply_flips trailing node {label}"),
            smoke_iters(3),
            smoke_iters(20),
            || sums.apply_flips(&cluster, &[(last, true)]),
        ));

        let rss = peak_rss_kb();
        let rss_row = BenchResult {
            name: format!("peak_rss_kb after {label}"),
            iters: 1,
            mean_ns: rss,
            p50_ns: rss,
            p99_ns: rss,
        };
        rss_row.print();
        results.push(rss_row);
    }

    write_json_env(&results);
}
