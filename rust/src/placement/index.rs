//! Epoch-cached spatial indices over cluster occupancy — the scheduling
//! hot path's shared acceleration structure.
//!
//! Placement probes used to re-derive everything from the raw busy bitmap
//! on every call: `static_place::find_first_box` rebuilt its O(V) prefix
//! table per fold variant, `reconfig_place` re-sorted the candidate-cube
//! list per (variant, offset) probe and checked cube-box freeness with
//! O(box-volume) scans. One scheduling event fires dozens of such probes
//! (every fold variant × every shared offset), and under head-of-line
//! FIFO the same head job re-probes at every completion — all against an
//! occupancy that only changes on commit/release.
//!
//! [`PlacementIndex`] captures everything those probes need, built **at
//! most once per occupancy change**: it is stamped with the cluster's
//! [`epoch`](crate::topology::cluster::ClusterState::epoch) and cached in
//! [`PolicyCore`](super::api::PolicyCore), which rebuilds only when the
//! epoch moved. Contents per topology family:
//!
//! * static torus — the existing [`OccupancySums`] 3D prefix table
//!   (O(1) wrap-aware box-freeness), shared across every variant;
//! * reconfigurable — a [`ReconfigIndex`]: per-cube 3D summed-occupancy
//!   tables making `is_cube_box_free`-style queries O(1) instead of
//!   O(box volume), plus the free-count-ordered candidate-cube list that
//!   `reconfig_place` previously re-filtered and re-sorted per probe.
//!
//! The scattered baselines' scan orders (snake order for BestEffort,
//! Hilbert curve order for SLURM-style segment search) are pure geometry,
//! not occupancy — they live outside the per-epoch index, in the
//! process-wide [`scan_orders`] cache, memoized per policy via
//! [`PolicyCore::scan_orders`](super::api::PolicyCore::scan_orders).
//!
//! Everything here is a pure function of the busy bitmap, so every query
//! is byte-equivalent to a fresh rebuild — `tests/prop_index.rs` locks
//! that down under randomized commit/release churn.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::static_place::OccupancySums;
use crate::topology::cluster::{ClusterState, ClusterTopo};
use crate::topology::P3;

/// Per-cube 3D summed-occupancy tables plus the candidate-cube list, for
/// reconfigurable topologies.
pub struct ReconfigIndex {
    n: usize,
    num_cubes: usize,
    /// `num_cubes` tables of `(n+1)³` inclusive prefix sums, flattened
    /// cube-major: `sums[cube * (n+1)³ + ((x*(n+1))+y)*(n+1)+z]` is the
    /// busy count of the cube-local box `[0,x)×[0,y)×[0,z)`.
    sums: Vec<u32>,
    /// Cubes with at least one free XPU, ascending free count with ties
    /// in cube-id order — exactly the best-fit scan order
    /// `reconfig_place` used to rebuild per probe (stable sort).
    cubes_by_fill: Vec<usize>,
}

impl ReconfigIndex {
    /// Build from the current busy bitmap. Panics on static topologies.
    pub fn build(cluster: &ClusterState) -> ReconfigIndex {
        let grid = match cluster.topo() {
            ClusterTopo::Reconfigurable { grid } => grid,
            _ => panic!("ReconfigIndex requires a reconfigurable topology"),
        };
        let n = grid.n;
        let num_cubes = grid.num_cubes();
        let mut index = ReconfigIndex {
            n,
            num_cubes,
            sums: vec![0u32; num_cubes * (n + 1) * (n + 1) * (n + 1)],
            cubes_by_fill: Vec::new(),
        };
        for cube in 0..num_cubes {
            index.rebuild_cube(cluster, cube);
        }
        index.refresh_fill_order(cluster);
        index
    }

    /// Recompute one cube's `(n+1)³` summed table from the busy bitmap.
    /// A cube is tiny (a 4³ cube is 125 table entries), so touched cubes
    /// are rebuilt whole rather than by sub-region.
    fn rebuild_cube(&mut self, cluster: &ClusterState, cube: usize) {
        let n = self.n;
        let vol = n * n * n;
        let s = n + 1;
        let tsize = s * s * s;
        let idx = |x: usize, y: usize, z: usize| (x * s + y) * s + z;
        let t = &mut self.sums[cube * tsize..(cube + 1) * tsize];
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    // Cube-local linear order matches the global node
                    // numbering: node = cube·n³ + local.index_in(n³).
                    let node = cube * vol + (x * n + y) * n + z;
                    let busy = !cluster.is_free(node);
                    t[idx(x + 1, y + 1, z + 1)] = busy as u32
                        + t[idx(x, y + 1, z + 1)]
                        + t[idx(x + 1, y, z + 1)]
                        + t[idx(x + 1, y + 1, z)]
                        - t[idx(x, y, z + 1)]
                        - t[idx(x, y + 1, z)]
                        - t[idx(x + 1, y, z)]
                        + t[idx(x, y, z)];
                }
            }
        }
    }

    /// Recompute the candidate-cube list with exactly the fresh-build
    /// expression (filter free > 0, stable sort by free count, ties in
    /// cube-id order) so incremental advances stay byte-equivalent.
    fn refresh_fill_order(&mut self, cluster: &ClusterState) {
        self.cubes_by_fill = (0..self.num_cubes)
            .filter(|&c| cluster.cube_free_count(c) > 0)
            .collect();
        self.cubes_by_fill
            .sort_by_key(|&c| cluster.cube_free_count(c));
    }

    /// Delta-advance across a batch of busy-bit flips: only the cubes
    /// containing a flipped node get their summed tables rebuilt, plus
    /// one O(C log C) candidate-list refresh — the other `C − k` cube
    /// tables (the overwhelming bulk of the index at 64k nodes) are
    /// untouched. Bit-identical to a fresh [`build`](Self::build).
    pub fn apply_flips(&mut self, cluster: &ClusterState, flips: &[(usize, bool)]) {
        if flips.is_empty() {
            return;
        }
        let vol = self.n * self.n * self.n;
        let mut touched: Vec<usize> = flips.iter().map(|&(node, _)| node / vol).collect();
        touched.sort_unstable();
        touched.dedup();
        for cube in touched {
            self.rebuild_cube(cluster, cube);
        }
        self.refresh_fill_order(cluster);
    }

    /// Cube side.
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn prefix(&self, cube: usize, x: usize, y: usize, z: usize) -> u32 {
        let s = self.n + 1;
        self.sums[cube * s * s * s + (x * s + y) * s + z]
    }

    /// Busy count in the cube-local half-open box `[lo, hi)` (component-
    /// wise; callers guarantee `lo ≤ hi ≤ n`).
    #[inline]
    pub fn busy_in(&self, cube: usize, lo: P3, hi: P3) -> u32 {
        let (x0, y0, z0) = (lo.0[0], lo.0[1], lo.0[2]);
        let (x1, y1, z1) = (hi.0[0], hi.0[1], hi.0[2]);
        self.prefix(cube, x1, y1, z1)
            .wrapping_sub(self.prefix(cube, x0, y1, z1))
            .wrapping_sub(self.prefix(cube, x1, y0, z1))
            .wrapping_sub(self.prefix(cube, x1, y1, z0))
            .wrapping_add(self.prefix(cube, x0, y0, z1))
            .wrapping_add(self.prefix(cube, x0, y1, z0))
            .wrapping_add(self.prefix(cube, x1, y0, z0))
            .wrapping_sub(self.prefix(cube, x0, y0, z0))
    }

    /// O(1) twin of
    /// [`ClusterState::is_cube_box_free`](crate::topology::cluster::ClusterState::is_cube_box_free):
    /// is the local box `[off, off+ext)` entirely free inside `cube`?
    /// Out-of-bounds boxes are `false`, matching the O(volume) original.
    #[inline]
    pub fn is_box_free(&self, cube: usize, off: P3, ext: P3) -> bool {
        if (0..3).any(|a| off.0[a] + ext.0[a] > self.n) {
            return false;
        }
        self.busy_in(cube, off, off.add(ext)) == 0
    }

    /// Cubes with free capacity in best-fit order (ascending free count,
    /// ties by cube id) — the shared candidate list for piece assignment.
    pub fn candidate_cubes(&self) -> &[usize] {
        &self.cubes_by_fill
    }

    /// Number of cubes in the machine.
    pub fn num_cubes(&self) -> usize {
        self.num_cubes
    }
}

/// Occupancy-independent node scan orders of one topology, shared
/// process-wide (the machine geometry never changes mid-run): the snake
/// order BestEffort allocates along and the Hilbert curve order the
/// SLURM-style baseline runs its segment search on (`None` when the
/// physical extent is not a power-of-two cube).
pub struct ScanOrders {
    pub snake: Vec<usize>,
    pub hilbert: Option<Vec<usize>>,
}

/// The per-topology scan-order cache. Scan orders are pure geometry, so
/// entries are computed once per process and shared by every index build,
/// every epoch, every thread.
pub fn scan_orders(topo: ClusterTopo) -> Arc<ScanOrders> {
    static CACHE: OnceLock<Mutex<HashMap<ClusterTopo, Arc<ScanOrders>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = cache.lock().unwrap();
    map.entry(topo)
        .or_insert_with(|| {
            let ext = topo.phys_ext();
            let snake = super::best_effort::snake_order(ext)
                .into_iter()
                .map(|p| super::best_effort::phys_to_node_topo(topo, p))
                .collect();
            // The Hilbert baseline only runs on power-of-two cubes (the
            // 4096-XPU machine is 16³); other extents keep `None` and the
            // policy rejects, exactly as the uncached search did.
            let order = ext.0[0].trailing_zeros();
            let hilbert = (ext.0 == [1 << order, 1 << order, 1 << order]).then(|| {
                super::hilbert::hilbert_order(order)
                    .into_iter()
                    .map(|p| super::best_effort::phys_to_node_topo(topo, p))
                    .collect()
            });
            Arc::new(ScanOrders { snake, hilbert })
        })
        .clone()
}

/// The topology-family-specific part of a [`PlacementIndex`].
enum IndexKind {
    Static(OccupancySums),
    Reconfig(ReconfigIndex),
}

/// Everything the placement engines consult about occupancy, built from
/// one bitmap sweep and valid for exactly one cluster epoch. Obtained via
/// [`PolicyCore::placement_index`](super::api::PolicyCore::placement_index),
/// which caches it across probes until the epoch moves.
pub struct PlacementIndex {
    epoch: u64,
    kind: IndexKind,
}

impl PlacementIndex {
    /// Build for the cluster's current occupancy (O(V) bitmap sweep).
    pub fn build(cluster: &ClusterState) -> PlacementIndex {
        let kind = match cluster.topo() {
            ClusterTopo::Static { .. } => IndexKind::Static(OccupancySums::build(cluster)),
            ClusterTopo::Reconfigurable { .. } => {
                IndexKind::Reconfig(ReconfigIndex::build(cluster))
            }
        };
        PlacementIndex {
            epoch: cluster.epoch(),
            kind,
        }
    }

    /// The cluster epoch this index was built against.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Try to delta-advance a stale index to the cluster's current epoch
    /// by replaying the busy-bit flips from the cluster's bounded delta
    /// journal ([`ClusterState::changes_since`]) — per-commit/release
    /// cost proportional to the touched region, not O(V). Returns `false`
    /// (index untouched, still stamped with its old epoch) when the
    /// journal no longer covers this index's epoch; the caller then pays
    /// the full [`build`](Self::build). On success the index is
    /// bit-identical to a fresh build at the new epoch, so the PR-5 epoch
    /// contract is unchanged: a matching epoch still proves validity.
    pub fn advance(&mut self, cluster: &ClusterState) -> bool {
        if self.epoch == cluster.epoch() {
            return true;
        }
        let Some(flips) = cluster.changes_since(self.epoch) else {
            return false;
        };
        match &mut self.kind {
            IndexKind::Static(s) => s.apply_flips(cluster, &flips),
            IndexKind::Reconfig(r) => r.apply_flips(cluster, &flips),
        }
        self.epoch = cluster.epoch();
        true
    }

    /// The static-torus prefix table. Panics on reconfigurable indices —
    /// policies gate on topology family before touching the index.
    pub fn static_sums(&self) -> &OccupancySums {
        match &self.kind {
            IndexKind::Static(s) => s,
            IndexKind::Reconfig(_) => panic!("static_sums on a reconfigurable index"),
        }
    }

    /// The reconfigurable-cluster index. Panics on static indices.
    pub fn reconfig(&self) -> &ReconfigIndex {
        match &self.kind {
            IndexKind::Reconfig(r) => r,
            IndexKind::Static(_) => panic!("reconfig index on a static topology"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::cluster::Allocation;
    use crate::util::Pcg64;

    fn occupy(c: &mut ClusterState, job: u64, nodes: Vec<usize>) {
        c.commit(Allocation {
            job,
            nodes,
            cubes: vec![],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 1]),
        });
    }

    #[test]
    fn reconfig_index_matches_bruteforce_box_queries() {
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let mut rng = Pcg64::seeded(11);
        let mut nodes: Vec<usize> = (0..900).map(|_| rng.below(4096)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        occupy(&mut c, 1, nodes);
        let idx = ReconfigIndex::build(&c);
        for _ in 0..300 {
            let cube = rng.below(64);
            let off = P3([rng.below(5), rng.below(5), rng.below(5)]);
            let ext = P3([rng.range(1, 5), rng.range(1, 5), rng.range(1, 5)]);
            assert_eq!(
                idx.is_box_free(cube, off, ext),
                c.is_cube_box_free(cube, off, ext),
                "cube={cube} off={off} ext={ext}"
            );
        }
    }

    #[test]
    fn candidate_cubes_match_legacy_best_fit_order() {
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let mut rng = Pcg64::seeded(5);
        let mut nodes: Vec<usize> = (0..2600).map(|_| rng.below(4096)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        occupy(&mut c, 1, nodes);
        let idx = ReconfigIndex::build(&c);
        // The exact expression reconfig_place's inner loop used per probe.
        let mut legacy: Vec<usize> = (0..64).filter(|&cb| c.cube_free_count(cb) > 0).collect();
        legacy.sort_by_key(|&cb| c.cube_free_count(cb));
        assert_eq!(idx.candidate_cubes(), legacy.as_slice());
    }

    #[test]
    fn scan_orders_are_cached_and_match_direct_computation() {
        let topo = ClusterTopo::reconfigurable_4096(4);
        let a = scan_orders(topo);
        let b = scan_orders(topo);
        assert!(Arc::ptr_eq(&a, &b), "one computation per topology");
        let c = ClusterState::new(topo);
        let direct: Vec<usize> = super::super::best_effort::snake_order(topo.phys_ext())
            .into_iter()
            .map(|p| super::super::best_effort::phys_to_node(&c, p))
            .collect();
        assert_eq!(a.snake, direct);
        assert!(a.hilbert.is_some(), "16^3 machine supports the curve");
        assert_eq!(a.hilbert.as_ref().unwrap().len(), 4096);
    }

    #[test]
    fn placement_index_carries_the_build_epoch() {
        let mut c = ClusterState::new(ClusterTopo::static_4096());
        let i0 = PlacementIndex::build(&c);
        assert_eq!(i0.epoch(), c.epoch());
        let _ = i0.static_sums();
        occupy(&mut c, 1, vec![0]);
        assert_ne!(i0.epoch(), c.epoch(), "stale index is detectable");
        let i1 = PlacementIndex::build(&c);
        assert_eq!(i1.epoch(), c.epoch());
        assert!(!i1.static_sums().box_free(P3([0, 0, 0]), P3([1, 1, 1])));
    }

    #[test]
    fn advance_replays_the_delta_journal_or_declines() {
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let mut idx = PlacementIndex::build(&c);
        assert!(idx.advance(&c), "current epoch advances trivially");
        occupy(&mut c, 1, vec![0, 1, 70, 200]);
        occupy(&mut c, 2, vec![5, 6]);
        c.release(1);
        assert!(idx.advance(&c), "journaled churn must replay");
        assert_eq!(idx.epoch(), c.epoch());
        let fresh = ReconfigIndex::build(&c);
        assert_eq!(idx.reconfig().sums, fresh.sums);
        assert_eq!(idx.reconfig().cubes_by_fill, fresh.cubes_by_fill);
        // A foreign cluster's journal cannot cover this index's epoch.
        let other = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        assert!(!idx.advance(&other), "unknown history must force a rebuild");
        assert_ne!(idx.epoch(), other.epoch(), "a declined advance leaves the stamp");
    }

    #[test]
    #[should_panic(expected = "reconfigurable index")]
    fn family_accessors_guard() {
        let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let idx = PlacementIndex::build(&c);
        let _ = idx.static_sums();
    }
}
