//! Candidate placement plans and atomic commit.

use crate::shape::fold::Variant;
use crate::shape::verify;
use crate::topology::cluster::{Allocation, ClusterState};
use crate::topology::P3;

/// One OCS path to reserve at commit: the cubes chained at face position
/// (i, j) of `axis`, cyclic when `closed`.
#[derive(Clone, Debug)]
pub struct OcsChainPlan {
    pub axis: usize,
    pub i: usize,
    pub j: usize,
    pub cubes: Vec<usize>,
    pub closed: bool,
}

/// A fully worked-out candidate placement for one job.
#[derive(Clone, Debug)]
pub struct Plan {
    pub job: u64,
    pub variant: Variant,
    /// Global node ids, indexed by placed-box linear coordinate: node for
    /// placed coord `p` is `nodes[p.index_in(variant.placed)]`.
    pub nodes: Vec<usize>,
    /// Distinct cubes touched (empty on static topologies).
    pub cubes: Vec<usize>,
    /// OCS paths to reserve (reconfigurable topologies only).
    pub chains: Vec<OcsChainPlan>,
    /// Wrap-around availability per placed axis this plan provides.
    pub wrap: [bool; 3],
}

impl Plan {
    /// Number of OCS entries the plan consumes ("fewest OCS links" is the
    /// second key of the paper's ranking heuristic).
    pub fn ocs_entries(&self) -> usize {
        self.chains.iter().map(|c| c.cubes.len()).sum()
    }

    /// Commit this plan: reserve OCS paths, occupy nodes, and record the
    /// allocation with its ring-closure profile for the JCT model.
    ///
    /// In debug builds the variant's homomorphism is re-verified against
    /// the wrap vector actually provided.
    pub fn commit(&self, cluster: &mut ClusterState) -> Result<(), String> {
        debug_assert!(
            verify::verify(&self.variant, self.wrap).is_ok(),
            "plan commits an unverifiable variant: {:?}",
            self.variant
        );
        for k in 0..3 {
            if self.variant.requires_wrap[k] && !self.wrap[k] {
                return Err(format!(
                    "variant requires wrap on axis {k} but plan lacks it"
                ));
            }
        }
        if let Some(ocs) = cluster.ocs_mut() {
            for ch in &self.chains {
                if let Err(e) =
                    ocs.reserve_path(ch.axis, ch.i, ch.j, &ch.cubes, ch.closed, self.job)
                {
                    // Roll back everything reserved so far.
                    ocs.release_job(self.job);
                    return Err(format!("OCS reservation failed: {e}"));
                }
            }
        } else if !self.chains.is_empty() {
            return Err("OCS chains planned on a static topology".into());
        }

        let rings = verify::ring_closures(&self.variant, self.wrap);
        cluster.commit(Allocation {
            job: self.job,
            nodes: self.nodes.clone(),
            cubes: self.cubes.clone(),
            ocs_entries: self.ocs_entries(),
            rings,
            placed_ext: self.variant.placed,
        });
        Ok(())
    }

    /// The placed coordinates → node id mapping as (coord, node) pairs.
    pub fn placed_nodes(&self) -> impl Iterator<Item = (P3, usize)> + '_ {
        let ext = self.variant.placed;
        self.nodes
            .iter()
            .enumerate()
            .map(move |(i, &n)| (P3::from_index(i, ext), n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shape::fold::Variant;
    use crate::shape::JobShape;
    use crate::topology::{ClusterState, ClusterTopo};

    fn box_plan(job: u64, cube: usize, ext: P3, cluster: &ClusterState) -> Plan {
        // All nodes of `cube` covering `ext` starting at the origin.
        let grid = match cluster.topo() {
            ClusterTopo::Reconfigurable { grid } => grid,
            _ => unreachable!(),
        };
        let variant = Variant::identity(JobShape::new(ext.0[0], ext.0[1], ext.0[2]));
        let nodes = ext
            .iter_box()
            .map(|p| grid.node_id(cube, p))
            .collect();
        Plan {
            job,
            variant,
            nodes,
            cubes: vec![cube],
            chains: vec![],
            wrap: [false; 3],
        }
    }

    #[test]
    fn commit_occupies_nodes() {
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let p = box_plan(1, 2, P3([2, 2, 2]), &c);
        p.commit(&mut c).unwrap();
        assert_eq!(c.busy_count(), 8);
        assert_eq!(c.cube_free_count(2), 56);
        c.check_consistency().unwrap();
    }

    #[test]
    fn commit_with_chain_reserves_ocs() {
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let mut p = box_plan(3, 0, P3([4, 1, 1]), &c);
        p.wrap = [true, false, false];
        p.chains = vec![OcsChainPlan {
            axis: 0,
            i: 0,
            j: 0,
            cubes: vec![0],
            closed: true,
        }];
        p.commit(&mut c).unwrap();
        assert_eq!(c.ocs().unwrap().reserved_entries(), 1);
        c.release(3);
        assert_eq!(c.ocs().unwrap().reserved_entries(), 0);
    }

    #[test]
    fn conflicting_chain_rolls_back() {
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let mut p1 = box_plan(1, 0, P3([4, 1, 1]), &c);
        p1.chains = vec![OcsChainPlan { axis: 0, i: 0, j: 0, cubes: vec![0], closed: true }];
        p1.wrap = [true, false, false];
        p1.commit(&mut c).unwrap();

        // Same OCS entry again (different job, artificial overlap on the
        // chain but disjoint nodes) must fail and roll back cleanly.
        let grid = match c.topo() {
            ClusterTopo::Reconfigurable { grid } => grid,
            _ => unreachable!(),
        };
        let variant = Variant::identity(JobShape::new(4, 1, 1));
        let nodes = (0..4).map(|x| grid.node_id(0, P3([x, 1, 0]))).collect();
        let p2 = Plan {
            job: 2,
            variant,
            nodes,
            cubes: vec![0],
            chains: vec![
                OcsChainPlan { axis: 0, i: 1, j: 0, cubes: vec![0], closed: true },
                OcsChainPlan { axis: 0, i: 0, j: 0, cubes: vec![0], closed: true },
            ],
            wrap: [true, false, false],
        };
        assert!(p2.commit(&mut c).is_err());
        // Rollback: job 2 owns nothing, job 1 untouched.
        assert_eq!(c.ocs().unwrap().reserved_entries(), 1);
        assert_eq!(c.busy_count(), 4);
        c.check_consistency().unwrap();
    }

    #[test]
    fn required_wrap_enforced() {
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let mut p = box_plan(9, 1, P3([4, 4, 4]), &c);
        p.variant.requires_wrap = [false, false, true];
        p.wrap = [false; 3];
        assert!(p.commit(&mut c).is_err());
        assert_eq!(c.busy_count(), 0);
    }
}
