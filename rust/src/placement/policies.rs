//! The built-in placement policies behind the open
//! [`PlacementPolicy`](super::api::PlacementPolicy) trait: FirstFit and
//! Folding drive the static-torus engine; Reconfig and RFold drive the
//! reconfigurable engine; BestEffort and Hilbert are the §5/§2 scattered
//! baselines (their search lives in `best_effort.rs` / `hilbert.rs`).
//!
//! Each policy is one small type embedding a shared
//! [`PolicyCore`](super::api::PolicyCore); registration lives in
//! [`registry::builtins`](super::registry::builtins). The old closed
//! [`PolicyKind`] enum survives only as a deprecated shim over registry
//! names so existing configs, sweep rows, and golden snapshots keep their
//! exact bytes.

use super::api::{
    select_victims, Attempt, DecisionStats, PlacementPolicy, PlacementRequest, PolicyCore,
    RunningJob, SchedAction,
};
use super::best_effort;
use super::hilbert;
use super::plan::Plan;
use super::registry::{builtins, PolicyHandle};
use super::reconfig_place;
use super::score::rank_plans;
use super::static_place;
use crate::shape::fold::Variant;
use crate::shape::JobShape;
use crate::topology::cluster::{ClusterState, ClusterTopo};

/// Engine-bound policies only run on their own topology family; on the
/// other family every request is a structured rejection (the engines
/// themselves panic on a family mismatch). Classified as `Infeasible` by
/// the empty-cluster probe, so mismatched jobs drop instead of wedging
/// the FIFO head.
fn wrong_family(cluster: &ClusterState, wants_reconfigurable: bool) -> bool {
    let is_reconfigurable = matches!(cluster.topo(), ClusterTopo::Reconfigurable { .. });
    is_reconfigurable != wants_reconfigurable
}

/// First-Fit with rotations in a static torus (`firstfit`): scan rotations
/// in order, commit the first hit.
#[derive(Default)]
pub struct FirstFit {
    core: PolicyCore,
}

impl FirstFit {
    pub fn new() -> FirstFit {
        FirstFit::default()
    }
}

impl PlacementPolicy for FirstFit {
    fn name(&self) -> &'static str {
        "FirstFit"
    }

    fn core(&mut self) -> &mut PolicyCore {
        &mut self.core
    }

    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
        if wrong_family(cluster, false) {
            return Attempt::rejected(DecisionStats::default());
        }
        let vs = self.core.variants(cluster.topo(), shape, false);
        let mut stats = DecisionStats::from_variants(&vs);
        let index = self.core.placement_index(cluster);
        for v in &vs {
            if let Some(p) = static_plan_indexed(cluster, index.static_sums(), v, job) {
                stats.candidates = 1;
                return Attempt {
                    plan: Some(p),
                    stats,
                };
            }
        }
        Attempt::rejected(stats)
    }
}

/// Folding + first-fit in a static torus (`folding`): all homomorphic
/// variants materialize, the scorer ranks them.
#[derive(Default)]
pub struct Folding {
    core: PolicyCore,
}

impl Folding {
    pub fn new() -> Folding {
        Folding::default()
    }
}

impl PlacementPolicy for Folding {
    fn name(&self) -> &'static str {
        "Folding"
    }

    fn core(&mut self) -> &mut PolicyCore {
        &mut self.core
    }

    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
        if wrong_family(cluster, false) {
            return Attempt::rejected(DecisionStats::default());
        }
        let vs = self.core.variants(cluster.topo(), shape, true);
        let mut stats = DecisionStats::from_variants(&vs);
        let index = self.core.placement_index(cluster);
        let plans: Vec<Plan> = vs
            .iter()
            .filter_map(|v| static_plan_indexed(cluster, index.static_sums(), v, job))
            .collect();
        stats.candidates = plans.len();
        let plan = rank_plans(cluster, &plans, self.core.scorer.as_mut())
            .map(|best| plans.into_iter().nth(best).unwrap());
        Attempt { plan, stats }
    }
}

/// Shared Reconfig/RFold search: cube decomposition + OCS chain planning
/// per variant against the epoch-cached index (one build serves every
/// variant × offset probe of the request — and every request until the
/// occupancy changes), ranked by the paper's heuristic.
fn reconfig_attempt(
    core: &mut PolicyCore,
    cluster: &ClusterState,
    job: u64,
    shape: JobShape,
    folds: bool,
) -> Attempt {
    if wrong_family(cluster, true) {
        return Attempt::rejected(DecisionStats::default());
    }
    let vs = core.variants(cluster.topo(), shape, folds);
    let mut stats = DecisionStats::from_variants(&vs);
    let offset_search = core.offset_search;
    let index = core.placement_index(cluster);
    let plans: Vec<Plan> = vs
        .iter()
        .filter_map(|v| {
            reconfig_place::place_indexed(cluster, index.reconfig(), v, job, offset_search)
        })
        .collect();
    stats.candidates = plans.len();
    let plan = rank_plans(cluster, &plans, core.scorer.as_mut())
        .map(|best| plans.into_iter().nth(best).unwrap());
    Attempt { plan, stats }
}

/// Reconfiguration with rotations (`reconfig`) — the paper's
/// origin-anchored prototype baseline.
#[derive(Default)]
pub struct Reconfig {
    core: PolicyCore,
}

impl Reconfig {
    pub fn new() -> Reconfig {
        Reconfig::default()
    }
}

impl PlacementPolicy for Reconfig {
    fn name(&self) -> &'static str {
        "Reconfig"
    }

    fn core(&mut self) -> &mut PolicyCore {
        &mut self.core
    }

    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
        reconfig_attempt(&mut self.core, cluster, job, shape, false)
    }
}

/// Folding + reconfiguration (`rfold`) — the paper's contribution. Also
/// searches shared in-cube offsets (the fragmentation-aware A4 extension;
/// flip `core().offset_search` to ablate).
pub struct RFold {
    core: PolicyCore,
}

impl RFold {
    pub fn new() -> RFold {
        let mut core = PolicyCore::new();
        core.offset_search = true;
        RFold { core }
    }
}

impl Default for RFold {
    fn default() -> Self {
        RFold::new()
    }
}

impl PlacementPolicy for RFold {
    fn name(&self) -> &'static str {
        "RFold"
    }

    fn core(&mut self) -> &mut PolicyCore {
        &mut self.core
    }

    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
        reconfig_attempt(&mut self.core, cluster, job, shape, true)
    }
}

/// RFold's search with an always-on preemption discipline
/// (`preempt-rfold`): identical placement plans, but a capacity-blocked
/// head names eviction victims even without a `--with preempt=` knob —
/// priority classes when the engine supplies a mode, SRTF otherwise.
/// The seventh built-in, and the in-tree demonstration that a policy can
/// own the whole ADMIT/QUEUE/PREEMPT/RECONFIGURE surface by overriding
/// [`PlacementPolicy::decide`].
pub struct PreemptRFold {
    core: PolicyCore,
}

impl PreemptRFold {
    pub fn new() -> PreemptRFold {
        let mut core = PolicyCore::new();
        core.offset_search = true;
        PreemptRFold { core }
    }
}

impl Default for PreemptRFold {
    fn default() -> Self {
        PreemptRFold::new()
    }
}

impl PlacementPolicy for PreemptRFold {
    fn name(&self) -> &'static str {
        "PreemptRFold"
    }

    fn core(&mut self) -> &mut PolicyCore {
        &mut self.core
    }

    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
        reconfig_attempt(&mut self.core, cluster, job, shape, true)
    }

    fn preemptive(&self) -> bool {
        true
    }

    fn decide(
        &mut self,
        req: &PlacementRequest<'_>,
        incoming: &RunningJob,
        running: &[RunningJob],
        preempt: Option<crate::trace::scenarios::PreemptMode>,
    ) -> SchedAction {
        use super::api::PlacementDecision;
        use crate::trace::scenarios::PreemptMode;
        match self.plan(req) {
            PlacementDecision::Placed { plan, stats } => {
                if plan.ocs_entries() > 0 {
                    SchedAction::Reconfigure { plan, stats }
                } else {
                    SchedAction::Admit { plan, stats }
                }
            }
            PlacementDecision::Infeasible { stats } => SchedAction::Reject { stats },
            PlacementDecision::NoCapacity { stats } => {
                // The knob (when present) picks the discipline; the
                // policy's own default is SRTF.
                let mode = preempt.unwrap_or(PreemptMode::Srtf);
                let victims = select_victims(incoming, running, mode);
                if victims.is_empty() {
                    SchedAction::Queue { stats }
                } else {
                    SchedAction::Preempt { victims, stats }
                }
            }
        }
    }
}

/// Scattered best-effort placement (§5 discussion, `besteffort`): first
/// free XPUs in snake order, rings routed over shared links.
#[derive(Default)]
pub struct BestEffort {
    core: PolicyCore,
}

impl BestEffort {
    pub fn new() -> BestEffort {
        BestEffort::default()
    }
}

impl PlacementPolicy for BestEffort {
    fn name(&self) -> &'static str {
        "BestEffort"
    }

    fn core(&mut self) -> &mut PolicyCore {
        &mut self.core
    }

    fn scattered(&self) -> bool {
        true
    }

    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
        // Scattered search only needs the occupancy-independent scan
        // order (freeness is probed per node on the live bitmap), so it
        // uses the policy-memoized scan orders instead of paying the
        // per-epoch occupancy-index build it would never query.
        let orders = self.core.scan_orders(cluster.topo());
        Attempt::single(best_effort::place_scattered_indexed(
            cluster,
            &orders.snake,
            job,
            shape,
        ))
    }
}

/// SLURM-style Hilbert-curve segment placement (§2 background, `slurm`):
/// compact but not torus-shaped — rings contend.
#[derive(Default)]
pub struct Hilbert {
    core: PolicyCore,
}

impl Hilbert {
    pub fn new() -> Hilbert {
        Hilbert::default()
    }
}

impl PlacementPolicy for Hilbert {
    fn name(&self) -> &'static str {
        "Hilbert"
    }

    fn core(&mut self) -> &mut PolicyCore {
        &mut self.core
    }

    fn scattered(&self) -> bool {
        true
    }

    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt {
        // Like BestEffort: the curve is pure geometry, freeness is probed
        // per node — skip the occupancy-index build entirely.
        let orders = self.core.scan_orders(cluster.topo());
        Attempt::single(hilbert::place_hilbert_indexed(
            cluster,
            orders.hilbert.as_deref(),
            job,
            shape,
        ))
    }
}

/// Place one variant in a static torus (first-fit anchor) against the
/// shared prefix table, if possible. Shared by [`FirstFit`] and
/// [`Folding`]: one epoch's table answers every variant where the old
/// path rebuilt it O(V) per variant.
pub(crate) fn static_plan_indexed(
    cluster: &ClusterState,
    sums: &static_place::OccupancySums,
    v: &Variant,
    job: u64,
) -> Option<Plan> {
    let wrap = static_place::box_wrap(cluster, v.placed);
    for k in 0..3 {
        if v.requires_wrap[k] && !wrap[k] {
            return None;
        }
    }
    let anchor = sums.find_first_box(v.placed)?;
    Some(Plan {
        job,
        variant: v.clone(),
        nodes: static_place::box_nodes(cluster, anchor, v.placed),
        cubes: vec![],
        chains: vec![],
        wrap,
    })
}

/// Deprecated policy selector, kept as a thin shim over registry names so
/// pre-registry call sites (and their golden output bytes) are unchanged.
/// New code should resolve names through
/// [`PolicyRegistry`](super::registry::PolicyRegistry) and carry
/// [`PolicyHandle`]s instead.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum PolicyKind {
    /// First-Fit with rotations in a static torus (`firstfit`).
    FirstFit,
    /// Folding + first-fit in a static torus (`folding`).
    Folding,
    /// Reconfiguration with rotations (`reconfig`).
    Reconfig,
    /// Folding + reconfiguration — the paper's contribution (`rfold`).
    RFold,
    /// Scattered best-effort placement (§5 discussion, `besteffort`).
    BestEffort,
    /// SLURM-style Hilbert-curve segment placement (§2 background,
    /// `slurm`): compact but not torus-shaped — rings contend.
    Hilbert,
}

impl PolicyKind {
    /// Every built-in, in the registry's reporting order.
    pub const ALL: [PolicyKind; 6] = [
        PolicyKind::FirstFit,
        PolicyKind::Folding,
        PolicyKind::Reconfig,
        PolicyKind::RFold,
        PolicyKind::BestEffort,
        PolicyKind::Hilbert,
    ];

    /// Parse a built-in policy name. Derived from the registry handles'
    /// keys and aliases so the shim can never drift from the registry.
    /// New code: use
    /// [`PolicyRegistry::resolve`](super::registry::PolicyRegistry::resolve),
    /// which also sees externally registered policies.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        let want = s.trim().to_ascii_lowercase();
        PolicyKind::ALL.into_iter().find(|kind| {
            let h = kind.handle();
            h.key() == want || h.aliases().iter().any(|a| a.eq_ignore_ascii_case(&want))
        })
    }

    /// The registry handle of this built-in.
    pub fn handle(self) -> PolicyHandle {
        match self {
            PolicyKind::FirstFit => builtins::FIRST_FIT,
            PolicyKind::Folding => builtins::FOLDING,
            PolicyKind::Reconfig => builtins::RECONFIG,
            PolicyKind::RFold => builtins::RFOLD,
            PolicyKind::BestEffort => builtins::BEST_EFFORT,
            PolicyKind::Hilbert => builtins::HILBERT,
        }
    }

    /// Build a fresh boxed policy (shim over [`PolicyHandle::instantiate`]).
    pub fn build(self) -> Box<dyn PlacementPolicy> {
        self.handle().instantiate()
    }

    pub fn name(&self) -> &'static str {
        self.handle().name()
    }

    /// The topology family the policy is designed for (paper Table 1 pairs
    /// FirstFit/Folding with the static torus).
    pub fn wants_reconfigurable(&self) -> bool {
        self.handle().wants_reconfigurable()
    }

    /// Does the policy fold shapes (vs rotations only)?
    pub fn folds(&self) -> bool {
        self.handle().folds()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{ClusterState, ClusterTopo};

    fn static_c() -> ClusterState {
        ClusterState::new(ClusterTopo::static_4096())
    }

    fn reconfig_c(n: usize) -> ClusterState {
        ClusterState::new(ClusterTopo::reconfigurable_4096(n))
    }

    #[test]
    fn parse_names() {
        assert_eq!(PolicyKind::parse("rfold"), Some(PolicyKind::RFold));
        assert_eq!(PolicyKind::parse("First-Fit"), Some(PolicyKind::FirstFit));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn kind_shim_matches_registry_metadata() {
        for kind in PolicyKind::ALL {
            let h = kind.handle();
            assert_eq!(kind.name(), h.name());
            assert_eq!(kind.wants_reconfigurable(), h.wants_reconfigurable());
            assert_eq!(kind.folds(), h.folds());
            // Keys AND every alias parse back to the same kind — the shim
            // is derived from the registry metadata, so it cannot drift.
            assert_eq!(PolicyKind::parse(h.key()), Some(kind));
            for alias in h.aliases() {
                assert_eq!(PolicyKind::parse(alias), Some(kind), "alias {alias}");
            }
            assert_eq!(kind.build().name(), h.name());
        }
    }

    #[test]
    fn firstfit_rejects_oversized_dim() {
        // §3.2's example: 4×4×32 cannot fit a 16³ static torus in any
        // rotation.
        let c = static_c();
        let mut p = FirstFit::new();
        assert!(p.place_now(&c, 1, JobShape::new(4, 4, 32)).is_none());
        assert!(!p.feasible_ever(c.topo(), JobShape::new(4, 4, 32)));
    }

    #[test]
    fn folding_places_18x1x1_in_static() {
        // 18 > 16, FirstFit fails even rotated; Folding reshapes to 2×9.
        let c = static_c();
        let mut ff = FirstFit::new();
        assert!(ff.place_now(&c, 1, JobShape::new(18, 1, 1)).is_none());
        let mut fo = Folding::new();
        let plan = fo.place_now(&c, 1, JobShape::new(18, 1, 1)).expect("folds");
        assert_eq!(plan.nodes.len(), 18);
    }

    #[test]
    fn reconfig_places_4x4x32() {
        let c = reconfig_c(4);
        let mut p = Reconfig::new();
        let plan = p.place_now(&c, 1, JobShape::new(4, 4, 32)).expect("8 cubes");
        assert_eq!(plan.cubes.len(), 8);
    }

    #[test]
    fn rfold_beats_reconfig_on_4x8x2() {
        let c = reconfig_c(4);
        let mut rf = RFold::new();
        let plan = rf.place_now(&c, 1, JobShape::new(4, 8, 2)).unwrap();
        assert_eq!(plan.cubes.len(), 1, "RFold folds into one cube");
        let mut rc = Reconfig::new();
        let plan = rc.place_now(&c, 1, JobShape::new(4, 8, 2)).unwrap();
        assert_eq!(plan.cubes.len(), 2, "Reconfig needs two cubes");
    }

    #[test]
    fn feasibility_cached_per_topo_and_shape() {
        let c = static_c();
        let mut p = FirstFit::new();
        let s = JobShape::new(8, 8, 8);
        assert!(p.feasible_ever(c.topo(), s));
        assert!(p.core().feasibility.contains_key(&(c.topo(), s)));
    }

    #[test]
    fn fold_dims_ablation_disables_1d_folds() {
        let c = static_c();
        let mut p = Folding::new();
        p.core().fold_dims_enabled = [false, true, true];
        // 18×1×1 is a 1D job; with 1D folding disabled it cannot fit.
        assert!(p.place_now(&c, 1, JobShape::new(18, 1, 1)).is_none());
    }

    #[test]
    fn firstfit_commits_first_rotation() {
        let c = static_c();
        let mut p = FirstFit::new();
        let plan = p.place_now(&c, 1, JobShape::new(2, 4, 8)).unwrap();
        plan.commit(&mut { c }).unwrap();
    }

    #[test]
    fn scattered_flag_marks_routed_policies() {
        assert!(BestEffort::new().scattered());
        assert!(Hilbert::new().scattered());
        assert!(!FirstFit::new().scattered());
        assert!(!RFold::new().scattered());
        assert!(!PreemptRFold::new().scattered());
    }

    #[test]
    fn preempt_rfold_places_like_rfold_and_preempts_without_a_knob() {
        // Identical plans to RFold (same search, same offset knob)…
        let c = reconfig_c(4);
        let mut pr = PreemptRFold::new();
        let mut rf = RFold::new();
        let a = pr.place_now(&c, 1, JobShape::new(4, 8, 2)).unwrap();
        let b = rf.place_now(&c, 1, JobShape::new(4, 8, 2)).unwrap();
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.cubes, b.cubes);
        assert!(pr.preemptive() && !rf.preemptive());

        // …but a capacity-blocked head falls back to SRTF victims with
        // no engine-supplied discipline at all.
        let mut busy = reconfig_c(4);
        pr.place_now(&busy, 2, JobShape::new(16, 16, 16))
            .unwrap()
            .commit(&mut busy)
            .unwrap();
        let hog = RunningJob {
            job: 2,
            priority: 0,
            size: 4096,
            remaining: 900.0,
            arrival: 0.0,
        };
        let head = RunningJob {
            job: 3,
            priority: 0,
            size: 8,
            remaining: 10.0,
            arrival: 5.0,
        };
        let action = pr.decide(
            &PlacementRequest::new(3, JobShape::new(2, 2, 2), &busy),
            &head,
            &[hog],
            None,
        );
        let SchedAction::Preempt { victims, .. } = action else {
            panic!("expected Preempt, got {}", action.label());
        };
        assert_eq!(victims, vec![2]);
    }

    #[test]
    fn decision_stats_track_search_effort() {
        let c = reconfig_c(4);
        let mut rf = RFold::new();
        let a = rf.attempt(&c, 1, JobShape::new(4, 8, 2));
        assert!(a.plan.is_some());
        assert!(a.stats.variants >= a.stats.candidates);
        assert!(a.stats.folds_tried > 0, "4x8x2 has a HalveDouble fold");
        assert!(a.stats.candidates >= 2, "identity and fold both place");
    }
}
