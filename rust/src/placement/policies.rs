//! The four placement policies of the paper's evaluation (§4) behind one
//! trait: FirstFit and Folding drive the static-torus engine; Reconfig and
//! RFold drive the reconfigurable engine. BestEffort (§5) lives in
//! `best_effort.rs`.

use std::collections::HashMap;

use super::best_effort;
use super::hilbert;
use super::plan::Plan;
use super::reconfig_place;
use super::score::{rank_plans, NativeScorer, PlanScorer};
use super::static_place;
use crate::shape::fold::{enumerate_variants, rotations_only, Variant};
use crate::shape::JobShape;
use crate::topology::cluster::{ClusterState, ClusterTopo};

/// Policy selector (CLI names in parentheses).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum PolicyKind {
    /// First-Fit with rotations in a static torus (`firstfit`).
    FirstFit,
    /// Folding + first-fit in a static torus (`folding`).
    Folding,
    /// Reconfiguration with rotations (`reconfig`).
    Reconfig,
    /// Folding + reconfiguration — the paper's contribution (`rfold`).
    RFold,
    /// Scattered best-effort placement (§5 discussion, `besteffort`).
    BestEffort,
    /// SLURM-style Hilbert-curve segment placement (§2 background,
    /// `slurm`): compact but not torus-shaped — rings contend.
    Hilbert,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "firstfit" | "first-fit" | "ff" => Some(PolicyKind::FirstFit),
            "folding" | "fold" => Some(PolicyKind::Folding),
            "reconfig" | "reconfiguration" => Some(PolicyKind::Reconfig),
            "rfold" => Some(PolicyKind::RFold),
            "besteffort" | "best-effort" | "be" => Some(PolicyKind::BestEffort),
            "hilbert" | "slurm" | "sfc" => Some(PolicyKind::Hilbert),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::FirstFit => "FirstFit",
            PolicyKind::Folding => "Folding",
            PolicyKind::Reconfig => "Reconfig",
            PolicyKind::RFold => "RFold",
            PolicyKind::BestEffort => "BestEffort",
            PolicyKind::Hilbert => "Hilbert",
        }
    }

    /// The topology family the policy is designed for (paper Table 1 pairs
    /// FirstFit/Folding with the static torus).
    pub fn wants_reconfigurable(&self) -> bool {
        matches!(self, PolicyKind::Reconfig | PolicyKind::RFold)
    }

    /// Does the policy fold shapes (vs rotations only)?
    pub fn folds(&self) -> bool {
        matches!(self, PolicyKind::Folding | PolicyKind::RFold)
    }
}

/// A placement policy: produce a committed-ready plan for a job, or decide
/// a job can never be placed on this topology.
pub struct Policy {
    kind: PolicyKind,
    scorer: Box<dyn PlanScorer>,
    /// Cache of "can this shape ever be placed on an empty cluster?".
    feasibility: HashMap<JobShape, bool>,
    /// Optional restriction of folding dimensionality (ablation A2):
    /// folds are only applied to jobs whose dimensionality is enabled.
    pub fold_dims_enabled: [bool; 3],
    /// Ablation A4: search shared non-zero piece offsets inside cubes
    /// (an extension over the paper's origin-anchored prototype).
    pub offset_search: bool,
}

impl Policy {
    pub fn new(kind: PolicyKind) -> Policy {
        Policy {
            kind,
            scorer: Box::new(NativeScorer),
            feasibility: HashMap::new(),
            fold_dims_enabled: [true; 3],
            // RFold is the fragmentation-aware contribution: it searches
            // shared in-cube offsets. The Reconfig baseline mirrors the
            // paper's origin-anchored prototype (ablation A4 flips this).
            offset_search: kind == PolicyKind::RFold,
        }
    }

    /// Swap in a different scorer (e.g. the PJRT-backed one).
    pub fn with_scorer(mut self, scorer: Box<dyn PlanScorer>) -> Policy {
        self.scorer = scorer;
        self
    }

    pub fn kind(&self) -> PolicyKind {
        self.kind
    }

    /// Largest dimension a placed shape may have on this topology.
    fn max_dim(topo: ClusterTopo) -> usize {
        match topo {
            ClusterTopo::Static { ext } => ext.0.iter().copied().max().unwrap(),
            ClusterTopo::Reconfigurable { grid } => (grid.n * grid.num_cubes()).min(4096),
        }
    }

    /// Shape variants this policy considers for a job.
    fn variants(&self, topo: ClusterTopo, shape: JobShape) -> Vec<Variant> {
        let max_dim = Self::max_dim(topo);
        if self.kind.folds() && self.fold_dims_enabled[shape.dimensionality().clamp(1, 3) - 1] {
            enumerate_variants(shape, max_dim)
        } else {
            rotations_only(shape, max_dim)
        }
    }

    /// Try to place `shape` for `job` on the cluster *now*. The returned
    /// plan has not been committed.
    pub fn plan(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Option<Plan> {
        match self.kind {
            PolicyKind::FirstFit => self.plan_first_fit(cluster, job, shape),
            PolicyKind::Folding => self.plan_static_ranked(cluster, job, shape),
            PolicyKind::Reconfig | PolicyKind::RFold => {
                self.plan_reconfig_ranked(cluster, job, shape)
            }
            PolicyKind::BestEffort => best_effort::place_scattered(cluster, job, shape),
            PolicyKind::Hilbert => hilbert::place_hilbert(cluster, job, shape),
        }
    }

    /// Can the job be placed on an *empty* cluster of this topology?
    /// (FIFO admission drops shape-incompatible jobs, §4.)
    pub fn feasible_ever(&mut self, topo: ClusterTopo, shape: JobShape) -> bool {
        if let Some(&f) = self.feasibility.get(&shape) {
            return f;
        }
        let empty = ClusterState::new(topo);
        let f = self.plan(&empty, u64::MAX, shape).is_some();
        self.feasibility.insert(shape, f);
        f
    }

    fn plan_first_fit(
        &mut self,
        cluster: &ClusterState,
        job: u64,
        shape: JobShape,
    ) -> Option<Plan> {
        // True First-Fit: scan rotations in order, commit the first hit.
        for v in self.variants(cluster.topo(), shape) {
            if let Some(p) = static_plan_for_variant(cluster, &v, job) {
                return Some(p);
            }
        }
        None
    }

    fn plan_static_ranked(
        &mut self,
        cluster: &ClusterState,
        job: u64,
        shape: JobShape,
    ) -> Option<Plan> {
        let plans: Vec<Plan> = self
            .variants(cluster.topo(), shape)
            .iter()
            .filter_map(|v| static_plan_for_variant(cluster, v, job))
            .collect();
        let best = rank_plans(cluster, &plans, self.scorer.as_mut())?;
        Some(plans.into_iter().nth(best).unwrap())
    }

    fn plan_reconfig_ranked(
        &mut self,
        cluster: &ClusterState,
        job: u64,
        shape: JobShape,
    ) -> Option<Plan> {
        let plans: Vec<Plan> = self
            .variants(cluster.topo(), shape)
            .iter()
            .filter_map(|v| {
                if self.offset_search {
                    reconfig_place::place_with_offsets(cluster, v, job)
                } else {
                    reconfig_place::place(cluster, v, job)
                }
            })
            .collect();
        let best = rank_plans(cluster, &plans, self.scorer.as_mut())?;
        Some(plans.into_iter().nth(best).unwrap())
    }
}

/// Place one variant in a static torus (first-fit anchor), if possible.
fn static_plan_for_variant(cluster: &ClusterState, v: &Variant, job: u64) -> Option<Plan> {
    let wrap = static_place::box_wrap(cluster, v.placed);
    for k in 0..3 {
        if v.requires_wrap[k] && !wrap[k] {
            return None;
        }
    }
    let anchor = static_place::find_first_box(cluster, v.placed)?;
    Some(Plan {
        job,
        variant: v.clone(),
        nodes: static_place::box_nodes(cluster, anchor, v.placed),
        cubes: vec![],
        chains: vec![],
        wrap,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{ClusterState, ClusterTopo};

    fn static_c() -> ClusterState {
        ClusterState::new(ClusterTopo::static_4096())
    }

    fn reconfig_c(n: usize) -> ClusterState {
        ClusterState::new(ClusterTopo::reconfigurable_4096(n))
    }

    #[test]
    fn parse_names() {
        assert_eq!(PolicyKind::parse("rfold"), Some(PolicyKind::RFold));
        assert_eq!(PolicyKind::parse("First-Fit"), Some(PolicyKind::FirstFit));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn firstfit_rejects_oversized_dim() {
        // §3.2's example: 4×4×32 cannot fit a 16³ static torus in any
        // rotation.
        let c = static_c();
        let mut p = Policy::new(PolicyKind::FirstFit);
        assert!(p.plan(&c, 1, JobShape::new(4, 4, 32)).is_none());
        assert!(!p.feasible_ever(c.topo(), JobShape::new(4, 4, 32)));
    }

    #[test]
    fn folding_places_18x1x1_in_static() {
        // 18 > 16, FirstFit fails even rotated; Folding reshapes to 2×9.
        let c = static_c();
        let mut ff = Policy::new(PolicyKind::FirstFit);
        assert!(ff.plan(&c, 1, JobShape::new(18, 1, 1)).is_none());
        let mut fo = Policy::new(PolicyKind::Folding);
        let plan = fo.plan(&c, 1, JobShape::new(18, 1, 1)).expect("folds");
        assert_eq!(plan.nodes.len(), 18);
    }

    #[test]
    fn reconfig_places_4x4x32() {
        let c = reconfig_c(4);
        let mut p = Policy::new(PolicyKind::Reconfig);
        let plan = p.plan(&c, 1, JobShape::new(4, 4, 32)).expect("8 cubes");
        assert_eq!(plan.cubes.len(), 8);
    }

    #[test]
    fn rfold_beats_reconfig_on_4x8x2() {
        let c = reconfig_c(4);
        let mut rf = Policy::new(PolicyKind::RFold);
        let plan = rf.plan(&c, 1, JobShape::new(4, 8, 2)).unwrap();
        assert_eq!(plan.cubes.len(), 1, "RFold folds into one cube");
        let mut rc = Policy::new(PolicyKind::Reconfig);
        let plan = rc.plan(&c, 1, JobShape::new(4, 8, 2)).unwrap();
        assert_eq!(plan.cubes.len(), 2, "Reconfig needs two cubes");
    }

    #[test]
    fn feasibility_cached() {
        let c = static_c();
        let mut p = Policy::new(PolicyKind::FirstFit);
        let s = JobShape::new(8, 8, 8);
        assert!(p.feasible_ever(c.topo(), s));
        assert!(p.feasibility.contains_key(&s));
    }

    #[test]
    fn fold_dims_ablation_disables_1d_folds() {
        let c = static_c();
        let mut p = Policy::new(PolicyKind::Folding);
        p.fold_dims_enabled = [false, true, true];
        // 18×1×1 is a 1D job; with 1D folding disabled it cannot fit.
        assert!(p.plan(&c, 1, JobShape::new(18, 1, 1)).is_none());
    }

    #[test]
    fn firstfit_commits_first_rotation() {
        let c = static_c();
        let mut p = Policy::new(PolicyKind::FirstFit);
        let plan = p.plan(&c, 1, JobShape::new(2, 4, 8)).unwrap();
        plan.commit(&mut { c }).unwrap();
    }
}
