//! The string-keyed policy registry: the single place where policy names
//! become policy instances.
//!
//! Every driver (CLI, sweep grids, the live coordinator, benches) resolves
//! a name to a [`PolicyHandle`] exactly once at config-build time and
//! threads the handle — a cheap `Copy` token — through its configs. The
//! handle instantiates a fresh [`PlacementPolicy`] per simulation, which
//! is also what the ROADMAP's multi-backend fan-out needs: remote workers
//! reconstruct policies from nothing but their registry key.
//!
//! Adding a policy takes one type implementing
//! [`PlacementPolicy`](crate::placement::PlacementPolicy) plus one
//! [`PolicyRegistry::register`] call — `tests/policy_registry.rs`
//! demonstrates an extra policy registered entirely from outside the
//! crate.

use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::{OnceLock, RwLock};

use super::api::PlacementPolicy;
use super::policies;

/// Constructor stored in the registry: builds a fresh boxed policy.
pub type PolicyCtor = fn() -> Box<dyn PlacementPolicy>;

/// A resolved registry entry: copyable, hashable by its canonical key, and
/// able to instantiate its policy. This is what configs carry instead of
/// the old closed `PolicyKind` enum.
#[derive(Clone, Copy)]
pub struct PolicyHandle {
    key: &'static str,
    display: &'static str,
    aliases: &'static [&'static str],
    wants_reconfigurable: bool,
    folds: bool,
    ctor: PolicyCtor,
}

impl PolicyHandle {
    /// Build a handle for registration. `key` is the canonical lowercase
    /// CLI name; `display` is the label used in report rows.
    pub const fn new(
        key: &'static str,
        display: &'static str,
        aliases: &'static [&'static str],
        wants_reconfigurable: bool,
        folds: bool,
        ctor: PolicyCtor,
    ) -> PolicyHandle {
        PolicyHandle {
            key,
            display,
            aliases,
            wants_reconfigurable,
            folds,
            ctor,
        }
    }

    /// Canonical lowercase registry key (the CLI name, e.g. `"rfold"`).
    pub fn key(&self) -> &'static str {
        self.key
    }

    /// Display name used in report rows (e.g. `"RFold"`).
    pub fn name(&self) -> &'static str {
        self.display
    }

    /// Accepted alternative CLI spellings.
    pub fn aliases(&self) -> &'static [&'static str] {
        self.aliases
    }

    /// The topology family the policy is designed for (paper Table 1
    /// pairs FirstFit/Folding with the static torus).
    pub fn wants_reconfigurable(&self) -> bool {
        self.wants_reconfigurable
    }

    /// Does the policy fold shapes (vs rotations only)?
    pub fn folds(&self) -> bool {
        self.folds
    }

    /// Build a fresh policy instance.
    pub fn instantiate(&self) -> Box<dyn PlacementPolicy> {
        (self.ctor)()
    }
}

// Identity is the canonical key alone: two handles with the same key are
// the same policy (the registry enforces key uniqueness), and comparing
// constructor fn pointers would be both meaningless and a clippy footgun.
impl PartialEq for PolicyHandle {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl Eq for PolicyHandle {}

impl Hash for PolicyHandle {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.key.hash(state);
    }
}

impl fmt::Debug for PolicyHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PolicyHandle({})", self.key)
    }
}

/// The built-in handles, in the paper's reporting order. `const`s so the
/// `PolicyKind` shim and the experiment cell tables can reference them
/// without a registry lookup.
pub mod builtins {
    use super::super::policies::{
        BestEffort, FirstFit, Folding, Hilbert, PreemptRFold, RFold, Reconfig,
    };
    use super::{PlacementPolicy, PolicyHandle};

    fn make_first_fit() -> Box<dyn PlacementPolicy> {
        Box::new(FirstFit::new())
    }
    fn make_folding() -> Box<dyn PlacementPolicy> {
        Box::new(Folding::new())
    }
    fn make_reconfig() -> Box<dyn PlacementPolicy> {
        Box::new(Reconfig::new())
    }
    fn make_rfold() -> Box<dyn PlacementPolicy> {
        Box::new(RFold::new())
    }
    fn make_best_effort() -> Box<dyn PlacementPolicy> {
        Box::new(BestEffort::new())
    }
    fn make_hilbert() -> Box<dyn PlacementPolicy> {
        Box::new(Hilbert::new())
    }
    fn make_preempt_rfold() -> Box<dyn PlacementPolicy> {
        Box::new(PreemptRFold::new())
    }

    /// First-Fit with rotations in a static torus.
    pub const FIRST_FIT: PolicyHandle = PolicyHandle::new(
        "firstfit",
        "FirstFit",
        &["first-fit", "ff"],
        false,
        false,
        make_first_fit,
    );
    /// Folding + first-fit in a static torus.
    pub const FOLDING: PolicyHandle =
        PolicyHandle::new("folding", "Folding", &["fold"], false, true, make_folding);
    /// Reconfiguration with rotations.
    pub const RECONFIG: PolicyHandle = PolicyHandle::new(
        "reconfig",
        "Reconfig",
        &["reconfiguration"],
        true,
        false,
        make_reconfig,
    );
    /// Folding + reconfiguration — the paper's contribution.
    pub const RFOLD: PolicyHandle =
        PolicyHandle::new("rfold", "RFold", &[], true, true, make_rfold);
    /// Scattered best-effort placement (§5 discussion).
    pub const BEST_EFFORT: PolicyHandle = PolicyHandle::new(
        "besteffort",
        "BestEffort",
        &["best-effort", "be"],
        false,
        false,
        make_best_effort,
    );
    /// SLURM-style Hilbert-curve segment placement (§2 background).
    pub const HILBERT: PolicyHandle = PolicyHandle::new(
        "hilbert",
        "Hilbert",
        &["slurm", "sfc"],
        false,
        false,
        make_hilbert,
    );

    /// RFold's search with an always-on preemption discipline.
    pub const PREEMPT_RFOLD: PolicyHandle = PolicyHandle::new(
        "preempt-rfold",
        "PreemptRFold",
        &["prfold"],
        true,
        true,
        make_preempt_rfold,
    );

    /// All built-ins in stable reporting order.
    pub const ALL: [PolicyHandle; 7] = [
        FIRST_FIT,
        FOLDING,
        RECONFIG,
        RFOLD,
        BEST_EFFORT,
        HILBERT,
        PREEMPT_RFOLD,
    ];
}

/// String-keyed policy registry. Names resolve case-insensitively against
/// canonical keys and aliases; registration order is preserved (it is the
/// reporting order of the smoke matrix).
pub struct PolicyRegistry {
    entries: RwLock<Vec<PolicyHandle>>,
}

impl PolicyRegistry {
    /// An empty registry (tests compose their own).
    pub fn new() -> PolicyRegistry {
        PolicyRegistry {
            entries: RwLock::new(Vec::new()),
        }
    }

    /// A registry pre-seeded with the seven built-ins.
    pub fn with_builtins() -> PolicyRegistry {
        let reg = PolicyRegistry::new();
        for h in builtins::ALL {
            reg.register(h).expect("builtin keys are unique");
        }
        reg
    }

    /// The process-wide registry every driver resolves against. Seeded
    /// with the built-ins; extend it with [`PolicyRegistry::register`].
    pub fn global() -> &'static PolicyRegistry {
        static GLOBAL: OnceLock<PolicyRegistry> = OnceLock::new();
        GLOBAL.get_or_init(PolicyRegistry::with_builtins)
    }

    /// Register a policy. Rejects empty or non-lowercase keys and any
    /// key/alias that collides with an existing entry.
    pub fn register(&self, handle: PolicyHandle) -> Result<(), String> {
        let key = handle.key();
        if key.is_empty() || key != key.to_ascii_lowercase() {
            return Err(format!("policy key '{key}' must be non-empty lowercase"));
        }
        let mut entries = self.entries.write().unwrap();
        for existing in entries.iter() {
            let mut names = vec![existing.key()];
            names.extend_from_slice(existing.aliases());
            for name in names {
                if name.eq_ignore_ascii_case(key)
                    || handle.aliases().iter().any(|a| a.eq_ignore_ascii_case(name))
                {
                    return Err(format!(
                        "policy name '{name}' already registered (by '{}')",
                        existing.key()
                    ));
                }
            }
        }
        entries.push(handle);
        Ok(())
    }

    /// Resolve a CLI name (canonical key or alias, case-insensitive).
    pub fn resolve(&self, name: &str) -> Option<PolicyHandle> {
        let want = name.trim().to_ascii_lowercase();
        self.entries
            .read()
            .unwrap()
            .iter()
            .find(|h| {
                h.key() == want || h.aliases().iter().any(|a| a.eq_ignore_ascii_case(&want))
            })
            .copied()
    }

    /// Snapshot of every registered handle, in registration order.
    pub fn handles(&self) -> Vec<PolicyHandle> {
        self.entries.read().unwrap().clone()
    }

    /// Comma-joined canonical keys, for CLI error messages.
    pub fn known_keys(&self) -> String {
        self.entries
            .read()
            .unwrap()
            .iter()
            .map(|h| h.key())
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Parse a comma-separated policy list. Returns `Err` naming the first
    /// unknown entry.
    pub fn parse_list(&self, spec: &str) -> Result<Vec<PolicyHandle>, String> {
        let mut out = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match self.resolve(part) {
                Some(h) => out.push(h),
                None => {
                    return Err(format!(
                        "unknown policy '{part}'; known: {}",
                        self.known_keys()
                    ))
                }
            }
        }
        if out.is_empty() {
            return Err("empty policy list".to_string());
        }
        Ok(out)
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        PolicyRegistry::with_builtins()
    }
}

/// Bridge from the deprecated `PolicyKind` shim: old call sites keep
/// compiling while new code passes handles directly.
impl From<policies::PolicyKind> for PolicyHandle {
    fn from(kind: policies::PolicyKind) -> PolicyHandle {
        kind.handle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_by_key_and_alias() {
        let reg = PolicyRegistry::with_builtins();
        assert_eq!(reg.len(), 7);
        for h in builtins::ALL {
            assert_eq!(reg.resolve(h.key()), Some(h), "{}", h.key());
            for a in h.aliases() {
                assert_eq!(reg.resolve(a), Some(h), "alias {a}");
            }
        }
        assert_eq!(reg.resolve("First-Fit"), Some(builtins::FIRST_FIT));
        assert_eq!(reg.resolve("  RFOLD "), Some(builtins::RFOLD));
        assert_eq!(reg.resolve("nope"), None);
    }

    #[test]
    fn duplicate_registration_rejected() {
        let reg = PolicyRegistry::with_builtins();
        assert!(reg.register(builtins::RFOLD).is_err());
        // Alias collision with an existing key is rejected too.
        fn ctor() -> Box<dyn PlacementPolicy> {
            Box::new(super::super::policies::FirstFit::new())
        }
        let clash = PolicyHandle::new("newpolicy", "New", &["rfold"], false, false, ctor);
        assert!(reg.register(clash).is_err());
        let bad_key = PolicyHandle::new("NewPolicy", "New", &[], false, false, ctor);
        assert!(reg.register(bad_key).is_err());
    }

    #[test]
    fn parse_list_reports_unknown_names() {
        let reg = PolicyRegistry::with_builtins();
        let got = reg.parse_list("rfold, ff").unwrap();
        assert_eq!(got, vec![builtins::RFOLD, builtins::FIRST_FIT]);
        let err = reg.parse_list("rfold,bogus").unwrap_err();
        assert!(err.contains("bogus") && err.contains("rfold"), "{err}");
        assert!(reg.parse_list("").is_err());
    }

    #[test]
    fn handle_identity_is_the_key() {
        let a = builtins::RFOLD;
        let b = PolicyRegistry::global().resolve("rfold").unwrap();
        assert_eq!(a, b);
        let mut set = std::collections::HashSet::new();
        set.insert(a);
        assert!(set.contains(&b));
        assert_eq!(format!("{a:?}"), "PolicyHandle(rfold)");
    }

    #[test]
    fn instantiated_policies_carry_display_names() {
        for h in builtins::ALL {
            assert_eq!(h.instantiate().name(), h.name(), "{}", h.key());
        }
    }
}
