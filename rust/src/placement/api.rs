//! The open placement-policy API: an object-safe trait, a structured
//! request/decision pair, and the shared helpers every built-in rides on.
//!
//! The paper's evaluation (§4–§5) is comparative — RFold wins because it
//! searches a richer space of homomorphic shapes and OCS reconfigurations
//! than its baselines — so the repo's long-term value is how cheaply it
//! hosts *new* policies. A policy is one type implementing
//! [`PlacementPolicy`] plus one registration line in the
//! [`registry`](crate::placement::registry); nothing else in the engine,
//! sweep runner, CLI, or benches needs to change.
//!
//! Three pieces:
//!
//! * [`PlacementRequest`] — everything a policy may consult: job id,
//!   shape, arrival time, and a read-only cluster view.
//! * [`PlacementDecision`] — a committed-ready [`Plan`] or a *structured*
//!   rejection ([`PlacementDecision::Infeasible`] vs
//!   [`PlacementDecision::NoCapacity`]), each carrying the
//!   [`DecisionStats`] of the search that produced it. The engine drops
//!   infeasible jobs and queues capacity-blocked ones (paper §4 FIFO
//!   semantics) without ever pattern-matching on the policy itself.
//! * [`PolicyCore`] — the shared scorer, feasibility cache, and ablation
//!   knobs, so concrete policies stay a few dozen lines each.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use super::index::{PlacementIndex, ScanOrders};
use super::plan::Plan;
use super::score::{NativeScorer, PlanScorer};
use crate::shape::fold::{enumerate_variants, rotations_only, FoldKind, Variant};
use crate::shape::JobShape;
use crate::topology::cluster::{ClusterState, ClusterTopo};
use crate::trace::scenarios::PreemptMode;

/// One placement question: "where does this job go *right now*?".
///
/// The cluster view is read-only — policies propose, the engine commits.
#[derive(Clone, Copy)]
pub struct PlacementRequest<'a> {
    /// Job id (used to tag the produced [`Plan`]).
    pub job: u64,
    /// The job's logical shape.
    pub shape: JobShape,
    /// Arrival time in trace seconds; `0.0` for live submissions with no
    /// trace context. Built-ins ignore it; arrival-aware policies (e.g.
    /// deadline- or ageing-based ones) get it without an API change.
    pub arrival: f64,
    /// Current cluster occupancy and topology.
    pub cluster: &'a ClusterState,
}

impl<'a> PlacementRequest<'a> {
    /// Request with no trace context (live submissions).
    pub fn new(job: u64, shape: JobShape, cluster: &'a ClusterState) -> PlacementRequest<'a> {
        PlacementRequest {
            job,
            shape,
            arrival: 0.0,
            cluster,
        }
    }
}

/// Counters describing one placement search, reported with every
/// [`PlacementDecision`] and aggregated by the scheduler-observer
/// telemetry (`sim::observer`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecisionStats {
    /// Shape variants the policy enumerated for the job.
    pub variants: usize,
    /// Of those, true folds (anything beyond an axis rotation).
    pub folds_tried: usize,
    /// Candidate plans that materialized and entered ranking.
    pub candidates: usize,
}

impl DecisionStats {
    /// Stats for a variant list, before any candidate materialized.
    pub fn from_variants(vs: &[Variant]) -> DecisionStats {
        DecisionStats {
            variants: vs.len(),
            folds_tried: vs
                .iter()
                .filter(|v| !matches!(v.kind, FoldKind::Identity))
                .count(),
            candidates: 0,
        }
    }
}

/// The structured outcome of [`PlacementPolicy::plan`].
#[derive(Debug)]
pub enum PlacementDecision {
    /// A committed-ready plan (not yet applied to the cluster).
    Placed { plan: Plan, stats: DecisionStats },
    /// The shape can never be placed on this topology, even on an empty
    /// cluster — the §4 admission rule removes such jobs from the queue.
    Infeasible { stats: DecisionStats },
    /// Feasible in principle, but the cluster lacks capacity right now —
    /// the job keeps its place at the head of the FIFO queue.
    NoCapacity { stats: DecisionStats },
}

impl PlacementDecision {
    /// The search counters, whatever the outcome.
    pub fn stats(&self) -> &DecisionStats {
        match self {
            PlacementDecision::Placed { stats, .. }
            | PlacementDecision::Infeasible { stats }
            | PlacementDecision::NoCapacity { stats } => stats,
        }
    }

    /// The plan, if one was produced.
    pub fn plan(&self) -> Option<&Plan> {
        match self {
            PlacementDecision::Placed { plan, .. } => Some(plan),
            _ => None,
        }
    }

    /// Consume the decision into its plan, if any.
    pub fn into_plan(self) -> Option<Plan> {
        match self {
            PlacementDecision::Placed { plan, .. } => Some(plan),
            _ => None,
        }
    }

    /// Stable lowercase tag for reports and tests.
    pub fn label(&self) -> &'static str {
        match self {
            PlacementDecision::Placed { .. } => "placed",
            PlacementDecision::Infeasible { .. } => "infeasible",
            PlacementDecision::NoCapacity { .. } => "no-capacity",
        }
    }
}

/// Scheduler-visible snapshot of one job for preemption decisions: the
/// incoming queue head and every currently running job are described in
/// this shape, so [`select_victims`] and [`PlacementPolicy::decide`] can
/// rank them without touching engine internals.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RunningJob {
    /// Job id.
    pub job: u64,
    /// Scheduling class ([`crate::trace::JobSpec::priority`]): higher
    /// preempts lower.
    pub priority: u8,
    /// Nodes the job occupies (running) or needs (incoming).
    pub size: usize,
    /// Remaining contention-free work (s): full duration minus
    /// checkpointed progress for the incoming head, duration minus
    /// elapsed useful work for running jobs.
    pub remaining: f64,
    /// Trace arrival time (s).
    pub arrival: f64,
}

/// The full decision surface of the scheduling loop — the reference
/// RFold `SchedDecision` (ADMIT / REJECT / PREEMPT / RECONFIGURE) plus
/// the FIFO engine's Queue. Returned by [`PlacementPolicy::decide`];
/// the engine pattern-matches on this instead of on the policy.
#[derive(Debug)]
pub enum SchedAction {
    /// Place the job now on existing topology (no OCS programming).
    Admit { plan: Plan, stats: DecisionStats },
    /// Place the job now, programming OCS entries for it.
    Reconfigure { plan: Plan, stats: DecisionStats },
    /// Keep the job at the head of the FIFO queue (capacity-blocked).
    Queue { stats: DecisionStats },
    /// Drop the job: its shape can never be placed on this topology.
    Reject { stats: DecisionStats },
    /// Evict `victims` (currently running jobs, to be checkpointed and
    /// re-queued) to make room, then retry the head.
    Preempt {
        victims: Vec<u64>,
        stats: DecisionStats,
    },
}

impl SchedAction {
    /// Stable lowercase tag for reports and tests.
    pub fn label(&self) -> &'static str {
        match self {
            SchedAction::Admit { .. } => "admit",
            SchedAction::Reconfigure { .. } => "reconfigure",
            SchedAction::Queue { .. } => "queue",
            SchedAction::Reject { .. } => "reject",
            SchedAction::Preempt { .. } => "preempt",
        }
    }
}

/// Deterministic victim selection shared by the default
/// [`PlacementPolicy::decide`] and any preemptive policy that wants the
/// stock discipline. Returns the ids to evict, or an empty vector when
/// no admissible victim set frees enough nodes (the action then degrades
/// to Queue).
///
/// * [`PreemptMode::Priority`]: strictly-lower-priority jobs are
///   candidates; equal-priority jobs only when they hold more remaining
///   work than the incoming head (an SRTF tie-break, so single-class
///   traces still preempt). Ordered lowest priority first, then most
///   remaining work, then highest id — a total order, so equal-priority
///   victim choice is reproducible byte-for-byte.
/// * [`PreemptMode::Srtf`]: jobs with more remaining work than the
///   incoming head, most remaining first, then highest id.
pub fn select_victims(
    incoming: &RunningJob,
    running: &[RunningJob],
    mode: PreemptMode,
) -> Vec<u64> {
    let mut candidates: Vec<&RunningJob> = running
        .iter()
        .filter(|r| r.job != incoming.job)
        .filter(|r| match mode {
            PreemptMode::Priority => {
                r.priority < incoming.priority
                    || (r.priority == incoming.priority && r.remaining > incoming.remaining)
            }
            PreemptMode::Srtf => r.remaining > incoming.remaining,
        })
        .collect();
    candidates.sort_by(|a, b| match mode {
        PreemptMode::Priority => a
            .priority
            .cmp(&b.priority)
            .then(b.remaining.total_cmp(&a.remaining))
            .then(b.job.cmp(&a.job)),
        PreemptMode::Srtf => b.remaining.total_cmp(&a.remaining).then(b.job.cmp(&a.job)),
    });
    let mut victims = Vec::new();
    let mut freed = 0usize;
    for c in candidates {
        if freed >= incoming.size {
            break;
        }
        victims.push(c.job);
        freed += c.size;
    }
    if freed >= incoming.size {
        victims
    } else {
        Vec::new()
    }
}

/// One raw placement attempt: the plan (if any) plus search counters.
/// This is what concrete policies implement; the classification into a
/// [`PlacementDecision`] is shared (see [`PlacementPolicy::plan`]).
#[derive(Debug)]
pub struct Attempt {
    pub plan: Option<Plan>,
    pub stats: DecisionStats,
}

impl Attempt {
    /// An attempt that produced nothing beyond its counters.
    pub fn rejected(stats: DecisionStats) -> Attempt {
        Attempt { plan: None, stats }
    }

    /// Attempt of a single-candidate search (no variant enumeration):
    /// scattered/space-filling policies either place their one obvious
    /// layout or nothing.
    pub fn single(plan: Option<Plan>) -> Attempt {
        Attempt {
            stats: DecisionStats {
                variants: 1,
                folds_tried: 0,
                candidates: plan.is_some() as usize,
            },
            plan,
        }
    }
}

/// State shared by every policy: the plan scorer, the feasibility cache,
/// and the ablation knobs. Concrete policies embed one and expose it via
/// [`PlacementPolicy::core`], which is what keeps the provided trait
/// methods (classification, feasibility memoization, scorer swap) free
/// for implementors.
pub struct PolicyCore {
    /// Plan-ranking scorer (native by default; the PJRT-backed one can be
    /// swapped in via [`PlacementPolicy::set_scorer`]).
    pub scorer: Box<dyn PlanScorer>,
    /// Cache of "can this shape ever be placed on an *empty* cluster?",
    /// keyed on `(topology, shape)`. The topology must be part of the key:
    /// one policy instance may be queried against several topologies (the
    /// workload-stats driver does exactly that), and a shape-only key
    /// returns stale answers across them.
    pub feasibility: HashMap<(ClusterTopo, JobShape), bool>,
    /// Ablation A2: which job dimensionalities may be folded.
    pub fold_dims_enabled: [bool; 3],
    /// Ablation A4: search shared non-zero piece offsets inside cubes (an
    /// extension over the paper's origin-anchored prototype). On by
    /// default only for RFold.
    pub offset_search: bool,
    /// Epoch-cached spatial index (`placement::index`): rebuilt lazily
    /// when the cluster's occupancy epoch moves, shared (`Rc`) across
    /// every variant probe of every request at that epoch. Policies are
    /// single-threaded by contract (see [`PlacementPolicy`]), so `Rc`
    /// keeps borrows out of the policy's way.
    index: Option<Rc<PlacementIndex>>,
    /// Per-policy memo of the topology's scan orders (pure geometry, so
    /// epoch-independent): the scattered policies read these every
    /// attempt, and going through the process-wide cache each time would
    /// put one global mutex acquisition on every scheduling decision of
    /// every concurrent sweep worker.
    scan: Option<(ClusterTopo, Arc<ScanOrders>)>,
}

impl PolicyCore {
    pub fn new() -> PolicyCore {
        PolicyCore {
            scorer: Box::new(NativeScorer),
            feasibility: HashMap::new(),
            fold_dims_enabled: [true; 3],
            offset_search: false,
            index: None,
            scan: None,
        }
    }

    /// The topology's scan orders (snake + Hilbert), memoized on the
    /// policy so repeat attempts skip the process-wide cache's mutex.
    pub fn scan_orders(&mut self, topo: ClusterTopo) -> Arc<ScanOrders> {
        match &self.scan {
            Some((t, orders)) if *t == topo => orders.clone(),
            _ => {
                let orders = super::index::scan_orders(topo);
                self.scan = Some((topo, orders.clone()));
                orders
            }
        }
    }

    /// The spatial index for `cluster`'s current occupancy: a cached
    /// index whose epoch matches is returned as-is; a stale one is
    /// delta-advanced in place by replaying the cluster's occupancy
    /// journal ([`PlacementIndex::advance`] — cost proportional to the
    /// nodes that actually flipped, not O(V)); only when the journal no
    /// longer covers the cached epoch (or on the first call / a
    /// different cluster's history) does a full O(V) rebuild run.
    /// Epochs are globally unique per occupancy state, so a matching
    /// epoch *proves* the bitmap is the one the index reflects —
    /// including across the empty-cluster feasibility probes interleaved
    /// by [`PlacementPolicy::feasible_ever`].
    pub fn placement_index(&mut self, cluster: &ClusterState) -> Rc<PlacementIndex> {
        if let Some(idx) = self.index.as_mut() {
            if idx.epoch() == cluster.epoch() {
                return idx.clone();
            }
            // Between scheduling events the core is the sole owner of
            // the Rc (probe-time clones are short-lived), so the index
            // can usually catch up in place instead of reallocating.
            if let Some(live) = Rc::get_mut(idx) {
                if live.advance(cluster) {
                    return idx.clone();
                }
            }
        }
        let idx = Rc::new(PlacementIndex::build(cluster));
        self.index = Some(idx.clone());
        idx
    }

    /// Largest dimension a placed shape may have on this topology.
    pub fn max_dim(topo: ClusterTopo) -> usize {
        match topo {
            ClusterTopo::Static { ext } => ext.0.iter().copied().max().unwrap(),
            ClusterTopo::Reconfigurable { grid } => (grid.n * grid.num_cubes()).min(4096),
        }
    }

    /// Shape variants to consider for a job: full homomorphic folds when
    /// `folds` is set and the job's dimensionality is enabled (ablation
    /// A2), axis rotations otherwise.
    pub fn variants(&self, topo: ClusterTopo, shape: JobShape, folds: bool) -> Vec<Variant> {
        let max_dim = Self::max_dim(topo);
        if folds && self.fold_dims_enabled[shape.dimensionality().clamp(1, 3) - 1] {
            enumerate_variants(shape, max_dim)
        } else {
            rotations_only(shape, max_dim)
        }
    }
}

impl Default for PolicyCore {
    fn default() -> Self {
        PolicyCore::new()
    }
}

/// A placement policy behind the registry: object-safe, so the engine,
/// sweep runner, and coordinator all drive `Box<dyn PlacementPolicy>`
/// without knowing the concrete type.
///
/// Implementors supply [`attempt`](PlacementPolicy::attempt) (one raw
/// placement search), [`name`](PlacementPolicy::name), and
/// [`core`](PlacementPolicy::core); classification, feasibility
/// memoization, and scorer swapping are provided. Policies are *not*
/// required to be `Send` — the PJRT scorer handle is thread-local, so
/// every driver instantiates its policy on the thread that runs it.
pub trait PlacementPolicy {
    /// Stable display name (matches the registry's display label, e.g.
    /// `"RFold"`).
    fn name(&self) -> &'static str;

    /// One placement attempt against the cluster as-is. Must be
    /// deterministic: same cluster + request ⇒ same plan bytes (the sweep
    /// result cache and the golden Table-1 snapshot rely on it).
    fn attempt(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Attempt;

    /// The shared scorer/cache/knob block.
    fn core(&mut self) -> &mut PolicyCore;

    /// `true` for policies whose placements are scattered or routed over
    /// shared links (best-effort, space-filling curves): the engine then
    /// charges ring contention instead of the open-ring penalty.
    fn scattered(&self) -> bool {
        false
    }

    /// Answer a request with a structured decision: a plan, or a
    /// rejection classified as [`PlacementDecision::Infeasible`] (never
    /// placeable on this topology — drop) vs
    /// [`PlacementDecision::NoCapacity`] (queue behind the FIFO head).
    fn plan(&mut self, req: &PlacementRequest<'_>) -> PlacementDecision {
        let Attempt { plan, stats } = self.attempt(req.cluster, req.job, req.shape);
        match plan {
            Some(plan) => PlacementDecision::Placed { plan, stats },
            None if self.feasible_ever(req.cluster.topo(), req.shape) => {
                PlacementDecision::NoCapacity { stats }
            }
            None => PlacementDecision::Infeasible { stats },
        }
    }

    /// Full scheduling decision for the queue head: the reference
    /// ADMIT / REJECT / PREEMPT / RECONFIGURE surface plus Queue. The
    /// default implementation wraps [`plan`](PlacementPolicy::plan) and
    /// reproduces today's FIFO semantics exactly — Placed becomes
    /// Admit/Reconfigure (by whether the plan programs OCS entries),
    /// Infeasible becomes Reject, NoCapacity becomes Queue — unless a
    /// preemption discipline is supplied, in which case a capacity-blocked
    /// head may instead name victims via [`select_victims`]. Policies
    /// override this to implement custom disciplines; `running` holds a
    /// deterministic snapshot of every running job.
    fn decide(
        &mut self,
        req: &PlacementRequest<'_>,
        incoming: &RunningJob,
        running: &[RunningJob],
        preempt: Option<PreemptMode>,
    ) -> SchedAction {
        match self.plan(req) {
            PlacementDecision::Placed { plan, stats } => {
                if plan.ocs_entries() > 0 {
                    SchedAction::Reconfigure { plan, stats }
                } else {
                    SchedAction::Admit { plan, stats }
                }
            }
            PlacementDecision::Infeasible { stats } => SchedAction::Reject { stats },
            PlacementDecision::NoCapacity { stats } => match preempt {
                Some(mode) => {
                    let victims = select_victims(incoming, running, mode);
                    if victims.is_empty() {
                        SchedAction::Queue { stats }
                    } else {
                        SchedAction::Preempt { victims, stats }
                    }
                }
                None => SchedAction::Queue { stats },
            },
        }
    }

    /// `true` for policies that preempt even without a `--with preempt=`
    /// knob (they choose their own discipline inside
    /// [`decide`](PlacementPolicy::decide)). The engine only builds the
    /// running-job snapshot when this or the knob is set, so the six
    /// non-preemptive built-ins pay nothing.
    fn preemptive(&self) -> bool {
        false
    }

    /// Can the job be placed on an *empty* cluster of this topology?
    /// (FIFO admission drops shape-incompatible jobs, §4.) Memoized per
    /// `(topology, shape)` in the [`PolicyCore`].
    fn feasible_ever(&mut self, topo: ClusterTopo, shape: JobShape) -> bool {
        if let Some(&f) = self.core().feasibility.get(&(topo, shape)) {
            return f;
        }
        // The throwaway empty-cluster probe must not evict the live
        // cluster's index from the single-slot cache — park it and put it
        // back, so the next same-epoch probe stays a cache hit.
        let live_index = self.core().index.take();
        let empty = ClusterState::new(topo);
        let f = self.attempt(&empty, u64::MAX, shape).plan.is_some();
        self.core().index = live_index;
        self.core().feasibility.insert((topo, shape), f);
        f
    }

    /// Swap in a different plan scorer (e.g. the PJRT-backed one).
    fn set_scorer(&mut self, scorer: Box<dyn PlanScorer>) {
        self.core().scorer = scorer;
    }

    /// Convenience `Option<Plan>` view of one attempt — no rejection
    /// classification, so no hidden empty-cluster probe. Used by tests,
    /// benches, and the live coordinator's drain loop.
    fn place_now(&mut self, cluster: &ClusterState, job: u64, shape: JobShape) -> Option<Plan> {
        self.attempt(cluster, job, shape).plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::policies::{FirstFit, Reconfig};

    #[test]
    fn decision_accessors() {
        let stats = DecisionStats {
            variants: 3,
            folds_tried: 1,
            candidates: 0,
        };
        let d = PlacementDecision::NoCapacity { stats };
        assert_eq!(d.stats().variants, 3);
        assert_eq!(d.label(), "no-capacity");
        assert!(d.plan().is_none());
        assert!(d.into_plan().is_none());
        let i = PlacementDecision::Infeasible { stats };
        assert_eq!(i.label(), "infeasible");
    }

    #[test]
    fn plan_classifies_rejections() {
        // 4×4×32 on a static 16³ torus can never fit → Infeasible; a
        // feasible full-cluster shape on a busy cluster → NoCapacity.
        let c = ClusterState::new(ClusterTopo::static_4096());
        let mut p = FirstFit::new();
        let d = p.plan(&PlacementRequest::new(1, JobShape::new(4, 4, 32), &c));
        assert_eq!(d.label(), "infeasible");

        let mut busy = ClusterState::new(ClusterTopo::static_4096());
        let full = p
            .plan(&PlacementRequest::new(2, JobShape::new(16, 16, 16), &busy))
            .into_plan()
            .expect("fits empty cluster");
        full.commit(&mut busy).unwrap();
        let d = p.plan(&PlacementRequest::new(3, JobShape::new(2, 2, 2), &busy));
        assert_eq!(d.label(), "no-capacity");
    }

    #[test]
    fn feasibility_keyed_on_topology_and_shape() {
        // Regression for the shape-only cache key: 4×4×32 is infeasible on
        // the static torus but feasible on Reconfig(4³). One instance
        // queried against both topologies must answer both correctly, in
        // either order.
        let shape = JobShape::new(4, 4, 32);
        let static_t = ClusterTopo::static_4096();
        let ocs_t = ClusterTopo::reconfigurable_4096(4);

        let mut p = Reconfig::new();
        assert!(!p.feasible_ever(static_t, shape), "cannot fit 16^3 torus");
        assert!(
            p.feasible_ever(ocs_t, shape),
            "stale static-topology answer leaked across topologies"
        );
        // And the reverse order on a fresh instance.
        let mut q = Reconfig::new();
        assert!(q.feasible_ever(ocs_t, shape));
        assert!(!q.feasible_ever(static_t, shape));
        // Both answers are cached under distinct keys.
        assert_eq!(q.core().feasibility.len(), 2);
    }

    #[test]
    fn placement_index_cached_per_epoch() {
        let mut core = PolicyCore::new();
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let a = core.placement_index(&c);
        let b = core.placement_index(&c);
        assert!(std::rc::Rc::ptr_eq(&a, &b), "same epoch must not rebuild");
        // Occupancy change → epoch change → rebuild reflecting the commit.
        let mut p = Reconfig::new();
        p.place_now(&c, 1, crate::shape::JobShape::new(4, 4, 4))
            .unwrap()
            .commit(&mut c)
            .unwrap();
        let d = core.placement_index(&c);
        assert!(!std::rc::Rc::ptr_eq(&a, &d), "stale epoch must rebuild");
        assert_eq!(d.epoch(), c.epoch());
        assert!(!d.reconfig().is_box_free(
            0,
            crate::topology::P3([0, 0, 0]),
            crate::topology::P3([1, 1, 1])
        ));
        // An interleaved empty-cluster probe (the feasible_ever pattern)
        // cannot poison the cache into serving stale answers.
        let empty = ClusterState::new(c.topo());
        let e = core.placement_index(&empty);
        assert!(e.reconfig().is_box_free(
            0,
            crate::topology::P3([0, 0, 0]),
            crate::topology::P3([4, 4, 4])
        ));
        let f = core.placement_index(&c);
        assert_eq!(f.epoch(), c.epoch());
        assert!(!f.reconfig().is_box_free(
            0,
            crate::topology::P3([0, 0, 0]),
            crate::topology::P3([1, 1, 1])
        ));
    }

    #[test]
    fn feasibility_probe_does_not_evict_live_index() {
        let mut p = Reconfig::new();
        let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let live = p.core().placement_index(&c);
        // A first-seen shape runs the empty-cluster probe internally; the
        // live cluster's index must still be cached afterwards.
        assert!(p.feasible_ever(c.topo(), JobShape::new(2, 2, 2)));
        let again = p.core().placement_index(&c);
        assert!(
            std::rc::Rc::ptr_eq(&live, &again),
            "the throwaway empty-cluster probe must not evict the live index"
        );
    }

    #[test]
    fn stale_index_advances_in_place_when_sole_owner() {
        let mut core = PolicyCore::new();
        let mut c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let first = core.placement_index(&c);
        let raw = std::rc::Rc::as_ptr(&first);
        drop(first); // the core is now the sole owner
        let mut p = Reconfig::new();
        p.place_now(&c, 1, crate::shape::JobShape::new(4, 4, 4))
            .unwrap()
            .commit(&mut c)
            .unwrap();
        let adv = core.placement_index(&c);
        assert_eq!(
            std::rc::Rc::as_ptr(&adv),
            raw,
            "journaled churn must delta-advance the cached index in place"
        );
        assert_eq!(adv.epoch(), c.epoch());
        // The advanced index answers exactly like a cold build.
        let fresh = PlacementIndex::build(&c);
        for cube in 0..4 {
            for off in [[0, 0, 0], [1, 1, 1], [0, 2, 0]] {
                let off = crate::topology::P3(off);
                let e = crate::topology::P3([2, 2, 2]);
                assert_eq!(
                    adv.reconfig().is_box_free(cube, off, e),
                    fresh.reconfig().is_box_free(cube, off, e)
                );
            }
        }
    }

    fn rj(job: u64, priority: u8, size: usize, remaining: f64) -> RunningJob {
        RunningJob {
            job,
            priority,
            size,
            remaining,
            arrival: 0.0,
        }
    }

    #[test]
    fn victim_selection_is_deterministic_under_equal_priorities() {
        // Single-class traces: longest remaining work first, highest id
        // breaks exact ties — a total order, so repeated selection is
        // byte-identical.
        let incoming = rj(10, 0, 8, 100.0);
        let running = vec![rj(1, 0, 4, 500.0), rj(2, 0, 4, 500.0), rj(3, 0, 4, 50.0)];
        let v = select_victims(&incoming, &running, PreemptMode::Priority);
        assert_eq!(v, vec![2, 1], "remaining desc, then id desc");
        assert_eq!(select_victims(&incoming, &running, PreemptMode::Priority), v);
        assert_eq!(select_victims(&incoming, &running, PreemptMode::Srtf), v);
    }

    #[test]
    fn victim_selection_respects_classes_and_capacity() {
        // Lower classes are evicted before longer-running peers.
        let incoming = rj(9, 2, 4, 10.0);
        let running = vec![rj(1, 0, 4, 5.0), rj(2, 1, 4, 500.0)];
        assert_eq!(
            select_victims(&incoming, &running, PreemptMode::Priority),
            vec![1]
        );
        // An inadmissible or insufficient victim set degrades to empty
        // (the engine then queues instead of evicting pointlessly).
        let big = rj(9, 2, 64, 10.0);
        assert!(select_victims(&big, &running, PreemptMode::Priority).is_empty());
        // SRTF never evicts jobs with less remaining work than the head.
        let long_head = rj(9, 0, 4, 1000.0);
        assert!(select_victims(&long_head, &running, PreemptMode::Srtf).is_empty());
    }

    #[test]
    fn default_decide_maps_plan_outcomes_and_preempts_only_with_a_mode() {
        let mut p = FirstFit::new();
        let mut busy = ClusterState::new(ClusterTopo::static_4096());
        let full = rj(2, 0, 4096, 1000.0);
        let action = p.decide(
            &PlacementRequest::new(2, JobShape::new(16, 16, 16), &busy),
            &full,
            &[],
            None,
        );
        assert_eq!(action.label(), "admit", "static plans program no OCS");
        let SchedAction::Admit { plan, .. } = action else {
            unreachable!()
        };
        plan.commit(&mut busy).unwrap();

        // Capacity-blocked head: Queue without a discipline, Preempt with
        // one (the long-running full-cluster job is the victim).
        let head = rj(3, 0, 8, 10.0);
        let q = p.decide(
            &PlacementRequest::new(3, JobShape::new(2, 2, 2), &busy),
            &head,
            &[full],
            None,
        );
        assert_eq!(q.label(), "queue");
        let pre = p.decide(
            &PlacementRequest::new(3, JobShape::new(2, 2, 2), &busy),
            &head,
            &[full],
            Some(PreemptMode::Priority),
        );
        let SchedAction::Preempt { victims, .. } = pre else {
            panic!("expected Preempt, got {}", pre.label());
        };
        assert_eq!(victims, vec![2]);

        // A never-placeable shape is rejected outright.
        let r = p.decide(
            &PlacementRequest::new(4, JobShape::new(4, 4, 32), &busy),
            &rj(4, 0, 512, 1.0),
            &[],
            None,
        );
        assert_eq!(r.label(), "reject");
        assert!(!p.preemptive(), "built-ins do not self-preempt");
    }

    #[test]
    fn stats_count_folds_vs_rotations() {
        let core = PolicyCore::new();
        let topo = ClusterTopo::static_4096();
        let rot = core.variants(topo, JobShape::new(2, 4, 8), false);
        let s = DecisionStats::from_variants(&rot);
        assert_eq!(s.variants, rot.len());
        assert_eq!(s.folds_tried, 0, "rotations are not folds");
        let folded = core.variants(topo, JobShape::new(18, 1, 1), true);
        let s = DecisionStats::from_variants(&folded);
        assert!(s.folds_tried > 0, "18x1x1 must enumerate real folds");
    }
}
