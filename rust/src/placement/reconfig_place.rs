//! Reconfigurable placement engine (paper §3.2): decompose a placed box
//! into cube-sized pieces, pick host cubes, and plan the OCS chains that
//! stitch the pieces into a (virtual) contiguous torus.
//!
//! Faithfully modeled constraints:
//! * pieces interior to a multi-cube dimension must span the full cube
//!   side `N` (only face XPUs have OCS ports); only the *last* piece of an
//!   axis may be partial, and it can then only attach backwards — so the
//!   composed dimension has wrap-around iff `dims[k] % N == 0` (§3.2
//!   inefficiency #3);
//! * all pieces share one local offset vector, which keeps every
//!   cube-to-cube face crossing position-aligned (§3.2 inefficiency #2);
//! * stranded-core XPUs are naturally unusable for multi-cube jobs because
//!   chains only touch face positions (§3.2 inefficiency #1).

use super::index::ReconfigIndex;
use super::plan::{OcsChainPlan, Plan};
use crate::shape::fold::Variant;
use crate::topology::cluster::{ClusterState, ClusterTopo};
use crate::topology::P3;

/// Attempt to place `variant` for `job` on a reconfigurable cluster,
/// pieces anchored at each cube's origin (the paper prototype's
/// behaviour; see [`place_with_offsets`] for the extension).
///
/// Builds a fresh [`ReconfigIndex`] per call — the one-shot convenience
/// entry for tests and benches. Policy hot paths reuse the epoch-cached
/// index through [`place_indexed`].
pub fn place(cluster: &ClusterState, variant: &Variant, job: u64) -> Option<Plan> {
    place_indexed(cluster, &ReconfigIndex::build(cluster), variant, job, false)
}

/// Like [`place`] but additionally searches shared non-zero offsets for
/// axes that fit inside one cube — reuses shifted free regions of
/// partially occupied cubes (ablation A4 quantifies the gain).
pub fn place_with_offsets(cluster: &ClusterState, variant: &Variant, job: u64) -> Option<Plan> {
    place_indexed(cluster, &ReconfigIndex::build(cluster), variant, job, true)
}

/// The index-backed placement search: cube-box freeness is O(1) against
/// the index's per-cube summed-occupancy tables and the best-fit
/// candidate-cube order is read precomputed, instead of re-scanning
/// O(box-volume) nodes and re-sorting all cubes per (variant, offset)
/// probe. `index` must have been built at the cluster's current epoch;
/// results are byte-identical to the uncached search.
pub fn place_indexed(
    cluster: &ClusterState,
    index: &ReconfigIndex,
    variant: &Variant,
    job: u64,
    offset_search: bool,
) -> Option<Plan> {
    let grid = match cluster.topo() {
        ClusterTopo::Reconfigurable { grid } => grid,
        _ => panic!("reconfig_place requires a reconfigurable topology"),
    };
    let n = grid.n;
    let dims = variant.placed;
    if dims.volume() > cluster.free_count() {
        return None;
    }

    // Piece grid and per-axis piece sizes.
    let mut g = [0usize; 3];
    let mut sizes: [Vec<usize>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for k in 0..3 {
        if dims.0[k] == 0 {
            return None;
        }
        g[k] = dims.0[k].div_ceil(n);
        for u in 0..g[k] {
            let s = if u + 1 < g[k] {
                n
            } else {
                dims.0[k] - (g[k] - 1) * n
            };
            sizes[k].push(s);
        }
    }
    let pieces = g[0] * g[1] * g[2];
    if pieces > grid.num_cubes() {
        return None;
    }

    // Wrap availability: a composed dimension closes iff it is a whole
    // number of cubes (then the OCS chain is a cycle).
    let wrap = [
        dims.0[0] % n == 0,
        dims.0[1] % n == 0,
        dims.0[2] % n == 0,
    ];
    for k in 0..3 {
        if variant.requires_wrap[k] && !wrap[k] {
            return None;
        }
    }

    // Offset freedom: only on axes fully inside one cube and not spanning
    // it (multi-cube axes pin to 0: interior pieces are full-N and the
    // partial tail must touch its -face to attach backwards).
    let off_range = |k: usize| -> usize {
        if offset_search && g[k] == 1 && dims.0[k] < n {
            n - dims.0[k]
        } else {
            0
        }
    };
    // Evaluate every shared offset and keep the tightest packing (the
    // plan leaving the least free space in its touched cubes) — this is
    // what lets a shifted free region in a partially used cube be reused.
    let mut best: Option<(usize, Plan)> = None;
    for ox in 0..=off_range(0) {
        for oy in 0..=off_range(1) {
            for oz in 0..=off_range(2) {
                let off = P3([ox, oy, oz]);
                if let Some(plan) = try_offset(cluster, index, variant, job, off, &g, &sizes) {
                    let slack: usize = plan
                        .cubes
                        .iter()
                        .map(|&c| cluster.cube_free_count(c))
                        .sum::<usize>()
                        - dims.volume();
                    if best.as_ref().map(|(s, _)| slack < *s).unwrap_or(true) {
                        let done = slack == 0;
                        best = Some((slack, plan));
                        if done {
                            return best.map(|(_, p)| p);
                        }
                    }
                }
            }
        }
    }
    best.map(|(_, p)| p)
}

/// Try to assign cubes for every piece under a fixed shared offset.
fn try_offset(
    cluster: &ClusterState,
    index: &ReconfigIndex,
    variant: &Variant,
    job: u64,
    off: P3,
    g: &[usize; 3],
    sizes: &[Vec<usize>; 3],
) -> Option<Plan> {
    let grid = match cluster.topo() {
        ClusterTopo::Reconfigurable { grid } => grid,
        _ => unreachable!(),
    };
    let n = grid.n;
    let dims = variant.placed;
    let gp = P3([g[0], g[1], g[2]]);
    let pieces = gp.volume();

    // Assign a host cube to every piece: iterate pieces grouped by extent
    // class, choosiest (largest volume) first; within a class use best-fit
    // (least free XPUs) so partial pieces pack into fragmented cubes and
    // full pieces take exactly-empty cubes. The best-fit candidate order
    // and the O(1) box-freeness queries both come from the shared index.
    let mut piece_order: Vec<P3> = gp.iter_box().collect();
    piece_order.sort_by_key(|p| {
        std::cmp::Reverse(sizes[0][p.0[0]] * sizes[1][p.0[1]] * sizes[2][p.0[2]])
    });

    let mut assignment = vec![usize::MAX; pieces];
    let mut used = vec![false; grid.num_cubes()];
    for piece in piece_order {
        let pe = P3([
            sizes[0][piece.0[0]],
            sizes[1][piece.0[1]],
            sizes[2][piece.0[2]],
        ]);
        let mut found = None;
        for &cube in index.candidate_cubes() {
            if used[cube] || cluster.cube_free_count(cube) < pe.volume() {
                continue;
            }
            if index.is_box_free(cube, off, pe) {
                found = Some(cube);
                break;
            }
        }
        let cube = found?;
        used[cube] = true;
        assignment[piece.index_in(gp)] = cube;
    }

    // Node list in placed-box linear order.
    let mut nodes = Vec::with_capacity(dims.volume());
    for p in dims.iter_box() {
        let piece = P3([p.0[0] / n, p.0[1] / n, p.0[2] / n]);
        let local = P3([
            p.0[0] % n + off.0[0],
            p.0[1] % n + off.0[1],
            p.0[2] % n + off.0[2],
        ]);
        nodes.push(grid.node_id(assignment[piece.index_in(gp)], local));
    }

    // OCS chains per axis and piece-column.
    let wrap = [
        dims.0[0] % n == 0,
        dims.0[1] % n == 0,
        dims.0[2] % n == 0,
    ];
    let mut chains = Vec::new();
    for k in 0..3 {
        let needs_chain = g[k] > 1 || (dims.0[k] == n); // composition or wrap
        if !needs_chain {
            continue;
        }
        let (e, f) = match k {
            0 => (1, 2),
            1 => (0, 2),
            _ => (0, 1),
        };
        // Piece columns over the other two axes.
        for v in 0..g[e] {
            for w in 0..g[f] {
                let mut col = Vec::with_capacity(g[k]);
                for u in 0..g[k] {
                    let mut pc = [0usize; 3];
                    pc[k] = u;
                    pc[e] = v;
                    pc[f] = w;
                    col.push(assignment[P3(pc).index_in(gp)]);
                }
                // Face positions covered by this column's cross-section.
                // PortKey (i, j) uses ascending non-axis order, which is
                // exactly (e, f).
                for ie in 0..sizes[e][v] {
                    for jf in 0..sizes[f][w] {
                        chains.push(OcsChainPlan {
                            axis: k,
                            i: off.0[e] + ie,
                            j: off.0[f] + jf,
                            cubes: col.clone(),
                            closed: wrap[k],
                        });
                    }
                }
            }
        }
    }

    // All chain entries must be reservable (another job may own a
    // wrap-around circuit on a face cell we do not occupy... cannot
    // happen for cells we occupy, but check defensively).
    if let Some(ocs) = cluster.ocs() {
        for ch in &chains {
            if !ocs.can_reserve_path(ch.axis, ch.i, ch.j, &ch.cubes) {
                return None;
            }
        }
    }

    let mut cubes: Vec<usize> = assignment.clone();
    cubes.sort_unstable();
    cubes.dedup();

    Some(Plan {
        job,
        variant: variant.clone(),
        nodes,
        cubes,
        chains,
        wrap,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shape::fold::{enumerate_variants, Variant};
    use crate::shape::JobShape;
    use crate::topology::{ClusterState, ClusterTopo};

    fn cluster(n: usize) -> ClusterState {
        ClusterState::new(ClusterTopo::reconfigurable_4096(n))
    }

    #[test]
    fn single_cube_job() {
        let c = cluster(4);
        let v = Variant::identity(JobShape::new(4, 4, 4));
        let p = place(&c, &v, 1).expect("fits one cube");
        assert_eq!(p.cubes.len(), 1);
        assert_eq!(p.nodes.len(), 64);
        assert_eq!(p.wrap, [true, true, true]);
        // Wrap reservation on every face position of all three axes.
        assert_eq!(p.chains.len(), 3 * 16);
        assert!(p.chains.iter().all(|ch| ch.closed && ch.cubes.len() == 1));
    }

    #[test]
    fn paper_4x4x32_needs_8_cubes() {
        // §3.2: "to place the 4×4×32 job ... we only need eight 4×4×4
        // cubes to be reconfigured side-by-side."
        let c = cluster(4);
        let v = Variant::identity(JobShape::new(4, 4, 32));
        let p = place(&c, &v, 1).expect("8-cube chain");
        assert_eq!(p.cubes.len(), 8);
        assert_eq!(p.nodes.len(), 512);
        assert_eq!(p.wrap, [true, true, true]);
        // Z chains are cycles over 8 cubes at 16 positions.
        let z_chains: Vec<_> = p.chains.iter().filter(|c| c.axis == 2).collect();
        assert_eq!(z_chains.len(), 16);
        assert!(z_chains.iter().all(|c| c.cubes.len() == 8 && c.closed));
    }

    #[test]
    fn partial_tail_leaves_open_chain() {
        // 4×4×34: one dimension is not a multiple of 4 → 9 cubes, open
        // chain, no wrap on z (§3.2 "jobs only receive wrap-around links
        // when their shapes are a multiple of the cube dimension size").
        let c = cluster(4);
        let v = Variant::identity(JobShape::new(4, 4, 34));
        let p = place(&c, &v, 1).expect("9-cube open chain");
        assert_eq!(p.cubes.len(), 9);
        assert_eq!(p.wrap, [true, true, false]);
        let z_chains: Vec<_> = p.chains.iter().filter(|c| c.axis == 2).collect();
        assert!(z_chains.iter().all(|c| !c.closed && c.cubes.len() == 9));
    }

    #[test]
    fn too_large_for_cluster() {
        let c = cluster(4);
        // 65 cubes needed > 64.
        let v = Variant::identity(JobShape::new(4, 4, 260));
        assert!(place(&c, &v, 1).is_none());
    }

    #[test]
    fn sub_cube_job_no_chains() {
        let c = cluster(4);
        let v = Variant::identity(JobShape::new(2, 3, 2));
        let p = place(&c, &v, 1).unwrap();
        assert_eq!(p.cubes.len(), 1);
        assert!(p.chains.is_empty());
        assert_eq!(p.wrap, [false, false, false]);
    }

    #[test]
    fn requires_wrap_rejected_without_multiple_of_n() {
        let c = cluster(8);
        // HalveDouble fold of 4×8×2 → 4×4×4 requires wrap on the doubled
        // axis; with N=8 a 4-extent axis cannot wrap → reject.
        let vs = enumerate_variants(JobShape::new(4, 8, 2), 64);
        let v = vs
            .iter()
            .find(|v| v.placed == P3([4, 4, 4]) && v.requires_wrap.iter().any(|&w| w))
            .unwrap();
        assert!(place(&c, v, 1).is_none());
        // With N=4 it works.
        let c4 = cluster(4);
        let p = place(&c4, v, 1).expect("4^3 cube gives wrap");
        assert_eq!(p.cubes.len(), 1);
    }

    #[test]
    fn commit_and_pack_two_jobs_one_cube() {
        let mut c = cluster(4);
        let v1 = Variant::identity(JobShape::new(2, 4, 4));
        let p1 = place(&c, &v1, 1).unwrap();
        p1.commit(&mut c).unwrap();
        // Second job should pack into the same cube's remaining half —
        // this requires the offset-search extension (the origin-anchored
        // paper prototype would open a second cube).
        let v2 = Variant::identity(JobShape::new(2, 4, 4));
        let origin_only = place(&c, &v2, 2).unwrap();
        assert_ne!(origin_only.cubes, p1.cubes, "origin-anchored opens a new cube");
        let p2 = place_with_offsets(&c, &v2, 2).unwrap();
        assert_eq!(p2.cubes, p1.cubes, "best-fit must reuse the cube");
        p2.commit(&mut c).unwrap();
        c.check_consistency().unwrap();
        assert_eq!(c.cube_free_count(p1.cubes[0]), 0);
    }

    #[test]
    fn offset_search_finds_shifted_slot() {
        let mut c = cluster(4);
        // Occupy the x=0 plane of cube 0.
        let grid = match c.topo() {
            ClusterTopo::Reconfigurable { grid } => grid,
            _ => unreachable!(),
        };
        let nodes: Vec<usize> = P3([1, 4, 4])
            .iter_box()
            .map(|p| grid.node_id(0, p))
            .collect();
        c.commit(crate::topology::cluster::Allocation {
            job: 9,
            nodes,
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 4, 4]),
        });
        // A 3×4×4 job must sit at x-offset 1 in cube 0 (best-fit picks the
        // fragmented cube first).
        let v = Variant::identity(JobShape::new(3, 4, 4));
        let p = place_with_offsets(&c, &v, 1).unwrap();
        assert_eq!(p.cubes, vec![0]);
        assert!(p.nodes.iter().all(|&nd| c.is_free(nd)));
    }

    #[test]
    fn shared_index_matches_per_call_builds() {
        // One index serving every variant of a job must produce the same
        // plans as the per-call fresh builds (the pre-index behaviour).
        let mut c = cluster(4);
        let warm = Variant::identity(JobShape::new(3, 4, 4));
        place_with_offsets(&c, &warm, 50).unwrap().commit(&mut c).unwrap();
        let idx = ReconfigIndex::build(&c);
        for s in [
            JobShape::new(4, 4, 32),
            JobShape::new(2, 4, 4),
            JobShape::new(18, 1, 1),
        ] {
            for v in enumerate_variants(s, 64) {
                let fresh = place_with_offsets(&c, &v, 1);
                let shared = place_indexed(&c, &idx, &v, 1, true);
                assert_eq!(
                    fresh.as_ref().map(|p| &p.nodes),
                    shared.as_ref().map(|p| &p.nodes),
                    "{s} {v:?}"
                );
                assert_eq!(
                    fresh.map(|p| p.cubes),
                    shared.map(|p| p.cubes),
                    "{s} {v:?}"
                );
            }
        }
    }

    #[test]
    fn nodes_cover_box_bijectively() {
        let c = cluster(4);
        let v = Variant::identity(JobShape::new(6, 5, 4));
        let p = place(&c, &v, 1).unwrap();
        let set: std::collections::HashSet<_> = p.nodes.iter().collect();
        assert_eq!(set.len(), 120);
        assert_eq!(p.cubes.len(), 4); // 2×2×1 piece grid
    }

    #[test]
    fn all_folded_variants_placeable_on_empty_4cube() {
        for s in [
            JobShape::new(18, 1, 1),
            JobShape::new(1, 6, 4),
            JobShape::new(4, 8, 2),
        ] {
            let c = cluster(4);
            let vs = enumerate_variants(s, 64);
            let mut placed_any = false;
            for v in &vs {
                if let Some(p) = place(&c, v, 1) {
                    placed_any = true;
                    // Verify the homomorphism under the plan's wrap vector.
                    crate::shape::verify::verify(v, p.wrap)
                        .unwrap_or_else(|e| panic!("{s} {v:?}: {e}"));
                }
            }
            assert!(placed_any, "{s} must be placeable on an empty cluster");
        }
    }
}
