//! SLURM-style space-filling-curve placement (§2 background: "SLURM ...
//! uses a Hilbert curve to map 3D nodes onto a 1D axis, so that XPUs with
//! proximity can be found using line segment search algorithms" —
//! Albing et al. [1], Kwon et al. [22]).
//!
//! The policy linearizes the machine with a 3D Hilbert curve and allocates
//! the first free *contiguous segment* of the requested size (falling back
//! to the first free nodes in curve order when no segment exists). The
//! curve's locality keeps allocations compact, but — unlike RFold — the
//! result is not a torus-shaped sub-block: rings are routed over shared
//! links and pay the §3.1 contention cost. This is the classical HPC
//! baseline the paper positions itself against.

use super::plan::Plan;
use crate::shape::fold::Variant;
use crate::shape::JobShape;
use crate::topology::cluster::ClusterState;
use crate::topology::P3;

/// Map a Hilbert index to 3D coordinates on a `2^order`-sided cube
/// (Skilling's transform, inverse direction).
pub fn hilbert_d2xyz(order: u32, index: u64) -> P3 {
    let n = 3usize; // dimensions
    let bits = order as usize;
    // Split the index into the transposed Gray-code representation.
    let mut x = [0u64; 3];
    for b in 0..bits * n {
        let bit = (index >> (bits * n - 1 - b)) & 1;
        x[b % n] = (x[b % n] << 1) | bit;
    }
    // Gray decode.
    let mut t = x[n - 1] >> 1;
    for i in (1..n).rev() {
        x[i] ^= x[i - 1];
    }
    x[0] ^= t;
    // Undo excess work.
    let mut q = 2u64;
    while q != (1u64 << bits) {
        let p = q - 1;
        for i in (0..n).rev() {
            if x[i] & q != 0 {
                x[0] ^= p; // invert low bits of x[0]
            } else {
                t = (x[0] ^ x[i]) & p;
                x[0] ^= t;
                x[i] ^= t;
            }
        }
        q <<= 1;
    }
    P3([x[0] as usize, x[1] as usize, x[2] as usize])
}

/// The full Hilbert traversal of a `2^order`-sided cube, cached per order.
pub fn hilbert_order(order: u32) -> Vec<P3> {
    let total = 1u64 << (3 * order);
    (0..total).map(|i| hilbert_d2xyz(order, i)).collect()
}

/// Place `shape` for `job` on the first free Hilbert segment of length
/// `size`; fall back to the first `size` free nodes in curve order.
/// Returns `None` only when fewer than `size` XPUs are free (or the
/// machine extent is not a power-of-two cube). Resolves the curve through
/// the process-wide scan-order cache; the policy hot path hands the
/// cached curve to [`place_hilbert_indexed`] directly.
pub fn place_hilbert(cluster: &ClusterState, job: u64, shape: JobShape) -> Option<Plan> {
    let orders = super::index::scan_orders(cluster.topo());
    place_hilbert_indexed(cluster, orders.hilbert.as_deref(), job, shape)
}

/// [`place_hilbert`] over a precomputed curve-order node-id list
/// ([`ScanOrders::hilbert`](super::index::ScanOrders)): skips the
/// per-probe Skilling transform of the whole machine. A `None` curve
/// (exotic machine extent) rejects, exactly like the uncached search did.
pub fn place_hilbert_indexed(
    cluster: &ClusterState,
    curve: Option<&[usize]>,
    job: u64,
    shape: JobShape,
) -> Option<Plan> {
    let size = shape.size();
    if size > cluster.free_count() {
        return None;
    }
    let curve = curve?;

    // Line-segment search: first contiguous free run of length `size`.
    let mut run_start = 0usize;
    let mut run_len = 0usize;
    for (i, &node) in curve.iter().enumerate() {
        if cluster.is_free(node) {
            if run_len == 0 {
                run_start = i;
            }
            run_len += 1;
            if run_len == size {
                return Some(segment_plan(job, shape, curve[run_start..=i].to_vec()));
            }
        } else {
            run_len = 0;
        }
    }
    // Fallback: scattered, still in curve order (keeps locality).
    let nodes: Vec<usize> = curve
        .iter()
        .copied()
        .filter(|&nd| cluster.is_free(nd))
        .take(size)
        .collect();
    if nodes.len() < size {
        return None;
    }
    Some(segment_plan(job, shape, nodes))
}

fn segment_plan(job: u64, shape: JobShape, nodes: Vec<usize>) -> Plan {
    Plan {
        job,
        variant: Variant::identity(shape),
        nodes,
        cubes: vec![],
        chains: vec![],
        // Rings are routed (multi-hop); contention is charged by the
        // simulator's link-load model, not an open-ring penalty.
        wrap: [true, true, true],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::cluster::{Allocation, ClusterTopo};

    #[test]
    fn curve_is_bijective() {
        for order in [1u32, 2, 3, 4] {
            let pts = hilbert_order(order);
            let side = 1usize << order;
            assert_eq!(pts.len(), side * side * side);
            let set: std::collections::HashSet<_> = pts.iter().collect();
            assert_eq!(set.len(), pts.len(), "order {order}");
            assert!(pts
                .iter()
                .all(|p| p.0.iter().all(|&c| c < side)));
        }
    }

    #[test]
    fn curve_steps_are_adjacent() {
        for order in [1u32, 2, 3, 4] {
            let pts = hilbert_order(order);
            for w in pts.windows(2) {
                let d: usize = (0..3).map(|a| w[0].0[a].abs_diff(w[1].0[a])).sum();
                assert_eq!(d, 1, "order {order}: {} -> {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn places_contiguous_segment_when_empty() {
        let c = ClusterState::new(ClusterTopo::static_4096());
        let p = place_hilbert(&c, 1, JobShape::new(4, 4, 2)).unwrap();
        assert_eq!(p.nodes.len(), 32);
        // Segment = first 32 curve points → physically compact: max
        // pairwise phys distance stays small.
        let coords: Vec<P3> = p.nodes.iter().map(|&n| c.phys_coords(n)).collect();
        let spread = coords
            .iter()
            .flat_map(|a| coords.iter().map(move |b| a.torus_dist(*b, P3([16, 16, 16]))))
            .max()
            .unwrap();
        assert!(spread <= 12, "Hilbert prefix should be compact: {spread}");
    }

    #[test]
    fn survives_fragmentation_via_fallback() {
        let mut c = ClusterState::new(ClusterTopo::static_4096());
        // Block every 3rd curve point: no contiguous run of 8 exists.
        let curve = hilbert_order(4);
        let blocked: Vec<usize> = curve
            .iter()
            .step_by(3)
            .map(|&p| p.index_in(P3([16, 16, 16])))
            .collect();
        c.commit(Allocation {
            job: 9,
            nodes: blocked,
            cubes: vec![],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 1]),
        });
        let p = place_hilbert(&c, 1, JobShape::new(4, 2, 1)).unwrap();
        assert_eq!(p.nodes.len(), 8);
        assert!(p.nodes.iter().all(|&n| c.is_free(n)));
    }

    #[test]
    fn rejects_only_on_capacity() {
        let c = ClusterState::new(ClusterTopo::static_4096());
        assert!(place_hilbert(&c, 1, JobShape::new(16, 16, 16)).is_some());
        assert!(place_hilbert(&c, 1, JobShape::new(64, 65, 1)).is_none());
    }

    #[test]
    fn works_on_reconfigurable_geometry_too() {
        // The physical machine is 16^3 regardless of cube decomposition.
        let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let p = place_hilbert(&c, 1, JobShape::new(2, 3, 5)).unwrap();
        assert_eq!(p.nodes.len(), 30);
    }
}
