//! Placement policies (paper §3): FirstFit, Folding, Reconfig, RFold,
//! plus the §5 best-effort alternative.
//!
//! All policies share two engines:
//! * [`static_place`] — contiguous box search in a statically wired torus;
//! * [`reconfig_place`] — cube decomposition + OCS chain planning in a
//!   reconfigurable cluster.
//!
//! A policy turns a job into a set of candidate [`plan::Plan`]s, the
//! [`score`] module ranks them (fewest cubes → fewest OCS links → least
//! fragmentation — the paper's core heuristic), and the winning plan is
//! committed atomically against the [`crate::topology::ClusterState`].

pub mod best_effort;
pub mod hilbert;
pub mod plan;
pub mod policies;
pub mod reconfig_place;
pub mod score;
pub mod static_place;

pub use plan::{OcsChainPlan, Plan};
pub use policies::{Policy, PolicyKind};
