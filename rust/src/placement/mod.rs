//! Placement policies (paper §3): FirstFit, Folding, Reconfig, RFold,
//! plus the §5 best-effort and §2 Hilbert baselines — all behind the open
//! [`PlacementPolicy`] trait and the string-keyed [`PolicyRegistry`].
//!
//! All policies share two engines:
//! * [`static_place`] — contiguous box search in a statically wired torus;
//! * [`reconfig_place`] — cube decomposition + OCS chain planning in a
//!   reconfigurable cluster.
//!
//! Both engines run against the epoch-cached spatial index in [`index`]
//! (built at most once per occupancy change, shared across every variant
//! probe and queued job at that epoch via
//! [`PolicyCore::placement_index`](api::PolicyCore::placement_index)).
//!
//! A policy turns a [`api::PlacementRequest`] into a
//! [`api::PlacementDecision`]: a committed-ready [`plan::Plan`] chosen by
//! the [`score`] ranking (fewest cubes → fewest OCS links → least
//! fragmentation — the paper's core heuristic), or a structured rejection
//! the engine acts on without knowing the policy. New policies implement
//! the trait and add one [`PolicyRegistry::register`] line; see the
//! README's "Adding a placement policy".

pub mod api;
pub mod best_effort;
pub mod hilbert;
pub mod index;
pub mod plan;
pub mod policies;
pub mod reconfig_place;
pub mod registry;
pub mod score;
pub mod static_place;

pub use api::{
    select_victims, Attempt, DecisionStats, PlacementDecision, PlacementPolicy, PlacementRequest,
    PolicyCore, RunningJob, SchedAction,
};
pub use index::{PlacementIndex, ReconfigIndex};
pub use plan::{OcsChainPlan, Plan};
pub use policies::PolicyKind;
pub use registry::{builtins, PolicyHandle, PolicyRegistry};
