//! Best-effort (non-contiguous) placement — the §5 "revisiting best-effort
//! placement" discussion and the A3 crossover experiment.
//!
//! Takes the first `size` free XPUs in a boustrophedon (snake) scan of the
//! physical machine — close to the space-filling-curve allocators the
//! paper cites [22, 27] — and maps the job's logical shape onto them in
//! scan order. Rings then traverse shared links; the resulting slowdown is
//! computed by `sim::contention` from the cluster-wide link-load field.

use super::plan::Plan;
use crate::shape::fold::Variant;
use crate::shape::JobShape;
use crate::topology::cluster::ClusterState;
use crate::topology::P3;

/// Scan order: boustrophedon over (x, y, z) — adjacent scan positions are
/// usually physically adjacent, giving the best-effort allocator the
/// "close to each other on a best-effort basis" behaviour of [22, 27].
pub fn snake_order(ext: P3) -> Vec<P3> {
    let mut out = Vec::with_capacity(ext.volume());
    for x in 0..ext.0[0] {
        let ys: Vec<usize> = if x % 2 == 0 {
            (0..ext.0[1]).collect()
        } else {
            (0..ext.0[1]).rev().collect()
        };
        for (yi, &y) in ys.iter().enumerate() {
            let flip = (x + yi) % 2 == 1;
            let zs: Vec<usize> = if flip {
                (0..ext.0[2]).rev().collect()
            } else {
                (0..ext.0[2]).collect()
            };
            for &z in &zs {
                out.push(P3([x, y, z]));
            }
        }
    }
    out
}

/// Place a job on any `size` free XPUs (snake order). Returns `None` only
/// when fewer than `size` XPUs are free — best-effort never blocks on
/// shape. Resolves the scan order through the process-wide
/// [`scan_orders`](super::index::scan_orders) cache (one map lookup), so
/// it is equivalent to [`place_scattered_indexed`] with the cached order;
/// callers already holding the order skip the lookup.
pub fn place_scattered(cluster: &ClusterState, job: u64, shape: JobShape) -> Option<Plan> {
    let order = super::index::scan_orders(cluster.topo());
    place_scattered_indexed(cluster, &order.snake, job, shape)
}

/// [`place_scattered`] over a precomputed snake-order node-id list
/// ([`ScanOrders::snake`](super::index::ScanOrders)): skips the per-probe
/// curve materialization and coordinate→node mapping.
pub fn place_scattered_indexed(
    cluster: &ClusterState,
    order: &[usize],
    job: u64,
    shape: JobShape,
) -> Option<Plan> {
    let size = shape.size();
    if size > cluster.free_count() {
        return None;
    }
    let mut nodes = Vec::with_capacity(size);
    for &node in order {
        if cluster.is_free(node) {
            nodes.push(node);
            if nodes.len() == size {
                break;
            }
        }
    }
    if nodes.len() < size {
        return None;
    }
    Some(Plan {
        job,
        variant: Variant::identity(shape),
        nodes,
        cubes: vec![],
        chains: vec![],
        // Logical rings are routed (multi-hop), so they always "close";
        // the cost shows up as contention, not as an open-ring penalty.
        wrap: [true, true, true],
    })
}

/// Inverse of `ClusterState::phys_coords`.
pub fn phys_to_node(cluster: &ClusterState, p: P3) -> usize {
    phys_to_node_topo(cluster.topo(), p)
}

/// [`phys_to_node`] from the topology alone (the mapping is pure
/// geometry; precomputed scan orders use this without a cluster).
pub fn phys_to_node_topo(topo: crate::topology::cluster::ClusterTopo, p: P3) -> usize {
    use crate::topology::cluster::ClusterTopo;
    match topo {
        ClusterTopo::Static { ext } => p.index_in(ext),
        ClusterTopo::Reconfigurable { grid } => {
            let c = P3([p.0[0] / grid.n, p.0[1] / grid.n, p.0[2] / grid.n]);
            let l = P3([p.0[0] % grid.n, p.0[1] % grid.n, p.0[2] % grid.n]);
            grid.node_id(grid.cube_id(c), l)
        }
    }
}

/// The logical ring node sequences of a best-effort allocation, in
/// *physical coordinates* (for link-load accounting): dimension-major
/// chunking of the scan-ordered node list.
pub fn ring_members(cluster: &ClusterState, plan: &Plan) -> Vec<Vec<P3>> {
    let dims = plan.variant.orig.dims();
    let mut rings = Vec::new();
    for d in 0..3 {
        if dims.0[d] < 2 {
            continue;
        }
        let (e, f) = match d {
            0 => (1, 2),
            1 => (0, 2),
            _ => (0, 1),
        };
        for ie in 0..dims.0[e] {
            for jf in 0..dims.0[f] {
                let mut members = Vec::with_capacity(dims.0[d]);
                for k in 0..dims.0[d] {
                    let mut l = [0usize; 3];
                    l[d] = k;
                    l[e] = ie;
                    l[f] = jf;
                    let node = plan.nodes[P3(l).index_in(dims)];
                    members.push(cluster.phys_coords(node));
                }
                rings.push(members);
            }
        }
    }
    rings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{ClusterState, ClusterTopo};

    #[test]
    fn snake_order_adjacent_steps() {
        let ext = P3([4, 4, 4]);
        let order = snake_order(ext);
        assert_eq!(order.len(), 64);
        let distinct: std::collections::HashSet<_> = order.iter().collect();
        assert_eq!(distinct.len(), 64);
        // Within an x-slab, consecutive positions are adjacent.
        for w in order.windows(2) {
            if w[0].0[0] == w[1].0[0] {
                let d = w[0].torus_dist(w[1], P3([64, 64, 64])); // no wrap
                assert_eq!(d, 1, "{} -> {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn scatters_when_fragmented() {
        let mut c = ClusterState::new(ClusterTopo::static_4096());
        // Busy-out a checkerboard of half the nodes.
        let ext = P3([16, 16, 16]);
        let nodes: Vec<usize> = ext
            .iter_box()
            .filter(|p| (p.0[0] + p.0[1] + p.0[2]) % 2 == 0)
            .map(|p| p.index_in(ext))
            .collect();
        c.commit(crate::topology::cluster::Allocation {
            job: 1,
            nodes,
            cubes: vec![],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: ext,
        });
        // No contiguous 2×2×2 box exists, but best-effort still places it.
        let p = place_scattered(&c, 2, JobShape::new(2, 2, 2)).unwrap();
        assert_eq!(p.nodes.len(), 8);
        assert!(p.nodes.iter().all(|&n| c.is_free(n)));
    }

    #[test]
    fn fails_only_when_not_enough_xpus() {
        let c = ClusterState::new(ClusterTopo::static_4096());
        assert!(place_scattered(&c, 1, JobShape::new(16, 16, 16)).is_some());
        assert!(place_scattered(&c, 1, JobShape::new(17, 16, 16)).is_none());
    }

    #[test]
    fn ring_members_cover_all_nodes() {
        let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let p = place_scattered(&c, 1, JobShape::new(4, 4, 1)).unwrap();
        let rings = ring_members(&c, &p);
        // 4 rings along each of two dims.
        assert_eq!(rings.len(), 8);
        assert!(rings.iter().all(|r| r.len() == 4));
    }

    #[test]
    fn phys_roundtrip() {
        let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        for node in [0usize, 100, 4095, 777] {
            assert_eq!(phys_to_node(&c, c.phys_coords(node)), node);
        }
    }
}
