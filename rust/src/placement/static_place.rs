//! Contiguous box search in a statically wired torus (FirstFit's engine,
//! and the Folding policy's per-variant engine).
//!
//! Boxes may wrap around any dimension (a static torus has hard wrap
//! cables on every full dimension). Fullness checks use a 3D
//! summed-occupancy table: O(1) per (anchor, sub-box) after an O(V) build,
//! so a full FirstFit scan of a 16³ torus costs ~4096 × ≤8 lookups.

use crate::topology::cluster::{ClusterState, ClusterTopo};
use crate::topology::P3;

/// 3D inclusive prefix sums over the busy bitmap of a static torus.
pub struct OccupancySums {
    ext: P3,
    /// `(ext+1)³` table; `s[x][y][z]` = busy count in `[0,x)×[0,y)×[0,z)`.
    s: Vec<u32>,
}

impl OccupancySums {
    pub fn build(cluster: &ClusterState) -> OccupancySums {
        let ext = match cluster.topo() {
            ClusterTopo::Static { ext } => ext,
            _ => panic!("OccupancySums requires a static topology"),
        };
        let (sx, sy, sz) = (ext.0[0] + 1, ext.0[1] + 1, ext.0[2] + 1);
        let mut sums = OccupancySums {
            ext,
            s: vec![0u32; sx * sy * sz],
        };
        // Entries with any zero coordinate are the all-zero border the
        // fresh vec already provides; everything else is one refresh of
        // the full region.
        sums.refresh_region(cluster, P3([0, 0, 0]));
        sums
    }

    /// Re-derive every prefix entry whose covered box can have changed
    /// given that no busy bit below `lo` (component-wise) flipped: the
    /// entries `(X,Y,Z)` with `X > lo.x ∧ Y > lo.y ∧ Z > lo.z`, in
    /// ascending order so each recurrence reads already-correct
    /// neighbours (the rest of the table is untouched and still valid).
    fn refresh_region(&mut self, cluster: &ClusterState, lo: P3) {
        let ext = self.ext;
        let (nx, ny, nz) = (ext.0[0], ext.0[1], ext.0[2]);
        let (sy, sz) = (ny + 1, nz + 1);
        let idx = |x: usize, y: usize, z: usize| (x * sy + y) * sz + z;
        let s = &mut self.s;
        for x in lo.0[0]..nx {
            for y in lo.0[1]..ny {
                for z in lo.0[2]..nz {
                    let busy = !cluster.is_free(P3([x, y, z]).index_in(ext));
                    s[idx(x + 1, y + 1, z + 1)] = busy as u32
                        + s[idx(x, y + 1, z + 1)]
                        + s[idx(x + 1, y, z + 1)]
                        + s[idx(x + 1, y + 1, z)]
                        - s[idx(x, y, z + 1)]
                        - s[idx(x, y + 1, z)]
                        - s[idx(x + 1, y, z)]
                        + s[idx(x, y, z)];
                }
            }
        }
    }

    /// Delta-advance the table across a batch of busy-bit flips (node
    /// ids whose state changed since this table was built), reading the
    /// post-flip occupancy from `cluster`. Only the suffix region past
    /// the flips' minimum corner is recomputed — a release high up the
    /// torus costs a corner sliver, never the full O(V) sweep — and the
    /// result is bit-identical to a fresh [`build`](Self::build).
    pub fn apply_flips(&mut self, cluster: &ClusterState, flips: &[(usize, bool)]) {
        if flips.is_empty() {
            return;
        }
        let mut lo = self.ext;
        for &(node, _) in flips {
            let p = P3::from_index(node, self.ext);
            for a in 0..3 {
                lo.0[a] = lo.0[a].min(p.0[a]);
            }
        }
        self.refresh_region(cluster, lo);
    }

    #[inline]
    fn prefix(&self, x: usize, y: usize, z: usize) -> u32 {
        let sy = self.ext.0[1] + 1;
        let sz = self.ext.0[2] + 1;
        self.s[(x * sy + y) * sz + z]
    }

    /// Total busy nodes (the full-extent prefix).
    pub fn total_busy(&self) -> u32 {
        self.prefix(self.ext.0[0], self.ext.0[1], self.ext.0[2])
    }

    /// Free nodes in the torus — identical to the cluster's
    /// `free_count()` at the epoch the table was built.
    pub fn free_count(&self) -> usize {
        self.ext.volume() - self.total_busy() as usize
    }

    /// Busy count in the half-open box `[x0,x1)×[y0,y1)×[z0,z1)` (no wrap).
    pub fn busy_in(&self, x0: usize, x1: usize, y0: usize, y1: usize, z0: usize, z1: usize) -> u32 {
        self.prefix(x1, y1, z1)
            .wrapping_sub(self.prefix(x0, y1, z1))
            .wrapping_sub(self.prefix(x1, y0, z1))
            .wrapping_sub(self.prefix(x1, y1, z0))
            .wrapping_add(self.prefix(x0, y0, z1))
            .wrapping_add(self.prefix(x0, y1, z0))
            .wrapping_add(self.prefix(x1, y0, z0))
            .wrapping_sub(self.prefix(x0, y0, z0))
    }

    /// Is the (possibly wrapping) box anchored at `anchor` of extent `e`
    /// entirely free? Each wrapped axis splits into ≤ 2 intervals.
    pub fn box_free(&self, anchor: P3, e: P3) -> bool {
        let mut ivs: [[(usize, usize); 2]; 3] = [[(0, 0); 2]; 3];
        let mut niv = [0usize; 3];
        for a in 0..3 {
            let n = self.ext.0[a];
            let start = anchor.0[a];
            let len = e.0[a];
            debug_assert!(len <= n);
            if start + len <= n {
                ivs[a][0] = (start, start + len);
                niv[a] = 1;
            } else {
                ivs[a][0] = (start, n);
                ivs[a][1] = (0, start + len - n);
                niv[a] = 2;
            }
        }
        for ix in 0..niv[0] {
            for iy in 0..niv[1] {
                for iz in 0..niv[2] {
                    let (x0, x1) = ivs[0][ix];
                    let (y0, y1) = ivs[1][iy];
                    let (z0, z1) = ivs[2][iz];
                    if self.busy_in(x0, x1, y0, y1, z0, z1) != 0 {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Find the first (lexicographic anchor order) free box of extent `e`,
    /// or `None`. Extents exceeding the torus are rejected. This is the
    /// index-backed probe: one table answers every variant of a job and
    /// every queued job at the same epoch, where the hot path used to
    /// rebuild the O(V) table per variant.
    pub fn find_first_box(&self, e: P3) -> Option<P3> {
        let ext = self.ext;
        if (0..3).any(|a| e.0[a] > ext.0[a] || e.0[a] == 0) {
            return None;
        }
        if e.volume() > self.free_count() {
            return None;
        }
        // Anchors only need to range over positions where wrapping
        // matters: if e[a] == ext[a] the anchor on that axis is
        // irrelevant — pin to 0.
        let ax = if e.0[0] == ext.0[0] { 1 } else { ext.0[0] };
        let ay = if e.0[1] == ext.0[1] { 1 } else { ext.0[1] };
        let az = if e.0[2] == ext.0[2] { 1 } else { ext.0[2] };
        for x in 0..ax {
            for y in 0..ay {
                for z in 0..az {
                    let anchor = P3([x, y, z]);
                    if self.box_free(anchor, e) {
                        return Some(anchor);
                    }
                }
            }
        }
        None
    }
}

/// [`OccupancySums::find_first_box`] against a freshly built table — the
/// uncached convenience entry used by tests and one-shot callers. Policy
/// hot paths go through the epoch-cached table in
/// [`PolicyCore::placement_index`](super::api::PolicyCore::placement_index)
/// instead.
pub fn find_first_box(cluster: &ClusterState, e: P3) -> Option<P3> {
    let ext = match cluster.topo() {
        ClusterTopo::Static { ext } => ext,
        _ => panic!("find_first_box requires a static topology"),
    };
    // Cheap rejections before paying the O(V) build.
    if (0..3).any(|a| e.0[a] > ext.0[a] || e.0[a] == 0) {
        return None;
    }
    if e.volume() > cluster.free_count() {
        return None;
    }
    OccupancySums::build(cluster).find_first_box(e)
}

/// Node ids covered by the (possibly wrapping) box, in placed-box linear
/// order (matching `Plan::nodes`).
pub fn box_nodes(cluster: &ClusterState, anchor: P3, e: P3) -> Vec<usize> {
    let ext = match cluster.topo() {
        ClusterTopo::Static { ext } => ext,
        _ => panic!("box_nodes requires a static topology"),
    };
    e.iter_box()
        .map(|d| {
            let p = P3([
                (anchor.0[0] + d.0[0]) % ext.0[0],
                (anchor.0[1] + d.0[1]) % ext.0[1],
                (anchor.0[2] + d.0[2]) % ext.0[2],
            ]);
            p.index_in(ext)
        })
        .collect()
}

/// Wrap-around availability of a box in a static torus: an axis has a
/// closed ring iff the box spans the full dimension.
pub fn box_wrap(cluster: &ClusterState, e: P3) -> [bool; 3] {
    let ext = match cluster.topo() {
        ClusterTopo::Static { ext } => ext,
        _ => panic!("box_wrap requires a static topology"),
    };
    [
        e.0[0] == ext.0[0],
        e.0[1] == ext.0[1],
        e.0[2] == ext.0[2],
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shape::fold::Variant;
    use crate::shape::JobShape;
    use crate::topology::cluster::Allocation;
    use crate::topology::ClusterTopo;

    fn static_cluster() -> ClusterState {
        ClusterState::new(ClusterTopo::static_4096())
    }

    fn occupy(c: &mut ClusterState, job: u64, nodes: Vec<usize>) {
        c.commit(Allocation {
            job,
            nodes,
            cubes: vec![],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 1]),
        });
    }

    #[test]
    fn empty_cluster_places_at_origin() {
        let c = static_cluster();
        assert_eq!(find_first_box(&c, P3([4, 4, 4])), Some(P3([0, 0, 0])));
        assert_eq!(find_first_box(&c, P3([16, 16, 16])), Some(P3([0, 0, 0])));
    }

    #[test]
    fn oversized_rejected() {
        let c = static_cluster();
        assert_eq!(find_first_box(&c, P3([17, 1, 1])), None);
        assert_eq!(find_first_box(&c, P3([0, 4, 4])), None);
    }

    #[test]
    fn skips_occupied_anchor() {
        let mut c = static_cluster();
        occupy(&mut c, 1, vec![P3([0, 0, 0]).index_in(P3([16, 16, 16]))]);
        let found = find_first_box(&c, P3([2, 2, 2])).unwrap();
        assert_ne!(found, P3([0, 0, 0]));
        let sums = OccupancySums::build(&c);
        assert!(sums.box_free(found, P3([2, 2, 2])));
    }

    #[test]
    fn wrapping_box_found() {
        let mut c = static_cluster();
        // Occupy the center slab x ∈ [1, 15): only a wrapped x-box fits.
        let ext = P3([16, 16, 16]);
        let mut nodes = Vec::new();
        for x in 1..15 {
            for y in 0..16 {
                for z in 0..16 {
                    nodes.push(P3([x, y, z]).index_in(ext));
                }
            }
        }
        occupy(&mut c, 1, nodes);
        let found = find_first_box(&c, P3([2, 4, 4])).expect("wrapped box must fit");
        assert_eq!(found.0[0], 15, "must anchor at x=15 wrapping to x=0");
        let nodes = box_nodes(&c, found, P3([2, 4, 4]));
        assert!(nodes.iter().all(|&n| c.is_free(n)));
        assert_eq!(nodes.len(), 32);
    }

    #[test]
    fn box_nodes_distinct_and_free_order() {
        let c = static_cluster();
        let nodes = box_nodes(&c, P3([14, 14, 14]), P3([4, 4, 4]));
        let set: std::collections::HashSet<_> = nodes.iter().collect();
        assert_eq!(set.len(), 64);
    }

    #[test]
    fn wrap_flags() {
        let c = static_cluster();
        assert_eq!(box_wrap(&c, P3([16, 4, 2])), [true, false, false]);
    }

    #[test]
    fn prefix_sums_match_bruteforce() {
        let mut c = static_cluster();
        let ext = P3([16, 16, 16]);
        // Deterministic scatter.
        let mut rng = crate::util::Pcg64::seeded(77);
        let nodes: Vec<usize> = (0..600).map(|_| rng.below(4096)).collect();
        let mut distinct: Vec<usize> = nodes.clone();
        distinct.sort_unstable();
        distinct.dedup();
        occupy(&mut c, 1, distinct);
        let sums = OccupancySums::build(&c);
        for _ in 0..200 {
            let anchor = P3([rng.below(16), rng.below(16), rng.below(16)]);
            let e = P3([rng.range(1, 5), rng.range(1, 5), rng.range(1, 5)]);
            let brute = e.iter_box().all(|d| {
                let p = P3([
                    (anchor.0[0] + d.0[0]) % 16,
                    (anchor.0[1] + d.0[1]) % 16,
                    (anchor.0[2] + d.0[2]) % 16,
                ]);
                c.is_free(p.index_in(ext))
            });
            assert_eq!(sums.box_free(anchor, e), brute, "anchor={anchor} e={e}");
        }
    }

    #[test]
    fn indexed_find_first_box_matches_fresh_build() {
        let mut c = static_cluster();
        let mut rng = crate::util::Pcg64::seeded(31);
        let mut nodes: Vec<usize> = (0..1500).map(|_| rng.below(4096)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        occupy(&mut c, 1, nodes);
        let sums = OccupancySums::build(&c);
        assert_eq!(sums.free_count(), c.free_count());
        for _ in 0..60 {
            let e = P3([rng.range(1, 17), rng.range(1, 17), rng.range(1, 17)]);
            assert_eq!(sums.find_first_box(e), find_first_box(&c, e), "e={e}");
        }
        // Degenerate extents reject in both paths.
        assert_eq!(sums.find_first_box(P3([0, 4, 4])), None);
        assert_eq!(sums.find_first_box(P3([17, 1, 1])), None);
    }

    #[test]
    fn applied_flips_match_fresh_build_under_churn() {
        let mut c = static_cluster();
        let mut sums = OccupancySums::build(&c);
        let mut rng = crate::util::Pcg64::seeded(123);
        let mut live: Vec<u64> = Vec::new();
        for step in 0..60u64 {
            if live.is_empty() || rng.chance(0.6) {
                let mut nodes: Vec<usize> = (0..rng.range(1, 40))
                    .map(|_| rng.below(4096))
                    .filter(|&n| c.is_free(n))
                    .collect();
                nodes.sort_unstable();
                nodes.dedup();
                if nodes.is_empty() {
                    continue;
                }
                let flips: Vec<(usize, bool)> =
                    nodes.iter().map(|&n| (n, true)).collect();
                occupy(&mut c, step, nodes);
                live.push(step);
                sums.apply_flips(&c, &flips);
            } else {
                let job = live.swap_remove(rng.below(live.len()));
                let alloc = c.release(job).unwrap();
                let flips: Vec<(usize, bool)> =
                    alloc.nodes.iter().map(|&n| (n, false)).collect();
                sums.apply_flips(&c, &flips);
            }
            let fresh = OccupancySums::build(&c);
            assert_eq!(sums.s, fresh.s, "delta table drifted at step {step}");
        }
    }

    #[test]
    fn full_box_placement_via_variant() {
        // Place an identity 16×2×2 variant: wrap on x only.
        let c = static_cluster();
        let v = Variant::identity(JobShape::new(16, 2, 2));
        let anchor = find_first_box(&c, v.placed).unwrap();
        let wrap = box_wrap(&c, v.placed);
        assert_eq!(wrap, [true, false, false]);
        assert_eq!(box_nodes(&c, anchor, v.placed).len(), 64);
    }
}
