//! Plan ranking: the paper's heuristic, "the optimal placement consumes
//! the fewest reconfigurable cubes and OCS links" (§3.1), refined with a
//! fragmentation composite that mirrors the AOT plan-scorer artifact
//! (python/compile/model.py — keep the weights in sync).

use super::plan::Plan;
use crate::topology::cluster::{ClusterState, ClusterTopo};

/// Ranking weights — MUST match python/compile/model.py.
pub const W_PARTIAL_CUBES: f64 = 64.0;
pub const W_STRANDED: f64 = 8.0;
pub const W_THRU_LOST: f64 = 1.0;
pub const W_TRANSITIONS: f64 = 0.5;
pub const W_MAX_LOAD: f64 = 32.0;

/// Raw fragmentation statistics of a hypothetical occupancy (the Rust twin
/// of `kernels/ref.py::frag_stats` for one plan).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FragStats {
    pub total_free: f64,
    pub partial_cubes: f64,
    pub stranded: f64,
    pub thru: f64,
    pub transitions: f64,
    pub empty_cubes: f64,
}

impl FragStats {
    /// The composite used for ranking (lower = better). `max_load` is 0
    /// for contention-free contiguous placements.
    pub fn composite(&self, cubes: usize, n: usize, max_load: f64) -> f64 {
        let max_thru = 3.0 * (n * n * cubes) as f64;
        W_PARTIAL_CUBES * self.partial_cubes
            + W_STRANDED * self.stranded
            + W_THRU_LOST * (max_thru - self.thru)
            + W_TRANSITIONS * self.transitions
            + W_MAX_LOAD * max_load
    }
}

/// Scorer abstraction: the native implementation below, or the PJRT-backed
/// one in `runtime::scorer` that executes the AOT artifact.
pub trait PlanScorer {
    /// Fragmentation statistics of each occupancy grid. `occ` is
    /// `[K][C][N][N][N]` flattened, values 0.0/1.0.
    fn frag_stats(&mut self, occ: &[f32], k: usize, cubes: usize, n: usize) -> Vec<FragStats>;
}

/// Pure-Rust scorer (bit-identical statistics to the jnp oracle).
#[derive(Default, Clone, Copy, Debug)]
pub struct NativeScorer;

impl PlanScorer for NativeScorer {
    fn frag_stats(&mut self, occ: &[f32], k: usize, cubes: usize, n: usize) -> Vec<FragStats> {
        let vol = n * n * n;
        assert_eq!(occ.len(), k * cubes * vol);
        let mut out = Vec::with_capacity(k);
        // Single pass per cube cell: every statistic accumulated in one
        // sweep (perf pass, EXPERIMENTS.md §Perf — ~2× over the naive
        // multi-loop version at n=4).
        for plan in 0..k {
            let base = plan * cubes * vol;
            let mut st = FragStats::default();
            for c in 0..cubes {
                let cb = &occ[base + c * vol..base + (c + 1) * vol];
                let at = |x: usize, y: usize, z: usize| cb[(x * n + y) * n + z];
                let mut busy = 0.0f32;
                for x in 0..n {
                    for y in 0..n {
                        for z in 0..n {
                            let v = at(x, y, z);
                            busy += v;
                            if n >= 3
                                && (1..n - 1).contains(&x)
                                && (1..n - 1).contains(&y)
                                && (1..n - 1).contains(&z)
                            {
                                st.stranded += (1.0 - v) as f64;
                            }
                            if x + 1 < n {
                                st.transitions += (at(x + 1, y, z) - v).abs() as f64;
                            }
                            if y + 1 < n {
                                st.transitions += (at(x, y + 1, z) - v).abs() as f64;
                            }
                            if z + 1 < n {
                                st.transitions += (at(x, y, z + 1) - v).abs() as f64;
                            }
                            if x == 0 {
                                st.thru += ((1.0 - v) * (1.0 - at(n - 1, y, z))) as f64;
                            }
                            if y == 0 {
                                st.thru += ((1.0 - v) * (1.0 - at(x, n - 1, z))) as f64;
                            }
                            if z == 0 {
                                st.thru += ((1.0 - v) * (1.0 - at(x, y, n - 1))) as f64;
                            }
                        }
                    }
                }
                st.total_free += (vol as f32 - busy) as f64;
                if busy > 0.0 && (busy as usize) < vol {
                    st.partial_cubes += 1.0;
                }
                if busy == 0.0 {
                    st.empty_cubes += 1.0;
                }
            }
            out.push(st);
        }
        out
    }
}

/// Build the hypothetical post-commit occupancy grid for each plan.
/// Layout `[K][C][N][N][N]` (cube-major node ids are already in this
/// order for reconfigurable clusters).
pub fn hypothetical_occupancy(cluster: &ClusterState, plans: &[Plan]) -> (Vec<f32>, usize, usize) {
    let (cubes, n) = match cluster.topo() {
        ClusterTopo::Reconfigurable { grid } => (grid.num_cubes(), grid.n),
        ClusterTopo::Static { ext } => (1, ext.0[0]),
    };
    let base = cluster.occupancy_f32();
    let mut occ = Vec::with_capacity(plans.len() * base.len());
    for p in plans {
        let mut o = base.clone();
        for &nd in &p.nodes {
            o[nd] = 1.0;
        }
        occ.extend_from_slice(&o);
    }
    (occ, cubes, n)
}

/// Rank candidate plans with the paper's heuristic and return the index of
/// the best one: fewest cubes, then fewest OCS entries, then lowest
/// fragmentation composite.
pub fn rank_plans(
    cluster: &ClusterState,
    plans: &[Plan],
    scorer: &mut dyn PlanScorer,
) -> Option<usize> {
    if plans.is_empty() {
        return None;
    }
    if plans.len() == 1 {
        return Some(0);
    }
    let (occ, cubes, n) = hypothetical_occupancy(cluster, plans);
    let stats = scorer.frag_stats(&occ, plans.len(), cubes, n);
    let mut best = 0usize;
    let mut best_key = (usize::MAX, usize::MAX, f64::INFINITY);
    for (i, (p, st)) in plans.iter().zip(&stats).enumerate() {
        let key = (
            p.cubes.len().max(1),
            p.ocs_entries(),
            st.composite(cubes, n, 0.0),
        );
        if key.0 < best_key.0
            || (key.0 == best_key.0 && key.1 < best_key.1)
            || (key.0 == best_key.0 && key.1 == best_key.1 && key.2 < best_key.2)
        {
            best_key = key;
            best = i;
        }
    }
    Some(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::reconfig_place;
    use crate::shape::fold::{enumerate_variants, Variant};
    use crate::shape::JobShape;
    use crate::topology::{ClusterState, ClusterTopo};

    #[test]
    fn native_scorer_all_free() {
        let mut s = NativeScorer;
        let occ = vec![0.0f32; 2 * 4 * 64];
        let st = s.frag_stats(&occ, 2, 4, 4);
        assert_eq!(st.len(), 2);
        assert_eq!(st[0].total_free, 256.0);
        assert_eq!(st[0].partial_cubes, 0.0);
        assert_eq!(st[0].stranded, 4.0 * 8.0);
        assert_eq!(st[0].thru, 4.0 * 48.0);
        assert_eq!(st[0].transitions, 0.0);
        assert_eq!(st[0].empty_cubes, 4.0);
    }

    #[test]
    fn native_scorer_corner_cell() {
        let mut s = NativeScorer;
        let mut occ = vec![0.0f32; 64];
        occ[0] = 1.0;
        let st = s.frag_stats(&occ, 1, 1, 4);
        assert_eq!(st[0].total_free, 63.0);
        assert_eq!(st[0].partial_cubes, 1.0);
        assert_eq!(st[0].stranded, 8.0);
        assert_eq!(st[0].thru, 48.0 - 3.0);
        assert_eq!(st[0].transitions, 3.0);
    }

    #[test]
    fn rank_prefers_fewer_cubes() {
        // 4×8×2 on an empty 4³-cube cluster: the HalveDouble fold fits one
        // cube, identity needs two — RFold must pick the fold.
        let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let vs = enumerate_variants(JobShape::new(4, 8, 2), 64);
        let plans: Vec<_> = vs
            .iter()
            .filter_map(|v| reconfig_place::place(&c, v, 1))
            .collect();
        assert!(plans.len() >= 2);
        let best = rank_plans(&c, &plans, &mut NativeScorer).unwrap();
        assert_eq!(plans[best].cubes.len(), 1, "fold into a single cube");
    }

    #[test]
    fn rank_single_plan_trivial() {
        let c = ClusterState::new(ClusterTopo::reconfigurable_4096(4));
        let v = Variant::identity(JobShape::new(2, 2, 2));
        let p = reconfig_place::place(&c, &v, 1).unwrap();
        assert_eq!(rank_plans(&c, &[p], &mut NativeScorer), Some(0));
        assert_eq!(rank_plans(&c, &[], &mut NativeScorer), None);
    }

    #[test]
    fn composite_matches_weights() {
        let st = FragStats {
            total_free: 0.0,
            partial_cubes: 2.0,
            stranded: 1.0,
            thru: 48.0,
            transitions: 4.0,
            empty_cubes: 0.0,
        };
        let comp = st.composite(1, 4, 0.0);
        assert_eq!(comp, 64.0 * 2.0 + 8.0 + (48.0 - 48.0) + 0.5 * 4.0);
    }
}
