//! Line-protocol TCP front end for the leader.
//!
//! Protocol (one command per line):
//! ```text
//! SUBMIT <a> <b> <c> <duration_s>   → OK <id> <state> | ERR <msg>
//! QUERY <id>                        → STATE <id> <state>
//! STATS                             → STATS {json}
//! QUIT                              → closes the connection
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use super::leader::{JobState, LeaderHandle, Submission};
use crate::shape::JobShape;

fn state_name(s: JobState) -> &'static str {
    match s {
        JobState::Queued => "QUEUED",
        JobState::Running => "RUNNING",
        JobState::Finished => "FINISHED",
        JobState::Rejected => "REJECTED",
    }
}

/// Handle one client connection (blocking).
pub fn handle_conn(stream: TcpStream, leader: LeaderHandle) -> std::io::Result<()> {
    let peer = stream.peer_addr().ok();
    let mut out = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let reply = dispatch(line.trim(), &leader);
        match reply {
            Some(r) => writeln!(out, "{r}")?,
            None => break, // QUIT
        }
    }
    let _ = peer; // quiet unused in release logs
    Ok(())
}

/// Parse and execute one command line; `None` means close.
pub fn dispatch(line: &str, leader: &LeaderHandle) -> Option<String> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["SUBMIT", a, b, c, dur] => {
            let parse = |s: &str| s.parse::<usize>().ok().filter(|&v| v >= 1);
            match (parse(a), parse(b), parse(c), dur.parse::<f64>().ok()) {
                (Some(a), Some(b), Some(c), Some(d)) if d > 0.0 => {
                    match leader.submit(Submission {
                        shape: JobShape::new(a, b, c),
                        duration: d,
                    }) {
                        Some((id, st)) => Some(format!("OK {id} {}", state_name(st))),
                        None => Some("ERR leader unavailable".into()),
                    }
                }
                _ => Some("ERR usage: SUBMIT <a> <b> <c> <duration_s>".into()),
            }
        }
        ["QUERY", id] => match id.parse::<u64>() {
            Ok(id) => match leader.query(id) {
                Some(st) => Some(format!("STATE {id} {}", state_name(st))),
                None => Some("ERR leader unavailable".into()),
            },
            Err(_) => Some("ERR bad id".into()),
        },
        ["STATS"] => match leader.stats() {
            Some(s) => Some(format!(
                "STATS {{\"submitted\":{},\"running\":{},\"queued\":{},\"finished\":{},\
                 \"rejected\":{},\"busy_xpus\":{},\"total_xpus\":{},\"ocs_reserved\":{}}}",
                s.submitted,
                s.running,
                s.queued,
                s.finished,
                s.rejected,
                s.busy_xpus,
                s.total_xpus,
                s.ocs_entries_reserved
            )),
            None => Some("ERR leader unavailable".into()),
        },
        ["QUIT"] => None,
        [] => Some(String::new()),
        _ => Some("ERR unknown command".into()),
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7070").
pub fn serve(addr: &str, leader: LeaderHandle) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("rfold leader listening on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        let leader = leader.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, leader);
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::Leader;
    use crate::placement::PolicyKind;
    use crate::topology::cluster::ClusterTopo;

    fn leader() -> (LeaderHandle, std::thread::JoinHandle<super::super::LeaderStats>) {
        Leader::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::RFold,
            1e-6,
        )
        .spawn()
    }

    #[test]
    fn dispatch_submit_and_query() {
        let (h, j) = leader();
        let r = dispatch("SUBMIT 4 4 4 10", &h).unwrap();
        assert!(r.starts_with("OK 0"), "{r}");
        let r = dispatch("QUERY 0", &h).unwrap();
        assert!(r.starts_with("STATE 0"), "{r}");
        let r = dispatch("STATS", &h).unwrap();
        assert!(r.contains("\"submitted\":1"), "{r}");
        assert!(dispatch("QUIT", &h).is_none());
        h.shutdown();
        j.join().unwrap();
    }

    #[test]
    fn dispatch_errors() {
        let (h, j) = leader();
        assert!(dispatch("SUBMIT 0 1 1 10", &h).unwrap().starts_with("ERR"));
        assert!(dispatch("SUBMIT x", &h).unwrap().starts_with("ERR"));
        assert!(dispatch("NOPE", &h).unwrap().starts_with("ERR"));
        assert!(dispatch("QUERY abc", &h).unwrap().starts_with("ERR"));
        h.shutdown();
        j.join().unwrap();
    }

    #[test]
    fn tcp_end_to_end() {
        let (h, j) = leader();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h2 = h.clone();
        std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            handle_conn(s, h2).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        writeln!(c, "SUBMIT 2 2 2 5").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK 0"), "{line}");
        writeln!(c, "QUIT").unwrap();
        h.shutdown();
        j.join().unwrap();
    }
}
