//! Line-protocol TCP front end for the leader.
//!
//! Protocol (one command per line):
//! ```text
//! SUBMIT <a> <b> <c> <duration_s>   → OK <id> <state> | ERR <msg>
//! QUERY <id>                        → STATE <id> <state>
//! STATS                             → STATS {json}
//! QUIT                              → closes the connection
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use super::leader::{JobState, LeaderHandle, Submission};
use crate::shape::JobShape;

fn state_name(s: JobState) -> &'static str {
    match s {
        JobState::Queued => "QUEUED",
        JobState::Running => "RUNNING",
        JobState::Finished => "FINISHED",
        JobState::Rejected => "REJECTED",
    }
}

/// Serve a line-oriented protocol on one connection (blocking):
/// `dispatch` maps each trimmed line to `Some(reply)` or `None` (close).
///
/// One bad line must not cost the whole connection: a non-UTF-8 line
/// (`InvalidData` — the bytes up to the newline are already consumed)
/// earns an `ERR` reply and the loop keeps serving. Genuine transport
/// errors (reset, broken pipe) end the connection gracefully instead of
/// propagating `Err` — important now that pooled sweep clients hold
/// long-lived connections next to interactive ones. Shared by the leader
/// front end here and the `coordinator::pool` worker daemon.
pub fn serve_lines(
    stream: TcpStream,
    mut dispatch: impl FnMut(&str) -> Option<String>,
) -> std::io::Result<()> {
    let mut out = stream.try_clone()?;
    let mut lines = BufReader::new(stream).lines();
    loop {
        let line = match lines.next() {
            None => break, // EOF
            Some(Ok(l)) => l,
            Some(Err(e)) if e.kind() == std::io::ErrorKind::InvalidData => {
                if writeln!(out, "ERR non-utf8 line").is_err() {
                    break;
                }
                continue;
            }
            Some(Err(_)) => break, // transport gone; nothing to salvage
        };
        match dispatch(line.trim()) {
            Some(r) => {
                if writeln!(out, "{r}").is_err() {
                    break;
                }
            }
            None => break, // QUIT
        }
    }
    Ok(())
}

/// Handle one client connection (blocking).
pub fn handle_conn(stream: TcpStream, leader: LeaderHandle) -> std::io::Result<()> {
    serve_lines(stream, |line| dispatch(line, &leader))
}

/// Parse and execute one command line; `None` means close.
pub fn dispatch(line: &str, leader: &LeaderHandle) -> Option<String> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["SUBMIT", a, b, c, dur] => {
            let parse = |s: &str| s.parse::<usize>().ok().filter(|&v| v >= 1);
            match (parse(a), parse(b), parse(c), dur.parse::<f64>().ok()) {
                (Some(a), Some(b), Some(c), Some(d)) if d > 0.0 => {
                    match leader.submit(Submission {
                        shape: JobShape::new(a, b, c),
                        duration: d,
                    }) {
                        Some((id, st)) => Some(format!("OK {id} {}", state_name(st))),
                        None => Some("ERR leader unavailable".into()),
                    }
                }
                _ => Some("ERR usage: SUBMIT <a> <b> <c> <duration_s>".into()),
            }
        }
        ["QUERY", id] => match id.parse::<u64>() {
            Ok(id) => match leader.query(id) {
                Some(st) => Some(format!("STATE {id} {}", state_name(st))),
                None => Some("ERR leader unavailable".into()),
            },
            Err(_) => Some("ERR bad id".into()),
        },
        ["STATS"] => match leader.stats() {
            Some(s) => Some(format!(
                "STATS {{\"submitted\":{},\"running\":{},\"queued\":{},\"finished\":{},\
                 \"rejected\":{},\"busy_xpus\":{},\"total_xpus\":{},\"ocs_reserved\":{}}}",
                s.submitted,
                s.running,
                s.queued,
                s.finished,
                s.rejected,
                s.busy_xpus,
                s.total_xpus,
                s.ocs_entries_reserved
            )),
            None => Some("ERR leader unavailable".into()),
        },
        ["QUIT"] => None,
        [] => Some(String::new()),
        _ => Some("ERR unknown command".into()),
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7070").
pub fn serve(addr: &str, leader: LeaderHandle) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("rfold leader listening on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        let leader = leader.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, leader);
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::Leader;
    use crate::placement::PolicyKind;
    use crate::topology::cluster::ClusterTopo;

    fn leader() -> (LeaderHandle, std::thread::JoinHandle<super::super::LeaderStats>) {
        Leader::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::RFold,
            1e-6,
        )
        .spawn()
    }

    #[test]
    fn dispatch_submit_and_query() {
        let (h, j) = leader();
        let r = dispatch("SUBMIT 4 4 4 10", &h).unwrap();
        assert!(r.starts_with("OK 0"), "{r}");
        let r = dispatch("QUERY 0", &h).unwrap();
        assert!(r.starts_with("STATE 0"), "{r}");
        let r = dispatch("STATS", &h).unwrap();
        assert!(r.contains("\"submitted\":1"), "{r}");
        assert!(dispatch("QUIT", &h).is_none());
        h.shutdown();
        j.join().unwrap();
    }

    #[test]
    fn dispatch_errors() {
        let (h, j) = leader();
        assert!(dispatch("SUBMIT 0 1 1 10", &h).unwrap().starts_with("ERR"));
        assert!(dispatch("SUBMIT x", &h).unwrap().starts_with("ERR"));
        assert!(dispatch("NOPE", &h).unwrap().starts_with("ERR"));
        assert!(dispatch("QUERY abc", &h).unwrap().starts_with("ERR"));
        h.shutdown();
        j.join().unwrap();
    }

    #[test]
    fn non_utf8_line_gets_err_and_connection_survives() {
        let (h, j) = leader();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h2 = h.clone();
        std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            handle_conn(s, h2).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        // Invalid UTF-8, then a valid command on the same connection.
        c.write_all(b"\xff\xfe garbage\n").unwrap();
        writeln!(c, "STATS").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "bad line must be rejected: {line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(
            line.starts_with("STATS"),
            "connection must keep serving after a bad line: {line}"
        );
        writeln!(c, "QUIT").unwrap();
        h.shutdown();
        j.join().unwrap();
    }

    #[test]
    fn tcp_end_to_end() {
        let (h, j) = leader();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h2 = h.clone();
        std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            handle_conn(s, h2).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        writeln!(c, "SUBMIT 2 2 2 5").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK 0"), "{line}");
        writeln!(c, "QUIT").unwrap();
        h.shutdown();
        j.join().unwrap();
    }
}
