//! Write-ahead arrival journal — the other half of crash-safe
//! `rfold serve` (snapshots bound *restart work*, the WAL bounds *data
//! loss* to zero).
//!
//! File form, one record per accepted `SUBMIT`, in acceptance order:
//!
//! ```text
//! RFOLD-WAL v1
//! J <fnv1a-64 of the payload, 16 hex digits> {job-json}
//! ...
//! ```
//!
//! Every record is appended **and fsynced before the daemon ACKs** the
//! submission, so an accepted job survives `kill -9` by construction.
//! Rejected and malformed submissions never reach the journal —
//! acceptance is the determinism boundary, and the WAL records exactly
//! the accepted trace.
//!
//! Recovery reads tolerate exactly one failure shape: a *torn final
//! record* (the crash landed mid-append, so the job was never ACKed and
//! losing it is correct). Any other damage — a corrupt interior record,
//! a missing or foreign header, an empty file — is a structured error,
//! never a panic: resuming past silent corruption would replay a
//! different trace than the one the daemon acknowledged.

use std::io::Write;

use crate::coordinator::pool;
use crate::trace::JobSpec;
use crate::util::json::Json;

/// Current journal format version; readers refuse other versions.
pub const WAL_VERSION: u64 = 1;

/// Magic header line (version included — the whole first line is fixed).
const MAGIC: &str = "RFOLD-WAL";

/// FNV-1a 64-bit checksum of one record payload. Same non-cryptographic
/// guard as the snapshot header: it catches tears and accidental edits,
/// the failure modes a crash-recovery file actually meets.
fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x100_0000_01b3;
    bytes
        .iter()
        .fold(OFFSET, |h, &b| (h ^ b as u64).wrapping_mul(PRIME))
}

fn header() -> String {
    format!("{MAGIC} v{WAL_VERSION}")
}

/// Append half: owns the journal file, writes one checksummed record per
/// accepted job, fsyncs before returning — `append` returning `Ok` *is*
/// the durability point the ACK may rely on.
pub struct WalWriter {
    file: std::fs::File,
    path: String,
}

impl WalWriter {
    /// Open `path` for appending. A missing or zero-length file gets the
    /// header written (and fsynced) first; an existing journal must lead
    /// with the expected header, so appending to a foreign or
    /// wrong-version file is refused up front.
    pub fn open(path: &str) -> Result<WalWriter, String> {
        let fresh = match std::fs::metadata(path) {
            Ok(m) => m.len() == 0,
            Err(_) => true,
        };
        if !fresh {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("wal: cannot read {path}: {e}"))?;
            let first = text.lines().next().unwrap_or("");
            if first != header() {
                return Err(format!(
                    "wal: {path} is not a '{}' journal (found '{first}')",
                    header()
                ));
            }
        }
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("wal: cannot open {path}: {e}"))?;
        if fresh {
            writeln!(file, "{}", header()).map_err(|e| format!("wal: {path}: {e}"))?;
            file.sync_data().map_err(|e| format!("wal: fsync {path}: {e}"))?;
        }
        Ok(WalWriter {
            file,
            path: path.to_string(),
        })
    }

    /// Journal one accepted job: record line, then fsync. Only after this
    /// returns `Ok` may the daemon ACK the submission.
    pub fn append(&mut self, job: &JobSpec) -> Result<(), String> {
        let payload = pool::job_json(job).to_string();
        let line = format!("J {:016x} {payload}\n", fnv1a(payload.as_bytes()));
        self.file
            .write_all(line.as_bytes())
            .map_err(|e| format!("wal: append to {}: {e}", self.path))?;
        self.file
            .sync_data()
            .map_err(|e| format!("wal: fsync {}: {e}", self.path))
    }
}

/// Result of reading a journal back.
pub struct WalReplay {
    /// Accepted jobs, in acceptance order.
    pub jobs: Vec<JobSpec>,
    /// `true` when a torn final record was dropped (crash mid-append —
    /// the job was never ACKed, so dropping it is lossless).
    pub torn: bool,
}

/// Parse a journal's full text. Structured errors for a missing/foreign
/// header, an unsupported version, an empty file, and any corrupt record
/// that is *not* the final one; the final record alone may be torn.
pub fn replay_text(text: &str) -> Result<WalReplay, String> {
    if text.is_empty() {
        return Err("wal: empty file (missing header)".to_string());
    }
    let lines: Vec<&str> = text.lines().collect();
    let first = lines[0];
    if first != header() {
        let mut parts = first.split_whitespace();
        if parts.next() != Some(MAGIC) {
            return Err(format!("wal: bad magic (expected '{} ...')", header()));
        }
        let ver = parts.next().unwrap_or("");
        return Err(format!(
            "wal: unsupported version '{ver}' (this build reads v{WAL_VERSION})"
        ));
    }
    let mut jobs = Vec::new();
    let mut torn = false;
    for (i, line) in lines.iter().enumerate().skip(1) {
        match parse_record(line) {
            Ok(job) => jobs.push(job),
            Err(e) => {
                if i == lines.len() - 1 {
                    // The crash landed mid-append: the record was never
                    // ACKed, so the tail is dropped, not an error.
                    torn = true;
                } else {
                    return Err(format!("wal: record {i}: {e}"));
                }
            }
        }
    }
    Ok(WalReplay { jobs, torn })
}

/// Read and [`replay_text`] a journal file.
pub fn replay(path: &str) -> Result<WalReplay, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("wal: cannot read {path}: {e}"))?;
    replay_text(&text)
}

fn parse_record(line: &str) -> Result<JobSpec, String> {
    let rest = line
        .strip_prefix("J ")
        .ok_or_else(|| format!("not a 'J' record: '{line}'"))?;
    let (sum, payload) = rest
        .split_once(' ')
        .ok_or("record missing payload".to_string())?;
    let sum = u64::from_str_radix(sum, 16).map_err(|_| format!("malformed checksum '{sum}'"))?;
    let actual = fnv1a(payload.as_bytes());
    if sum != actual {
        return Err(format!(
            "checksum mismatch (record {sum:016x}, payload {actual:016x})"
        ));
    }
    let j = Json::parse(payload).map_err(|e| format!("payload is not JSON: {e}"))?;
    pool::parse_job(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shape::JobShape;

    fn job(id: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            arrival,
            duration: 25.0,
            shape: JobShape::new(2, 2, 4),
            comm_frac: 0.3,
            priority: 1,
        }
    }

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("rfold_wal_{name}_{}.wal", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn append_then_replay_round_trips() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let mut w = WalWriter::open(&path).unwrap();
            for i in 0..5 {
                w.append(&job(i, i as f64 * 10.0)).unwrap();
            }
        }
        let r = replay(&path).unwrap();
        assert_eq!(r.jobs.len(), 5);
        assert!(!r.torn);
        assert_eq!(r.jobs[3], job(3, 30.0));
        // Reopening appends, never truncates.
        let mut w = WalWriter::open(&path).unwrap();
        w.append(&job(5, 50.0)).unwrap();
        drop(w);
        let r = replay(&path).unwrap();
        assert_eq!(r.jobs.len(), 6);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_final_record_is_dropped_not_fatal() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path).unwrap();
        w.append(&job(0, 0.0)).unwrap();
        w.append(&job(1, 10.0)).unwrap();
        drop(w);
        // Simulate a crash mid-append: chop the file mid-final-record.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 7]).unwrap();
        let r = replay(&path).unwrap();
        assert_eq!(r.jobs.len(), 1, "the torn record never ACKed; drop it");
        assert!(r.torn);
        // The writer can keep appending after a torn tail is *not*
        // auto-repaired here (recovery rewrites via replay+fresh WAL or
        // accepts the dangling bytes as a dead prefix of the next line) —
        // but opening it is still legal: the header is intact.
        assert!(WalWriter::open(&path).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn interior_corruption_and_bad_headers_are_structured_errors() {
        // Empty file: structured error, never a panic.
        let err = replay_text("").unwrap_err();
        assert!(err.contains("empty file"), "{err}");
        // Foreign file.
        let err = replay_text("TOTALLY-NOT-A-WAL v1\n").unwrap_err();
        assert!(err.contains("bad magic"), "{err}");
        // Wrong version.
        let err = replay_text("RFOLD-WAL v999\n").unwrap_err();
        assert!(err.contains("unsupported version"), "{err}");
        // A corrupt record with records after it is fatal (silent
        // mid-journal loss would replay a different trace than ACKed).
        let path = tmp("interior");
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path).unwrap();
        w.append(&job(0, 0.0)).unwrap();
        w.append(&job(1, 10.0)).unwrap();
        w.append(&job(2, 20.0)).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // Flip one checksum nibble of the middle record.
        let bad = if lines[2].as_bytes()[2] == b'0' {
            lines[2].replacen("J 0", "J 1", 1)
        } else {
            format!("J 0{}", &lines[2][4..])
        };
        let tampered = format!("{}\n{}\n{}\n{}\n", lines[0], lines[1], bad, lines[3]);
        let err = replay_text(&tampered).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
        // The same damage in the *final* record is a tolerated tear.
        let tail_tampered = format!("{}\n{}\n{}\n", lines[0], lines[1], bad);
        let r = replay_text(&tail_tampered).unwrap();
        assert_eq!(r.jobs.len(), 1);
        assert!(r.torn);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_refuses_foreign_files() {
        let path = tmp("foreign");
        std::fs::write(&path, "something else entirely\n").unwrap();
        let err = WalWriter::open(&path).unwrap_err();
        assert!(err.contains("not a"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
