//! Trace replay against a live leader: feeds a `trace::JobSpec` stream at
//! (scaled) real-time pace and waits for the cluster to drain.

use std::time::Duration;

use super::leader::{JobState, LeaderHandle, Submission};
use crate::trace::JobSpec;

/// Replay summary.
#[derive(Debug, Clone, Default)]
pub struct ReplayReport {
    pub submitted: usize,
    pub rejected: usize,
    pub finished: usize,
    pub wall_secs: f64,
}

/// Replay `trace` against `leader`, compressing simulated time by
/// `time_scale` (wall = sim × scale; the leader must be built with the
/// same scale for durations to line up).
pub fn replay(
    leader: &LeaderHandle,
    trace: &[JobSpec],
    time_scale: f64,
    quiet: bool,
) -> ReplayReport {
    let t0 = std::time::Instant::now();
    let mut report = ReplayReport::default();
    let mut ids = Vec::new();
    let mut prev_arrival = 0.0f64;
    for j in trace {
        let gap = (j.arrival - prev_arrival).max(0.0) * time_scale;
        prev_arrival = j.arrival;
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
        match leader.submit(Submission {
            shape: j.shape,
            duration: j.duration,
        }) {
            Some((id, JobState::Rejected)) => {
                report.rejected += 1;
                ids.push(id);
            }
            Some((id, _)) => ids.push(id),
            None => break,
        }
        report.submitted += 1;
        if !quiet && report.submitted % 64 == 0 {
            if let Some(s) = leader.stats() {
                eprintln!(
                    "replayed {}/{} running={} queued={} busy={}/{}",
                    report.submitted,
                    trace.len(),
                    s.running,
                    s.queued,
                    s.busy_xpus,
                    s.total_xpus
                );
            }
        }
    }
    // Drain: poll until nothing is running or queued.
    loop {
        match leader.stats() {
            Some(s) if s.running == 0 && s.queued == 0 => {
                report.finished = s.finished;
                break;
            }
            Some(_) => std::thread::sleep(Duration::from_millis(20)),
            None => break,
        }
    }
    report.wall_secs = t0.elapsed().as_secs_f64();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::leader::Leader;
    use crate::placement::PolicyKind;
    use crate::topology::cluster::ClusterTopo;
    use crate::trace::gen::{generate, TraceConfig};

    #[test]
    fn replay_small_trace() {
        let scale = 1e-6;
        let (h, j) = Leader::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::RFold,
            scale,
        )
        .spawn();
        let trace = generate(&TraceConfig {
            num_jobs: 25,
            ..Default::default()
        });
        let rep = replay(&h, &trace, scale, true);
        assert_eq!(rep.submitted, 25);
        assert_eq!(rep.finished + rep.rejected, 25);
        h.shutdown();
        j.join().unwrap();
    }
}
