//! Versioned, checksummed service snapshots — crash recovery for
//! `rfold serve`.
//!
//! A snapshot file is two lines:
//!
//! ```text
//! RFOLD-SNAPSHOT v1 <fnv1a-64 of the body, 16 hex digits>
//! {one-line JSON body}
//! ```
//!
//! The body carries the full service state: the engine's dynamic state
//! ([`Simulation::snapshot_state`]), the accepted-job ledger (the trace
//! the engine's indices point into), the configuration needed to rebuild
//! [`SimConfig`] (topology, policy registry key, modifier fingerprint),
//! and the admission counters. `rfold serve --restore PATH` resumes such
//! that completion rows are byte-identical to an uninterrupted run —
//! [`decode`] re-verifies the checksum and version before anything is
//! instantiated, so a truncated or hand-edited file fails loudly instead
//! of resuming a subtly different cluster.
//!
//! Wire-form reuse, not reinvention: jobs and topologies are encoded
//! with the pool protocol's [`pool::job_json`]/[`pool::topo_json`], so a
//! snapshot's job rows are the same bytes a worker would accept.

use std::collections::BTreeMap;

use crate::coordinator::pool;
use crate::placement::PolicyRegistry;
use crate::sim::{SimConfig, Simulation};
use crate::trace::scenarios::ModifierSet;
use crate::trace::JobSpec;
use crate::util::json::Json;

/// Current snapshot format version. Bump on any body-layout change;
/// [`decode`] refuses other versions rather than guessing.
pub const SNAPSHOT_VERSION: u64 = 1;

/// Magic prefix of the header line.
const MAGIC: &str = "RFOLD-SNAPSHOT";

/// FNV-1a 64-bit checksum of the body line. Not cryptographic — it
/// guards against truncation and accidental edits, the failure modes a
/// crash-recovery file actually meets.
fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x100_0000_01b3;
    bytes
        .iter()
        .fold(OFFSET, |h, &b| (h ^ b as u64).wrapping_mul(PRIME))
}

/// Everything a daemon needs to resume: decoded configuration, the
/// accepted-job ledger, the raw engine state, and the admission
/// counters.
pub struct ServiceSnapshot {
    /// Rebuilt configuration (always `drain: true` — service mode drains
    /// on request, not at a workload horizon).
    pub cfg: SimConfig,
    /// Accepted jobs in submission order — the trace whose indices the
    /// engine state refers to.
    pub jobs: Vec<JobSpec>,
    /// Engine state for [`Simulation::restore`].
    pub engine: Json,
    /// Admission-control queue cap the daemon ran with.
    pub queue_cap: usize,
    /// `SUBMIT`s seen (admitted + rejected, excluding protocol errors).
    pub submitted: usize,
    /// `SUBMIT`s accepted into the engine.
    pub admitted: usize,
    /// `SUBMIT`s refused by admission control.
    pub rejected: usize,
}

/// Serialize a running service's state to the two-line file form.
pub fn encode(sim: &Simulation, meta: &ServiceMeta) -> String {
    let mut service = BTreeMap::new();
    service.insert(
        "jobs".to_string(),
        Json::Arr(meta.jobs.iter().map(pool::job_json).collect()),
    );
    service.insert("topo".to_string(), pool::topo_json(meta.cfg.topo));
    service.insert(
        "policy".to_string(),
        Json::Str(meta.cfg.policy.key().to_string()),
    );
    service.insert(
        "mods".to_string(),
        Json::Str(meta.cfg.modifiers.fingerprint()),
    );
    service.insert("queue_cap".to_string(), Json::Num(meta.queue_cap as f64));
    service.insert("submitted".to_string(), Json::Num(meta.submitted as f64));
    service.insert("admitted".to_string(), Json::Num(meta.admitted as f64));
    service.insert("rejected".to_string(), Json::Num(meta.rejected as f64));
    let mut body = BTreeMap::new();
    body.insert("version".to_string(), Json::Num(SNAPSHOT_VERSION as f64));
    body.insert("engine".to_string(), sim.snapshot_state());
    body.insert("service".to_string(), Json::Obj(service));
    let body = Json::Obj(body).to_string();
    format!("{MAGIC} v{SNAPSHOT_VERSION} {:016x}\n{body}\n", fnv1a(body.as_bytes()))
}

/// The service-level half of a snapshot (everything but the live
/// engine), borrowed from the serve loop at snapshot time.
pub struct ServiceMeta<'a> {
    pub cfg: &'a SimConfig,
    pub jobs: &'a [JobSpec],
    pub queue_cap: usize,
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
}

/// Parse and verify the two-line file form. Checks magic, version, and
/// checksum before touching the body; resolves the policy against the
/// global registry and re-parses the modifier fingerprint, so the
/// returned [`SimConfig`] is exactly the one the daemon ran with.
pub fn decode(text: &str) -> Result<ServiceSnapshot, String> {
    let mut lines = text.lines();
    let header = lines.next().ok_or("snapshot: empty file")?;
    let body = lines.next().ok_or("snapshot: missing body line")?;
    let mut parts = header.split_whitespace();
    if parts.next() != Some(MAGIC) {
        return Err(format!("snapshot: bad magic (expected '{MAGIC} ...')"));
    }
    let version = parts.next().ok_or("snapshot: header missing version")?;
    if version != format!("v{SNAPSHOT_VERSION}") {
        return Err(format!(
            "snapshot: unsupported version '{version}' (this build reads v{SNAPSHOT_VERSION})"
        ));
    }
    let sum = parts.next().ok_or("snapshot: header missing checksum")?;
    let sum = u64::from_str_radix(sum, 16)
        .map_err(|_| format!("snapshot: malformed checksum '{sum}'"))?;
    let actual = fnv1a(body.as_bytes());
    if sum != actual {
        return Err(format!(
            "snapshot: checksum mismatch (header {sum:016x}, body {actual:016x}) — truncated or edited file"
        ));
    }
    let j = Json::parse(body).map_err(|e| format!("snapshot: body is not JSON: {e}"))?;
    let ver = j
        .get("version")
        .and_then(Json::as_f64)
        .ok_or("snapshot: body missing 'version'")?;
    if ver != SNAPSHOT_VERSION as f64 {
        return Err(format!("snapshot: body version {ver} != header v{SNAPSHOT_VERSION}"));
    }
    let engine = j.get("engine").ok_or("snapshot: body missing 'engine'")?.clone();
    let service = j.get("service").ok_or("snapshot: body missing 'service'")?;
    let topo = pool::parse_topo(
        service.get("topo").ok_or("snapshot: service missing 'topo'")?,
    )
    .map_err(|e| format!("snapshot: topo: {e}"))?;
    let policy_key = service
        .get("policy")
        .and_then(Json::as_str)
        .ok_or("snapshot: service missing 'policy'")?;
    let policy = PolicyRegistry::global()
        .resolve(policy_key)
        .ok_or_else(|| format!("snapshot: unknown policy '{policy_key}'"))?;
    let mods = service
        .get("mods")
        .and_then(Json::as_str)
        .ok_or("snapshot: service missing 'mods'")?;
    let modifiers =
        ModifierSet::parse(mods).map_err(|e| format!("snapshot: mods: {e}"))?;
    let num = |key: &str| -> Result<usize, String> {
        service
            .get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("snapshot: service missing '{key}'"))
    };
    let jobs = service
        .get("jobs")
        .and_then(Json::as_arr)
        .ok_or("snapshot: service missing 'jobs'")?
        .iter()
        .map(|job| pool::parse_job(job).map_err(|e| format!("snapshot: job: {e}")))
        .collect::<Result<Vec<_>, _>>()?;
    let mut cfg = SimConfig::new(topo, policy);
    cfg.drain = true;
    cfg.modifiers = modifiers;
    Ok(ServiceSnapshot {
        cfg,
        jobs,
        engine,
        queue_cap: num("queue_cap")?,
        submitted: num("submitted")?,
        admitted: num("admitted")?,
        rejected: num("rejected")?,
    })
}

/// Write a snapshot file (atomically enough for crash recovery: write
/// to `path.tmp`, then rename — a crash mid-write never clobbers the
/// previous good snapshot).
pub fn save(path: &str, sim: &Simulation, meta: &ServiceMeta) -> Result<(), String> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, encode(sim, meta))
        .map_err(|e| format!("snapshot: cannot write {tmp}: {e}"))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("snapshot: cannot rename {tmp} -> {path}: {e}"))
}

/// Read and [`decode`] a snapshot file.
pub fn load(path: &str) -> Result<ServiceSnapshot, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("snapshot: cannot read {path}: {e}"))?;
    decode(&text)
}

/// List the `*.snap` files directly under `dir`, sorted ascending by
/// file name. Auto-snapshots are named `auto-<zero-padded seq>.snap`, so
/// lexicographic order *is* age order; manual snapshots sort among them
/// harmlessly. Missing/unreadable directories list as empty.
pub fn list_snapshots(dir: &str) -> Vec<String> {
    let Ok(rd) = std::fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut out: Vec<String> = rd
        .flatten()
        .filter_map(|e| e.path().to_str().map(str::to_string))
        .filter(|p| p.ends_with(".snap"))
        .collect();
    out.sort();
    out
}

/// Load the newest *valid* snapshot. `path` may be a single file (loaded
/// directly) or a directory (candidates tried newest-first, skipping
/// corrupt ones with a note on stderr — an interrupted rotation must not
/// strand a recoverable service). `Ok(None)` only for a directory that
/// holds no `*.snap` files at all; a directory with only corrupt
/// snapshots is an error, because resuming fresh would silently drop
/// acknowledged state.
pub fn load_newest(path: &str) -> Result<Option<(ServiceSnapshot, String)>, String> {
    if !std::path::Path::new(path).is_dir() {
        return load(path).map(|s| Some((s, path.to_string())));
    }
    let candidates = list_snapshots(path);
    if candidates.is_empty() {
        return Ok(None);
    }
    let mut last_err = String::new();
    for cand in candidates.iter().rev() {
        match load(cand) {
            Ok(s) => return Ok(Some((s, cand.clone()))),
            Err(e) => {
                eprintln!("serve: skipping invalid snapshot {cand}: {e}");
                last_err = e;
            }
        }
    }
    Err(format!(
        "snapshot: no valid *.snap in {path} ({} candidate(s); last error: {last_err})",
        candidates.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::PolicyKind;
    use crate::shape::JobShape;
    use crate::topology::cluster::ClusterTopo;

    fn sample() -> (SimConfig, Vec<JobSpec>, Simulation) {
        let mut cfg = SimConfig::new(ClusterTopo::static_4096(), PolicyKind::FirstFit);
        cfg.drain = true;
        cfg.modifiers = ModifierSet::parse("preempt=priority,checkpoint=3s").unwrap();
        let jobs: Vec<JobSpec> = (0..4)
            .map(|id| JobSpec {
                id,
                arrival: id as f64 * 5.0,
                duration: 50.0,
                shape: JobShape::new(4, 4, 4),
                comm_frac: 0.2,
                priority: (id % 2) as u8,
            })
            .collect();
        let mut sim = Simulation::new(cfg);
        for idx in 0..jobs.len() {
            sim.advance_before(&jobs, jobs[idx].arrival);
            sim.submit(&jobs, idx);
        }
        (cfg, jobs, sim)
    }

    #[test]
    fn encode_decode_round_trips() {
        let (cfg, jobs, sim) = sample();
        let meta = ServiceMeta {
            cfg: &cfg,
            jobs: &jobs,
            queue_cap: 64,
            submitted: 6,
            admitted: 4,
            rejected: 2,
        };
        let text = encode(&sim, &meta);
        assert!(text.starts_with("RFOLD-SNAPSHOT v1 "));
        assert_eq!(text.lines().count(), 2);
        let snap = decode(&text).expect("round trip");
        assert_eq!(snap.jobs, jobs);
        assert_eq!(snap.queue_cap, 64);
        assert_eq!(snap.submitted, 6);
        assert_eq!(snap.admitted, 4);
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.cfg.modifiers, cfg.modifiers);
        assert_eq!(snap.cfg.policy.key(), cfg.policy.key());
        // The engine state restores into a working simulation.
        let restored = Simulation::restore(snap.cfg, &snap.engine).expect("restore");
        assert_eq!(restored.queue_depth() + restored.running_count(), 4);
    }

    #[test]
    fn load_newest_scans_directories_and_skips_corruption() {
        let (cfg, jobs, sim) = sample();
        let meta = ServiceMeta {
            cfg: &cfg,
            jobs: &jobs,
            queue_cap: 8,
            submitted: 4,
            admitted: 4,
            rejected: 0,
        };
        let dir = std::env::temp_dir().join(format!("rfold_snapdir_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let dir_s = dir.to_str().unwrap().to_string();
        // A directory with no snapshots is "nothing to restore", not an error.
        assert!(load_newest(&dir_s).unwrap().is_none());
        save(&format!("{dir_s}/auto-00000001.snap"), &sim, &meta).unwrap();
        save(&format!("{dir_s}/auto-00000002.snap"), &sim, &meta).unwrap();
        let (snap, picked) = load_newest(&dir_s).unwrap().unwrap();
        assert!(picked.ends_with("auto-00000002.snap"), "{picked}");
        assert_eq!(snap.jobs.len(), 4);
        // Corrupt the newest: the scan falls back to the older valid one.
        std::fs::write(format!("{dir_s}/auto-00000003.snap"), "garbage").unwrap();
        let (_, picked) = load_newest(&dir_s).unwrap().unwrap();
        assert!(picked.ends_with("auto-00000002.snap"), "{picked}");
        // Only corrupt snapshots: structured error, never a silent fresh start.
        std::fs::write(format!("{dir_s}/auto-00000001.snap"), "junk").unwrap();
        std::fs::write(format!("{dir_s}/auto-00000002.snap"), "junk").unwrap();
        let err = load_newest(&dir_s).unwrap_err();
        assert!(err.contains("no valid"), "{err}");
        // A plain file path loads directly.
        let file = format!("{dir_s}/manual.snap");
        save(&file, &sim, &meta).unwrap();
        assert!(load_newest(&file).unwrap().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn decode_rejects_corruption() {
        let (cfg, jobs, sim) = sample();
        let meta = ServiceMeta {
            cfg: &cfg,
            jobs: &jobs,
            queue_cap: 64,
            submitted: 4,
            admitted: 4,
            rejected: 0,
        };
        let good = encode(&sim, &meta);

        let err = decode("").unwrap_err();
        assert!(err.contains("empty"), "{err}");

        let err = decode("NOT-A-SNAPSHOT v1 00\n{}\n").unwrap_err();
        assert!(err.contains("magic"), "{err}");

        let wrong_ver = good.replacen("v1", "v999", 1);
        let err = decode(&wrong_ver).unwrap_err();
        assert!(err.contains("unsupported version"), "{err}");

        // Flip one body byte: the checksum must catch it.
        let mut lines: Vec<&str> = good.lines().collect();
        let tampered_body = lines[1].replacen("queue_cap\":64", "queue_cap\":65", 1);
        assert_ne!(tampered_body, lines[1], "tamper target must exist");
        lines[1] = &tampered_body;
        let err = decode(&format!("{}\n{}\n", lines[0], lines[1])).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");

        // Truncation (body line missing) fails before any parsing.
        let header_only = good.lines().next().unwrap();
        let err = decode(header_only).unwrap_err();
        assert!(err.contains("missing body"), "{err}");
    }
}
