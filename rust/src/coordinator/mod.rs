//! The cluster leader: RFold as a long-running coordinator process rather
//! than a batch simulator.
//!
//! * [`leader`] — the allocation event loop: FIFO admission queue,
//!   placement via any [`crate::placement::PolicyKind`], wall-clock job
//!   completions (with a time-scale knob so demos run fast), metrics.
//! * [`server`] — a line-protocol TCP front end (`SUBMIT`, `STATS`,
//!   `UTIL`, `QUIT`) for interactive use; std-thread based (tokio is not
//!   available in this offline environment — see DESIGN.md §4).
//! * [`replay`] — feeds a trace file to the leader in (scaled) real time.
//! * [`pool`] — the distributed sweep plane: `rfold worker` trial daemons
//!   plus the leader-side TCP pool executor behind `rfold sweep --pool`.
//! * [`serve`] — the always-on scheduling service: the deterministic
//!   virtual-clock engine behind `SUBMIT`/`STATUS`/`DRAIN`/`SNAPSHOT`
//!   line commands, plus the `rfold submit` trace-replay client.
//! * [`snapshot`] — versioned, checksummed serialization of a live
//!   service (`rfold serve --restore` resumes byte-identically).
//! * [`wal`] — the write-ahead arrival journal (`rfold serve --wal`):
//!   accepted submissions are fsynced before the ACK, so a `kill -9`
//!   loses zero acknowledged jobs.

pub mod leader;
pub mod pool;
pub mod replay;
pub mod serve;
pub mod server;
pub mod snapshot;
pub mod wal;

pub use leader::{Leader, LeaderHandle, LeaderStats};
