//! Distributed sweep fan-out: `rfold worker` daemons plus the leader-side
//! TCP [`PoolExecutor`] backend for `sim::sweep`.
//!
//! The leader streams (workload, cell, trial) work items to a pool of
//! workers over a line/JSON protocol (one request or reply per line,
//! `coordinator::server` style) and merges the results position-stably,
//! so `rfold sweep --pool host1:7171,host2:7171` emits rows byte-identical
//! to `--workers N` on one box.
//!
//! ```text
//! TRIAL {json}   → RESULT {json} | ERR <msg>
//! PING           → PONG
//! QUIT           → closes the connection
//! ```
//!
//! ## Wire format
//!
//! [`crate::util::json`] objects, one per line. Policies travel as their
//! canonical registry key and are reconstructed on the worker through
//! [`PolicyRegistry::global`] — the registry is the cross-process policy
//! namespace. Synthetic workloads travel as their scenario name (the
//! worker regenerates the trace from the seed); CSV workloads ship their
//! job list inline, so workers need no shared filesystem. Every `f64`
//! travels as its IEEE-754 bit pattern ([`Json::f64_bits`]), and seeds
//! and job ids — true 64-bit values — as decimal strings
//! ([`Json::u64_str`]): the sweep's determinism contract is
//! *byte*-identical rows for any backend, which a decimal float
//! rendering cannot guarantee. Small counts (shape dims, job totals)
//! ride as plain JSON numbers, validated strictly on decode.
//!
//! ## Fault tolerance
//!
//! A connection that dies mid-item pushes the item back onto a shared
//! retry queue for the surviving workers; an item rejected by every
//! worker (`ERR` replies), or left over after all connections are gone,
//! is simulated by the leader itself. The grid therefore always
//! completes, and always with the exact bytes a local run would produce.
//!
//! The pool also *self-heals*: each host carries a circuit breaker
//! ([`BREAKER_STRIKES`] consecutive failures open it, with exponential
//! cool-off capped at [`BREAKER_MAX_BACKOFF`]), and a host whose breaker
//! is open is probed with background `PING` heartbeats — a `PONG` closes
//! the breaker and the host's connections re-join the grid, so a worker
//! that restarts mid-sweep gets its capacity back instead of being
//! written off. Health only moves *where* an item runs, never its bytes.
//! Per-worker and per-host statistics are reported on stderr only (see
//! `metrics::report::print_pool_telemetry`).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::report;
use crate::placement::{PolicyHandle, PolicyRegistry};
use crate::shape::JobShape;
use crate::sim::engine::{JobOutcome, RunResult};
use crate::sim::sweep::{self, TrialExecutor, TrialOutput, WorkItem};
use crate::topology::cluster::ClusterTopo;
use crate::topology::{CubeGrid, P3};
use crate::trace::scenarios::{ModifierSet, Scenario, Workload};
use crate::trace::JobSpec;
use crate::util::json::Json;
use crate::util::stats::WeightedCdf;

// ---------------------------------------------------------------------------
// Wire codec
// ---------------------------------------------------------------------------

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn need<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn need_u64(j: &Json, key: &str) -> Result<u64, String> {
    need(j, key)?
        .as_u64_str()
        .ok_or_else(|| format!("field '{key}' is not a u64 string"))
}

fn need_f64_bits(j: &Json, key: &str) -> Result<f64, String> {
    need(j, key)?
        .as_f64_bits()
        .ok_or_else(|| format!("field '{key}' is not an f64 bit pattern"))
}

/// Strict integer read: `Json::as_usize` is a saturating f64 cast (NaN
/// and negatives → 0, huge → `usize::MAX`), which would let a corrupt
/// peer smuggle wrong counts into rows instead of tripping the ERR path
/// that routes the item to retry/fallback.
fn strict_usize(v: &Json) -> Option<usize> {
    let n = v.as_f64()?;
    // f64 integers are exact only up to 2^53; anything larger (or
    // fractional, or negative) is a malformed wire value.
    (n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n <= (1u64 << 53) as f64)
        .then(|| n as usize)
}

fn need_usize(j: &Json, key: &str) -> Result<usize, String> {
    strict_usize(need(j, key)?)
        .ok_or_else(|| format!("field '{key}' is not a non-negative integer"))
}

fn need_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    need(j, key)?
        .as_str()
        .ok_or_else(|| format!("field '{key}' is not a string"))
}

/// Wire form of a topology — shared by the pool protocol and the service
/// snapshot envelope (`coordinator::snapshot`).
pub fn topo_json(topo: ClusterTopo) -> Json {
    match topo {
        ClusterTopo::Static { ext } => obj(vec![
            ("kind", Json::Str("static".into())),
            (
                "ext",
                Json::Arr(ext.0.iter().map(|&v| Json::Num(v as f64)).collect()),
            ),
        ]),
        ClusterTopo::Reconfigurable { grid } => obj(vec![
            ("kind", Json::Str("ocs".into())),
            (
                "dims",
                Json::Arr(grid.dims.0.iter().map(|&v| Json::Num(v as f64)).collect()),
            ),
            ("n", Json::Num(grid.n as f64)),
        ]),
    }
}

/// Decode [`topo_json`] output; structured errors, never a panic.
pub fn parse_topo(j: &Json) -> Result<ClusterTopo, String> {
    // Geometry values must be >= 1: a zero extent/dim/side would panic
    // downstream constructors (`JobShape::new`, grid math) on the worker
    // thread instead of producing the contractual `ERR` reply.
    let triple = |key: &str| -> Result<P3, String> {
        let arr = need(j, key)?
            .as_arr()
            .filter(|a| a.len() == 3)
            .ok_or_else(|| format!("field '{key}' is not a 3-array"))?;
        let mut out = [0usize; 3];
        for (o, v) in out.iter_mut().zip(arr) {
            *o = strict_usize(v)
                .filter(|&d| d >= 1)
                .ok_or_else(|| format!("field '{key}' holds a non-positive dim"))?;
        }
        Ok(P3(out))
    };
    match need_str(j, "kind")? {
        "static" => Ok(ClusterTopo::Static { ext: triple("ext")? }),
        "ocs" => Ok(ClusterTopo::Reconfigurable {
            grid: CubeGrid {
                dims: triple("dims")?,
                n: need_usize(j, "n").and_then(|n| {
                    if n >= 1 {
                        Ok(n)
                    } else {
                        Err("field 'n' must be >= 1".to_string())
                    }
                })?,
            },
        }),
        k => Err(format!("unknown topology kind '{k}'")),
    }
}

/// Wire form of one job: the compact 7/8-array also accepted by
/// `SUBMIT` in service mode and stored in snapshot envelopes.
pub fn job_json(j: &JobSpec) -> Json {
    let d = j.shape.dims();
    let mut a = vec![
        Json::u64_str(j.id),
        Json::f64_bits(j.arrival),
        Json::f64_bits(j.duration),
        Json::Num(d.0[0] as f64),
        Json::Num(d.0[1] as f64),
        Json::Num(d.0[2] as f64),
        Json::f64_bits(j.comm_frac),
    ];
    // Priority rides as an optional eighth element: the default class
    // encodes exactly the legacy 7-array, so priority-free traces keep
    // the wire bytes older workers already accept.
    if j.priority != 0 {
        a.push(Json::Num(j.priority as f64));
    }
    Json::Arr(a)
}

/// Decode [`job_json`] output; structured errors, never a panic.
pub fn parse_job(j: &Json) -> Result<JobSpec, String> {
    let a = j
        .as_arr()
        .filter(|a| a.len() == 7 || a.len() == 8)
        .ok_or("job is not a 7- or 8-array")?;
    // `JobShape::new` asserts dims >= 1, which would panic the worker's
    // connection thread; reject bad dims as a decode error instead.
    let dim = |i: usize| {
        strict_usize(&a[i])
            .filter(|&d| d >= 1)
            .ok_or_else(|| format!("job dim {i} not a positive integer"))
    };
    let priority = match a.get(7) {
        None => 0,
        Some(v) => strict_usize(v)
            .filter(|&p| p <= u8::MAX as usize)
            .ok_or("job priority not in 0..=255")? as u8,
    };
    Ok(JobSpec {
        id: a[0].as_u64_str().ok_or("job id not a u64 string")?,
        arrival: a[1].as_f64_bits().ok_or("job arrival not f64 bits")?,
        duration: a[2].as_f64_bits().ok_or("job duration not f64 bits")?,
        shape: JobShape::new(dim(3)?, dim(4)?, dim(5)?),
        comm_frac: a[6].as_f64_bits().ok_or("job comm_frac not f64 bits")?,
        priority,
    })
}

fn workload_json(w: &Workload) -> Json {
    match w {
        Workload::Synthetic(sc) => obj(vec![
            ("kind", Json::Str("synthetic".into())),
            ("scenario", Json::Str(sc.name().into())),
        ]),
        Workload::Csv { name, jobs, .. } => obj(vec![
            ("kind", Json::Str("csv".into())),
            ("name", Json::Str(name.clone())),
            ("trace", Json::Arr(jobs.iter().map(job_json).collect())),
        ]),
    }
}

/// Delta form of [`workload_json`] (`--pool-delta`): a CSV job list whose
/// content hash is already in `sent` travels as a `csv-ref` — name plus
/// FNV-1a content hash — instead of the full inline list; the first
/// occurrence records the hash and ships inline as usual. The receiving
/// connection resolves refs against the traces it decoded earlier
/// ([`CsvCache`]), so a grid of many trials over one recorded trace pays
/// the job-list bytes once per connection, not once per trial. Synthetic
/// workloads are untouched (they already travel as a name).
fn workload_json_delta(w: &Workload, sent: &mut HashSet<u64>) -> Json {
    if let Workload::Csv {
        name, content_hash, ..
    } = w
    {
        if !sent.insert(*content_hash) {
            return obj(vec![
                ("kind", Json::Str("csv-ref".into())),
                ("name", Json::Str(name.clone())),
                ("hash", Json::u64_str(*content_hash)),
            ]);
        }
    }
    workload_json(w)
}

fn parse_workload(j: &Json) -> Result<Workload, String> {
    match need_str(j, "kind")? {
        "synthetic" => {
            let name = need_str(j, "scenario")?;
            Scenario::parse(name)
                .map(Workload::Synthetic)
                .ok_or_else(|| format!("unknown scenario '{name}'"))
        }
        "csv" => {
            let name = need_str(j, "name")?.to_string();
            let arr = need(j, "trace")?.as_arr().ok_or("trace is not an array")?;
            let jobs: Result<Vec<JobSpec>, String> = arr.iter().map(parse_job).collect();
            Ok(Workload::from_jobs(name, jobs?))
        }
        k => Err(format!("unknown workload kind '{k}'")),
    }
}

/// Serialize one work item for the wire. The cell label, run count and
/// base seed stay leader-side: a worker only needs what determines the
/// trial's bytes. Modifiers travel as their canonical fingerprint, and
/// only when non-empty — a modifier-free item's wire bytes are exactly
/// what older workers expect.
pub fn encode_work_item(item: &WorkItem) -> String {
    encode_item_with(item, workload_json(&item.cfg.workload))
}

/// [`encode_work_item`] with the `csv-ref` delta encoding: repeated CSV
/// job lists on one connection travel by content hash (`--pool-delta`).
/// `sent_csv` is the connection's sent-hash set — it must live as long as
/// the connection, and must start empty on a fresh one (the peer's
/// [`CsvCache`] is per-connection too).
pub fn encode_work_item_delta(item: &WorkItem, sent_csv: &mut HashSet<u64>) -> String {
    encode_item_with(item, workload_json_delta(&item.cfg.workload, sent_csv))
}

fn encode_item_with(item: &WorkItem, workload: Json) -> String {
    let mut pairs = vec![
        ("policy", Json::Str(item.cell.policy.key().into())),
        ("topo", topo_json(item.cell.topo)),
        ("workload", workload),
        ("jobs", Json::Num(item.cfg.jobs_per_run as f64)),
        ("seed", Json::u64_str(item.seed())),
        (
            "folds",
            Json::Arr(
                item.cfg
                    .fold_dims_enabled
                    .iter()
                    .map(|&b| Json::Bool(b))
                    .collect(),
            ),
        ),
    ];
    if !item.cfg.modifiers.is_empty() {
        pairs.push(("mods", Json::Str(item.cfg.modifiers.fingerprint())));
    }
    obj(pairs).to_string()
}

/// A decoded wire item: everything a worker needs to reproduce the
/// trial's bytes.
pub struct RemoteWorkItem {
    pub policy: PolicyHandle,
    pub topo: ClusterTopo,
    pub workload: Workload,
    pub jobs_per_run: usize,
    pub seed: u64,
    pub fold_dims: [bool; 3],
    /// The *base* modifier set — the worker mixes the wire seed in via
    /// [`ModifierSet::for_trial`], exactly as the leader would, so both
    /// sides derive the same per-trial fault stream by construction.
    pub mods: ModifierSet,
}

impl RemoteWorkItem {
    /// Simulate the item — the same code path as a leader-local
    /// [`WorkItem::run`], so the result is bit-identical.
    pub fn run(&self) -> RunResult {
        let trace = self.workload.trace(self.jobs_per_run, self.seed);
        sweep::run_trial_raw(
            self.policy,
            self.topo,
            &trace,
            self.fold_dims,
            self.mods.for_trial(self.seed),
        )
    }
}

/// Per-connection CSV trace cache for the `csv-ref` delta encoding:
/// content hash → the workload received inline earlier on the same
/// connection (clones share the `Arc<[JobSpec]>` job list). A fresh
/// connection starts empty, mirroring the leader's sent-hash set.
pub type CsvCache = HashMap<u64, Workload>;

/// Decode a `TRIAL` body with no connection cache: `csv-ref` items are
/// rejected (the stateless path — exactly what a pre-delta worker does).
pub fn decode_work_item(body: &str) -> Result<RemoteWorkItem, String> {
    decode_work_item_cached(body, &mut CsvCache::new())
}

/// Decode a `TRIAL` body. The policy is resolved through the global
/// registry — an unknown key means leader and worker binaries disagree,
/// reported as a wire error rather than a panic. An inline CSV workload
/// is recorded in `cache` under its content hash; a `csv-ref` workload
/// resolves against it, and a miss (leader bug, or a ref sent to a fresh
/// connection) is a wire error — the `ERR` reply routes the item to
/// another host or the leader fallback, never a silent wrong trace.
pub fn decode_work_item_cached(body: &str, cache: &mut CsvCache) -> Result<RemoteWorkItem, String> {
    let j = Json::parse(body).map_err(|e| format!("bad json: {e}"))?;
    let key = need_str(&j, "policy")?;
    let policy = PolicyRegistry::global().resolve(key).ok_or_else(|| {
        format!(
            "unknown policy '{key}' (worker knows: {})",
            PolicyRegistry::global().known_keys()
        )
    })?;
    let folds_arr = need(&j, "folds")?
        .as_arr()
        .filter(|a| a.len() == 3)
        .ok_or("folds is not a 3-array")?;
    let mut fold_dims = [true; 3];
    for (f, v) in fold_dims.iter_mut().zip(folds_arr) {
        *f = match v {
            Json::Bool(b) => *b,
            _ => return Err("folds holds a non-bool".into()),
        };
    }
    // Absent "mods" means a modifier-free item (the encoder omits the
    // field for the default set); a present fingerprint must parse, or
    // the item earns an ERR instead of silently simulating fault-free.
    let mods = match j.get("mods") {
        None => ModifierSet::default(),
        Some(v) => {
            let s = v.as_str().ok_or("field 'mods' is not a string")?;
            ModifierSet::parse(s).map_err(|e| format!("bad 'mods': {e}"))?
        }
    };
    let wj = need(&j, "workload")?;
    let workload = match need_str(wj, "kind")? {
        "csv-ref" => {
            let hash = need_u64(wj, "hash")?;
            cache
                .get(&hash)
                .cloned()
                .ok_or_else(|| format!("csv-ref {hash:016x}: trace not in connection cache"))?
        }
        _ => {
            let w = parse_workload(wj)?;
            if let Workload::Csv { content_hash, .. } = &w {
                cache.insert(*content_hash, w.clone());
            }
            w
        }
    };
    Ok(RemoteWorkItem {
        policy,
        topo: parse_topo(need(&j, "topo")?)?,
        workload,
        jobs_per_run: need_usize(&j, "jobs")?,
        seed: need_u64(&j, "seed")?,
        fold_dims,
        mods,
    })
}

/// Serialize a trial result. Only the run result travels: the leader
/// regenerates the trace (synthetic) or already holds it (CSV), so the
/// reply stays small.
pub fn encode_run_result(r: &RunResult) -> String {
    let outcomes: Vec<Json> = r
        .outcomes
        .iter()
        .map(|&(id, o)| match o {
            JobOutcome::Completed { start, finish } => Json::Arr(vec![
                Json::u64_str(id),
                Json::Str("c".into()),
                Json::f64_bits(start),
                Json::f64_bits(finish),
            ]),
            JobOutcome::Dropped => Json::Arr(vec![Json::u64_str(id), Json::Str("d".into())]),
            JobOutcome::NotScheduled => {
                Json::Arr(vec![Json::u64_str(id), Json::Str("n".into())])
            }
        })
        .collect();
    let util: Vec<Json> = r
        .utilization
        .samples()
        .iter()
        .map(|&(v, w)| Json::Arr(vec![Json::f64_bits(v), Json::f64_bits(w)]))
        .collect();
    let mut pairs = vec![
        ("outcomes", Json::Arr(outcomes)),
        ("util", Json::Arr(util)),
        ("scheduled", Json::Num(r.scheduled as f64)),
        ("dropped", Json::Num(r.dropped as f64)),
        ("makespan", Json::f64_bits(r.makespan)),
    ];
    // Disruption accounting travels only when something actually happened:
    // a knob-free (or merely fault-injected) result keeps the exact reply
    // bytes older workers produce and older leaders accept.
    if r.preemptions > 0 || r.wasted_work != 0.0 || r.migration_time != 0.0 {
        pairs.push((
            "preempt",
            obj(vec![
                ("count", Json::Num(r.preemptions as f64)),
                ("wasted", Json::f64_bits(r.wasted_work)),
                ("migration", Json::f64_bits(r.migration_time)),
                ("useful_util", Json::f64_bits(r.useful_util)),
            ]),
        ));
    }
    obj(pairs).to_string()
}

/// Decode a `RESULT` body. `policy` is the leader-side handle of the item
/// this result answers (the display name does not travel).
pub fn decode_run_result(body: &str, policy: PolicyHandle) -> Result<RunResult, String> {
    let j = Json::parse(body).map_err(|e| format!("bad json: {e}"))?;
    let mut outcomes = Vec::new();
    for o in need(&j, "outcomes")?.as_arr().ok_or("outcomes not an array")? {
        let a = o.as_arr().ok_or("outcome is not an array")?;
        if a.len() < 2 {
            return Err("outcome array too short".into());
        }
        let id = a[0].as_u64_str().ok_or("outcome id not a u64 string")?;
        let outcome = match (a[1].as_str(), a.len()) {
            (Some("c"), 4) => JobOutcome::Completed {
                start: a[2].as_f64_bits().ok_or("outcome start not f64 bits")?,
                finish: a[3].as_f64_bits().ok_or("outcome finish not f64 bits")?,
            },
            (Some("d"), 2) => JobOutcome::Dropped,
            (Some("n"), 2) => JobOutcome::NotScheduled,
            _ => return Err("malformed outcome".into()),
        };
        outcomes.push((id, outcome));
    }
    let mut samples = Vec::new();
    for s in need(&j, "util")?.as_arr().ok_or("util not an array")? {
        let a = s
            .as_arr()
            .filter(|a| a.len() == 2)
            .ok_or("util sample is not a 2-array")?;
        samples.push((
            a[0].as_f64_bits().ok_or("util value not f64 bits")?,
            a[1].as_f64_bits().ok_or("util weight not f64 bits")?,
        ));
    }
    let utilization = WeightedCdf::from_samples(samples);
    // An absent "preempt" object means nothing was disrupted; the engine
    // then defines `useful_util` as exactly the utilization mean, which
    // the bit-exact samples reproduce on this side of the wire.
    let (preemptions, wasted_work, migration_time, useful_util) = match j.get("preempt") {
        None => (0, 0.0, 0.0, utilization.mean()),
        Some(p) => (
            need_usize(p, "count")?,
            need_f64_bits(p, "wasted")?,
            need_f64_bits(p, "migration")?,
            need_f64_bits(p, "useful_util")?,
        ),
    };
    Ok(RunResult {
        policy: policy.name(),
        outcomes,
        utilization,
        scheduled: need_usize(&j, "scheduled")?,
        dropped: need_usize(&j, "dropped")?,
        makespan: need_f64_bits(&j, "makespan")?,
        preemptions,
        wasted_work,
        migration_time,
        useful_util,
    })
}

// ---------------------------------------------------------------------------
// Worker daemon
// ---------------------------------------------------------------------------

/// Execute one protocol line statelessly (`csv-ref` items are rejected);
/// `None` means close the connection. Kept for compatibility and tests —
/// the worker daemon serves connections through
/// [`worker_dispatch_cached`] so the delta encoding works.
pub fn worker_dispatch(line: &str) -> Option<String> {
    worker_dispatch_cached(line, &mut CsvCache::new())
}

/// Execute one protocol line against a per-connection [`CsvCache`];
/// `None` means close the connection.
pub fn worker_dispatch_cached(line: &str, cache: &mut CsvCache) -> Option<String> {
    if line.is_empty() {
        return Some(String::new());
    }
    if line == "QUIT" {
        return None;
    }
    if line == "PING" {
        return Some("PONG".into());
    }
    if let Some(body) = line.strip_prefix("TRIAL ") {
        return Some(match decode_work_item_cached(body, cache) {
            Ok(item) => format!("RESULT {}", encode_run_result(&item.run())),
            Err(e) => format!("ERR {e}"),
        });
    }
    Some("ERR unknown command".into())
}

/// Handle one leader connection through the shared line-serving loop
/// (`coordinator::server::serve_lines`): a non-UTF-8 line earns an `ERR`
/// reply and the connection keeps serving — a flaky peer must not take a
/// pool worker down; genuine I/O errors close the connection quietly.
/// The CSV trace cache lives exactly as long as the connection, matching
/// the leader's per-connection sent-hash set.
fn handle_worker_conn(stream: TcpStream) {
    let mut cache = CsvCache::new();
    let _ = super::server::serve_lines(stream, move |line: &str| {
        worker_dispatch_cached(line, &mut cache)
    });
}

/// Serve trials on an already-bound listener (blocking). Each connection
/// gets its own thread; trials within a connection run serially, so a
/// worker's parallelism is the number of leader connections it accepts.
pub fn serve_worker_on(listener: TcpListener) -> std::io::Result<()> {
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                std::thread::spawn(move || handle_worker_conn(s));
            }
            Err(e) => eprintln!("worker: accept failed: {e}"),
        }
    }
    Ok(())
}

/// Serve forever on `addr` — the `rfold worker --listen <addr>` daemon.
pub fn serve_worker(addr: &str) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("rfold worker listening on {}", listener.local_addr()?);
    serve_worker_on(listener)
}

/// Spawn a worker on an ephemeral local port, serving on a background
/// thread; returns the address to hand to a [`PoolExecutor`]. Used by
/// the distributed test suite and handy for in-process smoke checks.
pub fn spawn_worker() -> std::io::Result<SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        let _ = serve_worker_on(listener);
    });
    Ok(addr)
}

// ---------------------------------------------------------------------------
// Leader-side pool executor
// ---------------------------------------------------------------------------

/// Telemetry of one pool worker connection (stderr reporting only — never
/// part of any row).
#[derive(Clone, Debug)]
pub struct WorkerStats {
    pub addr: String,
    /// Items this connection completed.
    pub completed: usize,
    /// The TCP connection was established.
    pub connected: bool,
    /// The connection was abandoned (I/O error or repeated `ERR`s).
    pub died: bool,
}

/// Per-host circuit-breaker telemetry (stderr reporting only — never part
/// of any row). One entry per `--pool` address, shared by all of the
/// host's connections.
#[derive(Clone, Debug)]
pub struct HostStats {
    pub addr: String,
    /// Times the host's breaker opened: [`BREAKER_STRIKES`] consecutive
    /// communication failures, or a failed half-open probe.
    pub trips: u64,
    /// Times the breaker closed again — a half-open `PING` answered
    /// `PONG`, or a reconnect that went on to serve trials.
    pub recoveries: u64,
}

/// Aggregate telemetry of one [`PoolExecutor::execute`] call.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub workers: Vec<WorkerStats>,
    /// Per-host breaker trips/recoveries (one entry per pool address).
    pub hosts: Vec<HostStats>,
    /// Items re-queued after a connection failure.
    pub retried: usize,
    /// Items the leader simulated itself (all workers dead or rejecting).
    pub leader_fallback: usize,
}

/// How long the leader waits for a worker to accept a connection before
/// writing it off (per resolved address).
const POOL_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Default for how long the leader waits for one `RESULT` before
/// declaring the connection dead. Sized with a wide margin over the
/// slowest realistic trial: a wedged worker (SIGSTOP, silent partition)
/// must hang a few items for minutes, not the whole grid forever — the
/// timed-out items go back through the retry/fallback path like any
/// other failure. Grids whose single trial legitimately exceeds this
/// raise it via [`PoolExecutor::with_read_timeout`] (`--pool-timeout`).
pub const POOL_READ_TIMEOUT: Duration = Duration::from_secs(600);

/// Consecutive communication failures (connect refusals, deaths, dropped
/// connections) that trip a host's circuit breaker.
pub const BREAKER_STRIKES: u32 = 3;

/// First open-state cool-off; doubles on every consecutive trip up to
/// [`BREAKER_MAX_BACKOFF`]. Tests shrink it via
/// [`PoolExecutor::with_breaker_backoff`].
pub const BREAKER_BASE_BACKOFF: Duration = Duration::from_secs(1);

/// Cool-off growth cap (1s → 2s → 4s → … → 60s).
pub const BREAKER_MAX_BACKOFF: Duration = Duration::from_secs(60);

/// Reconnect attempts per connection slot, the initial connect included.
/// A transiently crashed worker gets picked back up through the breaker;
/// a permanently dead one stops costing probes after a few tries so its
/// leftovers reach the leader fallback instead of stalling the join.
const MAX_CONN_ATTEMPTS: usize = 4;

/// Failed half-open probes a single connection thread tolerates before it
/// gives up on the host for the rest of the grid.
const MAX_PROBE_FAILURES: usize = 2;

/// Circuit-breaker position for one host.
enum BreakerState {
    /// Healthy: connections proceed normally.
    Closed,
    /// Tripped: no connection attempts until `until`; then the first
    /// thread to ask becomes the half-open probe.
    Open { until: Instant },
    /// One probe is in flight; everyone else waits for its verdict.
    HalfOpen,
}

/// What a connection thread that wants to talk to a host should do now.
enum Gate {
    /// Breaker closed — connect and pull trials.
    Proceed,
    /// Breaker just moved open → half-open and elected *this* caller as
    /// the probe: send `PING`, report the verdict.
    Probe,
    /// Breaker open (or a sibling is probing): back off this long, then
    /// ask again.
    Wait(Duration),
}

/// Shared health of one worker host — the circuit breaker plus its
/// telemetry counters. All of a host's connections consult the same
/// instance (under a mutex), so strikes accumulate across siblings and a
/// single probe speaks for the whole host.
struct HostHealth {
    state: BreakerState,
    /// Consecutive failures since the last success.
    strikes: u32,
    /// Cool-off the *next* trip will impose (doubles per trip, capped).
    backoff: Duration,
    trips: u64,
    recoveries: u64,
}

impl HostHealth {
    fn new(base: Duration) -> HostHealth {
        HostHealth {
            state: BreakerState::Closed,
            strikes: 0,
            backoff: base,
            trips: 0,
            recoveries: 0,
        }
    }

    /// A connection served trials (or a probe got its `PONG`): reset the
    /// strike count and the backoff ladder, close the breaker. Counts a
    /// recovery if the breaker was open or half-open.
    fn on_success(&mut self, base: Duration) {
        self.strikes = 0;
        self.backoff = base;
        if !matches!(self.state, BreakerState::Closed) {
            self.recoveries += 1;
        }
        self.state = BreakerState::Closed;
    }

    /// A connection to this host failed (connect refusal, death, drop).
    /// Trips the breaker on the [`BREAKER_STRIKES`]th consecutive strike;
    /// a failure while half-open re-trips immediately (the probe spoke
    /// for the host).
    fn on_failure(&mut self, now: Instant) {
        self.strikes += 1;
        match self.state {
            BreakerState::Closed if self.strikes >= BREAKER_STRIKES => self.trip(now),
            BreakerState::HalfOpen => self.trip(now),
            _ => {}
        }
    }

    fn trip(&mut self, now: Instant) {
        self.trips += 1;
        self.state = BreakerState::Open {
            until: now + self.backoff,
        };
        self.backoff = (self.backoff * 2).min(BREAKER_MAX_BACKOFF);
    }

    /// Admission decision for a connection thread. Exactly one caller is
    /// handed [`Gate::Probe`] when an open breaker's cool-off expires —
    /// the transition to half-open happens here, under the caller's lock.
    fn gate(&mut self, now: Instant) -> Gate {
        match self.state {
            BreakerState::Closed => Gate::Proceed,
            BreakerState::HalfOpen => Gate::Wait(Duration::from_millis(50)),
            BreakerState::Open { until } => {
                if now >= until {
                    self.state = BreakerState::HalfOpen;
                    Gate::Probe
                } else {
                    Gate::Wait(until - now)
                }
            }
        }
    }
}

/// The half-open heartbeat: connect, send `PING`, require `PONG`. Cheap
/// (no trial state), bounded by [`POOL_CONNECT_TIMEOUT`] plus a short
/// read timeout, and safe to aim at any protocol-speaking worker.
fn probe_worker(addr: &str) -> bool {
    let Ok(stream) = connect_worker(addr) else {
        return false;
    };
    if stream
        .set_read_timeout(Some(POOL_CONNECT_TIMEOUT))
        .is_err()
    {
        return false;
    }
    let Ok(mut out) = stream.try_clone() else {
        return false;
    };
    if writeln!(out, "PING").is_err() {
        return false;
    }
    let mut line = String::new();
    let ok = matches!(BufReader::new(stream).read_line(&mut line), Ok(n) if n > 0)
        && line.trim() == "PONG";
    if ok {
        let _ = writeln!(out, "QUIT");
    }
    ok
}

/// The TCP-pool [`TrialExecutor`]: [`connections`](PoolExecutor::with_connections)
/// connections (and threads) per worker address, all pulling from the
/// same atomic cursor the local backend uses, with dead-connection retry
/// and leader-side fallback. Output is position-stable and bit-identical
/// to local execution.
pub struct PoolExecutor {
    addrs: Vec<String>,
    /// Connections opened per address. A worker serves each connection on
    /// its own thread with trials serialized per connection, so one
    /// connection occupies exactly one remote core — `--pool-connections`
    /// is how a multi-core worker box gets saturated without listing its
    /// address N times.
    connections: usize,
    /// Unanswered `TRIAL`s kept in flight per connection
    /// (`--pool-pipeline`; default 1 = strict request/reply).
    pipeline: usize,
    /// `--pool-delta`: send repeated CSV job lists as `csv-ref` content
    /// hashes after the first inline transfer on each connection. Off by
    /// default — the inline encoding is what pre-delta workers accept.
    csv_delta: bool,
    read_timeout: Duration,
    /// First breaker cool-off ([`BREAKER_BASE_BACKOFF`] by default;
    /// tests shrink it so half-open probes happen in milliseconds).
    breaker_base: Duration,
    stats: Mutex<PoolStats>,
}

/// Resolve and connect with [`POOL_CONNECT_TIMEOUT`] per address — a
/// plain `TcpStream::connect` can block for minutes on a silently
/// dropping network, stalling the whole pool start.
fn connect_worker(addr: &str) -> std::io::Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, POOL_CONNECT_TIMEOUT) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::NotFound, "address resolved to nothing")
    }))
}

impl PoolExecutor {
    /// `addrs` as `host:port` strings (e.g. from `--pool a:7171,b:7171`).
    pub fn new(addrs: Vec<String>) -> PoolExecutor {
        assert!(!addrs.is_empty(), "a pool needs at least one worker address");
        PoolExecutor {
            addrs,
            connections: 1,
            pipeline: 1,
            csv_delta: false,
            read_timeout: POOL_READ_TIMEOUT,
            breaker_base: BREAKER_BASE_BACKOFF,
            stats: Mutex::new(PoolStats::default()),
        }
    }

    /// Override the first breaker cool-off (doubles per trip up to
    /// [`BREAKER_MAX_BACKOFF`]). Zero is clamped to 1ms so an open
    /// breaker always yields the CPU before probing.
    pub fn with_breaker_backoff(mut self, base: Duration) -> PoolExecutor {
        self.breaker_base = base.max(Duration::from_millis(1));
        self
    }

    /// Enable the `csv-ref` delta encoding (the CLI's `--pool-delta`):
    /// after the first trial ships a CSV job list inline, later trials on
    /// the same connection reference it by content hash. Workers predating
    /// the encoding answer refs with `ERR`, so the item retries elsewhere
    /// or falls back to the leader — rows stay byte-identical either way,
    /// which is why this is opt-in rather than sniffed.
    pub fn with_csv_delta(mut self, on: bool) -> PoolExecutor {
        self.csv_delta = on;
        self
    }

    /// Keep `k` unanswered `TRIAL`s in flight per connection (the CLI's
    /// `--pool-pipeline`; default 1, 0 is clamped to 1). Workers process
    /// requests strictly in order, so replies pair with requests FIFO and
    /// rows stay byte-identical for any `k` — pipelining only hides the
    /// network round-trip between a reply and the next request, which
    /// dominates on grids of many short trials. Keep `k` modest (≤ a few
    /// dozen): every in-flight item must fit in the socket buffers, and a
    /// dying connection re-queues all of them at once.
    pub fn with_pipeline(mut self, k: usize) -> PoolExecutor {
        self.pipeline = k.max(1);
        self
    }

    /// Open `n` connections per worker host (the CLI's
    /// `--pool-connections`; default 1, 0 is clamped to 1). One
    /// connection ≙ one busy remote core, so this is the remote
    /// parallelism knob. Determinism is unaffected: connections are just
    /// more pullers on the same position-stable item stream.
    pub fn with_connections(mut self, n: usize) -> PoolExecutor {
        self.connections = n.max(1);
        self
    }

    /// Override the per-`RESULT` read timeout (the CLI's `--pool-timeout`)
    /// for grids whose single trial legitimately runs longer than the
    /// [`POOL_READ_TIMEOUT`] default. A zero duration disables the
    /// timeout entirely (wait forever).
    pub fn with_read_timeout(mut self, timeout: Duration) -> PoolExecutor {
        self.read_timeout = timeout;
        self
    }

    /// Parse a comma-separated `--pool` list.
    pub fn parse_pool(spec: &str) -> Vec<String> {
        spec.split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }

    /// Telemetry of the most recent [`PoolExecutor::execute`] call.
    pub fn stats(&self) -> PoolStats {
        self.stats.lock().unwrap().clone()
    }

    /// One connection slot's lifecycle: consult the host's circuit
    /// breaker, then drive a connection ([`PoolExecutor::drive_conn`])
    /// until the queue drains. On a death the breaker takes a strike and
    /// — while work this host could take remains — the slot reconnects
    /// through it, acting as the background `PING` heartbeat when
    /// elected as the half-open probe. Bounded by [`MAX_CONN_ATTEMPTS`]
    /// drive attempts and [`MAX_PROBE_FAILURES`] failed probes, so a
    /// permanently dead host hands its leftovers to the leader fallback
    /// instead of stalling the join. Returns completed
    /// `(item index, output)` pairs.
    #[allow(clippy::too_many_arguments)]
    fn run_conn(
        &self,
        conn: (&str, usize),
        items: &[WorkItem],
        next: &(dyn Fn(usize) -> Option<usize> + Sync),
        fail: &(dyn Fn(usize, usize, bool) + Sync),
        progress: &(dyn Fn(&WorkItem) + Sync),
        work_remains: &(dyn Fn(usize) -> bool + Sync),
        health: &Mutex<HostHealth>,
        stats: &mut WorkerStats,
    ) -> Vec<(usize, Arc<TrialOutput>)> {
        let (addr, host) = conn;
        let mut got = Vec::new();
        let mut probe_failures = 0usize;
        for attempt in 0..MAX_CONN_ATTEMPTS {
            // Breaker gate: wait out an open breaker (bailing once the
            // grid holds nothing this host could serve), probing when
            // elected.
            loop {
                if !work_remains(host) {
                    return got;
                }
                let g = health.lock().unwrap().gate(Instant::now());
                match g {
                    Gate::Proceed => break,
                    Gate::Probe => {
                        if probe_worker(addr) {
                            health.lock().unwrap().on_success(self.breaker_base);
                            eprintln!("pool: {addr}: probe PONG; breaker closed");
                        } else {
                            health.lock().unwrap().on_failure(Instant::now());
                            probe_failures += 1;
                            eprintln!("pool: {addr}: probe failed; breaker re-opened");
                            if probe_failures >= MAX_PROBE_FAILURES {
                                return got;
                            }
                        }
                    }
                    // Sleep in short slices so the thread notices the
                    // queue draining underneath it.
                    Gate::Wait(d) => {
                        std::thread::sleep(d.min(Duration::from_millis(200)));
                    }
                }
            }
            if attempt > 0 {
                // Fresh verdict for the new connection; `connected` and
                // `completed` keep accumulating across attempts.
                stats.died = false;
                eprintln!("pool: {addr}: reconnecting (attempt {})", attempt + 1);
            }
            let outs = self.drive_conn((addr, host), items, next, fail, progress, stats);
            got.extend(outs);
            if stats.died {
                let tripped = {
                    let mut h = health.lock().unwrap();
                    let before = h.trips;
                    h.on_failure(Instant::now());
                    h.trips > before
                };
                if tripped {
                    eprintln!(
                        "pool: {addr}: breaker opened after {BREAKER_STRIKES} consecutive failures"
                    );
                }
            } else {
                // Clean drain: the host answered everything it was
                // offered — reset the strike ladder and close the
                // breaker (a recovery, if it was open).
                health.lock().unwrap().on_success(self.breaker_base);
                return got;
            }
        }
        got
    }

    /// Drive one connection until the queue drains or the connection is
    /// abandoned. `conn` is (connect address, host index); `fail`'s third
    /// argument flags a deterministic remote rejection (`ERR` reply) as
    /// opposed to a transient connection death — rejections are recorded
    /// per *host*, so an item a host refused is never futilely re-sent to
    /// that host's sibling connections. Returns completed
    /// `(item index, output)` pairs.
    fn drive_conn(
        &self,
        conn: (&str, usize),
        items: &[WorkItem],
        next: &(dyn Fn(usize) -> Option<usize> + Sync),
        fail: &(dyn Fn(usize, usize, bool) + Sync),
        progress: &(dyn Fn(&WorkItem) + Sync),
        stats: &mut WorkerStats,
    ) -> Vec<(usize, Arc<TrialOutput>)> {
        let (addr, host) = conn;
        let mut got = Vec::new();
        let stream = match connect_worker(addr) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("pool: cannot connect to {addr}: {e}");
                stats.died = true;
                return got;
            }
        };
        stats.connected = true;
        // A read timeout turns a silently wedged worker into an ordinary
        // connection death (the pending item is failed and retried); the
        // timeout error surfaces through the `Err(_)` arm below. A zero
        // timeout means "wait forever" (`--pool-timeout 0`) — std rejects
        // `Some(ZERO)`, so it maps to `None`.
        let timeout = (!self.read_timeout.is_zero()).then_some(self.read_timeout);
        if let Err(e) = stream.set_read_timeout(timeout) {
            eprintln!("pool: {addr}: cannot set read timeout: {e}");
            stats.died = true;
            return got;
        }
        let mut out = match stream.try_clone() {
            Ok(o) => o,
            Err(e) => {
                eprintln!("pool: {addr}: clone failed: {e}");
                stats.died = true;
                return got;
            }
        };
        let mut reader = BufReader::new(stream);
        // Consecutive items the worker answered with ERR: a peer that
        // rejects everything (version skew, garbage speaker) is abandoned
        // rather than fed the whole grid one failure at a time.
        let mut consecutive_errs = 0usize;
        // Hashes of CSV job lists already shipped inline on *this*
        // connection (`--pool-delta`); the peer's decode cache has the
        // same per-connection lifetime by construction.
        let mut sent_csv: HashSet<u64> = HashSet::new();
        // Request window: indices written but not yet answered, oldest
        // first. The worker serializes trials per connection and replies
        // in request order, so reply k pairs with `inflight[0]` at the
        // time of the read — FIFO matching, no tagging needed. With
        // `--pool-pipeline 1` this degenerates to the strict
        // write-one/read-one loop (window never exceeds one item).
        let mut inflight: VecDeque<usize> = VecDeque::new();
        // When the connection dies, every unanswered in-flight item is
        // failed as a transient death (retryable anywhere) alongside the
        // item that triggered the failure.
        'conn: loop {
            while inflight.len() < self.pipeline {
                let Some(i) = next(host) else { break };
                let body = if self.csv_delta {
                    encode_work_item_delta(&items[i], &mut sent_csv)
                } else {
                    encode_work_item(&items[i])
                };
                if writeln!(out, "TRIAL {body}").is_err() {
                    fail(i, host, false);
                    for j in inflight.drain(..) {
                        fail(j, host, false);
                    }
                    stats.died = true;
                    break 'conn;
                }
                inflight.push_back(i);
            }
            let Some(i) = inflight.pop_front() else { break };
            let it = &items[i];
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => {
                    fail(i, host, false);
                    for j in inflight.drain(..) {
                        fail(j, host, false);
                    }
                    stats.died = true;
                    break;
                }
                Ok(_) => {}
            }
            let line = line.trim();
            if let Some(body) = line.strip_prefix("RESULT ") {
                match decode_run_result(body, it.cell.policy) {
                    Ok(result) => {
                        consecutive_errs = 0;
                        let trace =
                            it.cfg.workload.trace(it.cfg.jobs_per_run, it.seed());
                        got.push((i, Arc::new(TrialOutput { result, trace })));
                        stats.completed += 1;
                        progress(it);
                    }
                    Err(e) => {
                        eprintln!("pool: {addr}: undecodable RESULT ({e}); dropping connection");
                        fail(i, host, false);
                        for j in inflight.drain(..) {
                            fail(j, host, false);
                        }
                        stats.died = true;
                        break;
                    }
                }
            } else {
                // ERR (or anything else): the connection still speaks the
                // protocol, so keep it — unless it keeps failing.
                eprintln!("pool: {addr}: item {i} failed remotely: {line}");
                fail(i, host, true);
                consecutive_errs += 1;
                if consecutive_errs >= 3 {
                    eprintln!("pool: {addr}: 3 consecutive failures; dropping connection");
                    for j in inflight.drain(..) {
                        fail(j, host, false);
                    }
                    stats.died = true;
                    break;
                }
            }
        }
        if !stats.died {
            let _ = writeln!(out, "QUIT");
        }
        got
    }
}

impl TrialExecutor for PoolExecutor {
    fn name(&self) -> &str {
        "tcp-pool"
    }

    fn execute(&self, items: &[WorkItem]) -> Vec<Arc<TrialOutput>> {
        let n = items.len();
        // One pulling connection per (address, connection slot), round-
        // robin across hosts so retries visit every box before a host's
        // extra connections. `#k` labels keep per-connection telemetry
        // readable when a host appears more than once.
        let conns: Vec<(String, usize)> = (0..self.connections)
            .flat_map(|k| {
                self.addrs.iter().enumerate().map(move |(host, addr)| {
                    let label = if self.connections > 1 {
                        format!("{addr}#{k}")
                    } else {
                        addr.clone()
                    };
                    (label, host)
                })
            })
            .collect();
        let cursor = AtomicUsize::new(0);
        let retries: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        // Per-item deterministic rejections (`ERR`) and transient
        // connection deaths, counted separately — see `fail` below.
        let failures: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let deaths: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        // Items each *host* has failed (shared by the host's connections):
        // an item one connection ERR'd or died on is offered to *other
        // hosts*, never to a sibling connection of the same worker process
        // — a deterministic remote failure costs one attempt per host,
        // exactly as with one connection each.
        let host_failed: Vec<Mutex<HashSet<usize>>> =
            self.addrs.iter().map(|_| Mutex::new(HashSet::new())).collect();
        // Per-host circuit breakers, shared by each host's connections:
        // three consecutive strikes open a breaker, a half-open `PING`
        // probe (after exponential cool-off) closes it again.
        let health: Vec<Mutex<HostHealth>> = self
            .addrs
            .iter()
            .map(|_| Mutex::new(HostHealth::new(self.breaker_base)))
            .collect();
        let retried = AtomicUsize::new(0);

        // Retried items first (they are blocking a grid slot), then the
        // cursor — the same item-granularity stream the local backend
        // drains. A connection never re-pulls an item its host already
        // failed: such items wait in the queue for a different host, or
        // for the post-join leader fallback.
        let next = |host: usize| -> Option<usize> {
            let exclude = host_failed[host].lock().unwrap();
            let mut queue = retries.lock().unwrap();
            if let Some(pos) = queue.iter().rposition(|i| !exclude.contains(i)) {
                return Some(queue.remove(pos));
            }
            drop(queue);
            drop(exclude);
            let c = cursor.fetch_add(1, Ordering::Relaxed);
            (c < n).then_some(c)
        };
        // `rejected` distinguishes a deterministic remote refusal (an
        // `ERR` reply — the host will refuse it again, so exclude the
        // host and burn one of the item's per-host rejection credits; an
        // item every host rejected goes unqueued, straight to leader
        // fallback) from a transient connection death/timeout, which may
        // retry on any surviving connection *including the same host's
        // siblings* — but on its own bounded budget of `host_count + 1`
        // attempts: a single-host pool still gets a sibling retry after a
        // blip, while a trial that reliably kills or wedges workers burns
        // at most hosts+1 connections (not hosts × --pool-connections
        // read-timeouts) before its unfilled slot reaches leader fallback.
        let host_count = self.addrs.len();
        let fail = |i: usize, host: usize, rejected: bool| {
            if rejected {
                host_failed[host].lock().unwrap().insert(i);
                let f = failures[i].fetch_add(1, Ordering::Relaxed) + 1;
                if f >= host_count {
                    return;
                }
            } else {
                let d = deaths[i].fetch_add(1, Ordering::Relaxed) + 1;
                if d > host_count {
                    return;
                }
            }
            retried.fetch_add(1, Ordering::Relaxed);
            retries.lock().unwrap().push(i);
        };

        // Whether the grid still holds work this host could take — what a
        // connection waiting on an open breaker checks before sleeping
        // again, so threads stop waiting (and probing) the moment the
        // queue drains. Items in flight on *other* connections are
        // invisible here by design: if one fails later it re-queues, and
        // surviving connections or the leader fallback absorb it.
        let work_remains = |host: usize| -> bool {
            if cursor.load(Ordering::Relaxed) < n {
                return true;
            }
            let exclude = host_failed[host].lock().unwrap();
            retries.lock().unwrap().iter().any(|i| !exclude.contains(i))
        };

        // The same every-tenth-trial liveness reporting the local backend
        // gives: a healthy multi-hour pooled grid must be distinguishable
        // from a wedged one before any timeout fires. Stderr only.
        let progress = sweep::progress_reporter("pool", n);

        let mut slots: Vec<Option<Arc<TrialOutput>>> = vec![None; n];
        let mut worker_stats: Vec<WorkerStats> = Vec::with_capacity(conns.len());
        let next_ref = &next;
        let fail_ref = &fail;
        let progress_ref = &progress;
        let work_remains_ref = &work_remains;
        let health_ref = &health;
        std::thread::scope(|scope| {
            let handles: Vec<_> = conns
                .iter()
                .map(|(label, host)| {
                    let host = *host;
                    scope.spawn(move || {
                        let mut stats = WorkerStats {
                            addr: label.clone(),
                            completed: 0,
                            connected: false,
                            died: false,
                        };
                        let got = self.run_conn(
                            (&self.addrs[host], host),
                            items,
                            next_ref,
                            fail_ref,
                            progress_ref,
                            work_remains_ref,
                            &health_ref[host],
                            &mut stats,
                        );
                        (stats, got)
                    })
                })
                .collect();
            for h in handles {
                let (stats, got) = h.join().expect("pool connection thread panicked");
                worker_stats.push(stats);
                for (i, out) in got {
                    slots[i] = Some(out);
                }
            }
        });

        // Leftovers — items every worker rejected, items stranded on the
        // retry queue after the last connection died, items never
        // dispatched because no connection survived long enough — are
        // exactly the unfilled slots, whatever bookkeeping path got them
        // there. The leader computes them itself through the in-process
        // executor (all cores, same determinism), so a fully dead pool
        // degrades to local parallel execution — the grid always
        // completes.
        let rest: Vec<usize> = (0..n).filter(|&i| slots[i].is_none()).collect();
        let fallback = rest.len();
        if fallback > 0 {
            eprintln!("pool: leader simulating {fallback} item(s) no worker could serve");
            let todo: Vec<WorkItem> = rest.iter().map(|&i| items[i].clone()).collect();
            let outs = sweep::LocalExecutor::new(0).execute(&todo);
            for (&i, out) in rest.iter().zip(outs) {
                slots[i] = Some(out);
            }
        }

        let host_stats: Vec<HostStats> = self
            .addrs
            .iter()
            .zip(&health)
            .map(|(addr, h)| {
                let h = h.lock().unwrap();
                HostStats {
                    addr: addr.clone(),
                    trips: h.trips,
                    recoveries: h.recoveries,
                }
            })
            .collect();
        let stats = PoolStats {
            workers: worker_stats,
            hosts: host_stats,
            retried: retried.load(Ordering::Relaxed),
            leader_fallback: fallback,
        };
        report::print_pool_telemetry(&stats);
        *self.stats.lock().unwrap() = stats;

        slots
            .into_iter()
            .map(|s| s.expect("every pool slot is filled by a worker or the leader"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::builtins;
    use crate::sim::experiments::Cell;
    use crate::sim::sweep::SweepConfig;
    use crate::trace::gen::{generate, TraceConfig};

    fn item(workload: Workload) -> WorkItem {
        let mut cfg = SweepConfig::new(3, 14, 9);
        cfg.workload = workload;
        WorkItem {
            cell: Cell {
                policy: builtins::RFOLD,
                topo: ClusterTopo::reconfigurable_4096(4),
                label: "RFold (4^3)",
            },
            cfg,
            trial: 2,
        }
    }

    #[test]
    fn work_item_roundtrips_synthetic() {
        let it = item(Workload::Synthetic(Scenario::CommHeavy));
        let decoded = decode_work_item(&encode_work_item(&it)).unwrap();
        assert_eq!(decoded.policy, it.cell.policy);
        assert_eq!(decoded.topo, it.cell.topo);
        assert_eq!(decoded.seed, it.seed());
        assert_eq!(decoded.jobs_per_run, 14);
        assert_eq!(decoded.fold_dims, [true; 3]);
        assert_eq!(decoded.workload.cache_key(), it.cfg.workload.cache_key());
    }

    #[test]
    fn work_item_roundtrips_csv_jobs_exactly() {
        let jobs = generate(&TraceConfig {
            num_jobs: 6,
            seed: 4,
            ..Default::default()
        });
        let it = item(Workload::from_jobs("wire-test".into(), jobs.clone()));
        let decoded = decode_work_item(&encode_work_item(&it)).unwrap();
        assert_eq!(
            &decoded.workload.trace(0, 0)[..],
            &jobs[..],
            "bit-exact job round trip"
        );
        assert_eq!(decoded.workload.cache_key(), it.cfg.workload.cache_key());
    }

    #[test]
    fn run_result_roundtrips_bit_exactly() {
        let it = item(Workload::Synthetic(Scenario::PaperDefault));
        let local = it.run();
        let wire = encode_run_result(&local.result);
        let back = decode_run_result(&wire, it.cell.policy).unwrap();
        assert_eq!(back.policy, local.result.policy);
        assert_eq!(back.outcomes, local.result.outcomes);
        assert_eq!(back.scheduled, local.result.scheduled);
        assert_eq!(back.dropped, local.result.dropped);
        assert_eq!(back.makespan.to_bits(), local.result.makespan.to_bits());
        assert_eq!(
            back.utilization.samples(),
            local.result.utilization.samples()
        );
        // A disruption-free reply omits the "preempt" object and decodes
        // to the engine's definition: useful_util == utilization mean.
        assert!(!wire.contains("\"preempt\""));
        assert_eq!(back.preemptions, 0);
        assert_eq!(back.wasted_work, 0.0);
        assert_eq!(back.migration_time, 0.0);
        assert_eq!(
            back.useful_util.to_bits(),
            back.utilization.mean().to_bits()
        );
    }

    #[test]
    fn disrupted_run_result_roundtrips_bit_exactly() {
        let it = item(Workload::Synthetic(Scenario::PaperDefault));
        let mut r = it.run().result;
        r.preemptions = 3;
        r.wasted_work = 8192.5;
        r.migration_time = 60.0;
        r.useful_util = 0.4321;
        let wire = encode_run_result(&r);
        assert!(wire.contains("\"preempt\""));
        let back = decode_run_result(&wire, it.cell.policy).unwrap();
        assert_eq!(back.preemptions, r.preemptions);
        assert_eq!(back.wasted_work.to_bits(), r.wasted_work.to_bits());
        assert_eq!(back.migration_time.to_bits(), r.migration_time.to_bits());
        assert_eq!(back.useful_util.to_bits(), r.useful_util.to_bits());
    }

    #[test]
    fn priority_rides_as_optional_eighth_job_field() {
        let mut jobs = generate(&TraceConfig {
            num_jobs: 2,
            seed: 9,
            ..Default::default()
        });
        jobs[1].priority = 3;
        // The default class keeps the legacy 7-element encoding older
        // workers accept; a real priority widens the array to 8.
        let legacy = job_json(&jobs[0]);
        assert_eq!(legacy.as_arr().unwrap().len(), 7);
        let wide = job_json(&jobs[1]);
        assert_eq!(wide.as_arr().unwrap().len(), 8);
        assert_eq!(parse_job(&legacy).unwrap(), jobs[0]);
        assert_eq!(parse_job(&wide).unwrap(), jobs[1]);
        // An out-of-range priority is a decode error, never a silent
        // truncation into a different scheduling class.
        let mut arr = wide.as_arr().unwrap().to_vec();
        arr[7] = Json::Num(300.0);
        assert!(parse_job(&Json::Arr(arr)).is_err());
    }

    #[test]
    fn remote_run_matches_local_run() {
        let it = item(Workload::Synthetic(Scenario::UniformSmall));
        let local = it.run();
        let remote = decode_work_item(&encode_work_item(&it)).unwrap().run();
        assert_eq!(
            encode_run_result(&local.result),
            encode_run_result(&remote),
            "worker-side execution must be bit-identical"
        );
    }

    #[test]
    fn dispatch_protocol_lines() {
        assert_eq!(worker_dispatch("PING"), Some("PONG".into()));
        assert_eq!(worker_dispatch("QUIT"), None);
        assert_eq!(worker_dispatch(""), Some(String::new()));
        assert!(worker_dispatch("NOPE").unwrap().starts_with("ERR"));
        assert!(worker_dispatch("TRIAL not-json").unwrap().starts_with("ERR"));
        let it = item(Workload::Synthetic(Scenario::PaperDefault));
        let reply = worker_dispatch(&format!("TRIAL {}", encode_work_item(&it))).unwrap();
        assert!(reply.starts_with("RESULT "), "{reply}");
    }

    #[test]
    fn work_item_roundtrips_modifiers() {
        let mut it = item(Workload::Synthetic(Scenario::PaperDefault));
        it.cfg.modifiers =
            ModifierSet::parse("failures=philly,ocs-latency=5s,stragglers=0.05").unwrap();
        let wire = encode_work_item(&it);
        let decoded = decode_work_item(&wire).unwrap();
        assert_eq!(decoded.mods, it.cfg.modifiers);
        // Worker-side execution mixes the same trial seed the leader
        // would, so modified trials stay bit-identical across the wire.
        let local = it.run();
        let remote = decoded.run();
        assert_eq!(
            encode_run_result(&local.result),
            encode_run_result(&remote),
            "modified trials must be bit-identical remotely"
        );
        // A modifier-free item omits the field: its wire bytes are what
        // older workers already accept.
        let plain = item(Workload::Synthetic(Scenario::PaperDefault));
        assert!(!encode_work_item(&plain).contains("\"mods\""));
        assert_eq!(
            decode_work_item(&encode_work_item(&plain)).unwrap().mods,
            ModifierSet::default()
        );
        // An unparseable fingerprint is a decode error (→ ERR reply), not
        // a silent fault-free simulation.
        let bad = wire.replace("philly", "weird-model");
        let err = decode_work_item(&bad).unwrap_err();
        assert!(err.contains("bad 'mods'"), "{err}");
    }

    #[test]
    fn csv_delta_refs_repeated_traces_by_hash() {
        let jobs = generate(&TraceConfig {
            num_jobs: 5,
            seed: 11,
            ..Default::default()
        });
        let it = item(Workload::from_jobs("delta-test".into(), jobs));
        let mut sent = HashSet::new();
        let first = encode_work_item_delta(&it, &mut sent);
        let second = encode_work_item_delta(&it, &mut sent);
        assert!(first.contains("\"trace\""), "first send ships inline");
        assert!(second.contains("csv-ref"), "repeat sends a reference");
        assert!(second.len() < first.len(), "the ref is the savings");
        // One connection-lifetime cache resolves the ref to the exact
        // trace the inline send delivered.
        let mut cache = CsvCache::new();
        let a = decode_work_item_cached(&first, &mut cache).unwrap();
        let b = decode_work_item_cached(&second, &mut cache).unwrap();
        assert_eq!(a.workload.cache_key(), b.workload.cache_key());
        assert_eq!(&a.workload.trace(0, 0)[..], &b.workload.trace(0, 0)[..]);
        // Synthetic workloads never delta-encode: same bytes every time.
        let sy = item(Workload::Synthetic(Scenario::PaperDefault));
        let mut sent2 = HashSet::new();
        assert_eq!(
            encode_work_item_delta(&sy, &mut sent2),
            encode_work_item(&sy)
        );
        assert_eq!(
            encode_work_item_delta(&sy, &mut sent2),
            encode_work_item(&sy)
        );
    }

    #[test]
    fn csv_ref_against_a_cold_cache_is_a_wire_error() {
        let jobs = generate(&TraceConfig {
            num_jobs: 4,
            seed: 12,
            ..Default::default()
        });
        let it = item(Workload::from_jobs("cold".into(), jobs));
        let mut sent = HashSet::new();
        let _inline = encode_work_item_delta(&it, &mut sent);
        let reference = encode_work_item_delta(&it, &mut sent);
        // The stateless decode path — effectively what a pre-delta worker
        // does — must reject the ref, not fabricate a trace.
        let err = decode_work_item(&reference).unwrap_err();
        assert!(err.contains("csv-ref"), "{err}");
        let reply = worker_dispatch(&format!("TRIAL {reference}")).unwrap();
        assert!(reply.starts_with("ERR"), "{reply}");
    }

    #[test]
    fn cached_dispatch_answers_refs_identically_to_inline() {
        let jobs = generate(&TraceConfig {
            num_jobs: 6,
            seed: 13,
            ..Default::default()
        });
        let it = item(Workload::from_jobs("conn".into(), jobs));
        let mut sent = HashSet::new();
        let inline_line = format!("TRIAL {}", encode_work_item_delta(&it, &mut sent));
        let ref_line = format!("TRIAL {}", encode_work_item_delta(&it, &mut sent));
        let mut cache = CsvCache::new();
        let r1 = worker_dispatch_cached(&inline_line, &mut cache).unwrap();
        let r2 = worker_dispatch_cached(&ref_line, &mut cache).unwrap();
        assert!(r1.starts_with("RESULT "), "{r1}");
        assert_eq!(r1, r2, "a ref trial must produce the inline trial's bytes");
    }

    #[test]
    fn unknown_policy_is_a_wire_error() {
        let it = item(Workload::Synthetic(Scenario::PaperDefault));
        let bad = encode_work_item(&it).replace("\"rfold\"", "\"no-such-policy\"");
        let err = decode_work_item(&bad).unwrap_err();
        assert!(err.contains("no-such-policy"), "{err}");
    }

    #[test]
    fn parse_pool_splits_and_trims() {
        assert_eq!(
            PoolExecutor::parse_pool(" a:1, b:2 ,,c:3 "),
            vec!["a:1".to_string(), "b:2".into(), "c:3".into()]
        );
        assert!(PoolExecutor::parse_pool(" , ").is_empty());
    }

    #[test]
    fn breaker_trips_after_strikes_and_recovers_via_probe() {
        // Pure state-machine walk with synthetic clocks — no sockets, no
        // sleeping: two strikes stay closed, the third opens the breaker,
        // exactly one caller is elected as the half-open probe, a failed
        // probe re-opens with doubled cool-off, a success recovers.
        let base = Duration::from_millis(10);
        let mut h = HostHealth::new(base);
        let t0 = Instant::now();
        assert!(matches!(h.gate(t0), Gate::Proceed));
        h.on_failure(t0);
        h.on_failure(t0);
        assert!(matches!(h.gate(t0), Gate::Proceed), "two strikes stay closed");
        h.on_failure(t0);
        assert_eq!(h.trips, 1, "third consecutive strike trips");
        match h.gate(t0) {
            Gate::Wait(d) => assert!(d <= base, "{d:?}"),
            _ => panic!("open breaker must wait"),
        }
        let expired = t0 + base;
        assert!(matches!(h.gate(expired), Gate::Probe), "first caller probes");
        assert!(
            matches!(h.gate(expired), Gate::Wait(_)),
            "siblings wait while the probe is in flight"
        );
        h.on_failure(expired);
        assert_eq!(h.trips, 2, "failed probe re-trips");
        match h.gate(expired) {
            Gate::Wait(d) => assert!(d > base, "cool-off must double: {d:?}"),
            _ => panic!("re-opened breaker must wait"),
        }
        let later = expired + base * 4;
        assert!(matches!(h.gate(later), Gate::Probe));
        h.on_success(base);
        assert_eq!((h.recoveries, h.strikes), (1, 0));
        assert!(matches!(h.gate(later), Gate::Proceed));
        // A lone pre-trip failure after recovery does not re-open.
        h.on_failure(later);
        assert!(matches!(h.gate(later), Gate::Proceed));
        assert_eq!(h.trips, 2);
    }

    #[test]
    fn breaker_backoff_is_capped() {
        let mut h = HostHealth::new(Duration::from_secs(40));
        let t0 = Instant::now();
        for _ in 0..BREAKER_STRIKES {
            h.on_failure(t0);
        }
        assert_eq!(h.trips, 1);
        assert_eq!(h.backoff, BREAKER_MAX_BACKOFF, "40s doubles to the 60s cap");
    }
}
