//! `rfold serve` — the always-on scheduling service.
//!
//! The batch simulator becomes a daemon: one service thread owns a
//! [`Simulation`] stepped incrementally by the engine's streaming API
//! (`advance_before` / `submit` / `drain` / `finalize`), and any number
//! of TCP connections feed it line commands through an mpsc channel
//! (the policy box is `!Send`, so the engine never leaves its thread).
//!
//! Protocol (one command per line, same line/JSON framing as the pool
//! worker; job arrays are [`pool::job_json`] bytes):
//! ```text
//! SUBMIT {job-json}   → OK {json} | REJECT {json} | ERR <msg>
//! STATUS              → STATUS {json}
//! STATUS <id>         → JOB {json} | ERR <msg>
//! DRAIN               → ROW {json} lines, then DRAIN-OK rows=<n>
//! SNAPSHOT <path>     → SNAPSHOT-OK <path> | ERR <msg>
//! SHUTDOWN            → BYE (service thread exits)
//! QUIT                → closes this connection only
//! ```
//!
//! Determinism bridge: the engine runs on a *virtual* clock driven
//! entirely by job arrival stamps — wall-clock pacing (the client's
//! `--speedup`) changes when bytes move, never what they say. A drained
//! service's `ROW` lines are byte-identical to `rfold simulate --rows`
//! on the accepted trace, and [`snapshot`](crate::coordinator::snapshot)
//! /kill/restore preserves those bytes exactly.
//!
//! Admission control: `SUBMIT` is rejected (structured `REJECT`, not a
//! protocol error) while the engine queue holds `queue_cap` jobs — the
//! bounded-queue backpressure of a real intake. Rejected jobs never
//! enter the trace, so acceptance *is* the determinism boundary.
//! Arrivals must be non-decreasing: the engine cannot schedule the past.
//!
//! Crash safety ([`ServeOptions`]): with `--wal`, every accepted
//! submission is appended to a fsynced write-ahead journal *before* the
//! `OK` is sent, and with `--snapshot-every`, the service auto-snapshots
//! on a virtual-time cadence (atomic write, keep-last-K rotation). A
//! `kill -9` therefore loses zero acknowledged jobs: restart restores
//! the newest valid snapshot and re-feeds the WAL suffix through the
//! exact admission path before the listener answers anything.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{self, Sender};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::coordinator::pool;
use crate::coordinator::server;
use crate::coordinator::snapshot::{self, ServiceMeta, ServiceSnapshot};
use crate::coordinator::wal;
use crate::metrics::report;
use crate::sim::engine::RunResult;
use crate::sim::observer::DecisionLatency;
use crate::sim::{SimConfig, Simulation};
use crate::trace::JobSpec;
use crate::util::json::Json;
use crate::util::stats::percentile_of;

/// Default admission-control queue cap (`rfold serve --queue-cap`).
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// Durability knobs for the service thread (`rfold serve --wal /
/// --snapshot-every / --snapshot-dir / --snapshot-keep`), plus the WAL
/// suffix to replay on restart. [`Default`] disables everything — the
/// pre-existing in-memory daemon.
#[derive(Clone, Default)]
pub struct ServeOptions {
    /// Write-ahead journal path; accepted jobs are fsynced there before
    /// the `OK` reply. `None` disables journaling.
    pub wal: Option<String>,
    /// Journaled jobs to re-submit through the admission path before any
    /// live command is handled (the WAL suffix past the restored
    /// snapshot). The writer attaches only *after* replay, so these are
    /// never re-journaled.
    pub replay: Vec<JobSpec>,
    /// Auto-snapshot cadence in *virtual* seconds, measured on accepted
    /// arrivals; `<= 0` disables.
    pub snapshot_every: f64,
    /// Directory for `auto-<seq>.snap` files (defaults to `snapshots`).
    pub snapshot_dir: Option<String>,
    /// Keep-last-K rotation for auto-snapshots; `0` keeps all.
    pub snapshot_keep: usize,
}

/// The first virtual timestamp at or past which the next auto-snapshot
/// is due, given the latest accepted arrival `after`.
fn next_cadence(after: f64, every: f64) -> f64 {
    if every <= 0.0 {
        return f64::INFINITY;
    }
    if after.is_finite() && after > 0.0 {
        (after / every).floor() * every + every
    } else {
        every
    }
}

/// One request to the service thread; every command carries its own
/// reply channel, so replies cannot cross between connections.
enum SvcCmd {
    Submit(JobSpec, Sender<String>),
    Status(Sender<String>),
    JobStatus(u64, Sender<String>),
    Drain(Sender<String>),
    Snapshot(String, Sender<String>),
    Shutdown(Sender<String>),
}

/// Cloneable client half of the service: connection threads (and tests)
/// send commands and block on the per-command reply.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<SvcCmd>,
}

impl ServiceHandle {
    fn request(&self, make: impl FnOnce(Sender<String>) -> SvcCmd) -> String {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(make(reply_tx)).is_err() {
            return "ERR service unavailable (shut down?)".into();
        }
        reply_rx
            .recv()
            .unwrap_or_else(|_| "ERR service unavailable (shut down?)".into())
    }
}

/// The engine side: everything the service thread owns.
struct Service {
    cfg: SimConfig,
    /// `None` after `DRAIN` consumed the engine.
    sim: Option<Simulation>,
    /// Accepted jobs in submission order — the live trace.
    jobs: Vec<JobSpec>,
    /// Accepted ids, for duplicate detection.
    ids: HashSet<u64>,
    /// Max arrival of any submission *seen* (accepted or rejected).
    /// Rejected jobs advance the virtual clock too (`advance_before`
    /// runs before the admission decision), so ordering must be
    /// enforced against this, not against the last accepted arrival —
    /// otherwise a post-rejection submission could ask the engine to
    /// schedule the past and diverge from the batch bytes.
    horizon: f64,
    queue_cap: usize,
    submitted: usize,
    admitted: usize,
    rejected: usize,
    latency: DecisionLatency,
    /// Final result, kept for post-drain `STATUS`.
    result: Option<RunResult>,
    /// Write-ahead journal; accepted submissions are fsynced here before
    /// the `OK` reply (`None` = journaling off, or replay in progress).
    wal: Option<wal::WalWriter>,
    /// Auto-snapshot cadence in virtual seconds (`<= 0` = off).
    snapshot_every: f64,
    snapshot_dir: String,
    /// Keep-last-K rotation bound for auto-snapshots (`0` keeps all).
    snapshot_keep: usize,
    /// Virtual time of the next due auto-snapshot (`INFINITY` when off).
    next_snapshot_at: f64,
    /// Sequence number of the last auto-snapshot written.
    snapshot_seq: u64,
}

impl Service {
    fn submit(&mut self, job: JobSpec) -> String {
        let Some(sim) = self.sim.as_mut() else {
            return "ERR service is drained; no further submissions".into();
        };
        if self.ids.contains(&job.id) {
            return format!("ERR duplicate job id {}", job.id);
        }
        if job.arrival < self.horizon {
            return format!(
                "ERR arrival {} precedes a prior submission's arrival {} (stream must be time-ordered)",
                job.arrival, self.horizon
            );
        }
        self.horizon = job.arrival;
        self.submitted += 1;
        // Deliver everything due strictly before this arrival, then make
        // the admission decision against the *current* queue — exactly
        // the state a batch run would see at this point of the trace.
        sim.advance_before(&self.jobs, job.arrival);
        if sim.queue_depth() >= self.queue_cap {
            self.rejected += 1;
            return format!(
                "REJECT {}",
                jobj(vec![
                    ("id", Json::u64_str(job.id)),
                    ("queue", Json::Num(sim.queue_depth() as f64)),
                    ("queue_cap", Json::Num(self.queue_cap as f64)),
                ])
            );
        }
        // Durability before acknowledgement: the accepted arrival reaches
        // the fsynced journal before the engine sees it or the client
        // hears `OK` — a `kill -9` past this line loses nothing.
        if let Some(w) = self.wal.as_mut() {
            if let Err(e) = w.append(&job) {
                return format!("ERR {e}");
            }
        }
        self.admitted += 1;
        self.ids.insert(job.id);
        self.jobs.push(job);
        sim.submit(&self.jobs, self.jobs.len() - 1);
        let reply = format!(
            "OK {}",
            jobj(vec![
                ("id", Json::u64_str(job.id)),
                ("queue", Json::Num(sim.queue_depth() as f64)),
                ("running", Json::Num(sim.running_count() as f64)),
            ])
        );
        self.maybe_auto_snapshot();
        reply
    }

    /// Write `auto-<seq>.snap` whenever accepted arrivals cross the
    /// cadence boundary, then rotate old auto-snapshots away. Failures
    /// are reported on stderr and never fail the submission: durability
    /// degrades to the WAL alone, it does not take the service down.
    fn maybe_auto_snapshot(&mut self) {
        if self.horizon < self.next_snapshot_at {
            return;
        }
        while self.next_snapshot_at <= self.horizon {
            self.next_snapshot_at += self.snapshot_every;
        }
        self.snapshot_seq += 1;
        let path = format!("{}/auto-{:08}.snap", self.snapshot_dir, self.snapshot_seq);
        let reply = self.snapshot(&path);
        if let Some(p) = reply.strip_prefix("SNAPSHOT-OK ") {
            eprintln!("serve: auto-snapshot {p} at t={}", self.horizon);
            self.rotate_snapshots();
        } else {
            eprintln!("serve: auto-snapshot {path}: {reply}");
        }
    }

    /// Delete the oldest `auto-*.snap` files beyond the keep bound.
    /// Manual `SNAPSHOT <path>` files are never rotated away.
    fn rotate_snapshots(&self) {
        if self.snapshot_keep == 0 {
            return;
        }
        let mut autos: Vec<String> = snapshot::list_snapshots(&self.snapshot_dir)
            .into_iter()
            .filter(|p| {
                std::path::Path::new(p)
                    .file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("auto-"))
            })
            .collect();
        // `list_snapshots` sorts ascending and auto names are zero-padded,
        // so the front of the list is the oldest.
        while autos.len() > self.snapshot_keep {
            let victim = autos.remove(0);
            if let Err(e) = std::fs::remove_file(&victim) {
                eprintln!("serve: snapshot rotation: cannot remove {victim}: {e}");
                break;
            }
        }
    }

    fn status(&self) -> String {
        let us = self.latency.samples();
        let mut fields = vec![
            ("admitted", Json::Num(self.admitted as f64)),
            ("drained", Json::Bool(self.sim.is_none())),
            ("queue_cap", Json::Num(self.queue_cap as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("submitted", Json::Num(self.submitted as f64)),
        ];
        if !us.is_empty() {
            fields.push(("decision_p50_us", Json::Num(percentile_of(&us, 0.50))));
            fields.push(("decision_p99_us", Json::Num(percentile_of(&us, 0.99))));
            fields.push(("decisions", Json::Num(us.len() as f64)));
        }
        match (&self.sim, &self.result) {
            (Some(sim), _) => {
                fields.push(("completed", Json::Num(sim.completed_count() as f64)));
                fields.push(("dropped", Json::Num(sim.dropped_count() as f64)));
                fields.push(("now", Json::Num(sim.now())));
                fields.push(("queue", Json::Num(sim.queue_depth() as f64)));
                fields.push(("running", Json::Num(sim.running_count() as f64)));
                fields.push(("util", Json::Num(sim.cluster_utilization())));
            }
            (None, Some(r)) => {
                fields.push(("completed", Json::Num(r.scheduled as f64)));
                fields.push(("dropped", Json::Num(r.dropped as f64)));
                fields.push(("makespan", Json::Num(r.makespan)));
            }
            (None, None) => {}
        }
        format!("STATUS {}", jobj(fields))
    }

    fn job_status(&self, id: u64) -> String {
        if !self.ids.contains(&id) {
            return format!("ERR unknown job {id}");
        }
        let status = match &self.sim {
            Some(sim) => sim.job_status(&self.jobs, id),
            None => match &self.result {
                Some(r) => r
                    .outcomes
                    .iter()
                    .rev()
                    .find(|(jid, _)| *jid == id)
                    .map(|(_, o)| match o {
                        crate::sim::engine::JobOutcome::Completed { .. } => "completed",
                        crate::sim::engine::JobOutcome::Dropped => "dropped",
                        crate::sim::engine::JobOutcome::NotScheduled => "not-scheduled",
                    })
                    .unwrap_or("unknown"),
                None => "unknown",
            },
        };
        format!(
            "JOB {}",
            jobj(vec![
                ("id", Json::u64_str(id)),
                ("status", Json::Str(status.to_string())),
            ])
        )
    }

    fn drain(&mut self) -> String {
        let Some(mut sim) = self.sim.take() else {
            return "ERR already drained".into();
        };
        sim.drain(&self.jobs);
        let result = sim.finalize(&self.jobs);
        let rows = report::outcome_rows(&result, &self.jobs);
        report::print_service_telemetry(
            self.submitted,
            self.admitted,
            self.rejected,
            &self.latency.samples(),
        );
        self.result = Some(result);
        let mut reply = rows.join("\n");
        if !reply.is_empty() {
            reply.push('\n');
        }
        reply.push_str(&format!("DRAIN-OK rows={}", rows.len()));
        reply
    }

    fn snapshot(&self, path: &str) -> String {
        let Some(sim) = self.sim.as_ref() else {
            return "ERR already drained; nothing to snapshot".into();
        };
        let meta = ServiceMeta {
            cfg: &self.cfg,
            jobs: &self.jobs,
            queue_cap: self.queue_cap,
            submitted: self.submitted,
            admitted: self.admitted,
            rejected: self.rejected,
        };
        match snapshot::save(path, sim, &meta) {
            Ok(()) => format!("SNAPSHOT-OK {path}"),
            Err(e) => format!("ERR {e}"),
        }
    }
}

/// Build a snapshot-style JSON object (sorted keys via BTreeMap).
fn jobj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Start the service thread. The engine (and its `!Send` policy box) is
/// instantiated *inside* the thread; `restore` resumes from a decoded
/// snapshot instead of an empty cluster. Returns the command handle and
/// the thread's join handle (the daemon's lifetime: joins when a
/// `SHUTDOWN` arrives or every handle is dropped).
pub fn spawn_service(
    cfg: SimConfig,
    queue_cap: usize,
    restore: Option<ServiceSnapshot>,
) -> (ServiceHandle, JoinHandle<()>) {
    spawn_service_opts(cfg, queue_cap, restore, ServeOptions::default())
}

/// [`spawn_service`] with durability options: before the command loop
/// starts, the WAL suffix in `opts.replay` is re-submitted through the
/// exact admission path, and only then is the journal writer attached
/// (replayed jobs are already on disk — re-appending would duplicate
/// them).
pub fn spawn_service_opts(
    cfg: SimConfig,
    queue_cap: usize,
    restore: Option<ServiceSnapshot>,
    opts: ServeOptions,
) -> (ServiceHandle, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<SvcCmd>();
    let join = thread::spawn(move || {
        let ServeOptions {
            wal: wal_path,
            replay,
            snapshot_every,
            snapshot_dir,
            snapshot_keep,
        } = opts;
        let snapshot_dir = snapshot_dir.unwrap_or_else(|| "snapshots".to_string());
        let snapshot_seq = snapshot::list_snapshots(&snapshot_dir)
            .iter()
            .filter_map(|p| {
                std::path::Path::new(p)
                    .file_name()?
                    .to_str()?
                    .strip_prefix("auto-")?
                    .strip_suffix(".snap")?
                    .parse::<u64>()
                    .ok()
            })
            .max()
            .unwrap_or(0);
        let latency = DecisionLatency::new();
        let mut svc = match restore {
            None => Service {
                cfg,
                sim: Some(Simulation::new(cfg).with_observer(Box::new(latency.clone()))),
                jobs: Vec::new(),
                ids: HashSet::new(),
                horizon: f64::NEG_INFINITY,
                queue_cap: queue_cap.max(1),
                submitted: 0,
                admitted: 0,
                rejected: 0,
                latency,
                result: None,
                wal: None,
                snapshot_every,
                snapshot_dir,
                snapshot_keep,
                next_snapshot_at: next_cadence(f64::NEG_INFINITY, snapshot_every),
                snapshot_seq,
            },
            Some(snap) => {
                let sim = match Simulation::restore(snap.cfg, &snap.engine) {
                    Ok(sim) => sim.with_observer(Box::new(latency.clone())),
                    Err(e) => {
                        // Refuse to serve from a bad snapshot: every
                        // command gets the unavailable error once the
                        // channel closes.
                        eprintln!("serve: restore failed: {e}");
                        return;
                    }
                };
                let ids = snap.jobs.iter().map(|j| j.id).collect();
                // The exact pre-kill horizon isn't persisted; the last
                // processed event time is a safe floor (every earlier
                // submission advanced the clock at most that far).
                let horizon = snap
                    .jobs
                    .last()
                    .map_or(f64::NEG_INFINITY, |j| j.arrival)
                    .max(sim.now());
                Service {
                    cfg: snap.cfg,
                    sim: Some(sim),
                    jobs: snap.jobs,
                    ids,
                    horizon,
                    queue_cap: snap.queue_cap.max(1),
                    submitted: snap.submitted,
                    admitted: snap.admitted,
                    rejected: snap.rejected,
                    latency,
                    result: None,
                    wal: None,
                    snapshot_every,
                    snapshot_dir,
                    snapshot_keep,
                    next_snapshot_at: next_cadence(horizon, snapshot_every),
                    snapshot_seq,
                }
            }
        };
        // Crash recovery: re-feed the journaled suffix through the exact
        // admission path before any live command is handled. Replayed
        // jobs were accepted pre-crash with the same cap and ordering,
        // so determinism re-accepts every one of them.
        let replayed = replay.len();
        for job in replay {
            let r = svc.submit(job);
            if !r.starts_with("OK") {
                eprintln!("serve: wal replay: journaled job not re-accepted: {r}");
            }
        }
        if replayed > 0 {
            eprintln!("serve: replayed {replayed} journaled job(s)");
        }
        if let Some(path) = wal_path {
            match wal::WalWriter::open(&path) {
                Ok(w) => svc.wal = Some(w),
                Err(e) => {
                    // Serving without the promised journal would be a
                    // silent durability downgrade — refuse instead.
                    eprintln!("serve: --wal: {e}");
                    return;
                }
            }
        }
        while let Ok(cmd) = rx.recv() {
            match cmd {
                SvcCmd::Submit(job, reply) => {
                    let _ = reply.send(svc.submit(job));
                }
                SvcCmd::Status(reply) => {
                    let _ = reply.send(svc.status());
                }
                SvcCmd::JobStatus(id, reply) => {
                    let _ = reply.send(svc.job_status(id));
                }
                SvcCmd::Drain(reply) => {
                    let _ = reply.send(svc.drain());
                }
                SvcCmd::Snapshot(path, reply) => {
                    let _ = reply.send(svc.snapshot(&path));
                }
                SvcCmd::Shutdown(reply) => {
                    let _ = reply.send("BYE".into());
                    break;
                }
            }
        }
    });
    (ServiceHandle { tx }, join)
}

/// Parse and execute one protocol line; `None` closes the connection.
pub fn dispatch(line: &str, handle: &ServiceHandle) -> Option<String> {
    if line.is_empty() {
        return Some(String::new());
    }
    if line == "QUIT" {
        return None;
    }
    let (verb, rest) = line.split_once(' ').unwrap_or((line, ""));
    let rest = rest.trim();
    match verb {
        "SUBMIT" => {
            // Parse errors are this connection's problem, not the
            // service's: reply ERR without consuming a submission slot
            // and keep the connection alive.
            let job = match Json::parse(rest) {
                Ok(j) => match pool::parse_job(&j) {
                    Ok(job) => job,
                    Err(e) => return Some(format!("ERR bad job: {e}")),
                },
                Err(e) => return Some(format!("ERR bad job json: {e}")),
            };
            Some(handle.request(|r| SvcCmd::Submit(job, r)))
        }
        "STATUS" => {
            if rest.is_empty() {
                Some(handle.request(SvcCmd::Status))
            } else {
                match rest.parse::<u64>() {
                    Ok(id) => Some(handle.request(|r| SvcCmd::JobStatus(id, r))),
                    Err(_) => Some(format!("ERR bad job id '{rest}'")),
                }
            }
        }
        "DRAIN" => Some(handle.request(SvcCmd::Drain)),
        "SNAPSHOT" => {
            if rest.is_empty() {
                Some("ERR usage: SNAPSHOT <path>".into())
            } else {
                Some(handle.request(|r| SvcCmd::Snapshot(rest.to_string(), r)))
            }
        }
        "SHUTDOWN" => Some(handle.request(SvcCmd::Shutdown)),
        _ => Some(
            "ERR unknown command (SUBMIT/STATUS/DRAIN/SNAPSHOT/SHUTDOWN/QUIT)".into(),
        ),
    }
}

/// Bind `addr` (e.g. `127.0.0.1:0` in tests), start the service and a
/// detached accept loop, and return the bound address plus handles.
/// Each connection gets its own thread running the shared
/// [`server::serve_lines`] framing, all multiplexed onto the single
/// service thread.
pub fn spawn_server_on(
    addr: &str,
    cfg: SimConfig,
    queue_cap: usize,
    restore: Option<ServiceSnapshot>,
) -> std::io::Result<(SocketAddr, ServiceHandle, JoinHandle<()>)> {
    spawn_server_on_opts(addr, cfg, queue_cap, restore, ServeOptions::default())
}

/// [`spawn_server_on`] with durability options. WAL replay happens on
/// the service thread before its command loop, and commands queue in
/// the mpsc channel, so connections accepted during replay are answered
/// only after recovery completes — no client can observe a half-restored
/// service.
pub fn spawn_server_on_opts(
    addr: &str,
    cfg: SimConfig,
    queue_cap: usize,
    restore: Option<ServiceSnapshot>,
    opts: ServeOptions,
) -> std::io::Result<(SocketAddr, ServiceHandle, JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let (handle, join) = spawn_service_opts(cfg, queue_cap, restore, opts);
    let accept_handle = handle.clone();
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let conn_handle = accept_handle.clone();
            thread::spawn(move || {
                let _ = server::serve_lines(stream, |line| dispatch(line, &conn_handle));
            });
        }
    });
    Ok((local, handle, join))
}

/// `rfold serve` entry point: serve until a `SHUTDOWN` command stops the
/// service thread (connections opened after that get
/// "ERR service unavailable" and the process exits).
pub fn serve(
    addr: &str,
    cfg: SimConfig,
    queue_cap: usize,
    restore: Option<ServiceSnapshot>,
) -> std::io::Result<()> {
    serve_opts(addr, cfg, queue_cap, restore, ServeOptions::default())
}

/// [`serve`] with durability options (`--wal` / `--snapshot-every`).
pub fn serve_opts(
    addr: &str,
    cfg: SimConfig,
    queue_cap: usize,
    restore: Option<ServiceSnapshot>,
    opts: ServeOptions,
) -> std::io::Result<()> {
    let wal_note = match &opts.wal {
        Some(p) => format!(", wal {p}"),
        None => String::new(),
    };
    let snap_note = if opts.snapshot_every > 0.0 {
        format!(
            ", auto-snapshot every {}s into {}",
            opts.snapshot_every,
            opts.snapshot_dir.as_deref().unwrap_or("snapshots")
        )
    } else {
        String::new()
    };
    let (local, _handle, join) = spawn_server_on_opts(addr, cfg, queue_cap, restore, opts)?;
    eprintln!("rfold serve listening on {local} (queue-cap {queue_cap}{wal_note}{snap_note})");
    join.join()
        .map_err(|_| std::io::Error::other("service thread panicked"))?;
    eprintln!("rfold serve: shut down");
    Ok(())
}

/// Outcome of one [`submit_trace`] replay.
#[derive(Debug, Default)]
pub struct SubmitSummary {
    /// Jobs the daemon accepted (`OK`).
    pub accepted: usize,
    /// Jobs refused by admission control (`REJECT`).
    pub rejected: usize,
    /// Protocol errors (`ERR` replies).
    pub errors: usize,
    /// `ROW` lines streamed back by `DRAIN` (empty unless `drain`).
    pub rows: Vec<String>,
}

/// `rfold submit`: replay `jobs` into a live daemon at `addr`, pacing
/// inter-arrival gaps by wall-clock `gap / speedup` (0 or non-finite
/// speedup replays as fast as the socket allows — pacing shapes *when*
/// bytes are sent, never their content). With `drain`, issue `DRAIN`
/// after the last job and collect the `ROW` lines.
pub fn submit_trace(
    addr: &str,
    jobs: &[JobSpec],
    speedup: f64,
    drain: bool,
) -> std::io::Result<SubmitSummary> {
    let stream = TcpStream::connect(addr)?;
    let mut out = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut summary = SubmitSummary::default();
    let mut prev = f64::NAN;
    let mut line = String::new();
    for job in jobs {
        if speedup.is_finite() && speedup > 0.0 && prev.is_finite() {
            let dt = (job.arrival - prev).max(0.0) / speedup;
            if dt > 0.0 {
                thread::sleep(Duration::from_secs_f64(dt));
            }
        }
        prev = job.arrival;
        writeln!(out, "SUBMIT {}", pool::job_json(job))?;
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::other("daemon closed the connection"));
        }
        let reply = line.trim();
        if reply.starts_with("OK") {
            summary.accepted += 1;
        } else if reply.starts_with("REJECT") {
            summary.rejected += 1;
        } else {
            summary.errors += 1;
            eprintln!("submit: job {}: {reply}", job.id);
        }
    }
    if drain {
        writeln!(out, "DRAIN")?;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                return Err(std::io::Error::other(
                    "daemon closed the connection mid-drain",
                ));
            }
            let reply = line.trim();
            if let Some(row) = reply.strip_prefix("ROW ") {
                summary.rows.push(format!("ROW {row}"));
            } else if reply.starts_with("DRAIN-OK") {
                break;
            } else {
                summary.errors += 1;
                eprintln!("submit: drain: {reply}");
                break;
            }
        }
    }
    let _ = writeln!(out, "QUIT");
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::PolicyKind;
    use crate::shape::JobShape;
    use crate::topology::cluster::ClusterTopo;

    fn cfg() -> SimConfig {
        let mut cfg = SimConfig::new(ClusterTopo::static_4096(), PolicyKind::FirstFit);
        cfg.drain = true;
        cfg
    }

    fn jsub(id: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            arrival,
            duration: 10.0,
            shape: JobShape::new(2, 2, 2),
            comm_frac: 0.1,
            priority: 0,
        }
    }

    #[test]
    fn dispatch_submit_status_drain_shutdown() {
        let (handle, join) = spawn_service(cfg(), 8, None);
        let r = dispatch(
            &format!("SUBMIT {}", pool::job_json(&jsub(0, 0.0))),
            &handle,
        )
        .unwrap();
        assert!(r.starts_with("OK "), "{r}");
        let r = dispatch("STATUS", &handle).unwrap();
        assert!(r.starts_with("STATUS "), "{r}");
        let j = Json::parse(r.strip_prefix("STATUS ").unwrap()).unwrap();
        assert_eq!(j.get("admitted").and_then(Json::as_usize), Some(1));
        let r = dispatch("STATUS 0", &handle).unwrap();
        assert!(r.contains("running"), "one small job runs immediately: {r}");
        let r = dispatch("STATUS 99", &handle).unwrap();
        assert!(r.starts_with("ERR unknown job"), "{r}");
        let r = dispatch("DRAIN", &handle).unwrap();
        assert!(r.contains("ROW ") && r.ends_with("DRAIN-OK rows=1"), "{r}");
        // Post-drain submissions are refused, STATUS still answers.
        let r = dispatch(
            &format!("SUBMIT {}", pool::job_json(&jsub(1, 1.0))),
            &handle,
        )
        .unwrap();
        assert!(r.starts_with("ERR service is drained"), "{r}");
        let r = dispatch("DRAIN", &handle).unwrap();
        assert!(r.starts_with("ERR already drained"), "{r}");
        let r = dispatch("STATUS", &handle).unwrap();
        assert!(r.contains("\"drained\":true"), "{r}");
        assert_eq!(dispatch("SHUTDOWN", &handle), Some("BYE".into()));
        join.join().unwrap();
        let r = dispatch("STATUS", &handle).unwrap();
        assert!(r.starts_with("ERR service unavailable"), "{r}");
    }

    #[test]
    fn dispatch_rejects_malformed_and_out_of_order() {
        let (handle, join) = spawn_service(cfg(), 8, None);
        let r = dispatch("SUBMIT not-json", &handle).unwrap();
        assert!(r.starts_with("ERR bad job json"), "{r}");
        let r = dispatch("SUBMIT [1,2]", &handle).unwrap();
        assert!(r.starts_with("ERR bad job"), "{r}");
        let r = dispatch("NOPE", &handle).unwrap();
        assert!(r.starts_with("ERR unknown command"), "{r}");
        let r = dispatch("STATUS abc", &handle).unwrap();
        assert!(r.starts_with("ERR bad job id"), "{r}");
        let r = dispatch("SNAPSHOT", &handle).unwrap();
        assert!(r.starts_with("ERR usage"), "{r}");
        assert_eq!(dispatch("", &handle), Some(String::new()));
        assert_eq!(dispatch("QUIT", &handle), None);
        // Time must not run backwards, and ids are unique.
        let ok = dispatch(
            &format!("SUBMIT {}", pool::job_json(&jsub(5, 50.0))),
            &handle,
        )
        .unwrap();
        assert!(ok.starts_with("OK "), "{ok}");
        let r = dispatch(
            &format!("SUBMIT {}", pool::job_json(&jsub(6, 40.0))),
            &handle,
        )
        .unwrap();
        assert!(r.starts_with("ERR arrival"), "{r}");
        let r = dispatch(
            &format!("SUBMIT {}", pool::job_json(&jsub(5, 60.0))),
            &handle,
        )
        .unwrap();
        assert!(r.starts_with("ERR duplicate job id"), "{r}");
        // Malformed and refused submissions consumed no admission slot.
        let st = dispatch("STATUS", &handle).unwrap();
        let j = Json::parse(st.strip_prefix("STATUS ").unwrap()).unwrap();
        assert_eq!(j.get("submitted").and_then(Json::as_usize), Some(1));
        let _ = dispatch("SHUTDOWN", &handle);
        join.join().unwrap();
    }

    #[test]
    fn queue_cap_rejects_structurally() {
        // Cap 1 on a cluster-filling stream: job 0 runs, job 1 queues,
        // job 2 must be REJECTed (queue is at cap), never entering the
        // engine.
        let (handle, join) = spawn_service(cfg(), 1, None);
        let big = |id: u64, arrival: f64| JobSpec {
            shape: JobShape::new(16, 16, 16),
            duration: 1000.0,
            ..jsub(id, arrival)
        };
        for (i, expect) in [(0u64, "OK "), (1, "OK "), (2, "REJECT ")] {
            let r = dispatch(
                &format!("SUBMIT {}", pool::job_json(&big(i, i as f64))),
                &handle,
            )
            .unwrap();
            assert!(r.starts_with(expect), "job {i}: {r}");
        }
        let st = dispatch("STATUS", &handle).unwrap();
        let j = Json::parse(st.strip_prefix("STATUS ").unwrap()).unwrap();
        assert_eq!(j.get("submitted").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("admitted").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("rejected").and_then(Json::as_usize), Some(1));
        // The drain result covers exactly the accepted jobs.
        let r = dispatch("DRAIN", &handle).unwrap();
        assert!(r.ends_with("DRAIN-OK rows=2"), "{r}");
        let _ = dispatch("SHUTDOWN", &handle);
        join.join().unwrap();
    }

    #[test]
    fn wal_and_auto_snapshots_survive_a_dropped_service() {
        let dir = std::env::temp_dir().join(format!("rfold_serve_dur_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let dir_s = dir.to_str().unwrap().to_string();
        let wal_path = format!("{dir_s}/arrivals.wal");
        let stream = [
            (0u64, 0.0),
            (1, 15.0),
            (2, 40.0),
            (3, 65.0),
            (4, 90.0),
            (5, 95.0),
        ];
        let opts = ServeOptions {
            wal: Some(wal_path.clone()),
            replay: Vec::new(),
            snapshot_every: 20.0,
            snapshot_dir: Some(dir_s.clone()),
            snapshot_keep: 2,
        };
        let (handle, join) = spawn_service_opts(cfg(), 8, None, opts);
        for (id, arrival) in stream {
            let r = dispatch(
                &format!("SUBMIT {}", pool::job_json(&jsub(id, arrival))),
                &handle,
            )
            .unwrap();
            assert!(r.starts_with("OK "), "job {id}: {r}");
        }
        // "kill -9": drop the service without DRAIN or SHUTDOWN. Only the
        // durable artifacts (WAL + auto-snapshots) survive.
        drop(handle);
        join.join().unwrap();
        // Every ACKed job is journaled.
        let replayed = wal::replay(&wal_path).unwrap();
        assert_eq!(replayed.jobs.len(), stream.len());
        assert!(!replayed.torn);
        // Cadence 20 over arrivals to 95 snapshots at t=40/65/90 (seq
        // 1..=3); keep-last-2 rotation leaves exactly seq 2 and 3.
        let autos: Vec<String> = snapshot::list_snapshots(&dir_s)
            .into_iter()
            .filter(|p| p.contains("auto-"))
            .collect();
        assert_eq!(autos.len(), 2, "{autos:?}");
        assert!(autos[1].ends_with("auto-00000003.snap"), "{autos:?}");
        // Restore the newest snapshot and replay the WAL suffix; the
        // drain must be byte-identical to an uninterrupted service.
        let (snap, _) = snapshot::load_newest(&dir_s).unwrap().unwrap();
        assert!(
            snap.jobs.len() < stream.len(),
            "job 5 must live only in the WAL for this test to bite"
        );
        let suffix = replayed.jobs[snap.jobs.len()..].to_vec();
        let opts = ServeOptions {
            replay: suffix,
            ..ServeOptions::default()
        };
        let (handle, join) = spawn_service_opts(cfg(), 8, Some(snap), opts);
        let restored = dispatch("DRAIN", &handle).unwrap();
        let _ = dispatch("SHUTDOWN", &handle);
        join.join().unwrap();
        let (handle, join) = spawn_service(cfg(), 8, None);
        for (id, arrival) in stream {
            let r = dispatch(
                &format!("SUBMIT {}", pool::job_json(&jsub(id, arrival))),
                &handle,
            )
            .unwrap();
            assert!(r.starts_with("OK "), "job {id}: {r}");
        }
        let uninterrupted = dispatch("DRAIN", &handle).unwrap();
        let _ = dispatch("SHUTDOWN", &handle);
        join.join().unwrap();
        assert_eq!(restored, uninterrupted);
        std::fs::remove_dir_all(&dir).ok();
    }
}
