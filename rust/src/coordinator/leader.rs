//! The leader event loop.

use std::collections::VecDeque;
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::placement::{PlacementPolicy, PolicyHandle};
use crate::shape::JobShape;
use crate::topology::cluster::{ClusterState, ClusterTopo};

/// A submission accepted by the leader.
#[derive(Clone, Copy, Debug)]
pub struct Submission {
    pub shape: JobShape,
    /// Requested run time in (unscaled) seconds.
    pub duration: f64,
}

/// Leader → client job status.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Finished,
    Rejected,
}

/// Aggregate statistics snapshot.
#[derive(Clone, Debug, Default)]
pub struct LeaderStats {
    pub submitted: usize,
    pub running: usize,
    pub queued: usize,
    pub finished: usize,
    pub rejected: usize,
    pub busy_xpus: usize,
    pub total_xpus: usize,
    pub ocs_entries_reserved: usize,
}

enum Cmd {
    Submit(Submission, Sender<(u64, JobState)>),
    Query(u64, Sender<JobState>),
    Stats(Sender<LeaderStats>),
    Shutdown,
}

/// Handle for talking to a running leader thread.
#[derive(Clone)]
pub struct LeaderHandle {
    tx: Sender<Cmd>,
}

impl LeaderHandle {
    /// Submit a job; returns its id and initial state.
    pub fn submit(&self, s: Submission) -> Option<(u64, JobState)> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Cmd::Submit(s, tx)).ok()?;
        rx.recv().ok()
    }

    pub fn query(&self, id: u64) -> Option<JobState> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Cmd::Query(id, tx)).ok()?;
        rx.recv().ok()
    }

    pub fn stats(&self) -> Option<LeaderStats> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Cmd::Stats(tx)).ok()?;
        rx.recv().ok()
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Cmd::Shutdown);
    }
}

/// The leader itself. Owns the cluster state and the policy; runs on its
/// own thread via [`Leader::spawn`].
pub struct Leader {
    cluster: ClusterState,
    policy: PolicyHandle,
    /// Wall seconds per simulated second (e.g. 0.001 → 1000× speedup).
    time_scale: f64,
    queue: VecDeque<(u64, Submission)>,
    running: Vec<(u64, Instant)>, // (job, deadline)
    states: std::collections::HashMap<u64, JobState>,
    next_id: u64,
    stats: LeaderStats,
    epoch: Instant,
}

impl Leader {
    /// Accepts a [`PolicyHandle`] or (via the deprecated shim) a
    /// `PolicyKind`.
    pub fn new(topo: ClusterTopo, policy: impl Into<PolicyHandle>, time_scale: f64) -> Leader {
        let cluster = ClusterState::new(topo);
        let total = cluster.num_nodes();
        Leader {
            cluster,
            policy: policy.into(),
            time_scale,
            queue: VecDeque::new(),
            running: Vec::new(),
            states: std::collections::HashMap::new(),
            next_id: 0,
            stats: LeaderStats {
                total_xpus: total,
                ..Default::default()
            },
            epoch: Instant::now(),
        }
    }

    /// Spawn the leader loop on a thread; returns the command handle and
    /// the join handle.
    pub fn spawn(mut self) -> (LeaderHandle, std::thread::JoinHandle<LeaderStats>) {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let handle = LeaderHandle { tx };
        let join = std::thread::spawn(move || {
            // The policy (and its scorer trait object) lives entirely on
            // this thread — PJRT handles are not `Send`, which is why the
            // registry hands out constructors rather than instances.
            let mut policy = self.policy.instantiate();
            loop {
                // Wake for the next completion deadline or a command.
                let timeout = self
                    .running
                    .iter()
                    .map(|(_, d)| d.saturating_duration_since(Instant::now()))
                    .min()
                    .unwrap_or(Duration::from_millis(50));
                match rx.recv_timeout(timeout.min(Duration::from_millis(50))) {
                    Ok(Cmd::Submit(s, reply)) => {
                        let id = self.next_id;
                        self.next_id += 1;
                        self.stats.submitted += 1;
                        // Reject shapes that can never be placed (§4).
                        if !policy.feasible_ever(self.cluster.topo(), s.shape) {
                            self.states.insert(id, JobState::Rejected);
                            self.stats.rejected += 1;
                            let _ = reply.send((id, JobState::Rejected));
                        } else {
                            self.states.insert(id, JobState::Queued);
                            self.queue.push_back((id, s));
                            self.drain(policy.as_mut());
                            let _ = reply.send((id, self.states[&id]));
                        }
                    }
                    Ok(Cmd::Query(id, reply)) => {
                        let _ = reply.send(
                            self.states
                                .get(&id)
                                .copied()
                                .unwrap_or(JobState::Rejected),
                        );
                    }
                    Ok(Cmd::Stats(reply)) => {
                        self.refresh_stats();
                        let _ = reply.send(self.stats.clone());
                    }
                    Ok(Cmd::Shutdown) => break,
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                self.reap();
                self.drain(policy.as_mut());
            }
            self.refresh_stats();
            self.stats
        });
        (handle, join)
    }

    fn refresh_stats(&mut self) {
        self.stats.busy_xpus = self.cluster.busy_count();
        self.stats.queued = self.queue.len();
        self.stats.running = self.running.len();
        self.stats.ocs_entries_reserved = self
            .cluster
            .ocs()
            .map(|o| o.reserved_entries())
            .unwrap_or(0);
    }

    /// Complete any job whose deadline passed.
    fn reap(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].1 <= now {
                let (id, _) = self.running.swap_remove(i);
                self.cluster.release(id);
                self.states.insert(id, JobState::Finished);
                self.stats.finished += 1;
            } else {
                i += 1;
            }
        }
    }

    /// FIFO drain (head-of-line blocking, like the simulator).
    fn drain(&mut self, policy: &mut dyn PlacementPolicy) {
        while let Some(&(id, s)) = self.queue.front() {
            match policy.place_now(&self.cluster, id, s.shape) {
                Some(plan) => {
                    // Defense in depth: a plan whose OCS reservations
                    // cannot all be taken (a planner inconsistency today;
                    // an interleaved reconfiguration if the leader ever
                    // pipelines placement) must not crash the long-running
                    // coordinator the way a batch simulation may panic.
                    // `commit` rolls its reservations back on error, so
                    // the cluster stays consistent, the job becomes a
                    // structured rejection, and the queue keeps draining —
                    // with a loud stderr note so the defect is not silent.
                    if let Err(e) = plan.commit(&mut self.cluster) {
                        eprintln!(
                            "leader: job {id} rejected (placement plan failed \
                             to commit: {e})"
                        );
                        self.states.insert(id, JobState::Rejected);
                        self.stats.rejected += 1;
                        self.queue.pop_front();
                        continue;
                    }
                    let dur = Duration::from_secs_f64(
                        (s.duration * self.time_scale).max(0.000_001),
                    );
                    self.running.push((id, Instant::now() + dur));
                    self.states.insert(id, JobState::Running);
                    self.queue.pop_front();
                }
                None => break,
            }
        }
    }

    /// Time since the leader started (diagnostics).
    pub fn uptime(&self) -> Duration {
        self.epoch.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_leader() -> (LeaderHandle, std::thread::JoinHandle<LeaderStats>) {
        Leader::new(
            ClusterTopo::reconfigurable_4096(4),
            crate::placement::builtins::RFOLD,
            1e-5, // 100k× speedup: 1s job ≈ 10µs wall
        )
        .spawn()
    }

    #[test]
    fn submit_run_finish() {
        let (h, join) = spawn_leader();
        let (id, st) = h
            .submit(Submission {
                shape: JobShape::new(4, 4, 4),
                duration: 1.0,
            })
            .unwrap();
        assert_eq!(st, JobState::Running);
        // Wait for completion.
        let mut tries = 0;
        loop {
            std::thread::sleep(Duration::from_millis(20));
            if h.query(id) == Some(JobState::Finished) {
                break;
            }
            tries += 1;
            assert!(tries < 200, "job never finished");
        }
        h.shutdown();
        let stats = join.join().unwrap();
        assert_eq!(stats.finished, 1);
        assert_eq!(stats.busy_xpus, 0);
    }

    #[test]
    fn infeasible_rejected() {
        let (h, join) = spawn_leader();
        let (_, st) = h
            .submit(Submission {
                shape: JobShape::new(64, 64, 64), // 262k XPUs
                duration: 1.0,
            })
            .unwrap();
        assert_eq!(st, JobState::Rejected);
        h.shutdown();
        assert_eq!(join.join().unwrap().rejected, 1);
    }

    #[test]
    fn fifo_queueing_under_load() {
        let (h, join) = Leader::new(
            ClusterTopo::reconfigurable_4096(4),
            crate::placement::builtins::RFOLD,
            1e-3, // long enough that job 1 is still running at submit 2
        )
        .spawn();
        // Two full-cluster jobs: second must queue.
        let big = Submission {
            shape: JobShape::new(16, 16, 16),
            duration: 200.0,
        };
        let (_, st1) = h.submit(big).unwrap();
        assert_eq!(st1, JobState::Running);
        let (id2, st2) = h.submit(big).unwrap();
        assert_eq!(st2, JobState::Queued);
        let mut tries = 0;
        while h.query(id2) != Some(JobState::Finished) {
            std::thread::sleep(Duration::from_millis(20));
            tries += 1;
            assert!(tries < 300, "queued job never ran");
        }
        h.shutdown();
        let stats = join.join().unwrap();
        assert_eq!(stats.finished, 2);
    }
}
