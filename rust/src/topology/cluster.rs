//! Cluster occupancy state: nodes, allocations, and the OCS plant.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};

use super::coords::{CubeGrid, P3};
use super::nodeset::NodeSet;
use super::ocs::OcsState;

/// Process-wide epoch source. Epochs are *globally* unique, not
/// per-cluster sequential: two live `ClusterState` values can only share
/// an epoch by being clones of the same snapshot (identical occupancy),
/// so `(epoch)` alone is a sound cache key for occupancy-derived indices
/// — no `(cluster id, generation)` pair needed, and clones stay safe.
/// Epoch values never flow into any simulation result, only into cache
/// validity checks, so the cross-thread counter cannot break determinism.
static EPOCH_SOURCE: AtomicU64 = AtomicU64::new(0);

fn next_epoch() -> u64 {
    EPOCH_SOURCE.fetch_add(1, Ordering::Relaxed)
}

/// Cluster topology flavor (paper §4 builds both). `Hash` so the sweep
/// result cache can key trial results on the topology identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ClusterTopo {
    /// Statically wired torus of the given extent (e.g. 16×16×16).
    /// Wrap-around links exist only on full dimensions.
    Static { ext: P3 },
    /// OCS-reconfigurable cluster of `grid.num_cubes()` cubes of side
    /// `grid.n` (e.g. 64 cubes of 4³).
    Reconfigurable { grid: CubeGrid },
}

impl ClusterTopo {
    /// The paper's static 16³ baseline.
    pub fn static_4096() -> ClusterTopo {
        ClusterTopo::Static {
            ext: P3([16, 16, 16]),
        }
    }

    /// The paper's reconfigurable 4096-XPU cluster with cubes of side `n`.
    pub fn reconfigurable_4096(n: usize) -> ClusterTopo {
        ClusterTopo::Reconfigurable {
            grid: CubeGrid::for_cluster(4096, n),
        }
    }

    pub fn num_xpus(&self) -> usize {
        match self {
            ClusterTopo::Static { ext } => ext.volume(),
            ClusterTopo::Reconfigurable { grid } => grid.num_xpus(),
        }
    }

    /// Cube side for reconfigurable topologies; the full extent for static
    /// ones (a static torus is one big "cube" with hard wrap-around).
    pub fn cube_side(&self) -> usize {
        match self {
            ClusterTopo::Static { ext } => ext.0[0],
            ClusterTopo::Reconfigurable { grid } => grid.n,
        }
    }

    /// Physical coordinate extent of the whole machine.
    pub fn phys_ext(&self) -> P3 {
        match self {
            ClusterTopo::Static { ext } => *ext,
            ClusterTopo::Reconfigurable { grid } => P3([
                grid.dims.0[0] * grid.n,
                grid.dims.0[1] * grid.n,
                grid.dims.0[2] * grid.n,
            ]),
        }
    }
}

/// A committed allocation: the nodes a job occupies plus communication
/// metadata the simulator needs for the JCT model.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub job: u64,
    /// Global node ids (topology-specific numbering).
    pub nodes: Vec<usize>,
    /// Cubes touched (empty for static topologies).
    pub cubes: Vec<usize>,
    /// Number of OCS entries this job reserved (rewired or wraparound).
    pub ocs_entries: usize,
    /// Per parallelism dimension: (ring length, ring closed?).
    pub rings: Vec<(usize, bool)>,
    /// Placed bounding-box extent (virtual, after reconfiguration).
    pub placed_ext: P3,
}

/// Upper bound on flipped-node records the occupancy-delta journal
/// retains. Large enough to span the bursts of small commits/releases a
/// scheduler produces between index probes; small enough that a cloned
/// `ClusterState` (defrag snapshots, sweeps) carries at most a few tens
/// of KiB of history.
const DELTA_JOURNAL_NODES: usize = 4096;

/// One epoch transition in the occupancy-delta journal: the nodes whose
/// busy bit flipped between `from_epoch` and `to_epoch`, with the state
/// they flipped *to*. Consecutive records chain (`to_epoch` of one is
/// `from_epoch` of the next), so replaying a suffix of the journal turns
/// an index built at any journaled epoch into the current one.
#[derive(Clone, Debug)]
struct OccupancyDelta {
    from_epoch: u64,
    to_epoch: u64,
    flips: Vec<(u32, bool)>,
}

/// Mutable cluster state: occupancy, live allocations, OCS plant.
#[derive(Clone, Debug)]
pub struct ClusterState {
    topo: ClusterTopo,
    /// Packed busy bitmap (a failed node is also busy).
    busy: NodeSet,
    /// Free-XPU count per cube (single entry for static topologies).
    cube_free: Vec<usize>,
    ocs: Option<OcsState>,
    allocs: HashMap<u64, Allocation>,
    /// Nodes down for repair (fault injection). A failed node is also
    /// `busy` — placement policies need no failure awareness, they simply
    /// cannot use it — but belongs to no allocation.
    failed: NodeSet,
    /// Occupancy version: a fresh globally-unique value on construction
    /// and after every [`commit`](Self::commit) / [`release`](Self::release)
    /// / [`fail_node`](Self::fail_node) / [`repair_node`](Self::repair_node).
    /// Spatial indices built against one epoch (`placement::index`) stay
    /// valid exactly while the epoch is unchanged.
    epoch: u64,
    /// Bounded journal of recent epoch transitions, oldest first, for
    /// incremental index maintenance (see [`changes_since`](Self::changes_since)).
    deltas: VecDeque<OccupancyDelta>,
    /// Total flips across `deltas`, for the journal size bound.
    delta_nodes: usize,
}

impl ClusterState {
    pub fn new(topo: ClusterTopo) -> ClusterState {
        let n_nodes = topo.num_xpus();
        let (cube_free, ocs) = match topo {
            ClusterTopo::Static { .. } => (vec![n_nodes], None),
            ClusterTopo::Reconfigurable { grid } => (
                vec![grid.n * grid.n * grid.n; grid.num_cubes()],
                Some(OcsState::new(grid)),
            ),
        };
        ClusterState {
            topo,
            busy: NodeSet::new(n_nodes),
            cube_free,
            ocs,
            allocs: HashMap::new(),
            failed: NodeSet::new(n_nodes),
            epoch: next_epoch(),
            deltas: VecDeque::new(),
            delta_nodes: 0,
        }
    }

    /// Move to a fresh epoch, journaling which busy bits flipped (and to
    /// what) in the transition. A transition too large to journal without
    /// blowing the bound clears the history instead — contiguity of the
    /// chain is what makes replay sound, so a gap must evict everything
    /// before it.
    fn bump_epoch(&mut self, flips: Vec<(u32, bool)>) {
        let from = self.epoch;
        self.epoch = next_epoch();
        if flips.len() > DELTA_JOURNAL_NODES {
            self.deltas.clear();
            self.delta_nodes = 0;
            return;
        }
        self.delta_nodes += flips.len();
        self.deltas.push_back(OccupancyDelta {
            from_epoch: from,
            to_epoch: self.epoch,
            flips,
        });
        while self.delta_nodes > DELTA_JOURNAL_NODES {
            let old = self.deltas.pop_front().expect("journal non-empty over budget");
            self.delta_nodes -= old.flips.len();
        }
    }

    /// The busy-bit flips that turn the occupancy as of `epoch` into the
    /// current occupancy, in application order — `Some(vec![])` when
    /// `epoch` is current, `None` when `epoch` has aged out of the
    /// bounded journal (or never belonged to this cluster's history) and
    /// the caller must rebuild from scratch. Sound across clones: epochs
    /// are globally unique, so a foreign epoch can appear in this journal
    /// only via shared snapshot history, where the occupancy matched.
    pub fn changes_since(&self, epoch: u64) -> Option<Vec<(usize, bool)>> {
        if epoch == self.epoch {
            return Some(Vec::new());
        }
        let start = self.deltas.iter().position(|d| d.from_epoch == epoch)?;
        let mut out = Vec::new();
        for d in self.deltas.iter().skip(start) {
            out.extend(d.flips.iter().map(|&(n, b)| (n as usize, b)));
        }
        Some(out)
    }

    /// Cube index of a node (0 for static topologies).
    fn cube_of(&self, node: usize) -> usize {
        match self.topo {
            ClusterTopo::Reconfigurable { grid } => node / (grid.n * grid.n * grid.n),
            ClusterTopo::Static { .. } => 0,
        }
    }

    /// Take a node down for repair. The node must be unoccupied (the
    /// engine kills any job touching it first); it then reads as busy to
    /// every placement query until [`repair_node`](Self::repair_node).
    /// Bumps the occupancy epoch — feasibility is no longer a run
    /// constant once nodes fail, so epoch-keyed caches must refresh.
    /// Returns `false` (and changes nothing) if the node is already down.
    pub fn fail_node(&mut self, node: usize) -> bool {
        if self.failed.contains(node) {
            return false;
        }
        debug_assert!(
            !self.busy.contains(node),
            "kill the occupant before failing node {node}"
        );
        if self.busy.contains(node) {
            return false;
        }
        self.failed.insert(node);
        self.busy.insert(node);
        self.cube_free[self.cube_of(node)] -= 1;
        self.bump_epoch(vec![(node as u32, true)]);
        true
    }

    /// Bring a failed node back. Bumps the occupancy epoch (capacity
    /// reappeared; head-of-line blocks may clear). Returns `false` if the
    /// node was not down.
    pub fn repair_node(&mut self, node: usize) -> bool {
        if !self.failed.contains(node) {
            return false;
        }
        self.failed.remove(node);
        self.busy.remove(node);
        self.cube_free[self.cube_of(node)] += 1;
        self.bump_epoch(vec![(node as u32, false)]);
        true
    }

    #[inline]
    pub fn is_failed(&self, node: usize) -> bool {
        self.failed.contains(node)
    }

    pub fn failed_count(&self) -> usize {
        self.failed.count()
    }

    /// Ascending ids of nodes currently down for repair — a word-level
    /// scan of the packed failed set, for snapshot serialization and
    /// telemetry (no O(V) per-node probe loop).
    pub fn failed_nodes(&self) -> impl Iterator<Item = usize> + '_ {
        self.failed.iter_ones()
    }

    /// The job whose allocation contains `node`, if any. Linear in the
    /// number of live allocations — fault injection is rare enough that
    /// a reverse index isn't worth carrying on the placement hot path.
    pub fn job_on_node(&self, node: usize) -> Option<u64> {
        self.allocs
            .values()
            .find(|a| a.nodes.contains(&node))
            .map(|a| a.job)
    }

    pub fn topo(&self) -> ClusterTopo {
        self.topo
    }

    /// The occupancy epoch: changes (to a globally-unique value) on every
    /// commit and release. Two reads returning the same epoch bracket a
    /// window in which the busy bitmap did not change, which is what lets
    /// `placement::index::PlacementIndex` be built once per occupancy
    /// change and shared across every variant probe and queued job.
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn ocs(&self) -> Option<&OcsState> {
        self.ocs.as_ref()
    }

    pub fn ocs_mut(&mut self) -> Option<&mut OcsState> {
        self.ocs.as_mut()
    }

    #[inline]
    pub fn is_free(&self, node: usize) -> bool {
        !self.busy.contains(node)
    }

    pub fn busy_count(&self) -> usize {
        self.busy.count()
    }

    pub fn free_count(&self) -> usize {
        self.busy.len() - self.busy.count()
    }

    /// Maximal runs of consecutive free node ids as `(start, length)`,
    /// ascending — scanned word-by-word over the packed occupancy, for
    /// policies and telemetry that want free intervals without an O(V)
    /// per-node loop.
    pub fn free_runs(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.busy.free_runs()
    }

    /// Fraction of *available* (non-failed) nodes doing work. With no
    /// failures this is exactly `busy_count / num_nodes` — the historical
    /// definition, so fault-free runs keep their bytes; failed nodes are
    /// excluded from both numerator and denominator rather than counted
    /// as "utilized".
    pub fn utilization(&self) -> f64 {
        let avail = self.busy.len() - self.failed.count();
        if avail == 0 {
            return 0.0;
        }
        (self.busy.count() - self.failed.count()) as f64 / avail as f64
    }

    pub fn num_nodes(&self) -> usize {
        self.busy.len()
    }

    /// Free XPUs in a cube (reconfigurable topologies).
    pub fn cube_free_count(&self, cube: usize) -> usize {
        self.cube_free[cube]
    }

    pub fn live_allocations(&self) -> impl Iterator<Item = &Allocation> {
        self.allocs.values()
    }

    pub fn allocation(&self, job: u64) -> Option<&Allocation> {
        self.allocs.get(&job)
    }

    /// Check a local box `[off, off+ext)` is entirely free inside `cube`.
    pub fn is_cube_box_free(&self, cube: usize, off: P3, ext: P3) -> bool {
        let grid = match self.topo {
            ClusterTopo::Reconfigurable { grid } => grid,
            _ => panic!("is_cube_box_free on static topology"),
        };
        if (0..3).any(|a| off.0[a] + ext.0[a] > grid.n) {
            return false;
        }
        ext.iter_box()
            .all(|d| self.is_free(grid.node_id(cube, off.add(d))))
    }

    /// Commit an allocation. Panics in debug builds if any node is busy
    /// (placement policies must never double-book).
    pub fn commit(&mut self, alloc: Allocation) {
        debug_assert!(!self.allocs.contains_key(&alloc.job), "job already placed");
        let mut flips = Vec::with_capacity(alloc.nodes.len());
        for &n in &alloc.nodes {
            let fresh = self.busy.insert(n);
            debug_assert!(fresh, "node {n} double-booked");
            if let ClusterTopo::Reconfigurable { grid } = self.topo {
                self.cube_free[n / (grid.n * grid.n * grid.n)] -= 1;
            } else {
                self.cube_free[0] -= 1;
            }
            flips.push((n as u32, true));
        }
        self.allocs.insert(alloc.job, alloc);
        self.bump_epoch(flips);
    }

    /// Release a job's nodes and OCS reservations. Returns the allocation
    /// if it existed.
    pub fn release(&mut self, job: u64) -> Option<Allocation> {
        let alloc = self.allocs.remove(&job)?;
        let mut flips = Vec::with_capacity(alloc.nodes.len());
        for &n in &alloc.nodes {
            let was = self.busy.remove(n);
            debug_assert!(was);
            if let ClusterTopo::Reconfigurable { grid } = self.topo {
                self.cube_free[n / (grid.n * grid.n * grid.n)] += 1;
            } else {
                self.cube_free[0] += 1;
            }
            flips.push((n as u32, false));
        }
        if let Some(ocs) = self.ocs.as_mut() {
            ocs.release_job(job);
        }
        self.bump_epoch(flips);
        Some(alloc)
    }

    /// Snapshot the occupancy as `f32` grids per cube — the layout the
    /// plan-scorer artifact consumes: `[C][N][N][N]` flattened.
    pub fn occupancy_f32(&self) -> Vec<f32> {
        (0..self.busy.len())
            .map(|n| if self.busy.contains(n) { 1.0 } else { 0.0 })
            .collect()
    }

    /// Physical coordinates of a node in the machine-room frame.
    pub fn phys_coords(&self, node: usize) -> P3 {
        match self.topo {
            ClusterTopo::Static { ext } => P3::from_index(node, ext),
            ClusterTopo::Reconfigurable { grid } => {
                let (cube, local) = grid.split_node(node);
                let c = grid.cube_coords(cube);
                P3([
                    c.0[0] * grid.n + local.0[0],
                    c.0[1] * grid.n + local.0[1],
                    c.0[2] * grid.n + local.0[2],
                ])
            }
        }
    }

    /// Invariant check used by property tests: busy counter, per-cube free
    /// counters and allocation node sets are mutually consistent, and no
    /// two allocations overlap.
    pub fn check_consistency(&self) -> Result<(), String> {
        let mut seen = vec![false; self.busy.len()];
        let mut total = 0usize;
        for a in self.allocs.values() {
            for &n in &a.nodes {
                if seen[n] {
                    return Err(format!("node {n} in two allocations"));
                }
                if !self.busy.contains(n) {
                    return Err(format!("allocated node {n} not marked busy"));
                }
                seen[n] = true;
                total += 1;
            }
        }
        for n in self.failed.iter_ones() {
            if !self.busy.contains(n) {
                return Err(format!("failed node {n} not marked busy"));
            }
            if seen[n] {
                return Err(format!("failed node {n} inside an allocation"));
            }
        }
        if self.failed.recount() != self.failed.count() {
            return Err("failed word data disagrees with its counter".into());
        }
        if total + self.failed.count() != self.busy.count() {
            return Err(format!(
                "busy count {} != allocated total {total} + failed {}",
                self.busy.count(),
                self.failed.count()
            ));
        }
        if self.busy.recount() != self.busy.count() {
            return Err("busy word data disagrees with its counter".into());
        }
        if let ClusterTopo::Reconfigurable { grid } = self.topo {
            let vol = grid.n * grid.n * grid.n;
            for cube in 0..grid.num_cubes() {
                let free = (0..vol)
                    .filter(|&i| !self.busy.contains(cube * vol + i))
                    .count();
                if free != self.cube_free[cube] {
                    return Err(format!("cube {cube} free counter drift"));
                }
            }
            if let Some(ocs) = &self.ocs {
                if !ocs.check_invariants() {
                    return Err("OCS crossbar invariant violated".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reconfig() -> ClusterState {
        ClusterState::new(ClusterTopo::reconfigurable_4096(4))
    }

    #[test]
    fn fresh_cluster_all_free() {
        let c = reconfig();
        assert_eq!(c.free_count(), 4096);
        assert_eq!(c.busy_count(), 0);
        assert_eq!(c.utilization(), 0.0);
        c.check_consistency().unwrap();
    }

    #[test]
    fn commit_release_roundtrip() {
        let mut c = reconfig();
        let nodes: Vec<usize> = (0..64).collect(); // cube 0 entirely
        c.commit(Allocation {
            job: 1,
            nodes: nodes.clone(),
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![(4, true)],
            placed_ext: P3([4, 4, 4]),
        });
        assert_eq!(c.busy_count(), 64);
        assert_eq!(c.cube_free_count(0), 0);
        assert_eq!(c.cube_free_count(1), 64);
        c.check_consistency().unwrap();

        let a = c.release(1).unwrap();
        assert_eq!(a.nodes, nodes);
        assert_eq!(c.busy_count(), 0);
        assert_eq!(c.cube_free_count(0), 64);
        c.check_consistency().unwrap();
    }

    #[test]
    fn release_unknown_job_is_none() {
        let mut c = reconfig();
        assert!(c.release(99).is_none());
    }

    #[test]
    fn epoch_changes_on_commit_and_release_only() {
        let mut c = reconfig();
        let e0 = c.epoch();
        // Reads leave the epoch alone.
        let _ = (c.free_count(), c.is_free(0), c.utilization());
        assert_eq!(c.epoch(), e0);
        c.commit(Allocation {
            job: 1,
            nodes: vec![0],
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 1]),
        });
        let e1 = c.epoch();
        assert_ne!(e1, e0, "commit must bump the epoch");
        // A failed release is a read.
        assert!(c.release(99).is_none());
        assert_eq!(c.epoch(), e1);
        c.release(1).unwrap();
        assert_ne!(c.epoch(), e1, "release must bump the epoch");
        // Distinct clusters never share an epoch, even with identical
        // occupancy — the index cache key needs no instance id.
        let a = reconfig();
        let b = reconfig();
        assert_ne!(a.epoch(), b.epoch());
    }

    #[test]
    fn cube_box_free_checks_bounds() {
        let mut c = reconfig();
        assert!(c.is_cube_box_free(0, P3([0, 0, 0]), P3([4, 4, 4])));
        assert!(!c.is_cube_box_free(0, P3([1, 0, 0]), P3([4, 4, 4])));
        c.commit(Allocation {
            job: 1,
            nodes: vec![0],
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 1]),
        });
        assert!(!c.is_cube_box_free(0, P3([0, 0, 0]), P3([1, 1, 1])));
        assert!(c.is_cube_box_free(0, P3([0, 0, 1]), P3([1, 1, 3])));
    }

    #[test]
    fn phys_coords_reconfigurable() {
        let c = reconfig();
        // node 0 of cube 0 is the origin
        assert_eq!(c.phys_coords(0), P3([0, 0, 0]));
        // first node of cube 1: grid coords (0,0,1) → physical (0,0,4)
        assert_eq!(c.phys_coords(64), P3([0, 0, 4]));
    }

    #[test]
    fn phys_coords_static() {
        let c = ClusterState::new(ClusterTopo::static_4096());
        assert_eq!(c.phys_coords(0), P3([0, 0, 0]));
        assert_eq!(c.phys_coords(16 * 16), P3([1, 0, 0]));
    }

    #[test]
    fn fail_repair_roundtrip_updates_counters_and_epoch() {
        let mut c = reconfig();
        let e0 = c.epoch();
        assert!(c.fail_node(3));
        assert!(c.is_failed(3));
        assert!(!c.is_free(3), "a failed node must read as busy to placement");
        assert_eq!(c.failed_count(), 1);
        assert_eq!(c.busy_count(), 1);
        assert_eq!(c.cube_free_count(0), 63);
        assert_eq!(c.utilization(), 0.0, "failed capacity is not utilization");
        assert_ne!(c.epoch(), e0, "failure must bump the epoch");
        c.check_consistency().unwrap();

        // Double-failure is a no-op.
        let e1 = c.epoch();
        assert!(!c.fail_node(3));
        assert_eq!(c.epoch(), e1);

        assert!(c.repair_node(3));
        assert!(!c.is_failed(3));
        assert!(c.is_free(3));
        assert_eq!(c.failed_count(), 0);
        assert_eq!(c.busy_count(), 0);
        assert_eq!(c.cube_free_count(0), 64);
        assert_ne!(c.epoch(), e1, "repair must bump the epoch");
        c.check_consistency().unwrap();
        assert!(!c.repair_node(3), "repairing a healthy node is a no-op");
    }

    #[test]
    fn utilization_excludes_failed_capacity() {
        let mut c = reconfig();
        c.commit(Allocation {
            job: 1,
            nodes: (0..64).collect(),
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([4, 4, 4]),
        });
        let before = c.utilization();
        assert_eq!(before, 64.0 / 4096.0);
        c.fail_node(100);
        // 64 working of 4095 available.
        assert_eq!(c.utilization(), 64.0 / 4095.0);
        c.repair_node(100);
        assert_eq!(c.utilization(), before);
    }

    #[test]
    fn job_on_node_finds_the_owner() {
        let mut c = reconfig();
        c.commit(Allocation {
            job: 7,
            nodes: vec![10, 11],
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 2]),
        });
        assert_eq!(c.job_on_node(10), Some(7));
        assert_eq!(c.job_on_node(11), Some(7));
        assert_eq!(c.job_on_node(12), None);
        // A failed (but unallocated) node has no owner.
        c.fail_node(20);
        assert_eq!(c.job_on_node(20), None);
    }

    #[test]
    fn occupancy_snapshot() {
        let mut c = reconfig();
        c.commit(Allocation {
            job: 1,
            nodes: vec![5],
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 1]),
        });
        let occ = c.occupancy_f32();
        assert_eq!(occ[5], 1.0);
        assert_eq!(occ[4], 0.0);
        assert_eq!(occ.iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn delta_journal_replays_commit_release_fail_repair() {
        let mut c = reconfig();
        let e0 = c.epoch();
        assert_eq!(c.changes_since(e0), Some(vec![]), "current epoch is a no-op");
        c.commit(Allocation {
            job: 1,
            nodes: vec![2, 3],
            cubes: vec![0],
            ocs_entries: 0,
            rings: vec![],
            placed_ext: P3([1, 1, 2]),
        });
        let e1 = c.epoch();
        assert_eq!(c.changes_since(e0), Some(vec![(2, true), (3, true)]));
        c.fail_node(9);
        c.release(1);
        c.repair_node(9);
        assert_eq!(
            c.changes_since(e0),
            Some(vec![
                (2, true),
                (3, true),
                (9, true),
                (2, false),
                (3, false),
                (9, false),
            ]),
            "suffix replay spans every mutation kind in order"
        );
        assert_eq!(
            c.changes_since(e1),
            Some(vec![(9, true), (2, false), (3, false), (9, false)])
        );
        // An epoch foreign to this cluster's history cannot be replayed.
        assert_eq!(reconfig().changes_since(e0), None);
    }

    #[test]
    fn delta_journal_evicts_aged_epochs() {
        let mut c = reconfig();
        let e0 = c.epoch();
        // More single-node transitions than the journal retains.
        for j in 0..(DELTA_JOURNAL_NODES as u64 + 10) {
            let n = (j % 64) as usize;
            c.commit(Allocation {
                job: j,
                nodes: vec![n],
                cubes: vec![0],
                ocs_entries: 0,
                rings: vec![],
                placed_ext: P3([1, 1, 1]),
            });
            c.release(j);
        }
        assert_eq!(c.changes_since(e0), None, "aged-out epoch must force a rebuild");
        assert!(
            c.changes_since(c.epoch()).is_some(),
            "the live epoch always replays"
        );
    }
}
