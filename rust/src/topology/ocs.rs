//! Optical-circuit-switch state: per-(axis, face-position) circuits.
//!
//! One OCS serves one `(axis, i, j)` face position across *all* cubes
//! (paper §2: "two opposing ports at the same position are connected to the
//! same OCS"). Its configuration maps each cube's `+axis` port to at most
//! one cube's `-axis` port: `next[cube] = Some(cube')` (the identity
//! `Some(cube)` is the wrap-around default; `None` is a dark port, needed
//! when a chain ends on a partially-filled cube). The map must stay
//! *injective* — an OCS is a crossbar, two inputs cannot drive one output.
//!
//! Jobs *reserve* the entries they rewire (or rely on for wrap-around
//! rings) so concurrent jobs can never steal each other's circuits.

use super::coords::CubeGrid;

/// Identifies one OCS entry: the `+axis` port of `cube` at face position
/// `(i, j)` (coordinates over the two non-axis dimensions, ascending).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct PortKey {
    pub axis: usize,
    pub i: usize,
    pub j: usize,
    pub cube: usize,
}

/// Sentinel for unreserved OCS entries.
pub const NO_OWNER: u64 = u64::MAX;

/// Full OCS plant state for a reconfigurable cluster.
#[derive(Clone, Debug)]
pub struct OcsState {
    grid: CubeGrid,
    /// `next[axis][pos][cube]`: destination of `cube`'s +axis port.
    next: Vec<Vec<Vec<Option<usize>>>>,
    /// Reservation owner per entry (`NO_OWNER` = free).
    owner: Vec<Vec<Vec<u64>>>,
}

impl OcsState {
    pub fn new(grid: CubeGrid) -> OcsState {
        let positions = grid.n * grid.n;
        let cubes = grid.num_cubes();
        let ident: Vec<Option<usize>> = (0..cubes).map(Some).collect();
        OcsState {
            grid,
            next: vec![vec![ident.clone(); positions]; 3],
            owner: vec![vec![vec![NO_OWNER; cubes]; positions]; 3],
        }
    }

    pub fn grid(&self) -> CubeGrid {
        self.grid
    }

    #[inline]
    fn pos_index(&self, i: usize, j: usize) -> usize {
        i * self.grid.n + j
    }

    /// Destination cube of `cube`'s +axis port at face position (i, j).
    pub fn next_cube(&self, key: PortKey) -> Option<usize> {
        self.next[key.axis][self.pos_index(key.i, key.j)][key.cube]
    }

    /// Is this entry currently in its wrap-around (identity) state?
    pub fn is_wrap(&self, key: PortKey) -> bool {
        self.next_cube(key) == Some(key.cube)
    }

    /// Reservation owner of an entry (NO_OWNER if free).
    pub fn owner(&self, key: PortKey) -> u64 {
        self.owner[key.axis][self.pos_index(key.i, key.j)][key.cube]
    }

    pub fn is_free(&self, key: PortKey) -> bool {
        self.owner(key) == NO_OWNER
    }

    /// Would connecting `cubes[k] → cubes[k+1]` (cyclically when `closed`)
    /// at this (axis, i, j) be legal? Every touched entry must be
    /// unreserved and still in wrap state (so the rewire cannot disturb a
    /// third party's circuit).
    pub fn can_reserve_path(
        &self,
        axis: usize,
        i: usize,
        j: usize,
        cubes: &[usize],
    ) -> bool {
        cubes.iter().all(|&c| {
            let k = PortKey { axis, i, j, cube: c };
            self.is_free(k) && self.is_wrap(k)
        })
    }

    /// Rewire `cubes[0] → cubes[1] → ...` at (axis, i, j), closing the
    /// cycle back to `cubes[0]` when `closed`, and reserve every touched
    /// entry for `job`.
    ///
    /// An open path leaves the last cube's +port dark (it ends on a
    /// partial piece whose far face is interior). A single-cube closed
    /// path reserves the cube's wrap-around circuit without rewiring.
    pub fn reserve_path(
        &mut self,
        axis: usize,
        i: usize,
        j: usize,
        cubes: &[usize],
        closed: bool,
        job: u64,
    ) -> Result<(), OcsError> {
        if !self.can_reserve_path(axis, i, j, cubes) {
            return Err(OcsError::Conflict { axis, i, j });
        }
        let pos = self.pos_index(i, j);
        let k = cubes.len();
        for idx in 0..k {
            let from = cubes[idx];
            self.owner[axis][pos][from] = job;
            if idx + 1 < k {
                self.next[axis][pos][from] = Some(cubes[idx + 1]);
            } else if closed {
                self.next[axis][pos][from] = Some(cubes[0]);
            } else {
                self.next[axis][pos][from] = None; // dark
            }
        }
        Ok(())
    }

    /// Release every entry owned by `job`, restoring wrap-around state.
    pub fn release_job(&mut self, job: u64) {
        for axis in 0..3 {
            for pos in 0..self.grid.n * self.grid.n {
                for cube in 0..self.grid.num_cubes() {
                    if self.owner[axis][pos][cube] == job {
                        self.owner[axis][pos][cube] = NO_OWNER;
                        self.next[axis][pos][cube] = Some(cube);
                    }
                }
            }
        }
    }

    /// Number of entries currently rewired away from wrap-around.
    pub fn rewired_entries(&self) -> usize {
        let mut n = 0;
        for axis in 0..3 {
            for pos in 0..self.grid.n * self.grid.n {
                for cube in 0..self.grid.num_cubes() {
                    if self.next[axis][pos][cube] != Some(cube) {
                        n += 1;
                    }
                }
            }
        }
        n
    }

    /// Number of entries reserved by any job.
    pub fn reserved_entries(&self) -> usize {
        self.owner
            .iter()
            .flat_map(|a| a.iter())
            .flat_map(|p| p.iter())
            .filter(|&&o| o != NO_OWNER)
            .count()
    }

    /// Crossbar invariant: destinations are injective per OCS, and every
    /// unreserved entry sits in wrap state. Used by property tests.
    pub fn check_invariants(&self) -> bool {
        let cubes = self.grid.num_cubes();
        for axis in 0..3 {
            for pos in 0..self.grid.n * self.grid.n {
                let mut seen = vec![false; cubes];
                for cube in 0..cubes {
                    if self.owner[axis][pos][cube] == NO_OWNER
                        && self.next[axis][pos][cube] != Some(cube)
                    {
                        return false;
                    }
                    if let Some(d) = self.next[axis][pos][cube] {
                        if d >= cubes || seen[d] {
                            return false;
                        }
                        seen[d] = true;
                    }
                }
            }
        }
        true
    }

    /// Every entry that deviates from the pristine state (reserved by a
    /// job, or rewired away from wrap-around), as
    /// `(key, owner, next_cube)` rows in ascending [`PortKey`] order.
    /// Feeding the dump to [`restore_entry`](Self::restore_entry) on a
    /// fresh plant of the same grid reproduces this state exactly.
    pub fn dump_entries(&self) -> Vec<(PortKey, u64, Option<usize>)> {
        let mut out = Vec::new();
        for axis in 0..3 {
            for i in 0..self.grid.n {
                for j in 0..self.grid.n {
                    let pos = self.pos_index(i, j);
                    for cube in 0..self.grid.num_cubes() {
                        let owner = self.owner[axis][pos][cube];
                        let next = self.next[axis][pos][cube];
                        if owner != NO_OWNER || next != Some(cube) {
                            out.push((PortKey { axis, i, j, cube }, owner, next));
                        }
                    }
                }
            }
        }
        out
    }

    /// Overwrite one entry's owner and destination verbatim — the
    /// snapshot-restore path. Bypasses the reservation checks of
    /// [`reserve_path`](Self::reserve_path): callers replay a
    /// [`dump_entries`](Self::dump_entries) capture, which satisfied the
    /// crossbar invariants when taken.
    pub fn restore_entry(&mut self, key: PortKey, owner: u64, next: Option<usize>) {
        let pos = self.pos_index(key.i, key.j);
        self.owner[key.axis][pos][key.cube] = owner;
        self.next[key.axis][pos][key.cube] = next;
    }
}

/// OCS reservation failures.
#[derive(Debug, PartialEq, Eq)]
pub enum OcsError {
    Conflict { axis: usize, i: usize, j: usize },
}

impl std::fmt::Display for OcsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OcsError::Conflict { axis, i, j } => {
                write!(f, "OCS conflict at axis {axis} position ({i},{j})")
            }
        }
    }
}

impl std::error::Error for OcsError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::coords::CubeGrid;

    fn ocs() -> OcsState {
        OcsState::new(CubeGrid::for_cluster(512, 4)) // 8 cubes of 4^3
    }

    #[test]
    fn starts_all_wrap_and_free() {
        let o = ocs();
        assert_eq!(o.rewired_entries(), 0);
        assert_eq!(o.reserved_entries(), 0);
        assert!(o.check_invariants());
    }

    #[test]
    fn closed_path_forms_cycle() {
        let mut o = ocs();
        o.reserve_path(2, 1, 1, &[0, 3, 5], true, 7).unwrap();
        let k = |c| PortKey { axis: 2, i: 1, j: 1, cube: c };
        assert_eq!(o.next_cube(k(0)), Some(3));
        assert_eq!(o.next_cube(k(3)), Some(5));
        assert_eq!(o.next_cube(k(5)), Some(0));
        assert!(o.check_invariants());
        assert_eq!(o.reserved_entries(), 3);
    }

    #[test]
    fn open_path_leaves_dark_port() {
        let mut o = ocs();
        o.reserve_path(0, 2, 2, &[1, 4, 6], false, 9).unwrap();
        let k = |c| PortKey { axis: 0, i: 2, j: 2, cube: c };
        assert_eq!(o.next_cube(k(1)), Some(4));
        assert_eq!(o.next_cube(k(4)), Some(6));
        assert_eq!(o.next_cube(k(6)), None);
        assert!(o.check_invariants());
    }

    #[test]
    fn conflicting_reservation_rejected() {
        let mut o = ocs();
        o.reserve_path(0, 0, 0, &[1, 2], true, 7).unwrap();
        let err = o.reserve_path(0, 0, 0, &[2, 4], true, 9).unwrap_err();
        assert_eq!(err, OcsError::Conflict { axis: 0, i: 0, j: 0 });
        // Different position is fine.
        o.reserve_path(0, 0, 1, &[2, 4], true, 9).unwrap();
        assert!(o.check_invariants());
    }

    #[test]
    fn single_cube_reserves_wraparound() {
        let mut o = ocs();
        o.reserve_path(1, 2, 3, &[6], true, 42).unwrap();
        let k = PortKey { axis: 1, i: 2, j: 3, cube: 6 };
        assert!(o.is_wrap(k));
        assert!(!o.is_free(k));
        assert!(o.reserve_path(1, 2, 3, &[6, 7], true, 43).is_err());
    }

    #[test]
    fn release_restores_wrap() {
        let mut o = ocs();
        o.reserve_path(0, 0, 0, &[0, 1, 2, 3], true, 5).unwrap();
        o.reserve_path(1, 0, 0, &[4, 5], false, 5).unwrap();
        assert!(o.rewired_entries() > 0);
        o.release_job(5);
        assert_eq!(o.rewired_entries(), 0);
        assert_eq!(o.reserved_entries(), 0);
        assert!(o.check_invariants());
    }

    #[test]
    fn dump_restore_round_trips() {
        let mut o = ocs();
        o.reserve_path(2, 1, 1, &[0, 3, 5], true, 7).unwrap();
        o.reserve_path(0, 2, 2, &[1, 4, 6], false, 9).unwrap();
        o.reserve_path(1, 2, 3, &[6], true, 42).unwrap();
        let dump = o.dump_entries();
        assert!(dump.windows(2).all(|w| w[0].0 < w[1].0), "dump unsorted");
        let mut fresh = ocs();
        for &(key, owner, next) in &dump {
            fresh.restore_entry(key, owner, next);
        }
        assert_eq!(fresh.dump_entries(), dump);
        assert!(fresh.check_invariants());
        assert_eq!(fresh.reserved_entries(), o.reserved_entries());
        assert_eq!(fresh.rewired_entries(), o.rewired_entries());
    }

    #[test]
    fn release_is_job_scoped() {
        let mut o = ocs();
        o.reserve_path(0, 0, 0, &[0, 1], true, 5).unwrap();
        o.reserve_path(0, 1, 1, &[2, 3], true, 6).unwrap();
        o.release_job(5);
        assert_eq!(o.reserved_entries(), 2);
        assert!(!o.is_free(PortKey { axis: 0, i: 1, j: 1, cube: 2 }));
    }
}
