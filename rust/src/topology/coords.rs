//! Integer 3-vectors and the cube-grid coordinate system.

/// Axis indices.
pub const AXES: [usize; 3] = [0, 1, 2];

/// A point or extent in 3-space (node coordinates, cube coordinates,
/// shapes...). Components are small, `usize` keeps indexing ergonomic.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug, Default)]
pub struct P3(pub [usize; 3]);

impl P3 {
    pub fn new(x: usize, y: usize, z: usize) -> Self {
        P3([x, y, z])
    }

    #[inline]
    pub fn x(&self) -> usize {
        self.0[0]
    }

    #[inline]
    pub fn y(&self) -> usize {
        self.0[1]
    }

    #[inline]
    pub fn z(&self) -> usize {
        self.0[2]
    }

    /// Product of components (volume / number of XPUs).
    pub fn volume(&self) -> usize {
        self.0[0] * self.0[1] * self.0[2]
    }

    /// Component-wise addition.
    pub fn add(&self, o: P3) -> P3 {
        P3([self.0[0] + o.0[0], self.0[1] + o.0[1], self.0[2] + o.0[2]])
    }

    /// Linearize within an extent box (row-major x-major order).
    #[inline]
    pub fn index_in(&self, ext: P3) -> usize {
        debug_assert!(self.0[0] < ext.0[0] && self.0[1] < ext.0[1] && self.0[2] < ext.0[2]);
        (self.0[0] * ext.0[1] + self.0[1]) * ext.0[2] + self.0[2]
    }

    /// Inverse of [`P3::index_in`].
    #[inline]
    pub fn from_index(i: usize, ext: P3) -> P3 {
        let z = i % ext.0[2];
        let y = (i / ext.0[2]) % ext.0[1];
        let x = i / (ext.0[1] * ext.0[2]);
        P3([x, y, z])
    }

    /// All points in the box `[0, self)` in linear order.
    pub fn iter_box(&self) -> impl Iterator<Item = P3> + '_ {
        let ext = *self;
        (0..ext.volume()).map(move |i| P3::from_index(i, ext))
    }

    /// Torus neighbour in `+axis` direction under extent `ext`.
    #[inline]
    pub fn torus_next(&self, axis: usize, ext: P3) -> P3 {
        let mut p = *self;
        p.0[axis] = (p.0[axis] + 1) % ext.0[axis];
        p
    }

    /// Torus neighbour in `-axis` direction under extent `ext`.
    #[inline]
    pub fn torus_prev(&self, axis: usize, ext: P3) -> P3 {
        let mut p = *self;
        p.0[axis] = (p.0[axis] + ext.0[axis] - 1) % ext.0[axis];
        p
    }

    /// Manhattan distance on a torus of extent `ext`.
    pub fn torus_dist(&self, o: P3, ext: P3) -> usize {
        (0..3)
            .map(|a| {
                let d = self.0[a].abs_diff(o.0[a]);
                d.min(ext.0[a] - d)
            })
            .sum()
    }

    /// Are the two points adjacent (unit step with torus wrap) on some axis?
    pub fn torus_adjacent(&self, o: P3, ext: P3) -> bool {
        self.torus_dist(o, ext) == 1
    }
}

impl std::fmt::Display for P3 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.0[0], self.0[1], self.0[2])
    }
}

/// The arrangement of cubes in the machine room: `dims` cubes per axis,
/// each of side `n`. A 4096-XPU cluster with 4³ cubes has
/// `dims = (4,4,4)`, `n = 4`; with 8³ cubes `dims = (2,2,2)`, `n = 8`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CubeGrid {
    pub dims: P3,
    pub n: usize,
}

impl CubeGrid {
    /// Build the grid housing `total` XPUs in cubes of side `n`, arranged
    /// as close to a cube as possible. Panics if `total` is not expressible.
    pub fn for_cluster(total: usize, n: usize) -> CubeGrid {
        let cubes = total / (n * n * n);
        assert_eq!(cubes * n * n * n, total, "total not a multiple of n^3");
        // Factor the cube count into the most balanced (a, b, c).
        let mut best = (1, 1, cubes);
        let mut best_spread = usize::MAX;
        for a in 1..=cubes {
            if cubes % a != 0 {
                continue;
            }
            let rest = cubes / a;
            for b in 1..=rest {
                if rest % b != 0 {
                    continue;
                }
                let c = rest / b;
                let spread = a.max(b).max(c) - a.min(b).min(c);
                if spread < best_spread {
                    best_spread = spread;
                    best = (a, b, c);
                }
            }
        }
        CubeGrid {
            dims: P3([best.0, best.1, best.2]),
            n,
        }
    }

    /// Number of cubes.
    pub fn num_cubes(&self) -> usize {
        self.dims.volume()
    }

    /// Total XPUs.
    pub fn num_xpus(&self) -> usize {
        self.num_cubes() * self.n * self.n * self.n
    }

    /// Extent of one cube.
    pub fn cube_ext(&self) -> P3 {
        P3([self.n, self.n, self.n])
    }

    /// Cube id from grid coordinates.
    pub fn cube_id(&self, c: P3) -> usize {
        c.index_in(self.dims)
    }

    /// Grid coordinates from cube id.
    pub fn cube_coords(&self, id: usize) -> P3 {
        P3::from_index(id, self.dims)
    }

    /// Global node id from (cube id, local coordinates).
    pub fn node_id(&self, cube: usize, local: P3) -> usize {
        cube * self.n * self.n * self.n + local.index_in(self.cube_ext())
    }

    /// (cube id, local coordinates) from global node id.
    pub fn split_node(&self, node: usize) -> (usize, P3) {
        let vol = self.n * self.n * self.n;
        (node / vol, P3::from_index(node % vol, self.cube_ext()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let ext = P3([3, 4, 5]);
        for p in ext.iter_box() {
            assert_eq!(P3::from_index(p.index_in(ext), ext), p);
        }
    }

    #[test]
    fn torus_neighbours_wrap() {
        let ext = P3([4, 4, 4]);
        let p = P3([3, 0, 2]);
        assert_eq!(p.torus_next(0, ext), P3([0, 0, 2]));
        assert_eq!(p.torus_prev(1, ext), P3([3, 3, 2]));
    }

    #[test]
    fn torus_distance_uses_wrap() {
        let ext = P3([16, 16, 16]);
        assert_eq!(P3([0, 0, 0]).torus_dist(P3([15, 0, 0]), ext), 1);
        assert_eq!(P3([2, 2, 2]).torus_dist(P3([2, 2, 2]), ext), 0);
        assert_eq!(P3([0, 0, 0]).torus_dist(P3([8, 8, 8]), ext), 24);
    }

    #[test]
    fn adjacency() {
        let ext = P3([4, 4, 4]);
        assert!(P3([0, 0, 0]).torus_adjacent(P3([3, 0, 0]), ext));
        assert!(!P3([0, 0, 0]).torus_adjacent(P3([1, 1, 0]), ext));
    }

    #[test]
    fn grid_for_4096_n4() {
        let g = CubeGrid::for_cluster(4096, 4);
        assert_eq!(g.num_cubes(), 64);
        assert_eq!(g.dims, P3([4, 4, 4]));
        assert_eq!(g.num_xpus(), 4096);
    }

    #[test]
    fn grid_for_4096_n8_and_n2() {
        assert_eq!(CubeGrid::for_cluster(4096, 8).num_cubes(), 8);
        assert_eq!(CubeGrid::for_cluster(4096, 2).num_cubes(), 512);
        assert_eq!(CubeGrid::for_cluster(4096, 16).num_cubes(), 1);
    }

    #[test]
    fn node_id_roundtrip() {
        let g = CubeGrid::for_cluster(4096, 4);
        for node in [0usize, 1, 63, 64, 4095, 2048] {
            let (c, l) = g.split_node(node);
            assert_eq!(g.node_id(c, l), node);
        }
    }

    #[test]
    fn volume_display() {
        assert_eq!(P3([4, 8, 2]).volume(), 64);
        assert_eq!(P3([4, 8, 2]).to_string(), "4x8x2");
    }
}
