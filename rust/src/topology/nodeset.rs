//! Packed node bitset: one bit per node in `u64` words.
//!
//! [`ClusterState`](super::cluster::ClusterState) keeps its `busy` and
//! `failed` occupancy maps in this type so a 64k-node torus costs 8 KiB
//! per map instead of 64 KiB of `Vec<bool>`, counting is a word-wise
//! `count_ones` sweep, and free-interval scans run per word (trailing
//! zeros) rather than per node. The snapshot serializer, the fault
//! layer, and `check_consistency` all ride the same representation.

/// Fixed-length set of node ids `0..len`, packed 64 per word, with a
/// maintained population count (`count` is O(1); `recount` recomputes
/// it from the words so invariant checks can cross-validate the two).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeSet {
    words: Vec<u64>,
    len: usize,
    ones: usize,
}

impl NodeSet {
    /// An empty set over the id universe `0..len`.
    pub fn new(len: usize) -> NodeSet {
        NodeSet {
            words: vec![0; len.div_ceil(64)],
            len,
            ones: 0,
        }
    }

    /// Size of the id universe (not the number of set bits).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the id universe itself is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of set bits, from the maintained counter: O(1).
    #[inline]
    pub fn count(&self) -> usize {
        self.ones
    }

    /// Number of set bits recomputed from the words with `count_ones` —
    /// the ground truth `check_consistency` compares [`count`](Self::count)
    /// against, so a drifted counter is caught, not masked.
    pub fn recount(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.len, "node {i} out of range {}", self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Set bit `i`; returns `true` if it was previously clear.
    #[inline]
    pub fn insert(&mut self, i: usize) -> bool {
        debug_assert!(i < self.len, "node {i} out of range {}", self.len);
        let w = &mut self.words[i >> 6];
        let mask = 1u64 << (i & 63);
        let fresh = *w & mask == 0;
        *w |= mask;
        self.ones += fresh as usize;
        fresh
    }

    /// Clear bit `i`; returns `true` if it was previously set.
    #[inline]
    pub fn remove(&mut self, i: usize) -> bool {
        debug_assert!(i < self.len, "node {i} out of range {}", self.len);
        let w = &mut self.words[i >> 6];
        let mask = 1u64 << (i & 63);
        let was = *w & mask != 0;
        *w &= !mask;
        self.ones -= was as usize;
        was
    }

    /// The raw words, low ids in low bits of low words. Bits at or past
    /// `len` in the final word are always zero.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// First set bit at or after `from`, scanning word-wise.
    pub fn next_one(&self, from: usize) -> Option<usize> {
        self.scan(from, |w| w)
    }

    /// First clear bit at or after `from` (and below `len`).
    pub fn next_zero(&self, from: usize) -> Option<usize> {
        self.scan(from, |w| !w)
    }

    fn scan(&self, from: usize, f: impl Fn(u64) -> u64) -> Option<usize> {
        if from >= self.len {
            return None;
        }
        let mut w = from >> 6;
        // Bits below `from` in its own word are masked off.
        let mut cur = f(self.words[w]) & (!0u64 << (from & 63));
        loop {
            if cur != 0 {
                let i = (w << 6) + cur.trailing_zeros() as usize;
                return (i < self.len).then_some(i);
            }
            w += 1;
            if w >= self.words.len() {
                return None;
            }
            cur = f(self.words[w]);
        }
    }

    /// Ascending ids of the set bits.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        let mut pos = 0usize;
        std::iter::from_fn(move || {
            let i = self.next_one(pos)?;
            pos = i + 1;
            Some(i)
        })
    }

    /// Maximal runs of *clear* bits as `(start, run_length)`, ascending —
    /// the free-interval view contiguous-placement scans want, produced
    /// with two word-level skips per run instead of a per-node walk.
    pub fn free_runs(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let mut pos = 0usize;
        std::iter::from_fn(move || {
            let start = self.next_zero(pos)?;
            let end = self.next_one(start).unwrap_or(self.len);
            pos = end;
            Some((start, end - start))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, expect};
    use crate::util::Pcg64;

    fn naive(set: &NodeSet) -> Vec<bool> {
        (0..set.len()).map(|i| set.contains(i)).collect()
    }

    fn random_set(rng: &mut Pcg64, len: usize, density_pct: u64) -> NodeSet {
        let mut s = NodeSet::new(len);
        for i in 0..len {
            if rng.below(100) < density_pct as usize {
                s.insert(i);
            }
        }
        s
    }

    #[test]
    fn insert_remove_maintain_the_count() {
        let mut s = NodeSet::new(130); // straddles a word boundary + tail
        assert!(s.insert(0));
        assert!(s.insert(64));
        assert!(s.insert(129));
        assert!(!s.insert(64), "double insert reports not-fresh");
        assert_eq!(s.count(), 3);
        assert_eq!(s.recount(), 3);
        assert!(s.remove(64));
        assert!(!s.remove(64), "double remove reports not-present");
        assert_eq!(s.count(), 2);
        assert_eq!(s.recount(), 2);
        assert!(s.contains(0) && s.contains(129) && !s.contains(64));
    }

    #[test]
    fn tail_bits_past_len_stay_zero() {
        let mut s = NodeSet::new(70);
        for i in 0..70 {
            s.insert(i);
        }
        assert_eq!(s.count(), 70);
        assert_eq!(s.words()[1] >> 6, 0, "bits past len must stay clear");
        assert_eq!(s.next_zero(0), None);
        assert_eq!(s.iter_ones().count(), 70);
    }

    #[test]
    fn prop_scans_match_bool_vec_oracle() {
        check("nodeset scans vs Vec<bool>", 60, |rng| {
            let len = 1 + rng.below(300);
            let s = random_set(rng, len, 10 + rng.below(80) as u64);
            let v = naive(&s);
            expect(
                s.count() == v.iter().filter(|&&b| b).count(),
                "count drift",
            )?;
            expect(s.count() == s.recount(), "recount drift")?;
            let ones: Vec<usize> = s.iter_ones().collect();
            let oracle_ones: Vec<usize> =
                (0..len).filter(|&i| v[i]).collect();
            expect(ones == oracle_ones, "iter_ones mismatch")?;
            // free_runs must tile exactly the clear positions.
            let mut free = vec![false; len];
            for (start, run) in s.free_runs() {
                expect(run > 0, "empty run emitted")?;
                for i in start..start + run {
                    expect(!free[i], "overlapping free runs")?;
                    free[i] = true;
                }
                // Maximality: neighbours of a run are set or out of range.
                expect(start == 0 || v[start - 1], "run start not maximal")?;
                expect(
                    start + run == len || v[start + run],
                    "run end not maximal",
                )?;
            }
            for i in 0..len {
                expect(free[i] == !v[i], "free coverage mismatch")?;
            }
            // next_one/next_zero from every origin match a linear scan.
            let probe = rng.below(len);
            expect(
                s.next_one(probe) == (probe..len).find(|&i| v[i]),
                "next_one mismatch",
            )?;
            expect(
                s.next_zero(probe) == (probe..len).find(|&i| !v[i]),
                "next_zero mismatch",
            )?;
            Ok(())
        });
    }
}
