//! Torus-cluster substrate: geometry, cubes, OCS reconfiguration, routing.
//!
//! Models the paper's §2 hardware: a 4096-XPU cluster built either as a
//! static 16×16×16 torus or from `C` hardwired `N×N×N` cubes whose face
//! ports attach to optical circuit switches (one OCS per axis × face
//! position; the two opposing ports of a cube at the same position land on
//! the same OCS). An OCS realizes an arbitrary permutation among the cubes'
//! port pairs at its position: `+face(cube A) → -face(cube π(A))`, with the
//! identity permutation meaning every cube keeps its own wrap-around link.
//!
//! Placement-relevant constraints modeled faithfully (paper §3.2):
//! * only face XPUs reach an OCS — stranded core XPUs cannot be stitched;
//! * a face port connects only to the *same position* port of another cube
//!   (misaligned free regions cannot be joined);
//! * wrap-around links exist only where a job spans a full composed
//!   dimension (multiples of the cube side N).

pub mod cluster;
pub mod coords;
pub mod nodeset;
pub mod ocs;
pub mod routing;

pub use cluster::{Allocation, ClusterState, ClusterTopo};
pub use coords::{CubeGrid, P3, AXES};
pub use nodeset::NodeSet;
pub use ocs::{OcsState, PortKey};
