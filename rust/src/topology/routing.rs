//! Dimension-order routing (DOR) and per-link load accounting.
//!
//! Torus clusters route X-then-Y-then-Z with shortest wrap direction
//! (paper §2 cites balanced DOR). Link loads drive the contention model:
//! the best-effort policy's scattered rings traverse links adjacent to
//! other jobs' nodes, and the §3.1 motivation experiment reproduces the
//! measured slowdowns from exactly this accounting.

use super::coords::P3;

/// Per-link load field over a torus of extent `ext`: `load[axis][node]` is
/// the traffic (arbitrary units) on the link from `node` towards its +axis
/// neighbour. Both directions of a physical cable share one entry — ring
/// collectives load both directions symmetrically.
#[derive(Clone, Debug)]
pub struct LinkLoads {
    pub ext: P3,
    /// Wrap-around cables exist per axis (torus) or not (mesh slice, like
    /// the §3.1 2×2 TPU v2 grid).
    pub wrap: [bool; 3],
    load: Vec<f64>, // [3 * ext.volume()], axis-major
}

impl LinkLoads {
    pub fn new(ext: P3) -> LinkLoads {
        LinkLoads {
            ext,
            wrap: [true; 3],
            load: vec![0.0; 3 * ext.volume()],
        }
    }

    /// Mesh (no wrap-around cables): routes take the in-grid direction.
    pub fn new_mesh(ext: P3) -> LinkLoads {
        LinkLoads {
            ext,
            wrap: [false; 3],
            load: vec![0.0; 3 * ext.volume()],
        }
    }

    #[inline]
    fn idx(&self, axis: usize, p: P3) -> usize {
        axis * self.ext.volume() + p.index_in(self.ext)
    }

    pub fn get(&self, axis: usize, p: P3) -> f64 {
        self.load[self.idx(axis, p)]
    }

    pub fn add(&mut self, axis: usize, p: P3, amount: f64) {
        let i = self.idx(axis, p);
        self.load[i] += amount;
    }

    /// Maximum load on any link of the whole fabric.
    pub fn max_load(&self) -> f64 {
        self.load.iter().cloned().fold(0.0, f64::max)
    }

    /// Flatten to f32 in the `[3][X][Y][Z]` layout the contention-scorer
    /// artifact expects.
    pub fn to_f32(&self) -> Vec<f32> {
        self.load.iter().map(|&l| l as f32).collect()
    }

    /// Apply `f` to every link on the DOR path from `a` to `b`, stepping
    /// the shorter wrap direction per axis, X then Y then Z.
    pub fn for_path<F: FnMut(&mut LinkLoads, usize, P3)>(
        &mut self,
        a: P3,
        b: P3,
        mut f: F,
    ) {
        let mut cur = a;
        for axis in 0..3 {
            while cur.0[axis] != b.0[axis] {
                let size = self.ext.0[axis];
                let fwd = (b.0[axis] + size - cur.0[axis]) % size;
                let bwd = size - fwd;
                let go_fwd = if !self.wrap[axis] {
                    b.0[axis] > cur.0[axis] // mesh: monotone in-grid walk
                } else {
                    fwd <= bwd
                };
                if go_fwd {
                    // +axis step: link belongs to `cur`.
                    f(self, axis, cur);
                    cur = cur.torus_next(axis, self.ext);
                } else {
                    // -axis step: link belongs to the predecessor.
                    let prev = cur.torus_prev(axis, self.ext);
                    f(self, axis, prev);
                    cur = prev;
                }
            }
        }
    }

    /// Add `amount` of traffic along the DOR path a→b. Returns hop count.
    pub fn add_path(&mut self, a: P3, b: P3, amount: f64) -> usize {
        let mut hops = 0;
        self.for_path(a, b, |s, axis, p| {
            s.add(axis, p, amount);
            hops += 1;
        });
        hops
    }

    /// Maximum load over the links of the DOR path a→b (0 if a == b).
    pub fn path_max(&mut self, a: P3, b: P3) -> f64 {
        let mut mx: f64 = 0.0;
        self.for_path(a, b, |s, axis, p| {
            mx = mx.max(s.get(axis, p));
        });
        mx
    }

    /// The distinct cables (axis, owning node) a DOR path traverses.
    pub fn path_cables(&mut self, a: P3, b: P3) -> Vec<(usize, P3)> {
        let mut out = Vec::new();
        self.for_path(a, b, |_, axis, p| out.push((axis, p)));
        out
    }

    /// The distinct cables of a whole ring (deduplicated — a 2-ring's two
    /// edges traverse the same cable once for load purposes: ring
    /// collectives stream each cable bidirectionally as one unit).
    pub fn ring_cables(&mut self, members: &[P3]) -> Vec<(usize, P3)> {
        let mut set = std::collections::BTreeSet::new();
        if members.len() >= 2 {
            for w in 0..members.len() {
                let a = members[w];
                let b = members[(w + 1) % members.len()];
                for c in self.path_cables(a, b) {
                    set.insert(c);
                }
            }
        }
        set.into_iter().collect()
    }

    /// Load a logical ring over `members`: every *distinct* cable on its
    /// DOR paths carries `unit` traffic.
    pub fn add_ring(&mut self, members: &[P3], unit: f64) {
        for (axis, p) in self.ring_cables(members) {
            self.add(axis, p, unit);
        }
    }
}

/// Hop count of the DOR path (shortest-wrap Manhattan distance).
pub fn dor_hops(a: P3, b: P3, ext: P3) -> usize {
    a.torus_dist(b, ext)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straight_path_loads_each_link_once() {
        let ext = P3([8, 8, 8]);
        let mut l = LinkLoads::new(ext);
        let hops = l.add_path(P3([0, 0, 0]), P3([3, 0, 0]), 1.0);
        assert_eq!(hops, 3);
        assert_eq!(l.get(0, P3([0, 0, 0])), 1.0);
        assert_eq!(l.get(0, P3([1, 0, 0])), 1.0);
        assert_eq!(l.get(0, P3([2, 0, 0])), 1.0);
        assert_eq!(l.get(0, P3([3, 0, 0])), 0.0);
    }

    #[test]
    fn wrap_direction_is_shorter() {
        let ext = P3([8, 1, 1]);
        let mut l = LinkLoads::new(ext);
        // 0 → 6 should go backwards over the wrap link (2 hops, via 7).
        let hops = l.add_path(P3([0, 0, 0]), P3([6, 0, 0]), 1.0);
        assert_eq!(hops, 2);
        assert_eq!(l.get(0, P3([7, 0, 0])), 1.0); // link 7→0 (wrap)
        assert_eq!(l.get(0, P3([6, 0, 0])), 1.0); // link 6→7
    }

    #[test]
    fn dor_goes_x_then_y() {
        let ext = P3([4, 4, 1]);
        let mut l = LinkLoads::new(ext);
        l.add_path(P3([0, 0, 0]), P3([1, 1, 0]), 1.0);
        // X first: link at (0,0) axis 0; then Y at (1,0) axis 1.
        assert_eq!(l.get(0, P3([0, 0, 0])), 1.0);
        assert_eq!(l.get(1, P3([1, 0, 0])), 1.0);
        assert_eq!(l.get(1, P3([0, 0, 0])), 0.0);
    }

    #[test]
    fn hops_match_torus_distance() {
        let ext = P3([16, 16, 16]);
        let mut l = LinkLoads::new(ext);
        let cases = [
            (P3([0, 0, 0]), P3([15, 15, 15])),
            (P3([1, 2, 3]), P3([9, 4, 12])),
            (P3([5, 5, 5]), P3([5, 5, 5])),
        ];
        for (a, b) in cases {
            assert_eq!(l.add_path(a, b, 0.0), dor_hops(a, b, ext));
        }
    }

    #[test]
    fn ring_on_a_row_loads_row_links() {
        let ext = P3([4, 4, 4]);
        let mut l = LinkLoads::new(ext);
        let members: Vec<P3> = (0..4).map(|x| P3([x, 0, 0])).collect();
        l.add_ring(&members, 1.0);
        // Closed ring over a full dimension: every row link carries exactly
        // one unit (3 forward hops + 1 wrap hop).
        for x in 0..4 {
            assert_eq!(l.get(0, P3([x, 0, 0])), 1.0);
        }
        assert_eq!(l.max_load(), 1.0);
    }

    #[test]
    fn diagonal_jobs_share_a_link() {
        // The §3.1 motivation setup: two 2-XPU jobs on the two diagonals of
        // a 2×2 grid (mesh — a TPU v2 slice has no wrap cables) must share
        // links.
        let ext = P3([2, 2, 1]);
        let mut l = LinkLoads::new_mesh(ext);
        l.add_ring(&[P3([0, 0, 0]), P3([1, 1, 0])], 1.0);
        l.add_ring(&[P3([1, 0, 0]), P3([0, 1, 0])], 1.0);
        assert!(l.max_load() >= 2.0, "diagonals must contend");
    }

    #[test]
    fn path_max_reads_without_adding() {
        let ext = P3([4, 1, 1]);
        let mut l = LinkLoads::new(ext);
        l.add(0, P3([1, 0, 0]), 3.0);
        assert_eq!(l.path_max(P3([0, 0, 0]), P3([2, 0, 0])), 3.0);
        // unchanged
        assert_eq!(l.get(0, P3([0, 0, 0])), 0.0);
    }
}
