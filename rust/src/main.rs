//! `rfold` — the leader binary: experiments, trace tools, and the live
//! coordinator.
//!
//! ```text
//! rfold table1   [--runs N] [--jobs J] [--seed S]      Table 1 (JCR)
//! rfold fig3     [--runs N] [--jobs J] [--seed S]      Figure 3 (JCT)
//! rfold fig4     [--runs N] [--jobs J] [--seed S]      Figure 4 (utilization)
//! rfold sweep    [--runs N] [--jobs J] [--seed S]      policy x topology x workload
//!                [--workers W] [--scenarios a,b|all]   grid, JSON rows on stdout
//!                [--policies p,q] [--out FILE]
//!                [--trace-file F]                      sweep a recorded CSV trace
//!                [--with failures=philly,...]          composable scenario modifiers
//!                [--with preempt=priority,...]         preemption / defrag knobs
//!                [--pool h1:p,h2:p]                    fan out to rfold workers
//!                [--pool-connections N]                N connections per worker host
//!                [--pool-pipeline K]                   K in-flight trials per connection
//!                [--mtbf-grid 6h,12h,24h]              failure-model ablation (FAULTGRID)
//! rfold worker   [--listen A]                          TCP trial worker daemon
//! rfold motivation                                     §3.1 contention study
//! rfold ablation [--folds] [--runs N] [--jobs J]       cube-size / fold-dim ablations
//! rfold besteffort [--runs N] [--jobs J]               §5 best-effort crossover
//! rfold simulate --policy P [--cube N|--static] ...    one cell, detailed
//!                [--trace-file F]                       replay a CSV trace instead
//! rfold trace-gen --out FILE [--jobs J] [--seed S]     write a CSV trace
//! rfold serve [--addr A] [--policy P] [--cube N]       always-on scheduling service
//!             [--queue-cap N] [--restore PATH|DIR]     (SUBMIT/STATUS/DRAIN/SNAPSHOT)
//!             [--wal FILE] [--snapshot-every 1h]       crash safety: fsynced arrival
//!             [--snapshot-dir D] [--snapshot-keep K]   journal + rotating snapshots
//! rfold submit --trace FILE [--addr A]                 replay a CSV into a live
//!              [--speedup X] [--drain]                 `rfold serve` daemon
//! rfold replay --trace FILE [--policy P] [--cube N]    replay CSV live (leader demo)
//! rfold scorer-check [--plans K]                       XLA vs native scorer
//! ```
//!
//! Every multi-run driver runs its seeded trials on the global work-queue
//! runner in `sim::sweep` (result-cached, worker threads pulling
//! (scenario, cell, trial) items); output is bit-identical for any worker
//! count and cache state.

use rfold::metrics::report;
use rfold::metrics::CellSummary;
use rfold::placement::{
    builtins, score::NativeScorer, score::PlanScorer, PlacementPolicy, PolicyHandle,
};
use rfold::sim::experiments as exp;
use rfold::sim::sweep;
use rfold::sim::{SharedTelemetry, SimConfig, Simulation};
use rfold::topology::cluster::ClusterTopo;
use rfold::trace;
use rfold::trace::scenarios::{ModifierSet, Scenario, Workload};
use rfold::util::cli::Args;
use rfold::util::Pcg64;

fn main() {
    let cmd = std::env::args().nth(1).unwrap_or_default();
    let args = Args::from_env(
        2,
        &["static", "folds", "quiet", "xla", "rows", "drain", "pool-delta"],
    );
    match cmd.as_str() {
        "table1" => table1(&args),
        "fig3" => fig3(&args),
        "fig4" => fig4(&args),
        "sweep" => sweep_cmd(&args),
        "motivation" => motivation(),
        "ablation" => ablation(&args),
        "besteffort" => besteffort(&args),
        "simulate" => simulate(&args),
        "trace-gen" => trace_gen(&args),
        "worker" => worker(&args),
        "serve" => serve(&args),
        "submit" => submit(&args),
        "replay" => replay(&args),
        "scorer-check" => scorer_check(&args),
        "workload-stats" => workload_stats(&args),
        "all" => {
            table1(&args);
            fig3(&args);
            fig4(&args);
            motivation();
        }
        _ => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    }
}

fn usage() -> &'static str {
    "usage: rfold <table1|fig3|fig4|sweep|motivation|ablation|besteffort|simulate|\
     trace-gen|worker|serve|submit|replay|scorer-check|all> [options]\n\
     common options: --runs N --jobs J --seed S --policy P --cube N|--static\n\
     scenario modifiers (sweep/simulate): --with failures=philly|exp:MTBF:REPAIR:LINKFRAC\
     |corr:MTBF:REPAIR:rack|cube|plane[:CASCADE],\
     ocs-latency=5s,stragglers=0.05,seed=U64,preempt=priority|srtf,migration-cost=30s,\
     defrag=idle,checkpoint=10m (composable, comma-separated)\n\
     sweep options:  --workers W (0=auto; --threads is an alias) \
     --scenarios a,b|all (--scenario works too) --policies p,q --out FILE --trace-file F \
     --pool host1:port,host2:port (distributed; workers run `rfold worker`) \
     --pool-connections N (connections per worker host; one connection = one busy \
     remote core, default 1) \
     --pool-pipeline K (in-flight trials per connection, default 1; hides RTT on \
     high-latency links, byte-identical output for any K) \
     --pool-timeout S (per-trial reply timeout, default 600, 0 = none) \
     --pool-delta (send repeated CSV job lists by content hash; needs new workers) \
     --cache-bytes N (resident result-cache bound, default 268435456) \
     --mtbf-grid T1,T2,... (failure-model ablation: independent exp: vs correlated \
     corr: per MTBF, FAULTGRID rows on stdout; sets its own modifiers, so no --with)\n\
     worker options: --listen A (default 127.0.0.1:7171)\n\
     simulate options: --trace-file F (replay a recorded CSV trace) \
     --rows (print one ROW {json} per job outcome — the service-mode determinism bridge)\n\
     serve options:  --addr A (default 127.0.0.1:7070) --queue-cap N (default 1024) \
     --restore PATH|DIR (resume from a snapshot file, or the newest valid *.snap in a dir) \
     --wal FILE (fsync every accepted SUBMIT before the ACK; replayed on restart) \
     --snapshot-every T (auto-snapshot cadence in virtual time, e.g. 30m, 1h) \
     --snapshot-dir D (default snapshots) --snapshot-keep K (rotation, default 4)\n\
     submit options: --trace F --addr A --speedup X (0 = no pacing, default) \
     --drain (issue DRAIN after the last job and print the ROW lines)\n\
     policies resolve by registry name (rfold, firstfit, folding, reconfig, \
     besteffort, hilbert, preempt-rfold, ...)"
}

fn runs_jobs_seed(args: &Args) -> (usize, usize, u64) {
    (
        args.get_usize("runs", 100),
        args.get_usize("jobs", 512),
        args.get_u64("seed", 1),
    )
}

/// Parse `--with key=value,...` scenario modifiers. A malformed spec is a
/// structured CLI error (exit 2) listing the valid modifiers — never a
/// panic.
fn parse_with(args: &Args) -> ModifierSet {
    match args.get("with") {
        None => ModifierSet::default(),
        Some(spec) => match ModifierSet::parse(spec) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("--with: {e}");
                std::process::exit(2);
            }
        },
    }
}

fn run_cells(cells: &[exp::Cell], args: &Args) -> Vec<CellSummary> {
    let (runs, jobs, seed) = runs_jobs_seed(args);
    cells
        .iter()
        .map(|&c| {
            eprintln!("running {} ({} runs x {} jobs)...", c.label, runs, jobs);
            exp::run_cell(c, runs, jobs, seed)
        })
        .collect()
}

fn table1(args: &Args) {
    let sums = run_cells(&exp::table1_cells(), args);
    report::print_table1(&sums);
}

fn fig3(args: &Args) {
    let sums = run_cells(&exp::fig3_cells(), args);
    report::print_fig3(&sums);
    // Headline ratios the paper quotes (11x/6x/2x at 4^3).
    let find = |l: &str| sums.iter().find(|s| s.label == l);
    if let (Some(rc), Some(rf)) = (find("Reconfig (4^3)"), find("RFold (4^3)")) {
        println!(
            "FIG3-RATIO 4^3 Reconfig/RFold p50={:.2}x p90={:.2}x p99={:.2}x",
            rc.jct_p50 / rf.jct_p50,
            rc.jct_p90 / rf.jct_p90,
            rc.jct_p99 / rf.jct_p99
        );
    }
    if let (Some(rc), Some(rf)) = (find("Reconfig (2^3)"), find("RFold (2^3)")) {
        println!(
            "FIG3-RATIO 2^3 Reconfig/RFold p50={:.2}x p90={:.2}x p99={:.2}x",
            rc.jct_p50 / rf.jct_p50,
            rc.jct_p90 / rf.jct_p90,
            rc.jct_p99 / rf.jct_p99
        );
    }
}

fn fig4(args: &Args) {
    let sums = run_cells(&exp::table1_cells(), args);
    report::print_fig4(&sums);
}

/// The full policy × topology × workload grid on the work-queue runner.
/// One `SWEEP {json}` row per cell on stdout; progress/timing and cache
/// hit/miss statistics on stderr, so stdout is byte-identical for any
/// `--workers` value — and for any `--pool`, which fans the same work
/// items out to `rfold worker` daemons over TCP.
fn sweep_cmd(args: &Args) {
    let runs = args.get_usize("runs", 8);
    let jobs = args.get_usize("jobs", 256);
    let seed = args.get_u64("seed", 1);
    // `--threads` kept as an alias from the per-cell sharding era.
    let workers = args.get_usize("workers", args.get_usize("threads", 0));
    if runs == 0 || jobs == 0 {
        eprintln!("--runs and --jobs must be >= 1");
        std::process::exit(2);
    }
    let modifiers = parse_with(args);
    // Workload axis: named synthetic scenarios, a recorded CSV trace, or
    // both. `--trace-file` alone replaces the scenario grid (the common
    // replay case); adding an explicit `--scenarios` sweeps both.
    // `--scenario` is accepted as a singular alias.
    let scenario_spec = args.get("scenarios").or_else(|| args.get("scenario"));
    let mut workloads: Vec<Workload> = match scenario_spec {
        Some(spec) => match Scenario::parse_list(spec) {
            Some(v) => v.into_iter().map(Workload::Synthetic).collect(),
            None => {
                let known: Vec<&str> = Scenario::ALL.iter().map(|s| s.name()).collect();
                eprintln!(
                    "unknown scenario in --scenarios '{spec}'; known: all, {}",
                    known.join(", ")
                );
                std::process::exit(2);
            }
        },
        None if args.get("trace-file").is_some() => Vec::new(),
        None => Scenario::ALL.iter().copied().map(Workload::Synthetic).collect(),
    };
    if let Some(path) = args.get("trace-file") {
        match Workload::from_csv(std::path::Path::new(path)) {
            Ok(w) => workloads.push(w),
            Err(e) => {
                eprintln!("cannot load --trace-file {path}: {e}");
                std::process::exit(2);
            }
        }
    }
    let cells: Vec<exp::Cell> = match args.get_policies("policies") {
        Ok(Some(handles)) => exp::table1_cells()
            .into_iter()
            .filter(|c| handles.contains(&c.policy))
            .collect(),
        Ok(None) => exp::table1_cells(),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if cells.is_empty() {
        eprintln!("--policies selected no Table-1 cells");
        std::process::exit(2);
    }
    // `--mtbf-grid 6h,12h,24h`: the failure-model ablation —
    // every selected cell at every MTBF under independent vs correlated
    // failures, as FAULTGRID rows. Its own mode: plain SWEEP rows keep
    // their exact bytes.
    if let Some(spec) = args.get("mtbf-grid") {
        let mut mtbfs = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match rfold::util::cli::parse_duration_secs(part) {
                Ok(x) if x > 0.0 => mtbfs.push(x),
                Ok(_) => {
                    eprintln!("--mtbf-grid: MTBF '{part}' must be > 0");
                    std::process::exit(2);
                }
                Err(e) => {
                    eprintln!("--mtbf-grid: {e}");
                    std::process::exit(2);
                }
            }
        }
        if mtbfs.is_empty() {
            eprintln!("--mtbf-grid needs a comma-separated duration list (e.g. 6h,12h,24h)");
            std::process::exit(2);
        }
        if !modifiers.is_empty() {
            eprintln!("--mtbf-grid sets its own failure modifiers; drop --with");
            std::process::exit(2);
        }
        eprintln!(
            "fault ablation: {} cells x {} MTBFs x 2 models x {runs} runs x {jobs} jobs",
            cells.len(),
            mtbfs.len()
        );
        let rows = exp::fault_ablation_grid(&cells, &mtbfs, runs, jobs, seed);
        report::print_fault_ablation(&rows);
        return;
    }
    let pool = args.get("pool").map(rfold::coordinator::pool::PoolExecutor::parse_pool);
    eprintln!(
        "sweep: {} cells x {} workloads x {runs} runs x {jobs} jobs ({})",
        cells.len(),
        workloads.len(),
        match &pool {
            Some(addrs) => format!(
                "pool of {} workers x {} connection(s)",
                addrs.len(),
                args.get_usize("pool-connections", 1).max(1)
            ),
            None if workers == 0 => format!("auto={} workers", sweep::auto_workers()),
            None => format!("{workers} workers"),
        }
    );
    let t0 = std::time::Instant::now();
    // One grid invocation for both backends: only the executor differs.
    let executor: Box<dyn sweep::TrialExecutor> = match pool {
        Some(addrs) => {
            if addrs.is_empty() {
                eprintln!("--pool needs at least one host:port");
                std::process::exit(2);
            }
            if args.get("workers").is_some() || args.get("threads").is_some() {
                eprintln!(
                    "note: --workers/--threads is ignored with --pool \
                     (parallelism = one connection per pool address)"
                );
            }
            Box::new(
                rfold::coordinator::pool::PoolExecutor::new(addrs)
                    .with_connections(args.get_usize("pool-connections", 1))
                    .with_pipeline(args.get_usize("pool-pipeline", 1))
                    .with_csv_delta(args.flag("pool-delta"))
                    .with_read_timeout(std::time::Duration::from_secs(
                        args.get_u64("pool-timeout", 600),
                    )),
            )
        }
        None => Box::new(sweep::LocalExecutor::new(workers)),
    };
    // `--cache-bytes` bounds the resident result cache. At the default
    // the process-global cache is kept (so `rfold all` subcommands share
    // trials); any other value gets a sweep-local cache with that exact
    // bound. Eviction policy is unchanged: oldest unpinned half first.
    let cache_bytes = args.get_usize("cache-bytes", sweep::MAX_RESIDENT_BYTES);
    if cache_bytes == 0 {
        eprintln!("--cache-bytes must be >= 1");
        std::process::exit(2);
    }
    let local_cache;
    let cache = if cache_bytes == sweep::MAX_RESIDENT_BYTES {
        sweep::ResultCache::global()
    } else {
        local_cache = sweep::ResultCache::with_capacity(cache_bytes);
        &local_cache
    };
    let rows = sweep::run_grid_with(
        &cells,
        &workloads,
        runs,
        jobs,
        seed,
        modifiers,
        cache,
        executor.as_ref(),
    );
    report::print_sweep(&rows);
    if let Some(out) = args.get("out") {
        let mut text = String::with_capacity(rows.len() * 256);
        for r in &rows {
            text.push_str(&report::sweep_row_json(r));
            text.push('\n');
        }
        std::fs::write(out, text).expect("write sweep rows");
        eprintln!("sweep: wrote {} rows to {out}", rows.len());
    }
    eprintln!(
        "sweep: {} rows in {:.1}s",
        rows.len(),
        t0.elapsed().as_secs_f64()
    );
}

fn motivation() {
    println!("\n§3.1 motivation: contention slowdowns on a 2x2 mesh");
    println!("{:<44} {:>10} {:>10}", "configuration", "model", "paper");
    let paper = [1.0, 1.17, 1.35, 1.95, 2.86];
    for (row, p) in exp::motivation_rows().iter().zip(paper) {
        println!("MOTIV {:<44} {:>9.2}x {:>9.2}x", row.0, row.1, p);
    }
}

fn ablation(args: &Args) {
    if args.flag("folds") {
        // A2: which folding dimensionalities matter for RFold(4^3)?
        let (runs, jobs, seed) = runs_jobs_seed(args);
        let cell = exp::Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        };
        println!("\nAblation A2: folding dimensionality (RFold 4^3)");
        let combos: [(&str, [bool; 3]); 5] = [
            ("all folds", [true, true, true]),
            ("no 1D folds", [false, true, true]),
            ("no 2D folds", [true, false, true]),
            ("no 3D folds", [true, true, false]),
            ("rotations only", [false, false, false]),
        ];
        for (label, dims) in combos {
            let s = exp::run_cell_with(cell, runs, jobs, seed, dims);
            println!(
                "ABLATION-FOLDS {:<16} jcr={:>6.2}% p50={} util={:.3}",
                label,
                s.avg_jcr_pct,
                report::fmt_secs(s.jct_p50),
                s.avg_util
            );
        }
    } else {
        // A1: cube-size sweep.
        let sums = run_cells(&exp::ablation_cube_cells(), args);
        println!("\nAblation A1: cube size sweep");
        for s in &sums {
            println!(
                "ABLATION-CUBES {:<16} jcr={:>6.2}% p50={} p99={} util={:.3}",
                s.label,
                s.avg_jcr_pct,
                report::fmt_secs(s.jct_p50),
                report::fmt_secs(s.jct_p99),
                s.avg_util
            );
        }
    }
}

fn besteffort(args: &Args) {
    let sums = run_cells(&exp::besteffort_cells(), args);
    println!("\n§5 best-effort vs contiguous (queueing delay vs contention)");
    for s in &sums {
        println!(
            "BESTEFFORT {:<18} jcr={:>6.2}% p50={} p99={} queue-delay={} util={:.3}",
            s.label,
            s.avg_jcr_pct,
            report::fmt_secs(s.jct_p50),
            report::fmt_secs(s.jct_p99),
            report::fmt_secs(s.avg_queue_delay),
            s.avg_util
        );
    }
}

fn parse_topo(args: &Args) -> ClusterTopo {
    if args.flag("static") {
        ClusterTopo::static_4096()
    } else {
        ClusterTopo::reconfigurable_4096(args.get_usize("cube", 4))
    }
}

/// Resolve `--policy` through the registry — the one point where a CLI
/// string becomes a [`PolicyHandle`]; unknown names exit with the list of
/// registered policies.
fn parse_policy(args: &Args, default: PolicyHandle) -> PolicyHandle {
    match args.get_policy("policy", default) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn simulate(args: &Args) {
    let policy = parse_policy(args, builtins::RFOLD);
    let topo = if policy.wants_reconfigurable() && !args.flag("static") {
        parse_topo(args)
    } else {
        ClusterTopo::static_4096()
    };
    let (runs, jobs, seed) = runs_jobs_seed(args);
    let modifiers = parse_with(args);

    // Real-trace mode (ROADMAP): `--trace-file` replays a recorded CSV
    // through the scenario registry's Workload wrapper — one realization,
    // so `--runs`/`--seed` are ignored (except as the fault-stream mix
    // under `--with`).
    if let Some(path) = args.get("trace-file") {
        let workload = match Workload::from_csv(std::path::Path::new(path)) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("cannot load --trace-file {path}: {e}");
                std::process::exit(2);
            }
        };
        let t = workload.trace(jobs, seed);
        eprintln!(
            "simulating {} on {:?}: trace '{}' ({} jobs)",
            policy.name(),
            topo,
            workload.name(),
            t.len()
        );
        let telemetry = SharedTelemetry::new();
        let mut sc = SimConfig::new(topo, policy);
        sc.modifiers = modifiers.for_trial(seed);
        let r = Simulation::new(sc)
            .with_observer(Box::new(telemetry.clone()))
            .run(&t);
        let pairs = [(&r, &t[..])];
        let s = rfold::metrics::summarize(workload.name(), &pairs);
        println!(
            "SIMULATE-TRACE trace={} policy={} jcr={:.2}% jct_p50={} jct_p90={} jct_p99={} \
             util={:.3} queue-delay={}",
            workload.name(),
            policy.name(),
            s.avg_jcr_pct,
            report::fmt_secs(s.jct_p50),
            report::fmt_secs(s.jct_p90),
            report::fmt_secs(s.jct_p99),
            s.avg_util,
            report::fmt_secs(s.avg_queue_delay),
        );
        // `--rows`: the per-job outcome encoding shared with service-mode
        // DRAIN — `rfold submit --drain` against a daemon fed the same
        // trace must produce these exact bytes.
        if args.flag("rows") {
            for row in report::outcome_rows(&r, &t) {
                println!("{row}");
            }
        }
        report::print_policy_telemetry(policy.name(), &telemetry.snapshot());
        return;
    }

    eprintln!(
        "simulating {} on {:?}: {} runs x {} jobs",
        policy.name(),
        topo,
        runs,
        jobs
    );
    let cell = exp::Cell {
        policy,
        topo,
        label: "custom",
    };
    let s = exp::run_cell_mods(cell, runs, jobs, seed, modifiers);
    println!(
        "SIMULATE policy={} jcr={:.2}% jct_p50={} jct_p90={} jct_p99={} util={:.3} queue-delay={}",
        policy.name(),
        s.avg_jcr_pct,
        report::fmt_secs(s.jct_p50),
        report::fmt_secs(s.jct_p90),
        report::fmt_secs(s.jct_p99),
        s.avg_util,
        report::fmt_secs(s.avg_queue_delay),
    );
    // Decision telemetry (stderr only, like all introspection output):
    // replay trial 0's trace with the scheduler observer attached. The
    // result-cache already holds the summary trials, so this is the only
    // extra simulation.
    let telemetry = SharedTelemetry::new();
    let tc = Scenario::PaperDefault.trace_config(jobs, sweep::trial_seed(seed, 0));
    let t = trace::gen::generate(&tc);
    let mut sc = SimConfig::new(topo, policy);
    sc.modifiers = modifiers.for_trial(sweep::trial_seed(seed, 0));
    let r = Simulation::new(sc)
        .with_observer(Box::new(telemetry.clone()))
        .run(&t);
    if args.flag("rows") {
        for row in report::outcome_rows(&r, &t) {
            println!("{row}");
        }
    }
    report::print_policy_telemetry(
        &format!("{} trial-0", policy.name()),
        &telemetry.snapshot(),
    );
}

fn trace_gen(args: &Args) {
    let out = args.get_str("out", "trace.csv").to_string();
    let cfg = trace::gen::TraceConfig {
        num_jobs: args.get_usize("jobs", 512),
        seed: args.get_u64("seed", 1),
        ..Default::default()
    };
    let t = trace::gen::generate(&cfg);
    trace::io::write_csv(std::path::Path::new(&out), &t).expect("write trace");
    println!("wrote {} jobs to {out}", t.len());
}

/// A distributed-sweep trial worker: serves `TRIAL` work items from any
/// number of leader connections (`rfold sweep --pool ...`), reconstructing
/// policies by registry name. One listener thread per connection; run
/// several leaders (or one leader listed several times behind distinct
/// daemons) to use several cores.
fn worker(args: &Args) {
    let addr = args.get_str("listen", "127.0.0.1:7171").to_string();
    rfold::coordinator::pool::serve_worker(&addr).expect("worker serve");
}

/// `rfold serve`: the always-on scheduling service — the deterministic
/// virtual-clock engine behind a `SUBMIT`/`STATUS`/`DRAIN`/`SNAPSHOT`
/// line-protocol front end. (The wall-clock leader demo that used to own
/// this verb is still exercised by `rfold replay`.)
fn serve(args: &Args) {
    let addr = args.get_str("addr", "127.0.0.1:7070").to_string();
    let queue_cap = args
        .get_usize("queue-cap", rfold::coordinator::serve::DEFAULT_QUEUE_CAP)
        .max(1);
    let snapshot_every = match args.get_duration("snapshot-every", 0.0) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if args.get("snapshot-every").is_some() && snapshot_every <= 0.0 {
        eprintln!("--snapshot-every: cadence must be > 0 (e.g. 30m, 1h); omit the flag to disable auto-snapshots");
        std::process::exit(2);
    }
    let snapshot_dir = args.get_str("snapshot-dir", "snapshots").to_string();
    let snapshot_keep = args.get_usize("snapshot-keep", 4);
    if snapshot_every > 0.0 {
        if let Err(e) = std::fs::create_dir_all(&snapshot_dir) {
            eprintln!("--snapshot-dir: cannot create {snapshot_dir}: {e}");
            std::process::exit(2);
        }
    }
    // --restore accepts a snapshot file or a directory (typically the
    // --snapshot-dir of the killed daemon): a directory scans for the
    // newest valid auto-snapshot; holding none at all means "nothing was
    // ever snapshotted — start fresh and lean on the WAL".
    let restore = match args.get("restore") {
        None => None,
        Some(path) => match rfold::coordinator::snapshot::load_newest(path) {
            Ok(Some((snap, picked))) => {
                eprintln!(
                    "serve: restoring {} accepted job(s) from {picked}",
                    snap.jobs.len()
                );
                Some(snap)
            }
            Ok(None) => {
                eprintln!("serve: {path} holds no snapshots; starting fresh");
                None
            }
            Err(e) => {
                eprintln!("--restore: {e}");
                std::process::exit(2);
            }
        },
    };
    // The WAL is both read and written: an existing journal is replayed
    // (the suffix past the restored snapshot) before the listener
    // answers, then appended to. A corrupt journal is a structured
    // refusal — resuming past it would drop acknowledged jobs.
    let wal_path = args.get("wal").map(str::to_string);
    let replay = match &wal_path {
        Some(path) if std::path::Path::new(path).exists() => {
            match rfold::coordinator::wal::replay(path) {
                Ok(r) => {
                    if r.torn {
                        eprintln!("serve: --wal: dropped a torn final record (crash mid-append; the job was never acknowledged)");
                    }
                    let skip = restore.as_ref().map_or(0, |s| s.jobs.len());
                    if skip > r.jobs.len() {
                        eprintln!(
                            "--wal: journal holds {} job(s) but the snapshot already has {skip} — wrong WAL for this snapshot?",
                            r.jobs.len()
                        );
                        std::process::exit(2);
                    }
                    r.jobs[skip..].to_vec()
                }
                Err(e) => {
                    eprintln!("--wal: {e}");
                    std::process::exit(2);
                }
            }
        }
        _ => Vec::new(),
    };
    // With --restore, topology/policy/modifiers/queue-cap all come from
    // the snapshot (that is the point: resume exactly what was running);
    // the flags below configure a fresh service only.
    let policy = parse_policy(args, builtins::RFOLD);
    let topo = parse_topo(args);
    let mut cfg = SimConfig::new(topo, policy);
    cfg.modifiers = parse_with(args).for_trial(args.get_u64("seed", 1));
    let opts = rfold::coordinator::serve::ServeOptions {
        wal: wal_path,
        replay,
        snapshot_every,
        snapshot_dir: Some(snapshot_dir),
        snapshot_keep,
    };
    rfold::coordinator::serve::serve_opts(&addr, cfg, queue_cap, restore, opts).expect("serve");
}

/// `rfold submit`: replay a recorded CSV trace into a live `rfold serve`
/// daemon, pacing inter-arrival gaps at wall-clock `gap / speedup`
/// (`--speedup 0`, the default, replays as fast as the socket allows —
/// pacing never changes the engine's virtual-clock results, only how
/// long the soak takes).
fn submit(args: &Args) {
    let addr = args.get_str("addr", "127.0.0.1:7070").to_string();
    let path = args.get_str("trace", "trace.csv").to_string();
    let t = trace::io::read_csv(std::path::Path::new(&path)).expect("read trace");
    let speedup = args.get_f64("speedup", 0.0);
    let t0 = std::time::Instant::now();
    let s = rfold::coordinator::serve::submit_trace(&addr, &t, speedup, args.flag("drain"))
        .expect("submit");
    for row in &s.rows {
        println!("{row}");
    }
    println!(
        "SUBMIT-DONE jobs={} accepted={} rejected={} errors={} rows={} wall={:.2}s",
        t.len(),
        s.accepted,
        s.rejected,
        s.errors,
        s.rows.len(),
        t0.elapsed().as_secs_f64()
    );
}

fn replay(args: &Args) {
    let path = args.get_str("trace", "trace.csv").to_string();
    let t = trace::io::read_csv(std::path::Path::new(&path)).expect("read trace");
    let policy = parse_policy(args, builtins::RFOLD);
    let topo = parse_topo(args);
    let scale = args.get_f64("time-scale", 1e-4);
    let (handle, join) = rfold::coordinator::leader::Leader::new(topo, policy, scale).spawn();
    let rep = rfold::coordinator::replay::replay(&handle, &t, scale, args.flag("quiet"));
    handle.shutdown();
    let stats = join.join().expect("leader thread");
    println!(
        "REPLAY jobs={} finished={} rejected={} wall={:.2}s busy_final={}",
        rep.submitted, stats.finished, stats.rejected, rep.wall_secs, stats.busy_xpus
    );
}

/// Analyze the synthetic workload: size/dimensionality distribution and
/// per-policy feasibility-on-empty (the upper bound on Table 1's JCR).
fn workload_stats(args: &Args) {
    let (_, jobs, seed) = runs_jobs_seed(args);
    let t = trace::gen::generate(&trace::gen::TraceConfig {
        num_jobs: jobs,
        seed,
        ..Default::default()
    });
    let n = t.len() as f64;
    let mean_size = t.iter().map(|j| j.size() as f64).sum::<f64>() / n;
    let mean_dur = t.iter().map(|j| j.duration).sum::<f64>() / n;
    let horizon = t.last().map(|j| j.arrival).unwrap_or(0.0);
    let offered = t.iter().map(|j| j.size() as f64 * j.duration).sum::<f64>()
        / (horizon * 4096.0);
    let dims = |d: usize| t.iter().filter(|j| j.shape.dimensionality() == d).count();
    let long = t
        .iter()
        .filter(|j| j.shape.dims().0.iter().any(|&x| x > 16))
        .count();
    let odd = t.iter().filter(|j| j.size() % 2 == 1).count();
    println!(
        "WORKLOAD jobs={} mean_size={mean_size:.0} mean_dur={mean_dur:.0}s \
         offered_load={offered:.2} dims=[{} {} {} {}] long_dim={}% odd={}%",
        t.len(),
        dims(0),
        dims(1),
        dims(2),
        dims(3),
        100 * long / t.len(),
        100 * odd / t.len()
    );
    let cells = [
        ("FirstFit  (16^3)", builtins::FIRST_FIT, ClusterTopo::static_4096()),
        ("Folding   (16^3)", builtins::FOLDING, ClusterTopo::static_4096()),
        ("Reconfig  (8^3)", builtins::RECONFIG, ClusterTopo::reconfigurable_4096(8)),
        ("RFold     (8^3)", builtins::RFOLD, ClusterTopo::reconfigurable_4096(8)),
        ("Reconfig  (4^3)", builtins::RECONFIG, ClusterTopo::reconfigurable_4096(4)),
        ("RFold     (4^3)", builtins::RFOLD, ClusterTopo::reconfigurable_4096(4)),
        ("Reconfig  (2^3)", builtins::RECONFIG, ClusterTopo::reconfigurable_4096(2)),
        ("RFold     (2^3)", builtins::RFOLD, ClusterTopo::reconfigurable_4096(2)),
    ];
    for (label, handle, topo) in cells {
        let mut p = handle.instantiate();
        let feasible = t
            .iter()
            .filter(|j| p.feasible_ever(topo, j.shape))
            .count();
        println!(
            "FEASIBLE {label} {:>6.2}%",
            100.0 * feasible as f64 / n
        );
    }
}

/// Compare the PJRT (AOT Pallas) scorer against the native Rust scorer on
/// random occupancy grids — the end-to-end L1↔L3 numerical check.
fn scorer_check(args: &Args) {
    let k = args.get_usize("plans", 64);
    let dir = rfold::runtime::Artifacts::default_dir();
    let arts = match rfold::runtime::Artifacts::load(&dir) {
        Ok(a) => std::rc::Rc::new(a),
        Err(e) => {
            eprintln!("cannot load artifacts from {}: {e}", dir.display());
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", arts.platform());
    let mut rng = Pcg64::seeded(args.get_u64("seed", 7));
    let mut native = NativeScorer;
    let mut xs = rfold::runtime::XlaScorer::new(arts.clone());
    let mut worst: f64 = 0.0;
    for &(cubes, n) in &[(64usize, 4usize), (8, 8), (512, 2)] {
        if !arts.has_scorer(cubes, n) {
            eprintln!("skipping {cubes}x{n}^3 (no artifact)");
            continue;
        }
        let vol = cubes * n * n * n;
        let occ: Vec<f32> = (0..k * vol)
            .map(|_| if rng.chance(0.4) { 1.0 } else { 0.0 })
            .collect();
        let a = native.frag_stats(&occ, k, cubes, n);
        let b = xs.frag_stats(&occ, k, cubes, n);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            for (u, v) in [
                (x.total_free, y.total_free),
                (x.partial_cubes, y.partial_cubes),
                (x.stranded, y.stranded),
                (x.thru, y.thru),
                (x.transitions, y.transitions),
                (x.empty_cubes, y.empty_cubes),
            ] {
                let d = (u - v).abs();
                worst = worst.max(d);
                assert!(d < 1e-3, "plan {i} ({cubes}x{n}^3): native {u} vs xla {v}");
            }
        }
        println!("SCORER-CHECK {cubes}x{n}^3: {k} plans agree (max |delta| {worst:.2e})");
    }
    println!("scorer-check OK");
}
