//! The PJRT-backed ring-AllReduce time model (`comm_model.hlo.txt`): the
//! same five-feature contract as `kernels/ref.py::comm_time`.
//!
//! The simulator's analytic fast path (`sim::contention`) covers the
//! common case; this executable exists so the L1 kernel's numerics can be
//! validated end-to-end from Rust and used by the serving loop in
//! `coordinator` when estimating step times for incoming jobs.
//!
//! Executing the artifact needs the `xla` cargo feature; the analytic twin
//! below is always available.

use std::rc::Rc;

use super::client::Artifacts;
use crate::anyhow;
use crate::util::error::Result;

/// Feature row for one ring (see `kernels/ref.py::comm_time`).
#[derive(Clone, Copy, Debug)]
pub struct CommFeatures {
    pub ring_len: f64,
    pub bytes: f64,
    pub bandwidth: f64,
    pub has_ring: bool,
    pub contention: f64,
}

/// PJRT-backed AllReduce step-time estimator.
pub struct CommModel {
    arts: Rc<Artifacts>,
}

impl CommModel {
    pub fn new(arts: Rc<Artifacts>) -> CommModel {
        CommModel { arts }
    }

    /// Estimated seconds per AllReduce for each feature row.
    #[cfg(feature = "xla")]
    pub fn estimate(&self, feats: &[CommFeatures]) -> Result<Vec<f64>> {
        let m = self.arts.manifest();
        let exe = self
            .arts
            .comm_exe()
            .ok_or_else(|| anyhow!("comm_model artifact missing"))?;
        let batch = m.comm_batch;
        let nf = m.comm_features;
        let mut out = Vec::with_capacity(feats.len());
        let mut i = 0;
        while i < feats.len() {
            let kk = (feats.len() - i).min(batch);
            let mut rows = vec![0.0f32; batch * nf];
            for (r, f) in feats[i..i + kk].iter().enumerate() {
                rows[r * nf] = f.ring_len as f32;
                rows[r * nf + 1] = f.bytes as f32;
                rows[r * nf + 2] = f.bandwidth as f32;
                rows[r * nf + 3] = if f.has_ring { 1.0 } else { 0.0 };
                rows[r * nf + 4] = f.contention as f32;
            }
            let lit = xla::Literal::vec1(&rows).reshape(&[batch as i64, nf as i64])?;
            let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let t = result.to_tuple1()?;
            let vals = t.to_vec::<f32>()?;
            crate::ensure!(vals.len() == batch, "comm model output mismatch");
            out.extend(vals[..kk].iter().map(|&v| v as f64));
            i += kk;
        }
        Ok(out)
    }

    /// Stub for builds without the `xla` feature: always errors.
    #[cfg(not(feature = "xla"))]
    pub fn estimate(&self, _feats: &[CommFeatures]) -> Result<Vec<f64>> {
        let _ = &self.arts;
        Err(anyhow!(
            "comm model requires the `xla` build feature; use CommModel::analytic"
        ))
    }

    /// The analytic twin (must match the kernel bit-for-bit-ish; tested in
    /// the integration suite).
    pub fn analytic(f: &CommFeatures) -> f64 {
        if f.ring_len <= 1.5 {
            return 0.0;
        }
        let n = f.ring_len.max(2.0);
        let base = 2.0 * (n - 1.0) / n * f.bytes / f.bandwidth.max(1e-9);
        let line = if f.has_ring { 1.0 } else { 2.0 };
        base * line * f.contention.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_ring_vs_line() {
        let ring = CommFeatures {
            ring_len: 8.0,
            bytes: 1e9,
            bandwidth: 25e9,
            has_ring: true,
            contention: 1.0,
        };
        let line = CommFeatures { has_ring: false, ..ring };
        assert!((CommModel::analytic(&line) / CommModel::analytic(&ring) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn analytic_degenerate() {
        let f = CommFeatures {
            ring_len: 1.0,
            bytes: 1e9,
            bandwidth: 25e9,
            has_ring: true,
            contention: 1.0,
        };
        assert_eq!(CommModel::analytic(&f), 0.0);
    }
}
