//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the placement hot path.
//!
//! Python runs only at build time (`make artifacts`); this module gives the
//! self-contained Rust binary the compiled plan-scorer and comm-model
//! graphs through the `xla` crate's PJRT CPU client.

pub mod client;
pub mod comm;
pub mod scorer;

pub use client::{Artifacts, Manifest};
pub use scorer::XlaScorer;
