//! The PJRT-backed plan scorer: executes the AOT `plan_scorer_*` artifact
//! (L1 Pallas fragmentation kernel + L2 composition) on candidate batches.
//!
//! Implements the same [`PlanScorer`] trait as the native Rust scorer, so
//! policies can switch between them (`--scorer xla|native`); the
//! integration suite asserts they agree on random occupancy grids.
//!
//! Execution needs the external `xla` crate (the `xla` cargo feature).
//! Without it this type still compiles — it is constructible but its
//! `frag_stats` is unreachable in practice because `Artifacts::load`
//! refuses to produce artifacts in a stub build.

use std::rc::Rc;

use super::client::Artifacts;
use crate::placement::score::{FragStats, PlanScorer};
#[cfg(feature = "xla")]
use crate::util::error::Result;

/// PJRT-backed scorer. Holds shared artifacts (one PJRT client process-
/// wide); falls back to panicking on missing variants — callers check
/// `Artifacts::has_scorer` first.
pub struct XlaScorer {
    arts: Rc<Artifacts>,
}

impl XlaScorer {
    pub fn new(arts: Rc<Artifacts>) -> XlaScorer {
        XlaScorer { arts }
    }
}

#[cfg(feature = "xla")]
impl XlaScorer {
    /// Execute the scorer artifact for `k` plans (k ≤ plan_batch after
    /// internal padding) and parse rows into [`FragStats`].
    fn run_batch(
        &self,
        occ: &[f32],
        k: usize,
        cubes: usize,
        n: usize,
    ) -> Result<Vec<FragStats>> {
        let m = self.arts.manifest();
        let batch = m.plan_batch;
        assert!(k <= batch);
        let vol = cubes * n * n * n;
        let exe = self
            .arts
            .scorer_exe(cubes, n)
            .ok_or_else(|| crate::anyhow!("no scorer artifact for {cubes}x{n}^3"))?;

        // Pad the occupancy to the fixed batch; loads/mask stay zero (the
        // contention term is handled natively by the simulator for
        // contiguous placements).
        let mut occ_pad = vec![0.0f32; batch * vol];
        occ_pad[..k * vol].copy_from_slice(&occ[..k * vol]);
        let torus_vol: usize = m.torus.iter().product();
        let loads = vec![0.0f32; 3 * torus_vol];
        let mask = vec![0.0f32; batch * torus_vol];

        let occ_lit = xla::Literal::vec1(&occ_pad).reshape(&[
            batch as i64,
            cubes as i64,
            n as i64,
            n as i64,
            n as i64,
        ])?;
        let loads_lit = xla::Literal::vec1(&loads).reshape(&[
            3,
            m.torus[0] as i64,
            m.torus[1] as i64,
            m.torus[2] as i64,
        ])?;
        let mask_lit = xla::Literal::vec1(&mask).reshape(&[
            batch as i64,
            m.torus[0] as i64,
            m.torus[1] as i64,
            m.torus[2] as i64,
        ])?;

        let result = exe.execute::<xla::Literal>(&[occ_lit, loads_lit, mask_lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let rows = out.to_vec::<f32>()?;
        let cols = m.score_cols;
        crate::ensure!(rows.len() == batch * cols, "scorer output shape mismatch");
        Ok((0..k)
            .map(|i| {
                let r = &rows[i * cols..(i + 1) * cols];
                FragStats {
                    total_free: r[0] as f64,
                    partial_cubes: r[1] as f64,
                    stranded: r[2] as f64,
                    thru: r[3] as f64,
                    transitions: r[4] as f64,
                    empty_cubes: r[5] as f64,
                }
            })
            .collect())
    }
}

impl PlanScorer for XlaScorer {
    #[cfg(feature = "xla")]
    fn frag_stats(&mut self, occ: &[f32], k: usize, cubes: usize, n: usize) -> Vec<FragStats> {
        let batch = self.arts.manifest().plan_batch;
        let vol = cubes * n * n * n;
        let mut out = Vec::with_capacity(k);
        // Chunk to the artifact's fixed batch width.
        let mut i = 0;
        while i < k {
            let kk = (k - i).min(batch);
            let chunk = &occ[i * vol..(i + kk) * vol];
            out.extend(
                self.run_batch(chunk, kk, cubes, n)
                    .expect("scorer execution failed"),
            );
            i += kk;
        }
        out
    }

    #[cfg(not(feature = "xla"))]
    fn frag_stats(&mut self, _occ: &[f32], _k: usize, _cubes: usize, _n: usize) -> Vec<FragStats> {
        let _ = &self.arts;
        unreachable!(
            "XlaScorer requires the `xla` build feature; \
             Artifacts::load refuses to construct artifacts without it"
        )
    }
}
