//! Artifact discovery + compilation: manifest.json → compiled PJRT
//! executables, one per model variant (the scorer is AOT-lowered for each
//! cube geometry; see `aot.py::SCORER_VARIANTS`).
//!
//! The PJRT pieces need the external `xla` crate, which the offline build
//! environment cannot fetch; they are gated behind the `xla` cargo
//! feature. Without it, [`Artifacts`] compiles as a stub whose `load`
//! always fails, and `Artifacts::runtime_available()` reports `false` so
//! callers (tests, benches, `rfold scorer-check`) can skip gracefully.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub plan_batch: usize,
    pub comm_batch: usize,
    pub torus: [usize; 3],
    pub score_cols: usize,
    pub comm_features: usize,
    /// stem → (file, kind, cubes, cube_side); cubes/side zero for
    /// non-scorer modules.
    pub modules: HashMap<String, (String, String, usize, usize)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let get = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let torus_arr = j
            .get("torus")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing torus"))?;
        if torus_arr.len() != 3 {
            bail!("torus must have 3 dims");
        }
        let mut torus = [0usize; 3];
        for (i, t) in torus_arr.iter().enumerate() {
            torus[i] = t.as_usize().ok_or_else(|| anyhow!("bad torus dim"))?;
        }
        let mut modules = HashMap::new();
        let mods = j
            .get("modules")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing modules"))?;
        for (stem, m) in mods {
            let file = m
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("module {stem} missing file"))?
                .to_string();
            let kind = m
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            let cubes = m.get("cubes").and_then(Json::as_usize).unwrap_or(0);
            let side = m.get("cube_side").and_then(Json::as_usize).unwrap_or(0);
            modules.insert(stem.clone(), (file, kind, cubes, side));
        }
        Ok(Manifest {
            plan_batch: get("plan_batch")?,
            comm_batch: get("comm_batch")?,
            torus,
            score_cols: get("score_cols")?,
            comm_features: get("comm_features")?,
            modules,
        })
    }
}

/// Default artifact directory: `$RFOLD_ARTIFACTS` or `./artifacts`.
fn default_artifact_dir() -> PathBuf {
    std::env::var_os("RFOLD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Compiled artifacts, ready to execute.
#[cfg(feature = "xla")]
pub struct Artifacts {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// (cubes, cube_side) → compiled plan-scorer executable.
    scorers: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    comm_model: Option<xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl Artifacts {
    /// Default artifact directory: `$RFOLD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        default_artifact_dir()
    }

    /// Whether this build can execute PJRT artifacts at all.
    pub fn runtime_available() -> bool {
        true
    }

    /// Load and compile every module listed in the manifest.
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let mut scorers = HashMap::new();
        let mut comm_model = None;
        for (stem, (file, kind, cubes, side)) in &manifest.modules {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("loading {stem}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {stem}: {e:?}"))?;
            match kind.as_str() {
                "plan_scorer" => {
                    scorers.insert((*cubes, *side), exe);
                }
                "comm_model" => comm_model = Some(exe),
                _ => {}
            }
        }
        Ok(Artifacts {
            manifest,
            client,
            scorers,
            comm_model,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has_scorer(&self, cubes: usize, side: usize) -> bool {
        self.scorers.contains_key(&(cubes, side))
    }

    pub fn scorer_exe(&self, cubes: usize, side: usize) -> Option<&xla::PjRtLoadedExecutable> {
        self.scorers.get(&(cubes, side))
    }

    pub fn comm_exe(&self) -> Option<&xla::PjRtLoadedExecutable> {
        self.comm_model.as_ref()
    }
}

/// Stub artifacts for builds without the `xla` feature: loading always
/// fails with a clear message, and no scorer is ever reported available.
/// The field is private on purpose — with `load` the only constructor and
/// always bailing, a stub `Artifacts` can never exist, which is what makes
/// the `unreachable!` in the stub `XlaScorer::frag_stats` sound.
#[cfg(not(feature = "xla"))]
pub struct Artifacts {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl Artifacts {
    /// Default artifact directory: `$RFOLD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        default_artifact_dir()
    }

    /// Whether this build can execute PJRT artifacts at all.
    pub fn runtime_available() -> bool {
        false
    }

    /// Always fails: this build cannot compile or execute PJRT artifacts.
    /// The manifest is still parsed first so configuration errors surface
    /// with the same messages as a full build.
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let _manifest = Manifest::load(dir)?;
        bail!(
            "rfold was built without the `xla` feature; cannot execute PJRT \
             artifacts from {} (the native Rust scorer is always available)",
            dir.display()
        )
    }

    pub fn platform(&self) -> String {
        "unavailable (built without `xla`)".into()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has_scorer(&self, _cubes: usize, _side: usize) -> bool {
        false
    }

    /// No executables exist in a stub build. The placeholder item type
    /// keeps callers' `is_some()` checks compiling without naming any
    /// `xla` type.
    pub fn comm_exe(&self) -> Option<&std::convert::Infallible> {
        None
    }
}
