//! Indexed binary min-heap for the engine's event queue.
//!
//! The engine orders events by the explicit key `(time, rank, seq)`
//! (PR-8): rank 0 is an arrival (seq = trace index), rank 1 is
//! everything else (seq = push counter), so every live key is unique
//! and pop order is a pure function of the keys. That makes this heap a
//! bytes-invariant drop-in for the previous
//! `BinaryHeap<Reverse<EventKey>>` — any correct min-heap pops the same
//! sequence — while adding what a plain `BinaryHeap` cannot do:
//!
//! * **in-place removal** — an eviction deletes the dead attempt's
//!   completion event via a job-id position map instead of leaving it
//!   to be lazily filtered at pop time (the incarnation filter stays as
//!   defence in depth), so a heavily preempted 64k-node run does not
//!   accumulate a heap full of stale entries;
//! * **sorted dump** — snapshots read the pending set in ascending key
//!   order with one clone + sort, no per-event `Reverse` unwrapping.
//!
//! Invariant: at most one pending completion event per job id. The
//! engine maintains this structurally — the finish-time re-arm pops
//! before it re-pushes, and an eviction removes the old attempt's event
//! before any re-placement schedules a new one.

use std::collections::HashMap;

/// f64 ordered wrapper for event keys (times are never NaN).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct OrdF64(pub f64);

impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("event times are finite")
    }
}

/// What a pending event does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum EventSlot {
    Arrival(usize),
    /// `(job id, incarnation)`: a completion is only honored if the job's
    /// incarnation still matches — a fault-kill bumps the incarnation, so
    /// the dead attempt's completion event becomes a stale no-op instead
    /// of a phantom completion.
    Completion(u64, u32),
    /// The next failure of the MTBF chain (node chosen when it fires).
    Fault,
    /// A failed node comes back.
    NodeRepair(usize),
}

/// Full event key: `(time, rank, seq, payload)`, popped in ascending
/// order. The payload participates in `Ord` only as a formality — live
/// `(time, rank, seq)` prefixes are unique.
pub(crate) type EventKey = (OrdF64, u8, u64, EventSlot);

/// The indexed min-heap. `completion_pos` tracks the heap index of each
/// pending completion event by job id; every swap keeps it current, so
/// removal is O(log n) with no scan.
pub(crate) struct EventHeap {
    heap: Vec<EventKey>,
    completion_pos: HashMap<u64, usize>,
}

impl EventHeap {
    pub fn new() -> EventHeap {
        EventHeap {
            heap: Vec::new(),
            completion_pos: HashMap::new(),
        }
    }

    #[cfg(test)]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[cfg(test)]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The minimum key, i.e. the next event to fire.
    pub fn peek(&self) -> Option<&EventKey> {
        self.heap.first()
    }

    pub fn push(&mut self, key: EventKey) {
        debug_assert!(
            !matches!(key.3, EventSlot::Completion(id, _) if self.completion_pos.contains_key(&id)),
            "one pending completion event per job"
        );
        self.heap.push(key);
        self.sift_up(self.heap.len() - 1);
    }

    pub fn pop(&mut self) -> Option<EventKey> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let key = self.heap.pop().expect("non-empty");
        self.untrack(&key);
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        Some(key)
    }

    /// Delete the pending completion event of `job` in place, wherever
    /// it sits in the heap. Returns the removed key, or `None` if no
    /// completion for that job is pending.
    pub fn remove_completion(&mut self, job: u64) -> Option<EventKey> {
        let i = self.completion_pos.remove(&job)?;
        let last = self.heap.len() - 1;
        self.heap.swap(i, last);
        let key = self.heap.pop().expect("tracked index implies non-empty");
        debug_assert!(matches!(key.3, EventSlot::Completion(id, _) if id == job));
        if i < self.heap.len() {
            // The element moved into the hole can be out of order in
            // either direction relative to its new neighbourhood.
            let j = self.sift_up(i);
            if j == i {
                self.sift_down(i);
            }
        }
        Some(key)
    }

    /// The pending events in ascending key order — the snapshot dump.
    pub fn sorted(&self) -> Vec<EventKey> {
        let mut evs = self.heap.clone();
        evs.sort_unstable();
        evs
    }

    /// Record the position of the element now at `i` (completions only).
    #[inline]
    fn track(&mut self, i: usize) {
        if let EventSlot::Completion(id, _) = self.heap[i].3 {
            self.completion_pos.insert(id, i);
        }
    }

    #[inline]
    fn untrack(&mut self, key: &EventKey) {
        if let EventSlot::Completion(id, _) = key.3 {
            self.completion_pos.remove(&id);
        }
    }

    /// Bubble `i` toward the root; returns the final index.
    fn sift_up(&mut self, mut i: usize) -> usize {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.heap[i] < self.heap[p] {
                self.heap.swap(i, p);
                self.track(i);
                i = p;
            } else {
                break;
            }
        }
        self.track(i);
        i
    }

    /// Push `i` toward the leaves; returns the final index.
    fn sift_down(&mut self, mut i: usize) -> usize {
        loop {
            let l = 2 * i + 1;
            if l >= self.heap.len() {
                break;
            }
            let r = l + 1;
            let c = if r < self.heap.len() && self.heap[r] < self.heap[l] {
                r
            } else {
                l
            };
            if self.heap[c] < self.heap[i] {
                self.heap.swap(i, c);
                self.track(i);
                i = c;
            } else {
                break;
            }
        }
        self.track(i);
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, expect};
    use crate::util::Pcg64;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Random key stream with the engine's uniqueness discipline: rank-1
    /// seqs strictly increase, rank-0 (arrival) seqs are distinct trace
    /// indices, and at most one pending completion per job id.
    fn random_key(rng: &mut Pcg64, seq: &mut u64, pending_jobs: &mut Vec<u64>) -> EventKey {
        *seq += 1;
        let t = OrdF64((rng.below(50) as f64) * 0.25);
        match rng.below(4) {
            0 => (t, 0, *seq, EventSlot::Arrival(*seq as usize)),
            1 => {
                let job = 1000 + *seq;
                pending_jobs.push(job);
                (t, 1, *seq, EventSlot::Completion(job, rng.below(3) as u32))
            }
            2 => (t, 1, *seq, EventSlot::Fault),
            _ => (t, 1, *seq, EventSlot::NodeRepair(rng.below(64))),
        }
    }

    #[test]
    fn prop_pop_sequence_matches_the_old_binary_heap() {
        // The exact structure the engine used before the swap: pops must
        // be byte-for-byte the same sequence on any recorded event log.
        check("indexed heap vs BinaryHeap<Reverse<_>>", 40, |rng| {
            let mut ours = EventHeap::new();
            let mut old: BinaryHeap<Reverse<EventKey>> = BinaryHeap::new();
            let (mut seq, mut jobs) = (0u64, Vec::new());
            for _ in 0..rng.range(1, 120) {
                let key = random_key(rng, &mut seq, &mut jobs);
                ours.push(key);
                old.push(Reverse(key));
            }
            // Interleave pops with fresh pushes, as the engine does.
            while !ours.is_empty() {
                expect(ours.peek() == old.peek().map(|r| &r.0), "peek drift")?;
                expect(ours.pop() == old.pop().map(|r| r.0), "pop drift")?;
                if rng.chance(0.2) {
                    let key = random_key(rng, &mut seq, &mut jobs);
                    ours.push(key);
                    old.push(Reverse(key));
                }
            }
            expect(old.is_empty(), "old heap has leftovers")?;
            Ok(())
        });
    }

    #[test]
    fn prop_removal_deletes_exactly_the_jobs_event() {
        check("in-place completion removal", 40, |rng| {
            let mut heap = EventHeap::new();
            let mut model: Vec<EventKey> = Vec::new();
            let (mut seq, mut jobs) = (0u64, Vec::new());
            for _ in 0..rng.range(2, 100) {
                let key = random_key(rng, &mut seq, &mut jobs);
                heap.push(key);
                model.push(key);
            }
            // Remove a random subset of pending completions in place.
            while !jobs.is_empty() && rng.chance(0.7) {
                let job = jobs.swap_remove(rng.below(jobs.len()));
                let removed = heap.remove_completion(job);
                let at = model
                    .iter()
                    .position(|k| matches!(k.3, EventSlot::Completion(j, _) if j == job));
                expect(
                    removed == at.map(|i| model.swap_remove(i)),
                    "removal mismatch",
                )?;
                expect(
                    heap.remove_completion(job).is_none(),
                    "double removal must be a no-op",
                )?;
            }
            expect(heap.len() == model.len(), "length drift")?;
            // Survivors drain in exactly sorted-model order.
            model.sort_unstable();
            expect(heap.sorted() == model, "sorted dump mismatch")?;
            for want in model {
                expect(heap.pop() == Some(want), "post-removal pop drift")?;
            }
            expect(heap.pop().is_none(), "heap must drain")?;
            Ok(())
        });
    }
}
