//! Scheduler observer hooks: the engine's structured introspection
//! surface.
//!
//! A [`SchedulerObserver`] is attached to a
//! [`Simulation`](crate::sim::Simulation) via
//! [`with_observer`](crate::sim::Simulation::with_observer) and receives
//! every admission, placement decision (with its
//! [`DecisionStats`](crate::placement::DecisionStats) and wall time), OCS
//! reconfiguration, and completion. Observers are read-only bystanders:
//! nothing they see or do flows back into scheduling, so attaching one
//! never changes result bytes — telemetry is reported on stderr only
//! (`metrics::report::print_policy_telemetry`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Duration;

use crate::placement::PlacementDecision;

/// Engine lifecycle hooks. All methods default to no-ops so observers
/// implement only what they care about.
pub trait SchedulerObserver {
    /// A job entered the FIFO queue at simulation time `t`.
    fn on_admit(&mut self, t: f64, job: u64) {
        let _ = (t, job);
    }

    /// The policy answered a placement request. `wall` is the real time
    /// the decision took (diagnostics only — it never feeds back into
    /// simulation time).
    fn on_decision(&mut self, t: f64, job: u64, decision: &PlacementDecision, wall: Duration) {
        let _ = (t, job, decision, wall);
    }

    /// A committed plan reprogrammed the OCS (`ocs_entries` > 0 switch
    /// entries reserved).
    fn on_reconfig(&mut self, t: f64, job: u64, ocs_entries: usize) {
        let _ = (t, job, ocs_entries);
    }

    /// A job released its allocation.
    fn on_complete(&mut self, t: f64, job: u64, start: f64, finish: f64) {
        let _ = (t, job, start, finish);
    }

    /// Fault injection hit `node` at time `t`. Link faults are transient
    /// (the touching job dies, capacity survives); node faults take the
    /// node out until [`on_repair`](Self::on_repair).
    fn on_fault(&mut self, t: f64, node: usize, is_link: bool) {
        let _ = (t, node, is_link);
    }

    /// A failed node came back at time `t`.
    fn on_repair(&mut self, t: f64, node: usize) {
        let _ = (t, node);
    }

    /// A correlated fault (`--with failures=corr:..`) failed an entire
    /// domain atomically: `size` nodes went down in one blast (the
    /// cascade neighbour included when `cascaded`). Fires once per fault
    /// event, after the per-node [`on_fault`](Self::on_fault) calls.
    fn on_domain_fault(&mut self, t: f64, domain: usize, size: usize, cascaded: bool) {
        let _ = (t, domain, size, cascaded);
    }

    /// A running job was killed by a fault and returned to the queue (or
    /// abandoned after too many retries).
    fn on_job_killed(&mut self, t: f64, job: u64) {
        let _ = (t, job);
    }

    /// An in-flight job was stalled by `delay` seconds because an OCS
    /// reconfiguration touched cubes it occupies.
    fn on_stall(&mut self, t: f64, job: u64, delay: f64) {
        let _ = (t, job, delay);
    }

    /// `victim` was evicted by a preemptive scheduling decision to make
    /// room for `for_job`; `wasted` node-seconds of its work beyond the
    /// last credited checkpoint will re-run.
    fn on_preempt(&mut self, t: f64, victim: u64, for_job: u64, wasted: f64) {
        let _ = (t, victim, for_job, wasted);
    }

    /// An idle-time defragmentation pass relocated `moved` running jobs.
    fn on_defrag(&mut self, t: f64, moved: usize) {
        let _ = (t, moved);
    }

    /// A restarting (previously evicted) job was charged `cost` seconds
    /// of migration surcharge on its new placement.
    fn on_migration(&mut self, t: f64, job: u64, cost: f64) {
        let _ = (t, job, cost);
    }
}

/// Aggregated per-policy decision telemetry: what the scheduler tried and
/// how long deciding took. Rendered by
/// `metrics::report::print_policy_telemetry` (stderr only).
#[derive(Clone, Debug, Default)]
pub struct DecisionTelemetry {
    /// Placement decisions observed, by outcome.
    pub decisions: u64,
    pub placed: u64,
    pub no_capacity: u64,
    pub infeasible: u64,
    /// Search effort summed over all decisions.
    pub variants_enumerated: u64,
    pub folds_tried: u64,
    pub candidates_ranked: u64,
    /// Commits that reprogrammed the OCS, and the entries they reserved.
    pub reconfigurations: u64,
    pub ocs_entries_reserved: u64,
    pub admissions: u64,
    pub completions: u64,
    /// Total wall time spent inside `PlacementPolicy::plan`.
    pub decision_wall: Duration,
    /// Fault-injection counters (all zero without `--with` modifiers;
    /// rendered as the stderr-only `FAULTS` section).
    pub node_failures: u64,
    pub link_failures: u64,
    pub repairs: u64,
    pub jobs_killed: u64,
    pub jobs_stalled: u64,
    /// Correlated-fault counters (`--with failures=corr:..` only):
    /// domain-level blast events, cascades, and a blast-size histogram
    /// (nodes taken down per event → occurrences).
    pub domain_faults: u64,
    pub domain_cascades: u64,
    pub blast_sizes: BTreeMap<usize, u64>,
    /// Total stall time injected by OCS reconfigurations (s).
    pub stall_time: f64,
    /// Disruption counters (all zero without preemption/defrag knobs;
    /// rendered as the stderr-only `PREEMPT` section).
    pub preemptions: u64,
    /// Node-seconds of work thrown away by evictions.
    pub preempt_wasted: f64,
    pub migrations: u64,
    /// Total migration surcharge charged (s).
    pub migration_time: f64,
    /// Defrag passes that moved at least one job, and the moves made.
    pub defrag_passes: u64,
    pub defrag_moves: u64,
}

impl DecisionTelemetry {
    /// Mean decision wall time in microseconds (0 when no decisions).
    pub fn mean_decision_us(&self) -> f64 {
        if self.decisions == 0 {
            return 0.0;
        }
        self.decision_wall.as_secs_f64() * 1e6 / self.decisions as f64
    }

    fn record_decision(&mut self, decision: &PlacementDecision, wall: Duration) {
        self.decisions += 1;
        match decision {
            PlacementDecision::Placed { .. } => self.placed += 1,
            PlacementDecision::NoCapacity { .. } => self.no_capacity += 1,
            PlacementDecision::Infeasible { .. } => self.infeasible += 1,
        }
        let stats = decision.stats();
        self.variants_enumerated += stats.variants as u64;
        self.folds_tried += stats.folds_tried as u64;
        self.candidates_ranked += stats.candidates as u64;
        self.decision_wall += wall;
    }
}

impl SchedulerObserver for DecisionTelemetry {
    fn on_admit(&mut self, _t: f64, _job: u64) {
        self.admissions += 1;
    }

    fn on_decision(&mut self, _t: f64, _job: u64, decision: &PlacementDecision, wall: Duration) {
        self.record_decision(decision, wall);
    }

    fn on_reconfig(&mut self, _t: f64, _job: u64, ocs_entries: usize) {
        self.reconfigurations += 1;
        self.ocs_entries_reserved += ocs_entries as u64;
    }

    fn on_complete(&mut self, _t: f64, _job: u64, _start: f64, _finish: f64) {
        self.completions += 1;
    }

    fn on_fault(&mut self, _t: f64, _node: usize, is_link: bool) {
        if is_link {
            self.link_failures += 1;
        } else {
            self.node_failures += 1;
        }
    }

    fn on_repair(&mut self, _t: f64, _node: usize) {
        self.repairs += 1;
    }

    fn on_domain_fault(&mut self, _t: f64, _domain: usize, size: usize, cascaded: bool) {
        self.domain_faults += 1;
        if cascaded {
            self.domain_cascades += 1;
        }
        *self.blast_sizes.entry(size).or_insert(0) += 1;
    }

    fn on_job_killed(&mut self, _t: f64, _job: u64) {
        self.jobs_killed += 1;
    }

    fn on_stall(&mut self, _t: f64, _job: u64, delay: f64) {
        self.jobs_stalled += 1;
        self.stall_time += delay;
    }

    fn on_preempt(&mut self, _t: f64, _victim: u64, _for_job: u64, wasted: f64) {
        self.preemptions += 1;
        self.preempt_wasted += wasted;
    }

    fn on_defrag(&mut self, _t: f64, moved: usize) {
        self.defrag_passes += 1;
        self.defrag_moves += moved as u64;
    }

    fn on_migration(&mut self, _t: f64, _job: u64, cost: f64) {
        self.migrations += 1;
        self.migration_time += cost;
    }
}

/// Shared telemetry handle: clone one half into the simulation as a boxed
/// observer, keep the other to read after `run` consumed the box.
/// `Rc`-based on purpose — simulations (and PJRT scorers) are
/// single-threaded, and each sweep worker builds its own.
#[derive(Clone, Default)]
pub struct SharedTelemetry(Rc<RefCell<DecisionTelemetry>>);

impl SharedTelemetry {
    pub fn new() -> SharedTelemetry {
        SharedTelemetry::default()
    }

    /// Copy of the counters accumulated so far.
    pub fn snapshot(&self) -> DecisionTelemetry {
        self.0.borrow().clone()
    }
}

impl SchedulerObserver for SharedTelemetry {
    fn on_admit(&mut self, t: f64, job: u64) {
        self.0.borrow_mut().on_admit(t, job);
    }

    fn on_decision(&mut self, t: f64, job: u64, decision: &PlacementDecision, wall: Duration) {
        self.0.borrow_mut().on_decision(t, job, decision, wall);
    }

    fn on_reconfig(&mut self, t: f64, job: u64, ocs_entries: usize) {
        self.0.borrow_mut().on_reconfig(t, job, ocs_entries);
    }

    fn on_complete(&mut self, t: f64, job: u64, start: f64, finish: f64) {
        self.0.borrow_mut().on_complete(t, job, start, finish);
    }

    fn on_fault(&mut self, t: f64, node: usize, is_link: bool) {
        self.0.borrow_mut().on_fault(t, node, is_link);
    }

    fn on_repair(&mut self, t: f64, node: usize) {
        self.0.borrow_mut().on_repair(t, node);
    }

    fn on_domain_fault(&mut self, t: f64, domain: usize, size: usize, cascaded: bool) {
        self.0.borrow_mut().on_domain_fault(t, domain, size, cascaded);
    }

    fn on_job_killed(&mut self, t: f64, job: u64) {
        self.0.borrow_mut().on_job_killed(t, job);
    }

    fn on_stall(&mut self, t: f64, job: u64, delay: f64) {
        self.0.borrow_mut().on_stall(t, job, delay);
    }

    fn on_preempt(&mut self, t: f64, victim: u64, for_job: u64, wasted: f64) {
        self.0.borrow_mut().on_preempt(t, victim, for_job, wasted);
    }

    fn on_defrag(&mut self, t: f64, moved: usize) {
        self.0.borrow_mut().on_defrag(t, moved);
    }

    fn on_migration(&mut self, t: f64, job: u64, cost: f64) {
        self.0.borrow_mut().on_migration(t, job, cost);
    }
}

/// Per-decision wall-clock latency collector for service telemetry:
/// records every `decide` call's duration in microseconds so the serve
/// loop can report p50/p99 decision latency over the daemon's lifetime.
/// Same `Rc` split as [`SharedTelemetry`]: one clone goes into the
/// engine as a boxed observer, the other stays with the service thread.
#[derive(Clone, Default)]
pub struct DecisionLatency(Rc<RefCell<Vec<f64>>>);

impl DecisionLatency {
    pub fn new() -> DecisionLatency {
        DecisionLatency::default()
    }

    /// All decision latencies recorded so far, in call order (µs).
    pub fn samples(&self) -> Vec<f64> {
        self.0.borrow().clone()
    }
}

impl SchedulerObserver for DecisionLatency {
    fn on_decision(&mut self, _t: f64, _job: u64, _decision: &PlacementDecision, wall: Duration) {
        self.0.borrow_mut().push(wall.as_secs_f64() * 1e6);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::DecisionStats;

    #[test]
    fn telemetry_classifies_outcomes() {
        let mut t = DecisionTelemetry::default();
        let stats = DecisionStats {
            variants: 4,
            folds_tried: 2,
            candidates: 3,
        };
        t.record_decision(
            &PlacementDecision::NoCapacity { stats },
            Duration::from_micros(10),
        );
        t.record_decision(
            &PlacementDecision::Infeasible { stats },
            Duration::from_micros(20),
        );
        assert_eq!(t.decisions, 2);
        assert_eq!(t.no_capacity, 1);
        assert_eq!(t.infeasible, 1);
        assert_eq!(t.placed, 0);
        assert_eq!(t.variants_enumerated, 8);
        assert_eq!(t.folds_tried, 4);
        assert_eq!(t.candidates_ranked, 6);
        assert!((t.mean_decision_us() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn shared_telemetry_reads_after_boxing() {
        let shared = SharedTelemetry::new();
        let mut boxed: Box<dyn SchedulerObserver> = Box::new(shared.clone());
        boxed.on_admit(0.0, 1);
        boxed.on_reconfig(1.0, 1, 6);
        boxed.on_complete(2.0, 1, 1.0, 2.0);
        let snap = shared.snapshot();
        assert_eq!(snap.admissions, 1);
        assert_eq!(snap.reconfigurations, 1);
        assert_eq!(snap.ocs_entries_reserved, 6);
        assert_eq!(snap.completions, 1);
        assert_eq!(snap.mean_decision_us(), 0.0);
    }

    #[test]
    fn fault_hooks_accumulate_counters() {
        let shared = SharedTelemetry::new();
        let mut boxed: Box<dyn SchedulerObserver> = Box::new(shared.clone());
        boxed.on_fault(1.0, 5, false);
        boxed.on_fault(2.0, 9, true);
        boxed.on_fault(3.0, 5, false);
        boxed.on_repair(4.0, 5);
        boxed.on_job_killed(2.0, 7);
        boxed.on_stall(5.0, 8, 2.5);
        boxed.on_stall(6.0, 9, 1.5);
        let snap = shared.snapshot();
        assert_eq!(snap.node_failures, 2);
        assert_eq!(snap.link_failures, 1);
        assert_eq!(snap.repairs, 1);
        assert_eq!(snap.jobs_killed, 1);
        assert_eq!(snap.jobs_stalled, 2);
        assert_eq!(snap.stall_time, 4.0);
        assert_eq!(snap.domain_faults, 0);
    }

    #[test]
    fn domain_fault_hook_builds_the_blast_histogram() {
        let shared = SharedTelemetry::new();
        let mut boxed: Box<dyn SchedulerObserver> = Box::new(shared.clone());
        boxed.on_domain_fault(1.0, 3, 256, false);
        boxed.on_domain_fault(2.0, 7, 512, true);
        boxed.on_domain_fault(3.0, 3, 256, false);
        let snap = shared.snapshot();
        assert_eq!(snap.domain_faults, 3);
        assert_eq!(snap.domain_cascades, 1);
        assert_eq!(snap.blast_sizes.get(&256), Some(&2));
        assert_eq!(snap.blast_sizes.get(&512), Some(&1));
    }

    #[test]
    fn disruption_hooks_accumulate_counters() {
        let shared = SharedTelemetry::new();
        let mut boxed: Box<dyn SchedulerObserver> = Box::new(shared.clone());
        boxed.on_preempt(1.0, 3, 9, 4096.0);
        boxed.on_preempt(2.0, 4, 9, 512.0);
        boxed.on_migration(3.0, 3, 30.0);
        boxed.on_defrag(4.0, 2);
        let snap = shared.snapshot();
        assert_eq!(snap.preemptions, 2);
        assert_eq!(snap.preempt_wasted, 4608.0);
        assert_eq!(snap.migrations, 1);
        assert_eq!(snap.migration_time, 30.0);
        assert_eq!(snap.defrag_passes, 1);
        assert_eq!(snap.defrag_moves, 2);
    }
}
