//! The discrete-event simulation engine.
//!
//! Admission is a scheduler decision loop over the FIFO queue head: the
//! policy's [`PlacementPolicy::decide`] returns a [`SchedAction`] —
//! Admit / Reconfigure / Queue / Reject / Preempt — and the engine acts
//! on it. With no preemption knobs this degenerates to the paper's §4
//! FIFO semantics exactly: "an unscheduled job will block all subsequent
//! jobs. If a job cannot be scheduled because of its incompatible shape,
//! the scheduler removes it from the system and proceeds to the next."
//! With `--with preempt=priority|srtf[,migration-cost=..,defrag=idle,
//! checkpoint=..]` the engine additionally evicts running jobs for a
//! blocked head (checkpoint-restart with a configurable migration
//! surcharge) and compacts the cluster when the head is
//! capacity-blocked.

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Instant;

use crate::placement::best_effort;
use crate::placement::{
    PlacementDecision, PlacementPolicy, PlacementRequest, PolicyHandle, RunningJob, SchedAction,
};
use crate::sim::contention::{effective_duration, ContentionModel};
use crate::sim::domains::DomainMap;
use crate::sim::event_heap::{EventHeap, EventSlot, OrdF64};
use crate::sim::observer::SchedulerObserver;
use crate::topology::cluster::{Allocation, ClusterState, ClusterTopo};
use crate::trace::scenarios::ModifierSet;
use crate::trace::JobSpec;
use crate::util::json::Json;
use crate::util::stats::WeightedCdf;
use crate::util::Pcg64;

/// Stream id of the fault RNG — distinct from the trace generator's
/// `0x7ace`, so fault draws can never perturb job arrivals.
const FAULT_STREAM: u64 = 0xFA;

/// A job killed by faults more often than this is abandoned (`Dropped`)
/// instead of requeued — the Philly schedulers' retry-then-give-up
/// policy. Without a cap, a heavy-tail job (up to 30 days) under a
/// realistic MTBF is killed before finishing with near certainty and the
/// simulation would requeue it forever.
const MAX_KILL_RETRIES: u32 = 3;

/// A job preempted this often becomes immune to further preemption (it is
/// excluded from the victim snapshot) — a starvation guard. Unlike the
/// fault-kill cap it never drops the job: preemption is a scheduling
/// choice, not an external failure.
const MAX_PREEMPTIONS: u32 = 3;

/// Why a running job is being evicted — one mechanism, two triggers.
#[derive(Clone, Copy, Debug)]
enum EvictReason {
    /// A fault landed on one of its nodes (PR-6 `kill_job` semantics:
    /// FIFO-ordered requeue, retry cap, drop on exhaustion).
    Fault,
    /// A preemptive scheduling decision evicted it for a blocked head
    /// (requeued at the tail, never dropped, starvation-capped).
    Preempt { for_job: u64 },
}

/// Execution record of a running job, kept only when a disruption knob
/// (preempt / defrag / checkpoint) is active: enough to convert elapsed
/// wall-clock into useful base-duration work at eviction time.
#[derive(Clone, Copy, Debug)]
struct RunInfo {
    /// Effective (stretched) wall-clock duration of this attempt.
    eff: f64,
    /// Remaining base duration this attempt started with.
    base: f64,
}

/// Simulation configuration. The policy is a registry handle resolved
/// once at config-build time; the engine instantiates it per run.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub topo: ClusterTopo,
    pub policy: PolicyHandle,
    /// Ablation A2: which job dimensionalities may be folded.
    pub fold_dims_enabled: [bool; 3],
    /// `true` (default): keep scheduling until the queue drains — JCR is
    /// then feasibility-limited, matching Table 1 (the paper's FIFO
    /// removes only shape-incompatible jobs; everything else eventually
    /// runs). `false`: freeze scheduling at the last arrival and count
    /// still-queued jobs as `NotScheduled` (a stricter JCR for ablation).
    pub drain: bool,
    /// Fault-injection modifiers (`--with`). The default (empty) set
    /// leaves every byte of a run unchanged; callers running sweeps are
    /// expected to pass a *per-trial* set
    /// ([`ModifierSet::for_trial`]) so trials draw independent fault
    /// realizations.
    pub modifiers: ModifierSet,
}

impl SimConfig {
    /// Accepts a [`PolicyHandle`] or (via the deprecated shim) a
    /// `PolicyKind`.
    pub fn new(topo: ClusterTopo, policy: impl Into<PolicyHandle>) -> SimConfig {
        SimConfig {
            topo,
            policy: policy.into(),
            fold_dims_enabled: [true; 3],
            drain: true,
            modifiers: ModifierSet::default(),
        }
    }
}

/// Per-job outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JobOutcome {
    /// Placed and finished: (start, finish).
    Completed { start: f64, finish: f64 },
    /// Removed at admission (shape incompatible with the topology).
    Dropped,
    /// Feasible but never scheduled within the workload horizon (the
    /// paper's JCR counts these as failures: a job queued past the end of
    /// the trace was not "successfully scheduled").
    NotScheduled,
}

/// Aggregated result of one simulated trace run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Display name of the policy that produced the run.
    pub policy: &'static str,
    pub outcomes: Vec<(u64, JobOutcome)>,
    /// Time-weighted utilization samples.
    pub utilization: WeightedCdf,
    pub scheduled: usize,
    pub dropped: usize,
    /// Wall-clock span of the run (first arrival → last completion).
    pub makespan: f64,
    /// Evictions made by preemptive scheduling decisions (not fault
    /// kills). 0 whenever preemption is disabled.
    pub preemptions: usize,
    /// Node-seconds of evicted-then-rerun work: wall-clock a victim spent
    /// running beyond its last credited checkpoint, times its node count.
    /// Accumulated by both preemptions and (when checkpointing is on)
    /// fault kills; exactly 0.0 when no disruption knob is active.
    pub wasted_work: f64,
    /// Total restart surcharge (s) charged through `migration-cost=`.
    pub migration_time: f64,
    /// Utilization with wasted work removed: `mean − wasted /(nodes ×
    /// window)`, clamped at 0 — the number preempting policies are judged
    /// on, so eviction churn cannot inflate the metric. Equals
    /// `utilization.mean()` bit-for-bit when `wasted_work == 0`.
    pub useful_util: f64,
}

impl RunResult {
    /// Job completion rate (Table 1).
    pub fn jcr(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.scheduled as f64 / self.outcomes.len() as f64
    }

    /// `(start, finish, arrival)` of every completed job, in job-id
    /// order — the one arrivals-map build and sort behind every
    /// per-completed-job metric. Jobs absent from `trace` (a caller
    /// handed the wrong trace for this run) are skipped rather than
    /// panicking on a missing arrival; debug builds still assert so the
    /// mismatch is caught in tests.
    fn completed_triples(&self, trace: &[JobSpec]) -> Vec<(f64, f64, f64)> {
        let arrivals: HashMap<u64, f64> = trace.iter().map(|j| (j.id, j.arrival)).collect();
        let mut rows: Vec<(u64, (f64, f64, f64))> = self
            .outcomes
            .iter()
            .filter_map(|(id, o)| match o {
                JobOutcome::Completed { start, finish } => {
                    let Some(&arrival) = arrivals.get(id) else {
                        debug_assert!(false, "job {id} is not in the provided trace");
                        return None;
                    };
                    Some((*id, (*start, *finish, arrival)))
                }
                _ => None,
            })
            .collect();
        rows.sort_by_key(|r| r.0);
        rows.into_iter().map(|r| r.1).collect()
    }

    /// Completion times (finish − arrival) of scheduled jobs in job-id
    /// order, given the original trace for arrival lookup.
    pub fn jcts(&self, trace: &[JobSpec]) -> Vec<f64> {
        self.completed_triples(trace)
            .into_iter()
            .map(|(_start, finish, arrival)| finish - arrival)
            .collect()
    }

    /// Queueing delays (start − arrival) of scheduled jobs in job-id order.
    pub fn queueing_delays(&self, trace: &[JobSpec]) -> Vec<f64> {
        self.completed_triples(trace)
            .into_iter()
            .map(|(start, _finish, arrival)| start - arrival)
            .collect()
    }

    /// [`RunResult::jcts`] and [`RunResult::queueing_delays`] from one
    /// arrivals-map build and one sort. `metrics::summarize` needs both
    /// per run per cell; computing them separately built the `HashMap`
    /// twice for every (run, cell) pair of every sweep row.
    pub fn jcts_and_queueing_delays(&self, trace: &[JobSpec]) -> (Vec<f64>, Vec<f64>) {
        let triples = self.completed_triples(trace);
        (
            triples
                .iter()
                .map(|&(_start, finish, arrival)| finish - arrival)
                .collect(),
            triples
                .iter()
                .map(|&(start, _finish, arrival)| start - arrival)
                .collect(),
        )
    }
}

/// The simulator.
pub struct Simulation {
    cfg: SimConfig,
    cluster: ClusterState,
    policy: Box<dyn PlacementPolicy>,
    /// Read-only lifecycle observers (`sim::observer`); nothing they see
    /// flows back into scheduling, so results are observer-invariant.
    observers: Vec<Box<dyn SchedulerObserver>>,
    contention: ContentionModel,
    /// Physical ring coordinates per best-effort job (for load removal).
    be_rings: HashMap<u64, Vec<Vec<crate::topology::P3>>>,
    queue: VecDeque<usize>,
    /// Pending events keyed `(time, rank, seq)`: rank 0 is an arrival
    /// (its seq is the trace index, so equal-time arrivals deliver in
    /// trace order), rank 1 is everything else (seq = push counter).
    /// Ranking arrivals ahead of same-time completions/faults reproduces
    /// the batch engine's push-all-arrivals-first ordering even when the
    /// streaming service stages arrivals one at a time. Keys are unique,
    /// so the indexed heap ([`EventHeap`]) pops the exact sequence the
    /// previous `BinaryHeap<Reverse<_>>` did, while letting evictions
    /// delete a dead attempt's completion event in place.
    events: EventHeap,
    seq: u64,
    now: f64,
    last_sample_t: f64,
    util: WeightedCdf,
    outcomes: Vec<(u64, JobOutcome)>,
    scheduled: usize,
    dropped: usize,
    started: HashMap<u64, f64>,
    /// Dedicated fault RNG stream, seeded from
    /// `cfg.modifiers.fault_seed` — never shared with trace generation,
    /// so job streams are byte-identical with and without modifiers.
    fault_rng: Pcg64,
    /// Per-job attempt counter; bumped by a fault kill so the dead
    /// attempt's in-flight completion event is recognized as stale.
    incarnation: HashMap<u64, u32>,
    /// Fault kills per job, for the retry cap.
    kill_count: HashMap<u64, u32>,
    /// Authoritative finish time per running job — maintained only when
    /// `ocs_latency > 0`, where stalls can push a finish past its already
    /// scheduled heap event (the event re-arms itself on pop).
    finish_at: HashMap<u64, f64>,
    /// Trace index by job id, for fault-kill requeueing (built only when
    /// failures are enabled).
    idx_of: HashMap<u64, usize>,
    /// Arrivals not yet delivered — part of the "work pending" predicate
    /// that keeps the fault chain alive.
    arrivals_pending: usize,
    /// Latest staged arrival: the workload horizon. Grows per submission
    /// in streaming mode; equals the trace maximum after a batch enqueue.
    horizon: f64,
    /// Arrivals staged so far; the fault chain arms on the first one.
    submitted: usize,
    /// Time of the last arrival or genuine completion: the makespan.
    /// Without faults this equals `now` at loop exit; with faults it
    /// excludes trailing repair events from the reported makespan.
    job_now: f64,
    /// Memo: head job that got `NoCapacity` against the given cluster
    /// epoch — skip re-planning until the occupancy epoch moves (only a
    /// release can move it while a head is blocked; arrivals cannot make
    /// a blocked head placeable).
    head_block: Option<(u64, u64)>,
    /// Memo of shapes the policy judged `Infeasible`. Topology and
    /// `fold_dims_enabled` — the other two components of the conceptual
    /// `(topo, shape, fold_dims)` key — are run constants, so the set is
    /// keyed on shape alone. A later job with a memoized shape drops via
    /// one hash lookup instead of a full variant-enumeration search.
    /// Sound because decisions are monotone: a shape that cannot place on
    /// an *empty* cluster (what `Infeasible` certifies) can never place
    /// on a loaded one, and the policy's own feasibility cache would
    /// repeat the verdict anyway.
    infeasible_shapes: HashSet<crate::shape::JobShape>,
    /// `cfg.modifiers.has_disruption() || policy.preemptive()`,
    /// precomputed: gates every piece of preemption/checkpoint
    /// bookkeeping so knob-free runs of non-preemptive policies stay
    /// byte-identical to (and as allocation-free as) the plain FIFO
    /// engine.
    disruption: bool,
    /// Execution record per running job (only when `disruption`).
    run_info: HashMap<u64, RunInfo>,
    /// Remaining base duration of jobs evicted with checkpointed
    /// progress; absent means "full duration".
    remaining_base: HashMap<u64, f64>,
    /// Jobs whose next placement owes the `migration-cost=` surcharge.
    migration_due: HashSet<u64>,
    /// Preemptions suffered per job, for the starvation cap.
    preempt_count: HashMap<u64, u32>,
    /// Head job that already got one eviction round without managing to
    /// place: a second consecutive Preempt for it degrades to Queue, so a
    /// geometry-blocked (rather than capacity-blocked) head cannot churn
    /// through the whole running set. Cleared by any successful placement
    /// or genuine completion.
    preempt_round: Option<u64>,
    /// Head job for which an idle-time defrag pass already ran (one
    /// compaction attempt per blocked head, not one per drain call).
    defrag_tried: Option<u64>,
    /// Disruption accounting for [`RunResult`].
    preemptions: usize,
    wasted_work: f64,
    migration_time: f64,
}

impl Simulation {
    pub fn new(cfg: SimConfig) -> Simulation {
        let cluster = ClusterState::new(cfg.topo);
        let mut policy = cfg.policy.instantiate();
        policy.core().fold_dims_enabled = cfg.fold_dims_enabled;
        let ext = cluster.topo().phys_ext();
        let disruption = cfg.modifiers.has_disruption() || policy.preemptive();
        Simulation {
            cfg,
            cluster,
            policy,
            observers: Vec::new(),
            contention: ContentionModel::new(ext),
            be_rings: HashMap::new(),
            queue: VecDeque::new(),
            events: EventHeap::new(),
            seq: 0,
            now: 0.0,
            last_sample_t: 0.0,
            util: WeightedCdf::new(),
            outcomes: Vec::new(),
            scheduled: 0,
            dropped: 0,
            started: HashMap::new(),
            fault_rng: Pcg64::new(cfg.modifiers.fault_seed, FAULT_STREAM),
            incarnation: HashMap::new(),
            kill_count: HashMap::new(),
            finish_at: HashMap::new(),
            idx_of: HashMap::new(),
            arrivals_pending: 0,
            horizon: 0.0,
            submitted: 0,
            job_now: 0.0,
            head_block: None,
            infeasible_shapes: HashSet::new(),
            disruption,
            run_info: HashMap::new(),
            remaining_base: HashMap::new(),
            migration_due: HashSet::new(),
            preempt_count: HashMap::new(),
            preempt_round: None,
            defrag_tried: None,
            preemptions: 0,
            wasted_work: 0.0,
            migration_time: 0.0,
        }
    }

    /// Replace the policy's plan scorer (e.g. with the PJRT-backed one).
    /// Rebuilds the policy so no cached state from the old scorer leaks.
    pub fn with_scorer(
        mut self,
        scorer: Box<dyn crate::placement::score::PlanScorer>,
    ) -> Simulation {
        let mut policy = self.cfg.policy.instantiate();
        policy.core().fold_dims_enabled = self.cfg.fold_dims_enabled;
        policy.set_scorer(scorer);
        self.policy = policy;
        self
    }

    /// Attach a [`SchedulerObserver`]. Observers receive every admission,
    /// placement decision, reconfiguration, and completion; they cannot
    /// influence the run.
    pub fn with_observer(mut self, observer: Box<dyn SchedulerObserver>) -> Simulation {
        self.observers.push(observer);
        self
    }

    fn push_event(&mut self, t: f64, slot: EventSlot) {
        self.seq += 1;
        self.events.push((OrdF64(t), 1, self.seq, slot));
    }

    /// Advance the utilization integral up to `t`.
    fn sample_util(&mut self, t: f64) {
        let dt = t - self.last_sample_t;
        if dt > 0.0 {
            self.util.push(self.cluster.utilization(), dt);
            self.last_sample_t = t;
        }
    }

    /// Current incarnation of a job (0 until it is ever killed).
    #[inline]
    fn incarnation_of(&self, job: u64) -> u32 {
        self.incarnation.get(&job).copied().unwrap_or(0)
    }

    /// Faults, repairs, and kills change what is placeable mid-run, so
    /// the two feasibility memos stop being sound: a `head_block` epoch
    /// is already invalidated by the epoch bump, but the
    /// `infeasible_shapes` set certifies "never placeable on an *empty*
    /// cluster", which failed nodes falsify. Drop both; they repopulate.
    fn clear_fault_memos(&mut self) {
        self.head_block = None;
        self.infeasible_shapes.clear();
    }

    /// Evict a running job — one mechanism, two triggers. Both release
    /// the allocation, invalidate the in-flight completion event via the
    /// incarnation bump, and (when a disruption knob is active) convert
    /// the attempt's elapsed wall-clock into checkpointed progress plus
    /// wasted work. They differ in the aftermath: a `Fault` requeues in
    /// FIFO (arrival) order and drops the job once it exhausts
    /// [`MAX_KILL_RETRIES`]; a `Preempt` requeues at the *tail* (behind
    /// the head it yielded to — re-inserting ahead of the blocked head
    /// would evict-and-requeue forever) and never drops.
    ///
    /// Returns `false` if the job was not running.
    fn evict_job(&mut self, job: u64, why: EvictReason) -> bool {
        let Some(alloc) = self.cluster.release(job) else {
            return false; // not running (already completed or never placed)
        };
        if let Some(rings) = self.be_rings.remove(&job) {
            self.contention.remove_job(&rings);
        }
        let start = self
            .started
            .remove(&job)
            .expect("running job has a start time");
        self.finish_at.remove(&job);
        let mut wasted = 0.0;
        if self.disruption {
            // Credit progress up to the last whole checkpoint interval
            // (in *base*-duration terms); everything past it re-runs.
            let info = self
                .run_info
                .remove(&job)
                .expect("disruption runs record every placement");
            let elapsed = self.now - start;
            let progress = info.base * (elapsed / info.eff).min(1.0);
            let c = self.cfg.modifiers.checkpoint;
            let credited = if c > 0.0 {
                ((progress / c).floor() * c).clamp(0.0, info.base)
            } else {
                0.0
            };
            self.remaining_base.insert(job, info.base - credited);
            let credited_wall = if info.base > 0.0 {
                credited * info.eff / info.base
            } else {
                0.0
            };
            wasted = (elapsed - credited_wall).max(0.0) * alloc.nodes.len() as f64;
            self.wasted_work += wasted;
            self.migration_due.insert(job);
        }
        *self.incarnation.entry(job).or_insert(0) += 1;
        // The dead attempt's completion event is deleted in place (the
        // incarnation filter at pop time remains as defence in depth).
        // None only mid-dispatch of the job's own completion, which no
        // eviction path reaches.
        let _ = self.events.remove_completion(job);
        self.scheduled -= 1;
        self.clear_fault_memos();
        match why {
            EvictReason::Fault => {
                for o in &mut self.observers {
                    o.on_job_killed(self.now, job);
                }
                let kills = self.kill_count.entry(job).or_insert(0);
                *kills += 1;
                if *kills > MAX_KILL_RETRIES {
                    self.outcomes.push((job, JobOutcome::Dropped));
                    self.dropped += 1;
                    return true;
                }
                // Requeue where FIFO order dictates: trace indices are
                // arrival-ordered, so a sorted insert restores
                // (arrival, id) order even when several kills interleave
                // with a partially drained queue.
                let idx = self.idx_of[&job];
                let pos = self.queue.partition_point(|&q| q < idx);
                self.queue.insert(pos, idx);
            }
            EvictReason::Preempt { for_job } => {
                self.preemptions += 1;
                *self.preempt_count.entry(job).or_insert(0) += 1;
                for o in &mut self.observers {
                    o.on_preempt(self.now, job, for_job, wasted);
                }
                self.queue.push_back(self.idx_of[&job]);
            }
        }
        true
    }

    /// Scheduling class as the decision loop sees it. With
    /// `--with aging=on`, a job that has suffered [`MAX_PREEMPTIONS`]
    /// evictions climbs one priority class (saturating) instead of being
    /// excluded from the victim snapshot — starvation relief that applies
    /// both when the job is a preemption candidate and when it competes
    /// as the incoming head. Off (the default), base priority passes
    /// through untouched, so existing rows keep their exact bytes.
    fn effective_priority(&self, base: u8, job: u64) -> u8 {
        if self.cfg.modifiers.aging
            && self.preempt_count.get(&job).copied().unwrap_or(0) >= MAX_PREEMPTIONS
        {
            base.saturating_add(1)
        } else {
            base
        }
    }

    /// Deterministic snapshot of preemptable running jobs, for
    /// [`PlacementPolicy::decide`]. Job-id sorted (`HashMap` iteration
    /// order must never reach a scheduling decision); jobs at the
    /// [`MAX_PREEMPTIONS`] starvation cap are excluded so the policy
    /// cannot churn them further — unless `--with aging=on`, which
    /// presents them one priority class up instead.
    fn running_snapshot(&self, trace: &[JobSpec]) -> Vec<RunningJob> {
        let mut ids: Vec<u64> = self.started.keys().copied().collect();
        ids.sort_unstable();
        ids.into_iter()
            .filter(|id| {
                self.cfg.modifiers.aging
                    || self.preempt_count.get(id).copied().unwrap_or(0) < MAX_PREEMPTIONS
            })
            .filter_map(|id| {
                let &idx = self.idx_of.get(&id)?;
                let info = self.run_info.get(&id)?;
                let start = self.started[&id];
                let remaining = (info.base
                    - info.base * ((self.now - start) / info.eff).min(1.0))
                .max(0.0);
                Some(RunningJob {
                    job: id,
                    priority: self.effective_priority(trace[idx].priority, id),
                    size: trace[idx].shape.size(),
                    remaining,
                    arrival: trace[idx].arrival,
                })
            })
            .collect()
    }

    /// Idle-time defragmentation (`--with defrag=idle`): re-fold running
    /// jobs one at a time toward the policy's preferred placement so a
    /// capacity-blocked head may fit without evicting anyone. Each job is
    /// released, re-planned against the compacted cluster, and either
    /// recommitted in its new spot or restored *exactly* (whole-cluster
    /// snapshot, so OCS reservations survive — `commit` alone would not
    /// re-reserve them). The move is modeled as hitless: completion
    /// events and accrued progress are untouched. Returns jobs moved.
    fn defrag_pass(&mut self, trace: &[JobSpec]) -> usize {
        let mut ids: Vec<u64> = self.cluster.live_allocations().map(|a| a.job).collect();
        ids.sort_unstable();
        let mut moved = 0;
        for id in ids {
            let Some(&idx) = self.idx_of.get(&id) else {
                continue;
            };
            let snapshot = self.cluster.clone();
            let Some(old) = self.cluster.release(id) else {
                continue;
            };
            match self.policy.place_now(&self.cluster, id, trace[idx].shape) {
                Some(plan) if plan.commit(&mut self.cluster).is_ok() => {
                    let new_nodes = self
                        .cluster
                        .allocation(id)
                        .map(|a| a.nodes.clone())
                        .unwrap_or_default();
                    if new_nodes != old.nodes {
                        moved += 1;
                    }
                }
                _ => {
                    // Restore the exact pre-release state (nodes, OCS
                    // reservations, epoch) — a failed relocation must
                    // never strand a running job.
                    self.cluster = snapshot;
                }
            }
        }
        if moved > 0 {
            self.clear_fault_memos();
            for o in &mut self.observers {
                o.on_defrag(self.now, moved);
            }
        }
        moved
    }

    /// One fault event: schedule the chain's next fault (while work is
    /// pending), pick link-vs-node and the victim node, kill whatever job
    /// touches it, and for node faults remove the capacity until the
    /// scheduled repair. The draw order (chain gap, kind, node, repair)
    /// is fixed so the failure realization is a pure function of the
    /// fault stream, independent of policy and occupancy.
    fn handle_fault(&mut self, pending: bool) {
        let Some(fm) = self.cfg.modifiers.failures else {
            return;
        };
        if pending {
            let gap = self.fault_rng.exponential(fm.mtbf);
            self.push_event(self.now + gap, EventSlot::Fault);
        }
        if let Some(corr) = fm.corr {
            // Correlated mode replaces the per-node draw with a domain
            // draw; the chain gap above is shared so swapping
            // `exp:` ↔ `corr:` keeps the fault *times* comparable.
            self.handle_domain_fault(fm.mean_repair, corr);
            return;
        }
        let is_link = self.fault_rng.chance(fm.link_fraction);
        let node = self.fault_rng.below(self.cluster.num_nodes());
        if let Some(victim) = self.cluster.job_on_node(node) {
            self.evict_job(victim, EvictReason::Fault);
        }
        if is_link {
            // Transient: the job is gone, the capacity survives.
            for o in &mut self.observers {
                o.on_fault(self.now, node, true);
            }
            return;
        }
        let repair_gap = self.fault_rng.exponential(fm.mean_repair);
        if self.cluster.fail_node(node) {
            self.push_event(self.now + repair_gap, EventSlot::NodeRepair(node));
            self.clear_fault_memos();
        }
        // Already-failed nodes keep their in-flight repair; the draw is
        // still consumed so the stream stays occupancy-independent.
        for o in &mut self.observers {
            o.on_fault(self.now, node, false);
        }
    }

    /// One correlated fault: an entire sampled domain fails atomically.
    /// The draw order (domain, cascade coin, repair gap) is fixed and
    /// every draw is consumed unconditionally — the realization is a pure
    /// function of the fault stream, independent of policy and occupancy.
    /// Resident jobs are killed in one ascending-node sweep, and every
    /// node that actually transitions gets a repair event at the *same*
    /// instant, so the domain comes back as a unit. Nodes already down
    /// (an overlapping earlier blast) keep their in-flight repair.
    fn handle_domain_fault(&mut self, mean_repair: f64, corr: crate::trace::scenarios::CorrFailure) {
        let map = DomainMap::new(self.cluster.topo(), corr.scope);
        let domain = self.fault_rng.below(map.num_domains());
        let cascaded = self.fault_rng.chance(corr.cascade);
        let repair_gap = self.fault_rng.exponential(mean_repair);
        let mut nodes = map.nodes_of(domain);
        let neighbor = map.neighbor(domain);
        if cascaded && neighbor != domain {
            nodes.extend(map.nodes_of(neighbor));
            nodes.sort_unstable();
        }
        let mut newly_failed = false;
        for &node in &nodes {
            if let Some(victim) = self.cluster.job_on_node(node) {
                self.evict_job(victim, EvictReason::Fault);
            }
            if self.cluster.fail_node(node) {
                self.push_event(self.now + repair_gap, EventSlot::NodeRepair(node));
                newly_failed = true;
            }
            for o in &mut self.observers {
                o.on_fault(self.now, node, false);
            }
        }
        if newly_failed {
            self.clear_fault_memos();
        }
        for o in &mut self.observers {
            o.on_domain_fault(self.now, domain, nodes.len(), cascaded && neighbor != domain);
        }
    }

    /// Stall every *other* in-flight job sharing a cube with `job`'s
    /// fresh allocation: an OCS reconfiguration is not hitless for
    /// traffic through the reconfigured cubes.
    fn stall_neighbours(&mut self, job: u64, delay: f64) {
        let Some(alloc) = self.cluster.allocation(job) else {
            return;
        };
        let cubes: HashSet<usize> = alloc.cubes.iter().copied().collect();
        let victims: Vec<u64> = self
            .cluster
            .live_allocations()
            .filter(|a| a.job != job && a.cubes.iter().any(|c| cubes.contains(c)))
            .map(|a| a.job)
            .collect();
        for v in victims {
            // Every running job has a `finish_at` entry when ocs_latency
            // is active; its completion event re-arms itself on pop.
            if let Some(f) = self.finish_at.get_mut(&v) {
                *f += delay;
                for o in &mut self.observers {
                    o.on_stall(self.now, v, delay);
                }
            }
        }
    }

    /// The scheduler decision loop over the head of the FIFO queue: ask
    /// the policy to [`decide`](PlacementPolicy::decide), then act —
    /// place (Admit/Reconfigure), drop (Reject), block (Queue), or evict
    /// victims and retry (Preempt). With no preemption knob and a
    /// non-preemptive policy this is byte-identical to the plain FIFO
    /// admit-or-queue loop: `decide` defaults to wrapping `plan`, the
    /// running-job snapshot is never built, and no extra state is
    /// touched.
    fn drain_queue(&mut self, trace: &[JobSpec]) {
        while let Some(&idx) = self.queue.front() {
            let job = trace[idx];
            if self.head_block == Some((job.id, self.cluster.epoch())) {
                break; // occupancy unchanged since this head last failed
            }
            let preempt_mode = self.cfg.modifiers.preempt;
            let preempt_enabled = preempt_mode.is_some() || self.policy.preemptive();
            // The decision wall-clock is observer-only diagnostics; skip
            // the timer entirely when nobody listens.
            let t0 = (!self.observers.is_empty()).then(Instant::now);
            let action = if self.infeasible_shapes.contains(&job.shape) {
                // A shape already judged never-placeable on this
                // (topology, fold_dims) run drops on a map lookup — the
                // synthesized decision keeps the observer stream (and its
                // decisions = placed + infeasible + no_capacity
                // invariant) intact, with zero search counters.
                SchedAction::Reject {
                    stats: Default::default(),
                }
            } else {
                let incoming = RunningJob {
                    job: job.id,
                    priority: self.effective_priority(job.priority, job.id),
                    size: job.shape.size(),
                    remaining: self
                        .remaining_base
                        .get(&job.id)
                        .copied()
                        .unwrap_or(job.duration),
                    arrival: job.arrival,
                };
                // The snapshot costs a sort of the running set; only
                // preemptive configurations can act on it, so only they
                // pay for it.
                let running = if preempt_enabled {
                    self.running_snapshot(trace)
                } else {
                    Vec::new()
                };
                self.policy.decide(
                    &PlacementRequest {
                        job: job.id,
                        shape: job.shape,
                        arrival: job.arrival,
                        cluster: &self.cluster,
                    },
                    &incoming,
                    &running,
                    preempt_mode,
                )
            };
            // Observers keep seeing the three-way PlacementDecision view
            // (their `decisions = placed + infeasible + no_capacity`
            // invariant predates SchedAction); a Preempt surfaces as the
            // NoCapacity it resolved, plus its own on_preempt events.
            let (view, victims) = match action {
                SchedAction::Admit { plan, stats } | SchedAction::Reconfigure { plan, stats } => {
                    (PlacementDecision::Placed { plan, stats }, Vec::new())
                }
                SchedAction::Reject { stats } => {
                    (PlacementDecision::Infeasible { stats }, Vec::new())
                }
                SchedAction::Queue { stats } => {
                    (PlacementDecision::NoCapacity { stats }, Vec::new())
                }
                SchedAction::Preempt { victims, stats } => {
                    (PlacementDecision::NoCapacity { stats }, victims)
                }
            };
            if let Some(t0) = t0 {
                let wall = t0.elapsed();
                for o in &mut self.observers {
                    o.on_decision(self.now, job.id, &view, wall);
                }
            }
            enum Resolved {
                Place(crate::placement::Plan),
                Drop,
                Block,
                Evict(Vec<u64>),
            }
            let resolved = match view {
                PlacementDecision::Placed { plan, .. } => Resolved::Place(plan),
                PlacementDecision::Infeasible { .. } => Resolved::Drop,
                PlacementDecision::NoCapacity { .. } if !victims.is_empty() => {
                    Resolved::Evict(victims)
                }
                PlacementDecision::NoCapacity { .. } => Resolved::Block,
            };
            match resolved {
                Resolved::Place(plan) => {
                    // Commit and schedule completion.
                    let mult = if self.policy.scattered() {
                        let rings = best_effort::ring_members(&self.cluster, &plan);
                        let m = self.contention.add_job(&rings);
                        self.be_rings.insert(job.id, rings);
                        m
                    } else {
                        1.0
                    };
                    let ocs_entries = plan.ocs_entries();
                    plan.commit(&mut self.cluster)
                        .expect("planned placement must commit");
                    if ocs_entries > 0 {
                        for o in &mut self.observers {
                            o.on_reconfig(self.now, job.id, ocs_entries);
                        }
                    }
                    let rings = self
                        .cluster
                        .allocation(job.id)
                        .expect("just committed")
                        .rings
                        .clone();
                    // Checkpoint-restart: a previously evicted job resumes
                    // from its remaining base duration, not from scratch.
                    let base = self
                        .remaining_base
                        .get(&job.id)
                        .copied()
                        .unwrap_or(job.duration);
                    let mut eff = effective_duration(base, job.comm_frac, &rings, mult);
                    // Modifier shaping. Every branch below draws from (or
                    // touches) fault state only when its modifier is
                    // active, so the default set runs this arm with zero
                    // extra RNG draws — byte-identical to the unmodified
                    // engine.
                    let mods = self.cfg.modifiers;
                    if mods.straggler_rate > 0.0 && self.fault_rng.chance(mods.straggler_rate) {
                        // Multiplicative slowdown in [1.25, 2.0): a
                        // straggling worker gates the whole ring.
                        eff *= 1.25 + 0.75 * self.fault_rng.f64();
                    }
                    if self.migration_due.remove(&job.id) {
                        // First placement after an eviction pays the
                        // restart surcharge (checkpoint restore + weight
                        // redistribution), once.
                        let mc = mods.migration_cost;
                        if mc > 0.0 {
                            eff += mc;
                            self.migration_time += mc;
                            for o in &mut self.observers {
                                o.on_migration(self.now, job.id, mc);
                            }
                        }
                    }
                    if mods.ocs_latency > 0.0 {
                        if ocs_entries > 0 {
                            // Reconfiguration is not hitless: this job
                            // pays the switch latency, and in-flight
                            // neighbours through the reconfigured cubes
                            // stall for the same window.
                            eff += mods.ocs_latency;
                            self.stall_neighbours(job.id, mods.ocs_latency);
                        }
                        self.finish_at.insert(job.id, self.now + eff);
                    }
                    if self.disruption {
                        self.run_info.insert(job.id, RunInfo { eff, base });
                    }
                    self.preempt_round = None;
                    self.defrag_tried = None;
                    self.started.insert(job.id, self.now);
                    let inc = self.incarnation_of(job.id);
                    self.push_event(self.now + eff, EventSlot::Completion(job.id, inc));
                    self.queue.pop_front();
                    self.scheduled += 1;
                }
                Resolved::Drop => {
                    // Shape incompatible: remove and move on (§4), and
                    // memoize so later jobs with the same shape skip the
                    // search entirely.
                    self.infeasible_shapes.insert(job.shape);
                    self.outcomes.push((job.id, JobOutcome::Dropped));
                    self.dropped += 1;
                    self.queue.pop_front();
                    self.preempt_round = None;
                    self.defrag_tried = None;
                }
                Resolved::Block => {
                    // Before conceding, a capacity-blocked head may try
                    // one idle-time defragmentation pass: compact the
                    // running jobs and re-plan. (Scattered policies place
                    // anywhere — compaction is meaningless for them.)
                    if self.cfg.modifiers.defrag
                        && !self.policy.scattered()
                        && self.defrag_tried != Some(job.id)
                    {
                        self.defrag_tried = Some(job.id);
                        if self.defrag_pass(trace) > 0 {
                            continue; // occupancy changed: retry the head
                        }
                    }
                    // Head blocks the queue until resources free up;
                    // memoize against the occupancy epoch so arrival
                    // storms don't re-run the search — the next release
                    // moves the epoch and wakes the head up.
                    self.head_block = Some((job.id, self.cluster.epoch()));
                    break;
                }
                Resolved::Evict(victims) => {
                    // One eviction round per blocked head: if the last
                    // round freed nodes but the head *still* cannot place
                    // (geometry, not capacity), queue instead of churning
                    // through more victims.
                    if self.preempt_round == Some(job.id) {
                        self.head_block = Some((job.id, self.cluster.epoch()));
                        break;
                    }
                    self.preempt_round = Some(job.id);
                    let mut evicted = 0;
                    for v in victims {
                        if self.evict_job(v, EvictReason::Preempt { for_job: job.id }) {
                            evicted += 1;
                        }
                    }
                    if evicted == 0 {
                        self.head_block = Some((job.id, self.cluster.epoch()));
                        break;
                    }
                    // Retry the head against the freed cluster.
                }
            }
        }
    }

    /// Stage one trace arrival into the event heap without delivering
    /// anything. Arrival events carry rank 0 and the trace index as their
    /// tie-break, so equal-time arrivals deliver in trace order and ahead
    /// of same-time completions/faults — the order the batch engine got
    /// by pushing the whole trace before its first pop. The first staged
    /// arrival arms the fault chain, which keeps the fault stream's draw
    /// positions identical to the batch prologue.
    fn enqueue_arrival(&mut self, trace: &[JobSpec], idx: usize) {
        if self.submitted == 0 {
            if let Some(fm) = self.cfg.modifiers.failures {
                let gap = self.fault_rng.exponential(fm.mtbf);
                self.push_event(gap, EventSlot::Fault);
            }
        }
        let job = &trace[idx];
        self.events
            .push((OrdF64(job.arrival), 0, idx as u64, EventSlot::Arrival(idx)));
        self.arrivals_pending += 1;
        self.horizon = self.horizon.max(job.arrival);
        if self.cfg.modifiers.failures.is_some() || self.disruption {
            // Both eviction triggers requeue through the id → trace-index
            // map; preemption additionally reads it for victim snapshots.
            self.idx_of.insert(job.id, idx);
        }
        self.submitted += 1;
    }

    /// Deliver pending events in `(time, rank, seq)` order: every event
    /// with key `<= bound` (the whole heap for `None`), running the batch
    /// engine's event-loop body per event. `freeze` and
    /// `util_end`/`extend` carry `run`'s horizon-freeze and
    /// measurement-window semantics; `external_arrival` tells the fault
    /// chain that an arrival not yet in the heap is pending (the
    /// streaming admission peek), keeping its liveness predicate — and
    /// therefore its RNG draw sequence — identical to a batch run over
    /// the same accepted trace.
    fn pump_until(
        &mut self,
        trace: &[JobSpec],
        bound: Option<(f64, u8, u64)>,
        freeze: bool,
        util_end: &mut f64,
        extend: bool,
        external_arrival: bool,
    ) {
        loop {
            let Some(&(OrdF64(t), rank, seq, slot)) = self.events.peek() else {
                break;
            };
            if let Some((bt, brank, bseq)) = bound {
                if (OrdF64(t), rank, seq) > (OrdF64(bt), brank, bseq) {
                    break;
                }
            }
            self.events.pop();
            if let EventSlot::Completion(id, inc) = slot {
                // A fault kill bumped the incarnation: this event belongs
                // to a dead attempt. Filter *before* the zero-horizon
                // util_end extension so a phantom completion never widens
                // the measurement window.
                if self.incarnation_of(id) != inc {
                    continue;
                }
                // An OCS stall pushed the finish later than this event:
                // re-arm at the authoritative time.
                if let Some(&f) = self.finish_at.get(&id) {
                    if f > t {
                        self.push_event(f, EventSlot::Completion(id, inc));
                        continue;
                    }
                }
            }
            if extend && util_end.is_infinite() && matches!(slot, EventSlot::Completion(..)) {
                *util_end = t;
            }
            self.sample_util(t.min(*util_end));
            self.now = t;
            match slot {
                EventSlot::Arrival(idx) => {
                    self.arrivals_pending -= 1;
                    self.job_now = self.now;
                    self.queue.push_back(idx);
                    for o in &mut self.observers {
                        o.on_admit(self.now, trace[idx].id);
                    }
                }
                EventSlot::Completion(id, _inc) => {
                    // `release` moves the occupancy epoch, which both
                    // invalidates the policy's placement index and wakes
                    // a `head_block`ed queue head.
                    self.job_now = self.now;
                    self.cluster.release(id);
                    if let Some(rings) = self.be_rings.remove(&id) {
                        self.contention.remove_job(&rings);
                    }
                    let start = self
                        .started
                        .remove(&id)
                        .expect("completing job has a start time");
                    self.finish_at.remove(&id);
                    // Real progress: the next blocked head earns a fresh
                    // eviction round.
                    self.preempt_round = None;
                    if self.disruption {
                        self.run_info.remove(&id);
                        self.remaining_base.remove(&id);
                        self.migration_due.remove(&id);
                    }
                    for o in &mut self.observers {
                        o.on_complete(self.now, id, start, self.now);
                    }
                    self.outcomes.push((
                        id,
                        JobOutcome::Completed {
                            start,
                            finish: self.now,
                        },
                    ));
                }
                EventSlot::Fault => {
                    // Keep the fault chain alive only while work is
                    // pending — arrivals to come, jobs in flight, or a
                    // queue the scheduler may still drain. A frozen
                    // queue past the horizon is *not* pending work, or
                    // the chain would self-perpetuate forever.
                    let queue_live = !freeze || self.now <= self.horizon;
                    let pending = external_arrival
                        || self.arrivals_pending > 0
                        || !self.started.is_empty()
                        || (!self.queue.is_empty() && queue_live);
                    self.handle_fault(pending);
                }
                EventSlot::NodeRepair(node) => {
                    self.cluster.repair_node(node);
                    self.clear_fault_memos();
                    for o in &mut self.observers {
                        o.on_repair(self.now, node);
                    }
                }
            }
            if !freeze || self.now <= self.horizon {
                self.drain_queue(trace);
            }
        }
    }

    /// Service-mode streaming submission: stage arrival `idx` (arrival
    /// times must be non-decreasing across calls — the service enforces
    /// this) and advance the simulation through every event up to and
    /// including the arrival itself. Same-time completions and faults
    /// rank after the arrival and stay pending, which keeps a streamed
    /// run byte-identical to a batch [`run`](Self::run) over the same
    /// trace.
    pub fn submit(&mut self, trace: &[JobSpec], idx: usize) {
        let arrival = trace[idx].arrival;
        self.enqueue_arrival(trace, idx);
        // Every event pumped here has `t <= arrival <=` the final
        // horizon, so the measurement clamp can never engage; the
        // degenerate all-arrivals-at-0 window is resolved by `drain`.
        let mut util_end = f64::INFINITY;
        self.pump_until(
            trace,
            Some((arrival, 0, idx as u64)),
            false,
            &mut util_end,
            false,
            false,
        );
    }

    /// Advance through every event strictly before time `t` without
    /// staging an arrival — the admission-control peek: queue depth and
    /// cluster state afterwards reflect the instant a candidate arriving
    /// at `t` would see. `t` must be `>=` every previously staged
    /// arrival. The candidate counts as a pending arrival for fault-chain
    /// liveness whether or not it is subsequently accepted, so an
    /// accepted stream stays byte-identical to its batch run.
    pub fn advance_before(&mut self, trace: &[JobSpec], t: f64) {
        let mut util_end = f64::INFINITY;
        self.pump_until(trace, Some((t, 0, 0)), false, &mut util_end, false, true);
    }

    /// Deliver every remaining event — the batch engine's main loop once
    /// the whole trace is staged. Freezing and the utilization window
    /// follow the staged horizon exactly as the monolithic `run` did.
    ///
    /// Utilization is measured over the workload window [0, last
    /// arrival] — the drain tail after submissions stop would otherwise
    /// dilute every policy's numbers (Figure 4 semantics). A degenerate
    /// trace whose arrivals all land at t=0 has a zero-width window, so
    /// the window extends to the *first completion*: between t=0 and
    /// that event the occupancy is constant, making the integral the
    /// point-in-time utilization of the loaded cluster instead of an
    /// empty measurement — and never the diluted full-drain integral.
    pub fn drain(&mut self, trace: &[JobSpec]) {
        let freeze = !self.cfg.drain && self.horizon > 0.0;
        let mut util_end = if self.horizon > 0.0 {
            self.horizon
        } else {
            f64::INFINITY
        };
        self.pump_until(trace, None, freeze, &mut util_end, true, false);
    }

    /// Close out a drained run: anything still queued never got scheduled
    /// within the horizon, the cluster must be empty (modulo failed
    /// nodes), and the utilization integral folds into a [`RunResult`].
    pub fn finalize(mut self, trace: &[JobSpec]) -> RunResult {
        for idx in std::mem::take(&mut self.queue) {
            self.outcomes.push((trace[idx].id, JobOutcome::NotScheduled));
        }
        debug_assert_eq!(self.cluster.busy_count(), self.cluster.failed_count());
        debug_assert!(self.cluster.check_consistency().is_ok());
        let mean = self.util.mean();
        // Useful utilization discounts wasted node-seconds over the same
        // measurement window the raw integral used. Bit-for-bit equal to
        // the raw mean whenever nothing was wasted.
        let useful_util = if self.wasted_work > 0.0 {
            let window: f64 = self.util.samples().iter().map(|&(_, w)| w).sum();
            let n = self.cluster.num_nodes();
            if window > 0.0 && n > 0 {
                (mean - self.wasted_work / (n as f64 * window)).max(0.0)
            } else {
                mean
            }
        } else {
            mean
        };
        RunResult {
            policy: self.cfg.policy.name(),
            outcomes: self.outcomes,
            utilization: self.util,
            scheduled: self.scheduled,
            dropped: self.dropped,
            makespan: self.job_now,
            preemptions: self.preemptions,
            wasted_work: self.wasted_work,
            migration_time: self.migration_time,
            useful_util,
        }
    }

    /// Run a whole trace and report.
    ///
    /// The workload horizon is the last arrival time: jobs not scheduled
    /// by then count against JCR (`NotScheduled`) — scheduling is frozen
    /// at the horizon and already-running jobs drain to completion. This
    /// matches the paper's reading of JCR where coarse-grained
    /// reconfiguration loses jobs to queueing (Reconfig 8³ < Folding 16³
    /// in Table 1), not only to shape incompatibility.
    ///
    /// Equivalent to staging every arrival via [`submit`](Self::submit)
    /// and then [`drain`](Self::drain) + [`finalize`](Self::finalize) —
    /// the streaming service's path — but stages everything up front
    /// without intermediate pumping, so unsorted traces run too.
    pub fn run(mut self, trace: &[JobSpec]) -> RunResult {
        if trace.is_empty() {
            // The batch prologue armed the fault chain even for an empty
            // trace (one fault fires into an idle cluster, then the chain
            // dies); keep that byte-exact rather than special-casing it
            // away.
            if let Some(fm) = self.cfg.modifiers.failures {
                let gap = self.fault_rng.exponential(fm.mtbf);
                self.push_event(gap, EventSlot::Fault);
            }
        }
        for idx in 0..trace.len() {
            self.enqueue_arrival(trace, idx);
        }
        self.drain(trace);
        self.finalize(trace)
    }

    /// Simulation clock: the time of the last delivered event.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Jobs waiting in the FIFO queue right now.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Jobs currently running on the cluster.
    pub fn running_count(&self) -> usize {
        self.started.len()
    }

    /// Jobs that ran to completion so far.
    pub fn completed_count(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|(_, o)| matches!(o, JobOutcome::Completed { .. }))
            .count()
    }

    /// Jobs dropped so far (infeasible shape or fault-retry exhaustion).
    pub fn dropped_count(&self) -> usize {
        self.dropped
    }

    /// Instantaneous cluster utilization (busy over non-failed nodes).
    pub fn cluster_utilization(&self) -> f64 {
        self.cluster.utilization()
    }

    /// Service-mode status of a submitted job.
    pub fn job_status(&self, trace: &[JobSpec], id: u64) -> &'static str {
        if let Some((_, o)) = self.outcomes.iter().rev().find(|(jid, _)| *jid == id) {
            return match o {
                JobOutcome::Completed { .. } => "completed",
                JobOutcome::Dropped => "dropped",
                JobOutcome::NotScheduled => "not-scheduled",
            };
        }
        if self.started.contains_key(&id) {
            return "running";
        }
        if self.queue.iter().any(|&idx| trace[idx].id == id) {
            return "queued";
        }
        "unknown"
    }
}

/// Snapshot/restore: every dynamic field that influences future
/// scheduling decisions or result bytes, serialized deterministically
/// (maps in sorted key order, floats as bit patterns, u64 ids as decimal
/// strings — JSON numbers only carry 53 exact bits). Performance memos
/// (`head_block`, `infeasible_shapes`, policy caches, placement indices)
/// are deliberately absent: they are epoch-keyed or monotone, so a cold
/// restart re-derives identical decisions, and the restored cluster gets
/// fresh epochs anyway.
impl Simulation {
    /// Serialize the engine's dynamic state. Restoring via
    /// [`restore`](Self::restore) and continuing yields completion rows
    /// byte-identical to the uninterrupted run.
    pub fn snapshot_state(&self) -> Json {
        fn num(v: usize) -> Json {
            Json::Num(v as f64)
        }
        fn pairs<V, F: Fn(&V) -> Vec<Json>>(m: &HashMap<u64, V>, f: F) -> Json {
            let mut ks: Vec<u64> = m.keys().copied().collect();
            ks.sort_unstable();
            Json::Arr(
                ks.into_iter()
                    .map(|k| {
                        let mut row = vec![Json::u64_str(k)];
                        row.extend(f(&m[&k]));
                        Json::Arr(row)
                    })
                    .collect(),
            )
        }
        fn opt_id(v: Option<u64>) -> Json {
            match v {
                Some(id) => Json::u64_str(id),
                None => Json::Null,
            }
        }
        let events: Vec<Json> = self
            .events
            .sorted()
            .into_iter()
            .map(|(OrdF64(t), rank, seq, slot)| {
                let slot = match slot {
                    EventSlot::Arrival(idx) => {
                        Json::Arr(vec![Json::Str("arrival".into()), num(idx)])
                    }
                    EventSlot::Completion(id, inc) => Json::Arr(vec![
                        Json::Str("completion".into()),
                        Json::u64_str(id),
                        Json::Num(inc as f64),
                    ]),
                    EventSlot::Fault => Json::Arr(vec![Json::Str("fault".into())]),
                    EventSlot::NodeRepair(node) => {
                        Json::Arr(vec![Json::Str("repair".into()), num(node)])
                    }
                };
                Json::Arr(vec![
                    Json::f64_bits(t),
                    Json::Num(rank as f64),
                    Json::u64_str(seq),
                    slot,
                ])
            })
            .collect();
        let failed: Vec<Json> = self.cluster.failed_nodes().map(num).collect();
        let mut alloc_ids: Vec<u64> = self.cluster.live_allocations().map(|a| a.job).collect();
        alloc_ids.sort_unstable();
        let allocs: Vec<Json> = alloc_ids
            .iter()
            .map(|id| {
                let a = self.cluster.allocation(*id).expect("live allocation");
                jmap(vec![
                    ("cubes", Json::Arr(a.cubes.iter().map(|&c| num(c)).collect())),
                    ("job", Json::u64_str(a.job)),
                    ("nodes", Json::Arr(a.nodes.iter().map(|&n| num(n)).collect())),
                    ("ocs_entries", num(a.ocs_entries)),
                    (
                        "placed_ext",
                        Json::Arr(vec![
                            num(a.placed_ext.0[0]),
                            num(a.placed_ext.0[1]),
                            num(a.placed_ext.0[2]),
                        ]),
                    ),
                    (
                        "rings",
                        Json::Arr(
                            a.rings
                                .iter()
                                .map(|&(len, closed)| {
                                    Json::Arr(vec![num(len), Json::Bool(closed)])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let ocs: Vec<Json> = self
            .cluster
            .ocs()
            .map(|ocs| {
                ocs.dump_entries()
                    .into_iter()
                    .map(|(k, owner, next)| {
                        Json::Arr(vec![
                            num(k.axis),
                            num(k.i),
                            num(k.j),
                            num(k.cube),
                            Json::u64_str(owner),
                            match next {
                                Some(c) => num(c),
                                None => Json::Null,
                            },
                        ])
                    })
                    .collect()
            })
            .unwrap_or_default();
        let mut be_ids: Vec<u64> = self.be_rings.keys().copied().collect();
        be_ids.sort_unstable();
        let be_rings: Vec<Json> = be_ids
            .iter()
            .map(|id| {
                let rings = &self.be_rings[id];
                Json::Arr(vec![
                    Json::u64_str(*id),
                    Json::Arr(
                        rings
                            .iter()
                            .map(|ring| {
                                Json::Arr(
                                    ring.iter()
                                        .map(|p| {
                                            Json::Arr(vec![
                                                num(p.0[0]),
                                                num(p.0[1]),
                                                num(p.0[2]),
                                            ])
                                        })
                                        .collect(),
                                )
                            })
                            .collect(),
                    ),
                ])
            })
            .collect();
        let outcomes: Vec<Json> = self
            .outcomes
            .iter()
            .map(|(id, o)| match o {
                JobOutcome::Completed { start, finish } => Json::Arr(vec![
                    Json::u64_str(*id),
                    Json::Str("completed".into()),
                    Json::f64_bits(*start),
                    Json::f64_bits(*finish),
                ]),
                JobOutcome::Dropped => {
                    Json::Arr(vec![Json::u64_str(*id), Json::Str("dropped".into())])
                }
                JobOutcome::NotScheduled => {
                    Json::Arr(vec![Json::u64_str(*id), Json::Str("not-scheduled".into())])
                }
            })
            .collect();
        let (rstate, rinc) = self.fault_rng.raw_state();
        let fault_rng = Json::Arr(vec![
            Json::u64_str((rstate >> 64) as u64),
            Json::u64_str(rstate as u64),
            Json::u64_str((rinc >> 64) as u64),
            Json::u64_str(rinc as u64),
        ]);
        let util: Vec<Json> = self
            .util
            .samples()
            .iter()
            .map(|&(v, w)| Json::Arr(vec![Json::f64_bits(v), Json::f64_bits(w)]))
            .collect();
        let mut migration_due: Vec<u64> = self.migration_due.iter().copied().collect();
        migration_due.sort_unstable();
        jmap(vec![
            ("arrivals_pending", num(self.arrivals_pending)),
            ("be_rings", Json::Arr(be_rings)),
            (
                "cluster",
                jmap(vec![
                    ("allocs", Json::Arr(allocs)),
                    ("failed", Json::Arr(failed)),
                    ("ocs", Json::Arr(ocs)),
                ]),
            ),
            ("defrag_tried", opt_id(self.defrag_tried)),
            ("dropped", num(self.dropped)),
            ("events", Json::Arr(events)),
            ("fault_rng", fault_rng),
            (
                "finish_at",
                pairs(&self.finish_at, |&v| vec![Json::f64_bits(v)]),
            ),
            ("horizon", Json::f64_bits(self.horizon)),
            ("idx_of", pairs(&self.idx_of, |&v| vec![num(v)])),
            (
                "incarnation",
                pairs(&self.incarnation, |&v| vec![Json::Num(v as f64)]),
            ),
            ("job_now", Json::f64_bits(self.job_now)),
            (
                "kill_count",
                pairs(&self.kill_count, |&v| vec![Json::Num(v as f64)]),
            ),
            ("last_sample_t", Json::f64_bits(self.last_sample_t)),
            (
                "migration_due",
                Json::Arr(migration_due.into_iter().map(Json::u64_str).collect()),
            ),
            ("migration_time", Json::f64_bits(self.migration_time)),
            ("now", Json::f64_bits(self.now)),
            ("outcomes", Json::Arr(outcomes)),
            (
                "preempt_count",
                pairs(&self.preempt_count, |&v| vec![Json::Num(v as f64)]),
            ),
            ("preempt_round", opt_id(self.preempt_round)),
            ("preemptions", num(self.preemptions)),
            (
                "queue",
                Json::Arr(self.queue.iter().map(|&i| num(i)).collect()),
            ),
            (
                "remaining_base",
                pairs(&self.remaining_base, |&v| vec![Json::f64_bits(v)]),
            ),
            (
                "run_info",
                pairs(&self.run_info, |ri| {
                    vec![Json::f64_bits(ri.eff), Json::f64_bits(ri.base)]
                }),
            ),
            ("scheduled", num(self.scheduled)),
            ("seq", Json::u64_str(self.seq)),
            (
                "started",
                pairs(&self.started, |&v| vec![Json::f64_bits(v)]),
            ),
            ("submitted", num(self.submitted)),
            ("util", Json::Arr(util)),
            ("wasted_work", Json::f64_bits(self.wasted_work)),
        ])
    }

    /// Rebuild a simulation from [`snapshot_state`](Self::snapshot_state)
    /// output. `cfg` must be the configuration of the snapshotted run —
    /// the service-level envelope (`coordinator::snapshot`) carries and
    /// re-checks it; the engine snapshot holds dynamic state only. The
    /// restored engine continues byte-identically: policy caches and
    /// feasibility memos start cold, but both are decision-invariant.
    pub fn restore(cfg: SimConfig, state: &Json) -> Result<Simulation, String> {
        let mut sim = Simulation::new(cfg);
        // Cluster: failed nodes first (they must be unoccupied), then
        // allocations (node occupancy + cube-free counters), then the raw
        // OCS circuits (plain `commit` does not re-reserve entries).
        let cluster = sget(state, "cluster")?;
        for node in sarr(cluster, "failed")? {
            let node = snum(node, "cluster.failed")?;
            if node >= sim.cluster.num_nodes() || !sim.cluster.fail_node(node) {
                return Err(snap_err("cluster.failed"));
            }
        }
        for a in sarr(cluster, "allocs")? {
            let job = sid(sget(a, "job")?, "alloc.job")?;
            let nodes = sarr(a, "nodes")?
                .iter()
                .map(|n| snum(n, "alloc.nodes"))
                .collect::<Result<Vec<_>, _>>()?;
            let cubes = sarr(a, "cubes")?
                .iter()
                .map(|c| snum(c, "alloc.cubes"))
                .collect::<Result<Vec<_>, _>>()?;
            let ocs_entries = snum(sget(a, "ocs_entries")?, "alloc.ocs_entries")?;
            let mut rings = Vec::new();
            for r in sarr(a, "rings")? {
                let row = r.as_arr().ok_or_else(|| snap_err("alloc.rings"))?;
                let len = snum(
                    row.first().ok_or_else(|| snap_err("alloc.rings"))?,
                    "alloc.rings",
                )?;
                let closed = match row.get(1) {
                    Some(Json::Bool(b)) => *b,
                    _ => return Err(snap_err("alloc.rings")),
                };
                rings.push((len, closed));
            }
            let ext = sarr(a, "placed_ext")?;
            if ext.len() != 3 {
                return Err(snap_err("alloc.placed_ext"));
            }
            let placed_ext = crate::topology::P3::new(
                snum(&ext[0], "alloc.placed_ext")?,
                snum(&ext[1], "alloc.placed_ext")?,
                snum(&ext[2], "alloc.placed_ext")?,
            );
            sim.cluster.commit(Allocation {
                job,
                nodes,
                cubes,
                ocs_entries,
                rings,
                placed_ext,
            });
        }
        let ocs_dump = sarr(cluster, "ocs")?;
        if !ocs_dump.is_empty() {
            let Some(ocs) = sim.cluster.ocs_mut() else {
                return Err(snap_err("cluster.ocs (topology has no OCS)"));
            };
            for e in ocs_dump {
                let row = e.as_arr().ok_or_else(|| snap_err("cluster.ocs"))?;
                if row.len() != 6 {
                    return Err(snap_err("cluster.ocs"));
                }
                let key = crate::topology::ocs::PortKey {
                    axis: snum(&row[0], "ocs.axis")?,
                    i: snum(&row[1], "ocs.i")?,
                    j: snum(&row[2], "ocs.j")?,
                    cube: snum(&row[3], "ocs.cube")?,
                };
                let owner = sid(&row[4], "ocs.owner")?;
                let next = match &row[5] {
                    Json::Null => None,
                    other => Some(snum(other, "ocs.next")?),
                };
                ocs.restore_entry(key, owner, next);
            }
        }
        // Best-effort ring loads restore by replay: per-cable loads are
        // integer unit sums, so replay order cannot perturb them.
        for row in sarr(state, "be_rings")? {
            let row = row.as_arr().ok_or_else(|| snap_err("be_rings"))?;
            if row.len() != 2 {
                return Err(snap_err("be_rings"));
            }
            let id = sid(&row[0], "be_rings.id")?;
            let mut rings: Vec<Vec<crate::topology::P3>> = Vec::new();
            for ring in row[1].as_arr().ok_or_else(|| snap_err("be_rings"))? {
                let mut members = Vec::new();
                for p in ring.as_arr().ok_or_else(|| snap_err("be_rings"))? {
                    let p = p.as_arr().ok_or_else(|| snap_err("be_rings"))?;
                    if p.len() != 3 {
                        return Err(snap_err("be_rings"));
                    }
                    members.push(crate::topology::P3::new(
                        snum(&p[0], "be_rings")?,
                        snum(&p[1], "be_rings")?,
                        snum(&p[2], "be_rings")?,
                    ));
                }
                rings.push(members);
            }
            let _ = sim.contention.add_job(&rings);
            sim.be_rings.insert(id, rings);
        }
        // Queue, running set, and the per-job bookkeeping maps.
        sim.queue = sarr(state, "queue")?
            .iter()
            .map(|n| snum(n, "queue"))
            .collect::<Result<VecDeque<_>, _>>()?;
        for (id, v) in spairs(sarr(state, "started")?, "started", |rest| {
            sbits(rest.first().ok_or_else(|| snap_err("started"))?, "started")
        })? {
            sim.started.insert(id, v);
        }
        for (id, v) in spairs(sarr(state, "incarnation")?, "incarnation", |rest| {
            snum(
                rest.first().ok_or_else(|| snap_err("incarnation"))?,
                "incarnation",
            )
        })? {
            sim.incarnation.insert(id, v as u32);
        }
        for (id, v) in spairs(sarr(state, "kill_count")?, "kill_count", |rest| {
            snum(
                rest.first().ok_or_else(|| snap_err("kill_count"))?,
                "kill_count",
            )
        })? {
            sim.kill_count.insert(id, v as u32);
        }
        for (id, v) in spairs(sarr(state, "finish_at")?, "finish_at", |rest| {
            sbits(
                rest.first().ok_or_else(|| snap_err("finish_at"))?,
                "finish_at",
            )
        })? {
            sim.finish_at.insert(id, v);
        }
        for (id, v) in spairs(sarr(state, "idx_of")?, "idx_of", |rest| {
            snum(rest.first().ok_or_else(|| snap_err("idx_of"))?, "idx_of")
        })? {
            sim.idx_of.insert(id, v);
        }
        for (id, v) in spairs(sarr(state, "run_info")?, "run_info", |rest| {
            if rest.len() != 2 {
                return Err(snap_err("run_info"));
            }
            Ok(RunInfo {
                eff: sbits(&rest[0], "run_info.eff")?,
                base: sbits(&rest[1], "run_info.base")?,
            })
        })? {
            sim.run_info.insert(id, v);
        }
        for (id, v) in spairs(sarr(state, "remaining_base")?, "remaining_base", |rest| {
            sbits(
                rest.first().ok_or_else(|| snap_err("remaining_base"))?,
                "remaining_base",
            )
        })? {
            sim.remaining_base.insert(id, v);
        }
        for (id, v) in spairs(sarr(state, "preempt_count")?, "preempt_count", |rest| {
            snum(
                rest.first().ok_or_else(|| snap_err("preempt_count"))?,
                "preempt_count",
            )
        })? {
            sim.preempt_count.insert(id, v as u32);
        }
        for id in sarr(state, "migration_due")? {
            sim.migration_due.insert(sid(id, "migration_due")?);
        }
        sim.preempt_round = sopt_id(sget(state, "preempt_round")?, "preempt_round")?;
        sim.defrag_tried = sopt_id(sget(state, "defrag_tried")?, "defrag_tried")?;
        // Outcomes (insertion order preserved), utilization integral,
        // fault RNG stream position, scalars.
        for row in sarr(state, "outcomes")? {
            let row = row.as_arr().ok_or_else(|| snap_err("outcomes"))?;
            let id = sid(row.first().ok_or_else(|| snap_err("outcomes"))?, "outcomes")?;
            let tag = row
                .get(1)
                .and_then(Json::as_str)
                .ok_or_else(|| snap_err("outcomes"))?;
            let outcome = match tag {
                "completed" => JobOutcome::Completed {
                    start: sbits(
                        row.get(2).ok_or_else(|| snap_err("outcomes"))?,
                        "outcomes.start",
                    )?,
                    finish: sbits(
                        row.get(3).ok_or_else(|| snap_err("outcomes"))?,
                        "outcomes.finish",
                    )?,
                },
                "dropped" => JobOutcome::Dropped,
                "not-scheduled" => JobOutcome::NotScheduled,
                _ => return Err(snap_err("outcomes")),
            };
            sim.outcomes.push((id, outcome));
        }
        let mut samples = Vec::new();
        for s in sarr(state, "util")? {
            let s = s.as_arr().ok_or_else(|| snap_err("util"))?;
            if s.len() != 2 {
                return Err(snap_err("util"));
            }
            samples.push((sbits(&s[0], "util")?, sbits(&s[1], "util")?));
        }
        sim.util = WeightedCdf::from_samples(samples);
        let fr = sarr(state, "fault_rng")?;
        if fr.len() != 4 {
            return Err(snap_err("fault_rng"));
        }
        let rstate =
            ((sid(&fr[0], "fault_rng")? as u128) << 64) | sid(&fr[1], "fault_rng")? as u128;
        let rinc = ((sid(&fr[2], "fault_rng")? as u128) << 64) | sid(&fr[3], "fault_rng")? as u128;
        sim.fault_rng = Pcg64::from_raw_state(rstate, rinc);
        sim.now = sbits(sget(state, "now")?, "now")?;
        sim.last_sample_t = sbits(sget(state, "last_sample_t")?, "last_sample_t")?;
        sim.job_now = sbits(sget(state, "job_now")?, "job_now")?;
        sim.horizon = sbits(sget(state, "horizon")?, "horizon")?;
        sim.wasted_work = sbits(sget(state, "wasted_work")?, "wasted_work")?;
        sim.migration_time = sbits(sget(state, "migration_time")?, "migration_time")?;
        sim.arrivals_pending = snum(sget(state, "arrivals_pending")?, "arrivals_pending")?;
        sim.submitted = snum(sget(state, "submitted")?, "submitted")?;
        sim.scheduled = snum(sget(state, "scheduled")?, "scheduled")?;
        sim.dropped = snum(sget(state, "dropped")?, "dropped")?;
        sim.preemptions = snum(sget(state, "preemptions")?, "preemptions")?;
        // Events last: raw `(t, rank, seq)` keys preserved, plus the push
        // counter so future pushes keep globally unique rank-1 keys.
        for row in sarr(state, "events")? {
            let row = row.as_arr().ok_or_else(|| snap_err("events"))?;
            if row.len() != 4 {
                return Err(snap_err("events"));
            }
            let t = sbits(&row[0], "events.t")?;
            let rank = snum(&row[1], "events.rank")? as u8;
            let seq = sid(&row[2], "events.seq")?;
            let slot = row[3].as_arr().ok_or_else(|| snap_err("events.slot"))?;
            let tag = slot
                .first()
                .and_then(Json::as_str)
                .ok_or_else(|| snap_err("events.slot"))?;
            let slot = match tag {
                "arrival" => EventSlot::Arrival(snum(
                    slot.get(1).ok_or_else(|| snap_err("events.arrival"))?,
                    "events.arrival",
                )?),
                "completion" => EventSlot::Completion(
                    sid(
                        slot.get(1).ok_or_else(|| snap_err("events.completion"))?,
                        "events.completion",
                    )?,
                    snum(
                        slot.get(2).ok_or_else(|| snap_err("events.completion"))?,
                        "events.completion",
                    )? as u32,
                ),
                "fault" => EventSlot::Fault,
                "repair" => EventSlot::NodeRepair(snum(
                    slot.get(1).ok_or_else(|| snap_err("events.repair"))?,
                    "events.repair",
                )?),
                _ => return Err(snap_err("events.slot")),
            };
            sim.events.push((OrdF64(t), rank, seq, slot));
        }
        sim.seq = sid(sget(state, "seq")?, "seq")?;
        Ok(sim)
    }
}

/// Build a snapshot object from `(key, value)` pairs.
fn jmap(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn snap_err(what: &str) -> String {
    format!("snapshot: malformed or missing '{what}'")
}

fn sget<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| snap_err(key))
}

fn sarr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json], String> {
    sget(j, key)?.as_arr().ok_or_else(|| snap_err(key))
}

fn sbits(j: &Json, what: &str) -> Result<f64, String> {
    j.as_f64_bits().ok_or_else(|| snap_err(what))
}

fn snum(j: &Json, what: &str) -> Result<usize, String> {
    j.as_usize().ok_or_else(|| snap_err(what))
}

fn sid(j: &Json, what: &str) -> Result<u64, String> {
    j.as_u64_str().ok_or_else(|| snap_err(what))
}

fn sopt_id(j: &Json, what: &str) -> Result<Option<u64>, String> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(sid(other, what)?)),
    }
}

/// Decode `[id, v...]` rows of a sorted u64-keyed map dump.
fn spairs<V, F: Fn(&[Json]) -> Result<V, String>>(
    rows: &[Json],
    what: &str,
    f: F,
) -> Result<Vec<(u64, V)>, String> {
    rows.iter()
        .map(|row| {
            let row = row.as_arr().ok_or_else(|| snap_err(what))?;
            let id = row
                .first()
                .and_then(Json::as_u64_str)
                .ok_or_else(|| snap_err(what))?;
            Ok((id, f(&row[1..])?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::PolicyKind;
    use crate::shape::JobShape;
    use crate::sim::observer::SharedTelemetry;
    use crate::trace::JobSpec;

    fn job(id: u64, arrival: f64, duration: f64, shape: JobShape) -> JobSpec {
        JobSpec {
            id,
            arrival,
            duration,
            shape,
            comm_frac: 0.0, // isolate scheduling effects
            priority: 0,
        }
    }

    fn run(policy: PolicyKind, topo: ClusterTopo, trace: &[JobSpec]) -> RunResult {
        let mut cfg = SimConfig::new(topo, policy);
        cfg.drain = true; // micro-tests exercise full-drain semantics
        Simulation::new(cfg).run(trace)
    }

    #[test]
    fn horizon_freezes_scheduling() {
        // Without drain, jobs that cannot start before the last arrival
        // count as NotScheduled (the paper's JCR semantics).
        let trace = vec![
            job(0, 0.0, 100.0, JobShape::new(16, 16, 16)),
            job(1, 10.0, 100.0, JobShape::new(16, 16, 16)),
        ];
        let mut cfg = SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::Reconfig,
        );
        cfg.drain = false;
        let r = Simulation::new(cfg).run(&trace);
        assert_eq!(r.scheduled, 1);
        assert!(r
            .outcomes
            .iter()
            .any(|(id, o)| *id == 1 && *o == JobOutcome::NotScheduled));
        assert!((r.jcr() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn single_job_completes_immediately() {
        let trace = vec![job(0, 10.0, 100.0, JobShape::new(4, 4, 4))];
        let r = run(
            PolicyKind::RFold,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        assert_eq!(r.scheduled, 1);
        assert_eq!(r.jcr(), 1.0);
        let jcts = r.jcts(&trace);
        assert_eq!(jcts, vec![100.0]);
        assert_eq!(r.makespan, 110.0);
    }

    #[test]
    fn incompatible_shape_dropped() {
        let trace = vec![
            job(0, 0.0, 50.0, JobShape::new(4, 4, 32)), // > 16 in any rotation
            job(1, 1.0, 50.0, JobShape::new(2, 2, 2)),
        ];
        let r = run(PolicyKind::FirstFit, ClusterTopo::static_4096(), &trace);
        assert_eq!(r.dropped, 1);
        assert_eq!(r.scheduled, 1);
        assert!((r.jcr() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fifo_blocks_until_resources_free() {
        // Two full-cluster jobs: the second must queue behind the first.
        let trace = vec![
            job(0, 0.0, 100.0, JobShape::new(16, 16, 16)),
            job(1, 10.0, 100.0, JobShape::new(16, 16, 16)),
            job(2, 20.0, 10.0, JobShape::new(2, 2, 2)), // blocked by FIFO
        ];
        let r = run(
            PolicyKind::Reconfig,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        assert_eq!(r.scheduled, 3);
        let jcts = r.jcts(&trace); // job-id order
        assert_eq!(jcts[0], 100.0);
        assert_eq!(jcts[1], 190.0); // waited until t=100, ran 100
        // job 2 stays blocked while job 1 hogs the whole cluster; it can
        // only start at t=200 → finish 210 → JCT 190.
        assert_eq!(jcts[2], 190.0);
    }

    #[test]
    fn combined_rows_match_separate_computations() {
        let trace = vec![
            job(0, 0.0, 100.0, JobShape::new(16, 16, 16)),
            job(1, 10.0, 100.0, JobShape::new(16, 16, 16)),
            job(2, 20.0, 10.0, JobShape::new(2, 2, 2)),
        ];
        let r = run(
            PolicyKind::Reconfig,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        let (jcts, delays) = r.jcts_and_queueing_delays(&trace);
        assert_eq!(jcts, r.jcts(&trace));
        assert_eq!(delays, r.queueing_delays(&trace));
    }

    #[test]
    fn utilization_integrates_busy_time() {
        let trace = vec![job(0, 0.0, 100.0, JobShape::new(16, 16, 16))];
        let r = run(
            PolicyKind::Reconfig,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        // Busy the whole makespan at 100%.
        assert!((r.utilization.mean() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_horizon_trace_excludes_drain_tail_from_utilization() {
        // Both jobs arrive at t=0 (horizon 0) and fill the cluster; the
        // short one finishes at t=10, after which the cluster drains at
        // 50% for 90 more seconds. The utilization window must stop at
        // the first completion (point-in-time utilization of the loaded
        // cluster = 100%), not integrate the drain tail (≈55%).
        let trace = vec![
            job(0, 0.0, 100.0, JobShape::new(16, 16, 8)),
            job(1, 0.0, 10.0, JobShape::new(16, 16, 8)),
        ];
        let r = run(
            PolicyKind::Reconfig,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        assert_eq!(r.scheduled, 2);
        assert!(
            (r.utilization.mean() - 1.0).abs() < 1e-9,
            "drain tail diluted utilization: {}",
            r.utilization.mean()
        );
    }

    #[test]
    fn mismatched_trace_does_not_panic_in_release() {
        // jcts/queueing_delays against a trace missing some run jobs:
        // debug builds assert (the mismatch is a caller bug), release
        // builds skip the unknown jobs instead of panicking on indexing.
        let trace = vec![
            job(0, 0.0, 10.0, JobShape::new(2, 2, 2)),
            job(1, 1.0, 10.0, JobShape::new(2, 2, 2)),
        ];
        let r = run(
            PolicyKind::Reconfig,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        assert_eq!(r.scheduled, 2);
        let partial = &trace[..1]; // job 1 missing
        if cfg!(debug_assertions) {
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                r.jcts(partial)
            }));
            let payload = res.expect_err("debug build must assert on the mismatch");
            // Assert on the debug_assert's own message: the pre-fix code
            // also panicked here (HashMap indexing, "no entry found for
            // key"), so a bare is_err() could not catch a regression.
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            assert!(
                msg.contains("not in the provided trace"),
                "expected the skip-path debug_assert, got: {msg:?}"
            );
        } else {
            assert_eq!(r.jcts(partial), vec![10.0]);
            assert_eq!(r.queueing_delays(partial), vec![0.0]);
        }
        // A matching trace keeps working either way.
        assert_eq!(r.jcts(&trace).len(), 2);
    }

    #[test]
    fn open_ring_penalty_stretches_duration() {
        // A 6×1×1 job on a static torus: no wrap at 6 < 16 → open ring;
        // comm_frac 0.5 → ×1.5 duration.
        let trace = vec![JobSpec {
            id: 0,
            arrival: 0.0,
            duration: 100.0,
            shape: JobShape::new(6, 1, 1),
            comm_frac: 0.5,
            priority: 0,
        }];
        let r = run(PolicyKind::FirstFit, ClusterTopo::static_4096(), &trace);
        let jcts = r.jcts(&trace);
        assert_eq!(jcts, vec![150.0]);
        // Folding closes the ring (2×3 serpentine) → no penalty.
        let r = run(PolicyKind::Folding, ClusterTopo::static_4096(), &trace);
        assert_eq!(r.jcts(&trace), vec![100.0]);
    }

    #[test]
    fn best_effort_never_blocks_on_shape() {
        let trace = vec![
            job(0, 0.0, 50.0, JobShape::new(4, 4, 32)),
            job(1, 1.0, 50.0, JobShape::new(3, 5, 7)),
        ];
        let r = run(PolicyKind::BestEffort, ClusterTopo::static_4096(), &trace);
        assert_eq!(r.scheduled, 2);
        assert_eq!(r.dropped, 0);
    }

    #[test]
    fn observers_see_the_full_lifecycle() {
        // One infeasible job (dropped), two placed jobs, one of which
        // reprograms the OCS — the observer must account for all of it,
        // and attaching it must not change the results.
        let trace = vec![
            job(0, 0.0, 50.0, JobShape::new(17, 17, 17)), // 4913 > 4096 XPUs
            job(1, 1.0, 50.0, JobShape::new(4, 4, 32)),   // 8 cubes at 4^3 → OCS chains
            job(2, 2.0, 10.0, JobShape::new(2, 2, 2)),
        ];
        let topo = ClusterTopo::reconfigurable_4096(4);
        let telemetry = SharedTelemetry::new();
        let mut cfg = SimConfig::new(topo, PolicyKind::Reconfig);
        cfg.drain = true;
        let observed = Simulation::new(cfg)
            .with_observer(Box::new(telemetry.clone()))
            .run(&trace);
        let plain = Simulation::new(cfg).run(&trace);
        assert_eq!(observed.scheduled, plain.scheduled);
        assert_eq!(observed.dropped, plain.dropped);
        assert_eq!(observed.jcts(&trace), plain.jcts(&trace));

        let t = telemetry.snapshot();
        assert_eq!(t.admissions, 3);
        assert_eq!(t.completions as usize, observed.scheduled);
        assert_eq!(t.placed as usize, observed.scheduled);
        assert_eq!(t.infeasible as usize, observed.dropped);
        assert_eq!(t.decisions, t.placed + t.infeasible + t.no_capacity);
        assert!(t.reconfigurations >= 1, "4x4x32 must reprogram the OCS");
        assert!(t.ocs_entries_reserved > 0);
        assert!(t.variants_enumerated > 0);
        assert!(t.decision_wall > std::time::Duration::ZERO);
    }

    #[test]
    fn no_capacity_memo_skips_probes_and_wakes_on_release() {
        // Job 0 fills the cluster; job 1 blocks at its head; five more
        // arrivals land while blocked. Each arrival triggers a drain, but
        // the epoch memo must keep the policy to exactly one NoCapacity
        // search — and job 0's release (epoch bump) must wake the head so
        // everything still completes.
        let mut trace = vec![
            job(0, 0.0, 100.0, JobShape::new(16, 16, 16)),
            // Half the cluster: blocked while job 0 runs, and leaves room
            // for the small jobs once it lands (so the storm behind it
            // never produces a second NoCapacity decision).
            job(1, 10.0, 10.0, JobShape::new(16, 16, 8)),
        ];
        for i in 2..7 {
            trace.push(job(i, 10.0 + i as f64, 5.0, JobShape::new(2, 2, 2)));
        }
        let telemetry = SharedTelemetry::new();
        let mut cfg = SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::Reconfig,
        );
        cfg.drain = true;
        let r = Simulation::new(cfg)
            .with_observer(Box::new(telemetry.clone()))
            .run(&trace);
        assert_eq!(r.scheduled, 7, "the release must wake the blocked head");
        let t = telemetry.snapshot();
        assert_eq!(
            t.no_capacity, 1,
            "arrival storms must not re-run the blocked head's search"
        );
        assert_eq!(t.decisions, t.placed + t.infeasible + t.no_capacity);
    }

    #[test]
    fn infeasible_shape_memoized_across_jobs() {
        // Three jobs sharing one never-placeable shape: all three drop,
        // but only the first runs a variant search — the repeats are
        // memo lookups whose synthesized decisions carry zero counters.
        let bad = JobShape::new(4, 4, 32); // > 16 on every static rotation
        let trace = vec![
            job(0, 0.0, 50.0, bad),
            job(1, 1.0, 50.0, JobShape::new(2, 2, 2)),
            job(2, 2.0, 50.0, bad),
            job(3, 3.0, 50.0, bad),
        ];
        let telemetry = SharedTelemetry::new();
        let mut cfg = SimConfig::new(ClusterTopo::static_4096(), PolicyKind::FirstFit);
        cfg.drain = true;
        let r = Simulation::new(cfg)
            .with_observer(Box::new(telemetry.clone()))
            .run(&trace);
        assert_eq!(r.dropped, 3);
        assert_eq!(r.scheduled, 1);
        let t = telemetry.snapshot();
        assert_eq!(t.infeasible, 3, "observers still see every drop");
        // One real search for the bad shape + one for the good job; the
        // two memoized drops contribute nothing.
        let single_bad = {
            let tele = SharedTelemetry::new();
            let mut c = SimConfig::new(ClusterTopo::static_4096(), PolicyKind::FirstFit);
            c.drain = true;
            Simulation::new(c)
                .with_observer(Box::new(tele.clone()))
                .run(&trace[..2]);
            tele.snapshot().variants_enumerated
        };
        assert_eq!(
            t.variants_enumerated, single_bad,
            "repeated infeasible shapes must cost a map lookup, not a search"
        );
    }

    #[test]
    fn ocs_latency_charges_reconfiguring_jobs() {
        // 4x4x32 reprograms the OCS (8 cubes chained); with
        // `ocs-latency=5s` its completion slips by exactly the switch
        // latency. The 2x2x2 job fits one cube without rewiring and must
        // pay nothing.
        let trace = vec![
            job(0, 0.0, 50.0, JobShape::new(4, 4, 32)),
            job(1, 0.0, 10.0, JobShape::new(2, 2, 2)),
        ];
        let mut cfg = SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::Reconfig,
        );
        cfg.drain = true;
        cfg.modifiers = ModifierSet::parse("ocs-latency=5s").unwrap();
        let r = Simulation::new(cfg).run(&trace);
        assert_eq!(r.scheduled, 2);
        let jcts = r.jcts(&trace);
        assert_eq!(jcts[0], 55.0, "OCS job pays the reconfiguration latency");
        assert_eq!(jcts[1], 10.0, "cube-local job is untouched");
    }

    #[test]
    fn fault_injection_yields_exactly_one_outcome_per_job() {
        // Aggressive Philly-style failures on a generated trace: jobs are
        // killed, requeued, re-killed, and sometimes dropped — but every
        // job must end with exactly one outcome (no phantom completion
        // from a dead attempt's stale event), Completed count must match
        // `scheduled`, and utilization must stay a probability even with
        // failures landing inside the measurement window.
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 80,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        let mut cfg = SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::RFold,
        );
        cfg.drain = true;
        cfg.modifiers = ModifierSet {
            failures: Some(crate::trace::scenarios::FailureModel {
                mtbf: 200.0,
                mean_repair: 100.0,
                link_fraction: 0.3,
                corr: None,
            }),
            fault_seed: 11,
            ..ModifierSet::default()
        };
        let telemetry = SharedTelemetry::new();
        let r = Simulation::new(cfg)
            .with_observer(Box::new(telemetry.clone()))
            .run(&trace);
        assert_eq!(r.outcomes.len(), trace.len(), "one outcome per job");
        let mut ids: Vec<u64> = r.outcomes.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "no job may finish twice");
        let completed = r
            .outcomes
            .iter()
            .filter(|(_, o)| matches!(o, JobOutcome::Completed { .. }))
            .count();
        assert_eq!(completed, r.scheduled);
        assert_eq!(r.jcts(&trace).len(), r.scheduled);
        let u = r.utilization.mean();
        assert!((0.0..=1.0).contains(&u), "utilization corrupted: {u}");
        let t = telemetry.snapshot();
        assert!(
            t.node_failures + t.link_failures > 0,
            "an MTBF of 200s must fire during a multi-hour trace"
        );
        assert!(t.repairs <= t.node_failures, "a repair needs a failure");
    }

    #[test]
    fn correlated_faults_blast_whole_domains() {
        // `corr:..:cube` on a 4^3-cube machine: every fault event must
        // take exactly one 64-node cube down (no cascade), with no
        // transient link flavor, and still leave one outcome per job.
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 40,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        let mut cfg = SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::RFold,
        );
        cfg.drain = true;
        cfg.modifiers = ModifierSet::parse("failures=corr:2000:600:cube").unwrap();
        let telemetry = SharedTelemetry::new();
        let r = Simulation::new(cfg)
            .with_observer(Box::new(telemetry.clone()))
            .run(&trace);
        let t = telemetry.snapshot();
        assert!(t.domain_faults > 0, "a 2000s MTBF must fire during the trace");
        assert_eq!(
            t.node_failures,
            t.domain_faults * 64,
            "every blast covers one whole 4^3 cube"
        );
        assert_eq!(t.link_failures, 0, "correlated faults remove capacity, always");
        assert_eq!(t.domain_cascades, 0, "cascade defaults to 0");
        assert_eq!(
            t.blast_sizes.keys().copied().collect::<Vec<_>>(),
            vec![64],
            "uniform cube-sized blasts"
        );
        assert!(t.repairs <= t.node_failures, "a repair needs a failure");
        assert_eq!(r.outcomes.len(), trace.len(), "one outcome per job");
        let u = r.utilization.mean();
        assert!((0.0..=1.0).contains(&u), "utilization corrupted: {u}");
    }

    #[test]
    fn cascades_double_the_blast_radius() {
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 30,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        let mut cfg = SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::RFold,
        );
        cfg.drain = true;
        cfg.modifiers = ModifierSet::parse("failures=corr:3000:600:cube:1").unwrap();
        let telemetry = SharedTelemetry::new();
        Simulation::new(cfg)
            .with_observer(Box::new(telemetry.clone()))
            .run(&trace);
        let t = telemetry.snapshot();
        assert!(t.domain_faults > 0);
        assert_eq!(
            t.domain_cascades, t.domain_faults,
            "cascade=1 must spill every blast into the neighbour domain"
        );
        assert_eq!(
            t.blast_sizes.keys().copied().collect::<Vec<_>>(),
            vec![128],
            "cube + neighbour cube"
        );
        assert_eq!(t.node_failures, t.domain_faults * 128);
    }

    /// Run `trace` through the streaming API (per-job `submit` with an
    /// `advance_before` admission peek, then `drain` + `finalize`) —
    /// the service loop's exact call sequence.
    fn run_streamed(mut cfg: SimConfig, trace: &[JobSpec]) -> RunResult {
        cfg.drain = true;
        let mut sim = Simulation::new(cfg);
        for idx in 0..trace.len() {
            sim.advance_before(trace, trace[idx].arrival);
            sim.submit(trace, idx);
        }
        sim.drain(trace);
        sim.finalize(trace)
    }

    fn assert_results_bit_equal(a: &RunResult, b: &RunResult, trace: &[JobSpec]) {
        assert_eq!(a.outcomes, b.outcomes);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.jcts(trace)), bits(&b.jcts(trace)));
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(
            a.utilization.mean().to_bits(),
            b.utilization.mean().to_bits()
        );
        assert_eq!(a.useful_util.to_bits(), b.useful_util.to_bits());
        assert_eq!(a.wasted_work.to_bits(), b.wasted_work.to_bits());
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.dropped, b.dropped);
    }

    #[test]
    fn streamed_submission_matches_batch_run() {
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 60,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        for mods in [
            "",
            "failures=philly,ocs-latency=5s,stragglers=0.05",
            "failures=corr:21600:3600:rack:0.3",
        ] {
            let mut cfg =
                SimConfig::new(ClusterTopo::reconfigurable_4096(4), PolicyKind::RFold);
            cfg.drain = true;
            cfg.modifiers = ModifierSet::parse(mods).unwrap();
            let batch = Simulation::new(cfg).run(&trace);
            let streamed = run_streamed(cfg, &trace);
            assert_results_bit_equal(&batch, &streamed, &trace);
        }
    }

    #[test]
    fn streamed_preemptive_run_matches_batch() {
        let trace = two_class_trace();
        let mut cfg = SimConfig::new(ClusterTopo::static_4096(), PolicyKind::FirstFit);
        cfg.drain = true;
        cfg.modifiers =
            ModifierSet::parse("preempt=priority,checkpoint=3s,migration-cost=30s").unwrap();
        let batch = Simulation::new(cfg).run(&trace);
        let streamed = run_streamed(cfg, &trace);
        assert_results_bit_equal(&batch, &streamed, &trace);
    }

    #[test]
    fn snapshot_restore_mid_run_reproduces_batch_bytes() {
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 60,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        for mods in [
            "",
            "failures=philly,ocs-latency=5s,stragglers=0.05",
            "failures=corr:21600:3600:rack:0.3",
        ] {
            let mut cfg =
                SimConfig::new(ClusterTopo::reconfigurable_4096(4), PolicyKind::RFold);
            cfg.drain = true;
            cfg.modifiers = ModifierSet::parse(mods).unwrap();
            let batch = Simulation::new(cfg).run(&trace);

            // Stream half the trace, snapshot through a JSON text round
            // trip (the persistence path), abandon the original engine,
            // and finish the run on the restored one.
            let mut sim = Simulation::new(cfg);
            for idx in 0..30 {
                sim.advance_before(&trace, trace[idx].arrival);
                sim.submit(&trace, idx);
            }
            let wire = sim.snapshot_state().to_string();
            drop(sim);
            let state = Json::parse(&wire).expect("snapshot must re-parse");
            let mut sim = Simulation::restore(cfg, &state).expect("restore");
            for idx in 30..trace.len() {
                sim.advance_before(&trace, trace[idx].arrival);
                sim.submit(&trace, idx);
            }
            sim.drain(&trace);
            let restored = sim.finalize(&trace);
            assert_results_bit_equal(&batch, &restored, &trace);
        }
    }

    #[test]
    fn restore_rejects_malformed_snapshots() {
        let cfg = SimConfig::new(ClusterTopo::static_4096(), PolicyKind::FirstFit);
        let err = Simulation::restore(cfg, &Json::parse("{}").unwrap()).unwrap_err();
        assert!(err.contains("snapshot"), "{err}");
        let mut sim = Simulation::new(cfg);
        let trace = vec![job(0, 0.0, 10.0, JobShape::new(2, 2, 2))];
        sim.submit(&trace, 0);
        let mut state = sim.snapshot_state().to_string();
        state = state.replace("\"queue\"", "\"not-the-queue\"");
        let err =
            Simulation::restore(cfg, &Json::parse(&state).unwrap()).unwrap_err();
        assert!(err.contains("queue"), "{err}");
    }

    #[test]
    fn fault_runs_are_deterministic() {
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 60,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        let mk = || {
            let mut cfg = SimConfig::new(
                ClusterTopo::reconfigurable_4096(4),
                PolicyKind::RFold,
            );
            cfg.drain = true;
            cfg.modifiers =
                ModifierSet::parse("failures=philly,ocs-latency=5s,stragglers=0.05")
                    .unwrap();
            Simulation::new(cfg).run(&trace)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.outcomes, b.outcomes);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.jcts(&trace)), bits(&b.jcts(&trace)));
        assert_eq!(
            a.utilization.mean().to_bits(),
            b.utilization.mean().to_bits()
        );
    }

    #[test]
    fn modifier_free_runs_match_the_unmodified_engine() {
        // Belt-and-braces for the golden bytes: constructing the config
        // with an explicit empty ModifierSet must change nothing
        // relative to the plain helper (which uses the default).
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 40,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        let plain = run(
            PolicyKind::RFold,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        let mut cfg = SimConfig::new(
            ClusterTopo::reconfigurable_4096(4),
            PolicyKind::RFold,
        );
        cfg.drain = true;
        cfg.modifiers = ModifierSet::parse("").unwrap();
        let explicit = Simulation::new(cfg).run(&trace);
        assert_eq!(plain.outcomes, explicit.outcomes);
        assert_eq!(plain.makespan.to_bits(), explicit.makespan.to_bits());
        assert_eq!(
            plain.utilization.mean().to_bits(),
            explicit.utilization.mean().to_bits()
        );
    }

    fn pjob(id: u64, arrival: f64, duration: f64, shape: JobShape, priority: u8) -> JobSpec {
        JobSpec {
            priority,
            ..job(id, arrival, duration, shape)
        }
    }

    /// Background class-0 job hogging the whole cluster, then an urgent
    /// class-1 arrival — the canonical two-class preemption scenario
    /// shared by the priority / checkpoint / migration tests below.
    fn two_class_trace() -> Vec<JobSpec> {
        vec![
            job(0, 0.0, 1000.0, JobShape::new(16, 16, 16)),
            pjob(1, 10.0, 10.0, JobShape::new(2, 2, 2), 1),
        ]
    }

    fn run_with(mods: &str, trace: &[JobSpec]) -> RunResult {
        let mut cfg = SimConfig::new(ClusterTopo::static_4096(), PolicyKind::FirstFit);
        cfg.drain = true;
        cfg.modifiers = ModifierSet::parse(mods).unwrap();
        Simulation::new(cfg).run(trace)
    }

    #[test]
    fn priority_preemption_unblocks_high_priority_head() {
        let trace = two_class_trace();
        // Without preemption the urgent job waits the full 1000s.
        let fifo = run_with("", &trace);
        assert_eq!(fifo.jcts(&trace), vec![1000.0, 1000.0]);
        assert_eq!(fifo.preemptions, 0);

        // With `preempt=priority` the class-1 head evicts the class-0
        // hog at t=10, runs immediately, and the hog restarts from
        // scratch (no checkpointing) once the cluster frees at t=20.
        let pre = run_with("preempt=priority", &trace);
        assert_eq!(pre.scheduled, 2, "preemption never drops the victim");
        assert_eq!(pre.jcts(&trace), vec![1020.0, 10.0]);
        assert_eq!(pre.preemptions, 1);
        // The victim's 10 un-checkpointed seconds on 4096 nodes re-run.
        assert_eq!(pre.wasted_work, 10.0 * 4096.0);
        // The measurement window [0,10] was fully busy with work that was
        // then thrown away: useful utilization collapses to exactly 0.
        assert_eq!(pre.utilization.mean(), 1.0);
        assert_eq!(pre.useful_util, 0.0);
    }

    #[test]
    fn checkpoint_restart_resumes_partial_work() {
        let trace = two_class_trace();
        // checkpoint=3s: the victim's 10 elapsed seconds credit 3 whole
        // intervals (9s); only 1s of progress is lost. Restart at t=20
        // with 991s remaining → finish 1011.
        let r = run_with("preempt=priority,checkpoint=3s", &trace);
        assert_eq!(r.jcts(&trace), vec![1011.0, 10.0]);
        assert_eq!(r.wasted_work, 1.0 * 4096.0);
        assert!((r.useful_util - 0.9).abs() < 1e-9);
    }

    #[test]
    fn migration_cost_charged_once_on_restart() {
        let trace = two_class_trace();
        // The evicted hog pays the 30s restart surcharge exactly once, on
        // its first post-eviction placement; the urgent job never
        // migrated and pays nothing.
        let r = run_with("preempt=priority,migration-cost=30s", &trace);
        assert_eq!(r.jcts(&trace), vec![1050.0, 10.0]);
        assert_eq!(r.migration_time, 30.0);
        assert_eq!(r.preemptions, 1);
    }

    #[test]
    fn aging_promotes_exhausted_victim_instead_of_excluding_it() {
        // A cluster-filling class-0 hog is preempted MAX_PREEMPTIONS (3)
        // times by short class-1 arrivals; a class-2 job then arrives at
        // t=70. Without aging the hog is immune (excluded from the victim
        // snapshot) and the class-2 job waits ~1000s behind it; with
        // `aging=on` the hog is presented one class up (priority 1),
        // which still yields to the class-2 head — a fourth eviction.
        let trace = vec![
            job(0, 0.0, 1000.0, JobShape::new(16, 16, 16)),
            pjob(1, 10.0, 10.0, JobShape::new(2, 2, 2), 1),
            pjob(2, 30.0, 10.0, JobShape::new(2, 2, 2), 1),
            pjob(3, 50.0, 10.0, JobShape::new(2, 2, 2), 1),
            pjob(4, 70.0, 10.0, JobShape::new(2, 2, 2), 2),
        ];
        // Immunity path: 3 evictions, restart at t=60, finish 1060; the
        // class-2 job runs only after the hog completes.
        let off = run_with("preempt=priority", &trace);
        assert_eq!(off.preemptions, 3, "starvation guard caps evictions");
        assert_eq!(off.jcts(&trace), vec![1060.0, 10.0, 10.0, 10.0, 1000.0]);

        // Aging path: a fourth eviction at t=70, restart at t=80.
        let aged = run_with("preempt=priority,aging=on", &trace);
        assert_eq!(aged.preemptions, 4, "aged victim is evictable again");
        assert_eq!(aged.jcts(&trace), vec![1080.0, 10.0, 10.0, 10.0, 10.0]);
        assert_eq!(aged.scheduled, 5, "aging never drops the victim");

        // The aged class (1) still outranks an equal-class head: class-1
        // arrivals cannot evict the promoted hog, so rows with only
        // class-0/1 traffic keep their no-aging bytes.
        let peer = run_with("preempt=priority,aging=on", &two_class_trace());
        let base = run_with("preempt=priority", &two_class_trace());
        assert_eq!(peer.jcts(&two_class_trace()), base.jcts(&two_class_trace()));
    }

    #[test]
    fn defrag_compacts_fragmented_cluster() {
        // Three quarter-cluster slabs; the middle one finishes first,
        // splitting the free space into two non-adjacent 1024-node holes.
        // A half-cluster job then needs 2048 *contiguous* nodes: without
        // defrag it waits for job 0 (t=100); with `defrag=idle` the
        // blocked head triggers a compaction pass that slides job 2 into
        // the hole, and the head starts at t=12.
        let trace = vec![
            job(0, 0.0, 100.0, JobShape::new(16, 16, 4)),
            job(1, 1.0, 10.0, JobShape::new(16, 16, 4)),
            job(2, 2.0, 100.0, JobShape::new(16, 16, 4)),
            job(3, 12.0, 10.0, JobShape::new(16, 16, 8)),
        ];
        let plain = run_with("", &trace);
        assert_eq!(plain.scheduled, 4);
        assert_eq!(plain.jcts(&trace)[3], 98.0, "head waits for job 0");

        let defrag = run_with("defrag=idle", &trace);
        assert_eq!(defrag.scheduled, 4, "defrag must never strand a job");
        assert_eq!(defrag.jcts(&trace)[3], 10.0, "compaction unblocks the head");
        assert_eq!(defrag.preemptions, 0, "defrag moves, it does not evict");
        // The moved job's completion is untouched (hitless relocation).
        assert_eq!(defrag.jcts(&trace)[2], plain.jcts(&trace)[2]);
    }

    #[test]
    fn fault_only_runs_carry_no_disruption_accounting() {
        // `--with failures=…` without any preemption/checkpoint knob must
        // leave every disruption field at its zero value and keep
        // useful_util bit-identical to the raw mean — the gate that keeps
        // pre-existing failure-row bytes untouched.
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 60,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&tc);
        let mut cfg = SimConfig::new(ClusterTopo::reconfigurable_4096(4), PolicyKind::RFold);
        cfg.drain = true;
        cfg.modifiers = ModifierSet::parse("failures=philly").unwrap();
        let r = Simulation::new(cfg).run(&trace);
        assert_eq!(r.preemptions, 0);
        assert_eq!(r.wasted_work, 0.0);
        assert_eq!(r.migration_time, 0.0);
        assert_eq!(r.useful_util.to_bits(), r.utilization.mean().to_bits());
    }

    #[test]
    fn preemptive_runs_are_deterministic() {
        // The full disruption surface at once — faults, priority
        // preemption, migration cost, idle defrag, checkpointing — twice,
        // bit-for-bit.
        let tc = crate::trace::gen::TraceConfig {
            num_jobs: 60,
            ..Default::default()
        };
        let mut trace = crate::trace::gen::generate(&tc);
        for (i, j) in trace.iter_mut().enumerate() {
            j.priority = (i % 3) as u8;
        }
        let mk = || {
            let mut cfg = SimConfig::new(ClusterTopo::reconfigurable_4096(4), PolicyKind::RFold);
            cfg.drain = true;
            cfg.modifiers = ModifierSet::parse(
                "failures=philly,preempt=priority,migration-cost=30s,defrag=idle,checkpoint=10m",
            )
            .unwrap();
            Simulation::new(cfg).run(&trace)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.wasted_work.to_bits(), b.wasted_work.to_bits());
        assert_eq!(a.migration_time.to_bits(), b.migration_time.to_bits());
        assert_eq!(a.useful_util.to_bits(), b.useful_util.to_bits());
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.jcts(&trace)), bits(&b.jcts(&trace)));
        // Every job still resolves to exactly one outcome.
        let mut ids: Vec<u64> = a.outcomes.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len());
    }

    #[test]
    fn deterministic_runs() {
        let cfg = crate::trace::gen::TraceConfig {
            num_jobs: 60,
            ..Default::default()
        };
        let trace = crate::trace::gen::generate(&cfg);
        let a = run(
            PolicyKind::RFold,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        let b = run(
            PolicyKind::RFold,
            ClusterTopo::reconfigurable_4096(4),
            &trace,
        );
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.jcts(&trace), b.jcts(&trace));
    }
}
