//! Nested failure domains for correlated fault injection.
//!
//! Real torus clusters fail in spatially correlated chunks: a rack PSU
//! takes out an x-column of nodes, an optical-switch incident takes out a
//! whole OCS cube, a plane failure takes out a z-slice. This module maps
//! every node of a [`ClusterTopo`] to exactly one domain per
//! [`DomainScope`], so the engine can fail and repair a sampled domain
//! atomically (`--with failures=corr:MTBF:REPAIR:SCOPE[:CASCADE]`).
//!
//! The mapping is a pure function of `(topology, scope)` — no RNG, no
//! occupancy — so the fault realization stays byte-deterministic and
//! occupancy-independent: the engine samples *which* domain fails from
//! the dedicated fault stream, and this module answers *what nodes* that
//! domain contains.

use crate::topology::cluster::ClusterTopo;
use crate::trace::scenarios::DomainScope;

/// The failure-domain decomposition of one topology at one scope.
///
/// Domains partition the node id space: every node belongs to exactly
/// one domain, ids run `0..num_domains()`, and the node list of a domain
/// is ascending — the engine's kill/repair sweeps stay deterministic by
/// iterating it in order.
#[derive(Clone, Copy, Debug)]
pub struct DomainMap {
    topo: ClusterTopo,
    scope: DomainScope,
}

impl DomainMap {
    pub fn new(topo: ClusterTopo, scope: DomainScope) -> DomainMap {
        DomainMap { topo, scope }
    }

    pub fn scope(&self) -> DomainScope {
        self.scope
    }

    /// Number of domains at this scope. Always >= 1.
    pub fn num_domains(&self) -> usize {
        match self.scope {
            // One rack per physical x coordinate.
            DomainScope::Rack => self.topo.phys_ext().x(),
            // One domain per OCS cube; a static torus is one big cube
            // (see `ClusterTopo::cube_side`), so `cube` on a static
            // topology is a whole-machine blast radius.
            DomainScope::Cube => match self.topo {
                ClusterTopo::Static { .. } => 1,
                ClusterTopo::Reconfigurable { grid } => grid.num_cubes(),
            },
            // One plane per physical z coordinate.
            DomainScope::Plane => self.topo.phys_ext().z(),
        }
    }

    /// Nodes of one domain, ascending node id.
    pub fn nodes_of(&self, domain: usize) -> Vec<usize> {
        debug_assert!(domain < self.num_domains());
        match self.scope {
            DomainScope::Cube => match self.topo {
                ClusterTopo::Static { ext } => (0..ext.volume()).collect(),
                ClusterTopo::Reconfigurable { grid } => {
                    let vol = grid.n * grid.n * grid.n;
                    (domain * vol..(domain + 1) * vol).collect()
                }
            },
            DomainScope::Rack | DomainScope::Plane => {
                let axis = if self.scope == DomainScope::Rack { 0 } else { 2 };
                let total = self.topo.num_xpus();
                (0..total)
                    .filter(|&n| self.coord(n, axis) == domain)
                    .collect()
            }
        }
    }

    /// Domain id of one node.
    pub fn domain_of(&self, node: usize) -> usize {
        match self.scope {
            DomainScope::Cube => match self.topo {
                ClusterTopo::Static { .. } => 0,
                ClusterTopo::Reconfigurable { grid } => node / (grid.n * grid.n * grid.n),
            },
            DomainScope::Rack => self.coord(node, 0),
            DomainScope::Plane => self.coord(node, 2),
        }
    }

    /// Number of nodes in each domain (uniform: domains partition the
    /// machine along one axis or the cube decomposition).
    pub fn domain_size(&self) -> usize {
        self.topo.num_xpus() / self.num_domains()
    }

    /// The deterministic cascade neighbour of a domain: the next domain
    /// id, wrapping — adjacent rack / plane / cube in scan order. Using a
    /// fixed neighbour (instead of sampling one) keeps a cascade to a
    /// single extra draw (the coin) on the fault stream.
    pub fn neighbor(&self, domain: usize) -> usize {
        (domain + 1) % self.num_domains()
    }

    /// Physical machine-room coordinate of a node along one axis.
    fn coord(&self, node: usize, axis: usize) -> usize {
        match self.topo {
            ClusterTopo::Static { ext } => {
                crate::topology::P3::from_index(node, ext).0[axis]
            }
            ClusterTopo::Reconfigurable { grid } => {
                let (cube, local) = grid.split_node(node);
                grid.cube_coords(cube).0[axis] * grid.n + local.0[axis]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scopes() -> [DomainScope; 3] {
        [DomainScope::Rack, DomainScope::Cube, DomainScope::Plane]
    }

    #[test]
    fn domains_partition_every_topology() {
        for topo in [
            ClusterTopo::static_4096(),
            ClusterTopo::reconfigurable_4096(4),
            ClusterTopo::reconfigurable_4096(8),
            ClusterTopo::reconfigurable_4096(2),
        ] {
            for scope in scopes() {
                let map = DomainMap::new(topo, scope);
                let nd = map.num_domains();
                assert!(nd >= 1, "{topo:?} {scope:?}");
                let mut seen = vec![false; topo.num_xpus()];
                for d in 0..nd {
                    let nodes = map.nodes_of(d);
                    assert_eq!(
                        nodes.len(),
                        map.domain_size(),
                        "{topo:?} {scope:?} domain {d} size"
                    );
                    assert!(
                        nodes.windows(2).all(|w| w[0] < w[1]),
                        "nodes of a domain must ascend"
                    );
                    for &n in &nodes {
                        assert!(!seen[n], "node {n} in two domains ({topo:?} {scope:?})");
                        seen[n] = true;
                        assert_eq!(map.domain_of(n), d, "domain_of must invert nodes_of");
                    }
                }
                assert!(seen.iter().all(|&s| s), "domains must cover every node");
            }
        }
    }

    #[test]
    fn rack_is_an_x_column_and_plane_a_z_slice() {
        let topo = ClusterTopo::reconfigurable_4096(4);
        let racks = DomainMap::new(topo, DomainScope::Rack);
        assert_eq!(racks.num_domains(), 16, "16 physical x coordinates");
        assert_eq!(racks.domain_size(), 256);
        let planes = DomainMap::new(topo, DomainScope::Plane);
        assert_eq!(planes.num_domains(), 16);
        // Node 0 is the machine-room origin: rack 0, plane 0.
        assert_eq!(racks.domain_of(0), 0);
        assert_eq!(planes.domain_of(0), 0);
        // First node of cube 1 sits at physical (0,0,4): rack 0, plane 4.
        assert_eq!(racks.domain_of(64), 0);
        assert_eq!(planes.domain_of(64), 4);
    }

    #[test]
    fn cube_scope_matches_the_ocs_decomposition() {
        let topo = ClusterTopo::reconfigurable_4096(4);
        let map = DomainMap::new(topo, DomainScope::Cube);
        assert_eq!(map.num_domains(), 64);
        assert_eq!(map.nodes_of(0), (0..64).collect::<Vec<_>>());
        assert_eq!(map.domain_of(63), 0);
        assert_eq!(map.domain_of(64), 1);
        // Static topologies degenerate to one whole-machine domain.
        let st = DomainMap::new(ClusterTopo::static_4096(), DomainScope::Cube);
        assert_eq!(st.num_domains(), 1);
        assert_eq!(st.domain_size(), 4096);
    }

    #[test]
    fn neighbor_wraps_deterministically() {
        let map = DomainMap::new(ClusterTopo::reconfigurable_4096(4), DomainScope::Rack);
        assert_eq!(map.neighbor(0), 1);
        assert_eq!(map.neighbor(15), 0);
    }
}
