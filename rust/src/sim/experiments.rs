//! Experiment drivers: one function per paper table/figure, shared by the
//! `rfold` CLI and the `cargo bench` harnesses so both always produce the
//! same rows (see DESIGN.md §3 experiment index).

use crate::metrics::CellSummary;
use crate::placement::{builtins, PolicyHandle};
use crate::sim::contention;
use crate::sim::sweep::{self, SweepConfig};
use crate::topology::cluster::ClusterTopo;
use crate::topology::routing::LinkLoads;
use crate::topology::P3;

/// One (policy, topology) experiment cell. The policy is a resolved
/// registry handle, so cell tables never pattern-match a policy enum.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub policy: PolicyHandle,
    pub topo: ClusterTopo,
    pub label: &'static str,
}

/// The six Table-1 cells (policy ↔ topology pairings of §4).
pub fn table1_cells() -> Vec<Cell> {
    vec![
        Cell {
            policy: builtins::FIRST_FIT,
            topo: ClusterTopo::static_4096(),
            label: "FirstFit (16^3)",
        },
        Cell {
            policy: builtins::FOLDING,
            topo: ClusterTopo::static_4096(),
            label: "Folding (16^3)",
        },
        Cell {
            policy: builtins::RECONFIG,
            topo: ClusterTopo::reconfigurable_4096(8),
            label: "Reconfig (8^3)",
        },
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(8),
            label: "RFold (8^3)",
        },
        Cell {
            policy: builtins::RECONFIG,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "Reconfig (4^3)",
        },
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        },
    ]
}

/// Figure 3 compares the policies that reach 100% JCR: Reconfig and RFold
/// at 4³ and 2³ cubes.
pub fn fig3_cells() -> Vec<Cell> {
    vec![
        Cell {
            policy: builtins::RECONFIG,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "Reconfig (4^3)",
        },
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        },
        Cell {
            policy: builtins::RECONFIG,
            topo: ClusterTopo::reconfigurable_4096(2),
            label: "Reconfig (2^3)",
        },
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(2),
            label: "RFold (2^3)",
        },
    ]
}

/// Run one cell over `runs` seeded traces. Seeds are `base_seed..+runs`,
/// shared across cells so every policy sees identical workloads.
///
/// Trials run on the global work-queue runner via
/// [`sweep::run_cell_sharded`], with the process-wide result cache in
/// front: a cell repeated across drivers (Table 1 → Figure 4, grids in
/// `rfold all`) simulates once. The summary is bit-identical to the old
/// serial loop (the runner keeps the same per-trial seed derivation and
/// aggregates in trial order).
pub fn run_cell(cell: Cell, runs: usize, jobs_per_run: usize, base_seed: u64) -> CellSummary {
    run_cell_with(cell, runs, jobs_per_run, base_seed, [true; 3])
}

/// `run_cell` with the folding-dimensionality ablation knob (A2).
pub fn run_cell_with(
    cell: Cell,
    runs: usize,
    jobs_per_run: usize,
    base_seed: u64,
    fold_dims_enabled: [bool; 3],
) -> CellSummary {
    let mut cfg = SweepConfig::new(runs, jobs_per_run, base_seed);
    cfg.fold_dims_enabled = fold_dims_enabled;
    sweep::run_cell_sharded(cell, &cfg)
}

/// `run_cell` with scenario modifiers (`--with` fault injection). The
/// base set is stored on the sweep config; each trial mixes its own seed
/// in at simulation time.
pub fn run_cell_mods(
    cell: Cell,
    runs: usize,
    jobs_per_run: usize,
    base_seed: u64,
    modifiers: crate::trace::scenarios::ModifierSet,
) -> CellSummary {
    let mut cfg = SweepConfig::new(runs, jobs_per_run, base_seed);
    cfg.modifiers = modifiers;
    sweep::run_cell_sharded(cell, &cfg)
}

/// One row of the failure-model ablation grid: a (policy, topology) cell
/// under one failure model at one MTBF. Printed by
/// `metrics::report::print_fault_ablation` as `FAULTGRID` lines.
#[derive(Clone, Debug)]
pub struct FaultAblationRow {
    /// Cell label (policy + topology).
    pub label: &'static str,
    /// Policy name alone, for per-policy grouping.
    pub policy: &'static str,
    /// `"independent"` (`exp:`) or `"correlated"` (`corr:`).
    pub model: &'static str,
    /// Cluster-wide mean time between failures (s).
    pub mtbf: f64,
    /// The full modifier fingerprint that produced the row — enough to
    /// reproduce it via `--with`.
    pub mods: String,
    pub summary: CellSummary,
}

/// The failure-model ablation grid (PR-6 follow-on): every cell at every
/// MTBF under independent (`exp:`) and correlated rack-scoped (`corr:`)
/// failures side by side, with the Philly repair mean and link fraction
/// held fixed so MTBF is the only moving part between rows. Rows come
/// back mtbf-major, model-minor, cell-minor — a stable order that diffs
/// cleanly. Trials run through the shared sweep runner, so repeated cells
/// hit the process-wide result cache like any other driver.
pub fn fault_ablation_grid(
    cells: &[Cell],
    mtbfs: &[f64],
    runs: usize,
    jobs_per_run: usize,
    base_seed: u64,
) -> Vec<FaultAblationRow> {
    let mut rows = Vec::new();
    for &mtbf in mtbfs {
        // Both specs share the Philly repair mean; `exp:` keeps the
        // Philly link fraction, `corr:` is infrastructure-scoped (no
        // transient link flavor) with a rack blast radius.
        let specs = [
            ("independent", format!("failures=exp:{mtbf}:3600:0.25")),
            ("correlated", format!("failures=corr:{mtbf}:3600:rack")),
        ];
        for (model, spec) in specs {
            let mods = crate::trace::scenarios::ModifierSet::parse(&spec)
                .expect("ablation specs are well-formed by construction");
            for &cell in cells {
                let summary = run_cell_mods(cell, runs, jobs_per_run, base_seed, mods);
                rows.push(FaultAblationRow {
                    label: cell.label,
                    policy: cell.policy.name(),
                    model,
                    mtbf,
                    mods: mods.fingerprint(),
                    summary,
                });
            }
        }
    }
    rows
}

/// §3.1 motivation experiment on a 2×2 mesh: returns
/// `(label, modeled slowdown vs baseline)` rows matching the paper's
/// measured percentages.
pub fn motivation_rows() -> Vec<(String, f64)> {
    let ext = P3([2, 2, 1]);
    let row = [P3([0, 0, 0]), P3([1, 0, 0])];
    let diag = [P3([0, 0, 0]), P3([1, 1, 0])];
    let diag2 = [P3([1, 0, 0]), P3([0, 1, 0])];

    // Helper: mean dilation + max load for a 2-node ring on a mesh with
    // optional competing rings at a traffic multiplier.
    let measure = |members: &[P3], others: &[(&[P3], f64)]| -> f64 {
        let mut loads = LinkLoads::new_mesh(ext);
        for (ring, mult) in others {
            for (axis, p) in loads.ring_cables(ring) {
                loads.add(axis, p, contention::RING_UNIT * mult);
            }
        }
        let mut hops = 0usize;
        for w in 0..members.len() {
            let a = members[w];
            let b = members[(w + 1) % members.len()];
            hops += loads.path_cables(a, b).len();
        }
        let cables = loads.ring_cables(members);
        for &(axis, p) in &cables {
            loads.add(axis, p, contention::RING_UNIT);
        }
        let max_load = cables
            .iter()
            .map(|&(axis, p)| loads.get(axis, p))
            .fold(0.0f64, f64::max);
        let dilation = hops as f64 / members.len() as f64;
        contention::slowdown(dilation, max_load)
    };

    let base_row = measure(&row, &[]);
    let single_diag = measure(&diag, &[]);
    let shared = measure(&diag, &[(&diag2, 1.0)]);
    let shared_2x = measure(&diag, &[(&diag2, 2.0)]);
    let shared_3x = measure(&diag, &[(&diag2, 3.0)]);

    vec![
        ("row placement (baseline)".into(), base_row / base_row),
        ("diagonal vs row".into(), single_diag / base_row),
        ("two diagonal jobs (vs single diagonal)".into(), shared / single_diag),
        ("competing load 2x (vs single diagonal)".into(), shared_2x / single_diag),
        ("competing load 3x (vs single diagonal)".into(), shared_3x / single_diag),
    ]
}

/// Ablation A1: Reconfig/RFold across cube sizes.
pub fn ablation_cube_cells() -> Vec<Cell> {
    vec![
        Cell {
            policy: builtins::RECONFIG,
            topo: ClusterTopo::reconfigurable_4096(8),
            label: "Reconfig (8^3)",
        },
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(8),
            label: "RFold (8^3)",
        },
        Cell {
            policy: builtins::RECONFIG,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "Reconfig (4^3)",
        },
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        },
        Cell {
            policy: builtins::RECONFIG,
            topo: ClusterTopo::reconfigurable_4096(2),
            label: "Reconfig (2^3)",
        },
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(2),
            label: "RFold (2^3)",
        },
    ]
}

/// A3: best-effort vs RFold — queueing delay vs contention slowdown.
pub fn besteffort_cells() -> Vec<Cell> {
    vec![
        Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        },
        Cell {
            policy: builtins::BEST_EFFORT,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "BestEffort (4^3)",
        },
        Cell {
            policy: builtins::HILBERT,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "Hilbert/SLURM (4^3)",
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn motivation_matches_paper_ratios() {
        let rows = motivation_rows();
        let val = |i: usize| rows[i].1;
        assert!((val(1) - 1.17).abs() < 0.02, "diag vs row: {}", val(1));
        assert!((val(2) - 1.35).abs() < 0.05, "shared: {}", val(2));
        assert!((val(3) - 1.95).abs() < 0.15, "2x: {}", val(3));
        assert!((val(4) - 2.86).abs() < 0.25, "3x: {}", val(4));
    }

    #[test]
    fn fault_ablation_grid_pairs_models_per_mtbf() {
        let cells = [Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        }];
        let rows = fault_ablation_grid(&cells, &[21_600.0, 86_400.0], 1, 20, 11);
        assert_eq!(rows.len(), 4, "2 MTBFs x 2 models x 1 cell");
        // mtbf-major, model-minor order; independent first.
        assert_eq!(rows[0].model, "independent");
        assert_eq!(rows[1].model, "correlated");
        assert_eq!(rows[0].mtbf, 21_600.0);
        assert_eq!(rows[2].mtbf, 86_400.0);
        assert!(rows.iter().all(|r| r.policy == "RFold"));
        // The mods fingerprint reproduces the row via --with.
        assert_eq!(rows[0].mods, "failures=exp:21600:3600:0.25");
        assert_eq!(rows[1].mods, "failures=corr:21600:3600:rack");
        // Every run under faults still yields a sane JCR.
        assert!(rows.iter().all(|r| r.summary.avg_jcr_pct > 0.0));
    }

    #[test]
    fn small_table1_ordering() {
        // A miniature Table 1 (few runs, few jobs) must already show the
        // qualitative ordering: RFold(4³) ≥ Reconfig(4³) ≥ ... ≥ FirstFit.
        let cells = table1_cells();
        let sums: Vec<CellSummary> = cells
            .iter()
            .map(|&c| run_cell(c, 2, 60, 10))
            .collect();
        let jcr = |label: &str| {
            sums.iter()
                .find(|s| s.label == label)
                .map(|s| s.avg_jcr_pct)
                .unwrap()
        };
        assert!(jcr("RFold (4^3)") >= 99.9, "{}", jcr("RFold (4^3)"));
        assert!(jcr("Reconfig (4^3)") >= 99.9);
        assert!(jcr("FirstFit (16^3)") < jcr("Folding (16^3)"));
        assert!(jcr("Folding (16^3)") <= jcr("RFold (8^3)") + 15.0);
        assert!(jcr("Reconfig (8^3)") < jcr("RFold (8^3)"));
    }
}
