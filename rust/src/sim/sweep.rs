//! Sharded multi-threaded experiment sweeps over the workload-scenario
//! matrix.
//!
//! Every paper table/figure is a grid of (policy × topology × scenario)
//! cells, each averaged over `runs` seeded trials. Trials are mutually
//! independent — they share nothing but their configuration — so this
//! module shards them across OS threads with `std::thread::scope` (no
//! external dependencies).
//!
//! ## Determinism contract
//!
//! Results are **bit-identical for any thread count**, including 1:
//!
//! * trial `r` always uses seed [`trial_seed`]`(base_seed, r)` — the same
//!   derivation the old serial loop in `experiments::run_cell` used;
//! * trial `r`'s result always lands in slot `r` of the output vector, so
//!   aggregation order never depends on scheduling;
//! * per-trial simulation is single-threaded and deterministic, and no
//!   wall-clock or thread-count value flows into any reported row
//!   (progress/timing goes to stderr only).
//!
//! `tests/sweep_determinism.rs` locks this contract down.

use std::time::Instant;

use crate::metrics::{summarize, CellSummary};
use crate::sim::engine::{RunResult, SimConfig, Simulation};
use crate::sim::experiments::Cell;
use crate::topology::cluster::ClusterTopo;
use crate::trace::gen::generate;
use crate::trace::scenarios::Scenario;
use crate::trace::JobSpec;

/// Knobs of one sharded cell run.
#[derive(Clone, Copy, Debug)]
pub struct SweepConfig {
    pub runs: usize,
    pub jobs_per_run: usize,
    pub base_seed: u64,
    /// OS threads to shard trials across; 0 = one per available core.
    pub threads: usize,
    /// Ablation A2 knob, forwarded to [`SimConfig`].
    pub fold_dims_enabled: [bool; 3],
    pub scenario: Scenario,
}

impl SweepConfig {
    pub fn new(runs: usize, jobs_per_run: usize, base_seed: u64) -> SweepConfig {
        SweepConfig {
            runs,
            jobs_per_run,
            base_seed,
            threads: 0,
            fold_dims_enabled: [true; 3],
            scenario: Scenario::PaperDefault,
        }
    }
}

/// Thread count used when `SweepConfig::threads` is 0.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1)
}

/// Seed of trial `r`: `base_seed + r`, the derivation the serial driver
/// always used, independent of sharding. Seeds are shared across cells and
/// scenarios so every policy sees identical per-trial randomness streams.
pub fn trial_seed(base_seed: u64, trial: usize) -> u64 {
    base_seed.wrapping_add(trial as u64)
}

/// One trial: generate the scenario trace for this trial's seed, simulate.
fn run_trial(cell: Cell, cfg: &SweepConfig, trial: usize) -> (RunResult, Vec<JobSpec>) {
    let tc = cfg
        .scenario
        .trace_config(cfg.jobs_per_run, trial_seed(cfg.base_seed, trial));
    let trace = generate(&tc);
    let mut sim_cfg = SimConfig::new(cell.topo, cell.policy);
    sim_cfg.fold_dims_enabled = cfg.fold_dims_enabled;
    let result = Simulation::new(sim_cfg).run(&trace);
    (result, trace)
}

/// Run every trial of one cell, sharded across OS threads. Slot `r` of the
/// returned vector always holds trial `r`.
pub fn run_trials(cell: Cell, cfg: &SweepConfig) -> Vec<(RunResult, Vec<JobSpec>)> {
    if cfg.runs == 0 {
        return Vec::new();
    }
    let requested = if cfg.threads == 0 {
        auto_threads()
    } else {
        cfg.threads
    };
    let threads = requested.clamp(1, cfg.runs);
    let mut slots: Vec<Option<(RunResult, Vec<JobSpec>)>> = Vec::new();
    slots.resize_with(cfg.runs, || None);
    if threads == 1 {
        for (trial, slot) in slots.iter_mut().enumerate() {
            *slot = Some(run_trial(cell, cfg, trial));
        }
    } else {
        // Contiguous shards: thread `t` owns trials [t*chunk, (t+1)*chunk).
        // Each shard gets a disjoint &mut slice of the slot vector, so no
        // locks and no result reordering are possible.
        let chunk = cfg.runs.div_ceil(threads);
        std::thread::scope(|scope| {
            for (shard, shard_slots) in slots.chunks_mut(chunk).enumerate() {
                let first = shard * chunk;
                scope.spawn(move || {
                    for (offset, slot) in shard_slots.iter_mut().enumerate() {
                        *slot = Some(run_trial(cell, cfg, first + offset));
                    }
                });
            }
        });
    }
    slots
        .into_iter()
        .map(|s| s.expect("every shard fills its slots"))
        .collect()
}

/// Sharded replacement for the serial per-cell experiment loop: identical
/// summary, wall-clock divided by the effective thread count.
pub fn run_cell_sharded(cell: Cell, cfg: &SweepConfig) -> CellSummary {
    let trials = run_trials(cell, cfg);
    let pairs: Vec<(RunResult, &[JobSpec])> = trials
        .iter()
        .map(|(r, t)| (r.clone(), t.as_slice()))
        .collect();
    summarize(cell.label, &pairs)
}

/// One row of the sweep grid: a (scenario, policy, topology) cell summary
/// plus the knobs that produced it. Serialized to machine-readable JSON by
/// `metrics::report::sweep_row_json`.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub scenario: &'static str,
    pub cell: &'static str,
    pub policy: &'static str,
    pub topo: String,
    pub runs: usize,
    pub jobs_per_run: usize,
    pub base_seed: u64,
    pub summary: CellSummary,
}

/// Short stable topology tag for machine-readable rows.
pub fn topo_tag(topo: ClusterTopo) -> String {
    match topo {
        ClusterTopo::Static { ext } => {
            format!("static-{}x{}x{}", ext.0[0], ext.0[1], ext.0[2])
        }
        ClusterTopo::Reconfigurable { grid } => {
            format!("ocs-{}cubes-{}^3", grid.num_cubes(), grid.n)
        }
    }
}

/// Run the full policy × topology × scenario grid. Cells run in order;
/// each cell's trials shard across `threads` OS threads (0 = auto).
/// Progress and timing go to stderr so the returned rows (and anything
/// printed from them) stay byte-identical across thread counts.
pub fn run_grid(
    cells: &[Cell],
    scenarios: &[Scenario],
    runs: usize,
    jobs_per_run: usize,
    base_seed: u64,
    threads: usize,
) -> Vec<SweepRow> {
    let mut rows = Vec::with_capacity(cells.len() * scenarios.len());
    for &scenario in scenarios {
        for &cell in cells {
            let mut cfg = SweepConfig::new(runs, jobs_per_run, base_seed);
            cfg.threads = threads;
            cfg.scenario = scenario;
            let t0 = Instant::now();
            let summary = run_cell_sharded(cell, &cfg);
            eprintln!(
                "sweep: {:<22} {:<20} {:>6.1}s",
                scenario.name(),
                cell.label,
                t0.elapsed().as_secs_f64()
            );
            rows.push(SweepRow {
                scenario: scenario.name(),
                cell: cell.label,
                policy: cell.policy.name(),
                topo: topo_tag(cell.topo),
                runs,
                jobs_per_run,
                base_seed,
                summary,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::PolicyKind;

    fn tiny_cell() -> Cell {
        Cell {
            policy: PolicyKind::Folding,
            topo: ClusterTopo::static_4096(),
            label: "Folding (16^3)",
        }
    }

    #[test]
    fn trial_seeds_match_serial_derivation() {
        assert_eq!(trial_seed(10, 0), 10);
        assert_eq!(trial_seed(10, 3), 13);
        assert_eq!(trial_seed(u64::MAX, 1), 0); // wraps, never panics
    }

    #[test]
    fn sharded_equals_serial() {
        let mut cfg = SweepConfig::new(5, 30, 3);
        cfg.threads = 1;
        let serial = run_trials(tiny_cell(), &cfg);
        cfg.threads = 3;
        let sharded = run_trials(tiny_cell(), &cfg);
        assert_eq!(serial.len(), sharded.len());
        for ((ra, ta), (rb, tb)) in serial.iter().zip(&sharded) {
            assert_eq!(ta, tb, "traces must match per trial slot");
            assert_eq!(ra.scheduled, rb.scheduled);
            assert_eq!(ra.dropped, rb.dropped);
            assert_eq!(ra.jcts(ta), rb.jcts(tb));
        }
    }

    #[test]
    fn more_threads_than_trials_is_fine() {
        let mut cfg = SweepConfig::new(2, 20, 1);
        cfg.threads = 16;
        assert_eq!(run_trials(tiny_cell(), &cfg).len(), 2);
    }

    #[test]
    fn zero_runs_yields_no_trials() {
        let cfg = SweepConfig::new(0, 10, 1);
        assert!(run_trials(tiny_cell(), &cfg).is_empty());
    }

    #[test]
    fn topo_tags_stable() {
        assert_eq!(topo_tag(ClusterTopo::static_4096()), "static-16x16x16");
        assert_eq!(
            topo_tag(ClusterTopo::reconfigurable_4096(4)),
            "ocs-64cubes-4^3"
        );
    }
}
