//! Global work-queue experiment runner over the workload matrix, with a
//! process-wide trial-result cache and pluggable trial executors.
//!
//! Every paper table/figure is a grid of (policy × topology × workload)
//! cells, each averaged over `runs` seeded trials. Trials are mutually
//! independent — they share nothing but their configuration — so the
//! whole grid flattens into (workload, cell, trial) work items. *Where*
//! those items simulate is behind the [`TrialExecutor`] trait:
//!
//! * [`LocalExecutor`] — N worker threads pulling items off a shared
//!   atomic cursor in this process (the default; 0 = one per core);
//! * [`crate::coordinator::pool::PoolExecutor`] — the same item stream
//!   fanned out to `rfold worker` daemons over TCP, with items from dead
//!   connections retried and a leader-side fallback, for cluster-scale
//!   grids.
//!
//! ## Determinism contract
//!
//! Results are **bit-identical for any executor**, including 1 local
//! worker and any mix of TCP workers:
//!
//! * trial `r` always uses seed [`trial_seed`]`(base_seed, r)` — the same
//!   derivation the old serial loop in `experiments::run_cell` used;
//! * every work item writes into its pre-indexed slot, so aggregation
//!   order never depends on scheduling or on which worker computed what;
//! * per-trial simulation is single-threaded and deterministic, remote
//!   results travel bit-exactly (f64s as IEEE-754 bit patterns), and no
//!   wall-clock, worker-count or host value flows into any reported row
//!   (progress/timing, cache and pool statistics go to stderr only).
//!
//! ## Result cache
//!
//! A trial is fully determined by
//! `(policy, topology, workload, trial seed, jobs_per_run, fold_dims)` —
//! notably *not* by the cell label — so cells sharing that tuple (Table 1
//! vs Figure 3 vs the ablation grids reuse many (policy, topology) pairs)
//! simulate once. The workload component is an *owned* key
//! ([`Workload::cache_key`]): synthetic scenarios key on their registry
//! name, `--trace-file` workloads on stem + content hash, so file-backed
//! traces flow through the cache without ever colliding across files.
//! Fixed traces also drop the seed and requested job count from the key
//! (their replay ignores both), so every trial of a trace cell beyond
//! the first is a cache hit rather than a duplicate simulation.
//! [`ResultCache::global`] persists across grids within a process;
//! duplicates inside one grid are deduplicated before the queue is built.
//! When the resident set would exceed the byte bound, the cache evicts
//! the **oldest half** of its entries (replacing the old wholesale
//! flush) while preserving keys pinned by grids still issuing items.
//! Within one `run_queue` call the pins are belt-and-braces — every
//! resolved hit already holds its `Arc` — but they keep concurrent
//! grids' inserts from evicting entries another grid is mid-resolve on,
//! and they are released before a grid's own inserts so the byte bound
//! still applies to it.
//!
//! `tests/sweep_determinism.rs` and `tests/distributed_pool.rs` lock
//! these contracts down.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::metrics::{summarize, CellSummary};
use crate::placement::PolicyHandle;
use crate::sim::engine::{RunResult, SimConfig, Simulation};
use crate::sim::experiments::Cell;
use crate::topology::cluster::ClusterTopo;
use crate::trace::scenarios::{ModifierSet, Scenario, Workload};
use crate::trace::JobSpec;

/// Knobs of one swept cell.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub runs: usize,
    pub jobs_per_run: usize,
    pub base_seed: u64,
    /// Worker threads pulling from the work queue; 0 = one per core.
    pub workers: usize,
    /// Ablation A2 knob, forwarded to [`SimConfig`].
    pub fold_dims_enabled: [bool; 3],
    /// The workload: a synthetic scenario (regenerated per seed) or a
    /// fixed CSV trace.
    pub workload: Workload,
    /// Scenario modifiers (`--with`). Stored as the *base* set; each
    /// trial mixes its own seed in via [`ModifierSet::for_trial`] at
    /// simulation time so trials draw independent fault realizations.
    pub modifiers: ModifierSet,
}

impl SweepConfig {
    pub fn new(runs: usize, jobs_per_run: usize, base_seed: u64) -> SweepConfig {
        SweepConfig {
            runs,
            jobs_per_run,
            base_seed,
            workers: 0,
            fold_dims_enabled: [true; 3],
            workload: Workload::Synthetic(Scenario::PaperDefault),
            modifiers: ModifierSet::default(),
        }
    }
}

/// Worker count used when `SweepConfig::workers` is 0.
pub fn auto_workers() -> usize {
    std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1)
}

/// Seed of trial `r`: `base_seed + r`, the derivation the serial driver
/// always used, independent of scheduling. Seeds are shared across cells
/// and workloads so every policy sees identical per-trial randomness
/// streams.
pub fn trial_seed(base_seed: u64, trial: usize) -> u64 {
    base_seed.wrapping_add(trial as u64)
}

/// One simulated trial: the run result plus the trace it consumed (needed
/// for arrival lookups during aggregation). Shared via `Arc` — the cache
/// hands the same output to every cell that maps to the same key. The
/// trace itself is also shared (`Arc<[JobSpec]>`): a fixed CSV workload's
/// job list is one allocation referenced by every trial, never re-cloned
/// per trial or per wire decode.
#[derive(Debug)]
pub struct TrialOutput {
    pub result: RunResult,
    pub trace: Arc<[JobSpec]>,
}

impl TrialOutput {
    /// Approximate heap footprint, for the cache's byte bound. The trace
    /// allocation is counted per referencing trial (an over-estimate for
    /// shared CSV traces — the safe direction for a memory bound).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.trace.len() * std::mem::size_of::<JobSpec>()
            + self.result.outcomes.capacity()
                * std::mem::size_of::<(u64, crate::sim::engine::JobOutcome)>()
            + self.result.utilization.approx_bytes()
    }
}

/// Everything that determines a trial's bytes. The cell *label* is
/// deliberately absent: it names the row, it does not influence the
/// simulation. The policy is identified by its canonical registry key and
/// the workload by [`Workload::cache_key`] — both stable across
/// processes, which is what the TCP pool needs to share caches between
/// leader and workers.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct TrialKey {
    policy: &'static str,
    topo: ClusterTopo,
    workload: String,
    seed: u64,
    jobs_per_run: usize,
    fold_dims: [bool; 3],
    /// Canonical modifier fingerprint ([`ModifierSet::fingerprint`]):
    /// empty for the default set, so modifier-free grids key exactly as
    /// before. The fingerprint includes the fault seed, so two sweeps
    /// differing only in `seed=` never share trials.
    mods: String,
}

/// One (workload, cell, trial) work item of a flattened grid. Public so
/// [`TrialExecutor`] backends outside this module (the TCP pool) can
/// encode and run items.
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub cell: Cell,
    pub cfg: SweepConfig,
    pub trial: usize,
}

impl WorkItem {
    /// The seed this item's trace is generated from.
    pub fn seed(&self) -> u64 {
        trial_seed(self.cfg.base_seed, self.trial)
    }

    fn key(&self) -> TrialKey {
        // A fixed CSV trace ignores both the seed and the requested job
        // count (`Workload::trace` replays the recorded realization), so
        // neither may enter the key: with them, a `--runs 8` trace sweep
        // would simulate the identical trial 8 times; without them, trial
        // 0 computes and trials 1..8 are in-grid cache hits.
        //
        // That collapse is only sound *without* modifiers: with faults
        // on, each trial mixes its own seed into the fault stream
        // ([`ModifierSet::for_trial`]), so trials of the same fixed trace
        // are genuinely distinct simulations and must keep their seed —
        // collapsing them would serve trial 0's fault realization for
        // every run *and* let a modified trial collide with its
        // unmodified twin's cached bytes.
        let (seed, jobs_per_run) = match &self.cfg.workload {
            Workload::Synthetic(_) => (self.seed(), self.cfg.jobs_per_run),
            Workload::Csv { jobs, .. } if self.cfg.modifiers.is_empty() => (0, jobs.len()),
            Workload::Csv { jobs, .. } => (self.seed(), jobs.len()),
        };
        TrialKey {
            policy: self.cell.policy.key(),
            topo: self.cell.topo,
            workload: self.cfg.workload.cache_key(),
            seed,
            jobs_per_run,
            fold_dims: self.cfg.fold_dims_enabled,
            mods: self.cfg.modifiers.fingerprint(),
        }
    }

    /// Simulate this item in-process: generate (or replay) the trace for
    /// this trial's seed and run it. Every executor backend bottoms out
    /// here — locally, or inside a remote `rfold worker`.
    pub fn run(&self) -> TrialOutput {
        let trace = self.cfg.workload.trace(self.cfg.jobs_per_run, self.seed());
        let result = run_trial_raw(
            self.cell.policy,
            self.cell.topo,
            &trace,
            self.cfg.fold_dims_enabled,
            self.cfg.modifiers.for_trial(self.seed()),
        );
        TrialOutput { result, trace }
    }
}

/// One trial from raw parts — the exact simulation a [`WorkItem::run`]
/// performs, exposed so a pool worker can execute a decoded wire item
/// through the same code path as the leader. `modifiers` is the
/// *per-trial* set — callers mix the trial seed in via
/// [`ModifierSet::for_trial`] before handing it over, so leader and
/// remote workers agree by construction (both mix the same wire seed).
pub fn run_trial_raw(
    policy: PolicyHandle,
    topo: ClusterTopo,
    trace: &[JobSpec],
    fold_dims_enabled: [bool; 3],
    modifiers: ModifierSet,
) -> RunResult {
    let mut sim_cfg = SimConfig::new(topo, policy);
    sim_cfg.fold_dims_enabled = fold_dims_enabled;
    sim_cfg.modifiers = modifiers;
    Simulation::new(sim_cfg).run(trace)
}

/// Build the every-tenth-trial stderr liveness reporter shared by the
/// executor backends (`prefix` tags the backend, e.g. `"sweep"` /
/// `"pool"`): a paper-scale grid takes hours, and silence would be
/// indistinguishable from a hang.
pub fn progress_reporter(prefix: &'static str, total: usize) -> impl Fn(&WorkItem) + Sync {
    let done = AtomicUsize::new(0);
    move |it: &WorkItem| {
        let d = done.fetch_add(1, Ordering::Relaxed) + 1;
        let step = (total / 10).max(1);
        if d % step == 0 || d == total {
            eprintln!(
                "{prefix}: {d}/{total} trials done ({} {})",
                it.cfg.workload.name(),
                it.cell.label
            );
        }
    }
}

/// Where a batch of fresh (cache-missed) work items gets computed. The
/// contract every backend must honor:
///
/// * return exactly one output per input item, **in input order** — the
///   caller's slot table depends on position stability;
/// * each output must be bit-identical to `items[i].run()` — determinism
///   across backends is what makes SWEEP rows byte-comparable between
///   `--workers N` and `--pool host1,host2`;
/// * progress/telemetry goes to stderr only.
pub trait TrialExecutor: Sync {
    /// Short backend tag for stderr diagnostics (e.g. `"local"`).
    fn name(&self) -> &str;

    /// Compute every item, position-stably.
    fn execute(&self, items: &[WorkItem]) -> Vec<Arc<TrialOutput>>;
}

/// The in-process backend: `workers` OS threads (0 = one per core) racing
/// on one atomic cursor over the item list — item granularity, so
/// small-`runs` grids still saturate every core.
pub struct LocalExecutor {
    pub workers: usize,
}

impl LocalExecutor {
    pub fn new(workers: usize) -> LocalExecutor {
        LocalExecutor { workers }
    }
}

impl TrialExecutor for LocalExecutor {
    fn name(&self) -> &str {
        "local"
    }

    fn execute(&self, items: &[WorkItem]) -> Vec<Arc<TrialOutput>> {
        let total = items.len();
        let progress = progress_reporter("sweep", total);
        let requested = if self.workers == 0 {
            auto_workers()
        } else {
            self.workers
        };
        let w = requested.clamp(1, total.max(1));
        if w <= 1 {
            return items
                .iter()
                .map(|it| {
                    let out = Arc::new(it.run());
                    progress(it);
                    out
                })
                .collect();
        }
        let cursor = AtomicUsize::new(0);
        let mut computed: Vec<Option<Arc<TrialOutput>>> = Vec::new();
        computed.resize_with(total, || None);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..w)
                .map(|_| {
                    scope.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            let f = cursor.fetch_add(1, Ordering::Relaxed);
                            let Some(it) = items.get(f) else { break };
                            local.push((f, Arc::new(it.run())));
                            progress(it);
                        }
                        local
                    })
                })
                .collect();
            for h in handles {
                for (f, out) in h.join().expect("sweep worker panicked") {
                    computed[f] = Some(out);
                }
            }
        });
        computed
            .into_iter()
            .map(|s| s.expect("queue fills every slot"))
            .collect()
    }
}

/// Upper bound on the approximate bytes the default caches keep resident
/// (256 MiB). A `TrialOutput` holds the full trace plus per-job outcomes
/// and utilization samples (~100 KB at paper scale), so an unbounded
/// process-global cache would grow monotonically across `rfold all` /
/// `make bench-full`. When an insert would exceed the bound the cache
/// evicts its oldest half (stderr note), preserving keys pinned by grids
/// still in flight; determinism is unaffected (an evicted trial
/// re-simulates to identical bytes).
pub const MAX_RESIDENT_BYTES: usize = 256 << 20;

/// A resident entry plus its insertion sequence number (the eviction
/// age — older entries go first).
struct CacheEntry {
    out: Arc<TrialOutput>,
    seq: u64,
}

/// Resident entries plus their bookkept approximate footprint and the
/// pin set — one struct behind one mutex so none of them can drift.
struct CacheInner {
    map: HashMap<TrialKey, CacheEntry>,
    bytes: usize,
    next_seq: u64,
    /// Refcounted keys of grids currently inside [`run_queue`]: eviction
    /// must not discard a trial that a not-yet-issued duplicate item in
    /// an in-flight grid still references.
    pinned: HashMap<TrialKey, usize>,
}

/// Memoized trial results keyed by [`TrialKey`], plus hit/miss counters.
/// Thread-safe; the process-global instance ([`ResultCache::global`])
/// makes repeated grids (Table 1 → Figure 4, repeated CLI subcommands in
/// `rfold all`, overlapping bench sections) reuse each other's trials.
/// Byte-bounded with oldest-half eviction (pinned keys survive).
pub struct ResultCache {
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    capacity: usize,
}

impl ResultCache {
    pub fn new() -> ResultCache {
        ResultCache::with_capacity(MAX_RESIDENT_BYTES)
    }

    /// A cache with an explicit byte bound (tests shrink it to force
    /// eviction without gigabytes of trials).
    pub fn with_capacity(capacity: usize) -> ResultCache {
        ResultCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                bytes: 0,
                next_seq: 0,
                pinned: HashMap::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            capacity,
        }
    }

    /// The process-wide cache used by [`run_trials`] / `run_cell_sharded`.
    pub fn global() -> &'static ResultCache {
        static GLOBAL: OnceLock<ResultCache> = OnceLock::new();
        GLOBAL.get_or_init(ResultCache::new)
    }

    fn get(&self, key: &TrialKey) -> Option<Arc<TrialOutput>> {
        self.inner.lock().unwrap().map.get(key).map(|e| e.out.clone())
    }

    /// Insert one trial, evicting the oldest unpinned half of the
    /// resident set first if the byte bound would be exceeded.
    fn insert(&self, key: TrialKey, out: Arc<TrialOutput>) {
        let add = out.approx_bytes();
        let mut inner = self.inner.lock().unwrap();
        if inner.bytes + add > self.capacity && !inner.map.is_empty() {
            let before = (inner.map.len(), inner.bytes);
            // Oldest (smallest seq) unpinned entries first, capped at
            // half the resident set. If everything is pinned the bound
            // is allowed to overshoot: correctness of in-flight grids
            // beats the memory target.
            let mut ages: Vec<(u64, TrialKey)> = inner
                .map
                .iter()
                .filter(|(k, _)| !inner.pinned.contains_key(*k))
                .map(|(k, e)| (e.seq, k.clone()))
                .collect();
            ages.sort_unstable_by_key(|(seq, _)| *seq);
            let target = inner.map.len().div_ceil(2);
            for (_, k) in ages.into_iter().take(target) {
                if let Some(e) = inner.map.remove(&k) {
                    inner.bytes = inner.bytes.saturating_sub(e.out.approx_bytes());
                }
            }
            eprintln!(
                "sweep: result cache evicted {} of {} trials (~{} -> ~{} MiB, bound {} MiB)",
                before.0 - inner.map.len(),
                before.0,
                before.1 >> 20,
                inner.bytes >> 20,
                self.capacity >> 20
            );
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if let Some(old) = inner.map.insert(key, CacheEntry { out, seq }) {
            inner.bytes = inner.bytes.saturating_sub(old.out.approx_bytes());
        }
        inner.bytes += add;
    }

    /// Pin `keys` against eviction for the duration of a grid (refcounted;
    /// call [`ResultCache::unpin`] with the same keys when done).
    fn pin(&self, keys: &[TrialKey]) {
        let mut inner = self.inner.lock().unwrap();
        for k in keys {
            *inner.pinned.entry(k.clone()).or_insert(0) += 1;
        }
    }

    fn unpin(&self, keys: &[TrialKey]) {
        let mut inner = self.inner.lock().unwrap();
        for k in keys {
            if let Some(c) = inner.pinned.get_mut(k) {
                *c -= 1;
                if *c == 0 {
                    inner.pinned.remove(k);
                }
            }
        }
    }

    /// Cached trial count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate bytes the cached trials keep resident.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Work items served without simulating (cache or in-grid dedup).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Work items actually simulated.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Drop every cached trial (counters are kept; callers wanting a
    /// pristine cache build a fresh one).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.clear();
        inner.bytes = 0;
    }
}

impl Default for ResultCache {
    fn default() -> Self {
        ResultCache::new()
    }
}

/// Where slot `i` of a queue run gets its output from.
enum Source {
    /// Served by the cache (or an identical item earlier in this grid).
    Cached(Arc<TrialOutput>),
    /// Computed by the executor; index into the fresh-output table.
    Fresh(usize),
}

/// Run a flattened item list against a cache and an executor. Slot `i` of
/// the returned vector always holds item `i`'s output, so results are
/// position-stable for any backend; items whose [`TrialKey`] repeats
/// (within the list or in the cache) simulate exactly once. The item
/// keys stay pinned in the cache while items are still being issued
/// (resolve + execute); the pins are released before results are
/// inserted so the grid's own inserts can evict normally.
/// Drop-guard releasing a grid's cache pins even if the executor (or a
/// collection assert) panics mid-queue — a leaked pin would permanently
/// exempt its key from eviction in the process-global cache.
struct PinGuard<'a> {
    cache: &'a ResultCache,
    keys: &'a [TrialKey],
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        self.cache.unpin(self.keys);
    }
}

fn run_queue(
    items: &[WorkItem],
    cache: &ResultCache,
    executor: &dyn TrialExecutor,
) -> Vec<Arc<TrialOutput>> {
    let keys: Vec<TrialKey> = items.iter().map(WorkItem::key).collect();
    cache.pin(&keys);
    let _pins = PinGuard { cache, keys: &keys };

    // Resolve each slot: cache hit, duplicate of an earlier slot, or a
    // fresh item for the executor. `fresh[f]` is the item index computed
    // by executor position `f`.
    let mut sources: Vec<Source> = Vec::with_capacity(items.len());
    let mut fresh: Vec<usize> = Vec::new();
    let mut fresh_of: HashMap<&TrialKey, usize> = HashMap::new();
    let mut hits = 0u64;
    for (i, key) in keys.iter().enumerate() {
        if let Some(out) = cache.get(key) {
            sources.push(Source::Cached(out));
            hits += 1;
        } else if let Some(&f) = fresh_of.get(key) {
            sources.push(Source::Fresh(f));
            hits += 1;
        } else {
            fresh_of.insert(key, fresh.len());
            sources.push(Source::Fresh(fresh.len()));
            fresh.push(i);
        }
    }
    cache.hits.fetch_add(hits, Ordering::Relaxed);
    cache.misses.fetch_add(fresh.len() as u64, Ordering::Relaxed);

    let mut computed: Vec<Arc<TrialOutput>> = Vec::new();
    if !fresh.is_empty() {
        let fresh_items: Vec<WorkItem> = fresh.iter().map(|&i| items[i].clone()).collect();
        computed = executor.execute(&fresh_items);
        assert_eq!(
            computed.len(),
            fresh_items.len(),
            "executor '{}' must fill every fresh slot",
            executor.name()
        );
        // Every item is now issued and its output held by an `Arc`, so
        // the pins have done their job — release them *before* the
        // insert loop, or a paper-scale grid (whose own keys can exceed
        // the byte bound) would exempt itself from eviction and overshoot
        // the cache's memory target until some later grid's insert.
        drop(_pins);
        for (f, &i) in fresh.iter().enumerate() {
            cache.insert(keys[i].clone(), computed[f].clone());
        }
    }

    sources
        .into_iter()
        .map(|s| match s {
            Source::Cached(out) => out,
            Source::Fresh(f) => computed[f].clone(),
        })
        .collect()
}

/// Run every trial of one cell through the work queue against an explicit
/// cache (in-process, `cfg.workers` threads). Slot `r` of the returned
/// vector always holds trial `r`.
pub fn run_trials_with(
    cell: Cell,
    cfg: &SweepConfig,
    cache: &ResultCache,
) -> Vec<Arc<TrialOutput>> {
    let items: Vec<WorkItem> = (0..cfg.runs)
        .map(|trial| WorkItem {
            cell,
            cfg: cfg.clone(),
            trial,
        })
        .collect();
    run_queue(&items, cache, &LocalExecutor::new(cfg.workers))
}

/// [`run_trials_with`] against the process-global cache.
pub fn run_trials(cell: Cell, cfg: &SweepConfig) -> Vec<Arc<TrialOutput>> {
    run_trials_with(cell, cfg, ResultCache::global())
}

/// Thin shim kept for the serial per-cell drivers (`experiments::run_cell`
/// and the golden Table-1 snapshot): one cell on the work-queue runner,
/// summarized identically to the old serial loop — borrowed trial
/// outputs, no per-cell deep clones.
pub fn run_cell_sharded(cell: Cell, cfg: &SweepConfig) -> CellSummary {
    let trials = run_trials(cell, cfg);
    let pairs: Vec<(&RunResult, &[JobSpec])> = trials
        .iter()
        .map(|t| (&t.result, &t.trace[..]))
        .collect();
    summarize(cell.label, &pairs)
}

/// One row of the sweep grid: a (workload, policy, topology) cell summary
/// plus the knobs that produced it. Serialized to machine-readable JSON by
/// `metrics::report::sweep_row_json`.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// Workload report name (scenario name or trace-file stem).
    pub scenario: String,
    pub cell: &'static str,
    pub policy: &'static str,
    pub topo: String,
    pub runs: usize,
    pub jobs_per_run: usize,
    pub base_seed: u64,
    pub summary: CellSummary,
}

/// Short stable topology tag for machine-readable rows.
pub fn topo_tag(topo: ClusterTopo) -> String {
    match topo {
        ClusterTopo::Static { ext } => {
            format!("static-{}x{}x{}", ext.0[0], ext.0[1], ext.0[2])
        }
        ClusterTopo::Reconfigurable { grid } => {
            format!("ocs-{}cubes-{}^3", grid.num_cubes(), grid.n)
        }
    }
}

/// [`run_grid_with`] on the in-process executor: every (workload, cell,
/// trial) item is pulled by `workers` OS threads (0 = auto) from one
/// shared cursor. Modifier-free — `rfold sweep --with ...` goes through
/// [`run_grid_with`] directly.
pub fn run_grid(
    cells: &[Cell],
    workloads: &[Workload],
    runs: usize,
    jobs_per_run: usize,
    base_seed: u64,
    workers: usize,
    cache: &ResultCache,
) -> Vec<SweepRow> {
    run_grid_with(
        cells,
        workloads,
        runs,
        jobs_per_run,
        base_seed,
        ModifierSet::default(),
        cache,
        &LocalExecutor::new(workers),
    )
}

/// Run the full policy × topology × workload grid: flatten into
/// (workload, cell, trial) items, deduplicate through `cache`, compute
/// the misses on `executor` (in-process threads or the TCP pool), and
/// aggregate position-stably. Progress, timing and cache statistics go
/// to stderr so the returned rows (and anything printed from them) stay
/// byte-identical across executors and cache states.
pub fn run_grid_with(
    cells: &[Cell],
    workloads: &[Workload],
    runs: usize,
    jobs_per_run: usize,
    base_seed: u64,
    modifiers: ModifierSet,
    cache: &ResultCache,
    executor: &dyn TrialExecutor,
) -> Vec<SweepRow> {
    if runs == 0 {
        return Vec::new();
    }
    let mut items = Vec::with_capacity(cells.len() * workloads.len() * runs);
    for workload in workloads {
        for &cell in cells {
            let mut cfg = SweepConfig::new(runs, jobs_per_run, base_seed);
            cfg.workload = workload.clone();
            cfg.modifiers = modifiers;
            for trial in 0..runs {
                items.push(WorkItem {
                    cell,
                    cfg: cfg.clone(),
                    trial,
                });
            }
        }
    }
    let (hits0, misses0) = (cache.hits(), cache.misses());
    let t0 = Instant::now();
    let slots = run_queue(&items, cache, executor);

    // Aggregate per cell: slots are grid-ordered (workload-major, then
    // cell, then trial), so each cell owns one contiguous `runs` chunk.
    let mut rows = Vec::with_capacity(cells.len() * workloads.len());
    let mut chunks = slots.chunks(runs);
    for workload in workloads {
        for &cell in cells {
            let trials = chunks.next().expect("one slot chunk per cell");
            let pairs: Vec<(&RunResult, &[JobSpec])> = trials
                .iter()
                .map(|t| (&t.result, &t.trace[..]))
                .collect();
            rows.push(SweepRow {
                scenario: workload.name().to_string(),
                cell: cell.label,
                policy: cell.policy.name(),
                topo: topo_tag(cell.topo),
                // What a trial actually saw, not the requested knobs: a
                // fixed trace ignores `--jobs` and replays one recording
                // for every seed, so its rows must not claim e.g. 256
                // jobs or 8 independent runs for a 12-job file. With
                // modifiers on, each trial of a fixed trace draws its own
                // fault realization, so the runs really are independent.
                runs: if modifiers.is_empty() {
                    workload.num_runs(runs)
                } else {
                    runs
                },
                jobs_per_run: workload.num_jobs(jobs_per_run),
                base_seed,
                summary: summarize(cell.label, &pairs),
            });
        }
    }
    eprintln!(
        "sweep: {} rows ({} work items, {} executor) in {:>6.1}s — cache: {} hits / {} \
         misses this grid, {} trials resident",
        rows.len(),
        items.len(),
        executor.name(),
        t0.elapsed().as_secs_f64(),
        cache.hits() - hits0,
        cache.misses() - misses0,
        cache.len(),
    );
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::builtins;
    use crate::trace::gen::{generate, TraceConfig};

    fn tiny_cell() -> Cell {
        Cell {
            policy: builtins::FOLDING,
            topo: ClusterTopo::static_4096(),
            label: "Folding (16^3)",
        }
    }

    fn paper_default() -> Vec<Workload> {
        vec![Workload::Synthetic(Scenario::PaperDefault)]
    }

    #[test]
    fn trial_seeds_match_serial_derivation() {
        assert_eq!(trial_seed(10, 0), 10);
        assert_eq!(trial_seed(10, 3), 13);
        assert_eq!(trial_seed(u64::MAX, 1), 0); // wraps, never panics
    }

    #[test]
    fn queued_equals_serial() {
        let mut cfg = SweepConfig::new(5, 30, 3);
        cfg.workers = 1;
        let serial = run_trials_with(tiny_cell(), &cfg, &ResultCache::new());
        cfg.workers = 3;
        let queued = run_trials_with(tiny_cell(), &cfg, &ResultCache::new());
        assert_eq!(serial.len(), queued.len());
        for (a, b) in serial.iter().zip(&queued) {
            assert_eq!(a.trace, b.trace, "traces must match per trial slot");
            assert_eq!(a.result.scheduled, b.result.scheduled);
            assert_eq!(a.result.dropped, b.result.dropped);
            assert_eq!(a.result.jcts(&a.trace), b.result.jcts(&b.trace));
        }
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let mut cfg = SweepConfig::new(2, 20, 1);
        cfg.workers = 16;
        assert_eq!(
            run_trials_with(tiny_cell(), &cfg, &ResultCache::new()).len(),
            2
        );
    }

    #[test]
    fn zero_runs_yields_no_trials() {
        let cfg = SweepConfig::new(0, 10, 1);
        assert!(run_trials_with(tiny_cell(), &cfg, &ResultCache::new()).is_empty());
        let rows = run_grid(
            &[tiny_cell()],
            &paper_default(),
            0,
            10,
            1,
            1,
            &ResultCache::new(),
        );
        assert!(rows.is_empty());
    }

    #[test]
    fn duplicate_items_simulate_once() {
        // The same cell listed twice in one grid: every duplicated slot
        // must be served by the first computation (hit), and the two rows
        // must be identical.
        let cache = ResultCache::new();
        let cells = [tiny_cell(), tiny_cell()];
        let rows = run_grid(&cells, &paper_default(), 3, 25, 7, 2, &cache);
        assert_eq!(rows.len(), 2);
        assert_eq!(cache.misses(), 3, "3 unique trials simulate");
        assert_eq!(cache.hits(), 3, "the duplicate cell's 3 slots are hits");
        assert_eq!(cache.len(), 3);
        assert_eq!(rows[0].summary.avg_jcr_pct, rows[1].summary.avg_jcr_pct);
        assert_eq!(rows[0].summary.util_cdf, rows[1].summary.util_cdf);
    }

    #[test]
    fn cache_survives_across_grids() {
        let cache = ResultCache::new();
        let cells = [tiny_cell()];
        let first = run_grid(&cells, &paper_default(), 2, 25, 7, 2, &cache);
        assert_eq!(cache.misses(), 2);
        assert!(cache.resident_bytes() > 0, "byte accounting must track inserts");
        let again = run_grid(&cells, &paper_default(), 2, 25, 7, 8, &cache);
        assert_eq!(cache.misses(), 2, "second grid is all hits");
        // Cold grid: 0 hits / 2 misses; warm grid: 2 hits / 0 misses.
        assert_eq!(cache.hits(), 2);
        assert_eq!(first[0].summary.avg_jcr_pct, again[0].summary.avg_jcr_pct);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn label_is_not_part_of_the_cache_key() {
        // Two cells differing only in label share trials; summaries carry
        // their own labels.
        let cache = ResultCache::new();
        let a = tiny_cell();
        let b = Cell { label: "same cell, other name", ..a };
        let rows = run_grid(&[a, b], &paper_default(), 2, 20, 5, 0, &cache);
        assert_eq!(cache.misses(), 2);
        assert_eq!(rows[0].summary.avg_jcr_pct, rows[1].summary.avg_jcr_pct);
        assert_eq!(rows[0].cell, "Folding (16^3)");
        assert_eq!(rows[1].cell, "same cell, other name");
    }

    #[test]
    fn fold_dims_are_part_of_the_cache_key() {
        let cache = ResultCache::new();
        let cell = Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        };
        let mut cfg = SweepConfig::new(2, 20, 5);
        let _ = run_trials_with(cell, &cfg, &cache);
        cfg.fold_dims_enabled = [false, false, false];
        let _ = run_trials_with(cell, &cfg, &cache);
        assert_eq!(cache.misses(), 4, "ablation knobs must not collide");
    }

    #[test]
    fn csv_workloads_key_on_content_not_stem() {
        // Two file-backed workloads with the same stem but different jobs
        // must occupy distinct cache keys; re-running the first must hit.
        let mk = |seed: u64| {
            generate(&TraceConfig {
                num_jobs: 10,
                seed,
                ..Default::default()
            })
        };
        let wa = Workload::from_jobs("trace".into(), mk(1));
        let wb = Workload::from_jobs("trace".into(), mk(2));
        let cache = ResultCache::new();
        // A fixed trace ignores the trial seed, so `runs = 2` is one
        // simulation plus one in-grid hit — not two simulations.
        let rows_a = run_grid(&[tiny_cell()], &[wa.clone()], 2, 10, 3, 1, &cache);
        assert_eq!(cache.misses(), 1, "fixed traces simulate once per cell");
        assert_eq!(cache.hits(), 1, "the second trial replays trial 0");
        let rows_b = run_grid(&[tiny_cell()], &[wb], 2, 10, 3, 1, &cache);
        assert_eq!(cache.misses(), 2, "same stem, different content: no collision");
        let again = run_grid(&[tiny_cell()], &[wa], 2, 10, 3, 1, &cache);
        assert_eq!(cache.misses(), 2, "identical content replays from cache");
        assert_eq!(rows_a[0].scenario, "trace");
        assert_eq!(rows_b[0].scenario, "trace");
        assert_eq!(rows_a[0].runs, 1, "a fixed trace is one realization, not 2");
        assert_eq!(rows_a[0].jobs_per_run, 10, "the trace's own job count");
        assert_eq!(
            rows_a[0].summary.avg_jcr_pct,
            again[0].summary.avg_jcr_pct
        );
    }

    #[test]
    fn eviction_drops_oldest_half_but_never_pinned_keys() {
        // A cache that holds roughly two trials: inserting a stream of
        // distinct trials must evict the oldest, yet a pinned key must
        // survive every eviction.
        let sample = WorkItem {
            cell: tiny_cell(),
            cfg: SweepConfig::new(1, 12, 1),
            trial: 0,
        };
        let bytes = sample.run().approx_bytes();
        let cache = ResultCache::with_capacity(bytes * 2 + bytes / 2);
        let item = |trial: usize| WorkItem {
            cell: tiny_cell(),
            cfg: SweepConfig::new(8, 12, 1),
            trial,
        };
        let pinned_key = item(0).key();
        cache.pin(std::slice::from_ref(&pinned_key));
        for trial in 0..8 {
            let it = item(trial);
            cache.insert(it.key(), Arc::new(it.run()));
        }
        assert!(
            cache.len() < 8,
            "a 2-trial capacity must have forced evictions ({} resident)",
            cache.len()
        );
        assert!(
            cache.get(&pinned_key).is_some(),
            "pinned key must survive every eviction"
        );
        cache.unpin(std::slice::from_ref(&pinned_key));
        // Once unpinned, the key is evictable again like any other.
        for trial in 8..16 {
            let it = item(trial);
            cache.insert(it.key(), Arc::new(it.run()));
        }
        assert!(cache.get(&pinned_key).is_none(), "unpinned oldest entry evicts");
    }

    #[test]
    fn pins_are_refcounted() {
        let key = WorkItem {
            cell: tiny_cell(),
            cfg: SweepConfig::new(1, 10, 1),
            trial: 0,
        }
        .key();
        let cache = ResultCache::new();
        cache.pin(std::slice::from_ref(&key));
        cache.pin(std::slice::from_ref(&key));
        cache.unpin(std::slice::from_ref(&key));
        assert!(
            cache.inner.lock().unwrap().pinned.contains_key(&key),
            "one of two pins released: still pinned"
        );
        cache.unpin(std::slice::from_ref(&key));
        assert!(!cache.inner.lock().unwrap().pinned.contains_key(&key));
    }

    #[test]
    fn topo_tags_stable() {
        assert_eq!(topo_tag(ClusterTopo::static_4096()), "static-16x16x16");
        assert_eq!(
            topo_tag(ClusterTopo::reconfigurable_4096(4)),
            "ocs-64cubes-4^3"
        );
    }
}
