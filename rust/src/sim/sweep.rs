//! Global work-queue experiment runner over the workload-scenario matrix,
//! with a process-wide trial-result cache.
//!
//! Every paper table/figure is a grid of (policy × topology × scenario)
//! cells, each averaged over `runs` seeded trials. Trials are mutually
//! independent — they share nothing but their configuration — so the
//! whole grid flattens into (scenario, cell, trial) work items that N
//! worker threads pull off a shared atomic cursor. Sharding at work-item
//! granularity (not per-cell) keeps every core busy even when `runs` is
//! tiny: a `runs=2` grid of 12 cells is 24 items, not 2-at-a-time.
//!
//! ## Determinism contract
//!
//! Results are **bit-identical for any worker count**, including 1:
//!
//! * trial `r` always uses seed [`trial_seed`]`(base_seed, r)` — the same
//!   derivation the old serial loop in `experiments::run_cell` used;
//! * every work item writes into its pre-indexed slot, so aggregation
//!   order never depends on scheduling;
//! * per-trial simulation is single-threaded and deterministic, and no
//!   wall-clock or worker-count value flows into any reported row
//!   (progress/timing and cache statistics go to stderr only).
//!
//! ## Result cache
//!
//! A trial is fully determined by
//! `(policy, topology, scenario, trial seed, jobs_per_run, fold_dims)` —
//! notably *not* by the cell label — so cells sharing that tuple (Table 1
//! vs Figure 3 vs the ablation grids reuse many (policy, topology) pairs)
//! simulate once. [`ResultCache::global`] persists across grids within a
//! process: `rfold all` pays for Figure 4's cells only once because Table
//! 1 already ran them. Duplicates inside one grid are deduplicated before
//! the queue is built, so they never occupy a worker. Hit/miss counts are
//! reported on stderr only.
//!
//! `tests/sweep_determinism.rs` locks both contracts down.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::metrics::{summarize, CellSummary};
use crate::sim::engine::{RunResult, SimConfig, Simulation};
use crate::sim::experiments::Cell;
use crate::topology::cluster::ClusterTopo;
use crate::trace::gen::generate;
use crate::trace::scenarios::Scenario;
use crate::trace::JobSpec;

/// Knobs of one swept cell.
#[derive(Clone, Copy, Debug)]
pub struct SweepConfig {
    pub runs: usize,
    pub jobs_per_run: usize,
    pub base_seed: u64,
    /// Worker threads pulling from the work queue; 0 = one per core.
    pub workers: usize,
    /// Ablation A2 knob, forwarded to [`SimConfig`].
    pub fold_dims_enabled: [bool; 3],
    pub scenario: Scenario,
}

impl SweepConfig {
    pub fn new(runs: usize, jobs_per_run: usize, base_seed: u64) -> SweepConfig {
        SweepConfig {
            runs,
            jobs_per_run,
            base_seed,
            workers: 0,
            fold_dims_enabled: [true; 3],
            scenario: Scenario::PaperDefault,
        }
    }
}

/// Worker count used when `SweepConfig::workers` is 0.
pub fn auto_workers() -> usize {
    std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1)
}

/// Seed of trial `r`: `base_seed + r`, the derivation the serial driver
/// always used, independent of scheduling. Seeds are shared across cells
/// and scenarios so every policy sees identical per-trial randomness
/// streams.
pub fn trial_seed(base_seed: u64, trial: usize) -> u64 {
    base_seed.wrapping_add(trial as u64)
}

/// One simulated trial: the run result plus the trace it consumed (needed
/// for arrival lookups during aggregation). Shared via `Arc` — the cache
/// hands the same output to every cell that maps to the same key.
#[derive(Debug)]
pub struct TrialOutput {
    pub result: RunResult,
    pub trace: Vec<JobSpec>,
}

impl TrialOutput {
    /// Approximate heap footprint, for the cache's byte bound.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.trace.capacity() * std::mem::size_of::<JobSpec>()
            + self.result.outcomes.capacity()
                * std::mem::size_of::<(u64, crate::sim::engine::JobOutcome)>()
            + self.result.utilization.approx_bytes()
    }
}

/// Everything that determines a trial's bytes. The cell *label* is
/// deliberately absent: it names the row, it does not influence the
/// simulation. The policy is identified by its canonical registry key —
/// stable across processes, which is what the ROADMAP's multi-backend
/// fan-out needs to share caches between workers.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct TrialKey {
    policy: &'static str,
    topo: ClusterTopo,
    scenario: &'static str,
    seed: u64,
    jobs_per_run: usize,
    fold_dims: [bool; 3],
}

/// One (scenario, cell, trial) work item of a flattened grid.
#[derive(Clone, Copy, Debug)]
struct WorkItem {
    cell: Cell,
    cfg: SweepConfig,
    trial: usize,
}

impl WorkItem {
    fn key(&self) -> TrialKey {
        TrialKey {
            policy: self.cell.policy.key(),
            topo: self.cell.topo,
            scenario: self.cfg.scenario.name(),
            seed: trial_seed(self.cfg.base_seed, self.trial),
            jobs_per_run: self.cfg.jobs_per_run,
            fold_dims: self.cfg.fold_dims_enabled,
        }
    }
}

/// Upper bound on the approximate bytes a cache keeps resident (256 MiB).
/// A `TrialOutput` holds the full trace plus per-job outcomes and
/// utilization samples (~100 KB at paper scale), so an unbounded
/// process-global cache would grow monotonically across `rfold all` /
/// `make bench-full`. When an insert would exceed the bound the cache
/// flushes wholesale (stderr note) — crude, but memory stays bounded,
/// determinism is unaffected (a flushed trial re-simulates to identical
/// bytes), and the reuse patterns that matter (Table 1 ↔ Figure 3/4
/// overlap, repeated grids) fit comfortably under it.
pub const MAX_RESIDENT_BYTES: usize = 256 << 20;

/// Resident entries plus their bookkept approximate footprint — one
/// struct behind one mutex so the two can never drift.
struct CacheInner {
    map: HashMap<TrialKey, Arc<TrialOutput>>,
    bytes: usize,
}

/// Memoized trial results keyed by [`TrialKey`], plus hit/miss counters.
/// Thread-safe; the process-global instance ([`ResultCache::global`])
/// makes repeated grids (Table 1 → Figure 4, repeated CLI subcommands in
/// `rfold all`, overlapping bench sections) reuse each other's trials.
/// Bounded by [`MAX_RESIDENT_BYTES`].
pub struct ResultCache {
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ResultCache {
    pub fn new() -> ResultCache {
        ResultCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                bytes: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The process-wide cache used by [`run_trials`] / `run_cell_sharded`.
    pub fn global() -> &'static ResultCache {
        static GLOBAL: OnceLock<ResultCache> = OnceLock::new();
        GLOBAL.get_or_init(ResultCache::new)
    }

    fn get(&self, key: &TrialKey) -> Option<Arc<TrialOutput>> {
        self.inner.lock().unwrap().map.get(key).cloned()
    }

    fn insert(&self, key: TrialKey, out: Arc<TrialOutput>) {
        let add = out.approx_bytes();
        let mut inner = self.inner.lock().unwrap();
        if inner.bytes + add > MAX_RESIDENT_BYTES && !inner.map.is_empty() {
            eprintln!(
                "sweep: result cache flushed at {} trials / ~{} MiB (bound {} MiB)",
                inner.map.len(),
                inner.bytes >> 20,
                MAX_RESIDENT_BYTES >> 20
            );
            inner.map.clear();
            inner.bytes = 0;
        }
        if let Some(old) = inner.map.insert(key, out) {
            inner.bytes = inner.bytes.saturating_sub(old.approx_bytes());
        }
        inner.bytes += add;
    }

    /// Cached trial count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate bytes the cached trials keep resident.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Work items served without simulating (cache or in-grid dedup).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Work items actually simulated.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Drop every cached trial (counters are kept; callers wanting a
    /// pristine cache build a fresh one).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.clear();
        inner.bytes = 0;
    }
}

impl Default for ResultCache {
    fn default() -> Self {
        ResultCache::new()
    }
}

/// One trial: generate the scenario trace for this trial's seed, simulate.
fn run_trial(cell: Cell, cfg: &SweepConfig, trial: usize) -> TrialOutput {
    let tc = cfg
        .scenario
        .trace_config(cfg.jobs_per_run, trial_seed(cfg.base_seed, trial));
    let trace = generate(&tc);
    let mut sim_cfg = SimConfig::new(cell.topo, cell.policy);
    sim_cfg.fold_dims_enabled = cfg.fold_dims_enabled;
    let result = Simulation::new(sim_cfg).run(&trace);
    TrialOutput { result, trace }
}

/// Where slot `i` of a queue run gets its output from.
enum Source {
    /// Served by the cache (or an identical item earlier in this grid).
    Cached(Arc<TrialOutput>),
    /// Computed by the queue; index into the fresh-output table.
    Fresh(usize),
}

/// Run a flattened item list through the shared work queue. Slot `i` of
/// the returned vector always holds item `i`'s output, so results are
/// position-stable for any worker count; items whose [`TrialKey`] repeats
/// (within the list or in the cache) simulate exactly once.
fn run_queue(items: &[WorkItem], workers: usize, cache: &ResultCache) -> Vec<Arc<TrialOutput>> {
    let keys: Vec<TrialKey> = items.iter().map(WorkItem::key).collect();

    // Resolve each slot: cache hit, duplicate of an earlier slot, or a
    // fresh item for the queue. `fresh[f]` is the item index computed by
    // queue position `f`.
    let mut sources: Vec<Source> = Vec::with_capacity(items.len());
    let mut fresh: Vec<usize> = Vec::new();
    let mut fresh_of: HashMap<&TrialKey, usize> = HashMap::new();
    let mut hits = 0u64;
    for (i, key) in keys.iter().enumerate() {
        if let Some(out) = cache.get(key) {
            sources.push(Source::Cached(out));
            hits += 1;
        } else if let Some(&f) = fresh_of.get(key) {
            sources.push(Source::Fresh(f));
            hits += 1;
        } else {
            fresh_of.insert(key, fresh.len());
            sources.push(Source::Fresh(fresh.len()));
            fresh.push(i);
        }
    }
    cache.hits.fetch_add(hits, Ordering::Relaxed);
    cache.misses.fetch_add(fresh.len() as u64, Ordering::Relaxed);

    // Drain the queue: workers race on one atomic cursor over the fresh
    // list — item granularity, so small-`runs` grids still saturate every
    // worker. Outputs come back tagged with their queue position; no
    // ordering or result content ever depends on scheduling.
    //
    // Liveness goes to stderr only: roughly every tenth completed trial a
    // worker reports the running count (a paper-scale grid takes hours —
    // silence would be indistinguishable from a hang).
    let total = fresh.len();
    let done = AtomicUsize::new(0);
    let progress = |it: &WorkItem| {
        let d = done.fetch_add(1, Ordering::Relaxed) + 1;
        let step = (total / 10).max(1);
        if d % step == 0 || d == total {
            eprintln!(
                "sweep: {d}/{total} trials done ({} {})",
                it.cfg.scenario.name(),
                it.cell.label
            );
        }
    };
    let mut computed: Vec<Option<Arc<TrialOutput>>> = Vec::new();
    computed.resize_with(fresh.len(), || None);
    if !fresh.is_empty() {
        let requested = if workers == 0 { auto_workers() } else { workers };
        let w = requested.clamp(1, fresh.len());
        if w == 1 {
            for (slot, &i) in computed.iter_mut().zip(&fresh) {
                let it = &items[i];
                *slot = Some(Arc::new(run_trial(it.cell, &it.cfg, it.trial)));
                progress(it);
            }
        } else {
            let cursor = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..w)
                    .map(|_| {
                        scope.spawn(|| {
                            let mut local = Vec::new();
                            loop {
                                let f = cursor.fetch_add(1, Ordering::Relaxed);
                                let Some(&i) = fresh.get(f) else { break };
                                let it = &items[i];
                                local.push((
                                    f,
                                    Arc::new(run_trial(it.cell, &it.cfg, it.trial)),
                                ));
                                progress(it);
                            }
                            local
                        })
                    })
                    .collect();
                for h in handles {
                    for (f, out) in h.join().expect("sweep worker panicked") {
                        computed[f] = Some(out);
                    }
                }
            });
        }
        for (f, &i) in fresh.iter().enumerate() {
            let out = computed[f].clone().expect("queue fills every fresh slot");
            cache.insert(keys[i].clone(), out);
        }
    }

    sources
        .into_iter()
        .map(|s| match s {
            Source::Cached(out) => out,
            Source::Fresh(f) => computed[f].clone().expect("queue fills every fresh slot"),
        })
        .collect()
}

/// Run every trial of one cell through the work queue against an explicit
/// cache. Slot `r` of the returned vector always holds trial `r`.
pub fn run_trials_with(
    cell: Cell,
    cfg: &SweepConfig,
    cache: &ResultCache,
) -> Vec<Arc<TrialOutput>> {
    let items: Vec<WorkItem> = (0..cfg.runs)
        .map(|trial| WorkItem { cell, cfg: *cfg, trial })
        .collect();
    run_queue(&items, cfg.workers, cache)
}

/// [`run_trials_with`] against the process-global cache.
pub fn run_trials(cell: Cell, cfg: &SweepConfig) -> Vec<Arc<TrialOutput>> {
    run_trials_with(cell, cfg, ResultCache::global())
}

/// Thin shim kept for the serial per-cell drivers (`experiments::run_cell`
/// and the golden Table-1 snapshot): one cell on the work-queue runner,
/// summarized identically to the old serial loop — borrowed trial
/// outputs, no per-cell deep clones.
pub fn run_cell_sharded(cell: Cell, cfg: &SweepConfig) -> CellSummary {
    let trials = run_trials(cell, cfg);
    let pairs: Vec<(&RunResult, &[JobSpec])> = trials
        .iter()
        .map(|t| (&t.result, t.trace.as_slice()))
        .collect();
    summarize(cell.label, &pairs)
}

/// One row of the sweep grid: a (scenario, policy, topology) cell summary
/// plus the knobs that produced it. Serialized to machine-readable JSON by
/// `metrics::report::sweep_row_json`.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub scenario: &'static str,
    pub cell: &'static str,
    pub policy: &'static str,
    pub topo: String,
    pub runs: usize,
    pub jobs_per_run: usize,
    pub base_seed: u64,
    pub summary: CellSummary,
}

/// Short stable topology tag for machine-readable rows.
pub fn topo_tag(topo: ClusterTopo) -> String {
    match topo {
        ClusterTopo::Static { ext } => {
            format!("static-{}x{}x{}", ext.0[0], ext.0[1], ext.0[2])
        }
        ClusterTopo::Reconfigurable { grid } => {
            format!("ocs-{}cubes-{}^3", grid.num_cubes(), grid.n)
        }
    }
}

/// Run the full policy × topology × scenario grid on the global work
/// queue: every (scenario, cell, trial) item is pulled by `workers` OS
/// threads (0 = auto) from one shared cursor, deduplicated through
/// `cache`. Progress, timing and cache statistics go to stderr so the
/// returned rows (and anything printed from them) stay byte-identical
/// across worker counts and cache states.
pub fn run_grid(
    cells: &[Cell],
    scenarios: &[Scenario],
    runs: usize,
    jobs_per_run: usize,
    base_seed: u64,
    workers: usize,
    cache: &ResultCache,
) -> Vec<SweepRow> {
    if runs == 0 {
        return Vec::new();
    }
    let mut items = Vec::with_capacity(cells.len() * scenarios.len() * runs);
    for &scenario in scenarios {
        for &cell in cells {
            let mut cfg = SweepConfig::new(runs, jobs_per_run, base_seed);
            cfg.workers = workers;
            cfg.scenario = scenario;
            for trial in 0..runs {
                items.push(WorkItem { cell, cfg, trial });
            }
        }
    }
    let (hits0, misses0) = (cache.hits(), cache.misses());
    let t0 = Instant::now();
    let slots = run_queue(&items, workers, cache);

    // Aggregate per cell: slots are grid-ordered (scenario-major, then
    // cell, then trial), so each cell owns one contiguous `runs` chunk.
    let mut rows = Vec::with_capacity(cells.len() * scenarios.len());
    let mut chunks = slots.chunks(runs);
    for &scenario in scenarios {
        for &cell in cells {
            let trials = chunks.next().expect("one slot chunk per cell");
            let pairs: Vec<(&RunResult, &[JobSpec])> = trials
                .iter()
                .map(|t| (&t.result, t.trace.as_slice()))
                .collect();
            rows.push(SweepRow {
                scenario: scenario.name(),
                cell: cell.label,
                policy: cell.policy.name(),
                topo: topo_tag(cell.topo),
                runs,
                jobs_per_run,
                base_seed,
                summary: summarize(cell.label, &pairs),
            });
        }
    }
    eprintln!(
        "sweep: {} rows ({} work items) in {:>6.1}s — cache: {} hits / {} misses \
         this grid, {} trials resident",
        rows.len(),
        items.len(),
        t0.elapsed().as_secs_f64(),
        cache.hits() - hits0,
        cache.misses() - misses0,
        cache.len(),
    );
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::builtins;

    fn tiny_cell() -> Cell {
        Cell {
            policy: builtins::FOLDING,
            topo: ClusterTopo::static_4096(),
            label: "Folding (16^3)",
        }
    }

    #[test]
    fn trial_seeds_match_serial_derivation() {
        assert_eq!(trial_seed(10, 0), 10);
        assert_eq!(trial_seed(10, 3), 13);
        assert_eq!(trial_seed(u64::MAX, 1), 0); // wraps, never panics
    }

    #[test]
    fn queued_equals_serial() {
        let mut cfg = SweepConfig::new(5, 30, 3);
        cfg.workers = 1;
        let serial = run_trials_with(tiny_cell(), &cfg, &ResultCache::new());
        cfg.workers = 3;
        let queued = run_trials_with(tiny_cell(), &cfg, &ResultCache::new());
        assert_eq!(serial.len(), queued.len());
        for (a, b) in serial.iter().zip(&queued) {
            assert_eq!(a.trace, b.trace, "traces must match per trial slot");
            assert_eq!(a.result.scheduled, b.result.scheduled);
            assert_eq!(a.result.dropped, b.result.dropped);
            assert_eq!(a.result.jcts(&a.trace), b.result.jcts(&b.trace));
        }
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let mut cfg = SweepConfig::new(2, 20, 1);
        cfg.workers = 16;
        assert_eq!(
            run_trials_with(tiny_cell(), &cfg, &ResultCache::new()).len(),
            2
        );
    }

    #[test]
    fn zero_runs_yields_no_trials() {
        let cfg = SweepConfig::new(0, 10, 1);
        assert!(run_trials_with(tiny_cell(), &cfg, &ResultCache::new()).is_empty());
        let rows = run_grid(
            &[tiny_cell()],
            &[Scenario::PaperDefault],
            0,
            10,
            1,
            1,
            &ResultCache::new(),
        );
        assert!(rows.is_empty());
    }

    #[test]
    fn duplicate_items_simulate_once() {
        // The same cell listed twice in one grid: every duplicated slot
        // must be served by the first computation (hit), and the two rows
        // must be identical.
        let cache = ResultCache::new();
        let cells = [tiny_cell(), tiny_cell()];
        let rows = run_grid(&cells, &[Scenario::PaperDefault], 3, 25, 7, 2, &cache);
        assert_eq!(rows.len(), 2);
        assert_eq!(cache.misses(), 3, "3 unique trials simulate");
        assert_eq!(cache.hits(), 3, "the duplicate cell's 3 slots are hits");
        assert_eq!(cache.len(), 3);
        assert_eq!(rows[0].summary.avg_jcr_pct, rows[1].summary.avg_jcr_pct);
        assert_eq!(rows[0].summary.util_cdf, rows[1].summary.util_cdf);
    }

    #[test]
    fn cache_survives_across_grids() {
        let cache = ResultCache::new();
        let cells = [tiny_cell()];
        let first = run_grid(&cells, &[Scenario::PaperDefault], 2, 25, 7, 2, &cache);
        assert_eq!(cache.misses(), 2);
        assert!(cache.resident_bytes() > 0, "byte accounting must track inserts");
        let again = run_grid(&cells, &[Scenario::PaperDefault], 2, 25, 7, 8, &cache);
        assert_eq!(cache.misses(), 2, "second grid is all hits");
        // Cold grid: 0 hits / 2 misses; warm grid: 2 hits / 0 misses.
        assert_eq!(cache.hits(), 2);
        assert_eq!(first[0].summary.avg_jcr_pct, again[0].summary.avg_jcr_pct);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn label_is_not_part_of_the_cache_key() {
        // Two cells differing only in label share trials; summaries carry
        // their own labels.
        let cache = ResultCache::new();
        let a = tiny_cell();
        let b = Cell { label: "same cell, other name", ..a };
        let rows = run_grid(&[a, b], &[Scenario::PaperDefault], 2, 20, 5, 0, &cache);
        assert_eq!(cache.misses(), 2);
        assert_eq!(rows[0].summary.avg_jcr_pct, rows[1].summary.avg_jcr_pct);
        assert_eq!(rows[0].cell, "Folding (16^3)");
        assert_eq!(rows[1].cell, "same cell, other name");
    }

    #[test]
    fn fold_dims_are_part_of_the_cache_key() {
        let cache = ResultCache::new();
        let cell = Cell {
            policy: builtins::RFOLD,
            topo: ClusterTopo::reconfigurable_4096(4),
            label: "RFold (4^3)",
        };
        let mut cfg = SweepConfig::new(2, 20, 5);
        let _ = run_trials_with(cell, &cfg, &cache);
        cfg.fold_dims_enabled = [false, false, false];
        let _ = run_trials_with(cell, &cfg, &cache);
        assert_eq!(cache.misses(), 4, "ablation knobs must not collide");
    }

    #[test]
    fn topo_tags_stable() {
        assert_eq!(topo_tag(ClusterTopo::static_4096()), "static-16x16x16");
        assert_eq!(
            topo_tag(ClusterTopo::reconfigurable_4096(4)),
            "ocs-64cubes-4^3"
        );
    }
}
