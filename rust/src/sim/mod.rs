//! Job-level discrete-event simulator (paper §4): FIFO admission with
//! head-of-line blocking, shape-incompatible job removal, utilization
//! sampling, and the calibrated contention model of §3.1.

pub mod contention;
pub mod domains;
pub mod engine;
pub(crate) mod event_heap;
pub mod experiments;
pub mod observer;
pub mod sweep;

pub use contention::ContentionModel;
pub use engine::{RunResult, SimConfig, Simulation};
pub use observer::{DecisionTelemetry, SchedulerObserver, SharedTelemetry};
pub use sweep::{
    LocalExecutor, ResultCache, SweepConfig, SweepRow, TrialExecutor, TrialOutput, WorkItem,
};
