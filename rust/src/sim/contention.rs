//! The communication-slowdown model, calibrated on the paper's §3.1
//! motivation measurements (TPU v2, 2×2 grid):
//!
//! | configuration                     | measured slowdown |
//! |-----------------------------------|-------------------|
//! | diagonal vs row (dilation 2)      | +17%              |
//! | two diagonal jobs (max load 2)    | +35% vs single    |
//! | competing load doubled (load 3)   | +95%              |
//! | competing load tripled (load 4)   | +186%             |
//!
//! We fit `slowdown = (1 + ALPHA·(dilation-1)) · (1 + BETA·(load-1)^GAMMA)`:
//! ALPHA from the first row, BETA from the second, GAMMA from the last two
//! (least-squares on the log). The same constants then drive both the
//! best-effort policy's JCT and the `motivation` experiment that
//! reproduces the table above.

use crate::topology::routing::LinkLoads;
use crate::topology::P3;

/// Dilation sensitivity: +17% at dilation 2.
pub const ALPHA: f64 = 0.17;
/// Sharing sensitivity: +35% at max load 2.
pub const BETA: f64 = 0.35;
/// Super-linear contention exponent (fits +95%/+186% at loads 3/4).
pub const GAMMA: f64 = 1.5;

/// Communication slowdown of a ring with the given mean hop dilation and
/// max link load along its paths (both ≥ 1).
pub fn slowdown(dilation: f64, max_load: f64) -> f64 {
    let d = dilation.max(1.0);
    let l = max_load.max(1.0);
    (1.0 + ALPHA * (d - 1.0)) * (1.0 + BETA * (l - 1.0).powf(GAMMA))
}

/// Cluster-wide contention bookkeeping for best-effort placements.
///
/// Contiguous (FirstFit/Folding/Reconfig/RFold) placements are exclusive
/// by construction and contribute nothing here; only scattered rings load
/// shared links.
#[derive(Clone, Debug)]
pub struct ContentionModel {
    loads: LinkLoads,
}

/// Per-ring traffic unit: one AllReduce's worth of bytes per step is
/// normalized to 1.0 per ring hop.
pub const RING_UNIT: f64 = 1.0;

impl ContentionModel {
    pub fn new(ext: P3) -> ContentionModel {
        ContentionModel {
            loads: LinkLoads::new(ext),
        }
    }

    /// Mesh variant (no wrap cables) — the §3.1 motivation testbed.
    pub fn new_mesh(ext: P3) -> ContentionModel {
        ContentionModel {
            loads: LinkLoads::new_mesh(ext),
        }
    }

    pub fn loads(&self) -> &LinkLoads {
        &self.loads
    }

    /// Add a job's rings (physical member coordinates per ring) and return
    /// the slowdown it experiences *at placement time*: mean hop dilation
    /// over its logical edges × max load over its cables after insertion.
    /// Each ring loads every distinct cable on its DOR paths with one
    /// bidirectional traffic unit — the accounting the §3.1 calibration
    /// constants were fit against.
    pub fn add_job(&mut self, rings: &[Vec<P3>]) -> f64 {
        let mut hops = 0usize;
        let mut edges = 0usize;
        let mut cables: Vec<Vec<(usize, P3)>> = Vec::with_capacity(rings.len());
        for ring in rings {
            if ring.len() < 2 {
                cables.push(Vec::new());
                continue;
            }
            for w in 0..ring.len() {
                let a = ring[w];
                let b = ring[(w + 1) % ring.len()];
                hops += self.loads.path_cables(a, b).len();
                edges += 1;
            }
            cables.push(self.loads.ring_cables(ring));
        }
        if edges == 0 {
            return 1.0;
        }
        for ring_cables in &cables {
            for &(axis, p) in ring_cables {
                self.loads.add(axis, p, RING_UNIT);
            }
        }
        let mut max_load: f64 = 0.0;
        for ring_cables in &cables {
            for &(axis, p) in ring_cables {
                max_load = max_load.max(self.loads.get(axis, p));
            }
        }
        slowdown(hops as f64 / edges as f64, max_load)
    }

    /// Remove a job's rings at completion.
    pub fn remove_job(&mut self, rings: &[Vec<P3>]) {
        for ring in rings {
            if ring.len() < 2 {
                continue;
            }
            for (axis, p) in self.loads.ring_cables(ring) {
                self.loads.add(axis, p, -RING_UNIT);
            }
        }
    }

    /// Current max load anywhere (diagnostics; ~0 when only contiguous
    /// jobs run).
    pub fn max_load(&self) -> f64 {
        self.loads.max_load()
    }
}

/// Effective job duration given its base duration, communication fraction
/// and per-dimension ring profile (`(len, closed)`): open rings double the
/// per-dimension communication cost (a logical ring folded onto a line
/// loads its bottleneck link twice — §2's wrap-around discussion), and a
/// best-effort contention multiplier stretches it further.
pub fn effective_duration(
    duration: f64,
    comm_frac: f64,
    rings: &[(usize, bool)],
    contention_multiplier: f64,
) -> f64 {
    if rings.is_empty() {
        return duration; // no communicating dimensions at all
    }
    let ring_penalty = {
        rings
            .iter()
            .map(|&(_, closed)| if closed { 1.0 } else { 2.0 })
            .sum::<f64>()
            / rings.len() as f64
    };
    let m = ring_penalty * contention_multiplier.max(1.0);
    duration * (1.0 - comm_frac + comm_frac * m)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tolerance for matching the paper's §3.1 percentages.
    const TOL: f64 = 0.08;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() / b < TOL
    }

    #[test]
    fn calibration_diagonal_vs_row() {
        // Single job on the diagonal: dilation 2, exclusive links.
        assert!(close(slowdown(2.0, 1.0), 1.17), "{}", slowdown(2.0, 1.0));
    }

    #[test]
    fn calibration_shared_diagonals() {
        // Two jobs on crossing diagonals: each sees max load 2.
        let single = slowdown(2.0, 1.0);
        let shared = slowdown(2.0, 2.0);
        assert!(close(shared / single, 1.35), "{}", shared / single);
    }

    #[test]
    fn calibration_load_scaling() {
        let single = slowdown(2.0, 1.0);
        assert!(close(slowdown(2.0, 3.0) / single, 1.95), "2x load");
        assert!(close(slowdown(2.0, 4.0) / single, 2.86), "3x load");
    }

    #[test]
    fn exclusive_row_has_no_slowdown() {
        assert_eq!(slowdown(1.0, 1.0), 1.0);
    }

    #[test]
    fn model_add_remove_roundtrip() {
        let mut m = ContentionModel::new(P3([8, 8, 8]));
        let rings = vec![vec![P3([0, 0, 0]), P3([3, 0, 0]), P3([3, 3, 0])]];
        let s = m.add_job(&rings);
        assert!(s >= 1.0);
        assert!(m.max_load() > 0.0);
        m.remove_job(&rings);
        assert!(m.max_load().abs() < 1e-9);
    }

    #[test]
    fn two_jobs_contend() {
        let mut m = ContentionModel::new_mesh(P3([2, 2, 1]));
        let j1 = vec![vec![P3([0, 0, 0]), P3([1, 1, 0])]];
        let s1 = m.add_job(&j1);
        let j2 = vec![vec![P3([1, 0, 0]), P3([0, 1, 0])]];
        let s2 = m.add_job(&j2);
        assert!(s2 > s1, "second diagonal job must see contention");
    }

    #[test]
    fn effective_duration_ring_penalty() {
        // All rings closed, no contention: base duration.
        assert_eq!(effective_duration(100.0, 0.3, &[(4, true)], 1.0), 100.0);
        // Open ring doubles the comm fraction.
        assert!((effective_duration(100.0, 0.3, &[(4, false)], 1.0) - 130.0).abs() < 1e-9);
        // Contention multiplies comm cost.
        let d = effective_duration(100.0, 0.5, &[(4, true)], 2.0);
        assert_eq!(d, 150.0);
        // No communication dims → no penalty.
        assert_eq!(effective_duration(100.0, 0.3, &[], 5.0), 100.0);
    }
}
