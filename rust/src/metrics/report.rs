//! Table/figure formatters: print the same rows and series the paper
//! reports, in a stable machine-greppable layout consumed by
//! EXPERIMENTS.md — plus the JSON row serializer behind `rfold sweep`.

use std::collections::BTreeMap;

use super::CellSummary;
use crate::coordinator::pool::PoolStats;
use crate::sim::engine::{JobOutcome, RunResult};
use crate::sim::observer::DecisionTelemetry;
use crate::sim::sweep::SweepRow;
use crate::trace::JobSpec;
use crate::util::json::Json;
use crate::util::stats::percentile_of;

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".into();
    }
    if s < 120.0 {
        format!("{s:.0}s")
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else if s < 172_800.0 {
        format!("{:.1}h", s / 3600.0)
    } else {
        format!("{:.1}d", s / 86400.0)
    }
}

/// Table 1: average JCR per policy/topology cell.
pub fn print_table1(cells: &[CellSummary]) {
    println!("\nTable 1: Average job completion rate (JCR)");
    println!("{:<22} {:>12}", "Policy", "Avg JCR (%)");
    println!("{}", "-".repeat(36));
    for c in cells {
        println!("TABLE1 {:<22} {:>11.2}", c.label, c.avg_jcr_pct);
    }
}

/// Figure 3: JCT p50/p90/p99 per cell.
pub fn print_fig3(cells: &[CellSummary]) {
    println!("\nFigure 3: Job completion time (averaged across runs)");
    println!(
        "{:<22} {:>12} {:>12} {:>12}",
        "Policy", "p50", "p90", "p99"
    );
    println!("{}", "-".repeat(62));
    for c in cells {
        println!(
            "FIG3 {:<22} {:>12} {:>12} {:>12}   (s: {:.0}/{:.0}/{:.0})",
            c.label,
            fmt_secs(c.jct_p50),
            fmt_secs(c.jct_p90),
            fmt_secs(c.jct_p99),
            c.jct_p50,
            c.jct_p90,
            c.jct_p99,
        );
    }
}

/// Figure 4: utilization CDF series per cell.
pub fn print_fig4(cells: &[CellSummary]) {
    println!("\nFigure 4: Cluster utilization CDF (per-quantile average)");
    for c in cells {
        let series: Vec<String> = c
            .util_cdf
            .iter()
            .map(|(q, u)| format!("{q:.2}:{u:.3}"))
            .collect();
        println!("FIG4 {:<22} mean={:.3} cdf=[{}]", c.label, c.avg_util, series.join(" "));
    }
}

/// JSON-safe number: non-finite values (empty-percentile NaNs) map to
/// `null` so every row stays valid, parseable JSON.
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Serialize one sweep row as a single-line JSON object.
///
/// Every field is derived only from (scenario, cell, seeds, trial
/// results) — never from wall-clock time, worker count, or cache state —
/// so `rfold sweep` output is byte-identical for any `--workers` value.
pub fn sweep_row_json(row: &SweepRow) -> String {
    let s = &row.summary;
    let mut m = BTreeMap::new();
    let mut put = |k: &str, v: Json| {
        m.insert(k.to_string(), v);
    };
    put("scenario", Json::Str(row.scenario.clone()));
    put("cell", Json::Str(row.cell.to_string()));
    put("policy", Json::Str(row.policy.to_string()));
    put("topo", Json::Str(row.topo.clone()));
    put("runs", Json::Num(row.runs as f64));
    put("jobs_per_run", Json::Num(row.jobs_per_run as f64));
    // Decimal string, not Json::Num: a u64 seed above 2^53 would be
    // silently corrupted by the f64 round-trip, and these rows are the
    // record needed to reproduce the cell.
    put("base_seed", Json::Str(row.base_seed.to_string()));
    put("jcr_pct", num(s.avg_jcr_pct));
    put("jct_p50_s", num(s.jct_p50));
    put("jct_p90_s", num(s.jct_p90));
    put("jct_p99_s", num(s.jct_p99));
    put("util_mean", num(s.avg_util));
    put("queue_delay_s", num(s.avg_queue_delay));
    put(
        "util_cdf",
        Json::Arr(
            s.util_cdf
                .iter()
                .map(|&(q, u)| Json::Arr(vec![num(q), num(u)]))
                .collect(),
        ),
    );
    // Disruption keys appear only when some disruption actually happened:
    // rows from preemption-free configurations (including pure
    // fault-injection ones) keep their pre-preemption bytes exactly.
    if s.avg_preemptions > 0.0 || s.avg_wasted_work > 0.0 || s.avg_migration_time > 0.0 {
        put("preemptions", num(s.avg_preemptions));
        put("wasted_work_s", num(s.avg_wasted_work));
        put("migration_s", num(s.avg_migration_time));
        put("useful_util", num(s.avg_useful_util));
    }
    Json::Obj(m).to_string()
}

/// Print the sweep grid as stable, machine-greppable `SWEEP {json}` lines.
pub fn print_sweep(rows: &[SweepRow]) {
    for r in rows {
        println!("SWEEP {}", sweep_row_json(r));
    }
}

/// Serialize one failure-model ablation row as a single-line JSON object.
///
/// A separate `FAULTGRID` channel rather than extra keys on
/// [`sweep_row_json`]: plain `SWEEP` rows (including `failures=philly`
/// ones) must keep their exact bytes, so the ablation grid gets its own
/// prefix and its own schema, with the failure model spelled out.
pub fn fault_ablation_row_json(row: &crate::sim::experiments::FaultAblationRow) -> String {
    let s = &row.summary;
    let mut m = BTreeMap::new();
    let mut put = |k: &str, v: Json| {
        m.insert(k.to_string(), v);
    };
    put("cell", Json::Str(row.label.to_string()));
    put("policy", Json::Str(row.policy.to_string()));
    put("model", Json::Str(row.model.to_string()));
    put("mtbf_s", num(row.mtbf));
    put("mods", Json::Str(row.mods.clone()));
    put("runs", Json::Num(s.runs as f64));
    put("jcr_pct", num(s.avg_jcr_pct));
    put("jct_p50_s", num(s.jct_p50));
    put("jct_p90_s", num(s.jct_p90));
    put("jct_p99_s", num(s.jct_p99));
    put("util_mean", num(s.avg_util));
    put("useful_util", num(s.avg_useful_util));
    Json::Obj(m).to_string()
}

/// Print the failure-model ablation grid as `FAULTGRID {json}` lines:
/// JCR/JCT/useful-util vs MTBF per policy, independent vs correlated
/// side by side (rows come pre-ordered mtbf-major, model-minor).
pub fn print_fault_ablation(rows: &[crate::sim::experiments::FaultAblationRow]) {
    for r in rows {
        println!("FAULTGRID {}", fault_ablation_row_json(r));
    }
}

/// Format the scheduler-observer decision telemetry of one run as
/// machine-greppable `TELEMETRY` lines.
pub fn policy_telemetry_lines(label: &str, t: &DecisionTelemetry) -> Vec<String> {
    vec![
        format!(
            "TELEMETRY {label} decisions={} placed={} no-capacity={} infeasible={}",
            t.decisions, t.placed, t.no_capacity, t.infeasible
        ),
        format!(
            "TELEMETRY {label} variants={} folds-tried={} candidates-ranked={}",
            t.variants_enumerated, t.folds_tried, t.candidates_ranked
        ),
        format!(
            "TELEMETRY {label} reconfigurations={} ocs-entries={} admissions={} completions={}",
            t.reconfigurations, t.ocs_entries_reserved, t.admissions, t.completions
        ),
        format!(
            "TELEMETRY {label} decision-wall={:.3}ms mean-decision={:.1}us",
            t.decision_wall.as_secs_f64() * 1e3,
            t.mean_decision_us()
        ),
    ]
}

/// Format the fault-injection counters as machine-greppable `FAULTS`
/// lines. Empty when no fault, kill, or stall was observed — modifier-free
/// runs emit no `FAULTS` section at all.
pub fn faults_telemetry_lines(label: &str, t: &DecisionTelemetry) -> Vec<String> {
    let any = t.node_failures
        + t.link_failures
        + t.repairs
        + t.jobs_killed
        + t.jobs_stalled
        > 0
        || t.stall_time > 0.0;
    if !any {
        return Vec::new();
    }
    let mut lines = vec![
        format!(
            "FAULTS {label} node-failures={} link-failures={} repairs={} jobs-killed={}",
            t.node_failures, t.link_failures, t.repairs, t.jobs_killed
        ),
        format!(
            "FAULTS {label} jobs-stalled={} stall-time={}",
            t.jobs_stalled,
            fmt_secs(t.stall_time)
        ),
    ];
    // Blast-radius histogram, correlated mode only: independent-failure
    // runs keep their exact pre-domain FAULTS bytes.
    if t.domain_faults > 0 {
        let hist: Vec<String> = t
            .blast_sizes
            .iter()
            .map(|(size, count)| format!("{size}:{count}"))
            .collect();
        lines.push(format!(
            "FAULTS {label} domain-faults={} cascades={} blast-sizes=[{}]",
            t.domain_faults,
            t.domain_cascades,
            hist.join(" ")
        ));
    }
    lines
}

/// Format the preemption/defrag/migration counters as machine-greppable
/// `PREEMPT` lines. Empty when nothing was disrupted — preemption-free
/// runs emit no `PREEMPT` section at all.
pub fn disruption_telemetry_lines(label: &str, t: &DecisionTelemetry) -> Vec<String> {
    let any = t.preemptions + t.migrations + t.defrag_passes + t.defrag_moves > 0
        || t.preempt_wasted > 0.0
        || t.migration_time > 0.0;
    if !any {
        return Vec::new();
    }
    vec![
        format!(
            "PREEMPT {label} preemptions={} wasted-work={} migrations={} migration-time={}",
            t.preemptions,
            fmt_secs(t.preempt_wasted),
            t.migrations,
            fmt_secs(t.migration_time)
        ),
        format!(
            "PREEMPT {label} defrag-passes={} defrag-moves={}",
            t.defrag_passes, t.defrag_moves
        ),
    ]
}

/// Print decision telemetry — **stderr only**, never stdout: report rows
/// (`SWEEP`/`TABLE1`/...) carry no wall-clock or observer state, so
/// stdout stays byte-identical whether or not anyone observes.
pub fn print_policy_telemetry(label: &str, t: &DecisionTelemetry) {
    for line in policy_telemetry_lines(label, t) {
        eprintln!("{line}");
    }
    for line in faults_telemetry_lines(label, t) {
        eprintln!("{line}");
    }
    for line in disruption_telemetry_lines(label, t) {
        eprintln!("{line}");
    }
}

/// One `ROW {json}` line per job of a finished run, in job-id order —
/// the byte-level determinism bridge between batch and service mode:
/// `rfold simulate --rows` prints these on stdout and a daemon's `DRAIN`
/// reply streams the identical lines, so `diff` is the oracle. Times are
/// encoded as f64 bit patterns (`Json::f64_bits`), ids as decimal
/// strings; keys sort alphabetically inside each object (BTreeMap), so
/// the bytes are a pure function of the run result.
pub fn outcome_rows(result: &RunResult, trace: &[JobSpec]) -> Vec<String> {
    let arrivals: BTreeMap<u64, f64> = trace.iter().map(|j| (j.id, j.arrival)).collect();
    let mut sorted: Vec<(u64, JobOutcome)> = result.outcomes.clone();
    sorted.sort_by_key(|r| r.0);
    sorted
        .into_iter()
        .map(|(id, outcome)| {
            let mut m = BTreeMap::new();
            m.insert("id".to_string(), Json::u64_str(id));
            let tag = match outcome {
                JobOutcome::Completed { start, finish } => {
                    m.insert("start".to_string(), Json::f64_bits(start));
                    m.insert("finish".to_string(), Json::f64_bits(finish));
                    if let Some(&arrival) = arrivals.get(&id) {
                        m.insert("arrival".to_string(), Json::f64_bits(arrival));
                        m.insert("jct".to_string(), Json::f64_bits(finish - arrival));
                    }
                    "completed"
                }
                JobOutcome::Dropped => "dropped",
                JobOutcome::NotScheduled => "not-scheduled",
            };
            m.insert("outcome".to_string(), Json::Str(tag.to_string()));
            format!("ROW {}", Json::Obj(m))
        })
        .collect()
}

/// Format service-mode counters as machine-greppable `SERVICE` lines:
/// the admission ledger plus decision-latency percentiles when any
/// decision was made. Self-consistency (`submitted = admitted +
/// rejected`) is the soak test's invariant.
pub fn service_telemetry_lines(
    submitted: usize,
    admitted: usize,
    rejected: usize,
    decision_us: &[f64],
) -> Vec<String> {
    let mut lines = vec![format!(
        "SERVICE submitted={submitted} admitted={admitted} rejected={rejected}"
    )];
    if !decision_us.is_empty() {
        lines.push(format!(
            "SERVICE decisions={} decision-p50={:.1}us decision-p99={:.1}us",
            decision_us.len(),
            percentile_of(decision_us, 0.50),
            percentile_of(decision_us, 0.99),
        ));
    }
    lines
}

/// Print service telemetry — **stderr only**, like every other
/// introspection channel: DRAIN's stdout-equivalent reply bytes must
/// stay a pure function of the accepted trace.
pub fn print_service_telemetry(
    submitted: usize,
    admitted: usize,
    rejected: usize,
    decision_us: &[f64],
) {
    for line in service_telemetry_lines(submitted, admitted, rejected, decision_us) {
        eprintln!("{line}");
    }
}

/// Format distributed-pool telemetry as machine-greppable `POOL` lines:
/// one per worker connection plus an aggregate retry/fallback line.
pub fn pool_telemetry_lines(stats: &PoolStats) -> Vec<String> {
    let mut lines: Vec<String> = stats
        .workers
        .iter()
        .map(|w| {
            let state = if !w.connected {
                "unreachable"
            } else if w.died {
                "died"
            } else {
                "ok"
            };
            format!(
                "POOL worker={} items={} state={state}",
                w.addr, w.completed
            )
        })
        .collect();
    // Circuit-breaker health, one line per host (a host may back several
    // worker connections): how often the breaker opened and how often a
    // half-open probe (or clean reconnect) closed it again.
    for h in &stats.hosts {
        lines.push(format!(
            "POOL host={} breaker-trips={} breaker-recoveries={}",
            h.addr, h.trips, h.recoveries
        ));
    }
    lines.push(format!(
        "POOL retried={} leader-fallback={}",
        stats.retried, stats.leader_fallback
    ));
    lines
}

/// Print pool telemetry — **stderr only**, like every other introspection
/// channel: SWEEP rows must stay byte-identical between `--workers N` and
/// `--pool host1,host2`, so nothing about the pool may reach stdout.
pub fn print_pool_telemetry(stats: &PoolStats) {
    for line in pool_telemetry_lines(stats) {
        eprintln!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(30.0), "30s");
        assert!(fmt_secs(600.0).ends_with('m'));
        assert!(fmt_secs(10_000.0).ends_with('h'));
        assert!(fmt_secs(500_000.0).ends_with('d'));
        assert_eq!(fmt_secs(f64::NAN), "n/a");
    }

    #[test]
    fn sweep_row_json_is_valid_and_thread_free() {
        let row = SweepRow {
            scenario: "paper-default".to_string(),
            cell: "RFold (4^3)",
            policy: "RFold",
            topo: "ocs-64cubes-4^3".to_string(),
            runs: 2,
            jobs_per_run: 10,
            base_seed: 7,
            summary: CellSummary {
                label: "RFold (4^3)".to_string(),
                runs: 2,
                avg_jcr_pct: 100.0,
                jct_p50: 12.5,
                jct_p90: 20.0,
                jct_p99: f64::NAN, // empty percentile → null, still valid
                util_cdf: vec![(0.0, 0.1), (1.0, 0.9)],
                avg_util: 0.5,
                avg_queue_delay: 3.0,
                avg_preemptions: 0.0,
                avg_wasted_work: 0.0,
                avg_migration_time: 0.0,
                avg_useful_util: 0.5,
            },
        };
        let line = sweep_row_json(&row);
        let parsed = Json::parse(&line).expect("row must be valid JSON");
        assert_eq!(
            parsed.get("scenario").unwrap().as_str(),
            Some("paper-default")
        );
        // Seed travels as a decimal string (u64 > 2^53 survives).
        assert_eq!(parsed.get("base_seed").unwrap().as_str(), Some("7"));
        assert_eq!(parsed.get("jcr_pct").unwrap().as_f64(), Some(100.0));
        assert_eq!(parsed.get("jct_p99_s"), Some(&Json::Null));
        assert_eq!(parsed.get("util_cdf").unwrap().as_arr().unwrap().len(), 2);
        // The determinism contract: no timing or thread info in rows.
        assert!(!line.contains("thread"));
        assert!(!line.contains("wall"));
        // Disruption-free rows carry no disruption keys at all (their
        // bytes predate the preemption feature and must stay put).
        assert!(parsed.get("preemptions").is_none());
        assert!(parsed.get("useful_util").is_none());

        // A row with disruption grows the gated keys.
        let mut disrupted = row.clone();
        disrupted.summary.avg_preemptions = 2.5;
        disrupted.summary.avg_wasted_work = 8192.0;
        disrupted.summary.avg_migration_time = 60.0;
        disrupted.summary.avg_useful_util = 0.4;
        let line = sweep_row_json(&disrupted);
        let parsed = Json::parse(&line).expect("disrupted row must be valid JSON");
        assert_eq!(parsed.get("preemptions").unwrap().as_f64(), Some(2.5));
        assert_eq!(parsed.get("wasted_work_s").unwrap().as_f64(), Some(8192.0));
        assert_eq!(parsed.get("migration_s").unwrap().as_f64(), Some(60.0));
        assert_eq!(parsed.get("useful_util").unwrap().as_f64(), Some(0.4));
    }

    #[test]
    fn outcome_rows_are_sorted_valid_json() {
        use crate::shape::JobShape;
        let result = RunResult {
            policy: "FirstFit",
            outcomes: vec![
                (2, JobOutcome::Dropped),
                (0, JobOutcome::Completed { start: 1.0, finish: 11.0 }),
                (1, JobOutcome::NotScheduled),
            ],
            utilization: crate::util::stats::WeightedCdf::new(),
            scheduled: 1,
            dropped: 1,
            makespan: 11.0,
            preemptions: 0,
            wasted_work: 0.0,
            migration_time: 0.0,
            useful_util: 0.0,
        };
        let trace: Vec<JobSpec> = (0..3)
            .map(|id| JobSpec {
                id,
                arrival: 0.5,
                duration: 10.0,
                shape: JobShape::new(2, 2, 2),
                comm_frac: 0.1,
                priority: 0,
            })
            .collect();
        let rows = outcome_rows(&result, &trace);
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| r.starts_with("ROW ")));
        let parsed: Vec<Json> = rows
            .iter()
            .map(|r| Json::parse(&r[4..]).expect("row must be valid JSON"))
            .collect();
        // Sorted by id regardless of completion order.
        let ids: Vec<&str> = parsed
            .iter()
            .map(|p| p.get("id").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(ids, ["0", "1", "2"]);
        assert_eq!(
            parsed[0].get("outcome").unwrap().as_str(),
            Some("completed")
        );
        assert_eq!(parsed[0].get("jct").unwrap().as_f64_bits(), Some(10.5));
        // Non-completed rows carry no time keys at all.
        assert_eq!(parsed[1].get("outcome").unwrap().as_str(), Some("not-scheduled"));
        assert!(parsed[1].get("start").is_none());
        assert!(parsed[2].get("finish").is_none());
    }

    #[test]
    fn service_lines_gate_latency_on_samples() {
        let bare = service_telemetry_lines(5, 3, 2, &[]);
        assert_eq!(bare.len(), 1);
        assert!(bare[0].contains("submitted=5"));
        assert!(bare[0].contains("admitted=3") && bare[0].contains("rejected=2"));
        let timed = service_telemetry_lines(5, 3, 2, &[10.0, 20.0, 30.0]);
        assert_eq!(timed.len(), 2);
        assert!(timed[1].contains("decisions=3"));
        assert!(timed[1].contains("decision-p50=20.0us"));
    }

    #[test]
    fn pool_telemetry_lines_cover_every_worker_state() {
        use crate::coordinator::pool::{HostStats, WorkerStats};
        let stats = PoolStats {
            workers: vec![
                WorkerStats {
                    addr: "10.0.0.1:7171".into(),
                    completed: 12,
                    connected: true,
                    died: false,
                },
                WorkerStats {
                    addr: "10.0.0.2:7171".into(),
                    completed: 3,
                    connected: true,
                    died: true,
                },
                WorkerStats {
                    addr: "10.0.0.3:7171".into(),
                    completed: 0,
                    connected: false,
                    died: true,
                },
            ],
            hosts: vec![
                HostStats {
                    addr: "10.0.0.2:7171".into(),
                    trips: 2,
                    recoveries: 1,
                },
            ],
            retried: 2,
            leader_fallback: 1,
        };
        let lines = pool_telemetry_lines(&stats);
        assert_eq!(lines.len(), 5);
        assert!(lines.iter().all(|l| l.starts_with("POOL ")));
        assert!(lines[0].contains("items=12") && lines[0].contains("state=ok"));
        assert!(lines[1].contains("state=died"));
        assert!(lines[2].contains("state=unreachable"));
        assert!(
            lines[3].contains("host=10.0.0.2:7171")
                && lines[3].contains("breaker-trips=2")
                && lines[3].contains("breaker-recoveries=1"),
            "{}",
            lines[3]
        );
        assert!(lines[4].contains("retried=2") && lines[4].contains("leader-fallback=1"));
    }

    #[test]
    fn telemetry_lines_are_greppable_and_complete() {
        let t = DecisionTelemetry {
            decisions: 10,
            placed: 7,
            no_capacity: 2,
            infeasible: 1,
            variants_enumerated: 40,
            folds_tried: 12,
            candidates_ranked: 25,
            reconfigurations: 3,
            ocs_entries_reserved: 18,
            admissions: 10,
            completions: 7,
            decision_wall: std::time::Duration::from_micros(500),
            ..Default::default()
        };
        let lines = policy_telemetry_lines("RFold (4^3)", &t);
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.starts_with("TELEMETRY RFold (4^3)")));
        assert!(lines[0].contains("placed=7") && lines[0].contains("infeasible=1"));
        assert!(lines[1].contains("folds-tried=12"));
        assert!(lines[2].contains("ocs-entries=18"));
        assert!(lines[3].contains("mean-decision=50.0us"));
    }

    #[test]
    fn faults_lines_appear_only_when_faults_happened() {
        let quiet = DecisionTelemetry::default();
        assert!(
            faults_telemetry_lines("RFold (4^3)", &quiet).is_empty(),
            "modifier-free runs must emit no FAULTS section"
        );
        let t = DecisionTelemetry {
            node_failures: 4,
            link_failures: 2,
            repairs: 3,
            jobs_killed: 5,
            jobs_stalled: 2,
            stall_time: 10.0,
            ..Default::default()
        };
        let lines = faults_telemetry_lines("RFold (4^3)", &t);
        assert_eq!(lines.len(), 2, "no domain line without correlated faults");
        assert!(lines.iter().all(|l| l.starts_with("FAULTS RFold (4^3)")));
        assert!(lines[0].contains("node-failures=4") && lines[0].contains("jobs-killed=5"));
        assert!(lines[1].contains("jobs-stalled=2") && lines[1].contains("stall-time=10s"));
    }

    #[test]
    fn faults_domain_line_carries_the_blast_histogram() {
        let mut t = DecisionTelemetry {
            node_failures: 512,
            repairs: 512,
            domain_faults: 3,
            domain_cascades: 1,
            ..Default::default()
        };
        t.blast_sizes.insert(256, 2);
        t.blast_sizes.insert(512, 1);
        let lines = faults_telemetry_lines("RFold (4^3)", &t);
        assert_eq!(lines.len(), 3);
        assert!(lines[2].contains("domain-faults=3"));
        assert!(lines[2].contains("cascades=1"));
        assert!(
            lines[2].contains("blast-sizes=[256:2 512:1]"),
            "histogram must be size-sorted: {}",
            lines[2]
        );
    }

    #[test]
    fn fault_ablation_rows_are_valid_json() {
        let row = crate::sim::experiments::FaultAblationRow {
            label: "RFold (4^3)",
            policy: "RFold",
            model: "correlated",
            mtbf: 21_600.0,
            mods: "failures=corr:21600:3600:rack".to_string(),
            summary: CellSummary {
                label: "RFold (4^3)".to_string(),
                runs: 2,
                avg_jcr_pct: 97.5,
                jct_p50: 100.0,
                jct_p90: 200.0,
                jct_p99: 300.0,
                util_cdf: vec![],
                avg_util: 0.5,
                avg_queue_delay: 3.0,
                avg_preemptions: 0.0,
                avg_wasted_work: 0.0,
                avg_migration_time: 0.0,
                avg_useful_util: 0.48,
            },
        };
        let line = fault_ablation_row_json(&row);
        let parsed = Json::parse(&line).expect("row must be valid JSON");
        assert_eq!(parsed.get("model").unwrap().as_str(), Some("correlated"));
        assert_eq!(parsed.get("mtbf_s").unwrap().as_f64(), Some(21_600.0));
        assert_eq!(parsed.get("jcr_pct").unwrap().as_f64(), Some(97.5));
        assert_eq!(parsed.get("useful_util").unwrap().as_f64(), Some(0.48));
        assert_eq!(
            parsed.get("mods").unwrap().as_str(),
            Some("failures=corr:21600:3600:rack")
        );
    }

    #[test]
    fn preempt_lines_appear_only_when_disruption_happened() {
        let quiet = DecisionTelemetry::default();
        assert!(
            disruption_telemetry_lines("RFold (4^3)", &quiet).is_empty(),
            "preemption-free runs must emit no PREEMPT section"
        );
        let t = DecisionTelemetry {
            preemptions: 3,
            preempt_wasted: 4096.0,
            migrations: 2,
            migration_time: 60.0,
            defrag_passes: 1,
            defrag_moves: 4,
            ..Default::default()
        };
        let lines = disruption_telemetry_lines("PreemptRFold (4^3)", &t);
        assert_eq!(lines.len(), 2);
        assert!(lines
            .iter()
            .all(|l| l.starts_with("PREEMPT PreemptRFold (4^3)")));
        assert!(lines[0].contains("preemptions=3") && lines[0].contains("migrations=2"));
        assert!(lines[1].contains("defrag-passes=1") && lines[1].contains("defrag-moves=4"));
    }
}
