//! Table/figure formatters: print the same rows and series the paper
//! reports, in a stable machine-greppable layout consumed by
//! EXPERIMENTS.md.

use super::CellSummary;

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".into();
    }
    if s < 120.0 {
        format!("{s:.0}s")
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else if s < 172_800.0 {
        format!("{:.1}h", s / 3600.0)
    } else {
        format!("{:.1}d", s / 86400.0)
    }
}

/// Table 1: average JCR per policy/topology cell.
pub fn print_table1(cells: &[CellSummary]) {
    println!("\nTable 1: Average job completion rate (JCR)");
    println!("{:<22} {:>12}", "Policy", "Avg JCR (%)");
    println!("{}", "-".repeat(36));
    for c in cells {
        println!("TABLE1 {:<22} {:>11.2}", c.label, c.avg_jcr_pct);
    }
}

/// Figure 3: JCT p50/p90/p99 per cell.
pub fn print_fig3(cells: &[CellSummary]) {
    println!("\nFigure 3: Job completion time (averaged across runs)");
    println!(
        "{:<22} {:>12} {:>12} {:>12}",
        "Policy", "p50", "p90", "p99"
    );
    println!("{}", "-".repeat(62));
    for c in cells {
        println!(
            "FIG3 {:<22} {:>12} {:>12} {:>12}   (s: {:.0}/{:.0}/{:.0})",
            c.label,
            fmt_secs(c.jct_p50),
            fmt_secs(c.jct_p90),
            fmt_secs(c.jct_p99),
            c.jct_p50,
            c.jct_p90,
            c.jct_p99,
        );
    }
}

/// Figure 4: utilization CDF series per cell.
pub fn print_fig4(cells: &[CellSummary]) {
    println!("\nFigure 4: Cluster utilization CDF (per-quantile average)");
    for c in cells {
        let series: Vec<String> = c
            .util_cdf
            .iter()
            .map(|(q, u)| format!("{q:.2}:{u:.3}"))
            .collect();
        println!("FIG4 {:<22} mean={:.3} cdf=[{}]", c.label, c.avg_util, series.join(" "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(30.0), "30s");
        assert!(fmt_secs(600.0).ends_with('m'));
        assert!(fmt_secs(10_000.0).ends_with('h'));
        assert!(fmt_secs(500_000.0).ends_with('d'));
        assert_eq!(fmt_secs(f64::NAN), "n/a");
    }
}
