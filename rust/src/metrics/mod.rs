//! Experiment metrics and multi-run aggregation: everything needed to
//! regenerate Table 1 (JCR), Figure 3 (JCT percentiles) and Figure 4
//! (utilization CDFs), each averaged across repeated seeded runs exactly
//! like the paper ("averaged across 100 runs").

pub mod report;

use crate::sim::engine::RunResult;
use crate::trace::JobSpec;
use crate::util::stats;

/// Summary of one (policy, topology) cell across many runs.
#[derive(Clone, Debug)]
pub struct CellSummary {
    pub label: String,
    pub runs: usize,
    /// Average JCR in percent (Table 1).
    pub avg_jcr_pct: f64,
    /// Mean-of-runs JCT percentiles in seconds (Figure 3).
    pub jct_p50: f64,
    pub jct_p90: f64,
    pub jct_p99: f64,
    /// Utilization CDF averaged per quantile across runs (Figure 4);
    /// `(quantile, utilization)` pairs.
    pub util_cdf: Vec<(f64, f64)>,
    /// Time-weighted mean utilization.
    pub avg_util: f64,
    /// Mean queueing delay (the §5 best-effort trade-off).
    pub avg_queue_delay: f64,
    /// Disruption averages (all exactly zero — and `avg_useful_util ==
    /// avg_util` bit-for-bit — when no preemption/checkpoint knob ran).
    pub avg_preemptions: f64,
    /// Mean node-seconds of evicted-then-rerun work per run.
    pub avg_wasted_work: f64,
    /// Mean migration surcharge per run (s).
    pub avg_migration_time: f64,
    /// Mean utilization net of wasted work.
    pub avg_useful_util: f64,
}

/// Number of points on the reported utilization CDF curves.
pub const CDF_POINTS: usize = 20;

/// Aggregate per-run results (with their traces) into a cell summary.
///
/// Takes borrowed results: trial outputs are shared (`Arc`ed by the sweep
/// result cache, possibly across several cells), so aggregation must not
/// deep-clone outcome vectors and utilization sample sets per cell.
pub fn summarize(label: &str, runs: &[(&RunResult, &[JobSpec])]) -> CellSummary {
    assert!(!runs.is_empty());
    let mut jcrs = Vec::new();
    let mut p50s = Vec::new();
    let mut p90s = Vec::new();
    let mut p99s = Vec::new();
    let mut utils = Vec::new();
    let mut delays = Vec::new();
    let mut preemptions = Vec::new();
    let mut wasted = Vec::new();
    let mut migration = Vec::new();
    let mut useful = Vec::new();
    let mut curves: Vec<Vec<f64>> = vec![Vec::new(); CDF_POINTS + 1];
    for &(r, trace) in runs {
        jcrs.push(r.jcr() * 100.0);
        preemptions.push(r.preemptions as f64);
        wasted.push(r.wasted_work);
        migration.push(r.migration_time);
        useful.push(r.useful_util);
        // One arrivals-map build per (run, cell) instead of two.
        let (jcts, qd) = r.jcts_and_queueing_delays(trace);
        if !jcts.is_empty() {
            p50s.push(stats::percentile_of(&jcts, 50.0));
            p90s.push(stats::percentile_of(&jcts, 90.0));
            p99s.push(stats::percentile_of(&jcts, 99.0));
        }
        if !qd.is_empty() {
            delays.push(stats::mean(&qd));
        }
        utils.push(r.utilization.mean());
        for (i, (_, v)) in r.utilization.curve(CDF_POINTS).into_iter().enumerate() {
            curves[i].push(v);
        }
    }
    CellSummary {
        label: label.to_string(),
        runs: runs.len(),
        avg_jcr_pct: stats::mean(&jcrs),
        jct_p50: stats::mean(&p50s),
        jct_p90: stats::mean(&p90s),
        jct_p99: stats::mean(&p99s),
        util_cdf: (0..=CDF_POINTS)
            .map(|i| (i as f64 / CDF_POINTS as f64, stats::mean(&curves[i])))
            .collect(),
        avg_util: stats::mean(&utils),
        avg_queue_delay: if delays.is_empty() {
            0.0
        } else {
            stats::mean(&delays)
        },
        avg_preemptions: stats::mean(&preemptions),
        avg_wasted_work: stats::mean(&wasted),
        avg_migration_time: stats::mean(&migration),
        avg_useful_util: stats::mean(&useful),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::PolicyKind;
    use crate::sim::{SimConfig, Simulation};
    use crate::topology::cluster::ClusterTopo;
    use crate::trace::gen::{generate, TraceConfig};

    #[test]
    fn summarize_two_runs() {
        let mut results = Vec::new();
        let mut traces = Vec::new();
        for seed in 1..=2 {
            let cfg = TraceConfig { num_jobs: 40, seed, ..Default::default() };
            traces.push(generate(&cfg));
        }
        for t in &traces {
            let r = Simulation::new(SimConfig::new(
                ClusterTopo::reconfigurable_4096(4),
                PolicyKind::RFold,
            ))
            .run(t);
            results.push(r);
        }
        let pairs: Vec<(&RunResult, &[JobSpec])> = results
            .iter()
            .zip(&traces)
            .map(|(r, t)| (r, t.as_slice()))
            .collect();
        let s = summarize("RFold (4^3)", &pairs);
        assert_eq!(s.runs, 2);
        assert!(s.avg_jcr_pct > 0.0 && s.avg_jcr_pct <= 100.0);
        assert!(s.jct_p50 <= s.jct_p90 && s.jct_p90 <= s.jct_p99);
        assert_eq!(s.util_cdf.len(), CDF_POINTS + 1);
        // CDF must be monotone.
        for w in s.util_cdf.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
    }
}
