//! Named workload scenarios: the third axis of the sweep grid.
//!
//! The paper evaluates one synthetic workload (§4). Scheduler conclusions
//! are workload-sensitive — CASSINI (arXiv:2308.00852) and the
//! ring-all-reduce contention study (arXiv:2207.07817) both stress
//! evaluating under diverse arrival burstiness and shape mixes — so the
//! registry parameterizes [`TraceConfig`]/[`ShapeRule`] into six named
//! workloads that `rfold sweep` crosses with every (policy, topology)
//! cell.
//!
//! Invariant shared by every scenario: `ShapeRule::max_dim` and
//! `max_cubes4` stay at the paper's caps, so each generated job remains
//! placeable on an empty Reconfig(4³) cluster — the property-test suite
//! (`tests/prop_trace.rs`) locks this down.

use std::path::Path;
use std::sync::Arc;

use super::gen::{generate, ShapeRule, TraceConfig};
use super::JobSpec;

/// A named workload scenario.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Scenario {
    /// The paper's §4 synthetic workload, unchanged.
    PaperDefault,
    /// Strongly bursty Philly-style arrivals: fast trains of submissions
    /// separated by long lulls (Jeon et al., ATC'19, figure 4 regime).
    BurstyPhilly,
    /// Heavier log-normal duration tail: a few multi-week jobs pin
    /// resources while medians stay short.
    HeavyTailDurations,
    /// Adversarially elongated shape mix: most jobs carry one very long
    /// communicating dimension, the regime that separates folding policies
    /// from rotation-only ones.
    ElongatedAdversarial,
    /// Many small round-sized jobs arriving quickly — a high-churn
    /// fragmentation stressor.
    UniformSmall,
    /// Communication-dominated jobs: comm_frac drawn from [0.45, 0.80),
    /// amplifying placement sensitivity of JCT.
    CommHeavy,
    /// The reference `packing.py` job mix: truncated-exponential sizes
    /// snapped to multiples of 4, dimensionality fixed by size class
    /// (1D for singletons, 3D above 1024 XPUs, 2D/3D above 128), uniform
    /// factorization choice.
    PackingRef,
}

impl Scenario {
    /// Every registered scenario, in stable reporting order.
    pub const ALL: [Scenario; 7] = [
        Scenario::PaperDefault,
        Scenario::BurstyPhilly,
        Scenario::HeavyTailDurations,
        Scenario::ElongatedAdversarial,
        Scenario::UniformSmall,
        Scenario::CommHeavy,
        Scenario::PackingRef,
    ];

    /// Stable CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::PaperDefault => "paper-default",
            Scenario::BurstyPhilly => "bursty-philly",
            Scenario::HeavyTailDurations => "heavy-tail-durations",
            Scenario::ElongatedAdversarial => "elongated-adversarial",
            Scenario::UniformSmall => "uniform-small",
            Scenario::CommHeavy => "comm-heavy",
            Scenario::PackingRef => "packing-ref",
        }
    }

    /// One-line description for `rfold sweep` help output.
    pub fn describe(&self) -> &'static str {
        match self {
            Scenario::PaperDefault => "the paper's §4 synthetic workload",
            Scenario::BurstyPhilly => "bursty Philly-style arrival trains",
            Scenario::HeavyTailDurations => "heavier log-normal duration tail",
            Scenario::ElongatedAdversarial => "mostly-elongated adversarial shapes",
            Scenario::UniformSmall => "many small round jobs, high churn",
            Scenario::CommHeavy => "communication-dominated jobs",
            Scenario::PackingRef => "reference packing.py size/shape rules",
        }
    }

    /// Parse a scenario name as printed by [`Scenario::name`].
    pub fn parse(s: &str) -> Option<Scenario> {
        let want = s.trim().to_ascii_lowercase();
        Scenario::ALL.into_iter().find(|sc| sc.name() == want)
    }

    /// Parse a comma-separated scenario list; `"all"` selects every
    /// scenario. Returns `None` if any entry is unknown.
    pub fn parse_list(spec: &str) -> Option<Vec<Scenario>> {
        if spec.trim().eq_ignore_ascii_case("all") {
            return Some(Scenario::ALL.to_vec());
        }
        let mut out = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            out.push(Scenario::parse(part)?);
        }
        if out.is_empty() {
            None
        } else {
            Some(out)
        }
    }

    /// The trace-generator configuration of this scenario for a given job
    /// count and seed. Seeds are shared across scenarios and cells so a
    /// sweep compares policies on identical per-trial randomness streams.
    pub fn trace_config(&self, num_jobs: usize, seed: u64) -> TraceConfig {
        let base = TraceConfig {
            num_jobs,
            seed,
            ..Default::default()
        };
        match self {
            Scenario::PaperDefault => base,
            Scenario::BurstyPhilly => TraceConfig {
                mean_interarrival: 90.0,
                burst_prob: 0.65,
                mean_lull: 9_000.0,
                ..base
            },
            Scenario::HeavyTailDurations => TraceConfig {
                dur_mu: (500.0f64).ln(),
                dur_sigma: 2.9,
                dur_max: 60.0 * 86_400.0,
                ..base
            },
            Scenario::ElongatedAdversarial => TraceConfig {
                size_scale: 700.0,
                shape_rule: ShapeRule {
                    small_p1: 0.10,
                    small_p2: 0.55,
                    large_p1: 0.0,
                    large_p2: 0.45,
                    w2d: [0.01, 0.04, 0.75, 0.20],
                    w3d: [0.04, 0.36, 0.60],
                    even_weight: 5.0,
                    ..ShapeRule::default()
                },
                ..base
            },
            Scenario::UniformSmall => TraceConfig {
                size_scale: 48.0,
                round8_prob: 0.9,
                mean_interarrival: 250.0,
                shape_rule: ShapeRule {
                    small_p1: 0.50,
                    small_p2: 0.45,
                    ..ShapeRule::default()
                },
                ..base
            },
            Scenario::CommHeavy => TraceConfig {
                comm_lo: 0.45,
                comm_hi: 0.80,
                size_scale: 500.0,
                ..base
            },
            Scenario::PackingRef => TraceConfig {
                packing_ref: true,
                ..base
            },
        }
    }
}

/// Default seed of the dedicated failure RNG stream. Modifiers draw from
/// their own [`Pcg64`](crate::util::Pcg64) stream, never from the trace
/// generator's, so job arrivals are byte-identical with and without
/// modifiers; this seed is the base the per-trial mixing starts from.
pub const DEFAULT_FAULT_SEED: u64 = 0xFA17;

/// Spatial failure-domain granularity for correlated faults
/// (`failures=corr:...`). Every node belongs to exactly one domain of
/// each scope; a correlated fault takes a whole sampled domain down
/// atomically (see `sim::domains`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DomainScope {
    /// One machine-room rack: the x-column of nodes sharing a physical
    /// x coordinate (a PSU/top-of-rack blast radius).
    Rack,
    /// One OCS cube of the reconfigurable decomposition (the whole
    /// machine for static topologies — one switch fronts everything).
    Cube,
    /// One z-slice of the machine (an OCS plane failure).
    Plane,
}

impl DomainScope {
    /// Stable CLI / fingerprint name.
    pub fn name(&self) -> &'static str {
        match self {
            DomainScope::Rack => "rack",
            DomainScope::Cube => "cube",
            DomainScope::Plane => "plane",
        }
    }

    /// Parse a `corr:` scope component. Unknown scopes are a structured
    /// error listing the valid values.
    pub fn parse(v: &str) -> Result<DomainScope, String> {
        match v {
            "rack" => Ok(DomainScope::Rack),
            "cube" => Ok(DomainScope::Cube),
            "plane" => Ok(DomainScope::Plane),
            other => Err(format!(
                "unknown failure-domain scope '{other}'; known: rack, cube, plane"
            )),
        }
    }
}

/// Correlated-failure parameters riding on a [`FailureModel`]: the blast
/// radius of every fault event and an optional cascade to one
/// neighbouring domain.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CorrFailure {
    /// Which nested domain a fault takes down atomically.
    pub scope: DomainScope,
    /// Probability that a domain fault cascades into the next domain of
    /// the same scope (deterministic neighbour order). 0 disables it.
    pub cascade: f64,
}

/// Exponential node/link failure-and-repair model (Philly-style MTBF,
/// Jeon et al., ATC'19). Times are cluster-wide: one failure somewhere in
/// the cluster every `mtbf` seconds on average.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureModel {
    /// Mean time between failures across the whole cluster (s).
    pub mtbf: f64,
    /// Mean node repair time (s).
    pub mean_repair: f64,
    /// Fraction of failures that are link (transient, kill the touching
    /// job but remove no capacity) rather than node failures.
    pub link_fraction: f64,
    /// Correlated blast radius (`failures=corr:...`): each fault fails an
    /// entire spatial domain instead of one node. `None` keeps the
    /// independent per-node model — and its exact byte stream.
    pub corr: Option<CorrFailure>,
}

impl FailureModel {
    /// The Philly trace regime (Jeon et al., ATC'19): a failure somewhere
    /// in the cluster every ~6 hours, hour-scale repairs, a quarter of
    /// incidents network-side.
    pub fn philly() -> FailureModel {
        FailureModel {
            mtbf: 21_600.0,
            mean_repair: 3_600.0,
            link_fraction: 0.25,
            corr: None,
        }
    }

    /// Parse a failure-model value: `philly`,
    /// `exp:<mtbf>:<mean-repair>:<link-fraction>` for explicit
    /// exponential parameters, or
    /// `corr:<mtbf>:<mean-repair>:<scope>[:<cascade>]` for correlated
    /// domain-scoped faults (scope ∈ rack|cube|plane).
    pub fn parse(v: &str) -> Result<FailureModel, String> {
        if v == "philly" {
            return Ok(FailureModel::philly());
        }
        let field = |s: &str, what: &str| -> Result<f64, String> {
            s.parse::<f64>()
                .ok()
                .filter(|x| x.is_finite() && *x >= 0.0)
                .ok_or_else(|| {
                    format!("failure-model {what} '{s}' is not a non-negative number")
                })
        };
        if let Some(rest) = v.strip_prefix("exp:") {
            let parts: Vec<&str> = rest.split(':').collect();
            if parts.len() == 3 {
                let mtbf = field(parts[0], "mtbf")?;
                if mtbf <= 0.0 {
                    return Err(format!("failure-model mtbf '{}' must be > 0", parts[0]));
                }
                let mean_repair = field(parts[1], "mean-repair")?;
                let link_fraction = field(parts[2], "link-fraction")?;
                if link_fraction > 1.0 {
                    return Err(format!(
                        "failure-model link-fraction '{}' out of range [0, 1]",
                        parts[2]
                    ));
                }
                return Ok(FailureModel {
                    mtbf,
                    mean_repair,
                    link_fraction,
                    corr: None,
                });
            }
        }
        if let Some(rest) = v.strip_prefix("corr:") {
            let parts: Vec<&str> = rest.split(':').collect();
            if parts.len() == 3 || parts.len() == 4 {
                let mtbf = field(parts[0], "mtbf")?;
                if mtbf <= 0.0 {
                    return Err(format!("failure-model mtbf '{}' must be > 0", parts[0]));
                }
                let mean_repair = field(parts[1], "mean-repair")?;
                let scope = DomainScope::parse(parts[2])?;
                let cascade = if parts.len() == 4 {
                    let c = field(parts[3], "cascade")?;
                    if c > 1.0 {
                        return Err(format!(
                            "failure-model cascade '{}' out of range [0, 1]",
                            parts[3]
                        ));
                    }
                    c
                } else {
                    0.0
                };
                return Ok(FailureModel {
                    mtbf,
                    mean_repair,
                    // Correlated faults are infrastructure-scoped: every
                    // event removes capacity; there is no transient link
                    // flavor.
                    link_fraction: 0.0,
                    corr: Some(CorrFailure { scope, cascade }),
                });
            }
        }
        Err(format!(
            "unknown failure model '{v}'; known: philly, \
             exp:<mtbf>:<mean-repair>:<link-fraction>, \
             corr:<mtbf>:<mean-repair>:<rack|cube|plane>[:<cascade>]"
        ))
    }
}

/// Victim-selection discipline for preemptive scheduling (`--with
/// preempt=priority|srtf`). Either mode turns the engine's NoCapacity
/// queueing into a PREEMPT decision when suitable victims exist.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptMode {
    /// Evict strictly-lower-priority jobs first; equal-priority ties fall
    /// back to longest-remaining-work (so preemption still engages on
    /// traces where every job shares the default class).
    Priority,
    /// Shortest-remaining-time-first: evict the jobs with the most
    /// remaining work to let short jobs through (Tiresias-style).
    Srtf,
}

impl PreemptMode {
    /// Stable CLI / fingerprint name.
    pub fn name(&self) -> &'static str {
        match self {
            PreemptMode::Priority => "priority",
            PreemptMode::Srtf => "srtf",
        }
    }

    /// Parse a `preempt=` value.
    pub fn parse(v: &str) -> Result<PreemptMode, String> {
        match v {
            "priority" => Ok(PreemptMode::Priority),
            "srtf" => Ok(PreemptMode::Srtf),
            other => Err(format!(
                "unknown preempt mode '{other}'; known: priority, srtf"
            )),
        }
    }
}

/// The parsed `--with` modifier set: composable fault-injection and
/// preemption knobs applied on top of any scenario or trace file. Parsed
/// once at the CLI boundary into this typed form; its
/// [`fingerprint`](Self::fingerprint) is the canonical string that flows
/// into sweep cache keys and the pool wire protocol.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModifierSet {
    /// Node/link failure injection; `None` disables it.
    pub failures: Option<FailureModel>,
    /// OCS reconfiguration latency (s): every placement that programs OCS
    /// entries pays this once, and stalls in-flight jobs sharing the
    /// reconfigured cubes by the same amount. 0 disables it.
    pub ocs_latency: f64,
    /// Probability a placed job is a straggler and runs 1.25–2× slower.
    /// 0 disables it.
    pub straggler_rate: f64,
    /// Preemptive scheduling discipline; `None` keeps the FIFO
    /// admit-or-queue loop byte-identical to the seed engine.
    pub preempt: Option<PreemptMode>,
    /// Restart surcharge (s) a job pays on its first placement after an
    /// eviction — checkpoint reload plus re-placement traffic. 0 disables
    /// it.
    pub migration_cost: f64,
    /// Idle-time defragmentation: when the queue head is
    /// NoCapacity-blocked, try re-folding every running job onto a
    /// compacted layout once before giving up.
    pub defrag: bool,
    /// Checkpoint interval (s of *useful work*): evicted and fault-killed
    /// jobs resume from the last completed interval instead of from
    /// scratch. 0 means no checkpoints (full rerun).
    pub checkpoint: f64,
    /// Priority aging: a job preempted `MAX_PREEMPTIONS` times climbs one
    /// priority class (+1, higher = more urgent) instead of becoming
    /// immune to preemption — the starvation guard turns into escalating
    /// protection rather than a hard exclusion, so a hot head can still
    /// claim the cluster from a many-times-preempted victim one class up.
    pub aging: bool,
    /// Base seed of the failure RNG stream; mixed per trial via
    /// [`for_trial`](Self::for_trial) so every trial sees an independent
    /// fault realization.
    pub fault_seed: u64,
}

impl Default for ModifierSet {
    fn default() -> Self {
        ModifierSet {
            failures: None,
            ocs_latency: 0.0,
            straggler_rate: 0.0,
            preempt: None,
            migration_cost: 0.0,
            defrag: false,
            checkpoint: 0.0,
            aging: false,
            fault_seed: DEFAULT_FAULT_SEED,
        }
    }
}

/// One-line list of valid modifiers, appended to every parse error.
const VALID_MODIFIERS: &str = "valid modifiers: failures=philly|exp:<mtbf>:<repair>:<link-frac>\
     |corr:<mtbf>:<repair>:<rack|cube|plane>[:<cascade>], \
     ocs-latency=<duration, e.g. 500ms|5s|2m|1h>, stragglers=<rate in [0,1]>, \
     preempt=priority|srtf, migration-cost=<duration>, defrag=idle|off, \
     checkpoint=<duration>, aging=on|off, seed=<u64>";

/// Parse a duration with an optional `ms`/`s`/`m`/`h` suffix (bare
/// numbers are seconds) into seconds.
fn parse_duration(v: &str) -> Result<f64, String> {
    let (num, mult) = if let Some(n) = v.strip_suffix("ms") {
        (n, 1e-3)
    } else if let Some(n) = v.strip_suffix('s') {
        (n, 1.0)
    } else if let Some(n) = v.strip_suffix('m') {
        (n, 60.0)
    } else if let Some(n) = v.strip_suffix('h') {
        (n, 3600.0)
    } else {
        (v, 1.0)
    };
    let x: f64 = num
        .trim()
        .parse()
        .map_err(|_| format!("malformed duration '{v}' (use e.g. 500ms, 5s, 2m, 1h)"))?;
    if !x.is_finite() || x < 0.0 {
        return Err(format!("duration '{v}' must be finite and >= 0"));
    }
    Ok(x * mult)
}

impl ModifierSet {
    /// Parse a comma-separated `--with` spec
    /// (`failures=philly,ocs-latency=5s,stragglers=0.05`). Unknown keys,
    /// malformed durations, and out-of-range rates return a structured
    /// error listing the valid modifiers — never a panic. The empty spec
    /// parses to the default (no-op) set.
    pub fn parse(spec: &str) -> Result<ModifierSet, String> {
        let mut out = ModifierSet::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((key, value)) = part.split_once('=') else {
                return Err(format!("modifier '{part}' is not key=value; {VALID_MODIFIERS}"));
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "failures" => out.failures = Some(FailureModel::parse(value)?),
                "ocs-latency" => {
                    out.ocs_latency =
                        parse_duration(value).map_err(|e| format!("ocs-latency: {e}"))?;
                }
                "stragglers" => {
                    let rate: f64 = value
                        .parse()
                        .map_err(|_| format!("stragglers '{value}' is not a number"))?;
                    if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
                        return Err(format!("stragglers {value} out of range [0, 1]"));
                    }
                    out.straggler_rate = rate;
                }
                "preempt" => out.preempt = Some(PreemptMode::parse(value)?),
                "migration-cost" => {
                    out.migration_cost =
                        parse_duration(value).map_err(|e| format!("migration-cost: {e}"))?;
                }
                "defrag" => {
                    out.defrag = match value {
                        "idle" => true,
                        "off" => false,
                        other => {
                            return Err(format!(
                                "unknown defrag mode '{other}'; known: idle, off"
                            ));
                        }
                    };
                }
                "checkpoint" => {
                    out.checkpoint =
                        parse_duration(value).map_err(|e| format!("checkpoint: {e}"))?;
                }
                "aging" => {
                    out.aging = match value {
                        "on" => true,
                        "off" => false,
                        other => {
                            return Err(format!(
                                "unknown aging mode '{other}'; known: on, off"
                            ));
                        }
                    };
                }
                "seed" => {
                    out.fault_seed = value
                        .parse()
                        .map_err(|_| format!("seed '{value}' is not a u64"))?;
                }
                other => {
                    return Err(format!("unknown modifier '{other}'; {VALID_MODIFIERS}"));
                }
            }
        }
        Ok(out)
    }

    /// True when no modifier is active: the engine runs byte-identically
    /// to a build without the fault layer.
    pub fn is_empty(&self) -> bool {
        *self == ModifierSet::default()
    }

    /// True when failure injection is on (the knob that creates fault
    /// events, as opposed to latency/straggler shaping).
    pub fn has_faults(&self) -> bool {
        self.failures.is_some()
    }

    /// True when any eviction path beyond fault kills is enabled —
    /// preemption, idle-time defragmentation, or checkpointed restarts.
    /// Gates the engine's disruption bookkeeping so runs without these
    /// knobs stay byte-identical to the seed engine.
    pub fn has_disruption(&self) -> bool {
        self.preempt.is_some() || self.defrag || self.checkpoint > 0.0
    }

    /// Canonical string form: parseable back via [`parse`](Self::parse)
    /// (`parse(fingerprint()) == self`), empty for the default set, and
    /// stable across processes — the sweep cache-key and wire-protocol
    /// representation. f64 components use Rust's shortest-round-trip
    /// `Display`, so re-parsing is bit-exact.
    pub fn fingerprint(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(fm) = self.failures {
            if fm == FailureModel::philly() {
                parts.push("failures=philly".to_string());
            } else if let Some(corr) = fm.corr {
                if corr.cascade > 0.0 {
                    parts.push(format!(
                        "failures=corr:{}:{}:{}:{}",
                        fm.mtbf,
                        fm.mean_repair,
                        corr.scope.name(),
                        corr.cascade
                    ));
                } else {
                    parts.push(format!(
                        "failures=corr:{}:{}:{}",
                        fm.mtbf,
                        fm.mean_repair,
                        corr.scope.name()
                    ));
                }
            } else {
                parts.push(format!(
                    "failures=exp:{}:{}:{}",
                    fm.mtbf, fm.mean_repair, fm.link_fraction
                ));
            }
        }
        if self.ocs_latency > 0.0 {
            parts.push(format!("ocs-latency={}s", self.ocs_latency));
        }
        if self.straggler_rate > 0.0 {
            parts.push(format!("stragglers={}", self.straggler_rate));
        }
        if let Some(mode) = self.preempt {
            parts.push(format!("preempt={}", mode.name()));
        }
        if self.migration_cost > 0.0 {
            parts.push(format!("migration-cost={}s", self.migration_cost));
        }
        if self.defrag {
            parts.push("defrag=idle".to_string());
        }
        if self.checkpoint > 0.0 {
            parts.push(format!("checkpoint={}s", self.checkpoint));
        }
        if self.aging {
            parts.push("aging=on".to_string());
        }
        if self.fault_seed != DEFAULT_FAULT_SEED {
            parts.push(format!("seed={}", self.fault_seed));
        }
        parts.join(",")
    }

    /// The per-trial modifier set: same knobs, fault seed mixed with the
    /// trial seed so each trial draws an independent failure realization.
    /// Engine-facing only — cache keys and the wire carry the base set
    /// plus the trial seed and re-mix on both sides, so leader and worker
    /// agree by construction.
    pub fn for_trial(&self, trial_seed: u64) -> ModifierSet {
        ModifierSet {
            fault_seed: self.fault_seed ^ trial_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ..*self
        }
    }
}

/// A workload source for experiment drivers: a registered synthetic
/// [`Scenario`], or an external CSV trace read through
/// [`crate::trace::io::read_csv`] — the ROADMAP's real-trace slot, wired
/// to the CLI's `--trace-file` flag.
#[derive(Clone, Debug)]
pub enum Workload {
    /// A named synthetic scenario; traces are regenerated per seed.
    Synthetic(Scenario),
    /// A fixed external trace (e.g. Philly-derived). The job list is
    /// shared, not cloned per reference, and is seed-independent: every
    /// trial replays the same recorded arrivals.
    Csv {
        /// Report name (the file stem).
        name: String,
        jobs: Arc<[JobSpec]>,
        /// FNV-1a hash of the job list, computed once at load time. Part
        /// of the sweep cache key: two different files sharing a stem
        /// must never share trial results.
        content_hash: u64,
    },
}

/// FNV-1a over the full job list (ids, arrival/duration/comm_frac bits,
/// shape dims). Cheap, dependency-free, and stable across processes —
/// exactly what the sweep cache key and the pool wire format need.
pub fn jobs_content_hash(jobs: &[JobSpec]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    for j in jobs {
        eat(j.id);
        eat(j.arrival.to_bits());
        eat(j.duration.to_bits());
        let d = j.shape.dims();
        eat(d.0[0] as u64);
        eat(d.0[1] as u64);
        eat(d.0[2] as u64);
        eat(j.comm_frac.to_bits());
        eat(j.priority as u64);
    }
    h
}

impl Workload {
    /// Load a CSV trace (`id,arrival,duration,a,b,c,comm_frac`, header
    /// required) as a workload. Fails on unreadable or malformed files
    /// and on empty traces.
    pub fn from_csv(path: &Path) -> std::io::Result<Workload> {
        let jobs = crate::trace::io::read_csv(path)?;
        if jobs.is_empty() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{}: trace has no jobs", path.display()),
            ));
        }
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("trace")
            .to_string();
        Ok(Workload::from_jobs(name, jobs))
    }

    /// Wrap an in-memory job list as a fixed-trace workload (the pool
    /// worker's decode path; [`Workload::from_csv`] routes through here).
    pub fn from_jobs(name: String, jobs: Vec<JobSpec>) -> Workload {
        let content_hash = jobs_content_hash(&jobs);
        Workload::Csv {
            name,
            jobs: jobs.into(),
            content_hash,
        }
    }

    /// Report name: the scenario name or the trace file stem.
    pub fn name(&self) -> &str {
        match self {
            Workload::Synthetic(sc) => sc.name(),
            Workload::Csv { name, .. } => name,
        }
    }

    /// Owned cache-key component for the sweep's `TrialKey`. Synthetic
    /// scenarios are fully identified by their registry name (the name
    /// pins every generator parameter); CSV workloads add the job-list
    /// content hash so two different files with the same stem can never
    /// collide, and carry a `csv:` prefix so a file named
    /// `paper-default.csv` cannot impersonate the synthetic scenario.
    pub fn cache_key(&self) -> String {
        match self {
            Workload::Synthetic(sc) => sc.name().to_string(),
            Workload::Csv {
                name, content_hash, ..
            } => format!("csv:{name}:{content_hash:016x}"),
        }
    }

    /// The job trace for one trial, shared rather than owned: synthetic
    /// workloads generate `num_jobs` jobs from `seed` (a fresh list per
    /// call); CSV workloads hand out another reference to the one
    /// recorded realization (both knobs are ignored) — every trial and
    /// every wire decode used to deep-clone the full job list here
    /// (ROADMAP perf item, retired).
    pub fn trace(&self, num_jobs: usize, seed: u64) -> Arc<[JobSpec]> {
        match self {
            Workload::Synthetic(sc) => generate(&sc.trace_config(num_jobs, seed)).into(),
            Workload::Csv { jobs, .. } => jobs.clone(),
        }
    }

    /// Number of jobs one trial will see.
    pub fn num_jobs(&self, requested: usize) -> usize {
        match self {
            Workload::Synthetic(_) => requested,
            Workload::Csv { jobs, .. } => jobs.len(),
        }
    }

    /// Number of *distinct* trial realizations `requested` runs produce:
    /// `requested` for synthetic workloads (each seed generates a new
    /// trace), at most 1 for a fixed trace (every trial replays the same
    /// recording). Report rows use this so a trace-file sweep cannot
    /// overstate its statistical support.
    pub fn num_runs(&self, requested: usize) -> usize {
        match self {
            Workload::Synthetic(_) => requested,
            Workload::Csv { .. } => requested.min(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_and_are_distinct() {
        let mut seen = std::collections::BTreeSet::new();
        for sc in Scenario::ALL {
            assert_eq!(Scenario::parse(sc.name()), Some(sc));
            assert!(seen.insert(sc.name()), "duplicate name {}", sc.name());
            assert!(!sc.describe().is_empty());
        }
        assert_eq!(Scenario::parse("no-such-scenario"), None);
    }

    #[test]
    fn parse_list_handles_all_and_commas() {
        assert_eq!(Scenario::parse_list("all").unwrap(), Scenario::ALL.to_vec());
        assert_eq!(
            Scenario::parse_list("paper-default, comm-heavy").unwrap(),
            vec![Scenario::PaperDefault, Scenario::CommHeavy]
        );
        assert_eq!(Scenario::parse_list("paper-default,bogus"), None);
        assert_eq!(Scenario::parse_list(""), None);
    }

    #[test]
    fn paper_default_matches_default_config() {
        let a = Scenario::PaperDefault.trace_config(64, 9);
        let b = TraceConfig {
            num_jobs: 64,
            seed: 9,
            ..Default::default()
        };
        // Same generator inputs → byte-identical traces.
        assert_eq!(generate(&a), generate(&b));
    }

    #[test]
    fn every_scenario_keeps_placement_caps() {
        for sc in Scenario::ALL {
            let cfg = sc.trace_config(16, 1);
            assert_eq!(cfg.shape_rule.max_dim, ShapeRule::default().max_dim, "{sc:?}");
            assert_eq!(
                cfg.shape_rule.max_cubes4,
                ShapeRule::default().max_cubes4,
                "{sc:?}"
            );
        }
    }

    #[test]
    fn workload_wraps_scenarios_and_csv() {
        let w = Workload::Synthetic(Scenario::PaperDefault);
        assert_eq!(w.name(), "paper-default");
        assert_eq!(w.trace(12, 3).len(), 12);
        assert_eq!(w.num_jobs(12), 12);

        let trace = generate(&TraceConfig {
            num_jobs: 9,
            ..Default::default()
        });
        let tmp = std::env::temp_dir().join("rfold_workload_test.csv");
        crate::trace::io::write_csv(&tmp, &trace).unwrap();
        let w = Workload::from_csv(&tmp).unwrap();
        assert_eq!(w.name(), "rfold_workload_test");
        // Requested size and seed are ignored: the recorded trace replays.
        assert_eq!(w.trace(100, 1).len(), 9);
        assert_eq!(w.trace(100, 1), w.trace(5, 2));
        // A fixed trace is *shared*, not deep-cloned per trial.
        assert!(Arc::ptr_eq(&w.trace(100, 1), &w.trace(5, 2)));
        assert_eq!(w.num_jobs(100), 9);
        std::fs::remove_file(&tmp).ok();

        assert!(Workload::from_csv(std::path::Path::new("/no/such/file.csv")).is_err());
    }

    #[test]
    fn cache_keys_distinguish_files_by_content_not_stem() {
        let mk = |seed: u64| {
            generate(&TraceConfig {
                num_jobs: 8,
                seed,
                ..Default::default()
            })
        };
        let a = Workload::from_jobs("trace".into(), mk(1));
        let b = Workload::from_jobs("trace".into(), mk(2));
        let a2 = Workload::from_jobs("trace".into(), mk(1));
        assert_eq!(a.name(), b.name(), "same stem");
        assert_ne!(a.cache_key(), b.cache_key(), "different content must not collide");
        assert_eq!(a.cache_key(), a2.cache_key(), "same content, same key");
        // A CSV stem equal to a scenario name cannot impersonate it.
        let fake = Workload::from_jobs("paper-default".into(), mk(3));
        assert_ne!(
            fake.cache_key(),
            Workload::Synthetic(Scenario::PaperDefault).cache_key()
        );
        assert_eq!(
            Workload::Synthetic(Scenario::PaperDefault).cache_key(),
            "paper-default"
        );
    }

    #[test]
    fn packing_ref_uses_reference_size_rules() {
        assert!(Scenario::PackingRef.trace_config(8, 1).packing_ref);
        for sc in Scenario::ALL {
            if sc != Scenario::PackingRef {
                assert!(!sc.trace_config(8, 1).packing_ref, "{sc:?}");
            }
        }
    }

    #[test]
    fn modifier_parse_happy_paths() {
        let m = ModifierSet::parse("failures=philly,ocs-latency=5s,stragglers=0.05").unwrap();
        assert_eq!(m.failures, Some(FailureModel::philly()));
        assert_eq!(m.ocs_latency, 5.0);
        assert_eq!(m.straggler_rate, 0.05);
        assert_eq!(m.fault_seed, DEFAULT_FAULT_SEED);
        assert!(!m.is_empty());
        assert!(m.has_faults());

        // Duration suffixes, bare seconds, and whitespace tolerance.
        assert_eq!(ModifierSet::parse("ocs-latency=500ms").unwrap().ocs_latency, 0.5);
        assert_eq!(ModifierSet::parse("ocs-latency=2m").unwrap().ocs_latency, 120.0);
        assert_eq!(ModifierSet::parse("ocs-latency=1h").unwrap().ocs_latency, 3600.0);
        assert_eq!(ModifierSet::parse("ocs-latency=7").unwrap().ocs_latency, 7.0);
        assert_eq!(
            ModifierSet::parse(" failures = philly , seed = 42 ").unwrap().fault_seed,
            42
        );

        // Explicit exponential model.
        let e = ModifierSet::parse("failures=exp:100:50:0.5").unwrap();
        assert_eq!(
            e.failures,
            Some(FailureModel {
                mtbf: 100.0,
                mean_repair: 50.0,
                link_fraction: 0.5,
                corr: None,
            })
        );

        // Correlated domain-scoped model, with and without cascade.
        let c = ModifierSet::parse("failures=corr:7200:600:rack").unwrap();
        assert_eq!(
            c.failures,
            Some(FailureModel {
                mtbf: 7200.0,
                mean_repair: 600.0,
                link_fraction: 0.0,
                corr: Some(CorrFailure {
                    scope: DomainScope::Rack,
                    cascade: 0.0
                }),
            })
        );
        let c = ModifierSet::parse("failures=corr:7200:600:cube:0.3").unwrap();
        let corr = c.failures.unwrap().corr.unwrap();
        assert_eq!(corr.scope, DomainScope::Cube);
        assert_eq!(corr.cascade, 0.3);
        assert_eq!(
            ModifierSet::parse("failures=corr:100:50:plane")
                .unwrap()
                .failures
                .unwrap()
                .corr
                .unwrap()
                .scope,
            DomainScope::Plane
        );

        // Empty spec is the no-op set.
        let empty = ModifierSet::parse("").unwrap();
        assert!(empty.is_empty());
        assert!(!empty.has_faults());
        assert_eq!(empty, ModifierSet::default());
    }

    #[test]
    fn modifier_parse_rejects_unknown_keys() {
        let err = ModifierSet::parse("failures=philly,bogus=1").unwrap_err();
        assert!(err.contains("unknown modifier 'bogus'"), "{err}");
        assert!(err.contains("valid modifiers"), "error must list valid modifiers: {err}");
    }

    #[test]
    fn modifier_parse_rejects_malformed_durations() {
        let err = ModifierSet::parse("ocs-latency=5x").unwrap_err();
        assert!(err.contains("malformed duration '5x'"), "{err}");
        let err = ModifierSet::parse("ocs-latency=-3s").unwrap_err();
        assert!(err.contains("finite and >= 0"), "{err}");
        let err = ModifierSet::parse("ocs-latency=inf").unwrap_err();
        assert!(err.contains("finite and >= 0"), "{err}");
    }

    #[test]
    fn modifier_parse_rejects_out_of_range_rates() {
        let err = ModifierSet::parse("stragglers=1.5").unwrap_err();
        assert!(err.contains("out of range [0, 1]"), "{err}");
        let err = ModifierSet::parse("stragglers=-0.1").unwrap_err();
        assert!(err.contains("out of range [0, 1]"), "{err}");
        let err = ModifierSet::parse("stragglers=abc").unwrap_err();
        assert!(err.contains("not a number"), "{err}");
    }

    #[test]
    fn modifier_parse_rejects_bad_seeds_models_and_bare_keys() {
        let err = ModifierSet::parse("seed=abc").unwrap_err();
        assert!(err.contains("not a u64"), "{err}");
        let err = ModifierSet::parse("failures=weird").unwrap_err();
        assert!(err.contains("unknown failure model 'weird'"), "{err}");
        let err = ModifierSet::parse("failures=exp:0:1:0").unwrap_err();
        assert!(err.contains("must be > 0"), "{err}");
        let err = ModifierSet::parse("failures=exp:1:1:2").unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        let err = ModifierSet::parse("justakey").unwrap_err();
        assert!(err.contains("not key=value"), "{err}");
    }

    #[test]
    fn corr_failures_reject_bad_scopes_and_cascades() {
        // The small-fix satellite: a bad sub-key inside `failures=` must
        // be a structured error listing the valid values, like top-level
        // unknown keys are.
        let err = ModifierSet::parse("failures=corr:100:50:tray").unwrap_err();
        assert!(
            err.contains("unknown failure-domain scope 'tray'"),
            "{err}"
        );
        assert!(err.contains("rack, cube, plane"), "must list valid scopes: {err}");
        let err = ModifierSet::parse("failures=corr:100:50:rack:1.5").unwrap_err();
        assert!(err.contains("cascade"), "{err}");
        assert!(err.contains("out of range"), "{err}");
        let err = ModifierSet::parse("failures=corr:0:50:rack").unwrap_err();
        assert!(err.contains("must be > 0"), "{err}");
        let err = ModifierSet::parse("failures=corr:100:50").unwrap_err();
        assert!(err.contains("unknown failure model"), "{err}");
        // Scope names round-trip.
        for s in [DomainScope::Rack, DomainScope::Cube, DomainScope::Plane] {
            assert_eq!(DomainScope::parse(s.name()), Ok(s));
        }
    }

    #[test]
    fn preempt_modifiers_parse_and_default_off() {
        let m = ModifierSet::parse("preempt=priority,migration-cost=30s,defrag=idle").unwrap();
        assert_eq!(m.preempt, Some(PreemptMode::Priority));
        assert_eq!(m.migration_cost, 30.0);
        assert!(m.defrag);
        assert_eq!(m.checkpoint, 0.0);
        assert!(!m.is_empty());
        assert!(m.has_disruption());
        assert!(!m.has_faults(), "preemption alone injects no faults");

        let s = ModifierSet::parse("preempt=srtf,checkpoint=10m").unwrap();
        assert_eq!(s.preempt, Some(PreemptMode::Srtf));
        assert_eq!(s.checkpoint, 600.0);

        // `defrag=off` is the explicit spelling of the default.
        assert!(!ModifierSet::parse("defrag=off").unwrap().defrag);
        assert!(ModifierSet::parse("defrag=off").unwrap().is_empty());

        // Aging is a preemption-shaping knob, not a disruption source: it
        // only changes which victims a preemptive head may take, so on its
        // own it must not flip the disruption bookkeeping on.
        let a = ModifierSet::parse("aging=on").unwrap();
        assert!(a.aging && !a.has_disruption() && !a.is_empty());
        assert!(ModifierSet::parse("aging=off").unwrap().is_empty());
        let err = ModifierSet::parse("aging=maybe").unwrap_err();
        assert!(err.contains("unknown aging mode 'maybe'"), "{err}");

        // The default set leaves every disruption path disabled.
        let d = ModifierSet::default();
        assert_eq!(d.preempt, None);
        assert!(!d.has_disruption());
    }

    #[test]
    fn preempt_modifiers_reject_bad_values() {
        let err = ModifierSet::parse("preempt=fifo").unwrap_err();
        assert!(err.contains("unknown preempt mode 'fifo'"), "{err}");
        let err = ModifierSet::parse("defrag=always").unwrap_err();
        assert!(err.contains("unknown defrag mode 'always'"), "{err}");
        let err = ModifierSet::parse("migration-cost=5x").unwrap_err();
        assert!(err.contains("malformed duration"), "{err}");
        let err = ModifierSet::parse("checkpoint=-1s").unwrap_err();
        assert!(err.contains("finite and >= 0"), "{err}");
    }

    #[test]
    fn modifier_fingerprint_roundtrips_and_is_canonical() {
        for spec in [
            "",
            "failures=philly",
            "failures=philly,ocs-latency=5s,stragglers=0.05",
            "ocs-latency=500ms",
            "stragglers=0.25,seed=77",
            "failures=exp:100:50:0.5,ocs-latency=2m",
            "preempt=priority,migration-cost=30s,defrag=idle",
            "preempt=srtf,checkpoint=10m,seed=5",
            "failures=philly,preempt=priority,checkpoint=1h",
            "preempt=priority,aging=on",
            "failures=philly,preempt=srtf,aging=on,seed=9",
            "failures=corr:7200:600:rack",
            "failures=corr:7200:600:cube:0.25",
            "failures=corr:21600:3600:plane,seed=11",
        ] {
            let m = ModifierSet::parse(spec).unwrap();
            let fp = m.fingerprint();
            let back = ModifierSet::parse(&fp).unwrap();
            assert_eq!(back, m, "fingerprint '{fp}' of '{spec}' must round-trip");
        }
        assert_eq!(ModifierSet::default().fingerprint(), "");
        // Two differently-spelled but equal specs share one fingerprint.
        assert_eq!(
            ModifierSet::parse("ocs-latency=120s").unwrap().fingerprint(),
            ModifierSet::parse("ocs-latency=2m").unwrap().fingerprint()
        );
    }

    #[test]
    fn for_trial_mixes_the_fault_seed_only() {
        let base = ModifierSet::parse("failures=philly,stragglers=0.1").unwrap();
        let a = base.for_trial(1);
        let b = base.for_trial(2);
        assert_ne!(a.fault_seed, b.fault_seed, "trials need independent fault streams");
        assert_eq!(a.failures, base.failures);
        assert_eq!(a.straggler_rate, base.straggler_rate);
        assert_eq!(a.ocs_latency, base.ocs_latency);
        // Mixing is deterministic.
        assert_eq!(base.for_trial(1), a);
    }

    #[test]
    fn comm_heavy_raises_comm_fraction() {
        let t = generate(&Scenario::CommHeavy.trace_config(80, 3));
        assert!(t.iter().all(|j| (0.45..0.80).contains(&j.comm_frac)));
        let d = generate(&Scenario::PaperDefault.trace_config(80, 3));
        assert!(d.iter().all(|j| (0.1..0.5).contains(&j.comm_frac)));
    }
}
