//! Named workload scenarios: the third axis of the sweep grid.
//!
//! The paper evaluates one synthetic workload (§4). Scheduler conclusions
//! are workload-sensitive — CASSINI (arXiv:2308.00852) and the
//! ring-all-reduce contention study (arXiv:2207.07817) both stress
//! evaluating under diverse arrival burstiness and shape mixes — so the
//! registry parameterizes [`TraceConfig`]/[`ShapeRule`] into six named
//! workloads that `rfold sweep` crosses with every (policy, topology)
//! cell.
//!
//! Invariant shared by every scenario: `ShapeRule::max_dim` and
//! `max_cubes4` stay at the paper's caps, so each generated job remains
//! placeable on an empty Reconfig(4³) cluster — the property-test suite
//! (`tests/prop_trace.rs`) locks this down.

use std::path::Path;
use std::sync::Arc;

use super::gen::{generate, ShapeRule, TraceConfig};
use super::JobSpec;

/// A named workload scenario.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Scenario {
    /// The paper's §4 synthetic workload, unchanged.
    PaperDefault,
    /// Strongly bursty Philly-style arrivals: fast trains of submissions
    /// separated by long lulls (Jeon et al., ATC'19, figure 4 regime).
    BurstyPhilly,
    /// Heavier log-normal duration tail: a few multi-week jobs pin
    /// resources while medians stay short.
    HeavyTailDurations,
    /// Adversarially elongated shape mix: most jobs carry one very long
    /// communicating dimension, the regime that separates folding policies
    /// from rotation-only ones.
    ElongatedAdversarial,
    /// Many small round-sized jobs arriving quickly — a high-churn
    /// fragmentation stressor.
    UniformSmall,
    /// Communication-dominated jobs: comm_frac drawn from [0.45, 0.80),
    /// amplifying placement sensitivity of JCT.
    CommHeavy,
}

impl Scenario {
    /// Every registered scenario, in stable reporting order.
    pub const ALL: [Scenario; 6] = [
        Scenario::PaperDefault,
        Scenario::BurstyPhilly,
        Scenario::HeavyTailDurations,
        Scenario::ElongatedAdversarial,
        Scenario::UniformSmall,
        Scenario::CommHeavy,
    ];

    /// Stable CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::PaperDefault => "paper-default",
            Scenario::BurstyPhilly => "bursty-philly",
            Scenario::HeavyTailDurations => "heavy-tail-durations",
            Scenario::ElongatedAdversarial => "elongated-adversarial",
            Scenario::UniformSmall => "uniform-small",
            Scenario::CommHeavy => "comm-heavy",
        }
    }

    /// One-line description for `rfold sweep` help output.
    pub fn describe(&self) -> &'static str {
        match self {
            Scenario::PaperDefault => "the paper's §4 synthetic workload",
            Scenario::BurstyPhilly => "bursty Philly-style arrival trains",
            Scenario::HeavyTailDurations => "heavier log-normal duration tail",
            Scenario::ElongatedAdversarial => "mostly-elongated adversarial shapes",
            Scenario::UniformSmall => "many small round jobs, high churn",
            Scenario::CommHeavy => "communication-dominated jobs",
        }
    }

    /// Parse a scenario name as printed by [`Scenario::name`].
    pub fn parse(s: &str) -> Option<Scenario> {
        let want = s.trim().to_ascii_lowercase();
        Scenario::ALL.into_iter().find(|sc| sc.name() == want)
    }

    /// Parse a comma-separated scenario list; `"all"` selects every
    /// scenario. Returns `None` if any entry is unknown.
    pub fn parse_list(spec: &str) -> Option<Vec<Scenario>> {
        if spec.trim().eq_ignore_ascii_case("all") {
            return Some(Scenario::ALL.to_vec());
        }
        let mut out = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            out.push(Scenario::parse(part)?);
        }
        if out.is_empty() {
            None
        } else {
            Some(out)
        }
    }

    /// The trace-generator configuration of this scenario for a given job
    /// count and seed. Seeds are shared across scenarios and cells so a
    /// sweep compares policies on identical per-trial randomness streams.
    pub fn trace_config(&self, num_jobs: usize, seed: u64) -> TraceConfig {
        let base = TraceConfig {
            num_jobs,
            seed,
            ..Default::default()
        };
        match self {
            Scenario::PaperDefault => base,
            Scenario::BurstyPhilly => TraceConfig {
                mean_interarrival: 90.0,
                burst_prob: 0.65,
                mean_lull: 9_000.0,
                ..base
            },
            Scenario::HeavyTailDurations => TraceConfig {
                dur_mu: (500.0f64).ln(),
                dur_sigma: 2.9,
                dur_max: 60.0 * 86_400.0,
                ..base
            },
            Scenario::ElongatedAdversarial => TraceConfig {
                size_scale: 700.0,
                shape_rule: ShapeRule {
                    small_p1: 0.10,
                    small_p2: 0.55,
                    large_p1: 0.0,
                    large_p2: 0.45,
                    w2d: [0.01, 0.04, 0.75, 0.20],
                    w3d: [0.04, 0.36, 0.60],
                    even_weight: 5.0,
                    ..ShapeRule::default()
                },
                ..base
            },
            Scenario::UniformSmall => TraceConfig {
                size_scale: 48.0,
                round8_prob: 0.9,
                mean_interarrival: 250.0,
                shape_rule: ShapeRule {
                    small_p1: 0.50,
                    small_p2: 0.45,
                    ..ShapeRule::default()
                },
                ..base
            },
            Scenario::CommHeavy => TraceConfig {
                comm_lo: 0.45,
                comm_hi: 0.80,
                size_scale: 500.0,
                ..base
            },
        }
    }
}

/// A workload source for experiment drivers: a registered synthetic
/// [`Scenario`], or an external CSV trace read through
/// [`crate::trace::io::read_csv`] — the ROADMAP's real-trace slot, wired
/// to the CLI's `--trace-file` flag.
#[derive(Clone, Debug)]
pub enum Workload {
    /// A named synthetic scenario; traces are regenerated per seed.
    Synthetic(Scenario),
    /// A fixed external trace (e.g. Philly-derived). The job list is
    /// shared, not cloned per reference, and is seed-independent: every
    /// trial replays the same recorded arrivals.
    Csv {
        /// Report name (the file stem).
        name: String,
        jobs: Arc<[JobSpec]>,
        /// FNV-1a hash of the job list, computed once at load time. Part
        /// of the sweep cache key: two different files sharing a stem
        /// must never share trial results.
        content_hash: u64,
    },
}

/// FNV-1a over the full job list (ids, arrival/duration/comm_frac bits,
/// shape dims). Cheap, dependency-free, and stable across processes —
/// exactly what the sweep cache key and the pool wire format need.
pub fn jobs_content_hash(jobs: &[JobSpec]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    for j in jobs {
        eat(j.id);
        eat(j.arrival.to_bits());
        eat(j.duration.to_bits());
        let d = j.shape.dims();
        eat(d.0[0] as u64);
        eat(d.0[1] as u64);
        eat(d.0[2] as u64);
        eat(j.comm_frac.to_bits());
    }
    h
}

impl Workload {
    /// Load a CSV trace (`id,arrival,duration,a,b,c,comm_frac`, header
    /// required) as a workload. Fails on unreadable or malformed files
    /// and on empty traces.
    pub fn from_csv(path: &Path) -> std::io::Result<Workload> {
        let jobs = crate::trace::io::read_csv(path)?;
        if jobs.is_empty() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{}: trace has no jobs", path.display()),
            ));
        }
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("trace")
            .to_string();
        Ok(Workload::from_jobs(name, jobs))
    }

    /// Wrap an in-memory job list as a fixed-trace workload (the pool
    /// worker's decode path; [`Workload::from_csv`] routes through here).
    pub fn from_jobs(name: String, jobs: Vec<JobSpec>) -> Workload {
        let content_hash = jobs_content_hash(&jobs);
        Workload::Csv {
            name,
            jobs: jobs.into(),
            content_hash,
        }
    }

    /// Report name: the scenario name or the trace file stem.
    pub fn name(&self) -> &str {
        match self {
            Workload::Synthetic(sc) => sc.name(),
            Workload::Csv { name, .. } => name,
        }
    }

    /// Owned cache-key component for the sweep's `TrialKey`. Synthetic
    /// scenarios are fully identified by their registry name (the name
    /// pins every generator parameter); CSV workloads add the job-list
    /// content hash so two different files with the same stem can never
    /// collide, and carry a `csv:` prefix so a file named
    /// `paper-default.csv` cannot impersonate the synthetic scenario.
    pub fn cache_key(&self) -> String {
        match self {
            Workload::Synthetic(sc) => sc.name().to_string(),
            Workload::Csv {
                name, content_hash, ..
            } => format!("csv:{name}:{content_hash:016x}"),
        }
    }

    /// The job trace for one trial, shared rather than owned: synthetic
    /// workloads generate `num_jobs` jobs from `seed` (a fresh list per
    /// call); CSV workloads hand out another reference to the one
    /// recorded realization (both knobs are ignored) — every trial and
    /// every wire decode used to deep-clone the full job list here
    /// (ROADMAP perf item, retired).
    pub fn trace(&self, num_jobs: usize, seed: u64) -> Arc<[JobSpec]> {
        match self {
            Workload::Synthetic(sc) => generate(&sc.trace_config(num_jobs, seed)).into(),
            Workload::Csv { jobs, .. } => jobs.clone(),
        }
    }

    /// Number of jobs one trial will see.
    pub fn num_jobs(&self, requested: usize) -> usize {
        match self {
            Workload::Synthetic(_) => requested,
            Workload::Csv { jobs, .. } => jobs.len(),
        }
    }

    /// Number of *distinct* trial realizations `requested` runs produce:
    /// `requested` for synthetic workloads (each seed generates a new
    /// trace), at most 1 for a fixed trace (every trial replays the same
    /// recording). Report rows use this so a trace-file sweep cannot
    /// overstate its statistical support.
    pub fn num_runs(&self, requested: usize) -> usize {
        match self {
            Workload::Synthetic(_) => requested,
            Workload::Csv { .. } => requested.min(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_and_are_distinct() {
        let mut seen = std::collections::BTreeSet::new();
        for sc in Scenario::ALL {
            assert_eq!(Scenario::parse(sc.name()), Some(sc));
            assert!(seen.insert(sc.name()), "duplicate name {}", sc.name());
            assert!(!sc.describe().is_empty());
        }
        assert_eq!(Scenario::parse("no-such-scenario"), None);
    }

    #[test]
    fn parse_list_handles_all_and_commas() {
        assert_eq!(Scenario::parse_list("all").unwrap(), Scenario::ALL.to_vec());
        assert_eq!(
            Scenario::parse_list("paper-default, comm-heavy").unwrap(),
            vec![Scenario::PaperDefault, Scenario::CommHeavy]
        );
        assert_eq!(Scenario::parse_list("paper-default,bogus"), None);
        assert_eq!(Scenario::parse_list(""), None);
    }

    #[test]
    fn paper_default_matches_default_config() {
        let a = Scenario::PaperDefault.trace_config(64, 9);
        let b = TraceConfig {
            num_jobs: 64,
            seed: 9,
            ..Default::default()
        };
        // Same generator inputs → byte-identical traces.
        assert_eq!(generate(&a), generate(&b));
    }

    #[test]
    fn every_scenario_keeps_placement_caps() {
        for sc in Scenario::ALL {
            let cfg = sc.trace_config(16, 1);
            assert_eq!(cfg.shape_rule.max_dim, ShapeRule::default().max_dim, "{sc:?}");
            assert_eq!(
                cfg.shape_rule.max_cubes4,
                ShapeRule::default().max_cubes4,
                "{sc:?}"
            );
        }
    }

    #[test]
    fn workload_wraps_scenarios_and_csv() {
        let w = Workload::Synthetic(Scenario::PaperDefault);
        assert_eq!(w.name(), "paper-default");
        assert_eq!(w.trace(12, 3).len(), 12);
        assert_eq!(w.num_jobs(12), 12);

        let trace = generate(&TraceConfig {
            num_jobs: 9,
            ..Default::default()
        });
        let tmp = std::env::temp_dir().join("rfold_workload_test.csv");
        crate::trace::io::write_csv(&tmp, &trace).unwrap();
        let w = Workload::from_csv(&tmp).unwrap();
        assert_eq!(w.name(), "rfold_workload_test");
        // Requested size and seed are ignored: the recorded trace replays.
        assert_eq!(w.trace(100, 1).len(), 9);
        assert_eq!(w.trace(100, 1), w.trace(5, 2));
        // A fixed trace is *shared*, not deep-cloned per trial.
        assert!(Arc::ptr_eq(&w.trace(100, 1), &w.trace(5, 2)));
        assert_eq!(w.num_jobs(100), 9);
        std::fs::remove_file(&tmp).ok();

        assert!(Workload::from_csv(std::path::Path::new("/no/such/file.csv")).is_err());
    }

    #[test]
    fn cache_keys_distinguish_files_by_content_not_stem() {
        let mk = |seed: u64| {
            generate(&TraceConfig {
                num_jobs: 8,
                seed,
                ..Default::default()
            })
        };
        let a = Workload::from_jobs("trace".into(), mk(1));
        let b = Workload::from_jobs("trace".into(), mk(2));
        let a2 = Workload::from_jobs("trace".into(), mk(1));
        assert_eq!(a.name(), b.name(), "same stem");
        assert_ne!(a.cache_key(), b.cache_key(), "different content must not collide");
        assert_eq!(a.cache_key(), a2.cache_key(), "same content, same key");
        // A CSV stem equal to a scenario name cannot impersonate it.
        let fake = Workload::from_jobs("paper-default".into(), mk(3));
        assert_ne!(
            fake.cache_key(),
            Workload::Synthetic(Scenario::PaperDefault).cache_key()
        );
        assert_eq!(
            Workload::Synthetic(Scenario::PaperDefault).cache_key(),
            "paper-default"
        );
    }

    #[test]
    fn comm_heavy_raises_comm_fraction() {
        let t = generate(&Scenario::CommHeavy.trace_config(80, 3));
        assert!(t.iter().all(|j| (0.45..0.80).contains(&j.comm_frac)));
        let d = generate(&Scenario::PaperDefault.trace_config(80, 3));
        assert!(d.iter().all(|j| (0.1..0.5).contains(&j.comm_frac)));
    }
}
