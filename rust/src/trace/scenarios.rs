//! Named workload scenarios: the third axis of the sweep grid.
//!
//! The paper evaluates one synthetic workload (§4). Scheduler conclusions
//! are workload-sensitive — CASSINI (arXiv:2308.00852) and the
//! ring-all-reduce contention study (arXiv:2207.07817) both stress
//! evaluating under diverse arrival burstiness and shape mixes — so the
//! registry parameterizes [`TraceConfig`]/[`ShapeRule`] into six named
//! workloads that `rfold sweep` crosses with every (policy, topology)
//! cell.
//!
//! Invariant shared by every scenario: `ShapeRule::max_dim` and
//! `max_cubes4` stay at the paper's caps, so each generated job remains
//! placeable on an empty Reconfig(4³) cluster — the property-test suite
//! (`tests/prop_trace.rs`) locks this down.

use super::gen::{ShapeRule, TraceConfig};

/// A named workload scenario.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Scenario {
    /// The paper's §4 synthetic workload, unchanged.
    PaperDefault,
    /// Strongly bursty Philly-style arrivals: fast trains of submissions
    /// separated by long lulls (Jeon et al., ATC'19, figure 4 regime).
    BurstyPhilly,
    /// Heavier log-normal duration tail: a few multi-week jobs pin
    /// resources while medians stay short.
    HeavyTailDurations,
    /// Adversarially elongated shape mix: most jobs carry one very long
    /// communicating dimension, the regime that separates folding policies
    /// from rotation-only ones.
    ElongatedAdversarial,
    /// Many small round-sized jobs arriving quickly — a high-churn
    /// fragmentation stressor.
    UniformSmall,
    /// Communication-dominated jobs: comm_frac drawn from [0.45, 0.80),
    /// amplifying placement sensitivity of JCT.
    CommHeavy,
}

impl Scenario {
    /// Every registered scenario, in stable reporting order.
    pub const ALL: [Scenario; 6] = [
        Scenario::PaperDefault,
        Scenario::BurstyPhilly,
        Scenario::HeavyTailDurations,
        Scenario::ElongatedAdversarial,
        Scenario::UniformSmall,
        Scenario::CommHeavy,
    ];

    /// Stable CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::PaperDefault => "paper-default",
            Scenario::BurstyPhilly => "bursty-philly",
            Scenario::HeavyTailDurations => "heavy-tail-durations",
            Scenario::ElongatedAdversarial => "elongated-adversarial",
            Scenario::UniformSmall => "uniform-small",
            Scenario::CommHeavy => "comm-heavy",
        }
    }

    /// One-line description for `rfold sweep` help output.
    pub fn describe(&self) -> &'static str {
        match self {
            Scenario::PaperDefault => "the paper's §4 synthetic workload",
            Scenario::BurstyPhilly => "bursty Philly-style arrival trains",
            Scenario::HeavyTailDurations => "heavier log-normal duration tail",
            Scenario::ElongatedAdversarial => "mostly-elongated adversarial shapes",
            Scenario::UniformSmall => "many small round jobs, high churn",
            Scenario::CommHeavy => "communication-dominated jobs",
        }
    }

    /// Parse a scenario name as printed by [`Scenario::name`].
    pub fn parse(s: &str) -> Option<Scenario> {
        let want = s.trim().to_ascii_lowercase();
        Scenario::ALL.into_iter().find(|sc| sc.name() == want)
    }

    /// Parse a comma-separated scenario list; `"all"` selects every
    /// scenario. Returns `None` if any entry is unknown.
    pub fn parse_list(spec: &str) -> Option<Vec<Scenario>> {
        if spec.trim().eq_ignore_ascii_case("all") {
            return Some(Scenario::ALL.to_vec());
        }
        let mut out = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            out.push(Scenario::parse(part)?);
        }
        if out.is_empty() {
            None
        } else {
            Some(out)
        }
    }

    /// The trace-generator configuration of this scenario for a given job
    /// count and seed. Seeds are shared across scenarios and cells so a
    /// sweep compares policies on identical per-trial randomness streams.
    pub fn trace_config(&self, num_jobs: usize, seed: u64) -> TraceConfig {
        let base = TraceConfig {
            num_jobs,
            seed,
            ..Default::default()
        };
        match self {
            Scenario::PaperDefault => base,
            Scenario::BurstyPhilly => TraceConfig {
                mean_interarrival: 90.0,
                burst_prob: 0.65,
                mean_lull: 9_000.0,
                ..base
            },
            Scenario::HeavyTailDurations => TraceConfig {
                dur_mu: (500.0f64).ln(),
                dur_sigma: 2.9,
                dur_max: 60.0 * 86_400.0,
                ..base
            },
            Scenario::ElongatedAdversarial => TraceConfig {
                size_scale: 700.0,
                shape_rule: ShapeRule {
                    small_p1: 0.10,
                    small_p2: 0.55,
                    large_p1: 0.0,
                    large_p2: 0.45,
                    w2d: [0.01, 0.04, 0.75, 0.20],
                    w3d: [0.04, 0.36, 0.60],
                    even_weight: 5.0,
                    ..ShapeRule::default()
                },
                ..base
            },
            Scenario::UniformSmall => TraceConfig {
                size_scale: 48.0,
                round8_prob: 0.9,
                mean_interarrival: 250.0,
                shape_rule: ShapeRule {
                    small_p1: 0.50,
                    small_p2: 0.45,
                    ..ShapeRule::default()
                },
                ..base
            },
            Scenario::CommHeavy => TraceConfig {
                comm_lo: 0.45,
                comm_hi: 0.80,
                size_scale: 500.0,
                ..base
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::generate;

    #[test]
    fn names_roundtrip_and_are_distinct() {
        let mut seen = std::collections::BTreeSet::new();
        for sc in Scenario::ALL {
            assert_eq!(Scenario::parse(sc.name()), Some(sc));
            assert!(seen.insert(sc.name()), "duplicate name {}", sc.name());
            assert!(!sc.describe().is_empty());
        }
        assert_eq!(Scenario::parse("no-such-scenario"), None);
    }

    #[test]
    fn parse_list_handles_all_and_commas() {
        assert_eq!(Scenario::parse_list("all").unwrap(), Scenario::ALL.to_vec());
        assert_eq!(
            Scenario::parse_list("paper-default, comm-heavy").unwrap(),
            vec![Scenario::PaperDefault, Scenario::CommHeavy]
        );
        assert_eq!(Scenario::parse_list("paper-default,bogus"), None);
        assert_eq!(Scenario::parse_list(""), None);
    }

    #[test]
    fn paper_default_matches_default_config() {
        let a = Scenario::PaperDefault.trace_config(64, 9);
        let b = TraceConfig {
            num_jobs: 64,
            seed: 9,
            ..Default::default()
        };
        // Same generator inputs → byte-identical traces.
        assert_eq!(generate(&a), generate(&b));
    }

    #[test]
    fn every_scenario_keeps_placement_caps() {
        for sc in Scenario::ALL {
            let cfg = sc.trace_config(16, 1);
            assert_eq!(cfg.shape_rule.max_dim, ShapeRule::default().max_dim, "{sc:?}");
            assert_eq!(
                cfg.shape_rule.max_cubes4,
                ShapeRule::default().max_cubes4,
                "{sc:?}"
            );
        }
    }

    #[test]
    fn comm_heavy_raises_comm_fraction() {
        let t = generate(&Scenario::CommHeavy.trace_config(80, 3));
        assert!(t.iter().all(|j| (0.45..0.80).contains(&j.comm_frac)));
        let d = generate(&Scenario::PaperDefault.trace_config(80, 3));
        assert!(d.iter().all(|j| (0.1..0.5).contains(&j.comm_frac)));
    }
}
