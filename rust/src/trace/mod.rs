//! Workload traces: the paper's §4 synthetic methodology.
//!
//! No public ML trace comes from a torus cluster, so the paper takes
//! inter-arrival and duration marginals from the Microsoft Philly trace
//! and overrides job sizes (truncated exponential on [1, 4096]) and shapes
//! (rule of thumb: small jobs are 1D/2D, large jobs 2D/3D). We implement
//! that generator with a statistical clone of the Philly marginals
//! (log-normal durations, exponential inter-arrivals — see DESIGN.md §4
//! for the substitution rationale) plus CSV I/O so real traces can be
//! dropped in.

pub mod gen;
pub mod io;
pub mod scenarios;

pub use gen::{ShapeRule, TraceConfig};
pub use scenarios::Scenario;

use crate::shape::JobShape;

/// One job of a workload trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobSpec {
    pub id: u64,
    /// Arrival time (seconds from trace start).
    pub arrival: f64,
    /// Contention-free run time once placed (seconds).
    pub duration: f64,
    pub shape: JobShape,
    /// Fraction of step time spent in communication (drives the placement
    /// sensitivity of JCT; sampled per job like the mixed workloads of §2).
    pub comm_frac: f64,
    /// Scheduling class for preemptive policies: higher values preempt
    /// lower ones. 0 (the default for every synthetic generator) keeps
    /// all jobs in one class, where preemption falls back to
    /// remaining-work ordering.
    pub priority: u8,
}

impl JobSpec {
    pub fn size(&self) -> usize {
        self.shape.size()
    }
}
