//! The synthetic trace generator (paper §4).

use super::JobSpec;
use crate::shape::JobShape;
use crate::util::Pcg64;

/// Shape-generation rule of thumb (§4): "small jobs (≤256 XPUs) are more
/// likely to have a shape of 1D or 2D, while large jobs (>256) are usually
/// 2D or 3D in shape".
#[derive(Clone, Copy, Debug)]
pub struct ShapeRule {
    /// Size boundary between "small" and "large".
    pub small_cutoff: usize,
    /// P(1D), P(2D) for small jobs (3D gets the rest).
    pub small_p1: f64,
    pub small_p2: f64,
    /// P(1D), P(2D) for large jobs.
    pub large_p1: f64,
    pub large_p2: f64,
    /// 2D factorization class weights:
    /// [blocky (all dims ≤ 16), one-long (one dim 17..=64),
    ///  one-xlong (one dim ≥ 65), two-long (two dims ≥ 17)].
    /// Production jobs are mostly elongated (a large DP or TP degree on a
    /// narrow second dimension) — which is what makes the static 16³
    /// torus hard (FirstFit ≈ 10%) and what separates the policies: the
    /// xlong class exceeds the longest 8-cube chain (64) so only folding
    /// or finer cubes can host it.
    pub w2d: [f64; 4],
    /// 3D class weights: [blocky, long (max dim 17..=64), xlong (≥ 65)].
    pub w3d: [f64; 3],
    /// Relative weight of shapes whose communicating dimensions are all
    /// even vs. shapes with an odd dimension. Real DP/TP/PP degrees are
    /// overwhelmingly even (powers of two dominate ML parallelism plans,
    /// §2), and evenness is exactly what makes a dimension foldable.
    pub even_weight: f64,
    /// Cap on any shape dimension. The paper's generator must bound this
    /// for Reconfig(4³) to reach 100% JCR (Table 1); 64 is the largest
    /// dimension composable from 16 chained 4³ cubes that still leaves
    /// cubes for the other axes (see DESIGN.md §4).
    pub max_dim: usize,
    /// Reject shapes needing more than this many 4³ cubes (∏⌈dᵢ/4⌉).
    /// 64 = the whole 4096-XPU cluster; keeps every generated job
    /// placeable-on-empty for Reconfig(4³), matching Table 1's 100% row.
    pub max_cubes4: usize,
}

impl Default for ShapeRule {
    fn default() -> Self {
        ShapeRule {
            small_cutoff: 256,
            small_p1: 0.35,
            small_p2: 0.60,
            large_p1: 0.02,
            large_p2: 0.55,
            w2d: [0.04, 0.07, 0.65, 0.24],
            w3d: [0.13, 0.60, 0.27],
            even_weight: 3.5,
            max_dim: 256,
            max_cubes4: 64,
        }
    }
}

/// Full trace-generation configuration.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub num_jobs: usize,
    /// Mean inter-arrival time (s) during bursts. Philly arrivals are
    /// strongly bursty (Jeon et al., ATC'19): trains of quick submissions
    /// separated by long lulls.
    pub mean_interarrival: f64,
    /// Probability an arrival continues the current burst; otherwise the
    /// gap is drawn from the lull distribution.
    pub burst_prob: f64,
    /// Mean lull gap (s) between bursts.
    pub mean_lull: f64,
    /// Log-normal duration parameters (Philly: median ≈ 13 min, heavy
    /// tail up to weeks — Jeon et al., ATC'19).
    pub dur_mu: f64,
    pub dur_sigma: f64,
    pub dur_min: f64,
    pub dur_max: f64,
    /// Truncated-exponential size scale on [1, 4096] (§4).
    pub size_scale: f64,
    /// Probability that a sampled size is rounded to a multiple of 8 —
    /// real accelerator allocations cluster on multiples of the host size
    /// (Philly/PAI both show strong 8/16-GPU modes), and round sizes are
    /// what make shapes foldable.
    pub round8_prob: f64,
    /// Per-job communication fraction, sampled uniformly from
    /// `[comm_lo, comm_hi)` — the knob behind the `comm-heavy` scenario.
    pub comm_lo: f64,
    pub comm_hi: f64,
    pub shape_rule: ShapeRule,
    /// Use the reference `packing.py` size/shape rules instead of the §4
    /// rule of thumb: sizes are integer-truncated truncated-exponential
    /// draws snapped down to multiples of 4 (1 and 2 stay as-is), and the
    /// dimensionality is picked uniformly from a size-class-dependent set
    /// (1D for size 1, 3D above 1024, 2D/3D above 128, anything below).
    pub packing_ref: bool,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            num_jobs: 512,
            mean_interarrival: 500.0,
            burst_prob: 0.25,
            mean_lull: 3800.0,
            dur_mu: (800.0f64).ln(),
            dur_sigma: 2.0,
            dur_min: 60.0,
            dur_max: 30.0 * 86_400.0,
            size_scale: 400.0,
            round8_prob: 0.75,
            comm_lo: 0.1,
            comm_hi: 0.5,
            shape_rule: ShapeRule::default(),
            packing_ref: false,
            seed: 1,
        }
    }
}

/// Cost of a shape in 4³ cubes (the Reconfig(4³) feasibility measure).
fn cubes4(s: JobShape) -> usize {
    s.dims().0.iter().map(|&d| d.div_ceil(4)).product()
}

/// Classify a factorization by dimensionality.
fn dimensionality(s: JobShape) -> usize {
    s.dimensionality().max(1)
}

/// Generate the job shape for a given size following the §4 rule of thumb.
/// Returns `None` when the size admits no acceptable factorization (the
/// caller then adjusts the size).
pub fn shape_for_size(rng: &mut Pcg64, size: usize, rule: &ShapeRule) -> Option<JobShape> {
    let all = JobShape::factorizations(size, rule.max_dim);
    let ok: Vec<JobShape> = all
        .into_iter()
        .filter(|s| cubes4(*s) <= rule.max_cubes4)
        .collect();
    if ok.is_empty() {
        return None;
    }
    let (p1, p2) = if size <= rule.small_cutoff {
        (rule.small_p1, rule.small_p2)
    } else {
        (rule.large_p1, rule.large_p2)
    };
    let u = rng.f64();
    let want = if u < p1 {
        1
    } else if u < p1 + p2 {
        2
    } else {
        3
    };
    // Prefer the requested dimensionality; fall back to the nearest one
    // that exists for this size ("if a job size can be factorized into
    // multiple shapes, we select one uniformly at random" — within the
    // elongation class sampled from the rule's weights).
    let long_dims = |s: &JobShape| s.dims().0.iter().filter(|&&d| d > 16).count();
    for d in [want, want.clamp(2, 3), 2, 1, 3] {
        let of_d: Vec<JobShape> =
            ok.iter().copied().filter(|s| dimensionality(*s) == d).collect();
        if of_d.is_empty() {
            continue;
        }
        // Sample an elongation class, renormalized over non-empty ones.
        let max_dim = |s: &JobShape| *s.dims().0.iter().max().unwrap();
        let classes: Vec<Vec<JobShape>> = match d {
            2 => vec![
                of_d.iter().copied().filter(|s| long_dims(s) == 0).collect(),
                of_d
                    .iter()
                    .copied()
                    .filter(|s| long_dims(s) == 1 && max_dim(s) <= 64)
                    .collect(),
                of_d
                    .iter()
                    .copied()
                    .filter(|s| long_dims(s) == 1 && max_dim(s) > 64)
                    .collect(),
                of_d.iter().copied().filter(|s| long_dims(s) >= 2).collect(),
            ],
            3 => vec![
                of_d.iter().copied().filter(|s| long_dims(s) == 0).collect(),
                of_d
                    .iter()
                    .copied()
                    .filter(|s| long_dims(s) >= 1 && max_dim(s) <= 64)
                    .collect(),
                of_d
                    .iter()
                    .copied()
                    .filter(|s| max_dim(s) > 64)
                    .collect(),
            ],
            _ => vec![of_d.clone()],
        };
        let weights: Vec<f64> = match d {
            2 => rule.w2d.to_vec(),
            3 => rule.w3d.to_vec(),
            _ => vec![1.0],
        };
        let total: f64 = classes
            .iter()
            .zip(&weights)
            .filter(|(c, _)| !c.is_empty())
            .map(|(_, w)| w)
            .sum();
        if total > 0.0 {
            let mut u = rng.f64() * total;
            for (c, w) in classes.iter().zip(&weights) {
                if c.is_empty() {
                    continue;
                }
                if u < *w {
                    return Some(weighted_even_choice(rng, c, rule.even_weight));
                }
                u -= w;
            }
        }
        return Some(weighted_even_choice(rng, &of_d, rule.even_weight));
    }
    Some(weighted_even_choice(rng, &ok, rule.even_weight))
}

/// Generate the job shape for a given size following the reference
/// `packing.py` rules: the dimensionality set is a hard function of the
/// size class (1D for size 1, 3D above 1024, 2D or 3D above 128, any
/// below), one dimensionality is drawn uniformly from that set, and the
/// factorization is chosen uniformly within it — no elongation classes,
/// no even-dimension weighting. The [`ShapeRule`] caps (`max_dim`,
/// `max_cubes4`) still apply so every job stays placeable on an empty
/// Reconfig(4³) cluster. Returns `None` when no factorization survives
/// the caps (the caller then adjusts the size).
pub fn shape_for_size_packing(rng: &mut Pcg64, size: usize, rule: &ShapeRule) -> Option<JobShape> {
    let ok: Vec<JobShape> = JobShape::factorizations(size, rule.max_dim)
        .into_iter()
        .filter(|s| cubes4(*s) <= rule.max_cubes4)
        .collect();
    if ok.is_empty() {
        return None;
    }
    let allowed: &[usize] = if size == 1 {
        &[1]
    } else if size > 1024 {
        &[3]
    } else if size > 128 {
        &[2, 3]
    } else {
        &[1, 2, 3]
    };
    let want = *rng.choose(allowed);
    // The wanted dimensionality can be unfactorizable (e.g. size 2 is
    // 1D-only); fall back to the nearest dimensionality that exists.
    for d in [want, 3, 2, 1] {
        let of_d: Vec<JobShape> =
            ok.iter().copied().filter(|s| dimensionality(*s) == d).collect();
        if !of_d.is_empty() {
            return Some(*rng.choose(&of_d));
        }
    }
    Some(*rng.choose(&ok))
}

/// Choose a shape, weighting all-even-dimension shapes by `even_weight`
/// (communicating dims only; size-1 dims are ignored).
fn weighted_even_choice(rng: &mut Pcg64, shapes: &[JobShape], even_weight: f64) -> JobShape {
    debug_assert!(!shapes.is_empty());
    let w = |s: &JobShape| {
        if s.dims().0.iter().all(|&d| d == 1 || d % 2 == 0) {
            even_weight
        } else {
            1.0
        }
    };
    let total: f64 = shapes.iter().map(w).sum();
    let mut u = rng.f64() * total;
    for s in shapes {
        let ws = w(s);
        if u < ws {
            return *s;
        }
        u -= ws;
    }
    *shapes.last().unwrap()
}

/// Generate a full trace.
pub fn generate(cfg: &TraceConfig) -> Vec<JobSpec> {
    let mut rng = Pcg64::new(cfg.seed, 0x7ace);
    let mut out = Vec::with_capacity(cfg.num_jobs);
    let mut t = 0.0f64;
    let mut id = 0u64;
    while out.len() < cfg.num_jobs {
        t += if rng.chance(cfg.burst_prob) {
            rng.exponential(cfg.mean_interarrival)
        } else {
            rng.exponential(cfg.mean_lull)
        };
        let duration = rng
            .lognormal(cfg.dur_mu, cfg.dur_sigma)
            .clamp(cfg.dur_min, cfg.dur_max);
        // Sample size; walk down until a shapeable size is found (primes
        // above the dim cap, for example, are unshapeable).
        let mut size = if cfg.packing_ref {
            // Reference packing.py: integer truncation of the draw, then
            // sizes above 2 snap *down* to a multiple of 4. The reference
            // snaps a sample of 3 to 0; we clamp that to 4 since a
            // zero-XPU job is meaningless.
            let s = (rng.trunc_exponential(cfg.size_scale, 1.0, 4096.0) as usize).clamp(1, 4096);
            if s > 2 {
                (s / 4 * 4).max(4)
            } else {
                s
            }
        } else {
            let s = rng.trunc_exponential(cfg.size_scale, 1.0, 4096.0).round() as usize;
            let mut s = s.clamp(1, 4096);
            if s >= 8 && rng.chance(cfg.round8_prob) {
                s = (s + 4) / 8 * 8; // nearest multiple of 8
            }
            s
        };
        let shape = loop {
            let attempt = if cfg.packing_ref {
                shape_for_size_packing(&mut rng, size, &cfg.shape_rule)
            } else {
                shape_for_size(&mut rng, size, &cfg.shape_rule)
            };
            match attempt {
                Some(s) => break s,
                // Size 4 (packing: stays on multiples of 4) and size 1
                // always factorize, so both walks terminate.
                None if cfg.packing_ref && size > 4 => size -= 4,
                None => size -= 1,
            }
        };
        let comm_frac = cfg.comm_lo + (cfg.comm_hi - cfg.comm_lo) * rng.f64();
        out.push(JobSpec {
            id,
            arrival: t,
            duration,
            shape,
            comm_frac,
            // Synthetic jobs all share the default class (no RNG draw),
            // so traces are byte-identical to pre-priority generators.
            priority: 0,
        });
        id += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig { num_jobs: 50, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a, b);
        let c = generate(&TraceConfig { seed: 2, ..cfg });
        assert_ne!(a, c);
    }

    #[test]
    fn arrivals_monotone() {
        let t = generate(&TraceConfig { num_jobs: 100, ..Default::default() });
        for w in t.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn sizes_and_durations_in_range() {
        let cfg = TraceConfig { num_jobs: 300, ..Default::default() };
        for j in generate(&cfg) {
            assert!((1..=4096).contains(&j.size()));
            assert!(j.duration >= cfg.dur_min && j.duration <= cfg.dur_max);
            assert!((0.1..=0.5).contains(&j.comm_frac));
        }
    }

    #[test]
    fn all_jobs_fit_reconfig4_on_empty() {
        // The Table 1 invariant: every generated job needs ≤ 64 4³ cubes.
        let t = generate(&TraceConfig { num_jobs: 400, ..Default::default() });
        for j in t {
            assert!(cubes4(j.shape) <= 64, "{} needs {} cubes", j.shape, cubes4(j.shape));
        }
    }

    #[test]
    fn small_jobs_skew_low_dimensional() {
        let t = generate(&TraceConfig { num_jobs: 2000, seed: 9, ..Default::default() });
        let small: Vec<_> = t.iter().filter(|j| j.size() <= 256 && j.size() > 1).collect();
        let large: Vec<_> = t.iter().filter(|j| j.size() > 256).collect();
        assert!(!small.is_empty() && !large.is_empty());
        let frac_3d = |v: &[&JobSpec]| {
            v.iter().filter(|j| j.shape.dimensionality() == 3).count() as f64 / v.len() as f64
        };
        assert!(
            frac_3d(&large) > frac_3d(&small),
            "large jobs must be more often 3D: {} vs {}",
            frac_3d(&large),
            frac_3d(&small)
        );
    }

    #[test]
    fn packing_ref_sizes_snap_to_multiples_of_four() {
        let t = generate(&TraceConfig {
            num_jobs: 400,
            packing_ref: true,
            seed: 5,
            ..Default::default()
        });
        for j in &t {
            let s = j.size();
            assert!(
                s == 1 || s == 2 || s % 4 == 0,
                "packing-ref size {s} is neither 1, 2, nor a multiple of 4"
            );
            assert!((1..=4096).contains(&s));
        }
        // The snap keeps real mass on the small non-multiple sizes too.
        assert!(t.iter().any(|j| j.size() % 4 == 0));
    }

    #[test]
    fn packing_ref_dimension_rules_follow_size_class() {
        let t = generate(&TraceConfig {
            num_jobs: 1500,
            packing_ref: true,
            seed: 11,
            ..Default::default()
        });
        for j in &t {
            let d = j.shape.dimensionality().max(1);
            let s = j.size();
            if s == 1 {
                assert_eq!(d, 1, "size-1 job must be 1D, got {}", j.shape);
            } else if s > 1024 {
                assert_eq!(d, 3, "size {s} must be 3D, got {}", j.shape);
            } else if s > 128 {
                assert!(d >= 2, "size {s} must be 2D/3D, got {}", j.shape);
            }
            assert!(cubes4(j.shape) <= 64, "{} breaks the cube cap", j.shape);
            assert!(j.shape.dims().0.iter().all(|&dim| dim <= 256));
        }
    }

    #[test]
    fn packing_ref_is_deterministic_and_differs_from_default() {
        let cfg = TraceConfig {
            num_jobs: 80,
            packing_ref: true,
            ..Default::default()
        };
        assert_eq!(generate(&cfg), generate(&cfg));
        let plain = generate(&TraceConfig {
            packing_ref: false,
            ..cfg
        });
        assert_ne!(generate(&cfg), plain, "the reference rules must change the mix");
    }

    #[test]
    fn shape_for_size_packing_respects_caps() {
        let mut rng = Pcg64::seeded(13);
        let rule = ShapeRule::default();
        for size in [1usize, 2, 4, 128, 132, 1024, 2048, 4096] {
            let s = shape_for_size_packing(&mut rng, size, &rule)
                .unwrap_or_else(|| panic!("size {size} must factorize"));
            assert_eq!(s.size(), size);
            assert!(s.dims().0.iter().all(|&d| d <= rule.max_dim));
            assert!(cubes4(s) <= rule.max_cubes4);
        }
        // A large prime still can't be shaped under the cap.
        assert!(shape_for_size_packing(&mut rng, 4093, &rule).is_none());
    }

    #[test]
    fn shape_for_size_respects_caps() {
        let mut rng = Pcg64::seeded(3);
        let rule = ShapeRule::default();
        for size in [1usize, 7, 64, 100, 512, 4096, 4093] {
            if let Some(s) = shape_for_size(&mut rng, size, &rule) {
                assert_eq!(s.size(), size);
                assert!(s.dims().0.iter().all(|&d| d <= rule.max_dim));
                assert!(cubes4(s) <= rule.max_cubes4);
            }
        }
        // A large prime can't be shaped under the cap.
        assert!(shape_for_size(&mut rng, 4093, &rule).is_none());
    }
}
